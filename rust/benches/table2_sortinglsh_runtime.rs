//! Bench: regenerates the paper's Table 2 (relative total running time, SortingLSH-based).
//! Run: `cargo bench --bench table2_sortinglsh_runtime` (STARS_BENCH_FULL=1 for paper-size R).
use stars::coordinator::experiments::{table12, ExpConfig};

fn main() {
    let cfg = ExpConfig::default();
    let (secs, _) = stars::bench::time_once(|| table12(&cfg, true));
    println!("\n[table2_sortinglsh_runtime] completed in {}", stars::bench::fmt_secs(secs));
}
