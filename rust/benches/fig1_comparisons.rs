//! Bench: regenerates the paper's Figure 1 (number of comparisons).
//! Run: `cargo bench --bench fig1_comparisons` (STARS_BENCH_FULL=1 for paper-size R).
use stars::coordinator::experiments::{fig1, ExpConfig};

fn main() {
    let cfg = ExpConfig::default();
    let (secs, _) = stars::bench::time_once(|| fig1(&cfg));
    println!("\n[fig1_comparisons] completed in {}", stars::bench::fmt_secs(secs));
}
