//! Serving-path benchmarks — the perf harness for `stars::serve`
//! (EXPERIMENTS.md §Serve).
//!
//! Run: `cargo bench --bench servebench`
//!
//! Besides the human-readable table, the run emits machine-readable
//! `BENCH_serve.json` at the repo root (override with `STARS_BENCH_OUT`) so
//! the serving trajectory — QPS, latency percentiles, recall@k — is tracked
//! across PRs alongside `BENCH_scoring.json` and `BENCH_sketch.json`:
//!
//! * snapshot build (graph build + index export) wall time;
//! * batched query throughput (QPS) at the host worker count;
//! * single-query latency distribution (p50/p99);
//! * recall@10 against brute-force scoring, and the brute-force QPS the
//!   two-hop route-and-expand path replaces;
//! * streaming inserts + **incremental-compaction latency vs delta size**
//!   (the O(delta) claim), plus one full rebuild for the speedup ratio and
//!   the final snapshot's memory telemetry;
//! * the **quantized tier** end-to-end (same build, `ServeConfig::
//!   quantized`): int8-first QPS/latency/recall next to the f32 numbers,
//!   with the recall ratio the 0.98 serve-integration gate tracks
//!   (EXPERIMENTS.md §Quant table convention);
//! * the **multi-shard scaling curve** (EXPERIMENTS.md §Sharding table
//!   convention): the same snapshot served at 1/2/4/8 shards through the
//!   fence-partitioned scatter-gather engine, answers asserted
//!   bit-identical across shard counts;
//! * the **durability probe** (EXPERIMENTS.md §Persistence): WAL append
//!   cost under the `os` and `always` fsync policies, delta-tail seal
//!   cost, snapshot file size, and the restart-without-rebuild pair
//!   (cold-start wall + per-record replay), with the recovered engine's
//!   answers asserted bit-identical to the uncrashed one.

use stars::bench::{fmt_count, fmt_secs, time_once, time_runs, Table};
use stars::obs::Histogram;
use stars::data::synth;
use stars::lsh::SimHash;
use stars::serve::{
    brute_force_topk, recall_against, AdmissionConfig, CompactionMode, DurableStore, FrontDoor,
    FsyncPolicy, QueryEngine, ServeConfig, ServeMeasure, ShardedEngine,
};
use stars::sim::CosineSim;
use stars::stars::{Algorithm, BuildParams, StarsBuilder};
use stars::util::fault::FaultPlan;
use stars::util::json::Json;
use stars::util::pool;
use std::path::PathBuf;

const N: usize = 50_000;
const DIM: usize = 100;
const K: usize = 10;
const BATCH_QUERIES: usize = 2000;
const LATENCY_QUERIES: usize = 500;
const RECALL_QUERIES: usize = 200;

/// Where to write the machine-readable report: `STARS_BENCH_OUT`, else the
/// repo root (benches run with CWD = rust/, so the root is one level up).
fn bench_out_path() -> PathBuf {
    if let Ok(p) = std::env::var("STARS_BENCH_OUT") {
        return PathBuf::from(p);
    }
    if std::path::Path::new("../ROADMAP.md").exists() {
        PathBuf::from("../BENCH_serve.json")
    } else {
        PathBuf::from("BENCH_serve.json")
    }
}

fn main() {
    let workers = pool::default_workers();
    let mut table = Table::new(&["stage", "n", "median", "rate"]);

    let ds = synth::gaussian_mixture(N, DIM, 100, 0.1, 42);
    let family = SimHash::new(DIM, 14, 7);
    let params = BuildParams::threshold_mode(Algorithm::LshStars)
        .sketches(8)
        .leaders(10)
        .threshold(0.5);

    // Snapshot build: graph + index export.
    let (build_s, (out, index)) = time_once(|| {
        StarsBuilder::new(&ds)
            .similarity(&CosineSim)
            .hash(&family)
            .params(params.clone())
            .build_indexed(ServeConfig::default().route_reps(8).compact_limit(0))
    });
    let router_entries = index.router().num_entries();
    table.row(vec![
        "snapshot build (graph + index)".into(),
        fmt_count(N as u64),
        fmt_secs(build_s),
        format!("{} router entries", fmt_count(router_entries as u64)),
    ]);
    let engine =
        QueryEngine::new(index, &family, ServeMeasure::Cosine, params.clone()).workers(workers);

    // Batched throughput.
    let qids: Vec<u32> = (0..BATCH_QUERIES as u32).map(|i| i * (N / BATCH_QUERIES) as u32).collect();
    let queries = ds.subset(&qids);
    let batch = time_runs(1, 5, || {
        std::hint::black_box(engine.query(&queries, K));
    });
    let qps = BATCH_QUERIES as f64 / batch.median();
    table.row(vec![
        format!("batched queries (k={K}, {workers} workers)"),
        fmt_count(BATCH_QUERIES as u64),
        fmt_secs(batch.median()),
        format!("{}/s", fmt_count(qps as u64)),
    ]);

    // Single-query latency distribution (log-bucketed histogram in µs —
    // the obs machinery the serve registry itself records into).
    let lat_hist = Histogram::new();
    for qi in 0..LATENCY_QUERIES {
        let one = queries.subset(&[(qi % BATCH_QUERIES) as u32]);
        let (s, _) = time_once(|| engine.query(&one, K));
        lat_hist.record((s * 1e6) as u64);
    }
    let lat = lat_hist.snapshot();
    let (p50, p99) = (
        lat.quantile(0.50) as f64 / 1e6,
        lat.quantile(0.99) as f64 / 1e6,
    );
    table.row(vec![
        "single-query latency".into(),
        fmt_count(LATENCY_QUERIES as u64),
        format!("p50 {}", fmt_secs(p50)),
        format!("p99 {}", fmt_secs(p99)),
    ]);

    // Recall@10 vs brute force, plus the brute-force rate it replaces.
    let rqueries = ds.subset(&qids[..RECALL_QUERIES]);
    let got = engine.query(&rqueries, K);
    let (bf_s, truth) = time_once(|| brute_force_topk(&ds, &rqueries, ServeMeasure::Cosine, K, workers));
    let recall = truth
        .iter()
        .zip(got.iter())
        .map(|(t, g)| recall_against(t, g))
        .sum::<f64>()
        / RECALL_QUERIES as f64;
    let bf_qps = RECALL_QUERIES as f64 / bf_s;
    table.row(vec![
        format!("recall@{K} vs brute force"),
        fmt_count(RECALL_QUERIES as u64),
        format!("{recall:.4}"),
        format!("brute {}/s", fmt_count(bf_qps as u64)),
    ]);

    // Streaming inserts + incremental compaction latency vs delta size:
    // the O(delta) claim, measured. Each round streams `delta` fresh-ish
    // points in and folds them through the incremental path.
    let mut insert_per_s = 0.0;
    let mut compaction_rows: Vec<Json> = Vec::new();
    for &delta in &[100usize, 1000, 10_000] {
        let (insert_s, _) = time_once(|| {
            for i in 0..delta {
                engine.insert(Some(ds.row(i % N)), None);
            }
        });
        insert_per_s = delta as f64 / insert_s.max(1e-12);
        let (inc_s, rep) = time_once(|| {
            engine
                .compact_with(CompactionMode::Incremental)
                .expect("delta pending")
        });
        table.row(vec![
            format!("incremental compact (delta={delta})"),
            fmt_count(engine.num_indexed() as u64),
            fmt_secs(inc_s),
            format!(
                "{} cands, {} buckets",
                fmt_count(rep.candidates_scored),
                fmt_count(rep.affected_buckets as u64)
            ),
        ]);
        compaction_rows.push(Json::obj(vec![
            ("delta", Json::from(delta)),
            ("incremental_s", Json::from(inc_s)),
            ("candidates_scored", Json::from(rep.candidates_scored)),
            ("affected_buckets", Json::from(rep.affected_buckets)),
            ("edges_emitted", Json::from(rep.edges_emitted)),
        ]));
    }
    // One full rebuild at the same delta size for the speedup ratio.
    for i in 0..1000 {
        engine.insert(Some(ds.row(i % N)), None);
    }
    let (full_s, _) = time_once(|| {
        engine
            .compact_with(CompactionMode::Full)
            .expect("delta pending")
    });
    table.row(vec![
        "full-rebuild compact (delta=1000)".into(),
        fmt_count(engine.num_indexed() as u64),
        fmt_secs(full_s),
        format!("{}/s insert", fmt_count(insert_per_s as u64)),
    ]);

    // Quantized tier: a second engine over the same graph with the SQ8
    // first pass on (rescore c = 4·k), measured with the same protocol so
    // the int8-vs-f32 pair reads off one file (§Quant table convention).
    let (_, qindex) = StarsBuilder::new(&ds)
        .similarity(&CosineSim)
        .hash(&family)
        .params(params.clone())
        .build_indexed(
            ServeConfig::default()
                .route_reps(8)
                .compact_limit(0)
                .quantized(4),
        );
    let qstats = qindex.stats();
    let qengine =
        QueryEngine::new(qindex, &family, ServeMeasure::Cosine, params.clone()).workers(workers);
    let qbatch = time_runs(1, 5, || {
        std::hint::black_box(qengine.query(&queries, K));
    });
    let q_qps = BATCH_QUERIES as f64 / qbatch.median();
    table.row(vec![
        format!("quantized batched queries (c={})", 4 * K),
        fmt_count(BATCH_QUERIES as u64),
        fmt_secs(qbatch.median()),
        format!("{}/s", fmt_count(q_qps as u64)),
    ]);
    let qlat_hist = Histogram::new();
    for qi in 0..LATENCY_QUERIES {
        let one = queries.subset(&[(qi % BATCH_QUERIES) as u32]);
        let (s, _) = time_once(|| qengine.query(&one, K));
        qlat_hist.record((s * 1e6) as u64);
    }
    let qlat = qlat_hist.snapshot();
    let (q_p50, q_p99) = (
        qlat.quantile(0.50) as f64 / 1e6,
        qlat.quantile(0.99) as f64 / 1e6,
    );
    let q_got = qengine.query(&rqueries, K);
    let q_recall = truth
        .iter()
        .zip(q_got.iter())
        .map(|(t, g)| recall_against(t, g))
        .sum::<f64>()
        / RECALL_QUERIES as f64;
    table.row(vec![
        format!("quantized recall@{K} vs brute force"),
        fmt_count(RECALL_QUERIES as u64),
        format!("{q_recall:.4}"),
        format!("{:.4} of f32", q_recall / recall.max(1e-12)),
    ]);

    // Admission front door over the quantized engine: one unloaded sweep,
    // one sweep against a full backlog (shed at the door), one at the
    // degrade threshold (served on the reduced-rescore quantized tier) —
    // the whole ladder's counters from three deterministic probes.
    const QUEUE_LIMIT: usize = 8;
    let door = FrontDoor::new(
        &qengine,
        AdmissionConfig::default()
            .queue_limit(QUEUE_LIMIT)
            .degraded_rescore(2),
    );
    let _ = door.query(&queries, K);
    {
        let full: Vec<_> = (0..QUEUE_LIMIT).map(|_| door.acquire()).collect();
        let _ = door.query(&queries, K);
        drop(full);
    }
    {
        // depth = held + the query itself = ceil(degrade_at · limit).
        let held =
            ((door.config().degrade_at * QUEUE_LIMIT as f64).ceil() as usize).saturating_sub(1);
        let partial: Vec<_> = (0..held).map(|_| door.acquire()).collect();
        let _ = door.query(&queries, K);
        drop(partial);
    }
    let adm = door.stats();
    table.row(vec![
        format!("front door (limit={QUEUE_LIMIT}, overload probe)"),
        fmt_count(adm.admitted + adm.shed()),
        format!("{} degraded", adm.degraded),
        format!("{} shed", adm.shed()),
    ]);

    // Fault-injected build: the same recipe under a pinned light schedule —
    // measures the recovery machinery's wall-clock overhead and proves the
    // output is bit-identical while the retry counters are nonzero.
    const FAULT_SPEC: &str = "seed=7,crash=0.02,delay=0.01:5,corrupt=0.02,max_failures=2";
    let fplan = FaultPlan::parse(FAULT_SPEC).expect("bench fault spec");
    let (fault_build_s, fout) = time_once(|| {
        StarsBuilder::new(&ds)
            .similarity(&CosineSim)
            .hash(&family)
            .params(params.clone())
            .faults(fplan)
            .build()
    });
    assert_eq!(
        fout.graph.edges(),
        out.graph.edges(),
        "faulted build diverged from the clean build"
    );
    let fc = fout.report.faults;
    table.row(vec![
        "faulted build (bit-identical)".into(),
        fmt_count(N as u64),
        fmt_secs(fault_build_s),
        format!(
            "{} retries, {} csum refetch",
            fmt_count(fc.task_retries),
            fmt_count(fc.corruption_retries)
        ),
    ]);

    // Multi-shard scaling curve: the same snapshot served through the
    // fence-partitioned scatter-gather engine at 1/2/4/8 shards. The
    // sharded build forces max_candidates to 0 (the shard-invariance
    // config), so this is a separate snapshot from the capped f32 engine
    // above; the per-count answers are asserted bit-identical, which is
    // the contract `tests/shard_parity.rs` proves exhaustively.
    let (_, sbase) = StarsBuilder::new(&ds)
        .similarity(&CosineSim)
        .hash(&family)
        .params(params.clone())
        .build_sharded(1, ServeConfig::default().route_reps(8).compact_limit(0));
    let shard_counts = [1usize, 2, 4, 8];
    let mut s_qps: Vec<f64> = Vec::new();
    let mut s_p50: Vec<f64> = Vec::new();
    let mut s_p99: Vec<f64> = Vec::new();
    let mut s_reference: Option<Vec<Vec<(u32, f32)>>> = None;
    for &ns in &shard_counts {
        let seng = ShardedEngine::new(
            sbase.resharded(ns),
            &family,
            ServeMeasure::Cosine,
            params.clone(),
        )
        .workers(workers);
        let sbatch = time_runs(1, 3, || {
            std::hint::black_box(seng.query(&queries, K));
        });
        let sqps = BATCH_QUERIES as f64 / sbatch.median();
        let sh = Histogram::new();
        for qi in 0..LATENCY_QUERIES.min(200) {
            let one = queries.subset(&[(qi % BATCH_QUERIES) as u32]);
            let (s, _) = time_once(|| seng.query(&one, K));
            sh.record((s * 1e6) as u64);
        }
        let slat = sh.snapshot();
        s_qps.push(sqps);
        s_p50.push(slat.quantile(0.50) as f64 / 1e3);
        s_p99.push(slat.quantile(0.99) as f64 / 1e3);
        let s_got = seng.query(&rqueries, K);
        match &s_reference {
            None => s_reference = Some(s_got),
            Some(r) => assert_eq!(r, &s_got, "sharded answers diverged at {ns} shards"),
        }
        table.row(vec![
            format!("sharded queries ({ns} shards, bit-identical)"),
            fmt_count(BATCH_QUERIES as u64),
            fmt_secs(sbatch.median()),
            format!("{}/s", fmt_count(sqps as u64)),
        ]);
    }

    // Durability probe: a smaller build (5k points) so the WAL/seal/replay
    // costs dominate the numbers instead of build wall. Dir A measures the
    // buffered `os` policy end to end — checkpoint, 4096 WAL'd inserts
    // (sealing every 256), recover, replay, bit-identity check; dir B
    // isolates the `always` policy's per-append fsync cost.
    const DUR_INSERTS: usize = 4096;
    const FSYNC_ROUNDS: usize = 64;
    const SEAL_LIMIT: usize = 256;
    let dds = ds.subset(&(0..5000u32).collect::<Vec<_>>());
    let dcfg = ServeConfig::default()
        .route_reps(8)
        .compact_limit(0)
        .seal_limit(SEAL_LIMIT);
    let (_, dindex) = StarsBuilder::new(&dds)
        .similarity(&CosineSim)
        .hash(&family)
        .params(params.clone())
        .build_indexed(dcfg.clone());
    let dengine =
        QueryEngine::new(dindex, &family, ServeMeasure::Cosine, params.clone()).workers(workers);
    let dur_dir = std::env::temp_dir().join(format!("stars-servebench-dur-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dur_dir);
    let mut dstore = DurableStore::open(&dur_dir, FsyncPolicy::Os).expect("state dir");
    let snap_path = dstore.checkpoint(&dengine.snapshot()).expect("checkpoint");
    let snapshot_bytes = std::fs::metadata(&snap_path).map(|m| m.len()).unwrap_or(0);
    let mut wal_ns = 0u64;
    for i in 0..DUR_INSERTS {
        let row = dds.row(i % dds.len());
        let gid = dengine.next_gid();
        let t = std::time::Instant::now();
        dstore.log_insert(gid, Some(row), None).expect("wal append");
        wal_ns += t.elapsed().as_nanos() as u64;
        dengine.insert(Some(row), None);
    }
    dstore.sync().expect("wal sync");
    let wal_append_ns = wal_ns as f64 / DUR_INSERTS as f64;
    let seal_us =
        stars::obs::registry().histogram("stars_serve_seal_us").snapshot().quantile(0.5) as f64;
    table.row(vec![
        format!("WAL append (fsync=os, seal every {SEAL_LIMIT})"),
        fmt_count(DUR_INSERTS as u64),
        format!("{wal_append_ns:.0} ns/append"),
        format!("seal p50 {seal_us:.0} µs"),
    ]);
    // Dir B: the same appends under Always — every record pays an fsync.
    let dur_dir_b =
        std::env::temp_dir().join(format!("stars-servebench-dur-b-{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dur_dir_b);
    let mut bstore = DurableStore::open(&dur_dir_b, FsyncPolicy::Always).expect("state dir");
    bstore.checkpoint(&dengine.snapshot()).expect("checkpoint");
    let base_b = dengine.next_gid();
    let (fsync_s, _) = time_once(|| {
        for i in 0..FSYNC_ROUNDS {
            bstore
                .log_insert(base_b + i as u32, Some(dds.row(i % dds.len())), None)
                .expect("wal append");
        }
    });
    let wal_fsync_always_ns = fsync_s * 1e9 / FSYNC_ROUNDS as f64;
    table.row(vec![
        "WAL append (fsync=always)".into(),
        fmt_count(FSYNC_ROUNDS as u64),
        format!("{wal_fsync_always_ns:.0} ns/append"),
        "durable per record".into(),
    ]);
    // Restart without rebuild: recover dir A (snapshot + 4096-record WAL
    // suffix), replay through a fresh engine, and require bit-identical
    // answers to the uncrashed engine.
    let dqueries = dds.subset(&(0..100u32).collect::<Vec<_>>());
    let d_ref = dengine.query(&dqueries, K);
    let mut rstore = DurableStore::open(&dur_dir, FsyncPolicy::Os).expect("state dir");
    let (rec_s, rec) = time_once(|| {
        rstore
            .recover(&family, dcfg.clone(), workers)
            .expect("recover")
            .expect("snapshot present")
    });
    let cold_start_ms = rec_s * 1e3;
    let replay_n = rec.replay.len();
    let rengine =
        QueryEngine::new(rec.index, &family, ServeMeasure::Cosine, params.clone()).workers(workers);
    let (replay_s, _) = time_once(|| {
        for r in &rec.replay {
            rengine.insert(r.row.as_deref(), r.set.clone());
        }
    });
    let replay_ns_per_record = replay_s * 1e9 / replay_n.max(1) as f64;
    let recovered_ok = rengine.query(&dqueries, K) == d_ref;
    assert!(recovered_ok, "recovered serving diverged from the uncrashed engine");
    table.row(vec![
        format!("recover + replay ({replay_n} records, bit-identical)"),
        fmt_count(dengine.num_indexed() as u64),
        format!("cold start {cold_start_ms:.1} ms"),
        format!("{replay_ns_per_record:.0} ns/record"),
    ]);
    let _ = std::fs::remove_dir_all(&dur_dir);
    let _ = std::fs::remove_dir_all(&dur_dir_b);

    table.print();

    let doc = Json::obj(vec![
        // v8: added the `durability` object — WAL append cost under both
        // fsync policies, seal cost, snapshot bytes, and the
        // restart-without-rebuild pair (cold-start wall + replay
        // ns/record), with `recovered_bit_identical` asserted in-run.
        // v7: added the `sharding` object — the multi-shard scaling curve
        // (QPS/p50/p99 vs shard count) through the fence-partitioned
        // scatter-gather engine, answers asserted bit-identical across
        // counts. v6: renamed `schema` → `schema_version` (CI bench-check
        // gate), added `data_status` and the `phases` object (the build's
        // self-profile from CostReport::phases; latency percentiles now
        // come from the obs histogram — ≤6.25% bucket error). v5: added
        // the `admission` and `faults` objects. v4: added the `quantized`
        // object (int8 first-pass tier next to its f32 twin).
        ("schema_version", Json::from("stars-bench-serve/v8")),
        (
            "data_status",
            Json::from("measured by `cargo bench --bench servebench` on this host"),
        ),
        ("bench", Json::from("servebench")),
        ("workers", Json::from(workers)),
        // Which SIMD lanes served every query in this file — p50/p99 are
        // only comparable across runs pinned to the same backend.
        (
            "simd_backend",
            Json::from(stars::util::simd::active().name()),
        ),
        (
            "dataset",
            Json::from(format!("gaussian_mixture({N}, {DIM}, 100, 0.1, 42)")),
        ),
        ("algorithm", Json::from("lsh+stars")),
        ("k", Json::from(K)),
        ("edges", Json::from(out.graph.num_edges())),
        ("router_entries", Json::from(router_entries)),
        ("build_s", Json::from(build_s)),
        // Build self-profile: phase path → {count, secs, busy_secs, bytes}
        // (EXPERIMENTS.md §Observability explains how to read it).
        ("phases", out.report.phases.to_json()),
        ("batch_queries", Json::from(BATCH_QUERIES)),
        ("batch_qps", Json::from(qps)),
        ("latency_p50_ms", Json::from(p50 * 1e3)),
        ("latency_p99_ms", Json::from(p99 * 1e3)),
        ("recall_at_10", Json::from(recall)),
        ("brute_force_qps", Json::from(bf_qps)),
        ("insert_per_s", Json::from(insert_per_s)),
        ("compaction_incremental", Json::Arr(compaction_rows)),
        ("compact_full_s", Json::from(full_s)),
        (
            "snapshot",
            engine.snapshot().stats().to_json(),
        ),
        (
            "quantized",
            Json::obj(vec![
                ("rescore_c", Json::from(4 * K)),
                ("batch_qps", Json::from(q_qps)),
                ("latency_p50_ms", Json::from(q_p50 * 1e3)),
                ("latency_p99_ms", Json::from(q_p99 * 1e3)),
                ("recall_at_10", Json::from(q_recall)),
                (
                    "recall_ratio_vs_f32",
                    Json::from(q_recall / recall.max(1e-12)),
                ),
                ("bytes_per_row", Json::from(qstats.bytes_per_row)),
                ("quant_bytes", Json::from(qstats.quant_bytes)),
            ]),
        ),
        (
            "sharding",
            Json::obj(vec![
                (
                    "shard_counts",
                    Json::Arr(shard_counts.iter().map(|&c| Json::from(c)).collect()),
                ),
                (
                    "batch_qps",
                    Json::Arr(s_qps.iter().map(|&v| Json::from(v)).collect()),
                ),
                (
                    "latency_p50_ms",
                    Json::Arr(s_p50.iter().map(|&v| Json::from(v)).collect()),
                ),
                (
                    "latency_p99_ms",
                    Json::Arr(s_p99.iter().map(|&v| Json::from(v)).collect()),
                ),
            ]),
        ),
        (
            "durability",
            Json::obj(vec![
                ("wal_records", Json::from(DUR_INSERTS)),
                ("wal_append_ns", Json::from(wal_append_ns)),
                ("wal_fsync_always_ns", Json::from(wal_fsync_always_ns)),
                ("seal_limit", Json::from(SEAL_LIMIT)),
                ("seal_us", Json::from(seal_us)),
                ("snapshot_bytes", Json::from(snapshot_bytes as usize)),
                ("cold_start_ms", Json::from(cold_start_ms)),
                ("replay_ns_per_record", Json::from(replay_ns_per_record)),
                ("recovered_bit_identical", Json::from(recovered_ok)),
            ]),
        ),
        ("admission", adm.to_json()),
        (
            "faults",
            Json::obj(vec![
                ("plan", Json::from(FAULT_SPEC)),
                ("build_s", Json::from(fault_build_s)),
                ("counters", fc.to_json()),
            ]),
        ),
    ]);
    let path = bench_out_path();
    match std::fs::write(&path, doc.to_pretty()) {
        Ok(()) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("failed to write {}: {e}", path.display()),
    }
}
