//! Microbenchmarks of the hot-path primitives — the L3 profiling harness
//! for the performance pass (EXPERIMENTS.md §Perf).
//!
//! Run: `cargo bench --bench microbench`

use stars::ampc::CostLedger;
use stars::bench::{fmt_count, fmt_secs, time_runs, Table};
use stars::data::synth;
use stars::lsh::{sorted_order, LshFamily, SimHash, WeightedMinHash};
use stars::sim::{CosineSim, Similarity};
use stars::stars::group_buckets;
use stars::util::rng::Rng;

fn main() {
    let mut table = Table::new(&["primitive", "n", "median", "throughput"]);
    let ds = synth::gaussian_mixture(100_000, 100, 100, 0.1, 42);

    // Cosine scoring: leader vs 10k candidates, batched.
    {
        let cands: Vec<u32> = (1..10_001).collect();
        let mut out = Vec::new();
        let stats = time_runs(3, 15, || {
            CosineSim.sim_batch(&ds, 0, &cands, &mut out);
            std::hint::black_box(&out);
        });
        table.row(vec![
            "cosine sim_batch (d=100)".into(),
            fmt_count(cands.len() as u64),
            fmt_secs(stats.median()),
            format!(
                "{}/s",
                fmt_count((cands.len() as f64 / stats.median()) as u64)
            ),
        ]);
    }

    // SimHash sketching: one repetition over 100k points.
    {
        let h = SimHash::new(100, 16, 7);
        let stats = time_runs(1, 5, || {
            std::hint::black_box(h.bucket_keys(&ds, 0));
        });
        table.row(vec![
            "simhash bucket_keys (M=16)".into(),
            fmt_count(ds.len() as u64),
            fmt_secs(stats.median()),
            format!(
                "{}/s",
                fmt_count((ds.len() as f64 / stats.median()) as u64)
            ),
        ]);
    }

    // Weighted MinHash sketching on sets.
    {
        let sets = synth::zipf_sets(20_000, &synth::ZipfSetsParams::default(), 3);
        let h = WeightedMinHash::new(3, 9);
        let stats = time_runs(1, 5, || {
            std::hint::black_box(h.bucket_keys(&sets, 0));
        });
        table.row(vec![
            "wminhash bucket_keys (M=3)".into(),
            fmt_count(sets.len() as u64),
            fmt_secs(stats.median()),
            format!(
                "{}/s",
                fmt_count((sets.len() as f64 / stats.median()) as u64)
            ),
        ]);
    }

    // Bucket grouping of 100k keys.
    {
        let h = SimHash::new(100, 16, 7);
        let keys = h.bucket_keys(&ds, 0);
        let stats = time_runs(2, 10, || {
            std::hint::black_box(group_buckets(&keys));
        });
        table.row(vec![
            "group_buckets".into(),
            fmt_count(keys.len() as u64),
            fmt_secs(stats.median()),
            format!(
                "{}/s",
                fmt_count((keys.len() as f64 / stats.median()) as u64)
            ),
        ]);
    }

    // SortingLSH: full sorted order (M=30) over 100k points.
    {
        let h = SimHash::new(100, 30, 7);
        let stats = time_runs(1, 3, || {
            std::hint::black_box(sorted_order(&h, &ds, 0));
        });
        table.row(vec![
            "sorted_order (M=30, matrix)".into(),
            fmt_count(ds.len() as u64),
            fmt_secs(stats.median()),
            format!(
                "{}/s",
                fmt_count((ds.len() as f64 / stats.median()) as u64)
            ),
        ]);
        // Packed-u64 fast path (what the scoring loop actually uses).
        let stats = time_runs(1, 3, || {
            std::hint::black_box(stars::lsh::sorting::sorted_indices(&h, &ds, 0));
        });
        table.row(vec![
            "sorted_indices (M=30, packed)".into(),
            fmt_count(ds.len() as u64),
            fmt_secs(stats.median()),
            format!(
                "{}/s",
                fmt_count((ds.len() as f64 / stats.median()) as u64)
            ),
        ]);
    }

    // TeraSort 1M u64 records.
    {
        let mut rng = Rng::new(5);
        let items: Vec<u64> = (0..1_000_000).map(|_| rng.next_u64()).collect();
        let ledger = CostLedger::new(8);
        let stats = time_runs(1, 3, || {
            std::hint::black_box(stars::ampc::terasort::terasort(
                items.clone(),
                8,
                8,
                |x| *x,
                &ledger,
                1,
            ));
        });
        table.row(vec![
            "terasort u64 x8 workers".into(),
            fmt_count(items.len() as u64),
            fmt_secs(stats.median()),
            format!(
                "{}/s",
                fmt_count((items.len() as f64 / stats.median()) as u64)
            ),
        ]);
    }

    // PJRT learned-model scoring throughput (if artifacts exist).
    if let Ok(meta) =
        stars::runtime::ArtifactMeta::load(&stars::runtime::ArtifactMeta::default_dir())
    {
        let engine = stars::runtime::Engine::cpu().unwrap();
        let model = stars::runtime::LearnedModel::load(&engine, &meta).unwrap();
        let prods = synth::products(2048, &synth::ProductsParams::default(), 42);
        let pairs: Vec<(u32, u32)> = (0..1024u32).map(|i| (i, i + 1024)).collect();
        let stats = time_runs(1, 5, || {
            std::hint::black_box(model.score(&prods, &pairs).unwrap());
        });
        table.row(vec![
            "learned model score (PJRT)".into(),
            fmt_count(pairs.len() as u64),
            fmt_secs(stats.median()),
            format!(
                "{} pairs/s",
                fmt_count((pairs.len() as f64 / stats.median()) as u64)
            ),
        ]);

        let scorer = stars::runtime::CosineScorer::load(&engine, &meta).unwrap();
        let leaders: Vec<f32> = ds.dense[..8 * 100].to_vec();
        let cands: Vec<f32> = ds.dense[..4096 * 100].to_vec();
        let stats = time_runs(1, 5, || {
            std::hint::black_box(scorer.score(&leaders, 8, &cands, 4096, 100).unwrap());
        });
        table.row(vec![
            "cosine scorer (PJRT, 8x4096)".into(),
            fmt_count(8 * 4096),
            fmt_secs(stats.median()),
            format!(
                "{} scores/s",
                fmt_count((8.0 * 4096.0 / stats.median()) as u64)
            ),
        ]);
    } else {
        println!("(PJRT rows skipped: run `make artifacts`)");
    }

    table.print();
}
