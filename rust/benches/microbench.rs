//! Microbenchmarks of the hot-path primitives — the L3 profiling harness
//! for the performance pass (EXPERIMENTS.md §Perf).
//!
//! Run: `cargo bench --bench microbench`
//!
//! Besides the human-readable table, the run emits machine-readable
//! `BENCH_scoring.json` at the repo root (override with `STARS_BENCH_OUT`)
//! so the scoring-kernel perf trajectory is tracked across PRs: batched vs
//! scalar cosine throughput at d ∈ {16, 100, 784} and the end-to-end
//! `StarsBuilder::build` wall time against the recorded pre-tiling baseline.

use stars::ampc::CostLedger;
use stars::bench::{fmt_count, fmt_secs, time_runs, Table};
use stars::data::synth;
use stars::lsh::{sorted_order, LshFamily, SimHash, WeightedMinHash};
use stars::sim::batch::dot_tile_with;
use stars::sim::quant::{quantize_row, QuantDataset};
use stars::sim::{CosineSim, Similarity};
use stars::stars::{group_buckets, Algorithm, BuildParams, StarsBuilder};
use stars::util::json::Json;
use stars::util::rng::Rng;
use stars::util::simd;
use std::path::PathBuf;

/// Pre-change reference for the e2e build below, measured on the seed
/// revision (serial coordinator accumulator + per-pair scalar scoring) on
/// the same reference box as the committed BENCH_scoring.json. Override via
/// `STARS_BASELINE_E2E_S` when re-baselining on new hardware.
const BASELINE_E2E_S: f64 = 11.8;

/// Where to write the machine-readable report: `STARS_BENCH_OUT`, else the
/// repo root (benches run with CWD = rust/, so the root is one level up).
fn bench_out_path() -> PathBuf {
    if let Ok(p) = std::env::var("STARS_BENCH_OUT") {
        return PathBuf::from(p);
    }
    if std::path::Path::new("../ROADMAP.md").exists() {
        PathBuf::from("../BENCH_scoring.json")
    } else {
        PathBuf::from("BENCH_scoring.json")
    }
}

/// Batched (tiled sim_batch) vs scalar (per-pair sim(), the pre-tiling
/// default) cosine scoring across the dimensions the acceptance tracks.
fn bench_cosine_scoring(table: &mut Table) -> Json {
    let mut rows = Vec::new();
    for &d in &[16usize, 100, 784] {
        let ds = synth::gaussian_mixture(20_000, d, 50, 0.1, 42);
        let cands: Vec<u32> = (1..8_193).collect();
        let pairs = cands.len();
        let mut out: Vec<f32> = Vec::with_capacity(pairs);
        // Scalar reference: exactly what the default trait sim_batch did
        // before the tiled kernels (one sim() per candidate).
        let scalar = time_runs(3, 15, || {
            out.clear();
            out.extend(cands.iter().map(|&c| CosineSim.sim(&ds, 0, c as usize)));
            std::hint::black_box(&out);
        });
        let batched = time_runs(3, 15, || {
            CosineSim.sim_batch(&ds, 0, &cands, &mut out);
            std::hint::black_box(&out);
        });
        let (s_med, b_med) = (scalar.median(), batched.median());
        let speedup = s_med / b_med;
        for (name, med) in [("scalar", s_med), ("batched", b_med)] {
            table.row(vec![
                format!("cosine {name} (d={d})"),
                fmt_count(pairs as u64),
                fmt_secs(med),
                format!("{}/s", fmt_count((pairs as f64 / med) as u64)),
            ]);
        }
        rows.push(Json::obj(vec![
            ("d", Json::from(d)),
            ("pairs", Json::from(pairs)),
            ("scalar_median_s", Json::from(s_med)),
            ("batched_median_s", Json::from(b_med)),
            ("scalar_pairs_per_s", Json::from(pairs as f64 / s_med)),
            ("batched_pairs_per_s", Json::from(pairs as f64 / b_med)),
            ("speedup", Json::from(speedup)),
        ]));
    }
    Json::Arr(rows)
}

/// Per-backend throughput of the blocked dot kernel — the same tile shapes
/// the scoring pass runs, forced through each backend the host can execute
/// (scalar is always present, so the JSON always records the lane speedup
/// the dispatcher is buying).
fn bench_simd_backends(table: &mut Table) -> Json {
    let mut out = Vec::new();
    // Dimension-major: the (identical, backend-independent) dataset, tile
    // gather and leader row are built once per d and reused across backends.
    for &d in &[16usize, 100, 784] {
        let ds = synth::gaussian_mixture(4_097, d, 8, 0.2, 11);
        let n = 4_096;
        let mut tile = vec![0f32; n * d];
        for r in 0..n {
            tile[r * d..(r + 1) * d].copy_from_slice(ds.row(r + 1));
        }
        let leader = ds.row(0);
        let mut scores = vec![0f32; n];
        for backend in simd::reachable() {
            let stats = time_runs(3, 15, || {
                dot_tile_with(backend, leader, &tile, n, &mut scores);
                std::hint::black_box(&scores);
            });
            let med = stats.median();
            table.row(vec![
                format!("dot_tile [{}] (d={d})", backend.name()),
                fmt_count(n as u64),
                fmt_secs(med),
                format!("{}/s", fmt_count((n as f64 / med) as u64)),
            ]);
            out.push(Json::obj(vec![
                ("backend", Json::from(backend.name())),
                ("d", Json::from(d)),
                ("pairs", Json::from(n)),
                ("median_s", Json::from(med)),
                ("pairs_per_s", Json::from(n as f64 / med)),
            ]));
        }
    }
    Json::Arr(out)
}

/// Per-backend throughput of the int8 first-pass estimate kernel
/// (`QuantDataset::dot_estimates_with`, the quantized serve tier's hot
/// loop) over the same tile shapes as the f32 sweep — the int8-vs-f32
/// kernel speedup reads off this array next to `simd_kernel_dot`
/// (EXPERIMENTS.md §Quant table convention).
fn bench_simd_int8(table: &mut Table) -> Json {
    let mut out = Vec::new();
    for &d in &[16usize, 100, 784] {
        let ds = synth::gaussian_mixture(4_097, d, 8, 0.2, 11);
        let q = QuantDataset::from_dataset(&ds);
        let mut qcodes = vec![0i8; d];
        let qscale = quantize_row(ds.row(0), &mut qcodes);
        let n = 4_096;
        let cands: Vec<u32> = (1..=n as u32).collect();
        let mut est = Vec::with_capacity(n);
        for backend in simd::reachable() {
            let stats = time_runs(3, 15, || {
                q.dot_estimates_with(backend, &qcodes, qscale, &cands, &mut est);
                std::hint::black_box(&est);
            });
            let med = stats.median();
            table.row(vec![
                format!("dot_i8 estimates [{}] (d={d})", backend.name()),
                fmt_count(n as u64),
                fmt_secs(med),
                format!("{}/s", fmt_count((n as f64 / med) as u64)),
            ]);
            out.push(Json::obj(vec![
                ("backend", Json::from(backend.name())),
                ("d", Json::from(d)),
                ("pairs", Json::from(n)),
                ("median_s", Json::from(med)),
                ("pairs_per_s", Json::from(n as f64 / med)),
            ]));
        }
    }
    Json::Arr(out)
}

/// End-to-end `StarsBuilder::build` wall time on the acceptance workload
/// (gaussian_mixture(50_000, 100, …), LSH+Stars), vs the recorded
/// pre-tiling/pre-sharding baseline.
fn bench_e2e_build(table: &mut Table) -> Json {
    let ds = synth::gaussian_mixture(50_000, 100, 100, 0.1, 42);
    let family = SimHash::new(100, 12, 7);
    let mut edges = 0usize;
    let stats = time_runs(1, 3, || {
        let out = StarsBuilder::new(&ds)
            .similarity(&CosineSim)
            .hash(&family)
            .params(
                BuildParams::threshold_mode(Algorithm::LshStars)
                    .sketches(8)
                    .leaders(10)
                    .threshold(0.5),
            )
            .build();
        edges = std::hint::black_box(out.graph.num_edges());
    });
    let baseline = std::env::var("STARS_BASELINE_E2E_S")
        .ok()
        .and_then(|s| s.parse::<f64>().ok())
        .unwrap_or(BASELINE_E2E_S);
    table.row(vec![
        "e2e build lsh+stars (n=50k,d=100,R=8)".into(),
        fmt_count(ds.len() as u64),
        fmt_secs(stats.median()),
        format!("baseline {}", fmt_secs(baseline)),
    ]);
    Json::obj(vec![
        ("dataset", Json::from("gaussian_mixture(50000, 100, 100, 0.1, 42)")),
        ("algorithm", Json::from("lsh+stars")),
        ("sketches", Json::from(8usize)),
        ("leaders", Json::from(10usize)),
        ("wall_median_s", Json::from(stats.median())),
        ("wall_min_s", Json::from(stats.min())),
        ("edges", Json::from(edges)),
        (
            "baseline",
            Json::obj(vec![
                ("wall_median_s", Json::from(baseline)),
                (
                    "note",
                    Json::from(
                        "pre-change seed: serial coordinator accumulator + per-pair scalar scoring",
                    ),
                ),
            ]),
        ),
    ])
}

/// Observability overhead probe: the per-iteration cost of a span
/// enter/exit, a histogram record, and a disabled-sink emission, next to an
/// uninstrumented baseline of the same loop body — the numbers behind the
/// "fully off the hot path" claim (`stars::obs` module docs). Tracing is
/// forced off first, so the emit row measures the one relaxed atomic load
/// every emission site pays when `STARS_TRACE` is unset.
fn bench_obs_overhead(table: &mut Table) -> Json {
    let _ = stars::obs::set_trace(None, 1);
    const ITERS: usize = 1_000_000;
    let mut acc = 0u64;
    let baseline = time_runs(2, 10, || {
        for i in 0..ITERS {
            acc = acc.wrapping_add(std::hint::black_box(i as u64));
        }
        std::hint::black_box(acc);
    });
    let phases = stars::obs::Phases::new();
    let span = time_runs(2, 10, || {
        for i in 0..ITERS {
            let _g = phases.enter("probe");
            acc = acc.wrapping_add(std::hint::black_box(i as u64));
        }
        std::hint::black_box(acc);
    });
    let hist = stars::obs::Histogram::new();
    let record = time_runs(2, 10, || {
        for i in 0..ITERS {
            hist.record(std::hint::black_box(i as u64));
            acc = acc.wrapping_add(i as u64);
        }
        std::hint::black_box(acc);
    });
    let emit = time_runs(2, 10, || {
        for i in 0..ITERS {
            stars::obs::emit_lazy("probe", || vec![("i", Json::from(0u64))]);
            acc = acc.wrapping_add(std::hint::black_box(i as u64));
        }
        std::hint::black_box(acc);
    });
    let per_ns = |s: f64| s / ITERS as f64 * 1e9;
    let (base_ns, span_ns, rec_ns, emit_ns) = (
        per_ns(baseline.median()),
        per_ns(span.median()),
        per_ns(record.median()),
        per_ns(emit.median()),
    );
    for (name, ns) in [
        ("baseline loop", base_ns),
        ("span enter/exit", span_ns),
        ("histogram record", rec_ns),
        ("disabled emit", emit_ns),
    ] {
        table.row(vec![
            format!("obs overhead: {name}"),
            fmt_count(ITERS as u64),
            format!("{ns:.1}ns/iter"),
            format!("+{:.1}ns vs baseline", (ns - base_ns).max(0.0)),
        ]);
    }
    Json::obj(vec![
        ("iters", Json::from(ITERS)),
        ("baseline_ns_per_iter", Json::from(base_ns)),
        ("span_enter_exit_ns_per_iter", Json::from(span_ns)),
        ("histogram_record_ns_per_iter", Json::from(rec_ns)),
        ("disabled_emit_ns_per_iter", Json::from(emit_ns)),
        ("span_overhead_ns", Json::from((span_ns - base_ns).max(0.0))),
        ("histogram_overhead_ns", Json::from((rec_ns - base_ns).max(0.0))),
        ("disabled_emit_overhead_ns", Json::from((emit_ns - base_ns).max(0.0))),
    ])
}

fn main() {
    let mut table = Table::new(&["primitive", "n", "median", "throughput"]);

    // Tiled batch scoring vs the scalar path (the perf-pass headline).
    let scoring = bench_cosine_scoring(&mut table);
    let simd_kernels = bench_simd_backends(&mut table);
    let simd_i8 = bench_simd_int8(&mut table);
    let e2e = bench_e2e_build(&mut table);
    let obs_overhead = bench_obs_overhead(&mut table);

    let ds = synth::gaussian_mixture(100_000, 100, 100, 0.1, 42);

    // Cosine scoring: leader vs 10k candidates, batched.
    {
        let cands: Vec<u32> = (1..10_001).collect();
        let mut out = Vec::new();
        let stats = time_runs(3, 15, || {
            CosineSim.sim_batch(&ds, 0, &cands, &mut out);
            std::hint::black_box(&out);
        });
        table.row(vec![
            "cosine sim_batch (d=100)".into(),
            fmt_count(cands.len() as u64),
            fmt_secs(stats.median()),
            format!(
                "{}/s",
                fmt_count((cands.len() as f64 / stats.median()) as u64)
            ),
        ]);
    }

    // SimHash sketching: one repetition over 100k points.
    {
        let h = SimHash::new(100, 16, 7);
        let stats = time_runs(1, 5, || {
            std::hint::black_box(h.bucket_keys(&ds, 0));
        });
        table.row(vec![
            "simhash bucket_keys (M=16)".into(),
            fmt_count(ds.len() as u64),
            fmt_secs(stats.median()),
            format!(
                "{}/s",
                fmt_count((ds.len() as f64 / stats.median()) as u64)
            ),
        ]);
    }

    // Weighted MinHash sketching on sets.
    {
        let sets = synth::zipf_sets(20_000, &synth::ZipfSetsParams::default(), 3);
        let h = WeightedMinHash::new(3, 9);
        let stats = time_runs(1, 5, || {
            std::hint::black_box(h.bucket_keys(&sets, 0));
        });
        table.row(vec![
            "wminhash bucket_keys (M=3)".into(),
            fmt_count(sets.len() as u64),
            fmt_secs(stats.median()),
            format!(
                "{}/s",
                fmt_count((sets.len() as f64 / stats.median()) as u64)
            ),
        ]);
    }

    // Bucket grouping of 100k keys.
    {
        let h = SimHash::new(100, 16, 7);
        let keys = h.bucket_keys(&ds, 0);
        let stats = time_runs(2, 10, || {
            std::hint::black_box(group_buckets(&keys));
        });
        table.row(vec![
            "group_buckets".into(),
            fmt_count(keys.len() as u64),
            fmt_secs(stats.median()),
            format!(
                "{}/s",
                fmt_count((keys.len() as f64 / stats.median()) as u64)
            ),
        ]);
    }

    // SortingLSH: full sorted order (M=30) over 100k points.
    {
        let h = SimHash::new(100, 30, 7);
        let stats = time_runs(1, 3, || {
            std::hint::black_box(sorted_order(&h, &ds, 0));
        });
        table.row(vec![
            "sorted_order (M=30, matrix)".into(),
            fmt_count(ds.len() as u64),
            fmt_secs(stats.median()),
            format!(
                "{}/s",
                fmt_count((ds.len() as f64 / stats.median()) as u64)
            ),
        ]);
        // Packed-u64 fast path (what the scoring loop actually uses).
        let stats = time_runs(1, 3, || {
            std::hint::black_box(stars::lsh::sorting::sorted_indices(&h, &ds, 0));
        });
        table.row(vec![
            "sorted_indices (M=30, packed)".into(),
            fmt_count(ds.len() as u64),
            fmt_secs(stats.median()),
            format!(
                "{}/s",
                fmt_count((ds.len() as f64 / stats.median()) as u64)
            ),
        ]);
    }

    // TeraSort 1M u64 records.
    {
        let mut rng = Rng::new(5);
        let items: Vec<u64> = (0..1_000_000).map(|_| rng.next_u64()).collect();
        let ledger = CostLedger::new(8);
        let stats = time_runs(1, 3, || {
            std::hint::black_box(stars::ampc::terasort::terasort(
                items.clone(),
                8,
                8,
                |x| *x,
                &ledger,
                1,
            ));
        });
        table.row(vec![
            "terasort u64 x8 workers".into(),
            fmt_count(items.len() as u64),
            fmt_secs(stats.median()),
            format!(
                "{}/s",
                fmt_count((items.len() as f64 / stats.median()) as u64)
            ),
        ]);
    }

    // PJRT learned-model scoring throughput (if artifacts exist).
    if let Ok(meta) =
        stars::runtime::ArtifactMeta::load(&stars::runtime::ArtifactMeta::default_dir())
    {
        let engine = stars::runtime::Engine::cpu().unwrap();
        let model = stars::runtime::LearnedModel::load(&engine, &meta).unwrap();
        let prods = synth::products(2048, &synth::ProductsParams::default(), 42);
        let pairs: Vec<(u32, u32)> = (0..1024u32).map(|i| (i, i + 1024)).collect();
        let stats = time_runs(1, 5, || {
            std::hint::black_box(model.score(&prods, &pairs).unwrap());
        });
        table.row(vec![
            "learned model score (PJRT)".into(),
            fmt_count(pairs.len() as u64),
            fmt_secs(stats.median()),
            format!(
                "{} pairs/s",
                fmt_count((pairs.len() as f64 / stats.median()) as u64)
            ),
        ]);

        let scorer = stars::runtime::CosineScorer::load(&engine, &meta).unwrap();
        let leaders: Vec<f32> = ds.dense[..8 * 100].to_vec();
        let cands: Vec<f32> = ds.dense[..4096 * 100].to_vec();
        let stats = time_runs(1, 5, || {
            std::hint::black_box(scorer.score(&leaders, 8, &cands, 4096, 100).unwrap());
        });
        table.row(vec![
            "cosine scorer (PJRT, 8x4096)".into(),
            fmt_count(8 * 4096),
            fmt_secs(stats.median()),
            format!(
                "{} scores/s",
                fmt_count((8.0 * 4096.0 / stats.median()) as u64)
            ),
        ]);
    } else {
        println!("(PJRT rows skipped: run `make artifacts`)");
    }

    table.print();

    // Machine-readable report for cross-PR perf tracking.
    let doc = Json::obj(vec![
        // v4: renamed `schema` → `schema_version` (CI bench-check gate),
        // added `data_status` and the `obs_overhead` probe (per-iteration
        // span/histogram/disabled-emit cost vs an uninstrumented loop).
        // v3: added the simd_kernel_dot_i8 per-backend sweep (the
        // quantized tier's int8 estimate kernel).
        ("schema_version", Json::from("stars-bench-scoring/v4")),
        (
            "data_status",
            Json::from("measured by `cargo bench --bench microbench` on this host"),
        ),
        ("bench", Json::from("microbench")),
        (
            "workers",
            Json::from(stars::util::pool::default_workers()),
        ),
        // Which lanes produced every number in this file (the override
        // STARS_SIMD=scalar|avx2|neon pins it for A/B runs).
        ("simd_backend", Json::from(simd::active().name())),
        ("cosine_scoring", scoring),
        ("simd_kernel_dot", simd_kernels),
        ("simd_kernel_dot_i8", simd_i8),
        ("e2e_build", e2e),
        ("obs_overhead", obs_overhead),
    ]);
    let path = bench_out_path();
    match std::fs::write(&path, doc.to_pretty()) {
        Ok(()) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("failed to write {}: {e}", path.display()),
    }
}
