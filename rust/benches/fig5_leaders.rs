//! Bench: regenerates the paper's Figures 5-7 (effect of the number of leaders).
//! Run: `cargo bench --bench fig5_leaders` (STARS_BENCH_FULL=1 for paper-size R).
use stars::coordinator::experiments::{fig5_leaders, ExpConfig};

fn main() {
    let cfg = ExpConfig::default();
    let (secs, _) = stars::bench::time_once(|| fig5_leaders(&cfg));
    println!("\n[fig5_leaders] completed in {}", stars::bench::fmt_secs(secs));
}
