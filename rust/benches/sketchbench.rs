//! Sketch-phase and sort-phase microbenchmarks — the perf harness for the
//! data-parallel sketching subsystem (EXPERIMENTS.md §Perf).
//!
//! Run: `cargo bench --bench sketchbench`
//!
//! Besides the human-readable table, the run emits machine-readable
//! `BENCH_sketch.json` at the repo root (override with `STARS_BENCH_OUT`)
//! so the sketch/sort perf trajectory is tracked across PRs alongside
//! `BENCH_scoring.json`:
//!
//! * scalar per-row vs tiled vs tiled+pool SimHash sketching at
//!   d ∈ {16, 100, 784}, M=16 (the acceptance dimension is d=100/M=16);
//! * per-point (seed default path) vs per-token-cached WeightedMinHash;
//! * comparison sort vs LSD radix argsort on packed sort keys;
//! * end-to-end SortingLSH+Stars build wall time.

use stars::bench::{fmt_count, fmt_secs, time_runs, Table};
use stars::data::synth;
use stars::lsh::sketch::sketch_tile_with;
use stars::lsh::{sketch, LshFamily, SimHash, WeightedMinHash};
use stars::sim::CosineSim;
use stars::stars::{Algorithm, BuildParams, StarsBuilder};
use stars::util::json::Json;
use stars::util::pool;
use stars::util::radix;
use stars::util::simd;
use std::path::PathBuf;

/// Pre-change reference for the e2e SortingLSH build below: the PR-1
/// revision (scalar per-row sketching, comparison sort, rep-only
/// parallelism). The committed value is a reference-box projection — no
/// toolchain was available to measure it (see EXPERIMENTS.md header);
/// override via `STARS_BASELINE_SORTING_E2E_S` when re-baselining on
/// measured hardware.
const BASELINE_SORTING_E2E_S: f64 = 4.31;

/// Where to write the machine-readable report: `STARS_BENCH_OUT`, else the
/// repo root (benches run with CWD = rust/, so the root is one level up).
fn bench_out_path() -> PathBuf {
    if let Ok(p) = std::env::var("STARS_BENCH_OUT") {
        return PathBuf::from(p);
    }
    if std::path::Path::new("../ROADMAP.md").exists() {
        PathBuf::from("../BENCH_sketch.json")
    } else {
        PathBuf::from("BENCH_sketch.json")
    }
}

/// Scalar per-row vs tiled vs tiled+pool SimHash sketching.
fn bench_simhash(table: &mut Table) -> Json {
    let mut rows = Vec::new();
    let workers = pool::default_workers();
    for &d in &[16usize, 100, 784] {
        let n = if d >= 784 { 20_000 } else { 100_000 };
        let ds = synth::gaussian_mixture(n, d, 50, 0.1, 42);
        let h = SimHash::new(d, 16, 7);
        let planes = h.hyperplanes(0);
        // Scalar reference: the seed bucket_keys loop — per-rep planes, one
        // sketch_row call per point.
        let scalar = time_runs(1, 7, || {
            let keys: Vec<u64> = (0..ds.len()).map(|i| h.sketch_row(ds.row(i), &planes)).collect();
            std::hint::black_box(keys);
        });
        let tiled = time_runs(1, 7, || {
            std::hint::black_box(h.bucket_keys(&ds, 0));
        });
        let tiled_par = time_runs(1, 7, || {
            std::hint::black_box(sketch::bucket_keys_par(&h, &ds, 0, workers));
        });
        let (s_med, t_med, p_med) = (scalar.median(), tiled.median(), tiled_par.median());
        for (name, med) in [
            ("scalar", s_med),
            ("tiled", t_med),
            ("tiled+pool", p_med),
        ] {
            table.row(vec![
                format!("simhash {name} (d={d}, M=16)"),
                fmt_count(n as u64),
                fmt_secs(med),
                format!("{}/s", fmt_count((n as f64 / med) as u64)),
            ]);
        }
        rows.push(Json::obj(vec![
            ("d", Json::from(d)),
            ("m", Json::from(16usize)),
            ("points", Json::from(n)),
            ("scalar_median_s", Json::from(s_med)),
            ("tiled_median_s", Json::from(t_med)),
            ("tiled_pool_median_s", Json::from(p_med)),
            ("scalar_points_per_s", Json::from(n as f64 / s_med)),
            ("tiled_points_per_s", Json::from(n as f64 / t_med)),
            ("tiled_pool_points_per_s", Json::from(n as f64 / p_med)),
            ("tiled_speedup", Json::from(s_med / t_med)),
            ("tiled_pool_speedup", Json::from(s_med / p_med)),
        ]));
    }
    Json::Arr(rows)
}

/// Per-backend throughput of the tiled sketch kernel (M=16 plane pairs),
/// forced through every backend the host can execute.
fn bench_simd_sketch_backends(table: &mut Table) -> Json {
    let mut out = Vec::new();
    let (bits, n) = (16usize, 8_192usize);
    // Dimension-major: the dataset and hyperplane matrix are backend-
    // independent, so build them once per d and sweep backends inside.
    for &d in &[16usize, 100, 784] {
        let ds = synth::gaussian_mixture(n, d, 8, 0.2, 13);
        let h = SimHash::new(d, bits, 7);
        let planes = h.hyperplanes(0);
        let mut keys = vec![0u64; n];
        for backend in simd::reachable() {
            let stats = time_runs(1, 7, || {
                sketch_tile_with(backend, &planes, bits, d, &ds.dense, n, &mut keys);
                std::hint::black_box(&keys);
            });
            let med = stats.median();
            table.row(vec![
                format!("sketch_tile [{}] (d={d}, M={bits})", backend.name()),
                fmt_count(n as u64),
                fmt_secs(med),
                format!("{}/s", fmt_count((n as f64 / med) as u64)),
            ]);
            out.push(Json::obj(vec![
                ("backend", Json::from(backend.name())),
                ("d", Json::from(d)),
                ("m", Json::from(bits)),
                ("points", Json::from(n)),
                ("median_s", Json::from(med)),
                ("points_per_s", Json::from(n as f64 / med)),
            ]));
        }
    }
    Json::Arr(out)
}

/// Seed default path (per-point `bucket_key`) vs per-token-cached state.
fn bench_wminhash(table: &mut Table) -> Json {
    let sets = synth::zipf_sets(20_000, &synth::ZipfSetsParams::default(), 3);
    let h = WeightedMinHash::new(3, 9);
    let per_point = time_runs(1, 5, || {
        let keys: Vec<u64> = (0..sets.len()).map(|i| h.bucket_key(&sets, i, 0)).collect();
        std::hint::black_box(keys);
    });
    let cached = time_runs(1, 5, || {
        std::hint::black_box(h.bucket_keys(&sets, 0));
    });
    let (p_med, c_med) = (per_point.median(), cached.median());
    for (name, med) in [("per-point", p_med), ("token-cached", c_med)] {
        table.row(vec![
            format!("wminhash {name} (M=3)"),
            fmt_count(sets.len() as u64),
            fmt_secs(med),
            format!("{}/s", fmt_count((sets.len() as f64 / med) as u64)),
        ]);
    }
    Json::obj(vec![
        ("points", Json::from(sets.len())),
        ("perms", Json::from(3usize)),
        ("per_point_median_s", Json::from(p_med)),
        ("cached_median_s", Json::from(c_med)),
        ("speedup", Json::from(p_med / c_med)),
    ])
}

/// Comparison sort vs LSD radix argsort on packed sort keys (M=30: four
/// live bytes, so half the radix passes are mask-skipped), serial and
/// pool-parallel.
fn bench_sort(table: &mut Table) -> Json {
    let workers = pool::default_workers();
    let ds = synth::gaussian_mixture(1_000_000, 16, 100, 0.1, 42);
    let h = SimHash::new(16, 30, 7);
    let keys = h.packed_sort_keys(&ds, 0).unwrap();
    let comparison = time_runs(1, 7, || {
        let mut order: Vec<u32> = (0..keys.len() as u32).collect();
        order.sort_unstable_by_key(|&i| (keys[i as usize], i));
        std::hint::black_box(order);
    });
    let radix_stats = time_runs(1, 7, || {
        std::hint::black_box(radix::argsort_u64(&keys));
    });
    let radix_par = time_runs(1, 7, || {
        std::hint::black_box(radix::argsort_u64_par(&keys, workers));
    });
    let (c_med, r_med, p_med) = (
        comparison.median(),
        radix_stats.median(),
        radix_par.median(),
    );
    for (name, med) in [
        ("comparison", c_med),
        ("radix", r_med),
        ("radix+pool", p_med),
    ] {
        table.row(vec![
            format!("argsort {name} (M=30 keys)"),
            fmt_count(keys.len() as u64),
            fmt_secs(med),
            format!("{}/s", fmt_count((keys.len() as f64 / med) as u64)),
        ]);
    }
    Json::obj(vec![
        ("keys", Json::from(keys.len())),
        ("workers", Json::from(workers)),
        ("comparison_median_s", Json::from(c_med)),
        ("radix_median_s", Json::from(r_med)),
        ("radix_par_median_s", Json::from(p_med)),
        ("speedup", Json::from(c_med / r_med)),
        ("par_speedup", Json::from(r_med / p_med)),
    ])
}

/// End-to-end SortingLSH+Stars build: the pipeline where all four layers
/// (state cache, tiled kernel, in-rep parallelism, radix sort) are live.
fn bench_e2e_sorting(table: &mut Table) -> Json {
    let ds = synth::gaussian_mixture(50_000, 100, 100, 0.1, 42);
    let family = SimHash::new(100, 30, 7);
    let mut edges = 0usize;
    let stats = time_runs(1, 3, || {
        let out = StarsBuilder::new(&ds)
            .similarity(&CosineSim)
            .hash(&family)
            .params(
                BuildParams::knn_mode(Algorithm::SortingLshStars)
                    .sketches(8)
                    .leaders(10)
                    .window(250)
                    .degree_cap(50),
            )
            .build();
        edges = std::hint::black_box(out.graph.num_edges());
    });
    let baseline = std::env::var("STARS_BASELINE_SORTING_E2E_S")
        .ok()
        .and_then(|s| s.parse::<f64>().ok())
        .unwrap_or(BASELINE_SORTING_E2E_S);
    table.row(vec![
        "e2e build sortinglsh+stars (n=50k,d=100,R=8)".into(),
        fmt_count(ds.len() as u64),
        fmt_secs(stats.median()),
        format!("baseline {}", fmt_secs(baseline)),
    ]);
    Json::obj(vec![
        ("dataset", Json::from("gaussian_mixture(50000, 100, 100, 0.1, 42)")),
        ("algorithm", Json::from("sortinglsh+stars")),
        ("sketches", Json::from(8usize)),
        ("leaders", Json::from(10usize)),
        ("window", Json::from(250usize)),
        ("wall_median_s", Json::from(stats.median())),
        ("wall_min_s", Json::from(stats.min())),
        ("edges", Json::from(edges)),
        (
            "baseline",
            Json::obj(vec![
                ("wall_median_s", Json::from(baseline)),
                (
                    "note",
                    Json::from(
                        "PR-1 revision: per-row scalar sketching, comparison sort, \
                         rep-only parallelism",
                    ),
                ),
            ]),
        ),
    ])
}

fn main() {
    let mut table = Table::new(&["primitive", "n", "median", "throughput"]);
    let simhash = bench_simhash(&mut table);
    let simd_kernels = bench_simd_sketch_backends(&mut table);
    let wminhash = bench_wminhash(&mut table);
    let sort = bench_sort(&mut table);
    let e2e = bench_e2e_sorting(&mut table);
    table.print();

    let doc = Json::obj(vec![
        // v3: renamed `schema` → `schema_version` and added `data_status`
        // (CI bench-check gate).
        ("schema_version", Json::from("stars-bench-sketch/v3")),
        (
            "data_status",
            Json::from("measured by `cargo bench --bench sketchbench` on this host"),
        ),
        ("bench", Json::from("sketchbench")),
        ("workers", Json::from(pool::default_workers())),
        ("simd_backend", Json::from(simd::active().name())),
        ("simhash_sketching", simhash),
        ("simd_kernel_sketch", simd_kernels),
        ("wminhash_sketching", wminhash),
        ("packed_key_sort", sort),
        ("e2e_sorting_build", e2e),
    ]);
    let path = bench_out_path();
    match std::fs::write(&path, doc.to_pretty()) {
        Ok(()) => println!("wrote {}", path.display()),
        Err(e) => eprintln!("failed to write {}: {e}", path.display()),
    }
}
