//! Bench: regenerates the paper's Figure 2 (recall of near(est) neighbors).
//! Run: `cargo bench --bench fig2_recall` (STARS_BENCH_FULL=1 for paper-size R).
use stars::coordinator::experiments::{fig2, ExpConfig};

fn main() {
    let cfg = ExpConfig::default();
    let (secs, _) = stars::bench::time_once(|| fig2(&cfg));
    println!("\n[fig2_recall] completed in {}", stars::bench::fmt_secs(secs));
}
