//! Bench: regenerates the paper's Table 3 (relative total running time at scale).
//! Run: `cargo bench --bench table3_scale` (STARS_BENCH_FULL=1 for paper-size R).
use stars::coordinator::experiments::{table3, ExpConfig};

fn main() {
    let cfg = ExpConfig::default();
    let (secs, _) = stars::bench::time_once(|| table3(&cfg));
    println!("\n[table3_scale] completed in {}", stars::bench::fmt_secs(secs));
}
