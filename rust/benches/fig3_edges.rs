//! Bench: regenerates the paper's Figure 3 (edges above the similarity threshold).
//! Run: `cargo bench --bench fig3_edges` (STARS_BENCH_FULL=1 for paper-size R).
use stars::coordinator::experiments::{fig3, ExpConfig};

fn main() {
    let cfg = ExpConfig::default();
    let (secs, _) = stars::bench::time_once(|| fig3(&cfg));
    println!("\n[fig3_edges] completed in {}", stars::bench::fmt_secs(secs));
}
