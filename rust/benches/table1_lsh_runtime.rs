//! Bench: regenerates the paper's Table 1 (relative total running time, LSH-based).
//! Run: `cargo bench --bench table1_lsh_runtime` (STARS_BENCH_FULL=1 for paper-size R).
use stars::coordinator::experiments::{table12, ExpConfig};

fn main() {
    let cfg = ExpConfig::default();
    let (secs, _) = stars::bench::time_once(|| table12(&cfg, false));
    println!("\n[table1_lsh_runtime] completed in {}", stars::bench::fmt_secs(secs));
}
