//! Bench: regenerates the paper's Figure 4 (V-Measure of Affinity clustering).
//! Run: `cargo bench --bench fig4_vmeasure` (STARS_BENCH_FULL=1 for paper-size R).
use stars::coordinator::experiments::{fig4, ExpConfig};

fn main() {
    let cfg = ExpConfig::default();
    let (secs, _) = stars::bench::time_once(|| fig4(&cfg));
    println!("\n[fig4_vmeasure] completed in {}", stars::bench::fmt_secs(secs));
}
