//! Declarative job specification: dataset × measure × hash family × params.
//!
//! Jobs are what the CLI, the examples, and the per-figure benches all
//! construct; [`super::driver::run_job`] executes them.

use crate::data::synth::{self, ProductsParams, ZipfSetsParams};
use crate::data::Dataset;
use crate::stars::BuildParams;
use crate::util::json::Json;

/// Which dataset to generate (or load).
#[derive(Clone, Debug)]
pub enum DatasetSpec {
    /// MNIST stand-in: 10 classes, 784-d images.
    Digits { n: usize },
    /// Wikipedia stand-in: weighted word sets.
    ZipfSets { n: usize },
    /// Amazon2m stand-in: 47 classes, embedding + co-purchase sets.
    Products { n: usize },
    /// Random1B/10B stand-in: 100-mode GMM.
    Random { n: usize, dim: usize, modes: usize },
    /// Load from a dataset file written by `stars gen-data`.
    File { path: String },
}

impl DatasetSpec {
    /// Instantiate the dataset (deterministic in `seed`).
    pub fn realize(&self, seed: u64) -> crate::Result<Dataset> {
        Ok(match self {
            DatasetSpec::Digits { n } => synth::digits(*n, seed),
            DatasetSpec::ZipfSets { n } => synth::zipf_sets(*n, &ZipfSetsParams::default(), seed),
            DatasetSpec::Products { n } => synth::products(*n, &ProductsParams::default(), seed),
            DatasetSpec::Random { n, dim, modes } => {
                synth::gaussian_mixture(*n, *dim, *modes, 0.1, seed)
            }
            DatasetSpec::File { path } => {
                let p = std::path::Path::new(path);
                if p.is_dir() {
                    crate::data::mnist::load_dir(p)?
                } else {
                    crate::data::io::load(p)?
                }
            }
        })
    }

    /// Parse from a CLI name like `digits`, `products`, `random`.
    pub fn parse(name: &str, n: usize) -> crate::Result<DatasetSpec> {
        Ok(match name {
            "digits" => DatasetSpec::Digits { n },
            "zipf" | "zipfsets" | "wikipedia" => DatasetSpec::ZipfSets { n },
            "products" | "amazon" => DatasetSpec::Products { n },
            "random" => DatasetSpec::Random {
                n,
                dim: 100,
                modes: 100,
            },
            path if std::path::Path::new(path).exists() => DatasetSpec::File {
                path: path.to_string(),
            },
            other => anyhow::bail!("unknown dataset '{other}'"),
        })
    }

    /// Short display name.
    pub fn name(&self) -> String {
        match self {
            DatasetSpec::Digits { n } => format!("digits-{n}"),
            DatasetSpec::ZipfSets { n } => format!("zipf-{n}"),
            DatasetSpec::Products { n } => format!("products-{n}"),
            DatasetSpec::Random { n, .. } => format!("random-{n}"),
            DatasetSpec::File { path } => path.clone(),
        }
    }
}

/// Which similarity measure to use.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum MeasureSpec {
    Cosine,
    Jaccard,
    WeightedJaccard,
    /// α=0.5 cosine/jaccard blend (Amazon2m "mixture of similarities").
    Mixture,
    /// The AOT neural model via PJRT (requires `make artifacts`).
    Learned,
}

impl MeasureSpec {
    /// Parse from a CLI name.
    pub fn parse(name: &str) -> crate::Result<MeasureSpec> {
        Ok(match name {
            "cosine" => MeasureSpec::Cosine,
            "jaccard" => MeasureSpec::Jaccard,
            "weighted-jaccard" | "wjaccard" => MeasureSpec::WeightedJaccard,
            "mixture" | "mix" => MeasureSpec::Mixture,
            "learned" | "nn" => MeasureSpec::Learned,
            other => anyhow::bail!("unknown measure '{other}'"),
        })
    }

    /// Display name (paper legend style).
    pub fn name(&self) -> &'static str {
        match self {
            MeasureSpec::Cosine => "cosine",
            MeasureSpec::Jaccard => "jaccard",
            MeasureSpec::WeightedJaccard => "weighted-jaccard",
            MeasureSpec::Mixture => "mixture",
            MeasureSpec::Learned => "learned",
        }
    }

    /// The natural measure for a dataset (paper §5 pairings).
    pub fn default_for(ds: &DatasetSpec) -> MeasureSpec {
        match ds {
            DatasetSpec::Digits { .. } | DatasetSpec::Random { .. } => MeasureSpec::Cosine,
            DatasetSpec::ZipfSets { .. } => MeasureSpec::WeightedJaccard,
            DatasetSpec::Products { .. } => MeasureSpec::Mixture,
            DatasetSpec::File { .. } => MeasureSpec::Cosine,
        }
    }
}

/// Which LSH family to use.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FamilySpec {
    /// SimHash with `bits` hyperplanes per sketch.
    SimHash { bits: usize },
    /// MinHash with `perms` permutations.
    MinHash { perms: usize },
    /// Weighted MinHash (Ioffe CWS) with `perms` hashes.
    WeightedMinHash { perms: usize },
    /// SimHash/MinHash per-symbol mixture of length `len`.
    Mixture { len: usize },
}

impl FamilySpec {
    /// Paper Appendix D.2 defaults per dataset and mode.
    /// `sorting` selects the M=30 SortingLSH sketching dimension.
    pub fn default_for(ds: &DatasetSpec, sorting: bool) -> FamilySpec {
        if sorting {
            return match ds {
                DatasetSpec::ZipfSets { .. } => FamilySpec::WeightedMinHash { perms: 30 },
                DatasetSpec::Products { .. } => FamilySpec::Mixture { len: 30 },
                _ => FamilySpec::SimHash { bits: 30 },
            };
        }
        match ds {
            DatasetSpec::Digits { .. } => FamilySpec::SimHash { bits: 12 },
            DatasetSpec::Random { .. } => FamilySpec::SimHash { bits: 16 },
            DatasetSpec::ZipfSets { .. } => FamilySpec::WeightedMinHash { perms: 3 },
            DatasetSpec::Products { .. } => FamilySpec::Mixture { len: 12 },
            DatasetSpec::File { .. } => FamilySpec::SimHash { bits: 12 },
        }
    }

    /// Display name.
    pub fn name(&self) -> String {
        match self {
            FamilySpec::SimHash { bits } => format!("simhash-{bits}"),
            FamilySpec::MinHash { perms } => format!("minhash-{perms}"),
            FamilySpec::WeightedMinHash { perms } => format!("wminhash-{perms}"),
            FamilySpec::Mixture { len } => format!("mixture-{len}"),
        }
    }
}

/// A full graph-building job.
#[derive(Clone, Debug)]
pub struct Job {
    /// Dataset to build over.
    pub dataset: DatasetSpec,
    /// Similarity measure.
    pub measure: MeasureSpec,
    /// LSH family (ignored for AllPair).
    pub family: FamilySpec,
    /// Algorithm + parameters.
    pub params: BuildParams,
    /// Dataset generation seed.
    pub data_seed: u64,
    /// Cluster workers (0 = auto).
    pub workers: usize,
}

impl Job {
    /// JSON echo for reports.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("dataset", Json::from(self.dataset.name())),
            ("measure", Json::from(self.measure.name())),
            ("family", Json::from(self.family.name())),
            ("params", self.params.to_json()),
            ("data_seed", Json::from(self.data_seed)),
            ("workers", Json::from(self.workers)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stars::Algorithm;

    #[test]
    fn dataset_spec_realize_and_names() {
        let ds = DatasetSpec::parse("digits", 50).unwrap().realize(1).unwrap();
        assert_eq!(ds.len(), 50);
        assert_eq!(ds.dim(), 784);
        let ds = DatasetSpec::parse("products", 2000).unwrap().realize(1).unwrap();
        assert_eq!(ds.num_classes(), 47);
        assert!(DatasetSpec::parse("nonsense-name", 10).is_err());
    }

    #[test]
    fn measure_parsing() {
        assert_eq!(MeasureSpec::parse("cosine").unwrap(), MeasureSpec::Cosine);
        assert_eq!(MeasureSpec::parse("nn").unwrap(), MeasureSpec::Learned);
        assert!(MeasureSpec::parse("???").is_err());
    }

    #[test]
    fn defaults_match_paper_pairings() {
        let d = DatasetSpec::Digits { n: 10 };
        assert_eq!(MeasureSpec::default_for(&d), MeasureSpec::Cosine);
        assert_eq!(FamilySpec::default_for(&d, false), FamilySpec::SimHash { bits: 12 });
        assert_eq!(FamilySpec::default_for(&d, true), FamilySpec::SimHash { bits: 30 });
        let w = DatasetSpec::ZipfSets { n: 10 };
        assert_eq!(MeasureSpec::default_for(&w), MeasureSpec::WeightedJaccard);
        assert_eq!(
            FamilySpec::default_for(&w, false),
            FamilySpec::WeightedMinHash { perms: 3 }
        );
        let r = DatasetSpec::Random { n: 10, dim: 100, modes: 100 };
        assert_eq!(FamilySpec::default_for(&r, false), FamilySpec::SimHash { bits: 16 });
    }

    #[test]
    fn job_json_echo() {
        let job = Job {
            dataset: DatasetSpec::Digits { n: 10 },
            measure: MeasureSpec::Cosine,
            family: FamilySpec::SimHash { bits: 12 },
            params: BuildParams::threshold_mode(Algorithm::LshStars),
            data_seed: 5,
            workers: 2,
        };
        let j = job.to_json().to_string();
        let v = crate::util::json::parse(&j).unwrap();
        assert_eq!(v.get("measure").unwrap().as_str().unwrap(), "cosine");
    }
}
