//! L3 coordinator: job specs, the driver that runs them on the simulated
//! AMPC cluster, and the experiment registry that regenerates every table
//! and figure of the paper.

pub mod job;
pub mod driver;
pub mod experiments;

pub use driver::{run_job, run_serve, run_serve_with, JobResult, ServeOpts};
pub use job::{DatasetSpec, FamilySpec, Job, MeasureSpec};
