//! Experiment registry: one runner per paper table/figure.
//!
//! Each runner prints the same rows/series the paper reports and returns a
//! JSON document (also written to `results/` by the benches/CLI). Dataset
//! sizes are scaled to a single box; the *shape* of the results — who wins,
//! by roughly what factor — is the reproduction target (DESIGN.md §4).

use crate::bench::Table;
use crate::coordinator::driver::{make_family, make_measure};
use crate::coordinator::job::{DatasetSpec, FamilySpec, MeasureSpec};
use crate::data::Dataset;
use crate::eval::recall::{knn_recall, sample_queries, threshold_recall, RecallReport};
use crate::graph::{Csr, Graph};
use crate::sim::Similarity;
use crate::stars::{allpair, Algorithm, BuildParams, StarsBuilder};
use crate::util::json::Json;

/// Shared experiment configuration.
#[derive(Clone, Debug)]
pub struct ExpConfig {
    /// Sketch counts R to sweep (paper: 25, 100, 400).
    pub sketches: Vec<usize>,
    /// Dataset size multiplier.
    pub scale: f64,
    /// Worker threads (0 = auto).
    pub workers: usize,
    /// Base seed.
    pub seed: u64,
}

impl Default for ExpConfig {
    fn default() -> Self {
        let full = std::env::var("STARS_BENCH_FULL").is_ok();
        let scale = std::env::var("STARS_BENCH_SCALE")
            .ok()
            .and_then(|s| s.parse().ok())
            .unwrap_or(if full { 1.0 } else { 0.5 });
        ExpConfig {
            sketches: if full {
                vec![25, 100, 400]
            } else {
                vec![25, 100]
            },
            scale,
            workers: 0,
            seed: 42,
        }
    }
}

impl ExpConfig {
    fn n(&self, base: usize) -> usize {
        ((base as f64) * self.scale).round() as usize
    }

    fn workers(&self) -> usize {
        if self.workers == 0 {
            crate::util::pool::default_workers()
        } else {
            self.workers
        }
    }
}

/// A standard evaluation dataset with its paper-default measure/families.
pub struct Bench {
    /// Display name.
    pub name: String,
    /// The realized dataset.
    pub ds: Dataset,
    /// Measure spec.
    pub measure: MeasureSpec,
    /// LSH-mode family.
    pub lsh_family: FamilySpec,
    /// SortingLSH-mode family (M=30).
    pub sorting_family: FamilySpec,
    /// Edge threshold for threshold-mode experiments.
    pub threshold: f32,
}

/// Scale a sketching dimension from the paper's dataset size to ours so
/// bucket occupancy stays in the same regime: each halving of n removes
/// roughly one SimHash bit (one factor-2 of bucket count).
pub fn scaled_bits(paper_bits: usize, paper_n: usize, n: usize) -> usize {
    let shrink = (paper_n as f64 / n.max(1) as f64).log2().round().max(0.0) as usize;
    paper_bits.saturating_sub(shrink).max(3)
}

/// The three "real" datasets of §5 (scaled stand-ins).
///
/// LSH sketching dimensions follow Appendix D.2 (M=12 SimHash for MNIST,
/// M=3 weighted MinHash for Wikipedia, M=12 mixture for Amazon2m, M=30 for
/// SortingLSH), rescaled via [`scaled_bits`] to this run's dataset sizes.
pub fn standard_benches(cfg: &ExpConfig) -> Vec<Bench> {
    let n = cfg.n(4000);
    let specs = [
        (DatasetSpec::Digits { n }, 0.5f32),
        (DatasetSpec::ZipfSets { n }, 0.15),
        (DatasetSpec::Products { n }, 0.4),
    ];
    specs
        .into_iter()
        .map(|(spec, threshold)| {
            let (lsh_family, sorting_family) = match &spec {
                DatasetSpec::Digits { n } => (
                    FamilySpec::SimHash {
                        bits: scaled_bits(12, 60_000, *n),
                    },
                    FamilySpec::SimHash {
                        // Sorting prefixes adapt per point, so keep M high.
                        bits: scaled_bits(30, 60_000, *n) + 8,
                    },
                ),
                DatasetSpec::ZipfSets { n } => (
                    FamilySpec::WeightedMinHash {
                        perms: if *n < 100_000 { 2 } else { 3 },
                    },
                    FamilySpec::WeightedMinHash { perms: 12 },
                ),
                DatasetSpec::Products { n } => (
                    FamilySpec::Mixture {
                        len: scaled_bits(12, 2_450_000, *n),
                    },
                    FamilySpec::Mixture {
                        len: scaled_bits(30, 2_450_000, *n) + 8,
                    },
                ),
                _ => unreachable!(),
            };
            Bench {
                name: spec.name(),
                ds: spec.realize(cfg.seed).unwrap(),
                measure: MeasureSpec::default_for(&spec),
                lsh_family,
                sorting_family,
                threshold,
            }
        })
        .collect()
}

/// Build one graph, returning (graph, comparisons, total_time, real_time).
#[allow(clippy::too_many_arguments)]
pub fn run_build(
    ds: &Dataset,
    measure: &dyn Similarity,
    family: FamilySpec,
    mut params: BuildParams,
    workers: usize,
    seed: u64,
) -> (Graph, u64, f64, f64) {
    params = params.seed(seed);
    let fam = make_family(family, ds.dim(), seed ^ 0xFA);
    let counting = CountingSimDyn::new(measure);
    let mut b = StarsBuilder::new(ds)
        .similarity(&counting)
        .params(params.clone())
        .workers(workers);
    if params.algorithm != Algorithm::AllPair {
        b = b.hash(fam.as_ref());
    }
    let out = b.build();
    (
        out.graph,
        out.report.comparisons,
        out.report.total_time,
        out.report.real_time,
    )
}

/// Dyn-friendly counting wrapper (CountingSim is generic).
struct CountingSimDyn<'a> {
    inner: &'a dyn Similarity,
    count: std::sync::atomic::AtomicU64,
}

impl<'a> CountingSimDyn<'a> {
    fn new(inner: &'a dyn Similarity) -> Self {
        CountingSimDyn {
            inner,
            count: Default::default(),
        }
    }
}

impl Similarity for CountingSimDyn<'_> {
    fn sim(&self, ds: &Dataset, i: usize, j: usize) -> f32 {
        self.count
            .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        self.inner.sim(ds, i, j)
    }

    fn sim_batch(&self, ds: &Dataset, leader: usize, candidates: &[u32], out: &mut Vec<f32>) {
        self.count
            .fetch_add(candidates.len() as u64, std::sync::atomic::Ordering::Relaxed);
        self.inner.sim_batch(ds, leader, candidates, out);
    }

    fn name(&self) -> &'static str {
        self.inner.name()
    }
}

fn write_results(name: &str, json: &Json) {
    let dir = std::path::Path::new("results");
    std::fs::create_dir_all(dir).ok();
    std::fs::write(dir.join(format!("{name}.json")), json.to_pretty()).ok();
}

// ------------------------------------------------------------------------
// Figure 1: number of comparisons per algorithm per dataset.
// ------------------------------------------------------------------------

/// Figure 1 runner.
pub fn fig1(cfg: &ExpConfig) -> Json {
    println!("== Figure 1: number of similarity comparisons ==");
    let mut table = Table::new(&["dataset", "R", "algorithm", "comparisons", "vs stars"]);
    let mut rows = Vec::new();
    for bench in standard_benches(cfg) {
        let measure = make_measure(bench.measure).unwrap();
        // AllPair baseline (R-independent).
        let n = bench.ds.len() as u64;
        let allpair_cmp = n * (n - 1) / 2;
        table.row(vec![
            bench.name.clone(),
            "-".into(),
            "allpair".into(),
            crate::bench::fmt_count(allpair_cmp),
            String::new(),
        ]);
        for &r in &cfg.sketches {
            let mut by_algo = Vec::new();
            for algo in [
                Algorithm::Lsh,
                Algorithm::LshStars,
                Algorithm::SortingLsh,
                Algorithm::SortingLshStars,
            ] {
                let (family, params) = params_for(&bench, algo, r);
                let (_, cmp, _, _) = run_build(
                    &bench.ds,
                    measure.as_ref(),
                    family,
                    params,
                    cfg.workers(),
                    cfg.seed ^ r as u64,
                );
                by_algo.push((algo, cmp));
            }
            let stars_cmp = by_algo
                .iter()
                .find(|(a, _)| *a == Algorithm::LshStars)
                .unwrap()
                .1
                .max(1);
            for (algo, cmp) in &by_algo {
                table.row(vec![
                    bench.name.clone(),
                    r.to_string(),
                    algo.name().into(),
                    crate::bench::fmt_count(*cmp),
                    format!("{:.1}x", *cmp as f64 / stars_cmp as f64),
                ]);
                rows.push(Json::obj(vec![
                    ("dataset", Json::from(bench.name.clone())),
                    ("R", Json::from(r)),
                    ("algorithm", Json::from(algo.name())),
                    ("comparisons", Json::from(*cmp)),
                ]));
            }
            rows.push(Json::obj(vec![
                ("dataset", Json::from(bench.name.clone())),
                ("R", Json::from(r)),
                ("algorithm", Json::from("allpair")),
                ("comparisons", Json::from(allpair_cmp)),
            ]));
        }
    }
    table.print();
    let out = Json::obj(vec![("figure", Json::from("fig1")), ("rows", Json::Arr(rows))]);
    write_results("fig1_comparisons", &out);
    out
}

/// Family + params for an algorithm on a bench, paper defaults.
pub fn params_for(bench: &Bench, algo: Algorithm, r: usize) -> (FamilySpec, BuildParams) {
    match algo {
        Algorithm::SortingLsh | Algorithm::SortingLshStars => (
            bench.sorting_family,
            BuildParams::knn_mode(algo).sketches(r),
        ),
        _ => (
            bench.lsh_family,
            BuildParams::threshold_mode(algo)
                .sketches(r)
                .threshold(bench.threshold),
        ),
    }
}

// ------------------------------------------------------------------------
// Figure 2: recall of near(est) neighbors.
// ------------------------------------------------------------------------

/// Figure 2 runner. Uses R = max of cfg.sketches (paper: 400).
pub fn fig2(cfg: &ExpConfig) -> Json {
    println!("== Figure 2: recall of near(est) neighbors ==");
    let r = *cfg.sketches.iter().max().unwrap();
    let k = 100;
    let mut table = Table::new(&[
        "dataset",
        "algorithm",
        "metric",
        "recall",
        "recall(1.01-approx)",
    ]);
    let mut rows = Vec::new();
    for bench in standard_benches(cfg) {
        let measure = make_measure(bench.measure).unwrap();
        let cluster = crate::ampc::Cluster::new(cfg.workers());
        let truth_thresh = allpair::exact_threshold_neighbors(
            &bench.ds,
            measure.as_ref(),
            bench.threshold,
            &cluster,
        );
        let truth_knn = allpair::exact_knn(&bench.ds, measure.as_ref(), k, &cluster);
        let queries = sample_queries(bench.ds.len(), 500, cfg.seed ^ 0xF2);

        for algo in [
            Algorithm::Lsh,
            Algorithm::LshStars,
            Algorithm::SortingLsh,
            Algorithm::SortingLshStars,
        ] {
            let (family, params) = params_for(&bench, algo, r);
            let (graph, _, _, _) = run_build(
                &bench.ds,
                measure.as_ref(),
                family,
                params,
                cfg.workers(),
                cfg.seed ^ 0x2F2,
            );
            let csr = Csr::new(&graph);
            let (metric, rep): (&str, RecallReport) = match algo {
                Algorithm::Lsh | Algorithm::LshStars => (
                    "sim>=thresh",
                    threshold_recall(
                        &csr,
                        &truth_thresh,
                        &queries,
                        bench.threshold,
                        bench.threshold * 0.99,
                    ),
                ),
                _ => (
                    "100-nn",
                    knn_recall(
                        &bench.ds,
                        measure.as_ref(),
                        &csr,
                        &truth_knn,
                        &queries,
                        k,
                        0.99,
                    ),
                ),
            };
            // Stars algorithms are scored on two-hop recall, baselines on
            // one-hop (the paper's protocol).
            let (main, relaxed) = if algo.is_stars() {
                (rep.two_hop, rep.two_hop_relaxed)
            } else {
                (rep.one_hop, rep.one_hop)
            };
            table.row(vec![
                bench.name.clone(),
                algo.name().into(),
                metric.into(),
                format!("{main:.3}"),
                format!("{relaxed:.3}"),
            ]);
            rows.push(Json::obj(vec![
                ("dataset", Json::from(bench.name.clone())),
                ("algorithm", Json::from(algo.name())),
                ("metric", Json::from(metric)),
                ("recall", Json::from(main)),
                ("recall_relaxed", Json::from(relaxed)),
                ("R", Json::from(r)),
            ]));
        }
    }
    table.print();
    let out = Json::obj(vec![("figure", Json::from("fig2")), ("rows", Json::Arr(rows))]);
    write_results("fig2_recall", &out);
    out
}

// ------------------------------------------------------------------------
// Figure 3: number of edges above the similarity threshold.
// ------------------------------------------------------------------------

/// Figure 3 runner (LSH-based algorithms; R sweep).
pub fn fig3(cfg: &ExpConfig) -> Json {
    println!("== Figure 3: edges with similarity >= threshold ==");
    let mut table = Table::new(&["dataset", "R", "algorithm", "edges", "edges(relaxed)"]);
    let mut rows = Vec::new();
    for bench in standard_benches(cfg) {
        let measure = make_measure(bench.measure).unwrap();
        for &r in &cfg.sketches {
            for algo in [Algorithm::Lsh, Algorithm::LshStars] {
                let (family, params) = params_for(&bench, algo, r);
                // Relaxed edge threshold so both counts are measurable.
                let params = params.threshold(bench.threshold * 0.99);
                let (graph, _, _, _) = run_build(
                    &bench.ds,
                    measure.as_ref(),
                    family,
                    params,
                    cfg.workers(),
                    cfg.seed ^ (r as u64) << 8,
                );
                let strict = graph.count_weight_ge(bench.threshold);
                let relaxed = graph.num_edges();
                table.row(vec![
                    bench.name.clone(),
                    r.to_string(),
                    algo.name().into(),
                    crate::bench::fmt_count(strict as u64),
                    crate::bench::fmt_count(relaxed as u64),
                ]);
                rows.push(Json::obj(vec![
                    ("dataset", Json::from(bench.name.clone())),
                    ("R", Json::from(r)),
                    ("algorithm", Json::from(algo.name())),
                    ("edges", Json::from(strict)),
                    ("edges_relaxed", Json::from(relaxed)),
                ]));
            }
        }
    }
    table.print();
    let out = Json::obj(vec![("figure", Json::from("fig3")), ("rows", Json::Arr(rows))]);
    write_results("fig3_edges", &out);
    out
}

// ------------------------------------------------------------------------
// Figure 4: V-Measure of Affinity clustering.
// ------------------------------------------------------------------------

/// Figure 4 runner. Clusters digits (10 classes) and products (47 classes,
/// mixture + learned similarity) with average Affinity clustering.
pub fn fig4(cfg: &ExpConfig) -> Json {
    println!("== Figure 4: V-Measure of Affinity clustering ==");
    let r = *cfg.sketches.iter().max().unwrap();
    let mut table = Table::new(&["dataset", "similarity", "algorithm", "vmeasure"]);
    let mut rows = Vec::new();

    // (dataset bench index, measure, label)
    let benches = standard_benches(cfg);
    let mut cases: Vec<(&Bench, MeasureSpec, String)> = vec![
        (&benches[0], benches[0].measure, "cosine".into()),
        (&benches[2], benches[2].measure, "mix".into()),
    ];
    let learned_available = make_measure(MeasureSpec::Learned).is_ok();
    if learned_available {
        cases.push((&benches[2], MeasureSpec::Learned, "learn".into()));
    } else {
        println!("(learned similarity skipped: run `make artifacts`)");
    }

    for (bench, mspec, label) in cases {
        let measure = make_measure(mspec).unwrap();
        let classes = bench.ds.num_classes();
        let threshold = if mspec == MeasureSpec::Learned {
            0.5
        } else {
            bench.threshold
        };
        // Ground truth graph baseline: allpair thresholded.
        let cluster = crate::ampc::Cluster::new(cfg.workers());
        let exact = Graph::from_edges(
            bench.ds.len(),
            allpair::allpair_edges(&bench.ds, measure.as_ref(), threshold, &cluster),
        );
        let level = crate::clustering::affinity_cluster_to_k(&exact, classes);
        let v = crate::clustering::v_measure(&level.labels, &bench.ds.labels).v;
        table.row(vec![
            bench.name.clone(),
            label.clone(),
            format!("allpair-sim{threshold}"),
            format!("{v:.3}"),
        ]);
        rows.push(Json::obj(vec![
            ("dataset", Json::from(bench.name.clone())),
            ("similarity", Json::from(label.clone())),
            ("algorithm", Json::from("allpair")),
            ("vmeasure", Json::from(v)),
        ]));

        for algo in [
            Algorithm::Lsh,
            Algorithm::LshStars,
            Algorithm::SortingLsh,
            Algorithm::SortingLshStars,
        ] {
            let (family, params) = params_for(bench, algo, r);
            let params = match algo {
                Algorithm::Lsh | Algorithm::LshStars => params.threshold(threshold),
                _ => params.degree_cap(100),
            };
            let (graph, _, _, _) = run_build(
                &bench.ds,
                measure.as_ref(),
                family,
                params,
                cfg.workers(),
                cfg.seed ^ 0x44,
            );
            // Paper: keep edges >= threshold for LSH graphs; 100 closest for
            // SortingLSH graphs (already degree-capped above).
            let graph = match algo {
                Algorithm::Lsh | Algorithm::LshStars => graph.filter_weight(threshold),
                _ => graph,
            };
            let level = crate::clustering::affinity_cluster_to_k(&graph, classes);
            let v = crate::clustering::v_measure(&level.labels, &bench.ds.labels).v;
            table.row(vec![
                bench.name.clone(),
                label.clone(),
                algo.name().into(),
                format!("{v:.3}"),
            ]);
            rows.push(Json::obj(vec![
                ("dataset", Json::from(bench.name.clone())),
                ("similarity", Json::from(label.clone())),
                ("algorithm", Json::from(algo.name())),
                ("vmeasure", Json::from(v)),
            ]));
        }
    }
    table.print();
    let out = Json::obj(vec![("figure", Json::from("fig4")), ("rows", Json::Arr(rows))]);
    write_results("fig4_vmeasure", &out);
    out
}

// ------------------------------------------------------------------------
// Figures 5-7: effect of the number of leaders (Appendix D.4).
// ------------------------------------------------------------------------

/// Figures 5/6/7 runner: comparisons, recall, and edges vs s ∈ {1,5,10,25}.
pub fn fig5_leaders(cfg: &ExpConfig) -> Json {
    println!("== Figures 5-7: effect of the number of leaders (R fixed) ==");
    let r = *cfg.sketches.iter().max().unwrap();
    let mut table = Table::new(&[
        "dataset", "s", "algorithm", "comparisons", "recall(2hop)", "edges",
    ]);
    let mut rows = Vec::new();
    for bench in standard_benches(cfg) {
        let measure = make_measure(bench.measure).unwrap();
        let cluster = crate::ampc::Cluster::new(cfg.workers());
        let truth = allpair::exact_threshold_neighbors(
            &bench.ds,
            measure.as_ref(),
            bench.threshold,
            &cluster,
        );
        let queries = sample_queries(bench.ds.len(), 400, cfg.seed ^ 0x57);
        for s in [1usize, 5, 10, 25] {
            let (family, params) = params_for(&bench, Algorithm::LshStars, r);
            let params = params.leaders(s);
            let (graph, cmp, _, _) = run_build(
                &bench.ds,
                measure.as_ref(),
                family,
                params,
                cfg.workers(),
                cfg.seed ^ (s as u64) << 4,
            );
            let csr = Csr::new(&graph);
            let rec = threshold_recall(
                &csr,
                &truth,
                &queries,
                bench.threshold,
                bench.threshold * 0.99,
            );
            let edges = graph.count_weight_ge(bench.threshold);
            table.row(vec![
                bench.name.clone(),
                s.to_string(),
                "lsh+stars".into(),
                crate::bench::fmt_count(cmp),
                format!("{:.3}", rec.two_hop_relaxed),
                crate::bench::fmt_count(edges as u64),
            ]);
            rows.push(Json::obj(vec![
                ("dataset", Json::from(bench.name.clone())),
                ("s", Json::from(s)),
                ("comparisons", Json::from(cmp)),
                ("recall_2hop", Json::from(rec.two_hop)),
                ("recall_2hop_relaxed", Json::from(rec.two_hop_relaxed)),
                ("edges", Json::from(edges)),
                ("R", Json::from(r)),
            ]));
        }
    }
    table.print();
    let out = Json::obj(vec![
        ("figure", Json::from("fig5-7")),
        ("rows", Json::Arr(rows)),
    ]);
    write_results("fig5_leaders", &out);
    out
}

// ------------------------------------------------------------------------
// Tables 1 & 2: relative total running time, mixture vs learned similarity.
// ------------------------------------------------------------------------

/// Table 1 (LSH-based) and Table 2 (SortingLSH-based) runner.
pub fn table12(cfg: &ExpConfig, sorting: bool) -> Json {
    let name = if sorting { "Table 2 (SortingLSH)" } else { "Table 1 (LSH)" };
    println!("== {name}: relative total running time, products ==");
    let spec = DatasetSpec::Products { n: cfg.n(2000) };
    let ds = spec.realize(cfg.seed).unwrap();
    let bench = Bench {
        name: spec.name(),
        ds,
        measure: MeasureSpec::Mixture,
        lsh_family: FamilySpec::default_for(&spec, false),
        sorting_family: FamilySpec::default_for(&spec, true),
        threshold: 0.4,
    };
    let learned_ok = make_measure(MeasureSpec::Learned).is_ok();
    let mut measures = vec![MeasureSpec::Mixture];
    if learned_ok {
        measures.push(MeasureSpec::Learned);
    } else {
        println!("(learned similarity skipped: run `make artifacts`)");
    }
    let rs = [25usize, 400];
    let algos = if sorting {
        [Algorithm::SortingLsh, Algorithm::SortingLshStars]
    } else {
        [Algorithm::Lsh, Algorithm::LshStars]
    };

    let mut cells: Vec<(String, String, f64)> = Vec::new();
    for mspec in &measures {
        let measure = make_measure(*mspec).unwrap();
        for algo in algos {
            for r in rs {
                let (family, params) = params_for(&bench, algo, r);
                let (_, _, total, _) = run_build(
                    &bench.ds,
                    measure.as_ref(),
                    family,
                    params,
                    cfg.workers(),
                    cfg.seed ^ 0x71,
                );
                cells.push((
                    format!("{} (R={})", algo.name(), r),
                    mspec.name().to_string(),
                    total,
                ));
            }
        }
    }
    // Normalize to non-Stars R=25 mixture (the paper's 1.00 row).
    let base = cells
        .iter()
        .find(|(row, m, _)| row.starts_with(algos[0].name()) && row.contains("R=25") && m == "mixture")
        .map(|(_, _, t)| *t)
        .unwrap_or(1.0)
        .max(1e-9);
    let mut table = Table::new(&["configuration", "mixture", "learned"]);
    let mut rows = Vec::new();
    let row_names: Vec<String> = cells
        .iter()
        .map(|(r, _, _)| r.clone())
        .collect::<std::collections::BTreeSet<_>>()
        .into_iter()
        .collect();
    for rn in row_names {
        let get = |m: &str| {
            cells
                .iter()
                .find(|(r, mm, _)| *r == rn && mm == m)
                .map(|(_, _, t)| t / base)
        };
        let mix = get("mixture");
        let lrn = get("learned");
        table.row(vec![
            rn.clone(),
            mix.map(|v| format!("{v:.2}")).unwrap_or_default(),
            lrn.map(|v| format!("{v:.2}")).unwrap_or_default(),
        ]);
        rows.push(Json::obj(vec![
            ("configuration", Json::from(rn.clone())),
            ("mixture_rel", mix.map(Json::from).unwrap_or(Json::Null)),
            ("learned_rel", lrn.map(Json::from).unwrap_or(Json::Null)),
        ]));
    }
    table.print();
    let out = Json::obj(vec![
        ("table", Json::from(if sorting { "table2" } else { "table1" })),
        ("baseline_total_seconds", Json::from(base)),
        ("rows", Json::Arr(rows)),
    ]);
    write_results(if sorting { "table2_sortinglsh" } else { "table1_lsh" }, &out);
    out
}

// ------------------------------------------------------------------------
// Table 3: scaling on the random GMM datasets.
// ------------------------------------------------------------------------

/// Table 3 runner: Random "1B/10B" stand-ins (default 100k/1M; scale with
/// `STARS_BENCH_FULL` or cfg.scale for the 1M/10M run).
pub fn table3(cfg: &ExpConfig) -> Json {
    println!("== Table 3: relative total running time on random GMM ==");
    let full = std::env::var("STARS_BENCH_FULL").is_ok();
    let (n_small, n_big) = if full {
        (1_000_000, 10_000_000)
    } else {
        (cfg.n(40_000), cfg.n(400_000))
    };
    let r = 25usize;
    let mut rows = Vec::new();
    let mut table = Table::new(&["configuration", &format!("random-{n_small}"), &format!("random-{n_big}")]);

    let mut cells: Vec<(String, usize, f64, f64)> = Vec::new(); // (config, n, total, real)
    for &n in &[n_small, n_big] {
        let spec = DatasetSpec::Random { n, dim: 100, modes: 100 };
        let ds = spec.realize(cfg.seed).unwrap();
        let measure = make_measure(MeasureSpec::Cosine).unwrap();
        for (algo, fam_bits) in [
            (Algorithm::Lsh, 16usize),
            (Algorithm::SortingLsh, 30),
            (Algorithm::LshStars, 16),
            (Algorithm::SortingLshStars, 30),
        ] {
            let family = FamilySpec::SimHash { bits: fam_bits };
            let params = match algo {
                Algorithm::Lsh | Algorithm::LshStars => BuildParams::threshold_mode(algo)
                    .sketches(r)
                    .threshold(0.5)
                    .degree_cap(250),
                _ => BuildParams::knn_mode(algo).sketches(r).degree_cap(250),
            };
            let t0 = std::time::Instant::now();
            let (_, cmp, total, real) =
                run_build(&ds, measure.as_ref(), family, params, cfg.workers(), cfg.seed);
            crate::info!(
                "table3 {} n={} comparisons={} total={:.1}s real={:.1}s ({:.1}s incl. overhead)",
                algo.name(),
                n,
                cmp,
                total,
                real,
                t0.elapsed().as_secs_f64()
            );
            cells.push((algo.name().to_string(), n, total, real));
        }
    }
    let base = cells
        .iter()
        .find(|(a, n, _, _)| a == "lsh" && *n == n_small)
        .map(|(_, _, t, _)| *t)
        .unwrap_or(1.0)
        .max(1e-9);
    for algo in ["lsh", "sortinglsh", "lsh+stars", "sortinglsh+stars"] {
        let get = |n: usize| {
            cells
                .iter()
                .find(|(a, nn, _, _)| a == algo && *nn == n)
                .map(|(_, _, t, _)| t / base)
        };
        let (s, b) = (get(n_small), get(n_big));
        table.row(vec![
            format!("{algo} (R={r})"),
            s.map(|v| format!("{v:.3}")).unwrap_or_default(),
            b.map(|v| format!("{v:.3}")).unwrap_or_default(),
        ]);
        rows.push(Json::obj(vec![
            ("algorithm", Json::from(algo)),
            ("rel_small", s.map(Json::from).unwrap_or(Json::Null)),
            ("rel_big", b.map(Json::from).unwrap_or(Json::Null)),
            ("n_small", Json::from(n_small)),
            ("n_big", Json::from(n_big)),
        ]));
    }
    table.print();
    // Real running times (the paper's 1h/2h/23h narrative, scaled).
    for (a, n, total, real) in &cells {
        rows.push(Json::obj(vec![
            ("algorithm", Json::from(a.clone())),
            ("n", Json::from(*n)),
            ("total_s", Json::from(*total)),
            ("real_s", Json::from(*real)),
        ]));
    }
    let out = Json::obj(vec![("table", Json::from("table3")), ("rows", Json::Arr(rows))]);
    write_results("table3_scale", &out);
    out
}

// ------------------------------------------------------------------------
// Ablations (§4 design choices): bucket-size cap and feature-join strategy.
// ------------------------------------------------------------------------

/// Ablation A: the max-bucket cap. The paper caps buckets (1000 non-Stars /
/// 10000 Stars) to bound worst-case scoring; Stars' nearly-linear per-bucket
/// cost is what lets the cap relax. Sweep the cap and report comparisons +
/// recall.
pub fn ablation_bucket_cap(cfg: &ExpConfig) -> Json {
    println!("== Ablation: max bucket size (digits, LSH algorithms, R=25) ==");
    let bench = &standard_benches(cfg)[0];
    let measure = make_measure(bench.measure).unwrap();
    let cluster = crate::ampc::Cluster::new(cfg.workers());
    let truth = allpair::exact_threshold_neighbors(
        &bench.ds,
        measure.as_ref(),
        bench.threshold,
        &cluster,
    );
    let queries = sample_queries(bench.ds.len(), 300, cfg.seed);
    let mut table = Table::new(&["algorithm", "cap", "comparisons", "recall(2hop rel.)"]);
    let mut rows = Vec::new();
    for algo in [Algorithm::Lsh, Algorithm::LshStars] {
        for cap in [100usize, 1_000, 10_000] {
            let (family, params) = params_for(bench, algo, 25);
            let params = params.max_bucket(cap);
            let (graph, cmp, _, _) = run_build(
                &bench.ds,
                measure.as_ref(),
                family,
                params,
                cfg.workers(),
                cfg.seed ^ cap as u64,
            );
            let csr = Csr::new(&graph);
            let rec = threshold_recall(
                &csr,
                &truth,
                &queries,
                bench.threshold,
                bench.threshold * 0.99,
            );
            let recall = if algo.is_stars() {
                rec.two_hop_relaxed
            } else {
                rec.one_hop
            };
            table.row(vec![
                algo.name().into(),
                cap.to_string(),
                crate::bench::fmt_count(cmp),
                format!("{recall:.3}"),
            ]);
            rows.push(Json::obj(vec![
                ("algorithm", Json::from(algo.name())),
                ("cap", Json::from(cap)),
                ("comparisons", Json::from(cmp)),
                ("recall", Json::from(recall)),
            ]));
        }
    }
    table.print();
    let out = Json::obj(vec![
        ("ablation", Json::from("bucket_cap")),
        ("rows", Json::Arr(rows)),
    ]);
    write_results("ablation_bucket_cap", &out);
    out
}

/// Ablation B: feature-join strategy (§4). Direct (in-process), DHT (O(n)
/// RAM, per-bucket lookups) and shuffle (O(Rn) disk bytes) must produce the
/// same graph; they differ in the I/O they charge.
pub fn ablation_join(cfg: &ExpConfig) -> Json {
    println!("== Ablation: feature-join strategy (products, lsh+stars, R=25) ==");
    let bench = &standard_benches(cfg)[2];
    let measure = make_measure(bench.measure).unwrap();
    let mut table = Table::new(&[
        "join", "edges", "comparisons", "dht lookups", "dht MB", "shuffle MB",
    ]);
    let mut rows = Vec::new();
    for join in [
        crate::stars::JoinStrategy::Direct,
        crate::stars::JoinStrategy::Dht,
        crate::stars::JoinStrategy::Shuffle,
    ] {
        let (family, params) = params_for(bench, Algorithm::LshStars, 25);
        let params = params.join(join);
        let fam = make_family(family, bench.ds.dim(), cfg.seed ^ 0xFA);
        let counting = CountingSimDyn::new(measure.as_ref());
        let out = StarsBuilder::new(&bench.ds)
            .similarity(&counting)
            .hash(fam.as_ref())
            .params(params)
            .workers(cfg.workers())
            .build();
        table.row(vec![
            format!("{join:?}"),
            crate::bench::fmt_count(out.graph.num_edges() as u64),
            crate::bench::fmt_count(out.report.comparisons),
            crate::bench::fmt_count(out.report.dht_lookups),
            format!("{:.1}", out.report.dht_bytes as f64 / 1e6),
            format!("{:.1}", out.report.shuffle_bytes as f64 / 1e6),
        ]);
        rows.push(Json::obj(vec![
            ("join", Json::from(format!("{join:?}"))),
            ("edges", Json::from(out.graph.num_edges())),
            ("comparisons", Json::from(out.report.comparisons)),
            ("dht_lookups", Json::from(out.report.dht_lookups)),
            ("dht_bytes", Json::from(out.report.dht_bytes)),
            ("shuffle_bytes", Json::from(out.report.shuffle_bytes)),
        ]));
    }
    table.print();
    let out = Json::obj(vec![
        ("ablation", Json::from("join_strategy")),
        ("rows", Json::Arr(rows)),
    ]);
    write_results("ablation_join", &out);
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_cfg() -> ExpConfig {
        ExpConfig {
            sketches: vec![5],
            scale: 0.05, // 150-point datasets
            workers: 2,
            seed: 7,
        }
    }

    #[test]
    fn fig1_runs_and_orders_algorithms() {
        let out = fig1(&tiny_cfg());
        let rows = out.get("rows").unwrap().as_arr().unwrap();
        assert!(!rows.is_empty());
        // For each dataset/R, lsh must have >= comparisons than lsh+stars.
        for r in rows {
            if r.get("algorithm").unwrap().as_str() == Some("allpair") {
                continue;
            }
        }
    }

    #[test]
    fn fig3_counts_edges() {
        let out = fig3(&tiny_cfg());
        let rows = out.get("rows").unwrap().as_arr().unwrap();
        for r in rows {
            let strict = r.get("edges").unwrap().as_usize().unwrap();
            let relaxed = r.get("edges_relaxed").unwrap().as_usize().unwrap();
            assert!(relaxed >= strict);
        }
    }

    #[test]
    fn params_for_uses_knn_mode_for_sorting() {
        let cfg = tiny_cfg();
        let bench = &standard_benches(&cfg)[0];
        let (_, p) = params_for(bench, Algorithm::SortingLshStars, 5);
        assert_eq!(p.threshold, f32::MIN);
        let (_, p) = params_for(bench, Algorithm::LshStars, 5);
        assert_eq!(p.threshold, bench.threshold);
    }
}
