//! Executes [`Job`]s: realizes the dataset, wires measure + family, runs the
//! builder on the simulated cluster, and returns a structured result.

use crate::ampc::CostReport;
use crate::coordinator::job::{FamilySpec, Job, MeasureSpec};
use crate::data::Dataset;
use crate::graph::Graph;
use crate::lsh::{LshFamily, MinHash, MixtureHash, SimHash, WeightedMinHash};
use crate::runtime::{ArtifactMeta, Engine, LearnedModel};
use crate::sim::{
    CosineSim, JaccardSim, LearnedSim, MixtureSim, Similarity, WeightedJaccardSim,
};
use crate::stars::{Algorithm, StarsBuilder};
use crate::util::json::Json;
use crate::util::rng::derive_seed;

/// Outcome of one job.
#[derive(Debug)]
pub struct JobResult {
    /// The built graph.
    pub graph: Graph,
    /// Cost report.
    pub report: CostReport,
    /// The dataset (kept for downstream evaluation).
    pub dataset: Dataset,
}

impl JobResult {
    /// JSON summary (without the graph payload).
    pub fn to_json(&self, job: &Job) -> Json {
        Json::obj(vec![
            ("job", job.to_json()),
            ("edges", Json::from(self.graph.num_edges())),
            ("nodes", Json::from(self.graph.num_nodes())),
            ("report", self.report.to_json()),
        ])
    }
}

/// Instantiate a hash family from its spec.
pub fn make_family(spec: FamilySpec, dim: usize, seed: u64) -> Box<dyn LshFamily> {
    match spec {
        FamilySpec::SimHash { bits } => Box::new(SimHash::new(dim.max(1), bits, seed)),
        FamilySpec::MinHash { perms } => Box::new(MinHash::new(perms, seed)),
        FamilySpec::WeightedMinHash { perms } => Box::new(WeightedMinHash::new(perms, seed)),
        FamilySpec::Mixture { len } => Box::new(MixtureHash::new(dim.max(1), len, seed)),
    }
}

/// Instantiate a similarity measure. `Learned` loads the AOT artifact and
/// fails with a clear message if `make artifacts` has not run.
pub fn make_measure(spec: MeasureSpec) -> crate::Result<Box<dyn Similarity>> {
    Ok(match spec {
        MeasureSpec::Cosine => Box::new(CosineSim),
        MeasureSpec::Jaccard => Box::new(JaccardSim),
        MeasureSpec::WeightedJaccard => Box::new(WeightedJaccardSim),
        MeasureSpec::Mixture => Box::new(MixtureSim::default()),
        MeasureSpec::Learned => {
            let meta = ArtifactMeta::load(&ArtifactMeta::default_dir())?;
            let engine = Engine::cpu()?;
            let model = LearnedModel::load(&engine, &meta)?;
            Box::new(LearnedSim::new(model))
        }
    })
}

/// Run a job end to end.
pub fn run_job(job: &Job) -> crate::Result<JobResult> {
    let dataset = job.dataset.realize(job.data_seed)?;
    let measure = make_measure(job.measure)?;
    let family = make_family(
        job.family,
        dataset.dim(),
        derive_seed(job.params.seed, 0xFA),
    );
    let workers = if job.workers == 0 {
        crate::util::pool::default_workers()
    } else {
        job.workers
    };
    let mut builder = StarsBuilder::new(&dataset)
        .similarity(measure.as_ref())
        .params(job.params.clone())
        .workers(workers);
    if job.params.algorithm != Algorithm::AllPair {
        builder = builder.hash(family.as_ref());
    }
    let out = builder.build();
    Ok(JobResult {
        graph: out.graph,
        report: out.report,
        dataset,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::job::DatasetSpec;
    use crate::stars::BuildParams;

    #[test]
    fn run_small_job_end_to_end() {
        let job = Job {
            dataset: DatasetSpec::Random {
                n: 500,
                dim: 32,
                modes: 10,
            },
            measure: MeasureSpec::Cosine,
            family: FamilySpec::SimHash { bits: 8 },
            params: BuildParams::threshold_mode(Algorithm::LshStars).sketches(10),
            data_seed: 3,
            workers: 2,
        };
        let res = run_job(&job).unwrap();
        assert!(res.graph.num_edges() > 0);
        assert!(res.report.comparisons > 0);
        let j = res.to_json(&job).to_string();
        assert!(j.contains("comparisons"));
    }

    #[test]
    fn zipf_job_with_weighted_minhash() {
        let job = Job {
            dataset: DatasetSpec::ZipfSets { n: 300 },
            measure: MeasureSpec::WeightedJaccard,
            family: FamilySpec::WeightedMinHash { perms: 3 },
            params: BuildParams::threshold_mode(Algorithm::LshStars)
                .sketches(8)
                .threshold(0.1),
            data_seed: 4,
            workers: 2,
        };
        let res = run_job(&job).unwrap();
        assert!(res.graph.num_edges() > 0);
    }

    #[test]
    fn family_construction() {
        let f = make_family(FamilySpec::SimHash { bits: 8 }, 16, 1);
        assert_eq!(f.sketch_len(), 8);
        let f = make_family(FamilySpec::WeightedMinHash { perms: 3 }, 0, 1);
        assert_eq!(f.sketch_len(), 3);
        let f = make_family(FamilySpec::Mixture { len: 12 }, 16, 1);
        assert_eq!(f.sketch_len(), 12);
        let f = make_family(FamilySpec::MinHash { perms: 4 }, 0, 1);
        assert_eq!(f.sketch_len(), 4);
    }
}
