//! Executes [`Job`]s: realizes the dataset, wires measure + family, runs the
//! builder on the simulated cluster, and returns a structured result.

use crate::ampc::CostReport;
use crate::coordinator::job::{FamilySpec, Job, MeasureSpec};
use crate::data::Dataset;
use crate::graph::Graph;
use crate::lsh::{LshFamily, MinHash, MixtureHash, SimHash, WeightedMinHash};
use crate::runtime::{ArtifactMeta, Engine, LearnedModel};
use crate::sim::{
    CosineSim, JaccardSim, LearnedSim, MixtureSim, Similarity, WeightedJaccardSim,
};
use crate::stars::{Algorithm, StarsBuilder};
use crate::util::json::Json;
use crate::util::rng::derive_seed;

/// Outcome of one job.
#[derive(Debug)]
pub struct JobResult {
    /// The built graph.
    pub graph: Graph,
    /// Cost report.
    pub report: CostReport,
    /// The dataset (kept for downstream evaluation).
    pub dataset: Dataset,
}

impl JobResult {
    /// JSON summary (without the graph payload).
    pub fn to_json(&self, job: &Job) -> Json {
        Json::obj(vec![
            ("job", job.to_json()),
            ("edges", Json::from(self.graph.num_edges())),
            ("nodes", Json::from(self.graph.num_nodes())),
            ("report", self.report.to_json()),
        ])
    }
}

/// Instantiate a hash family from its spec.
pub fn make_family(spec: FamilySpec, dim: usize, seed: u64) -> Box<dyn LshFamily> {
    match spec {
        FamilySpec::SimHash { bits } => Box::new(SimHash::new(dim.max(1), bits, seed)),
        FamilySpec::MinHash { perms } => Box::new(MinHash::new(perms, seed)),
        FamilySpec::WeightedMinHash { perms } => Box::new(WeightedMinHash::new(perms, seed)),
        FamilySpec::Mixture { len } => Box::new(MixtureHash::new(dim.max(1), len, seed)),
    }
}

/// Instantiate a similarity measure. `Learned` loads the AOT artifact and
/// fails with a clear message if `make artifacts` has not run.
pub fn make_measure(spec: MeasureSpec) -> crate::Result<Box<dyn Similarity>> {
    Ok(match spec {
        MeasureSpec::Cosine => Box::new(CosineSim),
        MeasureSpec::Jaccard => Box::new(JaccardSim),
        MeasureSpec::WeightedJaccard => Box::new(WeightedJaccardSim),
        MeasureSpec::Mixture => Box::new(MixtureSim::default()),
        MeasureSpec::Learned => {
            let meta = ArtifactMeta::load(&ArtifactMeta::default_dir())?;
            let engine = Engine::cpu()?;
            let model = LearnedModel::load(&engine, &meta)?;
            Box::new(LearnedSim::new(model))
        }
    })
}

/// Run a job end to end.
pub fn run_job(job: &Job) -> crate::Result<JobResult> {
    let dataset = job.dataset.realize(job.data_seed)?;
    let measure = make_measure(job.measure)?;
    let family = make_family(
        job.family,
        dataset.dim(),
        derive_seed(job.params.seed, 0xFA),
    );
    let workers = if job.workers == 0 {
        crate::util::pool::default_workers()
    } else {
        job.workers
    };
    let mut builder = StarsBuilder::new(&dataset)
        .similarity(measure.as_ref())
        .params(job.params.clone())
        .workers(workers);
    if job.params.algorithm != Algorithm::AllPair {
        builder = builder.hash(family.as_ref());
    }
    let out = builder.build();
    Ok(JobResult {
        graph: out.graph,
        report: out.report,
        dataset,
    })
}

/// The serving-side measure for a job's measure spec. `Learned` scores
/// through the PJRT engine, which has no batched query-row path yet.
pub fn serve_measure(spec: MeasureSpec) -> crate::Result<crate::serve::ServeMeasure> {
    use crate::serve::ServeMeasure;
    Ok(match spec {
        MeasureSpec::Cosine => ServeMeasure::Cosine,
        MeasureSpec::Jaccard => ServeMeasure::Jaccard,
        MeasureSpec::WeightedJaccard => ServeMeasure::WeightedJaccard,
        MeasureSpec::Mixture => ServeMeasure::Mixture { alpha: 0.5 },
        MeasureSpec::Learned => {
            anyhow::bail!("the learned measure has no serving path yet (see ROADMAP)")
        }
    })
}

/// Options for [`run_serve_with`] beyond the basic query sweep.
#[derive(Clone, Debug)]
pub struct ServeOpts {
    /// Queries sampled from the dataset (the paper's recall protocol).
    pub queries: usize,
    /// Neighbors returned per query.
    pub k: usize,
    /// Points streamed in after the query sweep to exercise compaction
    /// (0 = skip the write-path phase).
    pub inserts: usize,
    /// How the compaction folds the inserts in (the serve config's knob).
    pub compaction: crate::serve::CompactionMode,
    /// Force one full rebuild per N compactions under the incremental mode
    /// (0 = never) — forwarded to
    /// [`crate::serve::ServeConfig::full_rebuild_every`]; the resulting
    /// full/incremental mix is reported in the compaction JSON.
    pub full_rebuild_every: usize,
    /// Serve with the quantized first-pass tier
    /// ([`crate::serve::ServeConfig::quantized`]): int8 estimates over the
    /// candidate set, exact f32 rescore of the top `k · rescore_factor`.
    /// The reported `recall_at_k` is still measured against exact brute
    /// force, so this is where the quantized recall cost becomes visible.
    pub quantized: bool,
    /// Rescore width multiplier for the quantized path (ignored unless
    /// `quantized`; clamped to ≥ 1).
    pub rescore_factor: usize,
    /// Serve the query sweep through an admission-controlled
    /// [`crate::serve::FrontDoor`] with this in-flight bound (0 = no front
    /// door; queries hit the engine directly).
    pub queue_limit: usize,
    /// Per-query deadline budget for the front door, milliseconds
    /// (0 = no deadline shedding). Ignored unless `queue_limit > 0`.
    pub deadline_ms: f64,
    /// Apply deterministic synthetic pressure to the front door (held
    /// admission permits) so the report shows the full ladder — admitted,
    /// degraded, and shed counts — from one run. Ignored unless
    /// `queue_limit > 0`.
    pub overload: bool,
    /// Periodically write a Prometheus-text metrics snapshot to this path
    /// while the serve sweep runs (atomic tmp+rename, so a scraper never
    /// reads a torn file). `None` = no exporter.
    pub metrics_out: Option<std::path::PathBuf>,
    /// Rewrite interval for `metrics_out`, seconds (clamped to ≥ 0.01 by
    /// the exporter). Ignored unless `metrics_out` is set.
    pub metrics_every_s: f64,
    /// Serve through a fence-partitioned [`crate::serve::ShardedEngine`]
    /// with this many shards (≤ 1 = the single-process
    /// [`crate::serve::QueryEngine`]). The sharded build forces
    /// `max_candidates = 0` — the shard-invariance contract needs the
    /// uncapped candidate walk — so recall is measured under that config.
    pub shards: usize,
    /// Per-tenant QPS cap spec `QPS[:BURST]` for the front door's token
    /// buckets (e.g. `"0.5:4"`; burst defaults to 8). Requires
    /// `queue_limit > 0`. The sweep then drives one hot tenant past its
    /// burst and one cold tenant through, so the report's
    /// `admission.tenant_sheds` shows the cap engaging without starving
    /// other tenants.
    pub tenants: Option<String>,
    /// Durable serving state directory ([`crate::serve::DurableStore`]):
    /// inserts are WAL'd before they are applied, compactions checkpoint a
    /// crash-consistent snapshot, and a restart cold-starts from the newest
    /// valid snapshot plus WAL-suffix replay instead of rebuilding.
    /// `None` = in-memory serving (the previous behavior, byte-identical
    /// JSON).
    pub state_dir: Option<std::path::PathBuf>,
    /// WAL fsync policy spec for `state_dir`: `always`, `os`, or
    /// `every:N` ([`crate::serve::FsyncPolicy::parse`]). Ignored without a
    /// state dir.
    pub fsync: String,
    /// Seal the active delta tail into an immutable, pre-sketched
    /// [`crate::serve::SealedSegment`] every N inserts
    /// ([`crate::serve::ServeConfig::seal_limit`]; 0 = never seal).
    /// Sealed serving is bit-identical to the brute-forced tail, so this
    /// only moves per-query work, never answers.
    pub seal_limit: usize,
}

impl Default for ServeOpts {
    fn default() -> ServeOpts {
        ServeOpts {
            queries: 1000,
            k: 10,
            inserts: 0,
            compaction: crate::serve::CompactionMode::default(),
            full_rebuild_every: 0,
            quantized: false,
            rescore_factor: 4,
            queue_limit: 0,
            deadline_ms: 0.0,
            overload: false,
            metrics_out: None,
            metrics_every_s: 1.0,
            shards: 1,
            tenants: None,
            state_dir: None,
            fsync: "os".to_string(),
            seal_limit: 0,
        }
    }
}

/// The serve sweep's engine: one process-local [`crate::serve::QueryEngine`]
/// or a fence-partitioned [`crate::serve::ShardedEngine`] scatter-gathering
/// across shard workers. Under `max_candidates = 0` both answer
/// bit-identically, so the sweep below never cares which one is behind it.
enum AnyEngine<'f> {
    Single(crate::serve::QueryEngine<'f>),
    Sharded(crate::serve::ShardedEngine<'f>),
}

impl<'f> AnyEngine<'f> {
    fn query(&self, queries: &Dataset, k: usize) -> Vec<Vec<(u32, f32)>> {
        match self {
            AnyEngine::Single(e) => e.query(queries, k),
            AnyEngine::Sharded(e) => e.query(queries, k),
        }
    }

    fn insert(
        &self,
        row: Option<&[f32]>,
        set: Option<crate::data::types::WeightedSet>,
    ) -> u32 {
        match self {
            AnyEngine::Single(e) => e.insert(row, set),
            AnyEngine::Sharded(e) => e.insert(row, set),
        }
    }

    fn compact_report(&self) -> Option<crate::serve::CompactionReport> {
        match self {
            AnyEngine::Single(e) => e.compact_report(),
            AnyEngine::Sharded(e) => e.compact_report(),
        }
    }

    fn snapshot(&self) -> std::sync::Arc<crate::serve::StarIndex<'f>> {
        match self {
            AnyEngine::Single(e) => e.snapshot(),
            AnyEngine::Sharded(e) => e.snapshot(),
        }
    }

    fn next_gid(&self) -> u32 {
        match self {
            AnyEngine::Single(e) => e.next_gid(),
            AnyEngine::Sharded(e) => e.next_gid(),
        }
    }
}

impl crate::serve::ServeBackend for AnyEngine<'_> {
    fn query(&self, queries: &Dataset, k: usize) -> Vec<Vec<(u32, f32)>> {
        AnyEngine::query(self, queries, k)
    }

    fn query_tier(
        &self,
        queries: &Dataset,
        k: usize,
        quant_rescore: Option<usize>,
    ) -> Vec<Vec<(u32, f32)>> {
        match self {
            AnyEngine::Single(e) => e.query_tier(queries, k, quant_rescore),
            AnyEngine::Sharded(e) => e.query_tier(queries, k, quant_rescore),
        }
    }

    fn quant_ready(&self) -> bool {
        match self {
            AnyEngine::Single(e) => e.quant_ready(),
            AnyEngine::Sharded(e) => e.quant_ready(),
        }
    }
}

/// Parse a `--tenants` spec: `QPS[:BURST]`, e.g. `0.5` or `0.5:4`.
fn parse_tenant_spec(spec: &str) -> crate::Result<(f64, usize)> {
    let mut it = spec.splitn(2, ':');
    let qps: f64 = it
        .next()
        .unwrap_or("")
        .trim()
        .parse()
        .map_err(|_| anyhow::anyhow!("bad --tenants spec {spec:?}: QPS must be a number"))?;
    let burst: usize = match it.next() {
        Some(b) => b.trim().parse().map_err(|_| {
            anyhow::anyhow!("bad --tenants spec {spec:?}: BURST must be an integer")
        })?,
        None => 8,
    };
    if !qps.is_finite() || qps <= 0.0 {
        anyhow::bail!("bad --tenants spec {spec:?}: QPS must be a positive number");
    }
    Ok((qps, burst.max(1)))
}

/// Build a job's graph, export a serving snapshot, and measure the query
/// path: batch QPS, single-query latency percentiles, and recall@k against
/// brute-force scoring. Query points are sampled from the dataset itself
/// (the paper's recall protocol).
pub fn run_serve(job: &Job, queries: usize, k: usize) -> crate::Result<Json> {
    run_serve_with(
        job,
        &ServeOpts {
            queries,
            k,
            ..ServeOpts::default()
        },
    )
}

/// [`run_serve`] with the full option set: optionally streams `inserts`
/// points in after the query sweep and reports the configured compaction's
/// cost ([`crate::serve::CompactionReport`]) plus the final snapshot's
/// memory telemetry, so capacity planning reads off the same JSON as build
/// costs.
pub fn run_serve_with(job: &Job, opts: &ServeOpts) -> crate::Result<Json> {
    use crate::serve::{brute_force_topk, recall_against, QueryEngine, ServeConfig};
    use std::time::Instant;
    // Live metrics exposition: while the sweep runs, the exporter rewrites
    // a scrapeable Prometheus-text snapshot of the global registry every
    // `metrics_every_s`. Dropped at the end of this fn, which writes one
    // final snapshot covering everything recorded below.
    let _metrics = opts.metrics_out.as_ref().map(|p| {
        crate::obs::MetricsExporter::start(
            p.clone(),
            std::time::Duration::from_secs_f64(opts.metrics_every_s.max(0.0)),
        )
    });
    let (queries, k) = (opts.queries, opts.k);
    // Tenant caps ride on the front door's token buckets — parse (and
    // fail) before the expensive build.
    let tenant_spec = match opts.tenants.as_deref() {
        Some(s) => Some(parse_tenant_spec(s)?),
        None => None,
    };
    if tenant_spec.is_some() && opts.queue_limit == 0 {
        anyhow::bail!("--tenants requires a front door: set --queue-limit > 0");
    }
    let dataset = job.dataset.realize(job.data_seed)?;
    let smeasure = serve_measure(job.measure)?;
    let measure = make_measure(job.measure)?;
    let family = make_family(
        job.family,
        dataset.dim(),
        derive_seed(job.params.seed, 0xFA),
    );
    let workers = if job.workers == 0 {
        crate::util::pool::default_workers()
    } else {
        job.workers
    };
    // Manual compaction only (compact_limit 0): the write-path phase below
    // measures inserts and exactly one compaction — a default auto-compact
    // limit would fire mid-loop for inserts ≥ 1024, folding compaction
    // walls into insert_per_s and draining the delta before the reported
    // compact_report() call.
    let mut cfg = ServeConfig::default()
        .route_reps(job.params.sketches.clamp(1, 8))
        .compact_limit(0)
        .compaction(opts.compaction)
        .full_rebuild_every(opts.full_rebuild_every)
        .seal_limit(opts.seal_limit);
    if opts.quantized {
        cfg = cfg.quantized(opts.rescore_factor);
    }
    if opts.shards >= 2 {
        // build_sharded forces max_candidates to 0 (shard invariance needs
        // the uncapped candidate walk); a snapshot recovered from disk must
        // carry the same config to pass the sharded engine's assert and
        // answer bit-identically.
        cfg = cfg.max_candidates(0);
    }
    let t = Instant::now();
    // Durable serving: open the state dir and try to recover before paying
    // for a build. `Ok(None)` means a fresh dir — build, then checkpoint.
    let policy = crate::serve::FsyncPolicy::parse(&opts.fsync)
        .map_err(|e| anyhow::anyhow!("bad --fsync spec: {e}"))?;
    let mut store = match opts.state_dir.as_deref() {
        Some(d) => Some(crate::serve::DurableStore::open(d, policy)?),
        None => None,
    };
    let recovered = match store.as_mut() {
        Some(s) => s.recover(family.as_ref(), cfg.clone(), workers)?,
        None => None,
    };
    let replayed = recovered.as_ref().map(|r| r.replay.len());
    let (edges, faults_json, engine) = if let Some(rec) = recovered {
        // Restart without rebuild: wrap the recovered index in the same
        // engine the build path would have produced, then replay the WAL
        // suffix through the normal insert path. Gid order is the store's
        // gapless-suffix contract; the assert turns a violation into a
        // diagnosis instead of a silently divergent index.
        let engine = if opts.shards >= 2 {
            let sindex = crate::serve::ShardedIndex::new(rec.index, opts.shards);
            AnyEngine::Sharded(
                crate::serve::ShardedEngine::new(
                    sindex,
                    family.as_ref(),
                    smeasure,
                    job.params.clone(),
                )
                .workers(workers),
            )
        } else {
            AnyEngine::Single(
                QueryEngine::new(rec.index, family.as_ref(), smeasure, job.params.clone())
                    .workers(workers),
            )
        };
        for r in &rec.replay {
            assert_eq!(r.gid, engine.next_gid(), "WAL replay out of gid order");
            engine.insert(r.row.as_deref(), r.set.clone());
        }
        // No build ran: edges come from the recovered snapshot and the
        // build-side fault counters are structurally zero.
        let edges = engine.snapshot().stats().edges;
        (edges, crate::ampc::FaultCounters::default().to_json(), engine)
    } else {
        let builder = StarsBuilder::new(&dataset)
            .similarity(measure.as_ref())
            .hash(family.as_ref())
            .params(job.params.clone())
            .workers(workers);
        let (out, engine) = if opts.shards >= 2 {
            // Fence-partitioned serving: the scatter-gather engine answers
            // bit-identically to the single-shard path under
            // max_candidates = 0 (forced above).
            let (out, sindex) = builder.build_sharded(opts.shards, cfg);
            let eng = crate::serve::ShardedEngine::new(
                sindex,
                family.as_ref(),
                smeasure,
                job.params.clone(),
            )
            .workers(workers);
            (out, AnyEngine::Sharded(eng))
        } else {
            let (out, index) = builder.build_indexed(cfg);
            let eng = QueryEngine::new(index, family.as_ref(), smeasure, job.params.clone())
                .workers(workers);
            (out, AnyEngine::Single(eng))
        };
        // First checkpoint: publish the freshly built snapshot so a crash
        // at any later point recovers without rebuilding.
        if let Some(s) = store.as_mut() {
            s.checkpoint(&engine.snapshot())?;
        }
        (out.graph.num_edges(), out.report.faults.to_json(), engine)
    };
    let build_s = t.elapsed().as_secs_f64();

    let qids = crate::eval::recall::sample_queries(dataset.len(), queries, job.data_seed ^ 0x9E);
    let qset = dataset.subset(&qids);
    // Batch throughput.
    let t = Instant::now();
    let got = engine.query(&qset, k);
    let batch_s = t.elapsed().as_secs_f64();
    // Single-query latency distribution over a bounded prefix, recorded
    // into a log-bucketed histogram (microseconds) — the same machinery the
    // serve registry uses, replacing the old sort-and-index percentile math.
    let lat_n = qids.len().min(200);
    let lat_hist = crate::obs::Histogram::new();
    for qi in 0..lat_n {
        let one = qset.subset(&[qi as u32]);
        let t = Instant::now();
        let _ = engine.query(&one, k);
        lat_hist.record(t.elapsed().as_micros() as u64);
    }
    let lat = lat_hist.snapshot();
    // Recall vs brute force with identical kernels and tie rule.
    let truth = brute_force_topk(&dataset, &qset, smeasure, k, workers);
    let recall = if got.is_empty() {
        1.0
    } else {
        truth
            .iter()
            .zip(got.iter())
            .map(|(t, g)| recall_against(t, g))
            .sum::<f64>()
            / got.len() as f64
    };
    let mut doc = vec![
        ("job", job.to_json()),
        ("edges", Json::from(edges)),
        ("router_entries", Json::from(engine.snapshot().router().num_entries())),
        (
            "simd_backend",
            Json::from(crate::util::simd::active().name()),
        ),
        ("build_s", Json::from(build_s)),
        ("queries", Json::from(qids.len())),
        ("k", Json::from(k)),
        ("batch_qps", Json::from(qids.len() as f64 / batch_s.max(1e-12))),
        ("p50_ms", Json::from(lat.quantile(0.50) as f64 / 1e3)),
        ("p90_ms", Json::from(lat.quantile(0.90) as f64 / 1e3)),
        ("p99_ms", Json::from(lat.quantile(0.99) as f64 / 1e3)),
        ("p999_ms", Json::from(lat.quantile(0.999) as f64 / 1e3)),
        ("recall_at_k", Json::from(recall)),
        ("quantized", Json::from(opts.quantized)),
        (
            "rescore_c",
            Json::from(if opts.quantized {
                k * opts.rescore_factor.max(1)
            } else {
                0
            }),
        ),
        ("shards", Json::from(opts.shards.max(1))),
    ];
    // Write path: stream inserts in and compact with the configured mode,
    // reporting the compaction's cost alongside the read-path numbers.
    if opts.inserts > 0 && !dataset.is_empty() {
        // A recovered engine has already replayed a prefix of this insert
        // schedule (its gids sit past the build floor); resume at the
        // position the sequencer high-water implies, so a restarted run
        // feeds exactly the suffix an uncrashed run would have.
        let start = (engine.next_gid() as usize)
            .saturating_sub(dataset.len())
            .min(opts.inserts);
        // Crash injection for the kill-and-restart gate: with a STARS_FAULTS
        // schedule active and a state dir, tear the WAL mid-append at the
        // schedule midpoint and exit hard. WAL-before-apply means the torn
        // record was never applied; recovery truncates it and the restarted
        // process re-inserts it from the schedule.
        let plan = crate::util::fault::FaultPlan::from_env();
        let t = Instant::now();
        for i in start..opts.inserts {
            let src = i % dataset.len();
            let row = (dataset.dim() > 0).then(|| dataset.row(src));
            let set = (!dataset.sets.is_empty()).then(|| dataset.set(src).clone());
            if let Some(s) = store.as_mut() {
                let gid = engine.next_gid();
                if plan.is_active()
                    && i == opts.inserts / 2
                    && matches!(
                        plan.decide(0, i as u64, 0),
                        crate::util::fault::Fault::Crash
                    )
                {
                    let kept = s.log_torn(gid, row, set.as_ref(), 7)?;
                    eprintln!(
                        "stars: injected crash mid-WAL-append (gid {gid}, {kept} torn bytes)"
                    );
                    std::process::exit(3);
                }
                s.log_insert(gid, row, set.as_ref())?;
            }
            engine.insert(row, set);
        }
        if let Some(s) = store.as_mut() {
            // Leave the WAL durable past the timed region even under the
            // `Os`/`EveryN` policies.
            s.sync()?;
        }
        let insert_s = t.elapsed().as_secs_f64();
        let done = opts.inserts - start;
        doc.push(("inserts", Json::from(done)));
        doc.push((
            "insert_per_s",
            Json::from(done as f64 / insert_s.max(1e-12)),
        ));
        if let Some(rep) = engine.compact_report() {
            // The report carries the engine's running full/incremental mix
            // (the `full_rebuild_every` policy's observable).
            doc.push(("compaction", rep.to_json()));
        }
        // Post-compaction checkpoint: the absorbed delta moves from
        // WAL-replay territory into a published snapshot, so the next
        // restart replays only what arrived after this point.
        if let Some(s) = store.as_mut() {
            s.checkpoint(&engine.snapshot())?;
        }
    }
    // Admission-controlled front door: replay the query sweep through the
    // door (unloaded — every batch admits), then optionally apply
    // deterministic pressure via held permits so one report shows the whole
    // ladder: admitted, degraded, queue-shed.
    if opts.queue_limit > 0 {
        use crate::serve::{AdmissionConfig, FrontDoor};
        let mut acfg = AdmissionConfig::default()
            .queue_limit(opts.queue_limit)
            .deadline_ms(opts.deadline_ms);
        if let Some((qps, burst)) = tenant_spec {
            acfg = acfg.tenant_qps(qps).tenant_burst(burst);
        }
        let door = FrontDoor::new(&engine, acfg);
        let _ = door.query(&qset, k);
        if let Some((_, burst)) = tenant_spec {
            // Per-tenant caps: drive one hot tenant past its burst so the
            // report shows the tenant-shed rung, then serve one batch for
            // a cold tenant whose untouched bucket admits it.
            for _ in 0..burst + 2 {
                let _ = door.query_for(7, &qset, k);
            }
            let _ = door.query_for(13, &qset, k);
        }
        if opts.overload {
            // Full backlog: the next batch is shed at the door.
            let full: Vec<_> = (0..opts.queue_limit).map(|_| door.acquire()).collect();
            let _ = door.query(&qset, k);
            drop(full);
            // Partial backlog at the degrade threshold: served on the
            // degraded quantized tier when the snapshot carries one.
            let held = ((door.config().degrade_at * opts.queue_limit as f64).ceil() as usize)
                .saturating_sub(1);
            let partial: Vec<_> = (0..held).map(|_| door.acquire()).collect();
            let _ = door.query(&qset, k);
            drop(partial);
        }
        doc.push(("admission", door.stats().to_json()));
    }
    // Deterministic digest of a final query sweep over the settled index
    // (after inserts and compaction) — the kill-and-restart gate's
    // comparand. The strict total order on (score desc, id asc) makes this
    // identical across worker counts, seal timing, and crash/recovery at a
    // fixed config; any divergence is a durability bug.
    let fin = engine.query(&qset, k);
    let mut digest = 0xcbf2_9ce4_8422_2325u64;
    for (qi, row) in fin.iter().enumerate() {
        digest = crate::util::fxhash::combine(digest, qi as u64);
        for &(id, score) in row {
            digest = crate::util::fxhash::combine(digest, id as u64);
            digest = crate::util::fxhash::combine(digest, u64::from(score.to_bits()));
        }
    }
    doc.push(("results_digest", Json::from(format!("{digest:016x}"))));
    // Build-side fault/recovery counters (nonzero only when a STARS_FAULTS
    // schedule or a pinned plan injected faults into the build; structurally
    // zero after a recovery, which runs no build).
    doc.push(("faults", faults_json));
    // Durability telemetry: present exactly when serving with --state-dir.
    // `cold_start_ms` is the build wall on a fresh dir and the
    // recover-plus-replay wall on a restart — the restart-without-rebuild
    // win reads straight off this pair.
    if let Some(s) = store.as_ref() {
        doc.push((
            "durable",
            Json::obj(vec![
                ("state_dir", Json::from(s.dir().display().to_string())),
                ("fsync", Json::from(opts.fsync.clone())),
                ("recovered", Json::from(replayed.is_some())),
                ("replayed", Json::from(replayed.unwrap_or(0))),
                ("cold_start_ms", Json::from(build_s * 1e3)),
                ("seal_limit", Json::from(opts.seal_limit)),
            ]),
        ));
    }
    // Final snapshot telemetry (router/CSR/state-table memory), tracked
    // like build costs (ROADMAP "Router memory telemetry").
    doc.push(("snapshot", engine.snapshot().stats().to_json()));
    // Per-shard slices of that telemetry when serving fence-partitioned:
    // points/edges/router entries are exact per shard, bytes prorated.
    if let AnyEngine::Sharded(se) = &engine {
        let shots: Vec<Json> = (0..se.n_shards())
            .map(|s| se.shard_stats(s).to_json())
            .collect();
        doc.push(("shard_snapshots", Json::Arr(shots)));
    }
    Ok(Json::obj(doc))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::job::DatasetSpec;
    use crate::stars::BuildParams;

    #[test]
    fn run_small_job_end_to_end() {
        let job = Job {
            dataset: DatasetSpec::Random {
                n: 500,
                dim: 32,
                modes: 10,
            },
            measure: MeasureSpec::Cosine,
            family: FamilySpec::SimHash { bits: 8 },
            params: BuildParams::threshold_mode(Algorithm::LshStars).sketches(10),
            data_seed: 3,
            workers: 2,
        };
        let res = run_job(&job).unwrap();
        assert!(res.graph.num_edges() > 0);
        assert!(res.report.comparisons > 0);
        let j = res.to_json(&job).to_string();
        assert!(j.contains("comparisons"));
    }

    #[test]
    fn zipf_job_with_weighted_minhash() {
        let job = Job {
            dataset: DatasetSpec::ZipfSets { n: 300 },
            measure: MeasureSpec::WeightedJaccard,
            family: FamilySpec::WeightedMinHash { perms: 3 },
            params: BuildParams::threshold_mode(Algorithm::LshStars)
                .sketches(8)
                .threshold(0.1),
            data_seed: 4,
            workers: 2,
        };
        let res = run_job(&job).unwrap();
        assert!(res.graph.num_edges() > 0);
    }

    #[test]
    fn run_serve_reports_recall_and_latency() {
        let job = Job {
            dataset: DatasetSpec::Random {
                n: 600,
                dim: 16,
                modes: 8,
            },
            measure: MeasureSpec::Cosine,
            family: FamilySpec::SimHash { bits: 8 },
            params: BuildParams::threshold_mode(crate::stars::Algorithm::LshStars)
                .sketches(8)
                .threshold(0.4),
            data_seed: 7,
            workers: 2,
        };
        let doc = run_serve(&job, 40, 5).unwrap();
        let recall = doc.get("recall_at_k").unwrap().as_f64().unwrap();
        assert!((0.0..=1.0).contains(&recall), "recall {recall}");
        assert!(doc.get("batch_qps").unwrap().as_f64().unwrap() > 0.0);
        assert!(doc.get("p99_ms").unwrap().as_f64().unwrap() >= 0.0);
        assert_eq!(doc.get("k").unwrap().as_usize().unwrap(), 5);
    }

    #[test]
    fn run_serve_with_inserts_reports_compaction_and_snapshot() {
        let job = Job {
            dataset: DatasetSpec::Random {
                n: 500,
                dim: 16,
                modes: 8,
            },
            measure: MeasureSpec::Cosine,
            family: FamilySpec::SimHash { bits: 8 },
            params: BuildParams::threshold_mode(crate::stars::Algorithm::LshStars)
                .sketches(6)
                .threshold(0.4),
            data_seed: 11,
            workers: 2,
        };
        let opts = ServeOpts {
            queries: 20,
            k: 5,
            inserts: 30,
            compaction: crate::serve::CompactionMode::Incremental,
            ..ServeOpts::default()
        };
        let doc = run_serve_with(&job, &opts).unwrap();
        assert!(doc.get("insert_per_s").unwrap().as_f64().unwrap() > 0.0);
        assert_eq!(
            doc.get("simd_backend").unwrap().as_str().unwrap(),
            crate::util::simd::active().name()
        );
        let comp = doc.get("compaction").expect("compaction report missing");
        assert_eq!(comp.get("mode").unwrap().as_str().unwrap(), "incremental");
        assert_eq!(comp.get("delta_points").unwrap().as_usize().unwrap(), 30);
        assert!(comp.get("seconds").unwrap().as_f64().unwrap() >= 0.0);
        assert_eq!(
            comp.get("incremental_compactions").unwrap().as_usize().unwrap(),
            1
        );
        assert_eq!(comp.get("full_compactions").unwrap().as_usize().unwrap(), 0);
        let snap = doc.get("snapshot").expect("snapshot telemetry missing");
        assert_eq!(snap.get("points").unwrap().as_usize().unwrap(), 530);
        assert!(snap.get("router_bytes").unwrap().as_usize().unwrap() > 0);
        assert!(snap.get("csr_bytes").unwrap().as_usize().unwrap() > 0);
        assert!(snap.get("state_table_bytes").unwrap().as_usize().unwrap() > 0);
        // Default opts serve exact: the quantized telemetry says so.
        assert!(!doc.get("quantized").unwrap().as_bool().unwrap());
        assert_eq!(doc.get("rescore_c").unwrap().as_usize().unwrap(), 0);
        assert!(!snap.get("quantized").unwrap().as_bool().unwrap());
        assert_eq!(snap.get("bytes_per_row").unwrap().as_usize().unwrap(), 64);
    }

    #[test]
    fn run_serve_quantized_reports_quant_telemetry() {
        let job = Job {
            dataset: DatasetSpec::Random {
                n: 500,
                dim: 16,
                modes: 8,
            },
            measure: MeasureSpec::Cosine,
            family: FamilySpec::SimHash { bits: 8 },
            params: BuildParams::threshold_mode(crate::stars::Algorithm::LshStars)
                .sketches(6)
                .threshold(0.4),
            data_seed: 11,
            workers: 2,
        };
        let opts = ServeOpts {
            queries: 20,
            k: 5,
            inserts: 10,
            quantized: true,
            rescore_factor: 8,
            ..ServeOpts::default()
        };
        let doc = run_serve_with(&job, &opts).unwrap();
        assert!(doc.get("quantized").unwrap().as_bool().unwrap());
        assert_eq!(doc.get("rescore_c").unwrap().as_usize().unwrap(), 40);
        let recall = doc.get("recall_at_k").unwrap().as_f64().unwrap();
        assert!((0.0..=1.0).contains(&recall), "recall {recall}");
        let snap = doc.get("snapshot").expect("snapshot telemetry missing");
        assert!(snap.get("quantized").unwrap().as_bool().unwrap());
        assert_eq!(snap.get("rescore_factor").unwrap().as_usize().unwrap(), 8);
        // dim 16: 16 + 4 bytes per quantized row vs 64 dense — the ~4×
        // first-pass storage reduction, via 510 compacted points.
        assert_eq!(snap.get("bytes_per_row").unwrap().as_usize().unwrap(), 20);
        assert_eq!(
            snap.get("quant_bytes").unwrap().as_usize().unwrap(),
            510 * 20
        );
    }

    #[test]
    fn run_serve_overload_reports_the_admission_ladder() {
        let job = Job {
            dataset: DatasetSpec::Random {
                n: 500,
                dim: 16,
                modes: 8,
            },
            measure: MeasureSpec::Cosine,
            family: FamilySpec::SimHash { bits: 8 },
            params: BuildParams::threshold_mode(crate::stars::Algorithm::LshStars)
                .sketches(6)
                .threshold(0.4),
            data_seed: 11,
            workers: 2,
        };
        let opts = ServeOpts {
            queries: 20,
            k: 5,
            quantized: true,
            queue_limit: 4,
            overload: true,
            ..ServeOpts::default()
        };
        let doc = run_serve_with(&job, &opts).unwrap();
        let adm = doc.get("admission").expect("admission stats missing");
        // Unloaded sweep + degraded sweep admitted; full-backlog sweep shed.
        assert_eq!(adm.get("admitted").unwrap().as_usize().unwrap(), 2);
        assert_eq!(adm.get("degraded").unwrap().as_usize().unwrap(), 1);
        assert_eq!(adm.get("queue_sheds").unwrap().as_usize().unwrap(), 1);
        assert_eq!(adm.get("deadline_sheds").unwrap().as_usize().unwrap(), 0);
        assert!(adm.get("depth_high_water").unwrap().as_usize().unwrap() <= 4);
        assert!(adm.get("ewma_ms").unwrap().as_f64().unwrap() > 0.0);
        // The fault-free build reports all-zero recovery counters.
        let faults = doc.get("faults").expect("fault counters missing");
        assert_eq!(faults.get("task_retries").unwrap().as_usize().unwrap(), 0);
        assert_eq!(faults.get("wave_restarts").unwrap().as_usize().unwrap(), 0);
        // Without a queue limit there is no admission object at all.
        let plain = run_serve_with(
            &job,
            &ServeOpts {
                queries: 10,
                k: 5,
                ..ServeOpts::default()
            },
        )
        .unwrap();
        assert!(plain.get("admission").is_none());
    }

    #[test]
    fn run_serve_sharded_reports_shard_snapshots() {
        let job = Job {
            dataset: DatasetSpec::Random {
                n: 500,
                dim: 16,
                modes: 8,
            },
            measure: MeasureSpec::Cosine,
            family: FamilySpec::SimHash { bits: 8 },
            params: BuildParams::threshold_mode(crate::stars::Algorithm::LshStars)
                .sketches(6)
                .threshold(0.4),
            data_seed: 11,
            workers: 2,
        };
        let opts = ServeOpts {
            queries: 20,
            k: 5,
            inserts: 12,
            shards: 3,
            ..ServeOpts::default()
        };
        let doc = run_serve_with(&job, &opts).unwrap();
        assert_eq!(doc.get("shards").unwrap().as_usize().unwrap(), 3);
        let recall = doc.get("recall_at_k").unwrap().as_f64().unwrap();
        assert!((0.0..=1.0).contains(&recall), "recall {recall}");
        assert!(doc.get("batch_qps").unwrap().as_f64().unwrap() > 0.0);
        // One compaction folded the inserts in; the per-shard snapshot
        // slices tile the compacted snapshot exactly.
        let comp = doc.get("compaction").expect("compaction report missing");
        assert_eq!(comp.get("delta_points").unwrap().as_usize().unwrap(), 12);
        let shots = doc.get("shard_snapshots").unwrap().as_arr().unwrap();
        assert_eq!(shots.len(), 3);
        let pts: usize = shots
            .iter()
            .map(|s| s.get("points").unwrap().as_usize().unwrap())
            .sum();
        assert_eq!(pts, 512);
        // The single-shard path reports no shard_snapshots at all.
        let plain = run_serve_with(
            &job,
            &ServeOpts {
                queries: 10,
                k: 5,
                ..ServeOpts::default()
            },
        )
        .unwrap();
        assert_eq!(plain.get("shards").unwrap().as_usize().unwrap(), 1);
        assert!(plain.get("shard_snapshots").is_none());
    }

    #[test]
    fn run_serve_durable_restart_is_bit_identical_without_rebuild() {
        for quantized in [false, true] {
            let dir = std::env::temp_dir().join(format!(
                "stars-driver-durable-{}-{}",
                std::process::id(),
                quantized
            ));
            let _ = std::fs::remove_dir_all(&dir);
            let job = Job {
                dataset: DatasetSpec::Random {
                    n: 400,
                    dim: 16,
                    modes: 8,
                },
                measure: MeasureSpec::Cosine,
                family: FamilySpec::SimHash { bits: 8 },
                params: BuildParams::threshold_mode(crate::stars::Algorithm::LshStars)
                    .sketches(6)
                    .threshold(0.4),
                data_seed: 11,
                workers: 2,
            };
            let opts = ServeOpts {
                queries: 20,
                k: 5,
                inserts: 16,
                quantized,
                seal_limit: 5,
                state_dir: Some(dir.clone()),
                fsync: "every:4".into(),
                ..ServeOpts::default()
            };
            let a = run_serve_with(&job, &opts).unwrap();
            let da = a.get("durable").expect("durable telemetry missing");
            assert!(!da.get("recovered").unwrap().as_bool().unwrap());
            assert_eq!(da.get("seal_limit").unwrap().as_usize().unwrap(), 5);
            assert_eq!(a.get("inserts").unwrap().as_usize().unwrap(), 16);
            let b = run_serve_with(&job, &opts).unwrap();
            let db = b.get("durable").expect("durable telemetry missing");
            assert!(db.get("recovered").unwrap().as_bool().unwrap());
            // The post-compaction checkpoint absorbed the whole insert
            // schedule: the restart replays nothing and re-inserts nothing.
            assert_eq!(db.get("replayed").unwrap().as_usize().unwrap(), 0);
            assert_eq!(b.get("inserts").unwrap().as_usize().unwrap(), 0);
            assert_eq!(
                a.get("results_digest").unwrap().as_str().unwrap(),
                b.get("results_digest").unwrap().as_str().unwrap(),
                "quantized={quantized}: recovered serving diverged from the build"
            );
            // Recovery runs no build, so its fault counters are all zero.
            let fb = b.get("faults").unwrap();
            assert_eq!(fb.get("task_retries").unwrap().as_usize().unwrap(), 0);
            // The in-memory path reports no durable object but still
            // carries the digest (the gate's comparand).
            let plain = run_serve_with(
                &job,
                &ServeOpts {
                    queries: 5,
                    k: 5,
                    ..ServeOpts::default()
                },
            )
            .unwrap();
            assert!(plain.get("durable").is_none());
            assert!(plain.get("results_digest").is_some());
            let _ = std::fs::remove_dir_all(&dir);
        }
    }

    #[test]
    fn run_serve_tenant_caps_report_tenant_sheds() {
        let job = Job {
            dataset: DatasetSpec::Random {
                n: 400,
                dim: 16,
                modes: 8,
            },
            measure: MeasureSpec::Cosine,
            family: FamilySpec::SimHash { bits: 8 },
            params: BuildParams::threshold_mode(crate::stars::Algorithm::LshStars)
                .sketches(6)
                .threshold(0.4),
            data_seed: 11,
            workers: 2,
        };
        let opts = ServeOpts {
            queries: 10,
            k: 5,
            queue_limit: 8,
            tenants: Some("0.001:2".into()),
            ..ServeOpts::default()
        };
        let doc = run_serve_with(&job, &opts).unwrap();
        let adm = doc.get("admission").expect("admission stats missing");
        // Hot tenant: burst 2 admitted, the 2 extra batches shed at the
        // bucket (refill at 0.001 qps is negligible); cold tenant admitted.
        assert!(adm.get("tenant_sheds").unwrap().as_usize().unwrap() >= 1);
        assert!(adm.get("admitted").unwrap().as_usize().unwrap() >= 4);
        assert_eq!(adm.get("queue_sheds").unwrap().as_usize().unwrap(), 0);
        // Tenant caps without a front door are a config error, as is a
        // malformed spec.
        let no_door = ServeOpts {
            queries: 5,
            k: 5,
            tenants: Some("1".into()),
            ..ServeOpts::default()
        };
        assert!(run_serve_with(&job, &no_door).is_err());
        let bad = ServeOpts {
            queries: 5,
            k: 5,
            queue_limit: 4,
            tenants: Some("-2:zap".into()),
            ..ServeOpts::default()
        };
        assert!(run_serve_with(&job, &bad).is_err());
    }

    #[test]
    fn tenant_spec_parses_qps_and_burst() {
        assert_eq!(parse_tenant_spec("0.5").unwrap(), (0.5, 8));
        assert_eq!(parse_tenant_spec("2:4").unwrap(), (2.0, 4));
        assert_eq!(parse_tenant_spec(" 1.5 : 0 ").unwrap(), (1.5, 1));
        assert!(parse_tenant_spec("").is_err());
        assert!(parse_tenant_spec("0").is_err());
        assert!(parse_tenant_spec("-1:2").is_err());
        assert!(parse_tenant_spec("1:x").is_err());
        assert!(parse_tenant_spec("nan:2").is_err());
    }

    #[test]
    fn learned_measure_has_no_serve_path() {
        assert!(serve_measure(MeasureSpec::Learned).is_err());
        assert_eq!(
            serve_measure(MeasureSpec::Mixture).unwrap(),
            crate::serve::ServeMeasure::Mixture { alpha: 0.5 }
        );
    }

    #[test]
    fn family_construction() {
        let f = make_family(FamilySpec::SimHash { bits: 8 }, 16, 1);
        assert_eq!(f.sketch_len(), 8);
        let f = make_family(FamilySpec::WeightedMinHash { perms: 3 }, 0, 1);
        assert_eq!(f.sketch_len(), 3);
        let f = make_family(FamilySpec::Mixture { len: 12 }, 16, 1);
        assert_eq!(f.sketch_len(), 12);
        let f = make_family(FamilySpec::MinHash { perms: 4 }, 0, 1);
        assert_eq!(f.sketch_len(), 4);
    }
}
