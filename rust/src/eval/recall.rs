//! Figure 2's recall metrics.
//!
//! Paper protocol:
//! * **Threshold (LSH-based) graphs** — ground truth: all points with
//!   similarity ≥ 0.5. Non-Stars graphs count *direct* neighbors; Stars
//!   graphs count neighbors within **two hops** where every edge on the path
//!   also has similarity ≥ 0.5, plus a relaxed variant at 0.495 (the
//!   1.01-approximation of §3.2).
//! * **k-NN (SortingLSH-based) graphs** — ground truth: exact 100-NN.
//!   One hop (non-Stars) vs two hops (Stars), plus the 1.01-approximate
//!   relaxation: candidates at dissimilarity ≤ 1.01 · d_k(p) count, with the
//!   ratio capped at 1 when more than k are found.

use crate::data::types::Dataset;
use crate::graph::two_hop::{capped_recall, one_hop_set, recall, two_hop_set};
use crate::graph::Csr;
use crate::sim::Similarity;
use crate::util::fxhash::FxHashSet;
use crate::util::pool::parallel_chunks;
use crate::util::rng::Rng;

/// Averaged recall over query points.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct RecallReport {
    /// Fraction of true neighbors that are direct neighbors.
    pub one_hop: f64,
    /// Fraction reachable within two hops.
    pub two_hop: f64,
    /// Two-hop fraction under the relaxed (1/ε-approximate) criterion.
    pub two_hop_relaxed: f64,
    /// Number of query points averaged.
    pub queries: usize,
}

/// Sample `k` query point ids (deterministic in `seed`).
pub fn sample_queries(n: usize, k: usize, seed: u64) -> Vec<u32> {
    let mut rng = Rng::new(seed);
    rng.sample_indices(n, k.min(n))
        .into_iter()
        .map(|i| i as u32)
        .collect()
}

/// Threshold-graph recall (Figure 2 left panels).
///
/// `truth[p]` = exact neighbors of p with similarity ≥ r. Edges on counted
/// paths must carry weight ≥ r (strict variant) / ≥ r_relaxed (relaxed).
pub fn threshold_recall(
    csr: &Csr,
    truth: &[Vec<u32>],
    queries: &[u32],
    r: f32,
    r_relaxed: f32,
) -> RecallReport {
    let workers = crate::util::pool::default_workers();
    let parts = parallel_chunks(queries.len(), workers, |_, range| {
        let (mut h1, mut h2, mut h2r, mut m) = (0.0, 0.0, 0.0, 0usize);
        for qi in range {
            let p = queries[qi];
            let targets = &truth[p as usize];
            if targets.is_empty() {
                continue;
            }
            m += 1;
            h1 += recall(&one_hop_set(csr, p, r), targets);
            h2 += recall(&two_hop_set(csr, p, r), targets);
            h2r += recall(&two_hop_set(csr, p, r_relaxed), targets);
        }
        (h1, h2, h2r, m)
    });
    reduce(parts)
}

/// k-NN recall (Figure 2 right panels).
///
/// `truth_knn[p]` = exact k-NN of p as (similarity, id), sorted descending.
/// The relaxed criterion counts any point with dissimilarity ≤ (1/ε)·d_k(p)
/// (`eps` ≈ 0.99 ⇒ 1.01-approximate), capped at ratio 1.
pub fn knn_recall(
    ds: &Dataset,
    sim: &dyn Similarity,
    csr: &Csr,
    truth_knn: &[Vec<(f32, u32)>],
    queries: &[u32],
    k: usize,
    eps: f64,
) -> RecallReport {
    let workers = crate::util::pool::default_workers();
    let parts = parallel_chunks(queries.len(), workers, |_, range| {
        let (mut h1, mut h2, mut h2r, mut m) = (0.0, 0.0, 0.0, 0usize);
        for qi in range {
            let p = queries[qi];
            let nbrs = &truth_knn[p as usize];
            if nbrs.is_empty() {
                continue;
            }
            m += 1;
            let k_eff = nbrs.len().min(k);
            let targets: Vec<u32> = nbrs[..k_eff].iter().map(|&(_, id)| id).collect();
            let one = one_hop_set(csr, p, f32::MIN);
            let two = two_hop_set(csr, p, f32::MIN);
            h1 += recall(&one, &targets);
            h2 += recall(&two, &targets);
            // Relaxed: similarity ≥ 1 - (1/eps)·(1 - tau_k).
            let tau_k = nbrs[k_eff - 1].0;
            let relaxed_thresh = 1.0 - (1.0 - tau_k as f64) / eps;
            let candidates: FxHashSet<u32> = two
                .iter()
                .copied()
                .filter(|&q| sim.sim(ds, p as usize, q as usize) as f64 >= relaxed_thresh)
                .collect();
            h2r += capped_recall(&two, &candidates, k_eff);
        }
        (h1, h2, h2r, m)
    });
    reduce(parts)
}

fn reduce(parts: Vec<(f64, f64, f64, usize)>) -> RecallReport {
    let (mut h1, mut h2, mut h2r, mut m) = (0.0, 0.0, 0.0, 0usize);
    for (a, b, c, n) in parts {
        h1 += a;
        h2 += b;
        h2r += c;
        m += n;
    }
    if m == 0 {
        return RecallReport::default();
    }
    RecallReport {
        one_hop: h1 / m as f64,
        two_hop: h2 / m as f64,
        two_hop_relaxed: h2r / m as f64,
        queries: m,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::graph::{Edge, Graph};
    use crate::sim::CosineSim;
    use crate::stars::allpair;

    #[test]
    fn sample_queries_distinct() {
        let q = sample_queries(100, 20, 5);
        assert_eq!(q.len(), 20);
        let set: std::collections::HashSet<_> = q.iter().collect();
        assert_eq!(set.len(), 20);
    }

    #[test]
    fn star_graph_two_hop_beats_one_hop() {
        // Star center 0 with 5 leaves, all true neighbors of each other.
        let g = Graph::from_edges(6, (1..6).map(|v| Edge::new(0, v, 0.9)).collect());
        let csr = Csr::new(&g);
        let truth: Vec<Vec<u32>> = (0..6)
            .map(|p| (0..6u32).filter(|&q| q != p).collect())
            .collect();
        let queries: Vec<u32> = (0..6).collect();
        let rep = threshold_recall(&csr, &truth, &queries, 0.5, 0.49);
        assert!(rep.two_hop > rep.one_hop);
        assert!((rep.two_hop - 1.0).abs() < 1e-9, "star covers all in 2 hops");
        assert_eq!(rep.queries, 6);
    }

    #[test]
    fn relaxed_threshold_finds_more() {
        // Edge at 0.495: strict 0.5 misses it, relaxed counts it.
        let g = Graph::from_edges(3, vec![Edge::new(0, 1, 0.495), Edge::new(1, 2, 0.9)]);
        let csr = Csr::new(&g);
        let truth = vec![vec![1u32, 2], vec![0, 2], vec![0, 1]];
        let rep = threshold_recall(&csr, &truth, &[0], 0.5, 0.495);
        assert_eq!(rep.two_hop, 0.0);
        assert!((rep.two_hop_relaxed - 1.0).abs() < 1e-9);
    }

    #[test]
    fn knn_recall_on_exact_graph_is_one() {
        let ds = synth::gaussian_mixture(150, 8, 3, 0.1, 9);
        let cluster = crate::ampc::Cluster::new(2);
        let truth = allpair::exact_knn(&ds, &CosineSim, 10, &cluster);
        // Build the exact 10-NN graph.
        let mut edges = Vec::new();
        for (i, nbrs) in truth.iter().enumerate() {
            for &(w, j) in nbrs {
                edges.push(Edge::new(i as u32, j, w));
            }
        }
        let csr = Csr::new(&Graph::from_edges(150, edges));
        let queries = sample_queries(150, 50, 3);
        let rep = knn_recall(&ds, &CosineSim, &csr, &truth, &queries, 10, 0.99);
        assert!((rep.one_hop - 1.0).abs() < 1e-9, "one hop {}", rep.one_hop);
        assert!((rep.two_hop - 1.0).abs() < 1e-9);
        assert!(rep.two_hop_relaxed >= rep.two_hop - 1e-9);
    }

    #[test]
    fn empty_truth_gives_empty_report() {
        let g = Graph::from_edges(3, vec![]);
        let csr = Csr::new(&g);
        let truth = vec![vec![], vec![], vec![]];
        let rep = threshold_recall(&csr, &truth, &[0, 1, 2], 0.5, 0.5);
        assert_eq!(rep.queries, 0);
        assert_eq!(rep.one_hop, 0.0);
    }
}
