//! Evaluation machinery: ground truth, recall metrics (Figure 2), and
//! experiment-level summaries.

pub mod recall;

pub use recall::{knn_recall, threshold_recall, RecallReport};
