//! # Stars: Tera-Scale Graph Building for Clustering and Graph Learning
//!
//! Full-system reproduction of the Stars paper (Google Research, 2022).
//!
//! Stars builds **two-hop spanners**: extremely sparse similarity graphs in
//! which similar points are connected by a path of length at most two. Within
//! each LSH bucket (or SortingLSH window) it creates *star graphs* centered on
//! randomly sampled leaders, reducing the per-bucket comparison cost from
//! quadratic to nearly linear.
//!
//! The crate is the L3 (coordinator) layer of a three-layer stack:
//!
//! * **L3 (this crate)** — the graph-building pipeline: LSH sketching,
//!   bucketing, star construction, a simulated AMPC cluster with per-worker
//!   cost accounting, downstream clustering and evaluation.
//! * **L2 (python/compile/model.py)** — the learned pairwise similarity model
//!   (JAX), AOT-lowered to HLO text at build time.
//! * **L1 (python/compile/kernels/)** — Pallas kernels for batched cosine
//!   scoring and SimHash sketching, lowered into the same HLO artifacts.
//!
//! Python never runs at request time: [`runtime::Engine`] loads the
//! `artifacts/*.hlo.txt` produced by `make artifacts` and executes them via
//! the PJRT CPU client (`xla` crate).
//!
//! ## Quickstart
//!
//! ```no_run
//! use stars::data::synth;
//! use stars::sim::{CosineSim, CountingSim};
//! use stars::lsh::SimHash;
//! use stars::stars::{Algorithm, BuildParams, StarsBuilder};
//!
//! let ds = synth::gaussian_mixture(10_000, 100, 100, 0.1, 42);
//! let sim = CountingSim::new(CosineSim);
//! let family = SimHash::new(ds.dim(), 12, 7);
//! let params = BuildParams::threshold_mode(Algorithm::LshStars)
//!     .sketches(25)
//!     .leaders(25)
//!     .threshold(0.5);
//! let out = StarsBuilder::new(&ds)
//!     .similarity(&sim)
//!     .hash(&family)
//!     .params(params)
//!     .build();
//! println!("{} edges, {} comparisons", out.graph.num_edges(), out.report.comparisons);
//! ```

pub mod util;
pub mod obs;
pub mod data;
pub mod sim;
pub mod lsh;
pub mod graph;
pub mod ampc;
pub mod stars;
pub mod serve;
pub mod clustering;
pub mod eval;
pub mod runtime;
pub mod coordinator;
pub mod bench;

/// Crate-wide result type (anyhow-based).
pub type Result<T> = anyhow::Result<T>;
