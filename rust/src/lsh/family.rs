//! The [`LshFamily`] trait.

use crate::data::types::Dataset;
use crate::util::fxhash;

/// A locality sensitive hash family over a dataset.
///
/// One *repetition* (`rep`) corresponds to one independent draw of the
/// concatenated hash `H(p) = (h_1(p), …, h_M(p))` from the family. The
/// pipeline evaluates repetitions `0..R` (the paper's "number of sketches").
pub trait LshFamily: Sync {
    /// Display name.
    fn name(&self) -> &'static str;

    /// Number of concatenated base hashes per sketch (the paper's M,
    /// "sketching dimension").
    fn sketch_len(&self) -> usize;

    /// Write the M base-hash symbols of point `i` under repetition `rep`
    /// into `out` (length `sketch_len()`).
    fn symbols(&self, ds: &Dataset, i: usize, rep: u64, out: &mut [u64]);

    /// Bucket key of point `i` under repetition `rep`: the combined hash of
    /// all M symbols. Two points share a bucket iff all symbols agree (up to
    /// a 2⁻⁶⁴ collision, which is negligible against the paper's n⁻⁴ bound).
    fn bucket_key(&self, ds: &Dataset, i: usize, rep: u64) -> u64 {
        let mut buf = vec![0u64; self.sketch_len()];
        self.symbols(ds, i, rep, &mut buf);
        combine_symbols(&buf)
    }

    /// Bucket keys for all points under repetition `rep`. Implementations
    /// override this when batch evaluation is cheaper (e.g. SimHash reuses
    /// the hyperplane matrix across points).
    fn bucket_keys(&self, ds: &Dataset, rep: u64) -> Vec<u64> {
        (0..ds.len()).map(|i| self.bucket_key(ds, i, rep)).collect()
    }

    /// Symbol matrix for all points (n × M, row-major) under repetition
    /// `rep`. Used by SortingLSH, which sorts rows lexicographically.
    fn symbol_matrix(&self, ds: &Dataset, rep: u64) -> Vec<u64> {
        let m = self.sketch_len();
        let mut out = vec![0u64; ds.len() * m];
        for i in 0..ds.len() {
            self.symbols(ds, i, rep, &mut out[i * m..(i + 1) * m]);
        }
        out
    }

    /// Optional fast path for SortingLSH: one u64 per point whose integer
    /// order equals the lexicographic order of the point's symbol sequence
    /// (families with ≤64 binary symbols pack sign bits MSB-first).
    /// Returning `Some` lets [`crate::lsh::sorting::sorted_indices`] sort
    /// plain u64 keys instead of comparing symbol rows.
    fn packed_sort_keys(&self, _ds: &Dataset, _rep: u64) -> Option<Vec<u64>> {
        None
    }
}

/// Collapse a symbol sequence into a single bucket key.
#[inline]
pub fn combine_symbols(symbols: &[u64]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &s in symbols {
        h = fxhash::combine(h, s);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn combine_symbols_is_injective_enough() {
        let mut seen = std::collections::HashSet::new();
        for a in 0..100u64 {
            for b in 0..100u64 {
                seen.insert(combine_symbols(&[a, b]));
            }
        }
        assert_eq!(seen.len(), 10_000);
        assert_ne!(combine_symbols(&[1, 2]), combine_symbols(&[2, 1]));
    }
}
