//! The [`LshFamily`] trait and its per-repetition [`SketchState`].
//!
//! The sketch phase evaluates every point under every repetition — R·n
//! evaluations per build. The seed trait made `symbols(point)` the primitive,
//! so each family re-derived its repetition constants (SimHash's `bits × dim`
//! Gaussian hyperplane matrix, CWS's per-token Gamma draws) *per point*:
//! O(n·M·d) redundant RNG work. The trait is now built around
//! [`LshFamily::prepare`]: one call per repetition captures everything that
//! depends only on `(family, rep)` — and, for set families, per-token tables
//! over the dataset — into a [`SketchState`], and all batch evaluation runs
//! through the state over point *ranges*. Ranges are what makes the sketch
//! phase data-parallel: `lsh::sketch` chunks `0..n` over the worker pool and
//! each chunk fills its disjoint output slice against the shared state.

use crate::data::types::Dataset;
use crate::util::fxhash;

/// Cached per-repetition evaluation state produced by [`LshFamily::prepare`].
///
/// All methods evaluate a contiguous point range `lo..lo + count` where
/// `count` is implied by the output slice length; the drivers in
/// [`crate::lsh::sketch`] call them from multiple pool threads at once, so
/// implementations must be immutable after `prepare` (hence `Sync`). The
/// serving layer additionally retains states inside `Arc`-shared snapshots
/// that hop threads on epoch swaps (hence `Send`).
///
/// **State-purity contract.** A state is a *cache*, never a definition: for
/// any evaluation dataset, outputs must be bit-identical to what a state
/// prepared against any other dataset would produce — every cached value is
/// a pure function of `(family seed, rep, point features)` alone (SimHash
/// hyperplanes depend only on the rep; MinHash/CWS per-token draws are
/// keyed by the token id, with an on-the-fly fallback for tokens outside
/// the prepare-time vocabulary). The serving layer leans on this twice:
/// query batches are sketched through index-time states, and incremental
/// compaction sketches *delta* points through the snapshot's states and
/// must land them in exactly the buckets a from-scratch rebuild would.
pub trait SketchState: Send + Sync {
    /// Bucket keys of points `lo..lo + out.len()` into `out`.
    fn bucket_keys_into(&self, ds: &Dataset, lo: usize, out: &mut [u64]);

    /// Symbol rows (row-major, `sketch_len` symbols per point) of points
    /// `lo..lo + out.len() / sketch_len` into `out`.
    fn symbols_into(&self, ds: &Dataset, lo: usize, out: &mut [u64]);

    /// Packed sort keys of points `lo..lo + out.len()` into `out`. Only
    /// called when the owning family reports
    /// [`LshFamily::supports_packed_sort`].
    fn packed_sort_keys_into(&self, _ds: &Dataset, _lo: usize, _out: &mut [u64]) {
        unreachable!("family does not support packed sort keys");
    }

    /// Heap bytes of the state's cached tables (hyperplane matrices,
    /// per-token draws) — serving-snapshot memory telemetry. 0 when the
    /// state caches nothing beyond the family constants.
    fn table_bytes(&self) -> usize {
        0
    }
}

/// A locality sensitive hash family over a dataset.
///
/// One *repetition* (`rep`) corresponds to one independent draw of the
/// concatenated hash `H(p) = (h_1(p), …, h_M(p))` from the family. The
/// pipeline evaluates repetitions `0..R` (the paper's "number of sketches").
pub trait LshFamily: Sync {
    /// Display name.
    fn name(&self) -> &'static str;

    /// Number of concatenated base hashes per sketch (the paper's M,
    /// "sketching dimension").
    fn sketch_len(&self) -> usize;

    /// Capture the repetition's cached evaluation state: hyperplane
    /// matrices, per-symbol component choices, per-token hash tables —
    /// whatever would otherwise be re-derived per point. Called once per
    /// (rep, job stage); everything downstream evaluates through the state.
    fn prepare<'a>(&'a self, ds: &Dataset, rep: u64) -> Box<dyn SketchState + 'a>;

    /// True if [`SketchState::packed_sort_keys_into`] is implemented: one
    /// u64 per point whose integer order equals the lexicographic order of
    /// the point's symbol sequence (families with ≤64 binary symbols pack
    /// sign bits MSB-first). This lets SortingLSH radix-sort plain u64 keys
    /// instead of comparing symbol rows.
    fn supports_packed_sort(&self) -> bool {
        false
    }

    /// Write the M base-hash symbols of point `i` under repetition `rep`
    /// into `out` (length `sketch_len()`). Single-point convenience: the
    /// default prepares a fresh state per call, so looping it over points
    /// re-derives the repetition constants — batch paths must use
    /// [`LshFamily::prepare`] (or the plural methods below) instead.
    fn symbols(&self, ds: &Dataset, i: usize, rep: u64, out: &mut [u64]) {
        self.prepare(ds, rep).symbols_into(ds, i, out);
    }

    /// Bucket key of point `i` under repetition `rep`: the combined hash of
    /// all M symbols. Two points share a bucket iff all symbols agree (up to
    /// a 2⁻⁶⁴ collision, which is negligible against the paper's n⁻⁴ bound).
    fn bucket_key(&self, ds: &Dataset, i: usize, rep: u64) -> u64 {
        let mut buf = vec![0u64; self.sketch_len()];
        self.symbols(ds, i, rep, &mut buf);
        combine_symbols(&buf)
    }

    /// Bucket keys for all points under repetition `rep` — one `prepare`,
    /// then a single state pass. See [`crate::lsh::sketch::bucket_keys_par`]
    /// for the pool-parallel variant.
    fn bucket_keys(&self, ds: &Dataset, rep: u64) -> Vec<u64> {
        let mut out = vec![0u64; ds.len()];
        if !out.is_empty() {
            self.prepare(ds, rep).bucket_keys_into(ds, 0, &mut out);
        }
        out
    }

    /// Symbol matrix for all points (n × M, row-major) under repetition
    /// `rep`. Used by SortingLSH, which sorts rows lexicographically.
    fn symbol_matrix(&self, ds: &Dataset, rep: u64) -> Vec<u64> {
        let mut out = vec![0u64; ds.len() * self.sketch_len()];
        if !out.is_empty() {
            self.prepare(ds, rep).symbols_into(ds, 0, &mut out);
        }
        out
    }

    /// Packed sort keys for all points, or `None` for families without the
    /// packed fast path (see [`LshFamily::supports_packed_sort`]).
    fn packed_sort_keys(&self, ds: &Dataset, rep: u64) -> Option<Vec<u64>> {
        if !self.supports_packed_sort() {
            return None;
        }
        let mut out = vec![0u64; ds.len()];
        if !out.is_empty() {
            self.prepare(ds, rep).packed_sort_keys_into(ds, 0, &mut out);
        }
        Some(out)
    }
}

/// Collapse a symbol sequence into a single bucket key.
#[inline]
pub fn combine_symbols(symbols: &[u64]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &s in symbols {
        h = fxhash::combine(h, s);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn combine_symbols_is_injective_enough() {
        let mut seen = std::collections::HashSet::new();
        for a in 0..100u64 {
            for b in 0..100u64 {
                seen.insert(combine_symbols(&[a, b]));
            }
        }
        assert_eq!(seen.len(), 10_000);
        assert_ne!(combine_symbols(&[1, 2]), combine_symbols(&[2, 1]));
    }
}
