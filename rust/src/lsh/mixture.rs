//! SimHash/MinHash mixture family (Amazon2m, paper Appendix D.2).
//!
//! Each of the M sketch symbols is independently drawn from either SimHash
//! (over the embedding) or MinHash (over the co-purchase set), chosen by a
//! per-(rep, symbol) coin. As the paper notes, this satisfies Definition 2.1
//! for the mixture similarity α·cosine + (1−α)·jaccard.
//!
//! [`MixtureHash::prepare`] draws the per-symbol coins once and nests the
//! SimHash component's own cached state, so every batch evaluation runs the
//! tiled hyperplane kernel per chunk instead of regenerating the `bits × dim`
//! matrix per point (the seed `symbols`/`symbol_matrix` path's O(n·M·d)
//! redundant RNG work).

use crate::data::types::Dataset;
use crate::lsh::family::{combine_symbols, LshFamily, SketchState};
use crate::lsh::{MinHash, SimHash};
use crate::util::rng::{derive_seed, SplitMix64};

/// Per-symbol random mixture of SimHash and MinHash over a hybrid dataset.
#[derive(Clone, Debug)]
pub struct MixtureHash {
    simhash: SimHash,
    minhash: MinHash,
    sketch_len: usize,
    /// Probability a symbol uses SimHash (0.5 = the paper's unbiased mix).
    pub simhash_prob: f64,
    seed: u64,
}

impl MixtureHash {
    /// Mixture family with `sketch_len` symbols over `dim`-dense + set data.
    pub fn new(dim: usize, sketch_len: usize, seed: u64) -> Self {
        MixtureHash {
            // Give each component its own full symbol budget; the mixture
            // picks per symbol which component's value to use.
            simhash: SimHash::new(dim, sketch_len.min(64), derive_seed(seed, 0x5D)),
            minhash: MinHash::new(sketch_len, derive_seed(seed, 0x3A)),
            sketch_len,
            simhash_prob: 0.5,
            seed,
        }
    }

    /// True if symbol `t` of repetition `rep` uses the SimHash component.
    #[inline]
    pub fn uses_simhash(&self, rep: u64, t: usize) -> bool {
        let mut sm = SplitMix64::new(derive_seed(
            self.seed ^ 0x4D49_58,
            rep.wrapping_mul(131).wrapping_add(t as u64),
        ));
        sm.next_f64() < self.simhash_prob
    }
}

/// Per-repetition mixture state: the nested SimHash state (cached planes)
/// plus the per-symbol component coins.
struct MixtureState<'a> {
    h: &'a MixtureHash,
    sim_state: Box<dyn SketchState + 'a>,
    choice: Vec<bool>,
    rep: u64,
}

impl MixtureState<'_> {
    /// SimHash keys of the chunk via the nested state's tiled kernel.
    fn sim_bits(&self, ds: &Dataset, lo: usize, count: usize) -> Vec<u64> {
        let mut bits = vec![0u64; count];
        self.sim_state.bucket_keys_into(ds, lo, &mut bits);
        bits
    }

    #[inline]
    fn symbol(&self, bits: u64, tokens: &[u32], t: usize) -> u64 {
        if self.choice[t] {
            (bits >> (t % 64)) & 1
        } else {
            self.h.minhash.symbol_of_set(tokens, self.rep, t)
        }
    }
}

impl SketchState for MixtureState<'_> {
    fn bucket_keys_into(&self, ds: &Dataset, lo: usize, out: &mut [u64]) {
        let bits = self.sim_bits(ds, lo, out.len());
        let mut buf = vec![0u64; self.h.sketch_len];
        for (k, key) in out.iter_mut().enumerate() {
            let tokens = &ds.set(lo + k).tokens;
            for (t, b) in buf.iter_mut().enumerate() {
                *b = self.symbol(bits[k], tokens, t);
            }
            *key = combine_symbols(&buf);
        }
    }

    fn symbols_into(&self, ds: &Dataset, lo: usize, out: &mut [u64]) {
        let m = self.h.sketch_len;
        let bits = self.sim_bits(ds, lo, out.len() / m);
        for (k, row) in out.chunks_mut(m).enumerate() {
            let tokens = &ds.set(lo + k).tokens;
            for (t, o) in row.iter_mut().enumerate() {
                *o = self.symbol(bits[k], tokens, t);
            }
        }
    }

    fn table_bytes(&self) -> usize {
        self.sim_state.table_bytes() + self.choice.len()
    }
}

impl LshFamily for MixtureHash {
    fn name(&self) -> &'static str {
        "mixture-hash"
    }

    fn sketch_len(&self) -> usize {
        self.sketch_len
    }

    fn prepare<'a>(&'a self, ds: &Dataset, rep: u64) -> Box<dyn SketchState + 'a> {
        Box::new(MixtureState {
            h: self,
            sim_state: self.simhash.prepare(ds, rep),
            choice: (0..self.sketch_len)
                .map(|t| self.uses_simhash(rep, t))
                .collect(),
            rep,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;

    #[test]
    fn deterministic_and_rep_dependent() {
        let ds = synth::products(60, &synth::ProductsParams::default(), 4);
        let h = MixtureHash::new(ds.dim(), 12, 9);
        assert_eq!(h.bucket_keys(&ds, 0), h.bucket_keys(&ds, 0));
        assert_ne!(h.bucket_keys(&ds, 0), h.bucket_keys(&ds, 1));
    }

    #[test]
    fn batch_matches_scalar_path() {
        let ds = synth::products(30, &synth::ProductsParams::default(), 4);
        let h = MixtureHash::new(ds.dim(), 8, 9);
        let batch = h.bucket_keys(&ds, 5);
        for i in 0..ds.len() {
            assert_eq!(batch[i], h.bucket_key(&ds, i, 5), "point {i}");
        }
    }

    #[test]
    fn symbol_matrix_matches_per_point_symbols() {
        // The seed symbol_matrix path regenerated hyperplanes per point; the
        // cached state must produce the same symbols.
        let ds = synth::products(40, &synth::ProductsParams::default(), 7);
        let h = MixtureHash::new(ds.dim(), 10, 2);
        let mat = h.symbol_matrix(&ds, 3);
        let mut buf = vec![0u64; 10];
        for i in 0..ds.len() {
            h.symbols(&ds, i, 3, &mut buf);
            assert_eq!(&mat[i * 10..(i + 1) * 10], &buf[..], "point {i}");
        }
    }

    #[test]
    fn mixture_uses_both_components() {
        let h = MixtureHash::new(10, 16, 1);
        let mut sim = 0;
        for rep in 0..8u64 {
            for t in 0..16 {
                if h.uses_simhash(rep, t) {
                    sim += 1;
                }
            }
        }
        // Out of 128 coins at p=0.5, both sides must appear.
        assert!(sim > 20 && sim < 108, "coin flips degenerate: {sim}/128");
    }

    #[test]
    fn same_class_collides_more_than_cross_class() {
        let ds = synth::products(300, &synth::ProductsParams::default(), 12);
        // Short sketches so full-key collisions are observable.
        let h = MixtureHash::new(ds.dim(), 2, 3);
        let (mut same_coll, mut same_n, mut diff_coll, mut diff_n) = (0, 0, 0, 0);
        for rep in 0..60u64 {
            let keys = h.bucket_keys(&ds, rep);
            for i in 0..60 {
                for j in (i + 1)..60 {
                    let coll = (keys[i] == keys[j]) as u64;
                    if ds.labels[i] == ds.labels[j] {
                        same_coll += coll;
                        same_n += 1;
                    } else {
                        diff_coll += coll;
                        diff_n += 1;
                    }
                }
            }
        }
        let ps = same_coll as f64 / same_n.max(1) as f64;
        let pd = diff_coll as f64 / diff_n.max(1) as f64;
        assert!(ps > pd, "same-class collision {ps} <= cross {pd}");
    }
}
