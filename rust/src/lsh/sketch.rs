//! Data-parallel sketch-phase drivers and the tiled multi-plane kernel.
//!
//! After the tiled batch-scoring pass (`sim/batch.rs`), the sketch-and-sort
//! phase became the dominant cost of a build ("TeraSort" in the production
//! system). This module is its counterpart:
//!
//! * [`sketch_tile`] — the dense hot kernel. Instead of [`sketch_row_scalar`]'s
//!   one-row × 2-plane loop, it scores a 4-row block against plane pairs as a
//!   cache-blocked mini-GEMM: one plane-element load feeds four multiply-add
//!   chains ([`crate::util::simd::sketch_block4`], runtime-dispatched to
//!   AVX2/NEON lanes or the blocked-scalar reference), so the kernel runs
//!   ~2× fewer loads per FMA and rides explicit vector registers where the
//!   host has them. Per (row, plane) dot the lane count, lane-sum order and
//!   scalar tail are kept identical to `sketch_row_scalar` on every
//!   backend, so tiled and scalar packed keys are **bit-identical**
//!   (asserted by `tests/sketch_parity.rs` and `tests/simd_parity.rs`).
//! * [`bucket_keys_par`] / [`symbol_matrix_par`] / [`packed_sort_keys_par`]
//!   (and [`crate::lsh::sorting::sorted_indices_par`] on top of them) — the
//!   data-parallel drivers. One
//!   [`LshFamily::prepare`] captures the repetition state, then point ranges
//!   are chunked over the pool with [`pool::parallel_fill`] and each chunk
//!   fills its disjoint output slice. This is what keeps cores busy when the
//!   builder has fewer live repetitions than workers (small R, wave tails).

use crate::data::types::Dataset;
use crate::lsh::family::LshFamily;
use crate::util::pool;
use crate::util::simd::{self, SimdBackend};

/// Minimum points a worker chunk must cover before the drivers spin up
/// threads — below this the spawn/join overhead beats the sketch work.
const PAR_MIN_CHUNK: usize = 1024;

/// Chunk length (in points) for `n` points over at most `workers` chunks,
/// or `n` when the range is too small to be worth splitting.
fn chunk_points(n: usize, workers: usize) -> usize {
    let w = workers.max(1).min(n.div_ceil(PAR_MIN_CHUNK).max(1));
    n.div_ceil(w).max(1)
}

/// Bucket keys of all points under `rep`, chunked over `workers` threads.
pub fn bucket_keys_par<F: LshFamily + ?Sized>(
    family: &F,
    ds: &Dataset,
    rep: u64,
    workers: usize,
) -> Vec<u64> {
    bucket_keys_par_timed(family, ds, rep, workers, |_, _| {})
}

/// [`bucket_keys_par`] reporting per-chunk busy spans to `busy` — the
/// builder threads its ledger through here so inner-worker machine-seconds
/// land in Σ busy (see `CostLedger::add_inner_busy`).
pub fn bucket_keys_par_timed<F, B>(
    family: &F,
    ds: &Dataset,
    rep: u64,
    workers: usize,
    busy: B,
) -> Vec<u64>
where
    F: LshFamily + ?Sized,
    B: Fn(usize, u64) + Sync,
{
    let n = ds.len();
    let mut out = vec![0u64; n];
    if n == 0 {
        return out;
    }
    let state = family.prepare(ds, rep);
    pool::parallel_fill_timed(&mut out, chunk_points(n, workers), busy, |lo, slice| {
        state.bucket_keys_into(ds, lo, slice)
    });
    out
}

/// Symbol matrix (n × M, row-major) under `rep`, chunked over `workers`.
pub fn symbol_matrix_par<F: LshFamily + ?Sized>(
    family: &F,
    ds: &Dataset,
    rep: u64,
    workers: usize,
) -> Vec<u64> {
    symbol_matrix_par_timed(family, ds, rep, workers, |_, _| {})
}

/// [`symbol_matrix_par`] with per-chunk busy reporting.
pub fn symbol_matrix_par_timed<F, B>(
    family: &F,
    ds: &Dataset,
    rep: u64,
    workers: usize,
    busy: B,
) -> Vec<u64>
where
    F: LshFamily + ?Sized,
    B: Fn(usize, u64) + Sync,
{
    let n = ds.len();
    let m = family.sketch_len();
    let mut out = vec![0u64; n * m];
    if out.is_empty() {
        return out;
    }
    let state = family.prepare(ds, rep);
    // Chunk boundaries must land on row boundaries: chunk in points, scale
    // to elements, and recover the first point from the element offset.
    pool::parallel_fill_timed(&mut out, chunk_points(n, workers) * m, busy, |off, slice| {
        state.symbols_into(ds, off / m, slice)
    });
    out
}

/// Bucket keys of the point range `lo..lo + count` of `ds` through an
/// already-prepared [`SketchState`], chunked over `workers` pool threads —
/// the *delta-range* driver. Where [`bucket_keys_par`] prepares a fresh
/// state and sketches a whole dataset, this sketches only a sub-range
/// through a state the caller already owns: the serving layer's incremental
/// compaction pays `O(|delta|)` sketch work by running the snapshot's
/// cached per-repetition states over just the appended rows of the merged
/// dataset (bit-identical keys by the state-purity contract on
/// [`SketchState`]). Output is identical for any worker count.
pub fn state_keys_range_par(
    state: &dyn crate::lsh::SketchState,
    ds: &Dataset,
    lo: usize,
    count: usize,
    workers: usize,
) -> Vec<u64> {
    debug_assert!(lo + count <= ds.len());
    let mut out = vec![0u64; count];
    if count == 0 {
        return out;
    }
    pool::parallel_fill(&mut out, chunk_points(count, workers), |off, slice| {
        state.bucket_keys_into(ds, lo + off, slice)
    });
    out
}

/// Packed sort keys under `rep`, chunked over `workers`; `None` when the
/// family has no packed fast path.
pub fn packed_sort_keys_par<F: LshFamily + ?Sized>(
    family: &F,
    ds: &Dataset,
    rep: u64,
    workers: usize,
) -> Option<Vec<u64>> {
    packed_sort_keys_par_timed(family, ds, rep, workers, |_, _| {})
}

/// [`packed_sort_keys_par`] with per-chunk busy reporting.
pub fn packed_sort_keys_par_timed<F, B>(
    family: &F,
    ds: &Dataset,
    rep: u64,
    workers: usize,
    busy: B,
) -> Option<Vec<u64>>
where
    F: LshFamily + ?Sized,
    B: Fn(usize, u64) + Sync,
{
    if !family.supports_packed_sort() {
        return None;
    }
    let n = ds.len();
    let mut out = vec![0u64; n];
    if n == 0 {
        return Some(out);
    }
    let state = family.prepare(ds, rep);
    pool::parallel_fill_timed(&mut out, chunk_points(n, workers), busy, |lo, slice| {
        state.packed_sort_keys_into(ds, lo, slice)
    });
    Some(out)
}

/// Packed sign bits of one row against a precomputed hyperplane matrix
/// (`bits × d`, row-major): bit `m` of the result is `dot(row, plane_m) ≥ 0`.
///
/// Perf: processes hyperplanes in pairs through the runtime-dispatched
/// plane-pair kernel ([`crate::util::simd::sketch_row2`] — AVX2/NEON lanes
/// where available, the 4-lane blocked-scalar reference otherwise), so the
/// row stays hot in L1 across both planes (see EXPERIMENTS.md §Perf).
/// "Scalar" in the name means *one row at a time* (vs the 4-row
/// [`sketch_tile`]); every backend reduces each (row, plane) dot in the
/// same fixed order, so the packed keys are bit-identical regardless of
/// backend — the parity tests assert exact key equality.
#[inline]
pub fn sketch_row_scalar(planes: &[f32], bits: usize, d: usize, row: &[f32]) -> u64 {
    sketch_row_with(simd::active(), planes, bits, d, row)
}

/// [`sketch_row_scalar`] on an explicit SIMD backend (dispatch hoisted to
/// one resolve per row).
pub fn sketch_row_with(
    backend: SimdBackend,
    planes: &[f32],
    bits: usize,
    d: usize,
    row: &[f32],
) -> u64 {
    debug_assert_eq!(row.len(), d);
    let mut key = 0u64;
    let mut m = 0;
    while m + 2 <= bits {
        let p0 = &planes[m * d..(m + 1) * d];
        let p1 = &planes[(m + 1) * d..(m + 2) * d];
        let (da, db) = simd::sketch_row2_with(backend, p0, p1, row);
        if da >= 0.0 {
            key |= 1 << m;
        }
        if db >= 0.0 {
            key |= 1 << (m + 1);
        }
        m += 2;
    }
    if m < bits {
        let plane = &planes[m * d..(m + 1) * d];
        let mut dot = 0f32;
        for k in 0..d {
            dot += row[k] * plane[k];
        }
        if dot >= 0.0 {
            key |= 1 << m;
        }
    }
    key
}

/// Packed keys of `n` contiguous rows (`rows[r*d..(r+1)*d]` is row r)
/// against a `bits × d` hyperplane matrix: the tiled multi-plane kernel.
/// 4-row blocks run through the runtime-dispatched
/// [`crate::util::simd::sketch_block4`] (one plane-element load feeds four
/// multiply-add chains per plane); tail rows (n % 4) fall back to
/// [`sketch_row_scalar`]'s plane-pair kernel, which reduces in the same
/// order, so the output is bit-identical to a per-row loop on every
/// backend.
pub fn sketch_tile(planes: &[f32], bits: usize, d: usize, rows: &[f32], n: usize, out: &mut [u64]) {
    sketch_tile_with(simd::active(), planes, bits, d, rows, n, out);
}

/// [`sketch_tile`] on an explicit SIMD backend (dispatch resolved once per
/// tile — benches and the parity suite force backends through here).
#[allow(clippy::too_many_arguments)]
pub fn sketch_tile_with(
    backend: SimdBackend,
    planes: &[f32],
    bits: usize,
    d: usize,
    rows: &[f32],
    n: usize,
    out: &mut [u64],
) {
    debug_assert!(bits >= 1 && bits <= 64);
    debug_assert!(planes.len() >= bits * d && rows.len() >= n * d && out.len() >= n);
    let mut r = 0;
    while r + 4 <= n {
        let base = r * d;
        let t0 = &rows[base..base + d];
        let t1 = &rows[base + d..base + 2 * d];
        let t2 = &rows[base + 2 * d..base + 3 * d];
        let t3 = &rows[base + 3 * d..base + 4 * d];
        let mut keys = [0u64; 4];
        let mut m = 0;
        while m + 2 <= bits {
            let p0 = &planes[m * d..(m + 1) * d];
            let p1 = &planes[(m + 1) * d..(m + 2) * d];
            let (da, db) = simd::sketch_block4_with(backend, p0, p1, t0, t1, t2, t3);
            for (row, key) in keys.iter_mut().enumerate() {
                if da[row] >= 0.0 {
                    *key |= 1 << m;
                }
                if db[row] >= 0.0 {
                    *key |= 1 << (m + 1);
                }
            }
            m += 2;
        }
        if m < bits {
            // Odd final plane: same plain scalar accumulation as the
            // scalar kernel's tail.
            let plane = &planes[m * d..(m + 1) * d];
            for (t, key) in [t0, t1, t2, t3].iter().zip(keys.iter_mut()) {
                let mut dot = 0f32;
                for (x, p) in t.iter().zip(plane.iter()) {
                    dot += x * p;
                }
                if dot >= 0.0 {
                    *key |= 1 << m;
                }
            }
        }
        out[r..r + 4].copy_from_slice(&keys);
        r += 4;
    }
    while r < n {
        out[r] = sketch_row_with(backend, planes, bits, d, &rows[r * d..(r + 1) * d]);
        r += 1;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::lsh::{MinHash, SimHash};

    #[test]
    fn tile_matches_scalar_rows_including_tails() {
        // 11 rows: two 4-blocks plus a 3-row tail; odd and even bit counts.
        for &(bits, d) in &[(1usize, 5usize), (7, 16), (12, 100), (30, 33), (64, 8)] {
            let ds = synth::gaussian_mixture(11, d, 3, 0.4, 77);
            let h = SimHash::new(d, bits, 5);
            let planes = h.hyperplanes(4);
            let mut out = vec![0u64; ds.len()];
            sketch_tile(&planes, bits, d, &ds.dense, ds.len(), &mut out);
            for i in 0..ds.len() {
                let want = sketch_row_scalar(&planes, bits, d, ds.row(i));
                assert_eq!(out[i], want, "bits={bits} d={d} row={i}");
            }
        }
    }

    #[test]
    fn parallel_drivers_match_serial_trait_paths() {
        let ds = synth::gaussian_mixture(3000, 16, 6, 0.1, 9);
        let h = SimHash::new(16, 12, 3);
        for workers in [1usize, 3, 8] {
            assert_eq!(bucket_keys_par(&h, &ds, 1, workers), h.bucket_keys(&ds, 1));
            assert_eq!(
                symbol_matrix_par(&h, &ds, 1, workers),
                h.symbol_matrix(&ds, 1)
            );
            assert_eq!(
                packed_sort_keys_par(&h, &ds, 1, workers),
                h.packed_sort_keys(&ds, 1)
            );
        }
    }

    #[test]
    fn state_range_driver_matches_full_sketch() {
        // The incremental-compaction driver: sketching a sub-range through a
        // prepared state must match the same rows of a full-dataset sketch,
        // for any worker count.
        let ds = synth::gaussian_mixture(2500, 16, 4, 0.1, 11);
        let h = SimHash::new(16, 10, 5);
        let state = h.prepare(&ds, 3);
        let full = h.bucket_keys(&ds, 3);
        for workers in [1usize, 4] {
            let range = state_keys_range_par(state.as_ref(), &ds, 300, 2100, workers);
            assert_eq!(&range[..], &full[300..2400], "workers={workers}");
        }
        assert!(state_keys_range_par(state.as_ref(), &ds, 10, 0, 2).is_empty());
    }

    #[test]
    fn drivers_handle_empty_and_unpacked_families() {
        let ds = crate::data::Dataset::from_sets("t", Vec::new(), Vec::new());
        let mh = MinHash::new(3, 1);
        assert!(bucket_keys_par(&mh, &ds, 0, 4).is_empty());
        assert!(symbol_matrix_par(&mh, &ds, 0, 4).is_empty());
        assert_eq!(packed_sort_keys_par(&mh, &ds, 0, 4), None);
    }

    #[test]
    fn sorted_indices_par_is_worker_invariant() {
        use crate::lsh::sorting::sorted_indices_par;
        let ds = synth::gaussian_mixture(2500, 16, 8, 0.1, 6);
        let h = SimHash::new(16, 30, 4);
        let serial = sorted_indices_par(&h, &ds, 2, 1);
        for workers in [2usize, 5, 16] {
            assert_eq!(sorted_indices_par(&h, &ds, 2, workers), serial);
        }
    }
}
