//! Weighted MinHash via Ioffe's Consistent Weighted Sampling (CWS).
//!
//! For non-negative weighted vectors x, y: `Pr[h(x) = h(y)] = J_w(x,y) =
//! Σ min(xᵢ,yᵢ) / Σ max(xᵢ,yᵢ)` — the weighted Jaccard similarity the paper
//! uses for Wikipedia. The paper cites [33] (Moulton & Jiang) for the
//! general-vector variant; Ioffe's CWS is the standard construction and
//! samples exactly from the same distribution.
//!
//! The CWS randomness `(r, c, β)` depends only on `(seed, rep, token, t)` —
//! not on the point — so [`WeightedMinHash::prepare`] derives it **once per
//! distinct token** of the dataset into a per-repetition table. The seed
//! path re-ran the four transcendental draws for every *occurrence* of a
//! token (every point × every symbol); with Zipf-ish token distributions the
//! table turns most of the sketch phase into table lookups. Table values are
//! the same doubles the on-the-fly path computes, so symbols are
//! bit-identical either way.
//!
//! The token → slot map itself is repetition-invariant, so it lives on the
//! dataset as the shared [`TokenVocab`] (one discovery pass per dataset,
//! not one per repetition) — `prepare` performs only the per-rep CWS draws.

use crate::data::types::{Dataset, TokenVocab};
use crate::lsh::family::{combine_symbols, LshFamily, SketchState};
use crate::util::fxhash;
use crate::util::rng::SplitMix64;
use std::sync::Arc;

/// Cap on cached CWS entries (distinct tokens × perms): past this the state
/// falls back to on-the-fly derivation so a pathological token universe
/// cannot blow up per-repetition memory (entries are 24 B each).
const CWS_CACHE_MAX_ENTRIES: usize = 1 << 21;

/// The per-(token, rep, t) CWS draw, stored in evaluation-ready form.
#[derive(Clone, Copy)]
struct CwsParam {
    /// Gamma(2, 1) scale of the quantization grid.
    r: f64,
    /// ln of the Gamma(2, 1) acceptance variable.
    ln_c: f64,
    /// Uniform grid offset.
    beta: f64,
}

/// Ioffe CWS family over weighted token sets.
#[derive(Clone, Debug)]
pub struct WeightedMinHash {
    perms: usize,
    seed: u64,
}

impl WeightedMinHash {
    /// Family with `perms` independent CWS hashes per sketch.
    pub fn new(perms: usize, seed: u64) -> Self {
        assert!(perms >= 1);
        WeightedMinHash { perms, seed }
    }

    /// The CWS draw for `(rep, token, t)`.
    ///
    /// Perf: Gamma(2,1) draws use one `ln` on the product of two uniforms
    /// instead of two separate `ln` calls (identical distribution), cutting
    /// the transcendental count per token from 5 to 4 (EXPERIMENTS.md §Perf).
    #[inline]
    fn cws_param(&self, rep: u64, tok: u32, t: usize) -> CwsParam {
        // Per-(token, rep, t) deterministic stream of uniforms.
        let key = fxhash::combine(
            self.seed ^ 0x4357_53_48, // "CWSH"
            fxhash::combine((rep << 24) ^ t as u64, tok as u64),
        );
        let mut sm = SplitMix64::new(key);
        // r, c ~ Gamma(2, 1) = -ln(u1 u2); beta ~ U(0,1).
        let r = -(sm.next_f64() * sm.next_f64()).max(1e-300).ln();
        let c = -(sm.next_f64() * sm.next_f64()).max(1e-300).ln();
        let beta = sm.next_f64();
        CwsParam {
            r,
            ln_c: c.ln(),
            beta,
        }
    }

    /// CWS symbol of one weighted set for (rep, t): encodes (k*, t_{k*}).
    pub fn symbol_of_set(&self, tokens: &[u32], weights: &[f32], rep: u64, t: usize) -> u64 {
        let mut best = (f64::INFINITY, u64::MAX);
        for (idx, &tok) in tokens.iter().enumerate() {
            let w = weights[idx] as f64;
            if w <= 0.0 {
                continue;
            }
            let p = self.cws_param(rep, tok, t);
            offer_symbol(&mut best, &p, w.ln(), tok);
        }
        best.1
    }
}

/// Evaluate one (token, symbol) candidate against the running minimum:
/// `ln a_k = ln c − ln y − r` with `y = e^{r (t_k − β)}`, `t_k = ⌊ln w / r +
/// β⌋`. Strict `<` keeps the first minimum, matching the seed path's token
/// iteration order.
#[inline]
fn offer_symbol(best: &mut (f64, u64), p: &CwsParam, ln_w: f64, tok: u32) {
    let t_k = (ln_w / p.r + p.beta).floor();
    let ln_y = p.r * (t_k - p.beta);
    let ln_a = p.ln_c - ln_y - p.r;
    if ln_a < best.0 {
        *best = (ln_a, fxhash::combine(tok as u64, t_k.to_bits()));
    }
}

/// Per-repetition CWS state: the per-distinct-token parameter table keyed
/// by the dataset's shared [`TokenVocab`] slots (or the fallback marker
/// when the universe exceeds [`CWS_CACHE_MAX_ENTRIES`]).
struct WeightedMinHashState<'a> {
    h: &'a WeightedMinHash,
    rep: u64,
    /// The prepare-time token universe; `None` disables the table.
    vocab: Option<Arc<TokenVocab>>,
    /// `params[slot * perms + t]` is the (token_of(slot), t) draw.
    params: Vec<CwsParam>,
}

impl<'a> WeightedMinHashState<'a> {
    fn new(h: &'a WeightedMinHash, ds: &Dataset, rep: u64) -> Self {
        // The repetition-invariant token -> slot map comes from the shared
        // per-dataset vocabulary (built once, reused by every repetition
        // and family); this function only performs the per-rep CWS draws.
        let vocab = ds.token_vocab();
        if vocab.overflow() || vocab.len() * h.perms > CWS_CACHE_MAX_ENTRIES {
            return WeightedMinHashState {
                h,
                rep,
                vocab: None,
                params: Vec::new(),
            };
        }
        let entries = vocab.len() * h.perms;
        let mut params = vec![
            CwsParam {
                r: 0.0,
                ln_c: 0.0,
                beta: 0.0
            };
            entries
        ];
        for (tok, slot) in vocab.iter() {
            let base = slot as usize * h.perms;
            for (t, p) in params[base..base + h.perms].iter_mut().enumerate() {
                *p = h.cws_param(rep, tok, t);
            }
        }
        WeightedMinHashState {
            h,
            rep,
            vocab: Some(Arc::clone(vocab)),
            params,
        }
    }

    /// Fill `best` (one `(ln a, symbol)` slot per base hash) for point `i`.
    fn point_min(&self, ds: &Dataset, i: usize, best: &mut [(f64, u64)]) {
        best.fill((f64::INFINITY, u64::MAX));
        let m = self.h.perms;
        let set = ds.set(i);
        for (idx, &tok) in set.tokens.iter().enumerate() {
            let w = set.weights[idx] as f64;
            if w <= 0.0 {
                continue;
            }
            let ln_w = w.ln();
            match self.vocab.as_ref().and_then(|v| v.slot(tok)) {
                Some(slot) => {
                    let ps = &self.params[slot as usize * m..(slot as usize + 1) * m];
                    for (b, p) in best.iter_mut().zip(ps.iter()) {
                        offer_symbol(b, p, ln_w, tok);
                    }
                }
                None => {
                    for (t, b) in best.iter_mut().enumerate() {
                        let p = self.h.cws_param(self.rep, tok, t);
                        offer_symbol(b, &p, ln_w, tok);
                    }
                }
            }
        }
    }
}

impl SketchState for WeightedMinHashState<'_> {
    fn bucket_keys_into(&self, ds: &Dataset, lo: usize, out: &mut [u64]) {
        let m = self.h.perms;
        let mut best = vec![(f64::INFINITY, u64::MAX); m];
        let mut buf = vec![0u64; m];
        for (k, key) in out.iter_mut().enumerate() {
            self.point_min(ds, lo + k, &mut best);
            for (b, &(_, sym)) in buf.iter_mut().zip(best.iter()) {
                *b = sym;
            }
            *key = combine_symbols(&buf);
        }
    }

    fn symbols_into(&self, ds: &Dataset, lo: usize, out: &mut [u64]) {
        let m = self.h.perms;
        let mut best = vec![(f64::INFINITY, u64::MAX); m];
        for (k, row) in out.chunks_mut(m).enumerate() {
            self.point_min(ds, lo + k, &mut best);
            for (o, &(_, sym)) in row.iter_mut().zip(best.iter()) {
                *o = sym;
            }
        }
    }

    fn table_bytes(&self) -> usize {
        self.params.len() * std::mem::size_of::<CwsParam>()
    }
}

impl LshFamily for WeightedMinHash {
    fn name(&self) -> &'static str {
        "weighted-minhash"
    }

    fn sketch_len(&self) -> usize {
        self.perms
    }

    fn prepare<'a>(&'a self, ds: &Dataset, rep: u64) -> Box<dyn SketchState + 'a> {
        Box::new(WeightedMinHashState::new(self, ds, rep))
    }

    fn symbols(&self, ds: &Dataset, i: usize, rep: u64, out: &mut [u64]) {
        let s = ds.set(i);
        for (t, o) in out.iter_mut().enumerate() {
            *o = self.symbol_of_set(&s.tokens, &s.weights, rep, t);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::types::{Dataset, WeightedSet};
    use crate::sim::weighted_jaccard;

    fn ds_of(sets: Vec<Vec<(u32, f32)>>) -> Dataset {
        Dataset::from_sets(
            "t",
            sets.into_iter().map(WeightedSet::from_pairs).collect(),
            vec![],
        )
    }

    #[test]
    fn identical_weighted_sets_always_collide() {
        let ds = ds_of(vec![
            vec![(1, 2.5), (7, 1.0), (9, 4.0)],
            vec![(1, 2.5), (7, 1.0), (9, 4.0)],
        ]);
        let h = WeightedMinHash::new(3, 11);
        for rep in 0..20 {
            assert_eq!(h.bucket_key(&ds, 0, rep), h.bucket_key(&ds, 1, rep));
        }
    }

    #[test]
    fn collision_rate_estimates_weighted_jaccard() {
        let ds = ds_of(vec![
            vec![(1, 3.0), (2, 1.0), (3, 2.0)],
            vec![(1, 1.0), (2, 1.0), (4, 2.0)],
        ]);
        let j = weighted_jaccard(ds.set(0), ds.set(1)) as f64;
        let h = WeightedMinHash::new(1, 5);
        let reps = 6000u64;
        let mut coll = 0;
        for rep in 0..reps {
            let a = h.symbol_of_set(&ds.set(0).tokens, &ds.set(0).weights, rep, 0);
            let b = h.symbol_of_set(&ds.set(1).tokens, &ds.set(1).weights, rep, 0);
            if a == b {
                coll += 1;
            }
        }
        let p = coll as f64 / reps as f64;
        assert!((p - j).abs() < 0.03, "estimate {p} vs weighted jaccard {j}");
    }

    #[test]
    fn weight_scaling_changes_hash_distribution() {
        // Doubling one weight moves some collisions: J_w changes.
        let ds = ds_of(vec![
            vec![(1, 1.0), (2, 1.0)],
            vec![(1, 2.0), (2, 1.0)],
        ]);
        let j = weighted_jaccard(ds.set(0), ds.set(1)) as f64; // (1+1)/(2+1) = 2/3
        assert!((j - 2.0 / 3.0).abs() < 1e-6);
        let h = WeightedMinHash::new(1, 2);
        let reps = 6000u64;
        let mut coll = 0;
        for rep in 0..reps {
            let a = h.symbol_of_set(&ds.set(0).tokens, &ds.set(0).weights, rep, 0);
            let b = h.symbol_of_set(&ds.set(1).tokens, &ds.set(1).weights, rep, 0);
            if a == b {
                coll += 1;
            }
        }
        let p = coll as f64 / reps as f64;
        assert!((p - j).abs() < 0.04, "estimate {p} vs {j}");
    }

    #[test]
    fn zero_weight_tokens_ignored() {
        let h = WeightedMinHash::new(1, 2);
        let a = h.symbol_of_set(&[1, 2], &[1.0, 0.0], 0, 0);
        let b = h.symbol_of_set(&[1], &[1.0], 0, 0);
        assert_eq!(a, b);
    }

    #[test]
    fn prepare_reuses_the_shared_vocab_across_reps() {
        let ds = crate::data::synth::zipf_sets(
            80,
            &crate::data::synth::ZipfSetsParams::default(),
            7,
        );
        let h = WeightedMinHash::new(2, 3);
        // First prepare builds the vocabulary; later reps must get the very
        // same Arc (no rediscovery pass).
        let _ = h.prepare(&ds, 0);
        let built = std::sync::Arc::clone(ds.token_vocab());
        let _ = h.prepare(&ds, 1);
        assert!(std::sync::Arc::ptr_eq(&built, ds.token_vocab()));
    }

    #[test]
    fn state_falls_back_for_out_of_vocab_tokens() {
        // Prepare against one dataset, evaluate another with unseen tokens
        // (the serving query path): bit-identical to the stateless path.
        let index_ds = ds_of(vec![vec![(1, 2.0), (2, 1.0)], vec![(2, 1.5), (3, 1.0)]]);
        let query_ds = ds_of(vec![vec![(700, 1.0), (1, 0.5)], vec![(701, 2.0)]]);
        let h = WeightedMinHash::new(3, 11);
        let state = h.prepare(&index_ds, 4);
        let mut keys = vec![0u64; 2];
        state.bucket_keys_into(&query_ds, 0, &mut keys);
        for i in 0..2 {
            assert_eq!(keys[i], h.bucket_key(&query_ds, i, 4), "query {i}");
        }
    }

    #[test]
    fn cached_state_matches_per_point_path() {
        let ds = crate::data::synth::zipf_sets(
            150,
            &crate::data::synth::ZipfSetsParams::default(),
            13,
        );
        let h = WeightedMinHash::new(4, 21);
        for rep in [0u64, 3] {
            let batch = h.bucket_keys(&ds, rep);
            for i in 0..ds.len() {
                assert_eq!(batch[i], h.bucket_key(&ds, i, rep), "point {i} rep {rep}");
            }
            let mat = h.symbol_matrix(&ds, rep);
            let mut buf = vec![0u64; 4];
            for i in 0..ds.len() {
                h.symbols(&ds, i, rep, &mut buf);
                assert_eq!(&mat[i * 4..(i + 1) * 4], &buf[..], "point {i} rep {rep}");
            }
        }
    }
}
