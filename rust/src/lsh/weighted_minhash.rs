//! Weighted MinHash via Ioffe's Consistent Weighted Sampling (CWS).
//!
//! For non-negative weighted vectors x, y: `Pr[h(x) = h(y)] = J_w(x,y) =
//! Σ min(xᵢ,yᵢ) / Σ max(xᵢ,yᵢ)` — the weighted Jaccard similarity the paper
//! uses for Wikipedia. The paper cites [33] (Moulton & Jiang) for the
//! general-vector variant; Ioffe's CWS is the standard construction and
//! samples exactly from the same distribution.

use crate::data::types::Dataset;
use crate::lsh::family::LshFamily;
use crate::util::fxhash;
use crate::util::rng::SplitMix64;

/// Ioffe CWS family over weighted token sets.
#[derive(Clone, Debug)]
pub struct WeightedMinHash {
    perms: usize,
    seed: u64,
}

impl WeightedMinHash {
    /// Family with `perms` independent CWS hashes per sketch.
    pub fn new(perms: usize, seed: u64) -> Self {
        assert!(perms >= 1);
        WeightedMinHash { perms, seed }
    }

    /// CWS symbol of one weighted set for (rep, t): encodes (k*, t_{k*}).
    ///
    /// Perf: Gamma(2,1) draws use one `ln` on the product of two uniforms
    /// instead of two separate `ln` calls (identical distribution), cutting
    /// the transcendental count per token from 5 to 4 (EXPERIMENTS.md §Perf).
    pub fn symbol_of_set(&self, tokens: &[u32], weights: &[f32], rep: u64, t: usize) -> u64 {
        let mut best = f64::INFINITY;
        let mut best_sym = u64::MAX;
        for (idx, &tok) in tokens.iter().enumerate() {
            let w = weights[idx] as f64;
            if w <= 0.0 {
                continue;
            }
            // Per-(token, rep, t) deterministic stream of uniforms.
            let key = fxhash::combine(
                self.seed ^ 0x4357_53_48, // "CWSH"
                fxhash::combine((rep << 24) ^ t as u64, tok as u64),
            );
            let mut sm = SplitMix64::new(key);
            // r, c ~ Gamma(2, 1) = -ln(u1 u2); beta ~ U(0,1).
            let r = -(sm.next_f64() * sm.next_f64()).max(1e-300).ln();
            let c = -(sm.next_f64() * sm.next_f64()).max(1e-300).ln();
            let beta = sm.next_f64();
            let t_k = (w.ln() / r + beta).floor();
            let ln_y = r * (t_k - beta);
            // a_k = c / (y e^r)  =>  ln a_k = ln c - ln y - r.
            let ln_a = c.ln() - ln_y - r;
            if ln_a < best {
                best = ln_a;
                best_sym = fxhash::combine(tok as u64, t_k.to_bits());
            }
        }
        best_sym
    }
}

impl LshFamily for WeightedMinHash {
    fn name(&self) -> &'static str {
        "weighted-minhash"
    }

    fn sketch_len(&self) -> usize {
        self.perms
    }

    fn symbols(&self, ds: &Dataset, i: usize, rep: u64, out: &mut [u64]) {
        let s = ds.set(i);
        for (t, o) in out.iter_mut().enumerate() {
            *o = self.symbol_of_set(&s.tokens, &s.weights, rep, t);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::types::{Dataset, WeightedSet};
    use crate::sim::weighted_jaccard;

    fn ds_of(sets: Vec<Vec<(u32, f32)>>) -> Dataset {
        Dataset::from_sets(
            "t",
            sets.into_iter().map(WeightedSet::from_pairs).collect(),
            vec![],
        )
    }

    #[test]
    fn identical_weighted_sets_always_collide() {
        let ds = ds_of(vec![
            vec![(1, 2.5), (7, 1.0), (9, 4.0)],
            vec![(1, 2.5), (7, 1.0), (9, 4.0)],
        ]);
        let h = WeightedMinHash::new(3, 11);
        for rep in 0..20 {
            assert_eq!(h.bucket_key(&ds, 0, rep), h.bucket_key(&ds, 1, rep));
        }
    }

    #[test]
    fn collision_rate_estimates_weighted_jaccard() {
        let ds = ds_of(vec![
            vec![(1, 3.0), (2, 1.0), (3, 2.0)],
            vec![(1, 1.0), (2, 1.0), (4, 2.0)],
        ]);
        let j = weighted_jaccard(ds.set(0), ds.set(1)) as f64;
        let h = WeightedMinHash::new(1, 5);
        let reps = 6000u64;
        let mut coll = 0;
        for rep in 0..reps {
            let a = h.symbol_of_set(&ds.set(0).tokens, &ds.set(0).weights, rep, 0);
            let b = h.symbol_of_set(&ds.set(1).tokens, &ds.set(1).weights, rep, 0);
            if a == b {
                coll += 1;
            }
        }
        let p = coll as f64 / reps as f64;
        assert!((p - j).abs() < 0.03, "estimate {p} vs weighted jaccard {j}");
    }

    #[test]
    fn weight_scaling_changes_hash_distribution() {
        // Doubling one weight moves some collisions: J_w changes.
        let ds = ds_of(vec![
            vec![(1, 1.0), (2, 1.0)],
            vec![(1, 2.0), (2, 1.0)],
        ]);
        let j = weighted_jaccard(ds.set(0), ds.set(1)) as f64; // (1+1)/(2+1) = 2/3
        assert!((j - 2.0 / 3.0).abs() < 1e-6);
        let h = WeightedMinHash::new(1, 2);
        let reps = 6000u64;
        let mut coll = 0;
        for rep in 0..reps {
            let a = h.symbol_of_set(&ds.set(0).tokens, &ds.set(0).weights, rep, 0);
            let b = h.symbol_of_set(&ds.set(1).tokens, &ds.set(1).weights, rep, 0);
            if a == b {
                coll += 1;
            }
        }
        let p = coll as f64 / reps as f64;
        assert!((p - j).abs() < 0.04, "estimate {p} vs {j}");
    }

    #[test]
    fn zero_weight_tokens_ignored() {
        let h = WeightedMinHash::new(1, 2);
        let a = h.symbol_of_set(&[1, 2], &[1.0, 0.0], 0, 0);
        let b = h.symbol_of_set(&[1], &[1.0], 0, 0);
        assert_eq!(a, b);
    }
}
