//! SortingLSH (paper §3.2, after Bawa et al.'s LSH Forest).
//!
//! Evaluate M base hashes per point, sort points lexicographically by their
//! symbol sequences, and split the order into contiguous windows of size ≤ W
//! with a random shift `r ∈ [W/2, W]` for the first window. Points in dense
//! regions share long prefixes and land in the same window; sparse-region
//! points still share shorter prefixes with their (more distant) neighbors.

use crate::data::types::Dataset;
use crate::lsh::family::LshFamily;
use crate::lsh::sketch;
use crate::util::radix;
use crate::util::rng::Rng;
use std::ops::Range;

/// The sorted order of points for one repetition.
#[derive(Clone, Debug)]
pub struct SortedOrder {
    /// Point indices in lexicographic symbol order.
    pub order: Vec<u32>,
    /// Symbol matrix (n × m, row-major, in *original* point order).
    pub symbols: Vec<u64>,
    /// Symbols per point.
    pub m: usize,
}

impl SortedOrder {
    /// Symbols of original point `i`.
    pub fn row(&self, i: u32) -> &[u64] {
        let m = self.m;
        &self.symbols[i as usize * m..(i as usize + 1) * m]
    }

    /// Common prefix length (in symbols) between two original points.
    pub fn common_prefix(&self, i: u32, j: u32) -> usize {
        self.row(i)
            .iter()
            .zip(self.row(j))
            .take_while(|(a, b)| a == b)
            .count()
    }
}

/// Just the lexicographic index order (the scoring loop's need). Uses the
/// family's packed-u64 fast path when available — LSD radix on 64-bit keys
/// ([`radix::argsort_u64`], pool-parallel via
/// [`radix::argsort_u64_par`] when the repetition has spare cores)
/// replaces both the symbol-row comparisons and the `n log n` key sort
/// (EXPERIMENTS.md §Perf); ties still break by index, so the order is
/// identical to the comparison path's.
pub fn sorted_indices<F: LshFamily + ?Sized>(family: &F, ds: &Dataset, rep: u64) -> Vec<u32> {
    sorted_indices_par(family, ds, rep, 1)
}

/// [`sorted_indices`] with the sketch stage chunked over `workers` pool
/// threads (the in-repetition parallel path — output is identical for any
/// worker count).
pub fn sorted_indices_par<F: LshFamily + ?Sized>(
    family: &F,
    ds: &Dataset,
    rep: u64,
    workers: usize,
) -> Vec<u32> {
    sorted_indices_par_timed(family, ds, rep, workers, |_, _| {})
}

/// [`sorted_indices_par`] reporting per-chunk busy spans to `busy` for both
/// parallel phases: the sketch chunks and, when the repetition is large
/// enough to clear the radix cutoffs, the pool-parallel radix passes
/// ([`radix::argsort_u64_par_timed`] — identical permutation for any worker
/// count, so granting a big repetition the wave's spare cores never changes
/// its window split). The matrix-sort fallback stays serial on the caller's
/// wall-clock charge.
pub fn sorted_indices_par_timed<F, B>(
    family: &F,
    ds: &Dataset,
    rep: u64,
    workers: usize,
    busy: B,
) -> Vec<u32>
where
    F: LshFamily + ?Sized,
    B: Fn(usize, u64) + Sync,
{
    if let Some(keys) = sketch::packed_sort_keys_par_timed(family, ds, rep, workers, &busy) {
        return radix::argsort_u64_par_timed(&keys, workers, &busy);
    }
    let m = family.sketch_len();
    let symbols = sketch::symbol_matrix_par_timed(family, ds, rep, workers, &busy);
    sort_by_symbol_rows(ds.len(), &symbols, m)
}

/// Compute the lexicographic order of all points under repetition `rep`.
pub fn sorted_order<F: LshFamily + ?Sized>(family: &F, ds: &Dataset, rep: u64) -> SortedOrder {
    sorted_order_par(family, ds, rep, 1)
}

/// [`sorted_order`] with the symbol matrix filled in parallel point chunks.
pub fn sorted_order_par<F: LshFamily + ?Sized>(
    family: &F,
    ds: &Dataset,
    rep: u64,
    workers: usize,
) -> SortedOrder {
    let m = family.sketch_len();
    let symbols = sketch::symbol_matrix_par(family, ds, rep, workers);
    let order = sort_by_symbol_rows(ds.len(), &symbols, m);
    SortedOrder { order, symbols, m }
}

/// Lexicographic index order over symbol rows, ties broken by index.
fn sort_by_symbol_rows(n: usize, symbols: &[u64], m: usize) -> Vec<u32> {
    let mut order: Vec<u32> = (0..n as u32).collect();
    order.sort_unstable_by(|&a, &b| {
        let ra = &symbols[a as usize * m..(a as usize + 1) * m];
        let rb = &symbols[b as usize * m..(b as usize + 1) * m];
        ra.cmp(rb).then(a.cmp(&b))
    });
    order
}

/// Split `n` sorted positions into windows of size ≤ `w`, with the first
/// window's size drawn uniformly from [w/2, w] (the paper's random shift,
/// Stars 2 step 3). Returns ranges over *positions in the sorted order*.
pub fn windows(n: usize, w: usize, rng: &mut Rng) -> Vec<Range<usize>> {
    assert!(w >= 2, "window size must be >= 2");
    if n == 0 {
        return Vec::new();
    }
    let first = rng.range(w / 2, w + 1).min(n);
    let mut out = Vec::with_capacity(n / w + 2);
    out.push(0..first);
    let mut start = first;
    while start < n {
        let end = (start + w).min(n);
        out.push(start..end);
        start = end;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::lsh::SimHash;
    use crate::util::quickcheck::{check, Gen};

    #[test]
    fn order_is_permutation_and_sorted() {
        let ds = synth::gaussian_mixture(200, 16, 8, 0.1, 6);
        let h = SimHash::new(16, 20, 3);
        let so = sorted_order(&h, &ds, 0);
        let mut seen = so.order.clone();
        seen.sort_unstable();
        assert_eq!(seen, (0..200).collect::<Vec<u32>>());
        for k in 1..so.order.len() {
            let (a, b) = (so.order[k - 1], so.order[k]);
            assert!(so.row(a) <= so.row(b), "not sorted at {k}");
        }
    }

    #[test]
    fn similar_points_sort_adjacent() {
        // Duplicate points share all symbols, so they must be adjacent.
        let mut dense = Vec::new();
        let mut rng = Rng::new(1);
        for _ in 0..50 {
            let v: Vec<f32> = (0..8).map(|_| rng.gaussian() as f32).collect();
            dense.extend(&v);
            dense.extend(&v); // duplicate
        }
        let ds = crate::data::Dataset::from_dense("t", 8, dense, vec![]);
        let h = SimHash::new(8, 24, 2);
        let so = sorted_order(&h, &ds, 0);
        for k in 0..so.order.len() {
            let i = so.order[k];
            let twin = if i % 2 == 0 { i + 1 } else { i - 1 };
            let pos_twin = so.order.iter().position(|&x| x == twin).unwrap();
            assert_eq!(
                (k as i64 - pos_twin as i64).abs(),
                1,
                "duplicates {i},{twin} not adjacent"
            );
        }
    }

    #[test]
    fn windows_partition_exactly() {
        check("windows-partition", 60, |g: &mut Gen| {
            let n = g.usize_in(0, 5000);
            let w = g.usize_in(2, 300);
            let mut rng = Rng::new(g.usize_in(0, 1 << 30) as u64);
            let ws = windows(n, w, &mut rng);
            let mut covered = 0;
            let mut prev_end = 0;
            for (k, r) in ws.iter().enumerate() {
                assert_eq!(r.start, prev_end, "gap before window {k}");
                assert!(r.end <= n);
                assert!(r.len() <= w, "window {k} too big: {}", r.len());
                if k == 0 {
                    assert!(r.len() >= (w / 2).min(n), "first window too small");
                }
                covered += r.len();
                prev_end = r.end;
            }
            assert_eq!(covered, n, "windows don't cover all points");
        });
    }

    #[test]
    fn first_window_size_varies_with_rng() {
        let mut sizes = std::collections::HashSet::new();
        for seed in 0..50 {
            let mut rng = Rng::new(seed);
            let ws = windows(10_000, 100, &mut rng);
            sizes.insert(ws[0].len());
        }
        assert!(sizes.len() > 10, "shift not random: {sizes:?}");
    }

    #[test]
    fn packed_fast_path_matches_matrix_sort() {
        // sorted_indices (packed u64 keys) must produce a valid
        // lexicographic order identical to the matrix path up to ties.
        let ds = synth::gaussian_mixture(500, 16, 8, 0.1, 9);
        for bits in [1usize, 7, 30, 64] {
            let h = SimHash::new(16, bits, 4);
            let fast = sorted_indices(&h, &ds, 3);
            let slow = sorted_order(&h, &ds, 3);
            // Both sorts tie-break by index, so the orders must be equal.
            assert_eq!(fast, slow.order, "bits={bits}");
        }
    }

    #[test]
    fn non_binary_families_fall_back() {
        use crate::lsh::WeightedMinHash;
        let ds = synth::zipf_sets(100, &synth::ZipfSetsParams::default(), 2);
        let h = WeightedMinHash::new(3, 5);
        let fast = sorted_indices(&h, &ds, 0);
        let slow = sorted_order(&h, &ds, 0);
        assert_eq!(fast, slow.order);
    }

    #[test]
    fn common_prefix_reflects_similarity() {
        let ds = synth::gaussian_mixture(400, 32, 4, 0.05, 8);
        let h = SimHash::new(32, 30, 5);
        let so = sorted_order(&h, &ds, 0);
        // Average prefix within a mode must exceed across modes.
        let (mut same, mut same_n, mut diff, mut diff_n) = (0usize, 0usize, 0usize, 0usize);
        for i in 0..100u32 {
            for j in (i + 1)..100u32 {
                let p = so.common_prefix(i, j);
                if ds.labels[i as usize] == ds.labels[j as usize] {
                    same += p;
                    same_n += 1;
                } else {
                    diff += p;
                    diff_n += 1;
                }
            }
        }
        let ms = same as f64 / same_n.max(1) as f64;
        let md = diff as f64 / diff_n.max(1) as f64;
        assert!(ms > md + 1.0, "prefixes don't separate modes: {ms} vs {md}");
    }

    use crate::util::rng::Rng;
}
