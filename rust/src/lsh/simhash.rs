//! SimHash (Charikar): random-hyperplane LSH for cosine/angular similarity.
//!
//! `Pr[h(x) = h(y)] = 1 − θ(x,y)/π` per bit. The paper uses sketching
//! dimension M=12 (MNIST), M=16 (Random1B/10B), and M=30 for SortingLSH.
//!
//! The hyperplane matrix depends only on `(seed, rep)`, so it is generated
//! once per repetition into [`SimHash::prepare`]'s state and every batch
//! evaluation runs the tiled multi-plane kernel
//! ([`crate::lsh::sketch::sketch_tile`]) over contiguous row blocks. The
//! tile's plane dots ride the runtime-dispatched lanes of
//! [`crate::util::simd`] (AVX2/NEON where the host has them), and every
//! backend produces bit-identical keys — so a SimHash bucket assignment
//! never depends on the instruction set that computed it.

use crate::data::types::Dataset;
use crate::lsh::family::{LshFamily, SketchState};
use crate::lsh::sketch::{sketch_row_scalar, sketch_tile};
use crate::util::rng::{derive_seed, Rng};

/// Random-hyperplane family over dense features.
#[derive(Clone, Debug)]
pub struct SimHash {
    dim: usize,
    bits: usize,
    seed: u64,
}

impl SimHash {
    /// Family over `dim`-dimensional vectors with `bits` hyperplanes per
    /// sketch (bits ≤ 64 so a sketch packs into one u64 key).
    pub fn new(dim: usize, bits: usize, seed: u64) -> Self {
        assert!(bits >= 1 && bits <= 64, "bits must be in 1..=64");
        assert!(dim >= 1);
        SimHash { dim, bits, seed }
    }

    /// Hyperplanes per sketch (the packed key width).
    pub fn bits(&self) -> usize {
        self.bits
    }

    /// Dense feature dimension the family was built for.
    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Generate the hyperplane matrix for a repetition: `bits × dim`,
    /// row-major. Deterministic in (seed, rep).
    pub fn hyperplanes(&self, rep: u64) -> Vec<f32> {
        let mut out = Vec::with_capacity(self.bits * self.dim);
        for m in 0..self.bits {
            let mut rng = Rng::new(derive_seed(
                self.seed ^ 0x51_4D48, // "SMH"
                rep.wrapping_mul(1_000_003).wrapping_add(m as u64),
            ));
            for _ in 0..self.dim {
                out.push(rng.gaussian() as f32);
            }
        }
        out
    }

    /// Packed sign bits of one row against a precomputed hyperplane matrix
    /// (delegates to the shared one-row kernel — the reduction-order
    /// reference the tiled kernel is parity-tested against, itself
    /// dispatched over the `util::simd` backends).
    #[inline]
    pub fn sketch_row(&self, row: &[f32], planes: &[f32]) -> u64 {
        sketch_row_scalar(planes, self.bits, self.dim, row)
    }
}

/// Per-repetition SimHash state: the cached hyperplane matrix.
struct SimHashState<'a> {
    h: &'a SimHash,
    planes: Vec<f32>,
}

impl SketchState for SimHashState<'_> {
    fn bucket_keys_into(&self, ds: &Dataset, lo: usize, out: &mut [u64]) {
        let d = self.h.dim;
        debug_assert_eq!(ds.dim(), d);
        let rows = &ds.dense[lo * d..(lo + out.len()) * d];
        sketch_tile(&self.planes, self.h.bits, d, rows, out.len(), out);
    }

    fn symbols_into(&self, ds: &Dataset, lo: usize, out: &mut [u64]) {
        let m = self.h.bits;
        let count = out.len() / m;
        debug_assert_eq!(out.len(), count * m);
        let mut keys = vec![0u64; count];
        self.bucket_keys_into(ds, lo, &mut keys);
        for (row, &key) in out.chunks_mut(m).zip(keys.iter()) {
            for (t, o) in row.iter_mut().enumerate() {
                *o = (key >> t) & 1;
            }
        }
    }

    fn packed_sort_keys_into(&self, ds: &Dataset, lo: usize, out: &mut [u64]) {
        self.bucket_keys_into(ds, lo, out);
        // Bit t of a key is symbol t; move symbol 0 to the MSB so integer
        // order equals lexicographic symbol order.
        for k in out.iter_mut() {
            *k = k.reverse_bits() >> (64 - self.h.bits);
        }
    }

    fn table_bytes(&self) -> usize {
        self.planes.len() * std::mem::size_of::<f32>()
    }
}

impl LshFamily for SimHash {
    fn name(&self) -> &'static str {
        "simhash"
    }

    fn sketch_len(&self) -> usize {
        self.bits
    }

    fn prepare<'a>(&'a self, _ds: &Dataset, rep: u64) -> Box<dyn SketchState + 'a> {
        Box::new(SimHashState {
            h: self,
            planes: self.hyperplanes(rep),
        })
    }

    fn supports_packed_sort(&self) -> bool {
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::util::quickcheck::{check, Gen};

    #[test]
    fn deterministic_across_calls() {
        let ds = synth::gaussian_mixture(50, 16, 4, 0.1, 3);
        let h = SimHash::new(16, 12, 7);
        assert_eq!(h.bucket_keys(&ds, 0), h.bucket_keys(&ds, 0));
        assert_ne!(h.bucket_keys(&ds, 0), h.bucket_keys(&ds, 1));
    }

    #[test]
    fn identical_points_always_collide() {
        let mut dense = vec![0.5f32; 16];
        dense.extend_from_slice(&dense.clone());
        let ds = crate::data::Dataset::from_dense("t", 16, dense, vec![]);
        let h = SimHash::new(16, 24, 1);
        for rep in 0..10 {
            let keys = h.bucket_keys(&ds, rep);
            assert_eq!(keys[0], keys[1]);
        }
    }

    #[test]
    fn collision_probability_tracks_angle() {
        // Pr[bit collision] = 1 - theta/pi. Validate empirically over many
        // repetitions for a known angle (90 degrees -> 0.5).
        let dense = vec![1.0, 0.0, 0.0, 1.0]; // orthogonal pair in d=2
        let ds = crate::data::Dataset::from_dense("t", 2, dense, vec![]);
        let h = SimHash::new(2, 1, 99);
        let reps = 4000;
        let mut coll = 0;
        for rep in 0..reps {
            let keys = h.bucket_keys(&ds, rep);
            if keys[0] == keys[1] {
                coll += 1;
            }
        }
        let p = coll as f64 / reps as f64;
        assert!((p - 0.5).abs() < 0.05, "orthogonal collision prob {p}");
    }

    #[test]
    fn closer_pairs_collide_more() {
        check("simhash-monotone", 10, |g: &mut Gen| {
            let d = 16;
            let x = g.unit_vec(d);
            // y_close = x + small noise, y_far = random.
            let mut y_close = x.clone();
            for v in &mut y_close {
                *v += 0.1 * g.f32_in(-1.0, 1.0);
            }
            let y_far = g.unit_vec(d);
            let mut dense = x.clone();
            dense.extend(&y_close);
            dense.extend(&y_far);
            let ds = crate::data::Dataset::from_dense("t", d, dense, vec![]);
            let h = SimHash::new(d, 8, 5);
            let (mut close, mut far) = (0, 0);
            for rep in 0..300 {
                let keys = h.bucket_keys(&ds, rep);
                if keys[0] == keys[1] {
                    close += 1;
                }
                if keys[0] == keys[2] {
                    far += 1;
                }
            }
            assert!(
                close > far,
                "close collided {close} <= far {far}"
            );
        });
    }

    #[test]
    fn symbols_match_bucket_key_bits() {
        let ds = synth::gaussian_mixture(10, 8, 2, 0.1, 4);
        let h = SimHash::new(8, 10, 2);
        let keys = h.bucket_keys(&ds, 3);
        let mat = h.symbol_matrix(&ds, 3);
        for i in 0..ds.len() {
            for t in 0..10 {
                assert_eq!(mat[i * 10 + t], (keys[i] >> t) & 1);
            }
        }
    }

    #[test]
    fn packed_sort_keys_reverse_key_bits() {
        let ds = synth::gaussian_mixture(23, 8, 2, 0.2, 8);
        let h = SimHash::new(8, 10, 6);
        let keys = h.bucket_keys(&ds, 1);
        let packed = h.packed_sort_keys(&ds, 1).unwrap();
        for i in 0..ds.len() {
            assert_eq!(packed[i], keys[i].reverse_bits() >> (64 - 10));
        }
    }
}
