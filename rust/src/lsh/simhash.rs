//! SimHash (Charikar): random-hyperplane LSH for cosine/angular similarity.
//!
//! `Pr[h(x) = h(y)] = 1 − θ(x,y)/π` per bit. The paper uses sketching
//! dimension M=12 (MNIST), M=16 (Random1B/10B), and M=30 for SortingLSH.

use crate::data::types::Dataset;
use crate::lsh::family::LshFamily;
use crate::util::rng::{derive_seed, Rng};

/// Random-hyperplane family over dense features.
#[derive(Clone, Debug)]
pub struct SimHash {
    dim: usize,
    bits: usize,
    seed: u64,
}

impl SimHash {
    /// Family over `dim`-dimensional vectors with `bits` hyperplanes per
    /// sketch (bits ≤ 64 so a sketch packs into one u64 key).
    pub fn new(dim: usize, bits: usize, seed: u64) -> Self {
        assert!(bits >= 1 && bits <= 64, "bits must be in 1..=64");
        assert!(dim >= 1);
        SimHash { dim, bits, seed }
    }

    /// Generate the hyperplane matrix for a repetition: `bits × dim`,
    /// row-major. Deterministic in (seed, rep).
    pub fn hyperplanes(&self, rep: u64) -> Vec<f32> {
        let mut out = Vec::with_capacity(self.bits * self.dim);
        for m in 0..self.bits {
            let mut rng = Rng::new(derive_seed(
                self.seed ^ 0x51_4D48, // "SMH"
                rep.wrapping_mul(1_000_003).wrapping_add(m as u64),
            ));
            for _ in 0..self.dim {
                out.push(rng.gaussian() as f32);
            }
        }
        out
    }

    /// Packed sign bits of one row against a precomputed hyperplane matrix.
    ///
    /// Perf: processes hyperplanes in pairs with 4-way unrolled
    /// multiply-accumulate lanes so the autovectorizer emits wide FMAs and
    /// the row stays hot in L1 across both planes (see EXPERIMENTS.md §Perf).
    #[inline]
    pub fn sketch_row(&self, row: &[f32], planes: &[f32]) -> u64 {
        debug_assert_eq!(row.len(), self.dim);
        let d = self.dim;
        let mut key = 0u64;
        let mut m = 0;
        while m + 2 <= self.bits {
            let p0 = &planes[m * d..(m + 1) * d];
            let p1 = &planes[(m + 1) * d..(m + 2) * d];
            let (mut a0, mut a1, mut a2, mut a3) = (0f32, 0f32, 0f32, 0f32);
            let (mut b0, mut b1, mut b2, mut b3) = (0f32, 0f32, 0f32, 0f32);
            let chunks = d / 4;
            for c in 0..chunks {
                let k = c * 4;
                a0 += row[k] * p0[k];
                a1 += row[k + 1] * p0[k + 1];
                a2 += row[k + 2] * p0[k + 2];
                a3 += row[k + 3] * p0[k + 3];
                b0 += row[k] * p1[k];
                b1 += row[k + 1] * p1[k + 1];
                b2 += row[k + 2] * p1[k + 2];
                b3 += row[k + 3] * p1[k + 3];
            }
            let (mut da, mut db) = (a0 + a1 + a2 + a3, b0 + b1 + b2 + b3);
            for k in chunks * 4..d {
                da += row[k] * p0[k];
                db += row[k] * p1[k];
            }
            if da >= 0.0 {
                key |= 1 << m;
            }
            if db >= 0.0 {
                key |= 1 << (m + 1);
            }
            m += 2;
        }
        if m < self.bits {
            let plane = &planes[m * d..(m + 1) * d];
            let mut dot = 0f32;
            for k in 0..d {
                dot += row[k] * plane[k];
            }
            if dot >= 0.0 {
                key |= 1 << m;
            }
        }
        key
    }

    /// Packed sort keys for SortingLSH: the M sign bits stored MSB-first so
    /// integer order == lexicographic symbol order. Fast path used by
    /// [`crate::lsh::sorting::sorted_indices`].
    pub fn packed_sort_keys(&self, ds: &Dataset, rep: u64) -> Vec<u64> {
        let planes = self.hyperplanes(rep);
        (0..ds.len())
            .map(|i| {
                let key = self.sketch_row(ds.row(i), &planes);
                // bit t of key is symbol t; move symbol 0 to the MSB.
                key.reverse_bits() >> (64 - self.bits)
            })
            .collect()
    }
}

impl LshFamily for SimHash {
    fn name(&self) -> &'static str {
        "simhash"
    }

    fn sketch_len(&self) -> usize {
        self.bits
    }

    fn symbols(&self, ds: &Dataset, i: usize, rep: u64, out: &mut [u64]) {
        let planes = self.hyperplanes(rep);
        let key = self.sketch_row(ds.row(i), &planes);
        for (m, o) in out.iter_mut().enumerate() {
            *o = (key >> m) & 1;
        }
    }

    fn bucket_keys(&self, ds: &Dataset, rep: u64) -> Vec<u64> {
        let planes = self.hyperplanes(rep);
        (0..ds.len())
            .map(|i| self.sketch_row(ds.row(i), &planes))
            .collect()
    }

    fn symbol_matrix(&self, ds: &Dataset, rep: u64) -> Vec<u64> {
        let planes = self.hyperplanes(rep);
        let m = self.bits;
        let mut out = vec![0u64; ds.len() * m];
        for i in 0..ds.len() {
            let key = self.sketch_row(ds.row(i), &planes);
            for t in 0..m {
                out[i * m + t] = (key >> t) & 1;
            }
        }
        out
    }

    fn packed_sort_keys(&self, ds: &Dataset, rep: u64) -> Option<Vec<u64>> {
        Some(SimHash::packed_sort_keys(self, ds, rep))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::util::quickcheck::{check, Gen};

    #[test]
    fn deterministic_across_calls() {
        let ds = synth::gaussian_mixture(50, 16, 4, 0.1, 3);
        let h = SimHash::new(16, 12, 7);
        assert_eq!(h.bucket_keys(&ds, 0), h.bucket_keys(&ds, 0));
        assert_ne!(h.bucket_keys(&ds, 0), h.bucket_keys(&ds, 1));
    }

    #[test]
    fn identical_points_always_collide() {
        let mut dense = vec![0.5f32; 16];
        dense.extend_from_slice(&dense.clone());
        let ds = crate::data::Dataset::from_dense("t", 16, dense, vec![]);
        let h = SimHash::new(16, 24, 1);
        for rep in 0..10 {
            let keys = h.bucket_keys(&ds, rep);
            assert_eq!(keys[0], keys[1]);
        }
    }

    #[test]
    fn collision_probability_tracks_angle() {
        // Pr[bit collision] = 1 - theta/pi. Validate empirically over many
        // repetitions for a known angle (90 degrees -> 0.5).
        let dense = vec![1.0, 0.0, 0.0, 1.0]; // orthogonal pair in d=2
        let ds = crate::data::Dataset::from_dense("t", 2, dense, vec![]);
        let h = SimHash::new(2, 1, 99);
        let reps = 4000;
        let mut coll = 0;
        for rep in 0..reps {
            let keys = h.bucket_keys(&ds, rep);
            if keys[0] == keys[1] {
                coll += 1;
            }
        }
        let p = coll as f64 / reps as f64;
        assert!((p - 0.5).abs() < 0.05, "orthogonal collision prob {p}");
    }

    #[test]
    fn closer_pairs_collide_more() {
        check("simhash-monotone", 10, |g: &mut Gen| {
            let d = 16;
            let x = g.unit_vec(d);
            // y_close = x + small noise, y_far = random.
            let mut y_close = x.clone();
            for v in &mut y_close {
                *v += 0.1 * g.f32_in(-1.0, 1.0);
            }
            let y_far = g.unit_vec(d);
            let mut dense = x.clone();
            dense.extend(&y_close);
            dense.extend(&y_far);
            let ds = crate::data::Dataset::from_dense("t", d, dense, vec![]);
            let h = SimHash::new(d, 8, 5);
            let (mut close, mut far) = (0, 0);
            for rep in 0..300 {
                let keys = h.bucket_keys(&ds, rep);
                if keys[0] == keys[1] {
                    close += 1;
                }
                if keys[0] == keys[2] {
                    far += 1;
                }
            }
            assert!(
                close > far,
                "close collided {close} <= far {far}"
            );
        });
    }

    #[test]
    fn symbols_match_bucket_key_bits() {
        let ds = synth::gaussian_mixture(10, 8, 2, 0.1, 4);
        let h = SimHash::new(8, 10, 2);
        let keys = h.bucket_keys(&ds, 3);
        let mat = h.symbol_matrix(&ds, 3);
        for i in 0..ds.len() {
            for t in 0..10 {
                assert_eq!(mat[i * 10 + t], (keys[i] >> t) & 1);
            }
        }
    }
}
