//! MinHash (Broder): LSH for Jaccard similarity over token sets.
//!
//! `Pr[h(A) = h(B)] = |A∩B| / |A∪B|` exactly, per base hash.

use crate::data::types::Dataset;
use crate::lsh::family::{combine_symbols, LshFamily, SketchState};
use crate::util::fxhash;
use crate::util::rng::SplitMix64;

/// MinHash family over (unweighted) token sets.
#[derive(Clone, Debug)]
pub struct MinHash {
    perms: usize,
    seed: u64,
}

impl MinHash {
    /// Family with `perms` independent min-wise hashes per sketch.
    pub fn new(perms: usize, seed: u64) -> Self {
        assert!(perms >= 1);
        MinHash { perms, seed }
    }

    /// The t-th permutation value of `token` under repetition `rep`:
    /// a stateless mix of (token, rep, t, seed).
    #[inline]
    pub fn perm_value(&self, token: u32, rep: u64, t: usize) -> u64 {
        // One SplitMix64 step keyed by (token, rep, t): statistically a fresh
        // random permutation per (rep, t).
        let key = fxhash::combine(
            self.seed ^ 0x4D49_4E48, // "MINH"
            (rep << 20) ^ (t as u64) << 40 ^ token as u64,
        );
        SplitMix64::new(key).next_u64()
    }

    /// Min-wise symbol of one set for (rep, t).
    #[inline]
    pub fn symbol_of_set(&self, tokens: &[u32], rep: u64, t: usize) -> u64 {
        tokens
            .iter()
            .map(|&tok| self.perm_value(tok, rep, t))
            .min()
            .unwrap_or(u64::MAX)
    }
}

/// Per-repetition MinHash state. The permutations are stateless mixes of
/// `(token, rep, t)`, so there is nothing to cache — the state's value is
/// the range-batched evaluation (one symbol buffer reused across a whole
/// chunk instead of a per-point allocation in the generic path).
struct MinHashState<'a> {
    h: &'a MinHash,
    rep: u64,
}

impl SketchState for MinHashState<'_> {
    fn bucket_keys_into(&self, ds: &Dataset, lo: usize, out: &mut [u64]) {
        let mut buf = vec![0u64; self.h.perms];
        for (k, key) in out.iter_mut().enumerate() {
            let tokens = &ds.set(lo + k).tokens;
            for (t, b) in buf.iter_mut().enumerate() {
                *b = self.h.symbol_of_set(tokens, self.rep, t);
            }
            *key = combine_symbols(&buf);
        }
    }

    fn symbols_into(&self, ds: &Dataset, lo: usize, out: &mut [u64]) {
        let m = self.h.perms;
        for (k, row) in out.chunks_mut(m).enumerate() {
            let tokens = &ds.set(lo + k).tokens;
            for (t, o) in row.iter_mut().enumerate() {
                *o = self.h.symbol_of_set(tokens, self.rep, t);
            }
        }
    }
}

impl LshFamily for MinHash {
    fn name(&self) -> &'static str {
        "minhash"
    }

    fn sketch_len(&self) -> usize {
        self.perms
    }

    fn prepare<'a>(&'a self, _ds: &Dataset, rep: u64) -> Box<dyn SketchState + 'a> {
        Box::new(MinHashState { h: self, rep })
    }

    fn symbols(&self, ds: &Dataset, i: usize, rep: u64, out: &mut [u64]) {
        let tokens = &ds.set(i).tokens;
        for (t, o) in out.iter_mut().enumerate() {
            *o = self.symbol_of_set(tokens, rep, t);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::types::{Dataset, WeightedSet};
    use crate::sim::jaccard;

    fn two_set_ds(a: Vec<u32>, b: Vec<u32>) -> Dataset {
        Dataset::from_sets(
            "t",
            vec![WeightedSet::from_tokens(a), WeightedSet::from_tokens(b)],
            vec![],
        )
    }

    #[test]
    fn identical_sets_always_collide() {
        let ds = two_set_ds(vec![1, 5, 9], vec![1, 5, 9]);
        let h = MinHash::new(4, 3);
        for rep in 0..20 {
            assert_eq!(h.bucket_key(&ds, 0, rep), h.bucket_key(&ds, 1, rep));
        }
    }

    #[test]
    fn disjoint_sets_rarely_collide() {
        let ds = two_set_ds((0..50).collect(), (100..150).collect());
        let h = MinHash::new(1, 3);
        let mut coll = 0;
        for rep in 0..500 {
            if h.bucket_key(&ds, 0, rep) == h.bucket_key(&ds, 1, rep) {
                coll += 1;
            }
        }
        assert!(coll < 10, "disjoint sets collided {coll}/500");
    }

    #[test]
    fn collision_rate_estimates_jaccard() {
        // |A∩B|=5, |A∪B|=15 -> J = 1/3 per base hash.
        let a: Vec<u32> = (0..10).collect();
        let b: Vec<u32> = (5..15).collect();
        let ds = two_set_ds(a.clone(), b.clone());
        let j = jaccard(ds.set(0), ds.set(1));
        assert!((j - 1.0 / 3.0).abs() < 1e-6);
        let h = MinHash::new(1, 7);
        let reps = 6000;
        let mut coll = 0;
        for rep in 0..reps {
            if h.symbol_of_set(&ds.set(0).tokens, rep, 0)
                == h.symbol_of_set(&ds.set(1).tokens, rep, 0)
            {
                coll += 1;
            }
        }
        let p = coll as f64 / reps as f64;
        assert!((p - j as f64).abs() < 0.03, "estimate {p} vs jaccard {j}");
    }

    #[test]
    fn empty_set_symbol_is_sentinel() {
        let h = MinHash::new(2, 1);
        assert_eq!(h.symbol_of_set(&[], 0, 0), u64::MAX);
    }
}
