//! MinHash (Broder): LSH for Jaccard similarity over token sets.
//!
//! `Pr[h(A) = h(B)] = |A∩B| / |A∪B|` exactly, per base hash.

use crate::data::types::{Dataset, TokenVocab};
use crate::lsh::family::{combine_symbols, LshFamily, SketchState};
use crate::util::fxhash;
use crate::util::rng::SplitMix64;
use std::sync::Arc;

/// Cap on cached permutation entries (distinct tokens × perms), matching
/// the CWS cache bound: past it the state falls back to on-the-fly mixing
/// so a pathological token universe cannot blow up per-repetition memory.
const MINHASH_CACHE_MAX_ENTRIES: usize = 1 << 21;

/// MinHash family over (unweighted) token sets.
#[derive(Clone, Debug)]
pub struct MinHash {
    perms: usize,
    seed: u64,
}

impl MinHash {
    /// Family with `perms` independent min-wise hashes per sketch.
    pub fn new(perms: usize, seed: u64) -> Self {
        assert!(perms >= 1);
        MinHash { perms, seed }
    }

    /// The t-th permutation value of `token` under repetition `rep`:
    /// a stateless mix of (token, rep, t, seed).
    #[inline]
    pub fn perm_value(&self, token: u32, rep: u64, t: usize) -> u64 {
        // One SplitMix64 step keyed by (token, rep, t): statistically a fresh
        // random permutation per (rep, t).
        let key = fxhash::combine(
            self.seed ^ 0x4D49_4E48, // "MINH"
            (rep << 20) ^ (t as u64) << 40 ^ token as u64,
        );
        SplitMix64::new(key).next_u64()
    }

    /// Min-wise symbol of one set for (rep, t).
    #[inline]
    pub fn symbol_of_set(&self, tokens: &[u32], rep: u64, t: usize) -> u64 {
        tokens
            .iter()
            .map(|&tok| self.perm_value(tok, rep, t))
            .min()
            .unwrap_or(u64::MAX)
    }
}

/// Per-repetition MinHash state: the per-(distinct token, t) permutation
/// table, keyed by the dataset's shared [`TokenVocab`] slots.
///
/// The permutations are stateless mixes of `(token, rep, t)`, but the seed
/// path re-ran the mix for every *occurrence* of a token (every point ×
/// every permutation). With the table, a repetition pays |vocab|·M mixes up
/// front and each occurrence is one indexed load. Tokens outside the vocab
/// (query points on the serving path, or an over-cap universe) fall back to
/// the on-the-fly mix; table entries hold the exact values
/// [`MinHash::perm_value`] computes, so symbols are bit-identical either way.
struct MinHashState<'a> {
    h: &'a MinHash,
    rep: u64,
    /// The prepare-time token universe; `None` when caching is disabled
    /// (overflowed vocab or over-cap table).
    vocab: Option<Arc<TokenVocab>>,
    /// `table[slot * perms + t]` = perm_value(token_of(slot), rep, t).
    table: Vec<u64>,
}

impl<'a> MinHashState<'a> {
    fn new(h: &'a MinHash, ds: &Dataset, rep: u64) -> Self {
        let vocab = ds.token_vocab();
        if vocab.overflow() || vocab.len() * h.perms > MINHASH_CACHE_MAX_ENTRIES {
            return MinHashState {
                h,
                rep,
                vocab: None,
                table: Vec::new(),
            };
        }
        let mut table = vec![0u64; vocab.len() * h.perms];
        for (tok, slot) in vocab.iter() {
            let base = slot as usize * h.perms;
            for (t, v) in table[base..base + h.perms].iter_mut().enumerate() {
                *v = h.perm_value(tok, rep, t);
            }
        }
        MinHashState {
            h,
            rep,
            vocab: Some(Arc::clone(vocab)),
            table,
        }
    }

    /// Fill `best` (one min slot per permutation) for a token list.
    fn point_min(&self, tokens: &[u32], best: &mut [u64]) {
        best.fill(u64::MAX);
        let m = self.h.perms;
        for &tok in tokens {
            match self.vocab.as_ref().and_then(|v| v.slot(tok)) {
                Some(slot) => {
                    let vals = &self.table[slot as usize * m..(slot as usize + 1) * m];
                    for (b, &v) in best.iter_mut().zip(vals.iter()) {
                        if v < *b {
                            *b = v;
                        }
                    }
                }
                None => {
                    for (t, b) in best.iter_mut().enumerate() {
                        let v = self.h.perm_value(tok, self.rep, t);
                        if v < *b {
                            *b = v;
                        }
                    }
                }
            }
        }
    }
}

impl SketchState for MinHashState<'_> {
    fn bucket_keys_into(&self, ds: &Dataset, lo: usize, out: &mut [u64]) {
        let mut buf = vec![0u64; self.h.perms];
        for (k, key) in out.iter_mut().enumerate() {
            self.point_min(&ds.set(lo + k).tokens, &mut buf);
            *key = combine_symbols(&buf);
        }
    }

    fn symbols_into(&self, ds: &Dataset, lo: usize, out: &mut [u64]) {
        let m = self.h.perms;
        for (k, row) in out.chunks_mut(m).enumerate() {
            self.point_min(&ds.set(lo + k).tokens, row);
        }
    }

    fn table_bytes(&self) -> usize {
        self.table.len() * std::mem::size_of::<u64>()
    }
}

impl LshFamily for MinHash {
    fn name(&self) -> &'static str {
        "minhash"
    }

    fn sketch_len(&self) -> usize {
        self.perms
    }

    fn prepare<'a>(&'a self, ds: &Dataset, rep: u64) -> Box<dyn SketchState + 'a> {
        Box::new(MinHashState::new(self, ds, rep))
    }

    fn symbols(&self, ds: &Dataset, i: usize, rep: u64, out: &mut [u64]) {
        let tokens = &ds.set(i).tokens;
        for (t, o) in out.iter_mut().enumerate() {
            *o = self.symbol_of_set(tokens, rep, t);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::types::{Dataset, WeightedSet};
    use crate::sim::jaccard;

    fn two_set_ds(a: Vec<u32>, b: Vec<u32>) -> Dataset {
        Dataset::from_sets(
            "t",
            vec![WeightedSet::from_tokens(a), WeightedSet::from_tokens(b)],
            vec![],
        )
    }

    #[test]
    fn identical_sets_always_collide() {
        let ds = two_set_ds(vec![1, 5, 9], vec![1, 5, 9]);
        let h = MinHash::new(4, 3);
        for rep in 0..20 {
            assert_eq!(h.bucket_key(&ds, 0, rep), h.bucket_key(&ds, 1, rep));
        }
    }

    #[test]
    fn disjoint_sets_rarely_collide() {
        let ds = two_set_ds((0..50).collect(), (100..150).collect());
        let h = MinHash::new(1, 3);
        let mut coll = 0;
        for rep in 0..500 {
            if h.bucket_key(&ds, 0, rep) == h.bucket_key(&ds, 1, rep) {
                coll += 1;
            }
        }
        assert!(coll < 10, "disjoint sets collided {coll}/500");
    }

    #[test]
    fn collision_rate_estimates_jaccard() {
        // |A∩B|=5, |A∪B|=15 -> J = 1/3 per base hash.
        let a: Vec<u32> = (0..10).collect();
        let b: Vec<u32> = (5..15).collect();
        let ds = two_set_ds(a.clone(), b.clone());
        let j = jaccard(ds.set(0), ds.set(1));
        assert!((j - 1.0 / 3.0).abs() < 1e-6);
        let h = MinHash::new(1, 7);
        let reps = 6000;
        let mut coll = 0;
        for rep in 0..reps {
            if h.symbol_of_set(&ds.set(0).tokens, rep, 0)
                == h.symbol_of_set(&ds.set(1).tokens, rep, 0)
            {
                coll += 1;
            }
        }
        let p = coll as f64 / reps as f64;
        assert!((p - j as f64).abs() < 0.03, "estimate {p} vs jaccard {j}");
    }

    #[test]
    fn empty_set_symbol_is_sentinel() {
        let h = MinHash::new(2, 1);
        assert_eq!(h.symbol_of_set(&[], 0, 0), u64::MAX);
    }

    #[test]
    fn cached_state_matches_per_point_path() {
        let ds = crate::data::synth::zipf_sets(
            150,
            &crate::data::synth::ZipfSetsParams::default(),
            19,
        );
        let h = MinHash::new(4, 21);
        for rep in [0u64, 5] {
            let batch = h.bucket_keys(&ds, rep);
            for i in 0..ds.len() {
                assert_eq!(batch[i], h.bucket_key(&ds, i, rep), "point {i} rep {rep}");
            }
            let mat = h.symbol_matrix(&ds, rep);
            let mut buf = vec![0u64; 4];
            for i in 0..ds.len() {
                h.symbols(&ds, i, rep, &mut buf);
                assert_eq!(&mat[i * 4..(i + 1) * 4], &buf[..], "point {i} rep {rep}");
            }
        }
    }

    #[test]
    fn state_falls_back_for_out_of_vocab_tokens() {
        // Prepare against one dataset, evaluate another whose tokens the
        // table has never seen (the serving query path): symbols must match
        // the stateless per-point mix exactly.
        let index_ds = two_set_ds(vec![1, 2, 3], vec![2, 3, 4]);
        let query_ds = two_set_ds(vec![900, 901], vec![1, 900]);
        let h = MinHash::new(3, 8);
        let state = h.prepare(&index_ds, 2);
        let mut keys = vec![0u64; 2];
        state.bucket_keys_into(&query_ds, 0, &mut keys);
        for i in 0..2 {
            assert_eq!(keys[i], h.bucket_key(&query_ds, i, 2), "query {i}");
        }
    }
}
