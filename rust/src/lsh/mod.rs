//! Locality sensitive hash families.
//!
//! A family produces, per repetition, either a **bucket key** per point (the
//! concatenation of its M base hashes — classic LSH bucketing, Stars 1) or a
//! **symbol sequence** per point (the M base hashes kept separate so points
//! can be sorted lexicographically — SortingLSH, Stars 2).
//!
//! Evaluation is two-phase: [`LshFamily::prepare`] captures everything a
//! repetition can cache (hyperplane matrices, component coins, per-token CWS
//! tables) into a [`SketchState`], and the [`sketch`] drivers batch-evaluate
//! point ranges against it — serially or chunked over the worker pool.
//!
//! Families implemented (matching the paper's Appendix D.2 setups):
//! * [`SimHash`] — random hyperplanes, for cosine/angular similarity.
//! * [`MinHash`] — for (unweighted) Jaccard.
//! * [`WeightedMinHash`] — Ioffe consistent weighted sampling, for weighted
//!   Jaccard (the Wikipedia measure).
//! * [`MixtureHash`] — per-symbol random choice of SimHash or MinHash (the
//!   Amazon2m family; satisfies Definition 2.1 for the mixture similarity).

mod family;
mod simhash;
mod minhash;
mod weighted_minhash;
mod mixture;
pub mod sketch;
pub mod sorting;

pub use family::{combine_symbols, LshFamily, SketchState};
pub use minhash::MinHash;
pub use mixture::MixtureHash;
pub use simhash::SimHash;
pub use sorting::{
    sorted_indices, sorted_indices_par, sorted_order, sorted_order_par, windows, SortedOrder,
};
pub use weighted_minhash::WeightedMinHash;
