//! Similarity functions and the [`Similarity`] trait.

use super::batch;
use crate::data::types::{Dataset, WeightedSet};
use std::sync::atomic::{AtomicU64, Ordering};

/// Shared cosine normalization: a dot product over a product of L2 norms.
/// Single definition used by the free function, the scalar trait impl and
/// the tiled batch kernels, so the three paths cannot drift.
#[inline]
pub(crate) fn cosine_from_parts(d: f32, norm_prod: f32) -> f32 {
    if norm_prod <= f32::MIN_POSITIVE {
        0.0
    } else {
        (d / norm_prod).clamp(-1.0, 1.0)
    }
}

/// L2 norm, via the same unrolled kernel as [`dot`].
#[inline]
pub fn l2_norm(a: &[f32]) -> f32 {
    dot(a, a).sqrt()
}

/// Cosine similarity of two dense vectors (norms computed on the fly; the
/// dataset path [`CosineSim`] reads them from [`Dataset::norms`] instead).
#[inline]
pub fn cosine(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    cosine_from_parts(dot(a, b), l2_norm(a) * l2_norm(b))
}

/// Dot product of two dense vectors.
///
/// Perf: runs on the runtime-dispatched 8-lane kernel of
/// [`crate::util::simd`] — explicit AVX2/NEON lanes where the host has
/// them, the blocked-scalar reference otherwise. Every backend reduces in
/// the historical order (8 lanes, pairwise tree, sequential tail), so the
/// result is bit-identical across backends and to the pre-dispatch kernel
/// (EXPERIMENTS.md §Perf, `tests/simd_parity.rs`).
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    crate::util::simd::dot(a, b)
}

/// Unweighted Jaccard similarity |A∩B| / |A∪B| over token sets.
pub fn jaccard(a: &WeightedSet, b: &WeightedSet) -> f32 {
    if a.is_empty() && b.is_empty() {
        return 0.0;
    }
    let (mut i, mut j, mut inter) = (0usize, 0usize, 0usize);
    while i < a.tokens.len() && j < b.tokens.len() {
        match a.tokens[i].cmp(&b.tokens[j]) {
            std::cmp::Ordering::Less => i += 1,
            std::cmp::Ordering::Greater => j += 1,
            std::cmp::Ordering::Equal => {
                inter += 1;
                i += 1;
                j += 1;
            }
        }
    }
    let union = a.tokens.len() + b.tokens.len() - inter;
    inter as f32 / union as f32
}

/// Weighted Jaccard similarity: Σ min(x_i, y_i) / Σ max(x_i, y_i).
pub fn weighted_jaccard(a: &WeightedSet, b: &WeightedSet) -> f32 {
    if a.is_empty() && b.is_empty() {
        return 0.0;
    }
    let (mut i, mut j) = (0usize, 0usize);
    let (mut num, mut den) = (0f32, 0f32);
    while i < a.tokens.len() && j < b.tokens.len() {
        match a.tokens[i].cmp(&b.tokens[j]) {
            std::cmp::Ordering::Less => {
                den += a.weights[i];
                i += 1;
            }
            std::cmp::Ordering::Greater => {
                den += b.weights[j];
                j += 1;
            }
            std::cmp::Ordering::Equal => {
                num += a.weights[i].min(b.weights[j]);
                den += a.weights[i].max(b.weights[j]);
                i += 1;
                j += 1;
            }
        }
    }
    // Suffix weights fold through the dispatched 4-lane accumulate helper
    // (one blocked reassociation vs the old sequential sum, identical on
    // every backend).
    den += crate::util::simd::sum_f32(&a.weights[i..]);
    den += crate::util::simd::sum_f32(&b.weights[j..]);
    if den <= 0.0 {
        0.0
    } else {
        num / den
    }
}

/// A pairwise similarity measure over a dataset.
///
/// Implementations must be `Sync`: the scoring phase fans out over worker
/// threads. The batch entry point exists because expensive measures (the
/// learned model running via PJRT) amortize dispatch over many candidates.
pub trait Similarity: Sync {
    /// Similarity of points `i` and `j`.
    fn sim(&self, ds: &Dataset, i: usize, j: usize) -> f32;

    /// Score one leader against many candidates. Default loops over [`Similarity::sim`].
    fn sim_batch(&self, ds: &Dataset, leader: usize, candidates: &[u32], out: &mut Vec<f32>) {
        out.clear();
        out.extend(candidates.iter().map(|&c| self.sim(ds, leader, c as usize)));
    }

    /// Display name used in reports.
    fn name(&self) -> &'static str;

    /// Relative evaluation cost (1.0 = cheap vector op). Used only for
    /// reporting; actual timings are measured, not modeled.
    fn cost_hint(&self) -> f64 {
        1.0
    }
}

/// Cosine similarity over dense rows (uses precomputed norms).
#[derive(Clone, Copy, Debug, Default)]
pub struct CosineSim;

impl Similarity for CosineSim {
    #[inline]
    fn sim(&self, ds: &Dataset, i: usize, j: usize) -> f32 {
        cosine_from_parts(dot(ds.row(i), ds.row(j)), ds.norm(i) * ds.norm(j))
    }

    fn sim_batch(&self, ds: &Dataset, leader: usize, candidates: &[u32], out: &mut Vec<f32>) {
        batch::with_scratch(|s| s.cosine(ds, leader, candidates, out));
    }

    fn name(&self) -> &'static str {
        "cosine"
    }
}

/// Dot-product similarity over dense rows.
#[derive(Clone, Copy, Debug, Default)]
pub struct DotSim;

impl Similarity for DotSim {
    #[inline]
    fn sim(&self, ds: &Dataset, i: usize, j: usize) -> f32 {
        dot(ds.row(i), ds.row(j))
    }

    fn sim_batch(&self, ds: &Dataset, leader: usize, candidates: &[u32], out: &mut Vec<f32>) {
        batch::with_scratch(|s| s.dot(ds, leader, candidates, out));
    }

    fn name(&self) -> &'static str {
        "dot"
    }
}

/// Unweighted Jaccard over token sets.
#[derive(Clone, Copy, Debug, Default)]
pub struct JaccardSim;

impl Similarity for JaccardSim {
    #[inline]
    fn sim(&self, ds: &Dataset, i: usize, j: usize) -> f32 {
        jaccard(ds.set(i), ds.set(j))
    }

    fn sim_batch(&self, ds: &Dataset, leader: usize, candidates: &[u32], out: &mut Vec<f32>) {
        batch::with_scratch(|s| s.jaccard(ds, leader, candidates, out));
    }

    fn name(&self) -> &'static str {
        "jaccard"
    }
}

/// Weighted Jaccard over weighted token sets (the Wikipedia measure).
#[derive(Clone, Copy, Debug, Default)]
pub struct WeightedJaccardSim;

impl Similarity for WeightedJaccardSim {
    #[inline]
    fn sim(&self, ds: &Dataset, i: usize, j: usize) -> f32 {
        weighted_jaccard(ds.set(i), ds.set(j))
    }

    fn sim_batch(&self, ds: &Dataset, leader: usize, candidates: &[u32], out: &mut Vec<f32>) {
        batch::with_scratch(|s| s.weighted_jaccard(ds, leader, candidates, out));
    }

    fn name(&self) -> &'static str {
        "weighted-jaccard"
    }
}

/// The Amazon2m "mixture" measure: α·cosine(embeddings) + (1-α)·jaccard(sets).
#[derive(Clone, Copy, Debug)]
pub struct MixtureSim {
    /// Weight on the cosine component.
    pub alpha: f32,
}

impl Default for MixtureSim {
    fn default() -> Self {
        MixtureSim { alpha: 0.5 }
    }
}

impl Similarity for MixtureSim {
    #[inline]
    fn sim(&self, ds: &Dataset, i: usize, j: usize) -> f32 {
        let c = CosineSim.sim(ds, i, j);
        let jac = jaccard(ds.set(i), ds.set(j));
        self.alpha * c + (1.0 - self.alpha) * jac
    }

    fn sim_batch(&self, ds: &Dataset, leader: usize, candidates: &[u32], out: &mut Vec<f32>) {
        batch::with_scratch(|s| s.mixture(self.alpha, ds, leader, candidates, out));
    }

    fn name(&self) -> &'static str {
        "mixture"
    }

    fn cost_hint(&self) -> f64 {
        1.5
    }
}

/// Wraps any measure with an atomic counter of similarity evaluations —
/// the paper's "number of comparisons" (Figure 1).
pub struct CountingSim<S> {
    inner: S,
    count: AtomicU64,
}

impl<S: Similarity> CountingSim<S> {
    /// Wrap a measure.
    pub fn new(inner: S) -> Self {
        CountingSim {
            inner,
            count: AtomicU64::new(0),
        }
    }

    /// Comparisons evaluated so far.
    pub fn comparisons(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Reset the counter.
    pub fn reset(&self) {
        self.count.store(0, Ordering::Relaxed);
    }

    /// Access the wrapped measure.
    pub fn inner(&self) -> &S {
        &self.inner
    }
}

impl<S: Similarity> Similarity for CountingSim<S> {
    #[inline]
    fn sim(&self, ds: &Dataset, i: usize, j: usize) -> f32 {
        self.count.fetch_add(1, Ordering::Relaxed);
        self.inner.sim(ds, i, j)
    }

    fn sim_batch(&self, ds: &Dataset, leader: usize, candidates: &[u32], out: &mut Vec<f32>) {
        self.count
            .fetch_add(candidates.len() as u64, Ordering::Relaxed);
        self.inner.sim_batch(ds, leader, candidates, out);
    }

    fn name(&self) -> &'static str {
        self.inner.name()
    }

    fn cost_hint(&self) -> f64 {
        self.inner.cost_hint()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::util::quickcheck::{check, Gen};

    fn set(pairs: &[(u32, f32)]) -> WeightedSet {
        WeightedSet::from_pairs(pairs.to_vec())
    }

    #[test]
    fn cosine_basic() {
        assert!((cosine(&[1.0, 0.0], &[1.0, 0.0]) - 1.0).abs() < 1e-6);
        assert!(cosine(&[1.0, 0.0], &[0.0, 1.0]).abs() < 1e-6);
        assert!((cosine(&[1.0, 0.0], &[-1.0, 0.0]) + 1.0).abs() < 1e-6);
        assert_eq!(cosine(&[0.0, 0.0], &[1.0, 0.0]), 0.0);
    }

    #[test]
    fn jaccard_basic() {
        let a = set(&[(1, 1.0), (2, 1.0), (3, 1.0)]);
        let b = set(&[(2, 1.0), (3, 1.0), (4, 1.0)]);
        assert!((jaccard(&a, &b) - 0.5).abs() < 1e-6);
        assert!((jaccard(&a, &a) - 1.0).abs() < 1e-6);
        assert_eq!(jaccard(&set(&[]), &set(&[])), 0.0);
        assert_eq!(jaccard(&a, &set(&[])), 0.0);
    }

    #[test]
    fn weighted_jaccard_basic() {
        let a = set(&[(1, 2.0), (2, 1.0)]);
        let b = set(&[(1, 1.0), (3, 1.0)]);
        // min sum = 1, max sum = 2 + 1 + 1 = 4.
        assert!((weighted_jaccard(&a, &b) - 0.25).abs() < 1e-6);
        assert!((weighted_jaccard(&a, &a) - 1.0).abs() < 1e-6);
    }

    #[test]
    fn weighted_jaccard_reduces_to_jaccard_on_unit_weights() {
        check("wj-eq-j", 60, |g: &mut Gen| {
            let a = WeightedSet::from_tokens(g.subset(50, 10).to_vec());
            let b = WeightedSet::from_tokens(g.subset(50, 10).to_vec());
            let wj = weighted_jaccard(&a, &b);
            let j = jaccard(&a, &b);
            assert!((wj - j).abs() < 1e-6, "wj={wj} j={j}");
        });
    }

    #[test]
    fn similarity_properties_symmetric_and_bounded() {
        check("sim-symmetric", 40, |g: &mut Gen| {
            let d = g.usize_in(2, 32);
            let x = g.unit_vec(d);
            let y = g.unit_vec(d);
            let s1 = cosine(&x, &y);
            let s2 = cosine(&y, &x);
            assert!((s1 - s2).abs() < 1e-6);
            assert!((-1.0..=1.0).contains(&s1));
        });
    }

    #[test]
    fn cosine_sim_uses_norm_cache_correctly() {
        let ds = synth::gaussian_mixture(50, 16, 4, 0.2, 5);
        for i in 0..10 {
            for j in 0..10 {
                let fast = CosineSim.sim(&ds, i, j);
                let slow = cosine(ds.row(i), ds.row(j));
                assert!((fast - slow).abs() < 1e-5, "i={i} j={j}: {fast} vs {slow}");
            }
        }
    }

    #[test]
    fn counting_sim_counts() {
        let ds = synth::gaussian_mixture(20, 8, 2, 0.1, 9);
        let cs = CountingSim::new(CosineSim);
        cs.sim(&ds, 0, 1);
        cs.sim(&ds, 1, 2);
        let mut out = Vec::new();
        cs.sim_batch(&ds, 0, &[1, 2, 3], &mut out);
        assert_eq!(cs.comparisons(), 5);
        assert_eq!(out.len(), 3);
        cs.reset();
        assert_eq!(cs.comparisons(), 0);
    }

    #[test]
    fn mixture_blends() {
        let ds = synth::products(30, &synth::ProductsParams::default(), 4);
        let m = MixtureSim { alpha: 0.5 };
        let v = m.sim(&ds, 0, 1);
        let c = CosineSim.sim(&ds, 0, 1);
        let j = jaccard(ds.set(0), ds.set(1));
        assert!((v - (0.5 * c + 0.5 * j)).abs() < 1e-6);
    }

    #[test]
    fn batch_matches_scalar() {
        let ds = synth::gaussian_mixture(40, 8, 4, 0.1, 13);
        let mut out = Vec::new();
        CosineSim.sim_batch(&ds, 3, &[0, 1, 2, 10, 20], &mut out);
        for (k, &c) in [0u32, 1, 2, 10, 20].iter().enumerate() {
            assert_eq!(out[k], CosineSim.sim(&ds, 3, c as usize));
        }
    }
}
