//! [`Similarity`] adapter for the PJRT-backed learned model.
//!
//! The learned measure is the paper's motivating case for Stars: similarity
//! evaluations dominate total runtime (5–10× slower than the mixture
//! measure), so reducing comparisons 10–20× translates directly into
//! wall-clock wins (Tables 1 and 2).

use crate::data::types::Dataset;
use crate::runtime::LearnedModel;
use crate::sim::Similarity;

/// Learned similarity measure backed by the AOT model artifact.
///
/// Scalar `sim()` calls are supported but slow (one PJRT dispatch per padded
/// batch); the scoring loops use `sim_batch`, which amortizes dispatch over
/// whole candidate blocks.
pub struct LearnedSim {
    model: LearnedModel,
}

impl LearnedSim {
    /// Wrap a loaded model.
    pub fn new(model: LearnedModel) -> Self {
        LearnedSim { model }
    }

    /// Access the underlying model (e.g. for dispatch counts).
    pub fn model(&self) -> &LearnedModel {
        &self.model
    }
}

impl Similarity for LearnedSim {
    fn sim(&self, ds: &Dataset, i: usize, j: usize) -> f32 {
        self.model
            .score(ds, &[(i as u32, j as u32)])
            .expect("learned model execution failed")[0]
    }

    fn sim_batch(&self, ds: &Dataset, leader: usize, candidates: &[u32], out: &mut Vec<f32>) {
        let pairs: Vec<(u32, u32)> = candidates.iter().map(|&c| (leader as u32, c)).collect();
        let scores = self
            .model
            .score(ds, &pairs)
            .expect("learned model execution failed");
        out.clear();
        out.extend(scores);
    }

    fn name(&self) -> &'static str {
        "learned"
    }

    fn cost_hint(&self) -> f64 {
        8.0
    }
}
