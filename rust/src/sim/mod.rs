//! Similarity measures.
//!
//! The paper evaluates cosine similarity (MNIST, Random1B/10B), weighted
//! Jaccard (Wikipedia), a cosine+Jaccard mixture and a learned neural
//! similarity (Amazon2m). All are exposed behind the [`Similarity`] trait;
//! [`CountingSim`] wraps any measure with an atomic comparison counter —
//! the paper's headline metric (Figure 1).
//!
//! The scoring hot path goes through `sim_batch`, which every built-in
//! measure overrides with the tiled kernels in [`batch`] (leader-vs-tile
//! blocked FMA dots for dense rows, hash-expanded leader sets for token
//! measures). Batched and scalar scores agree exactly for cosine/dot/
//! jaccard/mixture and to f32 rounding for weighted Jaccard — asserted by
//! the parity property tests in `tests/batch_parity.rs`.

pub mod batch;
mod measure;
mod learned;
pub mod quant;

pub use batch::BatchScratch;
pub use learned::LearnedSim;
pub use quant::QuantDataset;
pub use measure::{
    cosine, dot, jaccard, l2_norm, weighted_jaccard, CosineSim, CountingSim, DotSim, JaccardSim,
    MixtureSim, Similarity, WeightedJaccardSim,
};
