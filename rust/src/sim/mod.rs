//! Similarity measures.
//!
//! The paper evaluates cosine similarity (MNIST, Random1B/10B), weighted
//! Jaccard (Wikipedia), a cosine+Jaccard mixture and a learned neural
//! similarity (Amazon2m). All are exposed behind the [`Similarity`] trait;
//! [`CountingSim`] wraps any measure with an atomic comparison counter —
//! the paper's headline metric (Figure 1).

mod measure;
mod learned;

pub use learned::LearnedSim;
pub use measure::{
    cosine, dot, jaccard, weighted_jaccard, CosineSim, CountingSim, DotSim, JaccardSim,
    MixtureSim, Similarity, WeightedJaccardSim,
};
