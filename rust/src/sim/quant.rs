//! Symmetric per-row SQ8 quantization for the quantized first-pass
//! scoring tier (ROADMAP "Quantized scoring path (int8/SQ8) with exact
//! rescore").
//!
//! Each dense f32 row is stored as `d` i8 codes plus one f32 scale —
//! `d + 4` bytes instead of `4·d`, a ~4× row-storage reduction — and the
//! dot of two quantized rows runs on the int8 kernels of
//! [`crate::util::simd`], which process 4× the lanes per instruction of
//! the f32 tiles.
//!
//! **Quantizer.** Per row, symmetric around zero (the zero-point is
//! always 0, so no cross-term correction is needed in the dot):
//! `scale = max|x| / 127`, `code[k] = round(x[k] / scale)` clamped to
//! `[-127, 127]`. `-128` is deliberately excluded — the AVX2 `maddubs`
//! idiom in `util::simd` needs `|code| ≤ 127` to rule out i16 saturation.
//! The estimate of `a·b` is then `scale_a · scale_b · Σ qa[k]·qb[k]`,
//! with the integer sum exact (i32) and only the two scale multiplies in
//! float. Rounding error per element is at most `scale / 2`, so the
//! round-trip bound `|x − deq(q(x))| ≤ max|x| / 254` holds per row
//! (asserted in `tests/quant_parity.rs`).
//!
//! **Determinism.** Quantization (round-half-away-from-zero), the integer
//! dot (associative, backend-independent — see `util::simd`), and the
//! two-multiply estimate are all deterministic, so the quantized first
//! pass is worker-count- and instruction-set-invariant even though its
//! *scores* are approximations. The parity relaxation lives one level up:
//! the quantized serve path is gated on recall against the f32 path, not
//! bit-identity with it (ARCHITECTURE.md "Quantized scoring tier").

use crate::data::types::Dataset;
use crate::util::simd::{self, SimdBackend};

use super::measure::cosine_from_parts;

/// Largest code magnitude the quantizer emits (`[-127, 127]`; never -128).
pub const QMAX: f32 = 127.0;

/// Quantize one dense row into `out` (same length), returning the scale.
///
/// An all-zero (or non-finite-max) row quantizes to zero codes with scale
/// 0 — estimates against it are exactly 0, matching the f32 dot.
pub fn quantize_row(row: &[f32], out: &mut [i8]) -> f32 {
    debug_assert_eq!(row.len(), out.len());
    let mut max_abs = 0f32;
    for &x in row {
        max_abs = max_abs.max(x.abs());
    }
    if max_abs <= 0.0 || !max_abs.is_finite() {
        out.fill(0);
        return 0.0;
    }
    let inv = QMAX / max_abs;
    for (o, &x) in out.iter_mut().zip(row) {
        *o = (x * inv).round().clamp(-QMAX, QMAX) as i8;
    }
    max_abs / QMAX
}

/// Reconstruct a row from its codes and scale (tests and diagnostics).
pub fn dequantize_into(codes: &[i8], scale: f32, out: &mut [f32]) {
    debug_assert_eq!(codes.len(), out.len());
    for (o, &c) in out.iter_mut().zip(codes) {
        *o = c as f32 * scale;
    }
}

/// Map a raw dot estimate to a cosine estimate with the same zero-guard
/// and `[-1, 1]` clamp as the exact scoring path.
#[inline]
pub fn cosine_estimate(dot_est: f32, norm_prod: f32) -> f32 {
    cosine_from_parts(dot_est, norm_prod)
}

/// Packed SQ8 codes for a dense dataset: row-major `n × dim` i8 codes
/// plus one f32 scale per row. Built once at `StarIndex` build/compaction
/// time (and incrementally on `DeltaBuffer` inserts); immutable snapshots
/// share it behind an `Arc`.
#[derive(Clone, Debug, Default)]
pub struct QuantDataset {
    dim: usize,
    codes: Vec<i8>,
    scales: Vec<f32>,
}

impl QuantDataset {
    /// An empty table for `dim`-dimensional rows.
    pub fn empty(dim: usize) -> QuantDataset {
        QuantDataset {
            dim,
            codes: Vec::new(),
            scales: Vec::new(),
        }
    }

    /// Quantize every dense row of `ds`.
    pub fn from_dataset(ds: &Dataset) -> QuantDataset {
        let mut q = QuantDataset::empty(ds.dim());
        q.extend_from(ds, 0);
        q
    }

    /// Append rows `from..ds.len()` of `ds` — the O(delta) path used by
    /// incremental compaction ([`Self::extended`]) and by rebuilding a
    /// delta-buffer table after a prefix absorb.
    pub fn extend_from(&mut self, ds: &Dataset, from: usize) {
        assert_eq!(self.dim, ds.dim(), "quant/dataset dim mismatch");
        assert!(from <= ds.len() && from >= self.len());
        // Rows already quantized past `from` are identical (per-row
        // quantization has no cross-row state), so skip to our own end.
        let start = self.len().max(from);
        for i in start..ds.len() {
            self.push_row(ds.row(i));
        }
    }

    /// Clone-and-append: this table extended with rows `from..ds.len()` of
    /// `ds`. Incremental compaction shares no codes with the old snapshot
    /// only here — the copy is `n·d` bytes, 4× smaller than copying f32.
    pub fn extended(&self, ds: &Dataset, from: usize) -> QuantDataset {
        let mut q = self.clone();
        q.extend_from(ds, from);
        q
    }

    /// Reassemble from flat code/scale arrays (snapshot persistence). The
    /// shape invariant is re-checked so a corrupted file cannot produce a
    /// misaligned row view later.
    pub(crate) fn from_raw_parts(dim: usize, codes: Vec<i8>, scales: Vec<f32>) -> QuantDataset {
        assert_eq!(codes.len(), scales.len() * dim, "quant codes/scales length mismatch");
        QuantDataset { dim, codes, scales }
    }

    /// The whole flat code table, row-major — snapshot persistence.
    pub(crate) fn code_slice(&self) -> &[i8] {
        &self.codes
    }

    /// All per-row scales — snapshot persistence.
    pub(crate) fn scale_slice(&self) -> &[f32] {
        &self.scales
    }

    /// Quantize and append one row.
    pub fn push_row(&mut self, row: &[f32]) {
        assert_eq!(row.len(), self.dim, "quant row dim mismatch");
        let at = self.codes.len();
        self.codes.resize(at + self.dim, 0);
        let scale = quantize_row(row, &mut self.codes[at..]);
        self.scales.push(scale);
    }

    /// Number of quantized rows.
    pub fn len(&self) -> usize {
        self.scales.len()
    }

    /// Whether no rows are stored.
    pub fn is_empty(&self) -> bool {
        self.scales.is_empty()
    }

    /// Row dimensionality.
    pub fn dim(&self) -> usize {
        self.dim
    }

    /// The i8 codes of row `i`.
    pub fn codes(&self, i: usize) -> &[i8] {
        &self.codes[i * self.dim..(i + 1) * self.dim]
    }

    /// The scale of row `i`.
    pub fn scale(&self, i: usize) -> f32 {
        self.scales[i]
    }

    /// Heap bytes held by the code and scale tables.
    pub fn heap_bytes(&self) -> usize {
        self.codes.len() + self.scales.len() * std::mem::size_of::<f32>()
    }

    /// Bytes per stored row: `dim` code bytes + one f32 scale.
    pub fn bytes_per_row(&self) -> usize {
        self.dim + std::mem::size_of::<f32>()
    }

    /// Estimated f32 dot products of a quantized query against candidate
    /// rows: `out[j] = qscale · scale(c_j) · Σ qcodes·codes(c_j)`, in
    /// 4-row blocks on the int8 kernels. Candidates are scored directly
    /// from the packed table (no gather — i8 rows are a quarter the size
    /// of the f32 tile rows, so the cache argument for staging is gone).
    pub fn dot_estimates_with(
        &self,
        backend: SimdBackend,
        qcodes: &[i8],
        qscale: f32,
        cands: &[u32],
        out: &mut Vec<f32>,
    ) {
        debug_assert_eq!(qcodes.len(), self.dim);
        out.clear();
        out.resize(cands.len(), 0.0);
        let blocks = cands.len() / 4;
        for blk in 0..blocks {
            let j = blk * 4;
            let d4 = simd::dot_i8_block4_with(
                backend,
                qcodes,
                self.codes(cands[j] as usize),
                self.codes(cands[j + 1] as usize),
                self.codes(cands[j + 2] as usize),
                self.codes(cands[j + 3] as usize),
            );
            for r in 0..4 {
                out[j + r] = qscale * self.scales[cands[j + r] as usize] * d4[r] as f32;
            }
        }
        for j in blocks * 4..cands.len() {
            let c = cands[j] as usize;
            let d = simd::dot_i8_with(backend, qcodes, self.codes(c));
            out[j] = qscale * self.scales[c] * d as f32;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::util::rng::Rng;

    fn rowf(d: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..d).map(|_| rng.gaussian() as f32).collect()
    }

    #[test]
    fn round_trip_error_is_bounded_by_half_a_step() {
        for d in [1usize, 3, 16, 100, 784] {
            let row = rowf(d, 42 + d as u64);
            let mut codes = vec![0i8; d];
            let scale = quantize_row(&row, &mut codes);
            let mut back = vec![0f32; d];
            dequantize_into(&codes, scale, &mut back);
            for k in 0..d {
                assert!(
                    (row[k] - back[k]).abs() <= scale * 0.5 + 1e-6,
                    "d={d} k={k}: {} vs {} (scale {scale})",
                    row[k],
                    back[k]
                );
            }
        }
    }

    #[test]
    fn zero_row_quantizes_to_zero_scale() {
        let mut codes = vec![7i8; 8];
        let scale = quantize_row(&[0.0; 8], &mut codes);
        assert_eq!(scale, 0.0);
        assert!(codes.iter().all(|&c| c == 0));
    }

    #[test]
    fn codes_never_reach_minus_128() {
        // The AVX2 maddubs idiom requires it; extreme negative values must
        // clamp to -127.
        let row = [-1e30f32, 1e30, -1.0, 0.5];
        let mut codes = vec![0i8; 4];
        quantize_row(&row, &mut codes);
        assert!(codes.iter().all(|&c| c >= -127));
        assert_eq!(codes[0], -127);
        assert_eq!(codes[1], 127);
    }

    #[test]
    fn from_dataset_and_incremental_paths_agree() {
        let ds = synth::gaussian_mixture(64, 16, 4, 0.2, 7);
        let whole = QuantDataset::from_dataset(&ds);
        assert_eq!(whole.len(), 64);
        assert_eq!(whole.bytes_per_row(), 16 + 4);
        assert_eq!(whole.heap_bytes(), 64 * 16 + 64 * 4);

        // Build a prefix table, then extend by the suffix — per-row
        // quantization must make the two routes identical.
        let prefix = ds.subset(&(0..40u32).collect::<Vec<_>>());
        let mut inc = QuantDataset::from_dataset(&prefix);
        inc.extend_from(&ds, 40);
        for i in 0..64 {
            assert_eq!(inc.codes(i), whole.codes(i), "row {i}");
            assert_eq!(inc.scale(i).to_bits(), whole.scale(i).to_bits(), "row {i}");
        }
    }

    #[test]
    fn dot_estimates_approximate_the_exact_dot() {
        let ds = synth::gaussian_mixture(40, 100, 4, 0.2, 9);
        let q = QuantDataset::from_dataset(&ds);
        let mut qcodes = vec![0i8; ds.dim()];
        let qscale = quantize_row(ds.row(0), &mut qcodes);
        let cands: Vec<u32> = (0..40).collect();
        let mut est = Vec::new();
        q.dot_estimates_with(simd::active(), &qcodes, qscale, &cands, &mut est);
        for (j, &c) in cands.iter().enumerate() {
            let exact = crate::sim::dot(ds.row(0), ds.row(c as usize));
            // Error bound: |a·b − est| ≤ Σ|a||Δb| + Σ|Δa||b̂| ≤
            // d·(max|a|·sb/2 + sa/2·max|b|); loose practical check here.
            assert!(
                (exact - est[j]).abs() < 0.05 * exact.abs().max(1.0),
                "cand {c}: exact {exact} vs est {}",
                est[j]
            );
        }
        // Block path (first 4·k candidates) and tail path (rest) must
        // agree with the single-row kernel on every backend.
        for backend in simd::reachable() {
            let mut per_backend = Vec::new();
            q.dot_estimates_with(backend, &qcodes, qscale, &cands, &mut per_backend);
            for j in 0..cands.len() {
                assert_eq!(
                    per_backend[j].to_bits(),
                    est[j].to_bits(),
                    "backend {backend:?} cand {j}"
                );
            }
        }
    }
}
