//! Tiled batch-scoring kernels — the dense and set hot paths.
//!
//! The paper's central claim is that similarity comparisons dominate graph
//! building, so the comparisons that *do* run must move at memory bandwidth.
//! The scalar path (`Similarity::sim` per pair) re-loads the leader row and
//! restarts the FMA pipeline for every candidate. This module instead:
//!
//! * gathers a bucket's candidate rows into a contiguous, cache-blocked
//!   **tile** (sized to ~half an L1d), then
//! * scores leader-vs-tile with a 4-row × 8-lane register-blocked dot kernel
//!   ([`dot_tile`]): one leader load feeds four multiply-add chains through
//!   the runtime-dispatched lanes of [`crate::util::simd`] (AVX2/NEON, or
//!   the blocked-scalar reference), and every backend's lane reduction
//!   matches [`crate::sim::measure::dot`] bit-for-bit so batched and scalar scores are
//!   identical on any backend (EXPERIMENTS.md §Perf,
//!   `tests/simd_parity.rs`);
//! * for set measures, expands the leader's token list into a hash map once
//!   per batch so each candidate walk is O(|B|) lookups instead of an
//!   O(|A|+|B|) cold merge per pair.
//!
//! Scratch buffers live in a thread-local [`BatchScratch`] so the `&self`
//! trait entry points allocate nothing in steady state. Helpers take explicit
//! buffers; only the `Similarity` impls touch the thread-local, exactly once
//! per call (never nested, which would panic the RefCell).

use super::measure::cosine_from_parts;
use crate::data::types::{Dataset, WeightedSet};
use crate::util::fxhash::FxHashMap;
use crate::util::simd::{self, SimdBackend};
use std::cell::RefCell;

/// Byte budget for one gathered tile: ~half a typical 32 KiB L1d, leaving
/// room for the leader row, the output slice, and the gather cursor.
const TILE_BYTES: usize = 16 * 1024;

/// Rows scored per register block (the lane structure inside a block —
/// 8 lanes per row, matching [`crate::sim::measure::dot`] — lives in
/// `util::simd`, which all backends replicate bit-for-bit).
const BLOCK: usize = 4;

/// Rows gathered per tile for dense dimension `d` (cache-blocking policy).
#[inline]
pub fn tile_rows(d: usize) -> usize {
    (TILE_BYTES / (d.max(1) * std::mem::size_of::<f32>())).clamp(BLOCK, 64)
}

/// Score `leader` against the first `rows` rows of a gathered tile, writing
/// `out[r] = dot(leader, tile_row_r)`. 4-row blocks run through the
/// runtime-dispatched [`simd::dot_block4_with`] (one leader load feeds four
/// multiply-add chains); tail rows (rows % 4) fall back to the single-row
/// kernel, which reduces in the same order — so the output is bit-identical
/// to a per-row [`crate::sim::measure::dot`] loop on every backend.
pub fn dot_tile(leader: &[f32], tile: &[f32], rows: usize, out: &mut [f32]) {
    dot_tile_with(simd::active(), leader, tile, rows, out);
}

/// [`dot_tile`] on an explicit SIMD backend (the dispatch is hoisted here,
/// once per tile — benches and the parity suite force backends through this
/// entry point).
pub fn dot_tile_with(
    backend: SimdBackend,
    leader: &[f32],
    tile: &[f32],
    rows: usize,
    out: &mut [f32],
) {
    let d = leader.len();
    debug_assert!(tile.len() >= rows * d && out.len() >= rows);
    let mut r = 0;
    while r + BLOCK <= rows {
        let base = r * d;
        let res = simd::dot_block4_with(
            backend,
            leader,
            &tile[base..base + d],
            &tile[base + d..base + 2 * d],
            &tile[base + 2 * d..base + 3 * d],
            &tile[base + 3 * d..base + 4 * d],
        );
        out[r..r + BLOCK].copy_from_slice(&res);
        r += BLOCK;
    }
    while r < rows {
        out[r] = simd::dot_with(backend, leader, &tile[r * d..(r + 1) * d]);
        r += 1;
    }
}

/// Gather candidate rows into contiguous tiles and score the leader against
/// each: `out[k] = dot(row(leader), row(candidates[k]))`. The gather turns
/// scattered bucket rows into a streaming read for the blocked kernel; one
/// leader-row load is amortized over the whole tile.
pub fn dot_batch(
    ds: &Dataset,
    leader: usize,
    candidates: &[u32],
    tile: &mut Vec<f32>,
    out: &mut Vec<f32>,
) {
    dot_batch_row(ds.row(leader), ds, candidates, tile, out);
}

/// [`dot_batch`] with the leader row passed explicitly — the serving path's
/// entry point, where the query vector lives outside the indexed dataset.
/// Same gather, same tiled kernel, same reduction order.
pub fn dot_batch_row(
    lrow: &[f32],
    ds: &Dataset,
    candidates: &[u32],
    tile: &mut Vec<f32>,
    out: &mut Vec<f32>,
) {
    out.clear();
    out.resize(candidates.len(), 0.0);
    if candidates.is_empty() {
        return;
    }
    let d = ds.dim();
    debug_assert_eq!(lrow.len(), d);
    let rows_per_tile = tile_rows(d);
    if tile.len() < rows_per_tile * d {
        tile.resize(rows_per_tile * d, 0.0);
    }
    for (t, chunk) in candidates.chunks(rows_per_tile).enumerate() {
        for (r, &c) in chunk.iter().enumerate() {
            tile[r * d..(r + 1) * d].copy_from_slice(ds.row(c as usize));
        }
        let off = t * rows_per_tile;
        dot_tile(lrow, tile, chunk.len(), &mut out[off..off + chunk.len()]);
    }
}

/// Batched cosine: tiled dots normalized by the precomputed
/// [`Dataset::norms`] (never recomputed — same source as the scalar path).
pub fn cosine_batch(
    ds: &Dataset,
    leader: usize,
    candidates: &[u32],
    tile: &mut Vec<f32>,
    out: &mut Vec<f32>,
) {
    cosine_batch_row(ds.row(leader), ds.norm(leader), ds, candidates, tile, out);
}

/// [`cosine_batch`] with the leader row and its L2 norm passed explicitly
/// (serving path). Candidate norms still come from [`Dataset::norms`].
pub fn cosine_batch_row(
    lrow: &[f32],
    lnorm: f32,
    ds: &Dataset,
    candidates: &[u32],
    tile: &mut Vec<f32>,
    out: &mut Vec<f32>,
) {
    dot_batch_row(lrow, ds, candidates, tile, out);
    for (k, &c) in candidates.iter().enumerate() {
        out[k] = cosine_from_parts(out[k], lnorm * ds.norm(c as usize));
    }
}

/// Batched unweighted Jaccard. The leader's tokens are expanded into
/// `leader_wts` once; each candidate then costs |B| hash probes instead of a
/// cold sorted merge. Integer counts make this bit-identical to
/// [`crate::sim::measure::jaccard`].
pub fn jaccard_batch(
    ds: &Dataset,
    leader: usize,
    candidates: &[u32],
    leader_wts: &mut FxHashMap<u32, f32>,
    out: &mut Vec<f32>,
) {
    jaccard_batch_set(ds.set(leader), ds, candidates, leader_wts, out);
}

/// [`jaccard_batch`] with the leader set passed explicitly (serving path).
pub fn jaccard_batch_set(
    a: &WeightedSet,
    ds: &Dataset,
    candidates: &[u32],
    leader_wts: &mut FxHashMap<u32, f32>,
    out: &mut Vec<f32>,
) {
    leader_wts.clear();
    for &t in &a.tokens {
        leader_wts.insert(t, 1.0);
    }
    out.clear();
    for &c in candidates {
        let b = ds.set(c as usize);
        if a.is_empty() && b.is_empty() {
            out.push(0.0);
            continue;
        }
        let inter = b
            .tokens
            .iter()
            .filter(|t| leader_wts.contains_key(t))
            .count();
        let union = a.len() + b.len() - inter;
        out.push(if union == 0 {
            0.0
        } else {
            inter as f32 / union as f32
        });
    }
}

/// Batched weighted Jaccard via the min-sum identity
/// Σ max(xᵢ, yᵢ) = Σxᵢ + Σyᵢ − Σ min(xᵢ, yᵢ): the leader's weights and total
/// are computed once, so each candidate walks only its own token list.
/// Matches [`crate::sim::measure::weighted_jaccard`] to f32 rounding (the
/// denominator is summed in a different order).
pub fn weighted_jaccard_batch(
    ds: &Dataset,
    leader: usize,
    candidates: &[u32],
    leader_wts: &mut FxHashMap<u32, f32>,
    out: &mut Vec<f32>,
) {
    weighted_jaccard_batch_set(ds.set(leader), ds, candidates, leader_wts, out);
}

/// [`weighted_jaccard_batch`] with the leader set passed explicitly
/// (serving path).
pub fn weighted_jaccard_batch_set(
    a: &WeightedSet,
    ds: &Dataset,
    candidates: &[u32],
    leader_wts: &mut FxHashMap<u32, f32>,
    out: &mut Vec<f32>,
) {
    leader_wts.clear();
    for (&t, &w) in a.tokens.iter().zip(&a.weights) {
        leader_wts.insert(t, w);
    }
    // Leader total through the dispatched accumulate helper — one blocked
    // fold per batch instead of a serial add chained through the hash
    // inserts.
    let ta = simd::sum_f32(&a.weights);
    out.clear();
    for &c in candidates {
        let b = ds.set(c as usize);
        if a.is_empty() && b.is_empty() {
            out.push(0.0);
            continue;
        }
        let (mut s_min, mut tb) = (0f32, 0f32);
        for (&t, &w) in b.tokens.iter().zip(&b.weights) {
            tb += w;
            if let Some(&aw) = leader_wts.get(&t) {
                s_min += w.min(aw);
            }
        }
        let den = ta + tb - s_min;
        out.push(if den <= 0.0 { 0.0 } else { s_min / den });
    }
}

/// Reusable per-thread scratch for the batch kernels: the gather tile, a
/// secondary score buffer (mixture blending), and the expanded leader set.
#[derive(Default)]
pub struct BatchScratch {
    tile: Vec<f32>,
    aux: Vec<f32>,
    leader_wts: FxHashMap<u32, f32>,
}

impl BatchScratch {
    /// `out[k] = dot(leader, candidates[k])`, tiled.
    pub fn dot(&mut self, ds: &Dataset, leader: usize, candidates: &[u32], out: &mut Vec<f32>) {
        dot_batch(ds, leader, candidates, &mut self.tile, out);
    }

    /// `out[k] = cosine(leader, candidates[k])`, tiled, norms precomputed.
    pub fn cosine(&mut self, ds: &Dataset, leader: usize, candidates: &[u32], out: &mut Vec<f32>) {
        cosine_batch(ds, leader, candidates, &mut self.tile, out);
    }

    /// `out[k] = jaccard(leader, candidates[k])`, leader set expanded once.
    pub fn jaccard(&mut self, ds: &Dataset, leader: usize, candidates: &[u32], out: &mut Vec<f32>) {
        jaccard_batch(ds, leader, candidates, &mut self.leader_wts, out);
    }

    /// `out[k] = weighted_jaccard(leader, candidates[k])`.
    pub fn weighted_jaccard(
        &mut self,
        ds: &Dataset,
        leader: usize,
        candidates: &[u32],
        out: &mut Vec<f32>,
    ) {
        weighted_jaccard_batch(ds, leader, candidates, &mut self.leader_wts, out);
    }

    /// `out[k] = α·cosine + (1−α)·jaccard` (the Amazon2m mixture), sharing
    /// this scratch's tile and leader-set buffers across both components.
    pub fn mixture(
        &mut self,
        alpha: f32,
        ds: &Dataset,
        leader: usize,
        candidates: &[u32],
        out: &mut Vec<f32>,
    ) {
        cosine_batch(ds, leader, candidates, &mut self.tile, out);
        jaccard_batch(ds, leader, candidates, &mut self.leader_wts, &mut self.aux);
        for (o, &j) in out.iter_mut().zip(self.aux.iter()) {
            *o = alpha * *o + (1.0 - alpha) * j;
        }
    }

    /// `out[k] = dot(query_row, candidates[k])` — query-side entry point.
    pub fn dot_row(&mut self, row: &[f32], ds: &Dataset, candidates: &[u32], out: &mut Vec<f32>) {
        dot_batch_row(row, ds, candidates, &mut self.tile, out);
    }

    /// `out[k] = cosine(query_row, candidates[k])`, query norm passed in.
    pub fn cosine_row(
        &mut self,
        row: &[f32],
        norm: f32,
        ds: &Dataset,
        candidates: &[u32],
        out: &mut Vec<f32>,
    ) {
        cosine_batch_row(row, norm, ds, candidates, &mut self.tile, out);
    }

    /// `out[k] = jaccard(query_set, candidates[k])` — query-side entry point.
    pub fn jaccard_set(
        &mut self,
        set: &WeightedSet,
        ds: &Dataset,
        candidates: &[u32],
        out: &mut Vec<f32>,
    ) {
        jaccard_batch_set(set, ds, candidates, &mut self.leader_wts, out);
    }

    /// `out[k] = weighted_jaccard(query_set, candidates[k])`.
    pub fn weighted_jaccard_set(
        &mut self,
        set: &WeightedSet,
        ds: &Dataset,
        candidates: &[u32],
        out: &mut Vec<f32>,
    ) {
        weighted_jaccard_batch_set(set, ds, candidates, &mut self.leader_wts, out);
    }

    /// `out[k] = α·cosine + (1−α)·jaccard` against an external query point
    /// carrying both a dense row and a token set (hybrid datasets).
    #[allow(clippy::too_many_arguments)]
    pub fn mixture_row_set(
        &mut self,
        alpha: f32,
        row: &[f32],
        norm: f32,
        set: &WeightedSet,
        ds: &Dataset,
        candidates: &[u32],
        out: &mut Vec<f32>,
    ) {
        cosine_batch_row(row, norm, ds, candidates, &mut self.tile, out);
        jaccard_batch_set(set, ds, candidates, &mut self.leader_wts, &mut self.aux);
        for (o, &j) in out.iter_mut().zip(self.aux.iter()) {
            *o = alpha * *o + (1.0 - alpha) * j;
        }
    }
}

thread_local! {
    static SCRATCH: RefCell<BatchScratch> = RefCell::new(BatchScratch::default());
}

/// Run `f` with this thread's scratch buffers. Callers must not call
/// `with_scratch` (or any `sim_batch` that uses it) from inside `f`.
pub fn with_scratch<R>(f: impl FnOnce(&mut BatchScratch) -> R) -> R {
    SCRATCH.with(|s| f(&mut s.borrow_mut()))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::sim::measure::{self, dot};

    #[test]
    fn tile_rows_respects_bounds() {
        assert_eq!(tile_rows(16), 64); // small d capped at 64 rows
        assert_eq!(tile_rows(100), 40); // 16 KiB / 400 B
        assert_eq!(tile_rows(784), 5); // 16 KiB / 3136 B
        assert_eq!(tile_rows(100_000), BLOCK); // huge d floors at the block
        assert_eq!(tile_rows(0), 64);
    }

    #[test]
    fn dot_tile_matches_scalar_dot_exactly() {
        for d in [1usize, 7, 8, 15, 16, 100, 784] {
            let ds = synth::gaussian_mixture(40, d, 4, 0.2, 9);
            let leader = ds.row(0);
            let rows = 13; // exercises both the 4-block and the tail path
            let mut tile = vec![0f32; rows * d];
            for r in 0..rows {
                tile[r * d..(r + 1) * d].copy_from_slice(ds.row(r + 1));
            }
            let mut out = vec![0f32; rows];
            dot_tile(leader, &tile, rows, &mut out);
            for r in 0..rows {
                let want = dot(leader, ds.row(r + 1));
                assert_eq!(out[r], want, "d={d} row={r}: {} vs {want}", out[r]);
            }
        }
    }

    #[test]
    fn dot_batch_gathers_and_scores() {
        let ds = synth::gaussian_mixture(200, 100, 4, 0.2, 3);
        let cands: Vec<u32> = (0..199).rev().collect(); // scattered order
        let (mut tile, mut out) = (Vec::new(), Vec::new());
        dot_batch(&ds, 7, &cands, &mut tile, &mut out);
        assert_eq!(out.len(), cands.len());
        for (k, &c) in cands.iter().enumerate() {
            assert_eq!(out[k], dot(ds.row(7), ds.row(c as usize)));
        }
    }

    #[test]
    fn empty_candidates_clear_output() {
        let ds = synth::gaussian_mixture(10, 8, 2, 0.1, 5);
        let (mut tile, mut out) = (Vec::new(), vec![1.0f32; 4]);
        dot_batch(&ds, 0, &[], &mut tile, &mut out);
        assert!(out.is_empty());
        let mut wts = FxHashMap::default();
        let sets = synth::zipf_sets(10, &synth::ZipfSetsParams::default(), 5);
        out.push(1.0);
        jaccard_batch(&sets, 0, &[], &mut wts, &mut out);
        assert!(out.is_empty());
    }

    #[test]
    fn jaccard_batch_matches_merge_walk() {
        let sets = synth::zipf_sets(120, &synth::ZipfSetsParams::default(), 11);
        let cands: Vec<u32> = (1..120).collect();
        let mut wts = FxHashMap::default();
        let mut out = Vec::new();
        jaccard_batch(&sets, 0, &cands, &mut wts, &mut out);
        for (k, &c) in cands.iter().enumerate() {
            let want = measure::jaccard(sets.set(0), sets.set(c as usize));
            assert_eq!(out[k], want, "candidate {c}");
        }
    }

    #[test]
    fn weighted_jaccard_batch_matches_merge_walk() {
        let sets = synth::zipf_sets(120, &synth::ZipfSetsParams::default(), 13);
        let cands: Vec<u32> = (1..120).collect();
        let mut wts = FxHashMap::default();
        let mut out = Vec::new();
        weighted_jaccard_batch(&sets, 0, &cands, &mut wts, &mut out);
        for (k, &c) in cands.iter().enumerate() {
            let want = measure::weighted_jaccard(sets.set(0), sets.set(c as usize));
            assert!(
                (out[k] - want).abs() < 1e-6,
                "candidate {c}: {} vs {want}",
                out[k]
            );
        }
    }
}
