//! Graph-based hierarchical agglomerative clustering (average linkage).
//!
//! The paper's primary downstream citation [16] (Dhulipala, Eisenstat,
//! Łącki, Mirrokni, Shi — "Hierarchical agglomerative graph clustering in
//! nearly-linear time", ICML 2021) shows graph HAC with average linkage runs
//! in time nearly linear in the number of *edges* — exactly why Stars'
//! sparse two-hop spanners matter: the spanner's edge count, not n², is
//! what downstream clustering pays for.
//!
//! This is the sequential heap-based variant: maintain cluster-level average
//! weights, repeatedly merge the globally best pair above a stopping
//! threshold, lazily invalidating stale heap entries. Complexity
//! O(E log E · α) with α the cluster-degree overlap factor — nearly linear
//! on the sparse graphs Stars produces.

use crate::graph::Graph;
use crate::util::fxhash::FxHashMap;
use std::collections::BinaryHeap;

/// A merge record in the dendrogram: clusters `a` and `b` (ids in the
/// internal node space) merged at average similarity `sim` into `into`.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Merge {
    /// First merged cluster id.
    pub a: u32,
    /// Second merged cluster id.
    pub b: u32,
    /// New cluster id (n + merge index).
    pub into: u32,
    /// Average-linkage similarity at merge time.
    pub sim: f32,
}

/// Dendrogram produced by [`average_linkage_hac`].
#[derive(Clone, Debug, Default)]
pub struct Dendrogram {
    /// Number of leaves (original points).
    pub n: usize,
    /// Merges in execution order (non-increasing similarity under exact
    /// average linkage on a static graph is NOT guaranteed — averages can
    /// rise after merges — but is monotone in practice on similarity
    /// graphs).
    pub merges: Vec<Merge>,
}

impl Dendrogram {
    /// Flat clustering: apply merges with `sim >= cut`, return labels.
    pub fn cut(&self, cut: f32) -> Vec<u32> {
        let mut uf = crate::graph::UnionFind::new(self.n);
        for m in &self.merges {
            if m.sim >= cut {
                // `into` ids are synthetic; union the leaf-space reps.
                uf.union(self.leaf_of(m.a), self.leaf_of(m.b));
            }
        }
        uf.labels()
    }

    /// Flat clustering with (at most) `k` clusters: apply merges best-first
    /// until k clusters remain (plus isolated leaves).
    pub fn cut_to_k(&self, k: usize) -> Vec<u32> {
        let mut uf = crate::graph::UnionFind::new(self.n);
        for m in &self.merges {
            if uf.num_components() <= k {
                break;
            }
            uf.union(self.leaf_of(m.a), self.leaf_of(m.b));
        }
        uf.labels()
    }

    /// Any leaf contained in cluster id `c` (leaf ids pass through).
    fn leaf_of(&self, c: u32) -> u32 {
        let mut c = c;
        while c as usize >= self.n {
            c = self.merges[c as usize - self.n].a;
        }
        c
    }
}

/// Run average-linkage graph HAC down to `min_sim`: merging stops when no
/// cluster pair with average similarity ≥ `min_sim` remains.
pub fn average_linkage_hac(g: &Graph, min_sim: f32) -> Dendrogram {
    let n = g.num_nodes();
    // Active cluster adjacency: cluster -> (neighbor cluster -> (Σw, cnt)).
    let mut adj: Vec<FxHashMap<u32, (f64, u64)>> = vec![FxHashMap::default(); n];
    for e in g.edges() {
        adj[e.u as usize]
            .entry(e.v)
            .and_modify(|x| {
                x.0 += e.w as f64;
                x.1 += 1;
            })
            .or_insert((e.w as f64, 1));
        adj[e.v as usize]
            .entry(e.u)
            .and_modify(|x| {
                x.0 += e.w as f64;
                x.1 += 1;
            })
            .or_insert((e.w as f64, 1));
    }
    // Cluster metadata: alive flag + current id mapping. Merged clusters get
    // fresh ids appended to `adj`.
    let mut alive: Vec<bool> = vec![true; n];
    let mut merges = Vec::new();

    // Max-heap of candidate merges (lazy deletion).
    #[derive(PartialEq)]
    struct Cand {
        sim: f32,
        a: u32,
        b: u32,
    }
    impl Eq for Cand {}
    impl Ord for Cand {
        fn cmp(&self, other: &Self) -> std::cmp::Ordering {
            self.sim
                .total_cmp(&other.sim)
                .then(self.a.cmp(&other.a))
                .then(self.b.cmp(&other.b))
        }
    }
    impl PartialOrd for Cand {
        fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
            Some(self.cmp(other))
        }
    }

    let mut heap = BinaryHeap::new();
    for (u, nbrs) in adj.iter().enumerate() {
        for (&v, &(sum, cnt)) in nbrs {
            if (u as u32) < v {
                let sim = (sum / cnt as f64) as f32;
                if sim >= min_sim {
                    heap.push(Cand {
                        sim,
                        a: u as u32,
                        b: v,
                    });
                }
            }
        }
    }

    while let Some(Cand { sim, a, b }) = heap.pop() {
        if sim < min_sim {
            break;
        }
        if !alive[a as usize] || !alive[b as usize] {
            continue; // stale entry
        }
        // Re-validate: the (a, b) average may have changed after merges.
        let current = adj[a as usize].get(&b).map(|&(s, c)| (s / c as f64) as f32);
        match current {
            Some(cur) if (cur - sim).abs() <= 1e-6 => {}
            Some(cur) => {
                if cur >= min_sim {
                    heap.push(Cand { sim: cur, a, b });
                }
                continue;
            }
            None => continue,
        }
        // Merge b into a new cluster id.
        let new_id = adj.len() as u32;
        alive[a as usize] = false;
        alive[b as usize] = false;
        alive.push(true);
        merges.push(Merge {
            a,
            b,
            into: new_id,
            sim,
        });
        // Union neighbor maps of a and b (excluding each other).
        let na = std::mem::take(&mut adj[a as usize]);
        let nb = std::mem::take(&mut adj[b as usize]);
        let mut merged: FxHashMap<u32, (f64, u64)> = FxHashMap::default();
        for (src, skip) in [(na, b), (nb, a)] {
            for (v, (sum, cnt)) in src {
                if v == skip {
                    continue;
                }
                let ent = merged.entry(v).or_insert((0.0, 0));
                ent.0 += sum;
                ent.1 += cnt;
            }
        }
        adj.push(FxHashMap::default());
        // Rewire neighbors to point at the new cluster and push fresh heap
        // candidates.
        let entries: Vec<(u32, (f64, u64))> = merged.into_iter().collect();
        for (v, (sum, cnt)) in entries {
            if !alive[v as usize] {
                continue;
            }
            adj[v as usize].remove(&a);
            adj[v as usize].remove(&b);
            adj[v as usize].insert(new_id, (sum, cnt));
            adj[new_id as usize].insert(v, (sum, cnt));
            let s = (sum / cnt as f64) as f32;
            if s >= min_sim {
                heap.push(Cand {
                    sim: s,
                    a: v.min(new_id),
                    b: v.max(new_id),
                });
            }
        }
    }

    Dendrogram { n, merges }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Edge;

    fn two_cliques() -> Graph {
        Graph::from_edges(
            6,
            vec![
                Edge::new(0, 1, 0.9),
                Edge::new(1, 2, 0.9),
                Edge::new(0, 2, 0.9),
                Edge::new(3, 4, 0.9),
                Edge::new(4, 5, 0.9),
                Edge::new(3, 5, 0.9),
                Edge::new(2, 3, 0.1),
            ],
        )
    }

    #[test]
    fn merges_cliques_before_bridge() {
        let d = average_linkage_hac(&two_cliques(), 0.0);
        // 5 merges total (connected graph -> single cluster).
        assert_eq!(d.merges.len(), 5);
        // The first four merges are all at high similarity (within cliques);
        // the bridge merge comes last at a low average.
        assert!(d.merges[0].sim > 0.5);
        let last = d.merges.last().unwrap();
        assert!(last.sim < 0.5, "bridge merged at {}", last.sim);
    }

    #[test]
    fn min_sim_stops_merging() {
        let d = average_linkage_hac(&two_cliques(), 0.5);
        // Bridge (avg 0.1) never merges: exactly 4 merges.
        assert_eq!(d.merges.len(), 4);
        let labels = d.cut(0.5);
        assert_eq!(labels[0], labels[1]);
        assert_eq!(labels[1], labels[2]);
        assert_eq!(labels[3], labels[5]);
        assert_ne!(labels[0], labels[3]);
    }

    #[test]
    fn cut_to_k_respects_k() {
        let d = average_linkage_hac(&two_cliques(), 0.0);
        let labels = d.cut_to_k(2);
        let distinct: std::collections::HashSet<_> = labels.iter().collect();
        assert_eq!(distinct.len(), 2);
        let labels = d.cut_to_k(1);
        let distinct: std::collections::HashSet<_> = labels.iter().collect();
        assert_eq!(distinct.len(), 1);
    }

    #[test]
    fn empty_graph_no_merges() {
        let g = Graph::from_edges(4, vec![]);
        let d = average_linkage_hac(&g, 0.0);
        assert!(d.merges.is_empty());
        assert_eq!(d.cut(0.5), vec![0, 1, 2, 3]);
    }

    #[test]
    fn average_linkage_uses_means_not_max() {
        // 0-1 at 1.0; cluster {0,1} connects to 2 via edges 1.0 and 0.0:
        // average 0.5, so with min_sim 0.6 the second merge must not happen.
        let g = Graph::from_edges(
            3,
            vec![
                Edge::new(0, 1, 1.0),
                Edge::new(0, 2, 1.0),
                Edge::new(1, 2, 0.0),
            ],
        );
        let d = average_linkage_hac(&g, 0.6);
        assert_eq!(d.merges.len(), 1, "merges: {:?}", d.merges);
    }

    #[test]
    fn hac_on_stars_graph_recovers_modes() {
        use crate::data::synth;
        use crate::lsh::SimHash;
        use crate::sim::CosineSim;
        use crate::stars::{Algorithm, BuildParams, StarsBuilder};

        let ds = synth::gaussian_mixture(600, 32, 6, 0.05, 13);
        let family = SimHash::new(32, 6, 2);
        let out = StarsBuilder::new(&ds)
            .similarity(&CosineSim)
            .hash(&family)
            .params(
                BuildParams::threshold_mode(Algorithm::LshStars)
                    .sketches(40)
                    .threshold(0.4),
            )
            .workers(2)
            .build();
        let d = average_linkage_hac(&out.graph, 0.4);
        let labels = d.cut_to_k(6);
        let vm = crate::clustering::v_measure(&labels, &ds.labels);
        assert!(vm.v > 0.7, "HAC on spanner V-Measure {}", vm.v);
    }
}
