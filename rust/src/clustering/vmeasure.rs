//! V-Measure (Rosenberg & Hirschberg, EMNLP-CoNLL 2007): the harmonic mean
//! of homogeneity and completeness — the paper's Figure 4 quality score.

use crate::util::fxhash::FxHashMap;

/// V-Measure decomposition.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct VMeasure {
    /// Each cluster contains only members of a single class (1.0 = perfect).
    pub homogeneity: f64,
    /// All members of a class are assigned to the same cluster (1.0 = perfect).
    pub completeness: f64,
    /// Harmonic mean of the two.
    pub v: f64,
}

/// Compute V-Measure between predicted cluster labels and ground-truth class
/// labels. Labels are arbitrary u32 ids; lengths must match.
pub fn v_measure(pred: &[u32], truth: &[u32]) -> VMeasure {
    assert_eq!(pred.len(), truth.len(), "label length mismatch");
    let n = pred.len();
    if n == 0 {
        return VMeasure {
            homogeneity: 1.0,
            completeness: 1.0,
            v: 1.0,
        };
    }
    // Contingency counts.
    let mut joint: FxHashMap<(u32, u32), u64> = FxHashMap::default();
    let mut by_cluster: FxHashMap<u32, u64> = FxHashMap::default();
    let mut by_class: FxHashMap<u32, u64> = FxHashMap::default();
    for i in 0..n {
        *joint.entry((pred[i], truth[i])).or_default() += 1;
        *by_cluster.entry(pred[i]).or_default() += 1;
        *by_class.entry(truth[i]).or_default() += 1;
    }
    let nf = n as f64;
    let entropy = |counts: &FxHashMap<u32, u64>| -> f64 {
        counts
            .values()
            .map(|&c| {
                let p = c as f64 / nf;
                -p * p.ln()
            })
            .sum()
    };
    let h_c = entropy(&by_class); // H(C): class entropy
    let h_k = entropy(&by_cluster); // H(K): cluster entropy
    // H(C|K) and H(K|C) from the joint.
    let mut h_c_given_k = 0.0;
    let mut h_k_given_c = 0.0;
    for (&(k, c), &cnt) in &joint {
        let p_joint = cnt as f64 / nf;
        let p_k = by_cluster[&k] as f64 / nf;
        let p_c = by_class[&c] as f64 / nf;
        h_c_given_k -= p_joint * (p_joint / p_k).ln();
        h_k_given_c -= p_joint * (p_joint / p_c).ln();
    }
    let homogeneity = if h_c <= 0.0 { 1.0 } else { 1.0 - h_c_given_k / h_c };
    let completeness = if h_k <= 0.0 { 1.0 } else { 1.0 - h_k_given_c / h_k };
    let v = if homogeneity + completeness <= 0.0 {
        0.0
    } else {
        2.0 * homogeneity * completeness / (homogeneity + completeness)
    };
    VMeasure {
        homogeneity,
        completeness,
        v,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn perfect_clustering_scores_one() {
        let truth = vec![0, 0, 1, 1, 2, 2];
        let m = v_measure(&truth, &truth);
        assert!((m.v - 1.0).abs() < 1e-9);
        assert!((m.homogeneity - 1.0).abs() < 1e-9);
        assert!((m.completeness - 1.0).abs() < 1e-9);
        // Label permutation does not matter.
        let permuted = vec![5, 5, 9, 9, 7, 7];
        assert!((v_measure(&permuted, &truth).v - 1.0).abs() < 1e-9);
    }

    #[test]
    fn single_cluster_is_complete_not_homogeneous() {
        let truth = vec![0, 0, 1, 1];
        let pred = vec![0, 0, 0, 0];
        let m = v_measure(&pred, &truth);
        assert!((m.completeness - 1.0).abs() < 1e-9);
        assert!(m.homogeneity < 0.01);
        assert!(m.v < 0.01);
    }

    #[test]
    fn singletons_are_homogeneous_not_complete() {
        let truth = vec![0, 0, 1, 1];
        let pred = vec![0, 1, 2, 3];
        let m = v_measure(&pred, &truth);
        assert!((m.homogeneity - 1.0).abs() < 1e-9);
        assert!(m.completeness < 1.0);
    }

    #[test]
    fn known_value_from_paper_example() {
        // sklearn cross-check: labels_true = [0,0,1,1], labels_pred = [0,0,1,2]
        // homogeneity = 1.0, completeness ≈ 0.6667, v ≈ 0.8.
        let m = v_measure(&[0, 0, 1, 2], &[0, 0, 1, 1]);
        assert!((m.homogeneity - 1.0).abs() < 1e-6);
        assert!((m.completeness - 2.0 / 3.0).abs() < 0.02, "{}", m.completeness);
        assert!((m.v - 0.8).abs() < 0.02, "{}", m.v);
    }

    #[test]
    fn better_clusterings_score_higher() {
        let truth: Vec<u32> = (0..100).map(|i| i / 25).collect();
        let good: Vec<u32> = truth
            .iter()
            .enumerate()
            .map(|(i, &t)| if i % 25 == 0 { (t + 1) % 4 } else { t })
            .collect();
        let bad: Vec<u32> = (0..100).map(|i| (i % 7) as u32).collect();
        assert!(v_measure(&good, &truth).v > v_measure(&bad, &truth).v);
    }

    #[test]
    fn empty_and_degenerate_inputs() {
        let m = v_measure(&[], &[]);
        assert_eq!(m.v, 1.0);
        // All one class, all one cluster: both entropies zero -> perfect.
        let m = v_measure(&[3, 3], &[1, 1]);
        assert!((m.v - 1.0).abs() < 1e-9);
    }
}
