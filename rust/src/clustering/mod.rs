//! Downstream clustering consumers of the built graphs.
//!
//! * [`affinity`] — average Affinity clustering (Bateni et al., NIPS'17):
//!   Borůvka-style MST clustering, the paper's Figure 4 workload.
//! * [`single_linkage`] — k-single-linkage via descending-weight edge
//!   unions; with two-hop spanners this realizes Theorem 2.5's
//!   2-approximation.
//! * [`vmeasure`] — the V-Measure external cluster quality score
//!   (Rosenberg & Hirschberg, 2007) used in Figure 4.

pub mod affinity;
pub mod hac;
pub mod single_linkage;
pub mod vmeasure;

pub use affinity::{affinity_cluster_to_k, affinity_levels};
pub use hac::{average_linkage_hac, Dendrogram, Merge};
pub use single_linkage::{single_linkage_k, sweep_components};
pub use vmeasure::{v_measure, VMeasure};
