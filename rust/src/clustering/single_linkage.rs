//! k-single-linkage clustering over similarity graphs (paper §2, Theorem 2.5
//! and Appendix A).
//!
//! The objective *minimizes the maximum cross-cluster similarity*: merge the
//! most-similar pairs first (descending-weight Kruskal unions) and stop at k
//! components. On an exact threshold graph this is optimal; Theorem 2.5 shows
//! that (r/c, r)-two-hop spanners over a geometric sweep of r give a
//! c-approximation (c = r₂/r₁ ≈ 1/ε).

use crate::graph::{Edge, Graph, UnionFind};

/// Cluster into exactly `k` components (or the natural component count if
/// the graph has more than `k` components). Returns (labels, cost) where
/// cost is the largest similarity crossing the final partition — the
/// k-single-linkage objective value (f32::NEG_INFINITY when every edge was
/// merged).
pub fn single_linkage_k(g: &Graph, k: usize) -> (Vec<u32>, f32) {
    let n = g.num_nodes();
    let mut edges: Vec<Edge> = g.edges().to_vec();
    edges.sort_unstable_by(|a, b| b.w.total_cmp(&a.w));
    let mut uf = UnionFind::new(n);
    let mut cost = f32::NEG_INFINITY;
    for e in edges {
        if uf.num_components() <= k.max(1) {
            // Remaining (unmerged) cross edges bound the objective: the best
            // of them is the max cross-cluster similarity.
            if !uf.connected(e.u, e.v) {
                cost = cost.max(e.w);
            }
            break;
        }
        uf.union(e.u, e.v);
    }
    (uf.labels(), cost)
}

/// Number of connected components when keeping only edges with weight ≥ r —
/// the component sweep used to realize the geometric-threshold construction
/// of Theorem 2.5 with a single weighted spanner.
pub fn sweep_components(g: &Graph, r: f32) -> usize {
    let mut uf = UnionFind::new(g.num_nodes());
    for e in g.edges() {
        if e.w >= r {
            uf.union(e.u, e.v);
        }
    }
    uf.num_components()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Edge;

    fn chain() -> Graph {
        // 0 -0.9- 1 -0.2- 2 -0.8- 3
        Graph::from_edges(
            4,
            vec![
                Edge::new(0, 1, 0.9),
                Edge::new(1, 2, 0.2),
                Edge::new(2, 3, 0.8),
            ],
        )
    }

    #[test]
    fn k2_cuts_weakest_link() {
        let (labels, cost) = single_linkage_k(&chain(), 2);
        assert_eq!(labels[0], labels[1]);
        assert_eq!(labels[2], labels[3]);
        assert_ne!(labels[0], labels[2]);
        assert!((cost - 0.2).abs() < 1e-6, "cost {cost}");
    }

    #[test]
    fn k1_merges_everything() {
        let (labels, cost) = single_linkage_k(&chain(), 1);
        assert!(labels.iter().all(|&l| l == labels[0]));
        assert_eq!(cost, f32::NEG_INFINITY);
    }

    #[test]
    fn more_components_than_k_is_ok() {
        let g = Graph::from_edges(5, vec![Edge::new(0, 1, 0.5)]);
        let (labels, _) = single_linkage_k(&g, 2);
        // 4 natural components > k=2; everything mergeable got merged.
        let distinct: std::collections::HashSet<_> = labels.iter().collect();
        assert_eq!(distinct.len(), 4);
    }

    #[test]
    fn sweep_monotone_in_r() {
        let g = chain();
        assert_eq!(sweep_components(&g, 0.1), 1);
        assert_eq!(sweep_components(&g, 0.5), 2);
        assert_eq!(sweep_components(&g, 0.85), 3);
        assert_eq!(sweep_components(&g, 0.95), 4);
        // Monotone non-decreasing.
        let mut prev = 0;
        for r in [0.0, 0.2, 0.4, 0.6, 0.8, 1.0] {
            let c = sweep_components(&g, r);
            assert!(c >= prev);
            prev = c;
        }
    }

    /// Theorem 2.5 / Observation A.1 sandwich: components of the
    /// (r/c, r)-two-hop spanner sit between those of the r-threshold and
    /// r/c-threshold graphs. We emulate the spanner by a Stars build and
    /// check against exact threshold graphs on a small dataset.
    #[test]
    fn spanner_components_sandwich_threshold_components() {
        use crate::data::synth;
        use crate::lsh::SimHash;
        use crate::sim::CosineSim;
        use crate::stars::{Algorithm, BuildParams, StarsBuilder};

        let ds = synth::gaussian_mixture(300, 16, 5, 0.05, 31);
        let (r, c) = (0.6f32, 1.2f32);
        let r1 = r / c;
        let family = SimHash::new(16, 6, 3);
        let out = StarsBuilder::new(&ds)
            .similarity(&CosineSim)
            .hash(&family)
            .params(
                BuildParams::threshold_mode(Algorithm::LshStars)
                    .sketches(60)
                    .threshold(r1)
                    .degree_cap(0),
            )
            .workers(2)
            .build();
        // Exact threshold graphs.
        let cluster = crate::ampc::Cluster::new(2);
        let hi = Graph::from_edges(
            300,
            crate::stars::allpair::allpair_edges(&ds, &CosineSim, r, &cluster),
        );
        let lo = Graph::from_edges(
            300,
            crate::stars::allpair::allpair_edges(&ds, &CosineSim, r1, &cluster),
        );
        let spanner_cc = sweep_components(&out.graph, r1);
        let hi_cc = sweep_components(&hi, f32::MIN); // all edges
        let lo_cc = sweep_components(&lo, f32::MIN);
        // Observation A.1: cc(r/c-threshold) ≤ cc(spanner) ≤ cc(r-threshold).
        assert!(
            lo_cc <= spanner_cc && spanner_cc <= hi_cc,
            "sandwich violated: {lo_cc} <= {spanner_cc} <= {hi_cc}"
        );
    }
}
