//! Average Affinity clustering (Bateni et al., "Affinity Clustering:
//! Hierarchical Clustering at Scale", NIPS 2017).
//!
//! Borůvka-style: each round every cluster selects its highest-similarity
//! incident edge (average linkage between clusters) and merges along the
//! selected edges; rounds repeat until the graph is exhausted. The sequence
//! of per-round labelings forms the hierarchy; Figure 4 clusters each built
//! graph this way and scores the result with V-Measure.

use crate::graph::{Graph, UnionFind};
use crate::util::fxhash::FxHashMap;

/// One level of the Affinity hierarchy.
#[derive(Clone, Debug)]
pub struct Level {
    /// Cluster label per point.
    pub labels: Vec<u32>,
    /// Number of clusters at this level.
    pub clusters: usize,
}

/// Run Borůvka rounds with average linkage until no merges remain or
/// `max_rounds` is hit. Returns the labeling after every round (coarsening).
pub fn affinity_levels(g: &Graph, max_rounds: usize) -> Vec<Level> {
    let n = g.num_nodes();
    let mut uf = UnionFind::new(n);
    // Contracted multigraph between current clusters: (cu, cv) -> (Σw, count)
    // with cu < cv; average linkage weight = Σw / count.
    let mut cluster_edges: FxHashMap<(u32, u32), (f64, u64)> = FxHashMap::default();
    for e in g.edges() {
        let key = (e.u.min(e.v), e.u.max(e.v));
        let ent = cluster_edges.entry(key).or_insert((0.0, 0));
        ent.0 += e.w as f64;
        ent.1 += 1;
    }

    let mut levels = Vec::new();
    for _round in 0..max_rounds {
        if cluster_edges.is_empty() {
            break;
        }
        // Each cluster picks its best average-weight incident edge.
        let mut best: FxHashMap<u32, (f64, u32)> = FxHashMap::default();
        for (&(cu, cv), &(sum, cnt)) in &cluster_edges {
            let avg = sum / cnt as f64;
            let better = |cur: Option<&(f64, u32)>| match cur {
                None => true,
                Some(&(bw, bv)) => avg > bw || (avg == bw && cv.min(cu) < bv),
            };
            if better(best.get(&cu)) {
                best.insert(cu, (avg, cv));
            }
            if better(best.get(&cv)) {
                best.insert(cv, (avg, cu));
            }
        }
        // Merge along selected edges.
        let mut merged = false;
        for (&cu, &(_, cv)) in &best {
            if uf.union(cu, cv) {
                merged = true;
            }
        }
        if !merged {
            break;
        }
        // Contract the cluster graph.
        let mut next: FxHashMap<(u32, u32), (f64, u64)> = FxHashMap::default();
        for ((cu, cv), (sum, cnt)) in cluster_edges.drain() {
            let (ru, rv) = (uf.find(cu), uf.find(cv));
            if ru == rv {
                continue;
            }
            let key = (ru.min(rv), ru.max(rv));
            let ent = next.entry(key).or_insert((0.0, 0));
            ent.0 += sum;
            ent.1 += cnt;
        }
        cluster_edges = next;
        levels.push(Level {
            labels: uf.labels(),
            clusters: uf.num_components(),
        });
        if uf.num_components() <= 1 {
            break;
        }
    }
    if levels.is_empty() {
        levels.push(Level {
            labels: uf.labels(),
            clusters: uf.num_components(),
        });
    }
    levels
}

/// Cluster to (approximately) `k` clusters: run the hierarchy and return the
/// finest level with at most `target_max` clusters, or the coarsest level if
/// every level is finer. `target_max` is typically the number of ground-truth
/// classes; isolated points keep singleton clusters (the paper's graphs also
/// leave sparse points isolated).
pub fn affinity_cluster_to_k(g: &Graph, target_max: usize) -> Level {
    let levels = affinity_levels(g, 64);
    for level in &levels {
        if level.clusters <= target_max {
            return level.clone();
        }
    }
    levels.last().cloned().unwrap_or(Level {
        labels: (0..g.num_nodes() as u32).collect(),
        clusters: g.num_nodes(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Edge;

    /// Two dense triangles joined by one weak edge.
    fn two_cliques() -> Graph {
        Graph::from_edges(
            6,
            vec![
                Edge::new(0, 1, 0.9),
                Edge::new(1, 2, 0.9),
                Edge::new(0, 2, 0.9),
                Edge::new(3, 4, 0.9),
                Edge::new(4, 5, 0.9),
                Edge::new(3, 5, 0.9),
                Edge::new(2, 3, 0.1),
            ],
        )
    }

    #[test]
    fn first_round_merges_strong_edges_first() {
        let g = two_cliques();
        let levels = affinity_levels(&g, 1);
        let l = &levels[0];
        // After one round both triangles are merged internally; the weak
        // bridge may or may not be taken depending on best-edge choices, but
        // points within a triangle must share a label.
        assert_eq!(l.labels[0], l.labels[1]);
        assert_eq!(l.labels[1], l.labels[2]);
        assert_eq!(l.labels[3], l.labels[4]);
        assert_eq!(l.labels[4], l.labels[5]);
    }

    #[test]
    fn hierarchy_coarsens_monotonically() {
        let g = two_cliques();
        let levels = affinity_levels(&g, 10);
        for w in levels.windows(2) {
            assert!(w[1].clusters <= w[0].clusters);
        }
        // Eventually everything merges (graph is connected).
        assert_eq!(levels.last().unwrap().clusters, 1);
    }

    #[test]
    fn cluster_to_k_respects_target() {
        let g = two_cliques();
        let l = affinity_cluster_to_k(&g, 2);
        assert!(l.clusters <= 2);
        if l.clusters == 2 {
            assert_ne!(l.labels[0], l.labels[5]);
        }
    }

    #[test]
    fn disconnected_graph_stops_at_components() {
        let g = Graph::from_edges(
            5,
            vec![Edge::new(0, 1, 0.5), Edge::new(2, 3, 0.5)],
        );
        let levels = affinity_levels(&g, 10);
        let last = levels.last().unwrap();
        // Components: {0,1}, {2,3}, {4} -> 3 clusters, never fewer.
        assert_eq!(last.clusters, 3);
    }

    #[test]
    fn empty_graph_keeps_singletons() {
        let g = Graph::from_edges(4, vec![]);
        let levels = affinity_levels(&g, 5);
        assert_eq!(levels.last().unwrap().clusters, 4);
    }

    #[test]
    fn average_linkage_prefers_consistent_groups() {
        // Chain 0-1 strong, 1-2 medium: round 1 pairs (0,1) (2 joins 1's best
        // or its own best = 1). Average linkage then controls later rounds.
        let g = Graph::from_edges(
            4,
            vec![
                Edge::new(0, 1, 0.9),
                Edge::new(1, 2, 0.5),
                Edge::new(2, 3, 0.9),
                Edge::new(0, 3, 0.1),
            ],
        );
        let levels = affinity_levels(&g, 1);
        let l = &levels[0];
        assert_eq!(l.labels[0], l.labels[1]);
        assert_eq!(l.labels[2], l.labels[3]);
    }
}
