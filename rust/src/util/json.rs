//! Minimal JSON implementation (value model, parser, writer).
//!
//! Used for: experiment reports (EXPERIMENTS.md provenance), job configs, and
//! reading `artifacts/meta.json` written by the python AOT step. serde is not
//! in the offline vendor set, hence this from-scratch implementation.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value. Objects use BTreeMap so output is deterministically ordered.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Build an object from key/value pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Access an object field.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// Numeric accessor.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    /// Integer accessor (rounds from the f64 representation).
    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|x| x.round() as i64)
    }

    /// Unsigned accessor.
    pub fn as_usize(&self) -> Option<usize> {
        self.as_i64().and_then(|x| usize::try_from(x).ok())
    }

    /// String accessor.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Array accessor.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Bool accessor.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    /// Serialize with 2-space indentation.
    pub fn to_pretty(&self) -> String {
        let mut s = String::new();
        self.write_pretty(&mut s, 0);
        s.push('\n');
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => write_num(out, *x),
            Json::Str(s) => write_str(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_str(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    fn write_pretty(&self, out: &mut String, indent: usize) {
        match self {
            Json::Arr(v) if !v.is_empty() => {
                out.push_str("[\n");
                for (i, x) in v.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    for _ in 0..indent + 2 {
                        out.push(' ');
                    }
                    x.write_pretty(out, indent + 2);
                }
                out.push('\n');
                for _ in 0..indent {
                    out.push(' ');
                }
                out.push(']');
            }
            Json::Obj(m) if !m.is_empty() => {
                out.push_str("{\n");
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push_str(",\n");
                    }
                    for _ in 0..indent + 2 {
                        out.push(' ');
                    }
                    write_str(out, k);
                    out.push_str(": ");
                    v.write_pretty(out, indent + 2);
                }
                out.push('\n');
                for _ in 0..indent {
                    out.push(' ');
                }
                out.push('}');
            }
            _ => self.write(out),
        }
    }
}

fn write_num(out: &mut String, x: f64) {
    if x.is_finite() {
        if x == x.trunc() && x.abs() < 1e15 {
            let _ = write!(out, "{}", x as i64);
        } else {
            let _ = write!(out, "{}", x);
        }
    } else {
        out.push_str("null"); // JSON has no NaN/Inf
    }
}

fn write_str(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Parse a JSON document. Returns an error with byte position on failure.
pub fn parse(input: &str) -> anyhow::Result<Json> {
    let bytes = input.as_bytes();
    let mut p = Parser { bytes, pos: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.pos != bytes.len() {
        anyhow::bail!("trailing content at byte {}", p.pos);
    }
    Ok(v)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn expect(&mut self, b: u8) -> anyhow::Result<()> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            anyhow::bail!(
                "expected '{}' at byte {}, found {:?}",
                b as char,
                self.pos,
                self.peek().map(|c| c as char)
            )
        }
    }

    fn value(&mut self) -> anyhow::Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => anyhow::bail!("unexpected {:?} at byte {}", other.map(|c| c as char), self.pos),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> anyhow::Result<Json> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            anyhow::bail!("invalid literal at byte {}", self.pos)
        }
    }

    fn number(&mut self) -> anyhow::Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.pos += 1;
        }
        let s = std::str::from_utf8(&self.bytes[start..self.pos])?;
        Ok(Json::Num(s.parse::<f64>()?))
    }

    fn string(&mut self) -> anyhow::Result<String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => anyhow::bail!("unterminated string"),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            let hex = std::str::from_utf8(
                                self.bytes
                                    .get(self.pos + 1..self.pos + 5)
                                    .ok_or_else(|| anyhow::anyhow!("bad \\u escape"))?,
                            )?;
                            let code = u32::from_str_radix(hex, 16)?;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.pos += 4;
                        }
                        other => anyhow::bail!("bad escape {:?}", other.map(|c| c as char)),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // Copy a run of plain UTF-8 bytes.
                    let start = self.pos;
                    while matches!(self.peek(), Some(c) if c != b'"' && c != b'\\') {
                        self.pos += 1;
                    }
                    out.push_str(std::str::from_utf8(&self.bytes[start..self.pos])?);
                }
            }
        }
    }

    fn array(&mut self) -> anyhow::Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                other => anyhow::bail!("expected ',' or ']' found {:?}", other.map(|c| c as char)),
            }
        }
    }

    fn object(&mut self) -> anyhow::Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                other => anyhow::bail!("expected ',' or '}}' found {:?}", other.map(|c| c as char)),
            }
        }
    }
}

/// Convenience constructors.
impl From<f64> for Json {
    fn from(x: f64) -> Json {
        Json::Num(x)
    }
}
impl From<usize> for Json {
    fn from(x: usize) -> Json {
        Json::Num(x as f64)
    }
}
impl From<u64> for Json {
    fn from(x: u64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<i64> for Json {
    fn from(x: i64) -> Json {
        Json::Num(x as f64)
    }
}
impl From<bool> for Json {
    fn from(x: bool) -> Json {
        Json::Bool(x)
    }
}
impl From<&str> for Json {
    fn from(x: &str) -> Json {
        Json::Str(x.to_string())
    }
}
impl From<String> for Json {
    fn from(x: String) -> Json {
        Json::Str(x)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(xs: Vec<T>) -> Json {
        Json::Arr(xs.into_iter().map(Into::into).collect())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_basic() {
        let v = Json::obj(vec![
            ("a", Json::from(1.5)),
            ("b", Json::from("hi\nthere")),
            ("c", Json::from(vec![1usize, 2, 3])),
            ("d", Json::Null),
            ("e", Json::from(true)),
        ]);
        let s = v.to_string();
        let back = parse(&s).unwrap();
        assert_eq!(v, back);
    }

    #[test]
    fn parse_nested() {
        let v = parse(r#"{"x": [1, 2, {"y": "z"}], "n": -3.25e2}"#).unwrap();
        assert_eq!(v.get("n").unwrap().as_f64().unwrap(), -325.0);
        assert_eq!(
            v.get("x").unwrap().as_arr().unwrap()[2]
                .get("y")
                .unwrap()
                .as_str()
                .unwrap(),
            "z"
        );
    }

    #[test]
    fn parse_escapes() {
        let v = parse(r#""a\"b\\c\ndA""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "a\"b\\c\ndA");
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("nul").is_err());
        assert!(parse("{} x").is_err());
        assert!(parse("\"unterminated").is_err());
    }

    #[test]
    fn integers_print_without_fraction() {
        assert_eq!(Json::from(42usize).to_string(), "42");
        assert_eq!(Json::from(1.25).to_string(), "1.25");
    }

    #[test]
    fn pretty_parses_back() {
        let v = Json::obj(vec![("k", Json::from(vec![1usize, 2]))]);
        let p = v.to_pretty();
        assert_eq!(parse(&p).unwrap(), v);
    }

    #[test]
    fn empty_containers() {
        assert_eq!(parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(parse("{}").unwrap(), Json::Obj(Default::default()));
    }
}
