//! Tiny declarative CLI argument parser (clap is not in the vendor set).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positional args, and
//! subcommands (handled by the caller peeling the first positional).

use std::collections::BTreeMap;

/// Parsed command line: flags/options by name plus positionals in order.
#[derive(Debug, Default, Clone)]
pub struct Args {
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    positional: Vec<String>,
}

impl Args {
    /// Parse from an iterator of arguments (excluding argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Self {
        let mut out = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(arg) = it.next() {
            if let Some(body) = arg.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    out.opts.insert(k.to_string(), v.to_string());
                } else if it
                    .peek()
                    .map(|next| !next.starts_with("--"))
                    .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.opts.insert(body.to_string(), v);
                } else {
                    out.flags.push(body.to_string());
                }
            } else {
                out.positional.push(arg);
            }
        }
        out
    }

    /// Parse the process's own arguments.
    pub fn from_env() -> Self {
        Args::parse(std::env::args().skip(1))
    }

    /// Boolean flag presence.
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    /// String option.
    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(|s| s.as_str())
    }

    /// String option with default.
    pub fn get_or<'a>(&'a self, name: &str, default: &'a str) -> &'a str {
        self.get(name).unwrap_or(default)
    }

    /// Typed option with default; panics with a clear message on parse error.
    pub fn get_parsed_or<T: std::str::FromStr>(&self, name: &str, default: T) -> T
    where
        T::Err: std::fmt::Display,
    {
        match self.get(name) {
            None => default,
            Some(s) => s
                .parse()
                .unwrap_or_else(|e| panic!("--{name}={s}: {e}")),
        }
    }

    /// Positional arguments.
    pub fn positional(&self) -> &[String] {
        &self.positional
    }

    /// Remove and return the first positional (subcommand dispatch).
    pub fn take_subcommand(&mut self) -> Option<String> {
        if self.positional.is_empty() {
            None
        } else {
            Some(self.positional.remove(0))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &[&str]) -> Args {
        Args::parse(s.iter().map(|s| s.to_string()))
    }

    #[test]
    fn options_and_flags() {
        // Note the greedy rule: `--key value` consumes the next token unless
        // it starts with `--`, so boolean flags go last or use `--flag=..`.
        let a = parse(&["build", "data.bin", "--n", "1000", "--algo=stars", "--verbose"]);
        assert_eq!(a.positional(), &["build".to_string(), "data.bin".to_string()]);
        assert_eq!(a.get("n"), Some("1000"));
        assert_eq!(a.get("algo"), Some("stars"));
        assert!(a.flag("verbose"));
        assert!(!a.flag("quiet"));
    }

    #[test]
    fn typed_defaults() {
        let a = parse(&["--k", "32"]);
        assert_eq!(a.get_parsed_or("k", 0usize), 32);
        assert_eq!(a.get_parsed_or("missing", 7usize), 7);
        assert_eq!(a.get_parsed_or("missing", 0.5f64), 0.5);
    }

    #[test]
    fn subcommand_peeling() {
        let mut a = parse(&["bench", "fig1", "--r", "25"]);
        assert_eq!(a.take_subcommand().as_deref(), Some("bench"));
        assert_eq!(a.take_subcommand().as_deref(), Some("fig1"));
        assert_eq!(a.take_subcommand(), None);
    }

    #[test]
    fn trailing_flag_without_value() {
        let a = parse(&["--fast"]);
        assert!(a.flag("fast"));
        assert_eq!(a.get("fast"), None);
    }
}
