//! Runtime-dispatched SIMD lane layer for the dense hot kernels.
//!
//! The scoring and sketching tiles (`sim::batch::dot_tile`,
//! `lsh::sketch::sketch_tile`) were written as fixed-shape blocked
//! reductions so the autovectorizer *could* emit wide FMAs — but "could" is
//! a compiler mood, not a contract. This module makes the lanes explicit:
//! every hot reduction has a scalar reference implementation plus
//! `std::arch` ports (AVX2 on `x86_64`, NEON on `aarch64`), and one backend
//! is chosen **at runtime** from CPUID-style feature detection.
//!
//! Two contracts, both load-bearing:
//!
//! * **Bit-identity.** Every backend replicates the scalar kernel's exact
//!   lane structure and reduction order — same lane count, same lane-sum
//!   association tree, same scalar tail, and separate multiply/add rounding
//!   (no FMA contraction: the scalar kernels round the product before the
//!   sum, so a fused `a*b+c` would differ in the last ulp). A switch of
//!   backend can therefore never change a similarity score, a sketch key,
//!   an edge, or a served top-k — the worker-count-invariance contract in
//!   ARCHITECTURE.md extends to an *instruction-set*-invariance contract,
//!   asserted by `tests/simd_parity.rs` for every backend reachable on the
//!   build host.
//! * **Observability.** The resolved backend is reported by name in
//!   `CostReport`/bench JSON (`simd_backend`), and `STARS_SIMD=
//!   scalar|avx2|neon` forces a backend (falling back to scalar, with a
//!   warning, when the host can't run the request) so perf numbers and CI
//!   runs can pin the lanes they exercise.
//!
//! The int8 kernels ([`dot_i8`], [`dot_i8_block4`]) satisfy a *stronger*
//! form of the first contract: they accumulate in `i32`, and integer
//! addition is associative, so every backend returns the **same integer**
//! no matter how the lanes are grouped — equality of values, not merely of
//! rounded bit patterns. Their operands must come from the SQ8 quantizer
//! (`sim::quant`, range `[-127, 127]`): the AVX2 port pairs an unsigned
//! `|a|` with a sign-transferred `b` through `maddubs`, whose i16 pair sums
//! only stay below saturation when `-128` is excluded.
//!
//! Dispatch is resolved once per tile (callers hoist [`active`] out of
//! their block loops and call the `_with` variants), so the per-block cost
//! is one predictable match, amortized over a `4 × d` reduction.

use std::sync::OnceLock;

/// Environment variable that forces a backend: `scalar`, `avx2` or `neon`.
pub const SIMD_ENV: &str = "STARS_SIMD";

/// An instruction-set backend for the lane kernels.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SimdBackend {
    /// Portable blocked-scalar kernels — the reduction-order reference.
    Scalar,
    /// 256-bit AVX2 lanes (`x86_64`, requires `avx2` + `fma` at runtime).
    Avx2,
    /// 128-bit NEON lanes (`aarch64`).
    Neon,
}

impl SimdBackend {
    /// Display name — the value `STARS_SIMD` accepts and the string
    /// reported as `simd_backend` in `CostReport`/bench JSON.
    pub fn name(&self) -> &'static str {
        match self {
            SimdBackend::Scalar => "scalar",
            SimdBackend::Avx2 => "avx2",
            SimdBackend::Neon => "neon",
        }
    }

    /// Parse a `STARS_SIMD` value (case-insensitive).
    pub fn parse(s: &str) -> Option<SimdBackend> {
        match s.to_ascii_lowercase().as_str() {
            "scalar" => Some(SimdBackend::Scalar),
            "avx2" => Some(SimdBackend::Avx2),
            "neon" => Some(SimdBackend::Neon),
            _ => None,
        }
    }
}

/// Whether this host can execute `backend`'s kernels. Scalar is always
/// supported; AVX2 additionally requires the `fma` feature so future
/// kernels may fuse where bit-identity permits.
pub fn supported(backend: SimdBackend) -> bool {
    match backend {
        SimdBackend::Scalar => true,
        SimdBackend::Avx2 => {
            #[cfg(target_arch = "x86_64")]
            {
                std::arch::is_x86_feature_detected!("avx2")
                    && std::arch::is_x86_feature_detected!("fma")
            }
            #[cfg(not(target_arch = "x86_64"))]
            {
                false
            }
        }
        SimdBackend::Neon => {
            #[cfg(target_arch = "aarch64")]
            {
                std::arch::is_aarch64_feature_detected!("neon")
            }
            #[cfg(not(target_arch = "aarch64"))]
            {
                false
            }
        }
    }
}

/// The best backend the host supports, ignoring any override.
pub fn detected() -> SimdBackend {
    if supported(SimdBackend::Avx2) {
        SimdBackend::Avx2
    } else if supported(SimdBackend::Neon) {
        SimdBackend::Neon
    } else {
        SimdBackend::Scalar
    }
}

/// Every backend this host can execute, scalar first — what the parity
/// tests sweep and the benches report per-backend throughput for.
pub fn reachable() -> Vec<SimdBackend> {
    let mut out = vec![SimdBackend::Scalar];
    for b in [SimdBackend::Avx2, SimdBackend::Neon] {
        if supported(b) {
            out.push(b);
        }
    }
    out
}

/// Resolve a backend from an optional override string (the `STARS_SIMD`
/// policy, factored out so tests can exercise it without touching the
/// process environment): `None` → [`detected`]; a valid, supported name →
/// that backend; a valid but unsupported name → scalar (with a warning —
/// forcing lanes the host lacks would be an illegal-instruction trap, and
/// scalar is the only backend guaranteed to agree bit-for-bit anyway); an
/// unrecognized name → [`detected`] (with a warning).
pub fn resolve(request: Option<&str>) -> SimdBackend {
    let Some(req) = request else {
        return detected();
    };
    match SimdBackend::parse(req) {
        Some(b) if supported(b) => b,
        Some(b) => {
            eprintln!(
                "stars: {SIMD_ENV}={req} requests the {} backend, which this host \
                 cannot execute; falling back to scalar",
                b.name()
            );
            SimdBackend::Scalar
        }
        None => {
            eprintln!(
                "stars: unrecognized {SIMD_ENV}={req} (expected scalar|avx2|neon); \
                 using detected backend {}",
                detected().name()
            );
            detected()
        }
    }
}

/// The active backend: `STARS_SIMD` if set, else the detected best.
/// Resolved once per process and cached — hot kernels hoist this out of
/// their block loops.
pub fn active() -> SimdBackend {
    static ACTIVE: OnceLock<SimdBackend> = OnceLock::new();
    *ACTIVE.get_or_init(|| resolve(std::env::var(SIMD_ENV).ok().as_deref()))
}

// ---------------------------------------------------------------------------
// Reduction-order reference kernels (scalar).
//
// These are the kernels the tiles shipped with; the SIMD ports below must
// match them bit-for-bit. Lane-sum association trees are written out
// explicitly — do not "simplify" them, the parity tests pin the rounding.
// ---------------------------------------------------------------------------

/// `((x0 + x1) + x2) + x3` — the 4-lane sum order shared by the sketch
/// kernels and [`sum_f32`].
#[inline(always)]
fn sum4(x: [f32; 4]) -> f32 {
    ((x[0] + x[1]) + x[2]) + x[3]
}

/// `(x0+x1) + (x2+x3) + ((x4+x5) + (x6+x7))` — the 8-lane tree shared by
/// the dot kernels (`sim::measure::dot`'s historical order).
#[inline(always)]
fn sum8(x: [f32; 8]) -> f32 {
    (x[0] + x[1]) + (x[2] + x[3]) + ((x[4] + x[5]) + (x[6] + x[7]))
}

/// 8-lane blocked dot product (one accumulator group).
fn dot_scalar(a: &[f32], b: &[f32]) -> f32 {
    let n = a.len();
    let chunks = n / 8;
    let mut acc = [0f32; 8];
    for c in 0..chunks {
        let k = c * 8;
        for l in 0..8 {
            acc[l] += a[k + l] * b[k + l];
        }
    }
    let mut d = sum8(acc);
    for k in chunks * 8..n {
        d += a[k] * b[k];
    }
    d
}

/// Dot of `leader` against four rows at once: one leader-element load feeds
/// four 8-lane accumulator groups.
fn dot_block4_scalar(leader: &[f32], t0: &[f32], t1: &[f32], t2: &[f32], t3: &[f32]) -> [f32; 4] {
    let d = leader.len();
    let chunks = d / 8;
    let mut acc = [[0f32; 8]; 4];
    for c in 0..chunks {
        let k = c * 8;
        for l in 0..8 {
            let x = leader[k + l];
            acc[0][l] += x * t0[k + l];
            acc[1][l] += x * t1[k + l];
            acc[2][l] += x * t2[k + l];
            acc[3][l] += x * t3[k + l];
        }
    }
    let mut out = [0f32; 4];
    for (r, a) in acc.iter().enumerate() {
        out[r] = sum8(*a);
    }
    for k in chunks * 8..d {
        let x = leader[k];
        out[0] += x * t0[k];
        out[1] += x * t1[k];
        out[2] += x * t2[k];
        out[3] += x * t3[k];
    }
    out
}

/// Dots of one row against a plane pair: two 4-lane accumulator groups
/// (the inner kernel of `lsh::sketch::sketch_row_scalar`).
fn sketch_row2_scalar(p0: &[f32], p1: &[f32], row: &[f32]) -> (f32, f32) {
    let d = row.len();
    let chunks = d / 4;
    let mut a = [0f32; 4];
    let mut b = [0f32; 4];
    for c in 0..chunks {
        let k = c * 4;
        for l in 0..4 {
            let x = row[k + l];
            a[l] += x * p0[k + l];
            b[l] += x * p1[k + l];
        }
    }
    let (mut da, mut db) = (sum4(a), sum4(b));
    for k in chunks * 4..d {
        da += row[k] * p0[k];
        db += row[k] * p1[k];
    }
    (da, db)
}

/// Dots of four rows against a plane pair at once: eight 4-lane accumulator
/// groups (the inner kernel of `lsh::sketch::sketch_tile`).
fn sketch_block4_scalar(
    p0: &[f32],
    p1: &[f32],
    t0: &[f32],
    t1: &[f32],
    t2: &[f32],
    t3: &[f32],
) -> ([f32; 4], [f32; 4]) {
    let d = p0.len();
    let chunks = d / 4;
    let mut a = [[0f32; 4]; 4]; // a[row][lane] against p0
    let mut b = [[0f32; 4]; 4]; // b[row][lane] against p1
    for c in 0..chunks {
        let k = c * 4;
        for l in 0..4 {
            let (x0, x1) = (p0[k + l], p1[k + l]);
            a[0][l] += t0[k + l] * x0;
            b[0][l] += t0[k + l] * x1;
            a[1][l] += t1[k + l] * x0;
            b[1][l] += t1[k + l] * x1;
            a[2][l] += t2[k + l] * x0;
            b[2][l] += t2[k + l] * x1;
            a[3][l] += t3[k + l] * x0;
            b[3][l] += t3[k + l] * x1;
        }
    }
    let mut da = [0f32; 4];
    let mut db = [0f32; 4];
    for (row, (aa, bb)) in a.iter().zip(b.iter()).enumerate() {
        da[row] = sum4(*aa);
        db[row] = sum4(*bb);
    }
    let tails = [t0, t1, t2, t3];
    for k in chunks * 4..d {
        let (x0, x1) = (p0[k], p1[k]);
        for (row, t) in tails.iter().enumerate() {
            da[row] += t[k] * x0;
            db[row] += t[k] * x1;
        }
    }
    (da, db)
}

/// Int8 dot reference: sequential i32 accumulation. Structure is
/// irrelevant for parity (integer adds are associative — wrapping on the
/// astronomically-unlikely overflow, `|dot| ≤ 127²·d` needs `d > 2¹⁷`), so
/// the reference stays in the shape the autovectorizer likes best.
fn dot_i8_scalar(a: &[i8], b: &[i8]) -> i32 {
    let mut acc = 0i32;
    for k in 0..a.len() {
        acc = acc.wrapping_add(a[k] as i32 * b[k] as i32);
    }
    acc
}

/// Int8 dot of `q` against four rows at once — one query-element load
/// feeds four integer accumulators.
fn dot_i8_block4_scalar(q: &[i8], t0: &[i8], t1: &[i8], t2: &[i8], t3: &[i8]) -> [i32; 4] {
    let mut out = [0i32; 4];
    for k in 0..q.len() {
        let x = q[k] as i32;
        out[0] = out[0].wrapping_add(x * t0[k] as i32);
        out[1] = out[1].wrapping_add(x * t1[k] as i32);
        out[2] = out[2].wrapping_add(x * t2[k] as i32);
        out[3] = out[3].wrapping_add(x * t3[k] as i32);
    }
    out
}

/// 4-lane blocked sum — the accumulate helper behind the weighted-jaccard
/// weight folds. NOTE: this is a *blocked* order (lanes then [`sum4`] then
/// the scalar tail), not the strictly sequential `iter().sum()`; all
/// backends agree bit-for-bit with each other, and callers that migrate
/// from a sequential sum accept an ulp-level reassociation once.
fn sum_f32_scalar(xs: &[f32]) -> f32 {
    let n = xs.len();
    let chunks = n / 4;
    let mut acc = [0f32; 4];
    for c in 0..chunks {
        let k = c * 4;
        for l in 0..4 {
            acc[l] += xs[k + l];
        }
    }
    let mut s = sum4(acc);
    for k in chunks * 4..n {
        s += xs[k];
    }
    s
}

// ---------------------------------------------------------------------------
// AVX2 ports (x86_64). Each kernel keeps the scalar kernel's lane count,
// association tree and scalar tail; multiplies and adds stay separate
// instructions (`_mm*_mul_ps` + `_mm*_add_ps`, never `fmadd`) because the
// scalar kernels round the product before the sum — fusing would break
// bit-identity. `fma` is still part of the backend gate so kernels that
// *can* fuse (none yet) have it available.
//
// Safety: every `unsafe fn` below requires the `avx2` feature (checked at
// dispatch via [`supported`]); pointer arithmetic stays inside the slices'
// bounds (`chunks * LANES <= len`).
// ---------------------------------------------------------------------------

#[cfg(target_arch = "x86_64")]
mod avx2 {
    use super::{sum4, sum8};
    use std::arch::x86_64::*;

    /// Spill a 256-bit register to its 8 f32 lanes (lane 0 first).
    #[inline(always)]
    unsafe fn lanes8(v: __m256) -> [f32; 8] {
        let mut out = [0f32; 8];
        _mm256_storeu_ps(out.as_mut_ptr(), v);
        out
    }

    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn dot(a: &[f32], b: &[f32]) -> f32 {
        let n = a.len();
        let chunks = n / 8;
        let mut acc = _mm256_setzero_ps();
        for c in 0..chunks {
            let k = c * 8;
            let va = _mm256_loadu_ps(a.as_ptr().add(k));
            let vb = _mm256_loadu_ps(b.as_ptr().add(k));
            acc = _mm256_add_ps(acc, _mm256_mul_ps(va, vb));
        }
        let mut d = sum8(lanes8(acc));
        for k in chunks * 8..n {
            d += a[k] * b[k];
        }
        d
    }

    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn dot_block4(
        leader: &[f32],
        t0: &[f32],
        t1: &[f32],
        t2: &[f32],
        t3: &[f32],
    ) -> [f32; 4] {
        let d = leader.len();
        let chunks = d / 8;
        let mut a0 = _mm256_setzero_ps();
        let mut a1 = _mm256_setzero_ps();
        let mut a2 = _mm256_setzero_ps();
        let mut a3 = _mm256_setzero_ps();
        for c in 0..chunks {
            let k = c * 8;
            let x = _mm256_loadu_ps(leader.as_ptr().add(k));
            a0 = _mm256_add_ps(a0, _mm256_mul_ps(x, _mm256_loadu_ps(t0.as_ptr().add(k))));
            a1 = _mm256_add_ps(a1, _mm256_mul_ps(x, _mm256_loadu_ps(t1.as_ptr().add(k))));
            a2 = _mm256_add_ps(a2, _mm256_mul_ps(x, _mm256_loadu_ps(t2.as_ptr().add(k))));
            a3 = _mm256_add_ps(a3, _mm256_mul_ps(x, _mm256_loadu_ps(t3.as_ptr().add(k))));
        }
        let mut out = [
            sum8(lanes8(a0)),
            sum8(lanes8(a1)),
            sum8(lanes8(a2)),
            sum8(lanes8(a3)),
        ];
        for k in chunks * 8..d {
            let x = leader[k];
            out[0] += x * t0[k];
            out[1] += x * t1[k];
            out[2] += x * t2[k];
            out[3] += x * t3[k];
        }
        out
    }

    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn sketch_row2(p0: &[f32], p1: &[f32], row: &[f32]) -> (f32, f32) {
        let d = row.len();
        let chunks = d / 4;
        // Low 128 bits accumulate against p0, high against p1 — each lane
        // chain matches one scalar accumulator exactly.
        let mut acc = _mm256_setzero_ps();
        for c in 0..chunks {
            let k = c * 4;
            let r = _mm_loadu_ps(row.as_ptr().add(k));
            let rr = _mm256_set_m128(r, r);
            let p = _mm256_set_m128(
                _mm_loadu_ps(p1.as_ptr().add(k)),
                _mm_loadu_ps(p0.as_ptr().add(k)),
            );
            acc = _mm256_add_ps(acc, _mm256_mul_ps(rr, p));
        }
        let l = lanes8(acc);
        let mut da = sum4([l[0], l[1], l[2], l[3]]);
        let mut db = sum4([l[4], l[5], l[6], l[7]]);
        for k in chunks * 4..d {
            da += row[k] * p0[k];
            db += row[k] * p1[k];
        }
        (da, db)
    }

    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn sketch_block4(
        p0: &[f32],
        p1: &[f32],
        t0: &[f32],
        t1: &[f32],
        t2: &[f32],
        t3: &[f32],
    ) -> ([f32; 4], [f32; 4]) {
        let d = p0.len();
        let chunks = d / 4;
        // Row pairs share a 256-bit register (row r in the low 128, row
        // r+1 in the high 128); each 4-lane half is one scalar accumulator
        // group.
        let mut a01 = _mm256_setzero_ps();
        let mut a23 = _mm256_setzero_ps();
        let mut b01 = _mm256_setzero_ps();
        let mut b23 = _mm256_setzero_ps();
        for c in 0..chunks {
            let k = c * 4;
            let x0 = _mm_loadu_ps(p0.as_ptr().add(k));
            let x1 = _mm_loadu_ps(p1.as_ptr().add(k));
            let p0v = _mm256_set_m128(x0, x0);
            let p1v = _mm256_set_m128(x1, x1);
            let t01 = _mm256_set_m128(
                _mm_loadu_ps(t1.as_ptr().add(k)),
                _mm_loadu_ps(t0.as_ptr().add(k)),
            );
            let t23 = _mm256_set_m128(
                _mm_loadu_ps(t3.as_ptr().add(k)),
                _mm_loadu_ps(t2.as_ptr().add(k)),
            );
            a01 = _mm256_add_ps(a01, _mm256_mul_ps(t01, p0v));
            a23 = _mm256_add_ps(a23, _mm256_mul_ps(t23, p0v));
            b01 = _mm256_add_ps(b01, _mm256_mul_ps(t01, p1v));
            b23 = _mm256_add_ps(b23, _mm256_mul_ps(t23, p1v));
        }
        let (la01, la23) = (lanes8(a01), lanes8(a23));
        let (lb01, lb23) = (lanes8(b01), lanes8(b23));
        let mut da = [
            sum4([la01[0], la01[1], la01[2], la01[3]]),
            sum4([la01[4], la01[5], la01[6], la01[7]]),
            sum4([la23[0], la23[1], la23[2], la23[3]]),
            sum4([la23[4], la23[5], la23[6], la23[7]]),
        ];
        let mut db = [
            sum4([lb01[0], lb01[1], lb01[2], lb01[3]]),
            sum4([lb01[4], lb01[5], lb01[6], lb01[7]]),
            sum4([lb23[0], lb23[1], lb23[2], lb23[3]]),
            sum4([lb23[4], lb23[5], lb23[6], lb23[7]]),
        ];
        let tails = [t0, t1, t2, t3];
        for k in chunks * 4..d {
            let (x0, x1) = (p0[k], p1[k]);
            for (row, t) in tails.iter().enumerate() {
                da[row] += t[k] * x0;
                db[row] += t[k] * x1;
            }
        }
        (da, db)
    }

    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn sum_f32(xs: &[f32]) -> f32 {
        let n = xs.len();
        let chunks = n / 4;
        let mut acc = _mm_setzero_ps();
        for c in 0..chunks {
            acc = _mm_add_ps(acc, _mm_loadu_ps(xs.as_ptr().add(c * 4)));
        }
        let mut l = [0f32; 4];
        _mm_storeu_ps(l.as_mut_ptr(), acc);
        let mut s = sum4(l);
        for k in chunks * 4..n {
            s += xs[k];
        }
        s
    }

    /// Spill a 256-bit register to its 8 i32 lanes (lane 0 first).
    #[inline(always)]
    unsafe fn lanes8_i32(v: __m256i) -> [i32; 8] {
        let mut out = [0i32; 8];
        _mm256_storeu_si256(out.as_mut_ptr() as *mut __m256i, v);
        out
    }

    /// One 32-element i8 chunk of `a·b` widened into 8 i32 lanes.
    ///
    /// AVX2 has no signed×signed byte multiply, so the classic idiom: feed
    /// `maddubs` (unsigned × signed) with `|a|` and `sign(b, a)` — per lane
    /// `|a|·(b·sign(a)) = a·b`. With operands clamped to `[-127, 127]` the
    /// i16 pair sums are ≤ `2·127² = 32258 < i16::MAX`, so `maddubs` cannot
    /// saturate; `madd` against ones then widens the pairs to i32.
    #[inline(always)]
    unsafe fn madd_i8_chunk(va: __m256i, vb: __m256i) -> __m256i {
        let pairs = _mm256_maddubs_epi16(_mm256_abs_epi8(va), _mm256_sign_epi8(vb, va));
        _mm256_madd_epi16(pairs, _mm256_set1_epi16(1))
    }

    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn dot_i8(a: &[i8], b: &[i8]) -> i32 {
        let n = a.len();
        let chunks = n / 32;
        let mut acc = _mm256_setzero_si256();
        for c in 0..chunks {
            let k = c * 32;
            let va = _mm256_loadu_si256(a.as_ptr().add(k) as *const __m256i);
            let vb = _mm256_loadu_si256(b.as_ptr().add(k) as *const __m256i);
            acc = _mm256_add_epi32(acc, madd_i8_chunk(va, vb));
        }
        let mut d = lanes8_i32(acc)
            .iter()
            .fold(0i32, |s, &x| s.wrapping_add(x));
        for k in chunks * 32..n {
            d = d.wrapping_add(a[k] as i32 * b[k] as i32);
        }
        d
    }

    #[target_feature(enable = "avx2,fma")]
    pub unsafe fn dot_i8_block4(
        q: &[i8],
        t0: &[i8],
        t1: &[i8],
        t2: &[i8],
        t3: &[i8],
    ) -> [i32; 4] {
        let d = q.len();
        let chunks = d / 32;
        let mut acc = [_mm256_setzero_si256(); 4];
        let rows = [t0, t1, t2, t3];
        for c in 0..chunks {
            let k = c * 32;
            let vq = _mm256_loadu_si256(q.as_ptr().add(k) as *const __m256i);
            for (r, t) in rows.iter().enumerate() {
                let vt = _mm256_loadu_si256(t.as_ptr().add(k) as *const __m256i);
                acc[r] = _mm256_add_epi32(acc[r], madd_i8_chunk(vq, vt));
            }
        }
        let mut out = [0i32; 4];
        for r in 0..4 {
            out[r] = lanes8_i32(acc[r])
                .iter()
                .fold(0i32, |s, &x| s.wrapping_add(x));
        }
        for k in chunks * 32..d {
            let x = q[k] as i32;
            for (r, t) in rows.iter().enumerate() {
                out[r] = out[r].wrapping_add(x * t[k] as i32);
            }
        }
        out
    }
}

// ---------------------------------------------------------------------------
// NEON ports (aarch64). 128-bit registers are 4 f32 lanes, so the 8-lane
// dot kernels split each accumulator group across a lo/hi register pair;
// the 4-lane sketch kernels map one group per register. Multiplies and adds
// stay separate (`vmulq`/`vaddq`, never `vfmaq`) for the same bit-identity
// reason as the AVX2 port.
//
// Safety: gated on the `neon` feature via [`supported`]; pointer reads stay
// inside the slices (`chunks * LANES <= len`).
// ---------------------------------------------------------------------------

#[cfg(target_arch = "aarch64")]
mod neon {
    use super::{sum4, sum8};
    use std::arch::aarch64::*;

    /// Spill a 128-bit register to its 4 f32 lanes (lane 0 first).
    #[inline(always)]
    unsafe fn lanes4(v: float32x4_t) -> [f32; 4] {
        let mut out = [0f32; 4];
        vst1q_f32(out.as_mut_ptr(), v);
        out
    }

    /// Lanes of a lo/hi register pair as one 8-lane group.
    #[inline(always)]
    unsafe fn lanes8(lo: float32x4_t, hi: float32x4_t) -> [f32; 8] {
        let (l, h) = (lanes4(lo), lanes4(hi));
        [l[0], l[1], l[2], l[3], h[0], h[1], h[2], h[3]]
    }

    #[target_feature(enable = "neon")]
    pub unsafe fn dot(a: &[f32], b: &[f32]) -> f32 {
        let n = a.len();
        let chunks = n / 8;
        let mut lo = vdupq_n_f32(0.0);
        let mut hi = vdupq_n_f32(0.0);
        for c in 0..chunks {
            let k = c * 8;
            lo = vaddq_f32(
                lo,
                vmulq_f32(vld1q_f32(a.as_ptr().add(k)), vld1q_f32(b.as_ptr().add(k))),
            );
            hi = vaddq_f32(
                hi,
                vmulq_f32(
                    vld1q_f32(a.as_ptr().add(k + 4)),
                    vld1q_f32(b.as_ptr().add(k + 4)),
                ),
            );
        }
        let mut d = sum8(lanes8(lo, hi));
        for k in chunks * 8..n {
            d += a[k] * b[k];
        }
        d
    }

    #[target_feature(enable = "neon")]
    pub unsafe fn dot_block4(
        leader: &[f32],
        t0: &[f32],
        t1: &[f32],
        t2: &[f32],
        t3: &[f32],
    ) -> [f32; 4] {
        let d = leader.len();
        let chunks = d / 8;
        let mut lo = [vdupq_n_f32(0.0); 4];
        let mut hi = [vdupq_n_f32(0.0); 4];
        let rows = [t0, t1, t2, t3];
        for c in 0..chunks {
            let k = c * 8;
            let xl = vld1q_f32(leader.as_ptr().add(k));
            let xh = vld1q_f32(leader.as_ptr().add(k + 4));
            for (r, t) in rows.iter().enumerate() {
                lo[r] = vaddq_f32(lo[r], vmulq_f32(xl, vld1q_f32(t.as_ptr().add(k))));
                hi[r] = vaddq_f32(hi[r], vmulq_f32(xh, vld1q_f32(t.as_ptr().add(k + 4))));
            }
        }
        let mut out = [0f32; 4];
        for r in 0..4 {
            out[r] = sum8(lanes8(lo[r], hi[r]));
        }
        for k in chunks * 8..d {
            let x = leader[k];
            for (r, t) in rows.iter().enumerate() {
                out[r] += x * t[k];
            }
        }
        out
    }

    #[target_feature(enable = "neon")]
    pub unsafe fn sketch_row2(p0: &[f32], p1: &[f32], row: &[f32]) -> (f32, f32) {
        let d = row.len();
        let chunks = d / 4;
        let mut a = vdupq_n_f32(0.0);
        let mut b = vdupq_n_f32(0.0);
        for c in 0..chunks {
            let k = c * 4;
            let r = vld1q_f32(row.as_ptr().add(k));
            a = vaddq_f32(a, vmulq_f32(r, vld1q_f32(p0.as_ptr().add(k))));
            b = vaddq_f32(b, vmulq_f32(r, vld1q_f32(p1.as_ptr().add(k))));
        }
        let mut da = sum4(lanes4(a));
        let mut db = sum4(lanes4(b));
        for k in chunks * 4..d {
            da += row[k] * p0[k];
            db += row[k] * p1[k];
        }
        (da, db)
    }

    #[target_feature(enable = "neon")]
    pub unsafe fn sketch_block4(
        p0: &[f32],
        p1: &[f32],
        t0: &[f32],
        t1: &[f32],
        t2: &[f32],
        t3: &[f32],
    ) -> ([f32; 4], [f32; 4]) {
        let d = p0.len();
        let chunks = d / 4;
        let mut a = [vdupq_n_f32(0.0); 4];
        let mut b = [vdupq_n_f32(0.0); 4];
        let rows = [t0, t1, t2, t3];
        for c in 0..chunks {
            let k = c * 4;
            let x0 = vld1q_f32(p0.as_ptr().add(k));
            let x1 = vld1q_f32(p1.as_ptr().add(k));
            for (r, t) in rows.iter().enumerate() {
                let tv = vld1q_f32(t.as_ptr().add(k));
                a[r] = vaddq_f32(a[r], vmulq_f32(tv, x0));
                b[r] = vaddq_f32(b[r], vmulq_f32(tv, x1));
            }
        }
        let mut da = [0f32; 4];
        let mut db = [0f32; 4];
        for r in 0..4 {
            da[r] = sum4(lanes4(a[r]));
            db[r] = sum4(lanes4(b[r]));
        }
        for k in chunks * 4..d {
            let (x0, x1) = (p0[k], p1[k]);
            for (r, t) in rows.iter().enumerate() {
                da[r] += t[k] * x0;
                db[r] += t[k] * x1;
            }
        }
        (da, db)
    }

    #[target_feature(enable = "neon")]
    pub unsafe fn sum_f32(xs: &[f32]) -> f32 {
        let n = xs.len();
        let chunks = n / 4;
        let mut acc = vdupq_n_f32(0.0);
        for c in 0..chunks {
            acc = vaddq_f32(acc, vld1q_f32(xs.as_ptr().add(c * 4)));
        }
        let mut s = sum4(lanes4(acc));
        for k in chunks * 4..n {
            s += xs[k];
        }
        s
    }

    /// Accumulate one 16-element i8 chunk of `a·b` into 4 i32 lanes via
    /// widening multiply + pairwise-add — plain NEON, no `dotprod`
    /// extension required (`vmull_s8` products fit i16: ≤ 127² = 16129;
    /// `vpadalq_s16` widens each pair into the i32 accumulator).
    #[inline(always)]
    unsafe fn padal_i8_chunk(acc: int32x4_t, va: int8x16_t, vb: int8x16_t) -> int32x4_t {
        let lo = vmull_s8(vget_low_s8(va), vget_low_s8(vb));
        let hi = vmull_s8(vget_high_s8(va), vget_high_s8(vb));
        vpadalq_s16(vpadalq_s16(acc, lo), hi)
    }

    #[target_feature(enable = "neon")]
    pub unsafe fn dot_i8(a: &[i8], b: &[i8]) -> i32 {
        let n = a.len();
        let chunks = n / 16;
        let mut acc = vdupq_n_s32(0);
        for c in 0..chunks {
            let k = c * 16;
            acc = padal_i8_chunk(acc, vld1q_s8(a.as_ptr().add(k)), vld1q_s8(b.as_ptr().add(k)));
        }
        let mut d = vaddvq_s32(acc);
        for k in chunks * 16..n {
            d = d.wrapping_add(a[k] as i32 * b[k] as i32);
        }
        d
    }

    #[target_feature(enable = "neon")]
    pub unsafe fn dot_i8_block4(
        q: &[i8],
        t0: &[i8],
        t1: &[i8],
        t2: &[i8],
        t3: &[i8],
    ) -> [i32; 4] {
        let d = q.len();
        let chunks = d / 16;
        let mut acc = [vdupq_n_s32(0); 4];
        let rows = [t0, t1, t2, t3];
        for c in 0..chunks {
            let k = c * 16;
            let vq = vld1q_s8(q.as_ptr().add(k));
            for (r, t) in rows.iter().enumerate() {
                acc[r] = padal_i8_chunk(acc[r], vq, vld1q_s8(t.as_ptr().add(k)));
            }
        }
        let mut out = [0i32; 4];
        for r in 0..4 {
            out[r] = vaddvq_s32(acc[r]);
        }
        for k in chunks * 16..d {
            let x = q[k] as i32;
            for (r, t) in rows.iter().enumerate() {
                out[r] = out[r].wrapping_add(x * t[k] as i32);
            }
        }
        out
    }
}

// ---------------------------------------------------------------------------
// Dispatched entry points. The `_with` variants take an explicit backend
// (tiles resolve [`active`] once and pass it per block; parity tests force
// each reachable backend); the plain variants dispatch on [`active`]. A
// backend the host cannot execute silently degrades to scalar — [`resolve`]
// never *selects* such a backend, this is the safety net for explicit
// `_with` calls.
// ---------------------------------------------------------------------------

/// Dot product of two equal-length rows (8-lane blocked; the reduction
/// order of `sim::measure::dot`).
#[inline]
pub fn dot(a: &[f32], b: &[f32]) -> f32 {
    dot_with(active(), a, b)
}

/// [`dot`] on an explicit backend.
#[inline]
pub fn dot_with(backend: SimdBackend, a: &[f32], b: &[f32]) -> f32 {
    debug_assert_eq!(a.len(), b.len());
    match backend {
        #[cfg(target_arch = "x86_64")]
        SimdBackend::Avx2 if supported(SimdBackend::Avx2) => unsafe { avx2::dot(a, b) },
        #[cfg(target_arch = "aarch64")]
        SimdBackend::Neon if supported(SimdBackend::Neon) => unsafe { neon::dot(a, b) },
        _ => dot_scalar(a, b),
    }
}

/// Dot of `leader` against four rows at once (`sim::batch::dot_tile`'s
/// block kernel).
#[inline]
pub fn dot_block4(leader: &[f32], t0: &[f32], t1: &[f32], t2: &[f32], t3: &[f32]) -> [f32; 4] {
    dot_block4_with(active(), leader, t0, t1, t2, t3)
}

/// [`dot_block4`] on an explicit backend.
#[inline]
pub fn dot_block4_with(
    backend: SimdBackend,
    leader: &[f32],
    t0: &[f32],
    t1: &[f32],
    t2: &[f32],
    t3: &[f32],
) -> [f32; 4] {
    let d = leader.len();
    debug_assert!(t0.len() == d && t1.len() == d && t2.len() == d && t3.len() == d);
    match backend {
        #[cfg(target_arch = "x86_64")]
        SimdBackend::Avx2 if supported(SimdBackend::Avx2) => unsafe {
            avx2::dot_block4(leader, t0, t1, t2, t3)
        },
        #[cfg(target_arch = "aarch64")]
        SimdBackend::Neon if supported(SimdBackend::Neon) => unsafe {
            neon::dot_block4(leader, t0, t1, t2, t3)
        },
        _ => dot_block4_scalar(leader, t0, t1, t2, t3),
    }
}

/// Dots of one row against a plane pair (`lsh::sketch::sketch_row_scalar`'s
/// pair kernel).
#[inline]
pub fn sketch_row2(p0: &[f32], p1: &[f32], row: &[f32]) -> (f32, f32) {
    sketch_row2_with(active(), p0, p1, row)
}

/// [`sketch_row2`] on an explicit backend.
#[inline]
pub fn sketch_row2_with(backend: SimdBackend, p0: &[f32], p1: &[f32], row: &[f32]) -> (f32, f32) {
    debug_assert!(p0.len() == row.len() && p1.len() == row.len());
    match backend {
        #[cfg(target_arch = "x86_64")]
        SimdBackend::Avx2 if supported(SimdBackend::Avx2) => unsafe {
            avx2::sketch_row2(p0, p1, row)
        },
        #[cfg(target_arch = "aarch64")]
        SimdBackend::Neon if supported(SimdBackend::Neon) => unsafe {
            neon::sketch_row2(p0, p1, row)
        },
        _ => sketch_row2_scalar(p0, p1, row),
    }
}

/// Dots of four rows against a plane pair (`lsh::sketch::sketch_tile`'s
/// block kernel): `(dots vs p0, dots vs p1)`.
#[inline]
pub fn sketch_block4(
    p0: &[f32],
    p1: &[f32],
    t0: &[f32],
    t1: &[f32],
    t2: &[f32],
    t3: &[f32],
) -> ([f32; 4], [f32; 4]) {
    sketch_block4_with(active(), p0, p1, t0, t1, t2, t3)
}

/// [`sketch_block4`] on an explicit backend.
#[inline]
#[allow(clippy::too_many_arguments)]
pub fn sketch_block4_with(
    backend: SimdBackend,
    p0: &[f32],
    p1: &[f32],
    t0: &[f32],
    t1: &[f32],
    t2: &[f32],
    t3: &[f32],
) -> ([f32; 4], [f32; 4]) {
    let d = p0.len();
    debug_assert!(
        p1.len() == d && t0.len() == d && t1.len() == d && t2.len() == d && t3.len() == d
    );
    match backend {
        #[cfg(target_arch = "x86_64")]
        SimdBackend::Avx2 if supported(SimdBackend::Avx2) => unsafe {
            avx2::sketch_block4(p0, p1, t0, t1, t2, t3)
        },
        #[cfg(target_arch = "aarch64")]
        SimdBackend::Neon if supported(SimdBackend::Neon) => unsafe {
            neon::sketch_block4(p0, p1, t0, t1, t2, t3)
        },
        _ => sketch_block4_scalar(p0, p1, t0, t1, t2, t3),
    }
}

/// Int8 dot product, the quantized first-pass kernel (`sim::quant`).
///
/// Accumulates in i32 — integer adds are associative, so **every backend
/// returns the same integer** (a stronger guarantee than the f32 kernels'
/// pinned reduction order). Operands must be SQ8 codes in `[-127, 127]`:
/// the AVX2 port's `maddubs` pairing would saturate on `-128`.
#[inline]
pub fn dot_i8(a: &[i8], b: &[i8]) -> i32 {
    dot_i8_with(active(), a, b)
}

/// [`dot_i8`] on an explicit backend.
#[inline]
pub fn dot_i8_with(backend: SimdBackend, a: &[i8], b: &[i8]) -> i32 {
    debug_assert_eq!(a.len(), b.len());
    match backend {
        #[cfg(target_arch = "x86_64")]
        SimdBackend::Avx2 if supported(SimdBackend::Avx2) => unsafe { avx2::dot_i8(a, b) },
        #[cfg(target_arch = "aarch64")]
        SimdBackend::Neon if supported(SimdBackend::Neon) => unsafe { neon::dot_i8(a, b) },
        _ => dot_i8_scalar(a, b),
    }
}

/// Int8 dot of `q` against four candidate rows at once — the block kernel
/// of the quantized first pass. Same integer-exact guarantee as
/// [`dot_i8`].
#[inline]
pub fn dot_i8_block4(q: &[i8], t0: &[i8], t1: &[i8], t2: &[i8], t3: &[i8]) -> [i32; 4] {
    dot_i8_block4_with(active(), q, t0, t1, t2, t3)
}

/// [`dot_i8_block4`] on an explicit backend.
#[inline]
pub fn dot_i8_block4_with(
    backend: SimdBackend,
    q: &[i8],
    t0: &[i8],
    t1: &[i8],
    t2: &[i8],
    t3: &[i8],
) -> [i32; 4] {
    let d = q.len();
    debug_assert!(t0.len() == d && t1.len() == d && t2.len() == d && t3.len() == d);
    match backend {
        #[cfg(target_arch = "x86_64")]
        SimdBackend::Avx2 if supported(SimdBackend::Avx2) => unsafe {
            avx2::dot_i8_block4(q, t0, t1, t2, t3)
        },
        #[cfg(target_arch = "aarch64")]
        SimdBackend::Neon if supported(SimdBackend::Neon) => unsafe {
            neon::dot_i8_block4(q, t0, t1, t2, t3)
        },
        _ => dot_i8_block4_scalar(q, t0, t1, t2, t3),
    }
}

/// Sum of a weight slice in a fixed 4-lane blocked order (lanes, then the
/// `((s0+s1)+s2)+s3` lane sum, then the sequential tail). All backends
/// agree bit-for-bit; callers migrating from a strictly sequential
/// `iter().sum()` accept a one-time ulp-level reassociation.
#[inline]
pub fn sum_f32(xs: &[f32]) -> f32 {
    sum_f32_with(active(), xs)
}

/// [`sum_f32`] on an explicit backend.
#[inline]
pub fn sum_f32_with(backend: SimdBackend, xs: &[f32]) -> f32 {
    match backend {
        #[cfg(target_arch = "x86_64")]
        SimdBackend::Avx2 if supported(SimdBackend::Avx2) => unsafe { avx2::sum_f32(xs) },
        #[cfg(target_arch = "aarch64")]
        SimdBackend::Neon if supported(SimdBackend::Neon) => unsafe { neon::sum_f32(xs) },
        _ => sum_f32_scalar(xs),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn vecf(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| rng.gaussian() as f32).collect()
    }

    #[test]
    fn backend_names_round_trip() {
        for b in [SimdBackend::Scalar, SimdBackend::Avx2, SimdBackend::Neon] {
            assert_eq!(SimdBackend::parse(b.name()), Some(b));
        }
        assert_eq!(SimdBackend::parse("AVX2"), Some(SimdBackend::Avx2));
        assert_eq!(SimdBackend::parse("sse9"), None);
    }

    #[test]
    fn resolve_policy() {
        assert_eq!(resolve(None), detected());
        assert_eq!(resolve(Some("scalar")), SimdBackend::Scalar);
        assert_eq!(resolve(Some("garbage")), detected());
        // Requesting each real backend yields it when supported, scalar
        // otherwise — never an unsupported backend.
        for (req, b) in [("avx2", SimdBackend::Avx2), ("neon", SimdBackend::Neon)] {
            let got = resolve(Some(req));
            if supported(b) {
                assert_eq!(got, b);
            } else {
                assert_eq!(got, SimdBackend::Scalar);
            }
        }
    }

    #[test]
    fn reachable_starts_scalar_and_is_supported() {
        let r = reachable();
        assert_eq!(r[0], SimdBackend::Scalar);
        assert!(r.iter().all(|&b| supported(b)));
        assert!(r.contains(&active()), "active backend must be reachable");
    }

    #[test]
    fn all_reachable_backends_are_bit_identical() {
        for backend in reachable() {
            for d in [0usize, 1, 3, 4, 7, 8, 15, 16, 100, 784] {
                let a = vecf(d, 1 + d as u64);
                let b = vecf(d, 100 + d as u64);
                let t = [
                    vecf(d, 7),
                    vecf(d, 8),
                    vecf(d, 9),
                    vecf(d, 10),
                ];
                assert_eq!(
                    dot_with(backend, &a, &b).to_bits(),
                    dot_with(SimdBackend::Scalar, &a, &b).to_bits(),
                    "dot {:?} d={d}",
                    backend
                );
                let got = dot_block4_with(backend, &a, &t[0], &t[1], &t[2], &t[3]);
                let want = dot_block4_with(SimdBackend::Scalar, &a, &t[0], &t[1], &t[2], &t[3]);
                assert_eq!(
                    got.map(f32::to_bits),
                    want.map(f32::to_bits),
                    "dot_block4 {:?} d={d}",
                    backend
                );
                let got = sketch_row2_with(backend, &a, &b, &t[0]);
                let want = sketch_row2_with(SimdBackend::Scalar, &a, &b, &t[0]);
                assert_eq!(
                    (got.0.to_bits(), got.1.to_bits()),
                    (want.0.to_bits(), want.1.to_bits()),
                    "sketch_row2 {:?} d={d}",
                    backend
                );
                let got = sketch_block4_with(backend, &a, &b, &t[0], &t[1], &t[2], &t[3]);
                let want =
                    sketch_block4_with(SimdBackend::Scalar, &a, &b, &t[0], &t[1], &t[2], &t[3]);
                assert_eq!(
                    (got.0.map(f32::to_bits), got.1.map(f32::to_bits)),
                    (want.0.map(f32::to_bits), want.1.map(f32::to_bits)),
                    "sketch_block4 {:?} d={d}",
                    backend
                );
                assert_eq!(
                    sum_f32_with(backend, &a).to_bits(),
                    sum_f32_with(SimdBackend::Scalar, &a).to_bits(),
                    "sum_f32 {:?} d={d}",
                    backend
                );
            }
        }
    }

    /// SQ8-range codes: uniform in [-127, 127], never -128.
    fn veci8(n: usize, seed: u64) -> Vec<i8> {
        let mut rng = Rng::new(seed);
        (0..n).map(|_| ((rng.next_u64() % 255) as i32 - 127) as i8).collect()
    }

    #[test]
    fn int8_kernels_are_integer_exact_across_backends() {
        // Stronger than the f32 `.to_bits()` checks: the i32 results must
        // be *equal* on every reachable backend, for every lane/tail
        // combination (32-lane AVX2 chunks, 16-lane NEON chunks, tails).
        for backend in reachable() {
            for d in [0usize, 1, 3, 15, 16, 17, 31, 32, 33, 100, 784] {
                let a = veci8(d, 1 + d as u64);
                let b = veci8(d, 100 + d as u64);
                let t = [veci8(d, 7), veci8(d, 8), veci8(d, 9), veci8(d, 10)];
                assert_eq!(
                    dot_i8_with(backend, &a, &b),
                    dot_i8_with(SimdBackend::Scalar, &a, &b),
                    "dot_i8 {:?} d={d}",
                    backend
                );
                assert_eq!(
                    dot_i8_block4_with(backend, &a, &t[0], &t[1], &t[2], &t[3]),
                    dot_i8_block4_with(SimdBackend::Scalar, &a, &t[0], &t[1], &t[2], &t[3]),
                    "dot_i8_block4 {:?} d={d}",
                    backend
                );
            }
        }
    }

    #[test]
    fn int8_scalar_reference_matches_naive() {
        let a = veci8(100, 21);
        let b = veci8(100, 22);
        let naive: i32 = a.iter().zip(&b).map(|(&x, &y)| x as i32 * y as i32).sum();
        assert_eq!(dot_i8_with(SimdBackend::Scalar, &a, &b), naive);
        let saturating = vec![127i8; 784];
        let negated = vec![-127i8; 784];
        // The worst case the quantizer can produce — exercises the maddubs
        // no-saturation bound on AVX2 hosts via the parity test above, and
        // the exact extreme value here.
        assert_eq!(dot_i8_with(SimdBackend::Scalar, &saturating, &negated), -127 * 127 * 784);
    }

    #[test]
    fn dispatched_entry_points_match_active_backend() {
        let b = active();
        let a = vecf(37, 5);
        let x = vecf(37, 6);
        assert_eq!(dot(&a, &x).to_bits(), dot_with(b, &a, &x).to_bits());
        assert_eq!(sum_f32(&a).to_bits(), sum_f32_with(b, &a).to_bits());
        let qa = veci8(37, 5);
        let qx = veci8(37, 6);
        assert_eq!(dot_i8(&qa, &qx), dot_i8_with(b, &qa, &qx));
    }

    #[test]
    fn unsupported_with_request_degrades_to_scalar() {
        // Whichever wide backend the host lacks must fall back to scalar
        // bits instead of faulting.
        let a = vecf(64, 2);
        let b = vecf(64, 3);
        for backend in [SimdBackend::Avx2, SimdBackend::Neon] {
            if !supported(backend) {
                assert_eq!(
                    dot_with(backend, &a, &b).to_bits(),
                    dot_with(SimdBackend::Scalar, &a, &b).to_bits()
                );
            }
        }
    }

    #[test]
    fn scalar_dot_matches_naive_within_tolerance() {
        // Sanity: the blocked order is a reassociation of the plain sum.
        let a = vecf(100, 11);
        let b = vecf(100, 12);
        let naive: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        assert!((dot_with(SimdBackend::Scalar, &a, &b) - naive).abs() < 1e-3);
    }
}
