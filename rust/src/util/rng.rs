//! Deterministic, splittable pseudo-random number generation.
//!
//! Two generators:
//!
//! * [`SplitMix64`] — the canonical 64-bit mixer. Used both for seeding and
//!   as the *shared recipe generator*: `python/compile/recipe.py` implements
//!   the identical stream so dataset class prototypes generated in rust match
//!   the ones the learned similarity model is trained on in python.
//! * [`Rng`] (xoshiro256\*\*) — the general-purpose workhorse for everything
//!   else (bucket leader sampling, shuffles, Gaussian noise, ...).
//!
//! All experiment code takes explicit seeds; a run is reproducible bit-for-bit.

/// SplitMix64: tiny, high-quality 64-bit generator (Steele et al.).
///
/// Mirrored exactly in `python/compile/recipe.py` — do not change constants
/// without updating the python side and regenerating artifacts.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// Create a generator from a seed.
    pub fn new(seed: u64) -> Self {
        SplitMix64 { state: seed }
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }

    /// Uniform f64 in [0, 1) using the top 53 bits.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Standard normal via Box–Muller (always consumes two uniforms;
    /// no cached spare, so the stream layout is trivial to mirror).
    #[inline]
    pub fn next_gaussian(&mut self) -> f64 {
        // Guard against log(0).
        let mut u1 = self.next_f64();
        if u1 < 1e-300 {
            u1 = 1e-300;
        }
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }
}

/// Derive a fresh independent seed from a parent seed and a stream index.
/// Used to give each worker / repetition / hash function its own stream.
pub fn derive_seed(parent: u64, stream: u64) -> u64 {
    let mut sm = SplitMix64::new(parent ^ stream.wrapping_mul(0xA076_1D64_78BD_642F));
    sm.next_u64()
}

/// xoshiro256** — fast general-purpose PRNG (Blackman & Vigna).
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 expansion (the recommended seeding procedure).
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Rng {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
        }
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Next 32-bit output (upper half of a 64-bit draw).
    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u64() >> 40) as f32 * (1.0 / (1u64 << 24) as f32)
    }

    /// Uniform integer in [0, bound) without modulo bias (Lemire reduction).
    #[inline]
    pub fn below(&mut self, bound: usize) -> usize {
        debug_assert!(bound > 0);
        ((self.next_u64() as u128 * bound as u128) >> 64) as usize
    }

    /// Uniform integer in [lo, hi).
    #[inline]
    pub fn range(&mut self, lo: usize, hi: usize) -> usize {
        debug_assert!(lo < hi);
        lo + self.below(hi - lo)
    }

    /// Bernoulli draw with probability p.
    #[inline]
    pub fn bool(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Standard normal via Box–Muller.
    #[inline]
    pub fn gaussian(&mut self) -> f64 {
        let mut u1 = self.next_f64();
        if u1 < 1e-300 {
            u1 = 1e-300;
        }
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Normal with given mean and standard deviation, as f32.
    #[inline]
    pub fn gaussian32(&mut self, mean: f32, std: f32) -> f32 {
        mean + std * self.gaussian() as f32
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from [0, n) (Floyd's algorithm for small k,
    /// shuffle for large k). Result order is unspecified.
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        let k = k.min(n);
        if k == 0 {
            return Vec::new();
        }
        if k * 4 >= n {
            let mut all: Vec<usize> = (0..n).collect();
            self.shuffle(&mut all);
            all.truncate(k);
            return all;
        }
        // Floyd: for j in n-k..n, pick t in [0..j], insert t or j.
        let mut chosen = crate::util::fxhash::FxHashSet::default();
        let mut out = Vec::with_capacity(k);
        for j in (n - k)..n {
            let t = self.below(j + 1);
            if chosen.insert(t) {
                out.push(t);
            } else {
                chosen.insert(j);
                out.push(j);
            }
        }
        out
    }

    /// One draw from a Zipf(s) distribution over {0, .., n-1} by inverse CDF
    /// on a precomputed table — see [`ZipfTable`]. Provided here for
    /// convenience in tests.
    pub fn zipf(&mut self, table: &ZipfTable) -> usize {
        table.sample(self)
    }
}

/// Precomputed inverse-CDF table for a Zipf distribution: P(i) ∝ 1/(i+1)^s.
#[derive(Clone, Debug)]
pub struct ZipfTable {
    cdf: Vec<f64>,
}

impl ZipfTable {
    /// Build the table for support size `n` and exponent `s`.
    pub fn new(n: usize, s: f64) -> Self {
        assert!(n > 0);
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0;
        for i in 0..n {
            acc += 1.0 / ((i + 1) as f64).powf(s);
            cdf.push(acc);
        }
        let total = acc;
        for c in &mut cdf {
            *c /= total;
        }
        ZipfTable { cdf }
    }

    /// Draw one index.
    pub fn sample(&self, rng: &mut Rng) -> usize {
        let u = rng.next_f64();
        match self
            .cdf
            .binary_search_by(|c| c.partial_cmp(&u).unwrap())
        {
            Ok(i) => i,
            Err(i) => i.min(self.cdf.len() - 1),
        }
    }

    /// Support size.
    pub fn len(&self) -> usize {
        self.cdf.len()
    }

    /// True if the support is empty (never, by construction).
    pub fn is_empty(&self) -> bool {
        self.cdf.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn splitmix_known_values() {
        // Reference values for seed=0 (cross-checked with the published
        // SplitMix64 reference implementation; python recipe.py asserts the
        // same three values).
        let mut sm = SplitMix64::new(0);
        assert_eq!(sm.next_u64(), 0xE220A8397B1DCDAF);
        assert_eq!(sm.next_u64(), 0x6E789E6AA1B965F4);
        assert_eq!(sm.next_u64(), 0x06C45D188009454F);
    }

    #[test]
    fn splitmix_f64_in_unit_interval() {
        let mut sm = SplitMix64::new(123);
        for _ in 0..1000 {
            let x = sm.next_f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn rng_determinism() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn rng_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(same, 0);
    }

    #[test]
    fn below_is_in_range_and_roughly_uniform() {
        let mut rng = Rng::new(7);
        let mut counts = [0usize; 10];
        for _ in 0..100_000 {
            let x = rng.below(10);
            counts[x] += 1;
        }
        for &c in &counts {
            assert!((8_000..12_000).contains(&c), "count {c} out of tolerance");
        }
    }

    #[test]
    fn gaussian_moments() {
        let mut rng = Rng::new(99);
        let n = 200_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let g = rng.gaussian();
            sum += g;
            sq += g * g;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Rng::new(5);
        let mut xs: Vec<usize> = (0..100).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn sample_indices_distinct_and_in_range() {
        let mut rng = Rng::new(11);
        for &(n, k) in &[(100usize, 5usize), (100, 50), (100, 100), (10, 20), (1, 1)] {
            let s = rng.sample_indices(n, k);
            assert_eq!(s.len(), k.min(n));
            let set: std::collections::HashSet<_> = s.iter().collect();
            assert_eq!(set.len(), s.len(), "duplicates for n={n} k={k}");
            assert!(s.iter().all(|&i| i < n));
        }
    }

    #[test]
    fn zipf_is_skewed() {
        let table = ZipfTable::new(1000, 1.1);
        let mut rng = Rng::new(3);
        let mut head = 0;
        let trials = 10_000;
        for _ in 0..trials {
            if table.sample(&mut rng) < 10 {
                head += 1;
            }
        }
        // Zipf(1.1) over 1000 puts a large constant mass on the top 10.
        assert!(head > trials / 4, "head mass {head}");
    }

    #[test]
    fn derive_seed_decorrelates() {
        let a = derive_seed(42, 0);
        let b = derive_seed(42, 1);
        assert_ne!(a, b);
        assert_ne!(a, 42);
    }
}
