//! Leveled stderr logger with elapsed-time stamps.
//!
//! Verbosity defaults to `Info` and can be set two ways: programmatically
//! via [`set_level`], or through the `STARS_LOG=error|info|debug` env var,
//! consumed once at the first [`level`]/[`log`] call (an explicit
//! [`set_level`] always wins). When the `STARS_TRACE` NDJSON sink is
//! active, every line at or above the active level is additionally routed
//! into it as a `{"kind": "log", ...}` event (see `crate::obs::sink`).

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

/// Log verbosity.
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Debug)]
pub enum Level {
    Error = 0,
    Info = 1,
    Debug = 2,
}

/// Sentinel: level not yet initialized from `STARS_LOG`.
const UNSET: u8 = u8::MAX;

static LEVEL: AtomicU8 = AtomicU8::new(UNSET);
static START: OnceLock<Instant> = OnceLock::new();

/// Set global verbosity (overrides `STARS_LOG`).
pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

/// Current verbosity; the first call consumes `STARS_LOG` (default
/// `Info` when unset or unparseable).
pub fn level() -> Level {
    match LEVEL.load(Ordering::Relaxed) {
        0 => Level::Error,
        1 => Level::Info,
        2 => Level::Debug,
        _ => {
            let from_env = match std::env::var("STARS_LOG").as_deref() {
                Ok("error") | Ok("ERROR") => Level::Error,
                Ok("debug") | Ok("DEBUG") => Level::Debug,
                _ => Level::Info,
            };
            // A concurrent set_level wins over the env default.
            let _ = LEVEL.compare_exchange(
                UNSET,
                from_env as u8,
                Ordering::Relaxed,
                Ordering::Relaxed,
            );
            level()
        }
    }
}

/// Seconds since first log call.
pub fn elapsed() -> f64 {
    START.get_or_init(Instant::now).elapsed().as_secs_f64()
}

/// Emit a log line if `lvl` is enabled; enabled lines are also routed to
/// the `STARS_TRACE` NDJSON sink when one is active.
pub fn log(lvl: Level, msg: std::fmt::Arguments<'_>) {
    if lvl <= level() {
        let tag = match lvl {
            Level::Error => "ERR ",
            Level::Info => "INFO",
            Level::Debug => "DBG ",
        };
        eprintln!("[{:9.3}s {}] {}", elapsed(), tag, msg);
        if crate::obs::sink::enabled() {
            let name = match lvl {
                Level::Error => "error",
                Level::Info => "info",
                Level::Debug => "debug",
            };
            crate::obs::sink::emit_log(name, &format!("{msg}"));
        }
    }
}

/// Info-level log.
#[macro_export]
macro_rules! info {
    ($($t:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Info, format_args!($($t)*))
    };
}

/// Debug-level log.
#[macro_export]
macro_rules! debug {
    ($($t:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Debug, format_args!($($t)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_roundtrip() {
        let prev = level();
        set_level(Level::Debug);
        assert_eq!(level(), Level::Debug);
        set_level(Level::Error);
        assert_eq!(level(), Level::Error);
        set_level(prev);
    }

    #[test]
    fn elapsed_monotone() {
        let a = elapsed();
        let b = elapsed();
        assert!(b >= a);
    }
}
