//! Leveled stderr logger with elapsed-time stamps.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

/// Log verbosity.
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Debug)]
pub enum Level {
    Error = 0,
    Info = 1,
    Debug = 2,
}

static LEVEL: AtomicU8 = AtomicU8::new(1);
static START: OnceLock<Instant> = OnceLock::new();

/// Set global verbosity.
pub fn set_level(level: Level) {
    LEVEL.store(level as u8, Ordering::Relaxed);
}

/// Current verbosity.
pub fn level() -> Level {
    match LEVEL.load(Ordering::Relaxed) {
        0 => Level::Error,
        1 => Level::Info,
        _ => Level::Debug,
    }
}

/// Seconds since first log call.
pub fn elapsed() -> f64 {
    START.get_or_init(Instant::now).elapsed().as_secs_f64()
}

/// Emit a log line if `lvl` is enabled.
pub fn log(lvl: Level, msg: std::fmt::Arguments<'_>) {
    if lvl <= level() {
        let tag = match lvl {
            Level::Error => "ERR ",
            Level::Info => "INFO",
            Level::Debug => "DBG ",
        };
        eprintln!("[{:9.3}s {}] {}", elapsed(), tag, msg);
    }
}

/// Info-level log.
#[macro_export]
macro_rules! info {
    ($($t:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Info, format_args!($($t)*))
    };
}

/// Debug-level log.
#[macro_export]
macro_rules! debug {
    ($($t:tt)*) => {
        $crate::util::logging::log($crate::util::logging::Level::Debug, format_args!($($t)*))
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_roundtrip() {
        let prev = level();
        set_level(Level::Debug);
        assert_eq!(level(), Level::Debug);
        set_level(Level::Error);
        assert_eq!(level(), Level::Error);
        set_level(prev);
    }

    #[test]
    fn elapsed_monotone() {
        let a = elapsed();
        let b = elapsed();
        assert!(b >= a);
    }
}
