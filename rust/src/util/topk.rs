//! Bounded top-k selection.
//!
//! The paper's graph post-processing keeps only the 250 most-similar
//! neighbors per node ("degree threshold"). [`TopK`] is a fixed-capacity
//! min-heap keyed on similarity: inserting is O(log k) and only when the
//! candidate beats the current worst retained item.

/// Fixed-capacity collector of the k largest items by f32 score.
#[derive(Clone, Debug)]
pub struct TopK<T> {
    k: usize,
    // Min-heap on score, realized as a binary heap over (negated order).
    heap: Vec<(f32, T)>,
}

impl<T: Clone> TopK<T> {
    /// Collector retaining the `k` highest-scoring items.
    pub fn new(k: usize) -> Self {
        TopK {
            k,
            heap: Vec::with_capacity(k.min(1024)),
        }
    }

    /// Current worst retained score (None until full).
    pub fn threshold(&self) -> Option<f32> {
        if self.heap.len() >= self.k {
            self.heap.first().map(|(s, _)| *s)
        } else {
            None
        }
    }

    /// Offer an item; keeps it only if it is among the k best seen so far.
    #[inline]
    pub fn push(&mut self, score: f32, item: T) {
        if self.k == 0 {
            return;
        }
        if self.heap.len() < self.k {
            self.heap.push((score, item));
            self.sift_up(self.heap.len() - 1);
        } else if score > self.heap[0].0 {
            self.heap[0] = (score, item);
            self.sift_down(0);
        }
    }

    fn sift_up(&mut self, mut i: usize) {
        while i > 0 {
            let parent = (i - 1) / 2;
            if self.heap[i].0 < self.heap[parent].0 {
                self.heap.swap(i, parent);
                i = parent;
            } else {
                break;
            }
        }
    }

    fn sift_down(&mut self, mut i: usize) {
        let n = self.heap.len();
        loop {
            let (l, r) = (2 * i + 1, 2 * i + 2);
            let mut smallest = i;
            if l < n && self.heap[l].0 < self.heap[smallest].0 {
                smallest = l;
            }
            if r < n && self.heap[r].0 < self.heap[smallest].0 {
                smallest = r;
            }
            if smallest == i {
                break;
            }
            self.heap.swap(i, smallest);
            i = smallest;
        }
    }

    /// Number of retained items.
    pub fn len(&self) -> usize {
        self.heap.len()
    }

    /// True when nothing has been retained.
    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    /// Extract items sorted by descending score.
    pub fn into_sorted(mut self) -> Vec<(f32, T)> {
        self.heap
            .sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap_or(std::cmp::Ordering::Equal));
        self.heap
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn keeps_exactly_top_k() {
        let mut t = TopK::new(3);
        for (i, s) in [5.0f32, 1.0, 9.0, 3.0, 7.0, 2.0].iter().enumerate() {
            t.push(*s, i);
        }
        let out = t.into_sorted();
        let scores: Vec<f32> = out.iter().map(|(s, _)| *s).collect();
        assert_eq!(scores, vec![9.0, 7.0, 5.0]);
        let items: Vec<usize> = out.iter().map(|(_, i)| *i).collect();
        assert_eq!(items, vec![2, 4, 0]);
    }

    #[test]
    fn matches_full_sort_randomized() {
        let mut rng = Rng::new(17);
        for _ in 0..50 {
            let n = rng.range(1, 200);
            let k = rng.range(1, 50);
            let xs: Vec<f32> = (0..n).map(|_| rng.next_f32()).collect();
            let mut t = TopK::new(k);
            for (i, &x) in xs.iter().enumerate() {
                t.push(x, i);
            }
            let got: Vec<f32> = t.into_sorted().into_iter().map(|(s, _)| s).collect();
            let mut want = xs.clone();
            want.sort_by(|a, b| b.partial_cmp(a).unwrap());
            want.truncate(k);
            assert_eq!(got, want);
        }
    }

    #[test]
    fn k_zero_is_noop() {
        let mut t = TopK::new(0);
        t.push(1.0, "x");
        assert!(t.is_empty());
        assert!(t.into_sorted().is_empty());
    }

    #[test]
    fn threshold_reports_worst_kept() {
        let mut t = TopK::new(2);
        assert_eq!(t.threshold(), None);
        t.push(1.0, ());
        assert_eq!(t.threshold(), None);
        t.push(5.0, ());
        assert_eq!(t.threshold(), Some(1.0));
        t.push(3.0, ());
        assert_eq!(t.threshold(), Some(3.0));
    }
}
