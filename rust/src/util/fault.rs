//! Deterministic fault injection: seeded crash / delay / corruption
//! schedules for the AMPC pipeline.
//!
//! At tera scale worker failure is the steady state, not the exception, so
//! the recovery paths (task retry, wave restart, checksum re-fetch) need to
//! be exercised continuously — but a fault test that cannot be replayed is
//! worse than none. A [`FaultPlan`] is therefore a pure function of
//! `(seed, round, task, attempt)`: the same plan injects the same faults at
//! the same points in every run, on any worker count, which is what lets
//! `tests/fault_injection.rs` assert the hard invariant that build output
//! and serve top-k are **bit-identical** under any schedule (recovery is
//! pure re-execution of deterministic tasks).
//!
//! A plan is typically supplied through the `STARS_FAULTS` environment
//! variable (read once per [`crate::ampc::Cluster`] construction):
//!
//! ```text
//! STARS_FAULTS="seed=7,crash=0.1,delay=0.05:40,corrupt=0.05,max_failures=2"
//! ```
//!
//! * `crash=P` — before executing, a task crashes with probability `P`
//!   until it has accumulated `max_failures` recorded failures; retries
//!   then run it clean (the schedule models "this task's host died twice").
//! * `delay=P:MS` — a task's *first* attempt is stalled `MS` milliseconds
//!   with probability `P` (a straggler; the re-execution pass covers it).
//! * `corrupt=P` — a shuffle partition / DHT batch response fails its
//!   checksum with probability `P` on each of the first `max_failures`
//!   attempts, forcing a re-fetch/re-sort.
//! * `max_failures=N` — per-decision-point failure budget (default 2).
//!
//! Tests should *not* set the env var (parallel test threads race on it);
//! they pin a plan explicitly via `StarsBuilder::faults` /
//! `Cluster::with_faults`.

use crate::util::rng::{derive_seed, SplitMix64};

/// Stream-id salt separating crash/delay draws from corruption draws.
const CORRUPT_TAG: u64 = 0xC0DE_D1CE_BAD_F00D;
/// Stream-id salt separating the round dimension from raw task ids.
const ROUND_TAG: u64 = 0x5EED_0FA1_1ED_40B5;

/// What a task's next attempt should suffer, per the plan.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Fault {
    /// Run clean.
    None,
    /// The task's host "dies" before producing a result.
    Crash,
    /// The task is stalled for the given number of milliseconds first.
    Delay(u64),
}

/// A seeded, replayable fault schedule. `Copy` so it rides on the shared
/// [`crate::ampc::CostLedger`] without lifetime plumbing.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FaultPlan {
    /// Root seed; every decision point derives its own stream from it.
    pub seed: u64,
    /// Per-(round, task) crash probability while under the failure budget.
    pub crash_prob: f64,
    /// Probability a task's first attempt is delayed.
    pub delay_prob: f64,
    /// Injected delay length, milliseconds.
    pub delay_ms: u64,
    /// Per-attempt checksum-corruption probability for shuffle/DHT traffic.
    pub corrupt_prob: f64,
    /// How many failures each decision point may accumulate before the
    /// schedule lets it through (bounds injected retries; a real system's
    /// analogue is "the scheduler moved the task to a healthy host").
    pub max_failures: u32,
}

impl FaultPlan {
    /// The inert plan: injects nothing, adds no overhead on hot paths.
    pub fn none() -> FaultPlan {
        FaultPlan {
            seed: 0,
            crash_prob: 0.0,
            delay_prob: 0.0,
            delay_ms: 0,
            corrupt_prob: 0.0,
            max_failures: 2,
        }
    }

    /// True if any fault kind has nonzero probability.
    pub fn is_active(&self) -> bool {
        self.crash_prob > 0.0 || self.delay_prob > 0.0 || self.corrupt_prob > 0.0
    }

    /// Read the plan from `STARS_FAULTS`, or the inert plan when unset.
    /// A malformed spec is a configuration error and panics loudly rather
    /// than silently running fault-free.
    pub fn from_env() -> FaultPlan {
        match std::env::var("STARS_FAULTS") {
            Ok(spec) => match FaultPlan::parse(&spec) {
                Ok(p) => p,
                Err(e) => panic!("invalid STARS_FAULTS spec {spec:?}: {e}"),
            },
            Err(_) => FaultPlan::none(),
        }
    }

    /// Parse a `key=value` comma list, e.g.
    /// `"seed=7,crash=0.1,delay=0.05:40,corrupt=0.05,max_failures=2"`.
    pub fn parse(spec: &str) -> Result<FaultPlan, String> {
        let mut plan = FaultPlan::none();
        for part in spec.split(',') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            let (key, val) = part
                .split_once('=')
                .ok_or_else(|| format!("expected key=value, got {part:?}"))?;
            let bad = |e: &dyn std::fmt::Display| format!("bad value for {key}: {e}");
            match key {
                "seed" => plan.seed = val.parse().map_err(|e| bad(&e))?,
                "crash" => plan.crash_prob = parse_prob(key, val)?,
                "delay" => {
                    // delay=P or delay=P:MS (MS defaults to 20).
                    let (p, ms) = match val.split_once(':') {
                        Some((p, ms)) => {
                            (parse_prob(key, p)?, ms.parse().map_err(|e| bad(&e))?)
                        }
                        None => (parse_prob(key, val)?, 20),
                    };
                    plan.delay_prob = p;
                    plan.delay_ms = ms;
                }
                "corrupt" => plan.corrupt_prob = parse_prob(key, val)?,
                "max_failures" => plan.max_failures = val.parse().map_err(|e| bad(&e))?,
                _ => return Err(format!("unknown fault key {key:?}")),
            }
        }
        Ok(plan)
    }

    /// What should attempt number `attempt` (0-based count of *recorded
    /// failures* at this decision point) of task `task` in round `round`
    /// suffer? Pure: same arguments, same answer, forever.
    pub fn decide(&self, round: u64, task: u64, attempt: u32) -> Fault {
        if !self.is_active() {
            return Fault::None;
        }
        let mut sm = SplitMix64::new(derive_seed(
            derive_seed(self.seed, round ^ ROUND_TAG),
            task,
        ));
        let u_crash = sm.next_f64();
        let u_delay = sm.next_f64();
        if self.crash_prob > 0.0 && u_crash < self.crash_prob && attempt < self.max_failures {
            return Fault::Crash;
        }
        if self.delay_prob > 0.0 && u_delay < self.delay_prob && attempt == 0 {
            return Fault::Delay(self.delay_ms);
        }
        Fault::None
    }

    /// Should the payload identified by `stream` (a content digest or a
    /// derived partition id) fail its checksum on attempt `attempt`?
    /// Injection stops after `max_failures` attempts so a plan with
    /// `corrupt=1.0` still terminates — deterministically, after exactly
    /// `max_failures` retries per payload.
    pub fn corrupt(&self, stream: u64, attempt: u32) -> bool {
        if self.corrupt_prob <= 0.0 || attempt >= self.max_failures {
            return false;
        }
        let mut sm = SplitMix64::new(derive_seed(
            self.seed ^ CORRUPT_TAG,
            derive_seed(stream, attempt as u64),
        ));
        sm.next_f64() < self.corrupt_prob
    }
}

fn parse_prob(key: &str, val: &str) -> Result<f64, String> {
    let p: f64 = val
        .parse()
        .map_err(|e| format!("bad value for {key}: {e}"))?;
    if !(0.0..=1.0).contains(&p) {
        return Err(format!("{key} probability {p} outside [0, 1]"));
    }
    Ok(p)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_full_spec() {
        let p = FaultPlan::parse("seed=7,crash=0.1,delay=0.05:40,corrupt=0.05,max_failures=3")
            .unwrap();
        assert_eq!(p.seed, 7);
        assert_eq!(p.crash_prob, 0.1);
        assert_eq!(p.delay_prob, 0.05);
        assert_eq!(p.delay_ms, 40);
        assert_eq!(p.corrupt_prob, 0.05);
        assert_eq!(p.max_failures, 3);
        assert!(p.is_active());
    }

    #[test]
    fn parse_defaults_and_empty() {
        let p = FaultPlan::parse("").unwrap();
        assert_eq!(p, FaultPlan::none());
        assert!(!p.is_active());
        let p = FaultPlan::parse("delay=0.5").unwrap();
        assert_eq!(p.delay_ms, 20, "delay ms defaults to 20");
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(FaultPlan::parse("crash").is_err());
        assert!(FaultPlan::parse("crash=notanumber").is_err());
        assert!(FaultPlan::parse("crash=1.5").is_err());
        assert!(FaultPlan::parse("frobnicate=1").is_err());
    }

    #[test]
    fn decide_is_deterministic_and_seed_sensitive() {
        let p = FaultPlan::parse("seed=11,crash=0.5,delay=0.5:5").unwrap();
        let q = FaultPlan::parse("seed=12,crash=0.5,delay=0.5:5").unwrap();
        let mut differ = false;
        for round in 0..4u64 {
            for task in 0..16u64 {
                for attempt in 0..3u32 {
                    assert_eq!(
                        p.decide(round, task, attempt),
                        p.decide(round, task, attempt),
                        "same plan must redecide identically"
                    );
                }
                if p.decide(round, task, 0) != q.decide(round, task, 0) {
                    differ = true;
                }
            }
        }
        assert!(differ, "different seeds should yield different schedules");
    }

    #[test]
    fn crash_respects_failure_budget() {
        let p = FaultPlan::parse("seed=3,crash=1.0,max_failures=2").unwrap();
        for task in 0..8u64 {
            assert_eq!(p.decide(0, task, 0), Fault::Crash);
            assert_eq!(p.decide(0, task, 1), Fault::Crash);
            assert_eq!(p.decide(0, task, 2), Fault::None, "budget exhausted");
        }
    }

    #[test]
    fn delay_only_hits_first_attempt() {
        let p = FaultPlan::parse("seed=3,delay=1.0:7").unwrap();
        assert_eq!(p.decide(1, 4, 0), Fault::Delay(7));
        assert_eq!(p.decide(1, 4, 1), Fault::None);
    }

    #[test]
    fn corruption_terminates_under_certainty() {
        let p = FaultPlan::parse("seed=9,corrupt=1.0,max_failures=2").unwrap();
        assert!(p.corrupt(0xABCD, 0));
        assert!(p.corrupt(0xABCD, 1));
        assert!(!p.corrupt(0xABCD, 2), "injection stops at the budget");
        let inert = FaultPlan::none();
        assert!(!inert.corrupt(0xABCD, 0));
    }

    #[test]
    fn inert_plan_decides_none_without_drawing() {
        let p = FaultPlan::none();
        assert_eq!(p.decide(0, 0, 0), Fault::None);
        assert_eq!(p.decide(9, 9, 9), Fault::None);
    }
}
