//! FxHash: the rustc-internal multiplicative hasher.
//!
//! Bucket maps in the hot scoring path hash billions of small keys (u64
//! sketches, u32 point ids); SipHash (std default) costs ~3x more there.
//! This is a faithful reimplementation of the well-known `fxhash` algorithm.

use std::hash::{BuildHasherDefault, Hasher};

const SEED: u64 = 0x51_7c_c1_b7_27_22_0a_95;

/// Multiplicative hasher used throughout the pipeline's hash maps.
#[derive(Default, Clone)]
pub struct FxHasher {
    hash: u64,
}

impl FxHasher {
    #[inline]
    fn add_to_hash(&mut self, i: u64) {
        self.hash = (self.hash.rotate_left(5) ^ i).wrapping_mul(SEED);
    }
}

impl Hasher for FxHasher {
    #[inline]
    fn write(&mut self, bytes: &[u8]) {
        let mut chunks = bytes.chunks_exact(8);
        for c in &mut chunks {
            self.add_to_hash(u64::from_le_bytes(c.try_into().unwrap()));
        }
        let rem = chunks.remainder();
        if !rem.is_empty() {
            let mut buf = [0u8; 8];
            buf[..rem.len()].copy_from_slice(rem);
            self.add_to_hash(u64::from_le_bytes(buf));
        }
    }

    #[inline]
    fn write_u8(&mut self, i: u8) {
        self.add_to_hash(i as u64);
    }

    #[inline]
    fn write_u32(&mut self, i: u32) {
        self.add_to_hash(i as u64);
    }

    #[inline]
    fn write_u64(&mut self, i: u64) {
        self.add_to_hash(i);
    }

    #[inline]
    fn write_usize(&mut self, i: usize) {
        self.add_to_hash(i as u64);
    }

    #[inline]
    fn finish(&self) -> u64 {
        self.hash
    }
}

/// `HashMap` keyed with [`FxHasher`].
pub type FxHashMap<K, V> = std::collections::HashMap<K, V, BuildHasherDefault<FxHasher>>;
/// `HashSet` keyed with [`FxHasher`].
pub type FxHashSet<K> = std::collections::HashSet<K, BuildHasherDefault<FxHasher>>;

/// Hash a single u64 (for tabulation-free bucket ids).
#[inline]
pub fn hash_u64(x: u64) -> u64 {
    let mut h = FxHasher::default();
    h.write_u64(x);
    h.finish()
}

/// Combine two hashes (order-sensitive).
#[inline]
pub fn combine(a: u64, b: u64) -> u64 {
    let mut h = FxHasher::default();
    h.write_u64(a);
    h.write_u64(b);
    h.finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn map_roundtrip() {
        let mut m: FxHashMap<u64, usize> = FxHashMap::default();
        for i in 0..1000u64 {
            m.insert(i * 17, i as usize);
        }
        assert_eq!(m.len(), 1000);
        for i in 0..1000u64 {
            assert_eq!(m[&(i * 17)], i as usize);
        }
    }

    #[test]
    fn hash_is_deterministic_and_spreads() {
        assert_eq!(hash_u64(42), hash_u64(42));
        let mut seen = std::collections::HashSet::new();
        for i in 0..10_000u64 {
            seen.insert(hash_u64(i));
        }
        assert_eq!(seen.len(), 10_000);
    }

    #[test]
    fn combine_order_sensitive() {
        assert_ne!(combine(1, 2), combine(2, 1));
    }

    #[test]
    fn write_bytes_matches_chunking() {
        // 8-aligned and unaligned inputs both hash deterministically.
        let mut h1 = FxHasher::default();
        h1.write(b"hello world, this is 29 bytes");
        let mut h2 = FxHasher::default();
        h2.write(b"hello world, this is 29 bytes");
        assert_eq!(h1.finish(), h2.finish());
    }
}
