//! From-scratch substrates.
//!
//! The offline vendor set contains only the `xla` crate closure plus
//! `anyhow`, so everything a production pipeline would normally pull from
//! crates.io — PRNG, JSON, CLI parsing, thread pool, property-testing
//! harness, timing harness — is implemented here.

pub mod rng;
pub mod fault;
pub mod json;
pub mod args;
pub mod bits;
pub mod topk;
pub mod pool;
pub mod fxhash;
pub mod quickcheck;
pub mod logging;
pub mod radix;
pub mod simd;
