//! Packed bit signatures for SimHash sketches.
//!
//! A SimHash sketch of M hyperplanes is M sign bits. We pack them into u64
//! words so sketch-equality bucketing is a word compare and prefix-length
//! computations (SortingLSH) are `leading_zeros` on XORs.

/// A packed bit vector of fixed length (≤ 64 * words).
#[derive(Clone, Debug, PartialEq, Eq, Hash)]
pub struct BitSig {
    words: Vec<u64>,
    len: usize,
}

impl BitSig {
    /// All-zero signature of `len` bits.
    pub fn zeros(len: usize) -> Self {
        BitSig {
            words: vec![0; len.div_ceil(64)],
            len,
        }
    }

    /// Build from a boolean slice.
    pub fn from_bools(bits: &[bool]) -> Self {
        let mut sig = BitSig::zeros(bits.len());
        for (i, &b) in bits.iter().enumerate() {
            if b {
                sig.set(i);
            }
        }
        sig
    }

    /// Number of bits.
    pub fn len(&self) -> usize {
        self.len
    }

    /// True if zero-length.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Set bit `i`.
    #[inline]
    pub fn set(&mut self, i: usize) {
        debug_assert!(i < self.len);
        self.words[i / 64] |= 1u64 << (i % 64);
    }

    /// Read bit `i`.
    #[inline]
    pub fn get(&self, i: usize) -> bool {
        debug_assert!(i < self.len);
        (self.words[i / 64] >> (i % 64)) & 1 == 1
    }

    /// Hamming distance to another signature of the same length.
    pub fn hamming(&self, other: &BitSig) -> usize {
        debug_assert_eq!(self.len, other.len);
        self.words
            .iter()
            .zip(&other.words)
            .map(|(a, b)| (a ^ b).count_ones() as usize)
            .sum()
    }

    /// Length of the common prefix (in bits) with another signature.
    /// This drives SortingLSH: points sharing longer prefixes sort together.
    pub fn common_prefix(&self, other: &BitSig) -> usize {
        debug_assert_eq!(self.len, other.len);
        let mut prefix = 0;
        for (a, b) in self.words.iter().zip(&other.words) {
            let x = a ^ b;
            if x == 0 {
                prefix += 64;
            } else {
                // Bits are stored LSB-first within a word, so the first
                // differing *stored* bit is the lowest set bit of x.
                prefix += x.trailing_zeros() as usize;
                break;
            }
        }
        prefix.min(self.len)
    }

    /// First `k` bits as a u64 key (k ≤ 64). Used for single-table bucketing.
    pub fn prefix_key(&self, k: usize) -> u64 {
        debug_assert!(k <= 64 && k <= self.len);
        if k == 0 {
            return 0;
        }
        let w = self.words[0];
        if k == 64 {
            w
        } else {
            w & ((1u64 << k) - 1)
        }
    }

    /// Raw words (LSB-first bit order within each word).
    pub fn words(&self) -> &[u64] {
        &self.words
    }

    /// Lexicographic comparison treating bit 0 as the most significant
    /// position (the SortingLSH sort order).
    pub fn lex_cmp(&self, other: &BitSig) -> std::cmp::Ordering {
        debug_assert_eq!(self.len, other.len);
        for (a, b) in self.words.iter().zip(&other.words) {
            // Reverse bit order within the word so bit 0 is most significant.
            let (ra, rb) = (a.reverse_bits(), b.reverse_bits());
            match ra.cmp(&rb) {
                std::cmp::Ordering::Equal => continue,
                o => return o,
            }
        }
        std::cmp::Ordering::Equal
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn set_get_roundtrip() {
        let mut s = BitSig::zeros(130);
        for i in [0usize, 1, 63, 64, 65, 127, 128, 129] {
            assert!(!s.get(i));
            s.set(i);
            assert!(s.get(i));
        }
    }

    #[test]
    fn from_bools_matches() {
        let bits: Vec<bool> = (0..100).map(|i| i % 3 == 0).collect();
        let s = BitSig::from_bools(&bits);
        for (i, &b) in bits.iter().enumerate() {
            assert_eq!(s.get(i), b);
        }
    }

    #[test]
    fn hamming_counts_differences() {
        let a = BitSig::from_bools(&[true, false, true, false]);
        let b = BitSig::from_bools(&[true, true, false, false]);
        assert_eq!(a.hamming(&b), 2);
        assert_eq!(a.hamming(&a), 0);
    }

    #[test]
    fn common_prefix_basic() {
        let a = BitSig::from_bools(&[true, true, false, true]);
        let b = BitSig::from_bools(&[true, true, true, true]);
        assert_eq!(a.common_prefix(&b), 2);
        assert_eq!(a.common_prefix(&a), 4);
    }

    #[test]
    fn common_prefix_across_words() {
        let mut a = BitSig::zeros(100);
        let mut b = BitSig::zeros(100);
        a.set(70);
        assert_eq!(a.common_prefix(&b), 70);
        b.set(70);
        assert_eq!(a.common_prefix(&b), 100);
    }

    #[test]
    fn lex_cmp_respects_bit0_msb() {
        // a = 01.., b = 10.. -> b > a? bit0 is most significant: a has bit0=0,
        // b has bit0=1, so b sorts after a.
        let a = BitSig::from_bools(&[false, true]);
        let b = BitSig::from_bools(&[true, false]);
        assert_eq!(a.lex_cmp(&b), std::cmp::Ordering::Less);
        assert_eq!(b.lex_cmp(&a), std::cmp::Ordering::Greater);
        assert_eq!(a.lex_cmp(&a), std::cmp::Ordering::Equal);
    }

    #[test]
    fn lex_cmp_sorts_by_prefix() {
        // Signatures sharing longer prefixes must be adjacent after sorting.
        let sigs = vec![
            BitSig::from_bools(&[true, true, true]),
            BitSig::from_bools(&[false, false, true]),
            BitSig::from_bools(&[true, true, false]),
            BitSig::from_bools(&[false, false, false]),
        ];
        let mut sorted = sigs.clone();
        sorted.sort_by(|a, b| a.lex_cmp(b));
        // After sorting: 000, 001, 110, 111 — pairs sharing 2-bit prefixes adjacent.
        assert_eq!(sorted[0].common_prefix(&sorted[1]), 2);
        assert_eq!(sorted[2].common_prefix(&sorted[3]), 2);
    }

    #[test]
    fn prefix_key_masks() {
        let mut s = BitSig::zeros(64);
        s.set(0);
        s.set(5);
        s.set(63);
        assert_eq!(s.prefix_key(6), 0b100001);
        assert_eq!(s.prefix_key(64) >> 63, 1);
    }
}
