//! Mini property-testing harness (proptest is not in the vendor set).
//!
//! A property is a closure over a [`Gen`] (seeded random source with sized
//! generators). [`check`] runs it for N cases; on failure it retries the same
//! seed to confirm, then panics with the reproducing seed so the case can be
//! replayed with [`replay`].

use crate::util::rng::Rng;

/// Random case generator handed to properties.
pub struct Gen {
    rng: Rng,
    /// Size hint: grows over the course of a run so later cases are larger.
    pub size: usize,
}

impl Gen {
    /// usize in [lo, hi].
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        self.rng.range(lo, hi + 1)
    }

    /// f32 in [lo, hi).
    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.rng.next_f32()
    }

    /// f64 in [lo, hi).
    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.rng.next_f64()
    }

    /// Bernoulli.
    pub fn bool(&mut self, p: f64) -> bool {
        self.rng.bool(p)
    }

    /// Vector of f32 with entries in [-1, 1].
    pub fn vec_f32(&mut self, len: usize) -> Vec<f32> {
        (0..len).map(|_| self.f32_in(-1.0, 1.0)).collect()
    }

    /// Random unit vector of dimension d (uniform on sphere).
    pub fn unit_vec(&mut self, d: usize) -> Vec<f32> {
        loop {
            let v: Vec<f32> = (0..d).map(|_| self.rng.gaussian() as f32).collect();
            let norm: f32 = v.iter().map(|x| x * x).sum::<f32>().sqrt();
            if norm > 1e-6 {
                return v.into_iter().map(|x| x / norm).collect();
            }
        }
    }

    /// Random subset of [0, universe) of expected size ~`expected`.
    pub fn subset(&mut self, universe: usize, expected: usize) -> Vec<u32> {
        let p = (expected as f64 / universe as f64).min(1.0);
        (0..universe as u32).filter(|_| self.rng.bool(p)).collect()
    }

    /// Access the raw RNG.
    pub fn rng(&mut self) -> &mut Rng {
        &mut self.rng
    }
}

/// Run `prop` over `cases` random inputs. Panics with the reproducing seed on
/// the first failure. Base seed can be overridden via env `STARS_QC_SEED`.
pub fn check<F: FnMut(&mut Gen)>(name: &str, cases: usize, mut prop: F) {
    let base: u64 = std::env::var("STARS_QC_SEED")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(0x5741_5253); // "STAR"
    for case in 0..cases {
        let seed = crate::util::rng::derive_seed(base, case as u64);
        let mut g = Gen {
            rng: Rng::new(seed),
            size: 4 + case * 96 / cases.max(1),
        };
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| prop(&mut g)));
        if let Err(err) = result {
            let msg = err
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| err.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!(
                "property '{name}' failed on case {case} (replay with STARS_QC_SEED={base}, \
                 seed={seed}): {msg}"
            );
        }
    }
}

/// Re-run a single failing case by its derived seed.
pub fn replay<F: FnMut(&mut Gen)>(seed: u64, size: usize, mut prop: F) {
    let mut g = Gen {
        rng: Rng::new(seed),
        size,
    };
    prop(&mut g);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        check("sum-commutes", 50, |g| {
            let a = g.usize_in(0, 100);
            let b = g.usize_in(0, 100);
            assert_eq!(a + b, b + a);
            count += 1;
        });
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "property 'always-fails' failed")]
    fn failing_property_reports_seed() {
        check("always-fails", 10, |g| {
            let x = g.usize_in(0, 10);
            assert!(x > 100, "x={x} not > 100");
        });
    }

    #[test]
    fn unit_vec_is_normalized() {
        check("unit-norm", 30, |g| {
            let d = g.usize_in(1, 64);
            let v = g.unit_vec(d);
            let norm: f32 = v.iter().map(|x| x * x).sum::<f32>();
            assert!((norm - 1.0).abs() < 1e-4);
        });
    }

    #[test]
    fn size_grows() {
        let mut sizes = Vec::new();
        check("sizes", 20, |g| sizes.push(g.size));
        assert!(sizes.first().unwrap() < sizes.last().unwrap());
    }
}
