//! Scoped thread pool (std-only).
//!
//! The AMPC simulator fans work out over "worker machines"; each worker is a
//! pool thread with its own cost ledger. The pool exposes two primitives:
//!
//! * [`parallel_chunks`] — split an index range into per-worker chunks and
//!   run a closure per chunk, collecting results in order.
//! * [`parallel_map`] — dynamic work distribution over items via an atomic
//!   cursor (work stealing degenerate case: one shared queue).
//!
//! The `_timed` variants additionally report each spawned worker's busy
//! span to a `busy(worker_index, nanos)` callback. This is how in-repetition
//! parallelism stays visible to the AMPC cost model: the builder's
//! `map_timed` charges a repetition's *wall* time to one worker slot, and
//! the inner primitives report the extra machines' busy seconds on top (the
//! ledger skips index 0, whose span the wall charge already covers — see
//! `CostLedger::add_inner_busy`). Σ busy then reflects machine-seconds even
//! when a wave grants repetitions spare cores.
//!
//! tokio is not in the offline vendor set; plain scoped threads are both
//! sufficient and simpler to account costs on.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::time::Instant;

/// Number of workers used by default: one per available core, capped so the
/// simulation's "machines" stay comparable across hosts.
pub fn default_workers() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
        .clamp(1, 64)
}

/// Split `n` items into `workers` contiguous chunks and run `f(worker_id,
/// range)` on each in parallel. Returns per-worker results in worker order.
pub fn parallel_chunks<R, F>(n: usize, workers: usize, f: F) -> Vec<R>
where
    R: Send,
    F: Fn(usize, std::ops::Range<usize>) -> R + Sync,
{
    let workers = workers.max(1);
    if workers == 1 || n <= 1 {
        return vec![f(0, 0..n)];
    }
    let chunk = n.div_ceil(workers);
    std::thread::scope(|scope| {
        let mut handles = Vec::with_capacity(workers);
        for w in 0..workers {
            let lo = (w * chunk).min(n);
            let hi = ((w + 1) * chunk).min(n);
            let f = &f;
            handles.push(scope.spawn(move || f(w, lo..hi)));
        }
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    })
}

/// Run `n` indexed tasks that each append to an output vector, and return
/// the outputs concatenated in task order. `scratch()` seeds per-evaluation
/// scratch state: the serial path (`workers <= 1` or `n <= 1`) builds it
/// once and reuses it across all tasks — keeping the sequential scoring
/// loops allocation-free — while the parallel path builds one per task and
/// fans out via [`parallel_map`]. Output order is identical either way.
pub fn parallel_flat_map<S, T, F, G>(n: usize, workers: usize, scratch: G, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize, &mut S, &mut Vec<T>) + Sync,
    G: Fn() -> S + Sync,
{
    parallel_flat_map_timed(n, workers, |_, _| {}, scratch, f)
}

/// [`parallel_flat_map`] reporting each worker's busy span to
/// `busy(worker_index, nanos)` (the serial path reports index 0 — the span
/// a caller's own wall-clock charge covers).
pub fn parallel_flat_map_timed<S, T, F, G, B>(
    n: usize,
    workers: usize,
    busy: B,
    scratch: G,
    f: F,
) -> Vec<T>
where
    T: Send,
    F: Fn(usize, &mut S, &mut Vec<T>) + Sync,
    G: Fn() -> S + Sync,
    B: Fn(usize, u64) + Sync,
{
    if workers <= 1 || n <= 1 {
        let t = Instant::now();
        let mut s = scratch();
        let mut out = Vec::new();
        for i in 0..n {
            f(i, &mut s, &mut out);
        }
        busy(0, t.elapsed().as_nanos() as u64);
        return out;
    }
    let parts = parallel_map_timed(n, workers, busy, |i| {
        let mut s = scratch();
        let mut local = Vec::new();
        f(i, &mut s, &mut local);
        local
    });
    let mut out = Vec::with_capacity(parts.iter().map(Vec::len).sum());
    for p in parts {
        out.extend(p);
    }
    out
}

/// Split a mutable output slice into contiguous chunks of `chunk` elements
/// and fill each in parallel: `f(start, slice)` writes `out[start..start +
/// slice.len()]`. The sketch drivers use this to chunk one repetition's
/// key/symbol buffers over the pool without staging per-worker vectors and
/// re-copying them (the chunks are disjoint `&mut` borrows).
pub fn parallel_fill<T, F>(out: &mut [T], chunk: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    parallel_fill_timed(out, chunk, |_, _| {}, f)
}

/// [`parallel_fill`] reporting each chunk worker's busy span to
/// `busy(chunk_index, nanos)` (the serial path reports index 0).
pub fn parallel_fill_timed<T, F, B>(out: &mut [T], chunk: usize, busy: B, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
    B: Fn(usize, u64) + Sync,
{
    let n = out.len();
    let chunk = chunk.max(1);
    if chunk >= n {
        let t = Instant::now();
        f(0, out);
        return busy(0, t.elapsed().as_nanos() as u64);
    }
    std::thread::scope(|scope| {
        for (c, slice) in out.chunks_mut(chunk).enumerate() {
            let f = &f;
            let busy = &busy;
            scope.spawn(move || {
                let t = Instant::now();
                f(c * chunk, slice);
                busy(c, t.elapsed().as_nanos() as u64);
            });
        }
    });
}

/// Dynamically distribute `n` independent tasks over `workers` threads via an
/// atomic cursor. `f(task_index)` is called exactly once per index; the
/// per-task results are returned in index order.
///
/// Each worker buffers its `(index, result)` pairs locally and the buffers
/// are merged once after the scope joins — no per-slot mutex, no `Default +
/// Clone` bound on `R` (the previous implementation paid a lock/unlock per
/// task plus an up-front clone of `n` defaults).
pub fn parallel_map<R, F>(n: usize, workers: usize, f: F) -> Vec<R>
where
    R: Send,
    F: Fn(usize) -> R + Sync,
{
    parallel_map_timed(n, workers, |_, _| {}, f)
}

/// [`parallel_map`] reporting each worker thread's busy span (its whole
/// task loop, one callback per worker) to `busy(worker_index, nanos)`. The
/// serial path reports index 0.
///
/// # Panic isolation
///
/// On the parallel path each task runs under `catch_unwind`: a panicking
/// closure stops neither its worker (the cursor loop continues, so every
/// task still executes) nor the other workers, and after the scope joins
/// the first panic *by task index* is re-raised on the caller's thread —
/// deterministic regardless of which worker hit it first. Without this, a
/// worker thread dying mid-loop would strand its queued tasks and the
/// scope join would abort the process on the poisoned handle. The serial
/// path propagates directly (same thread, nothing to strand).
pub fn parallel_map_timed<R, F, B>(n: usize, workers: usize, busy: B, f: F) -> Vec<R>
where
    R: Send,
    F: Fn(usize) -> R + Sync,
    B: Fn(usize, u64) + Sync,
{
    let workers = workers.max(1).min(n.max(1));
    if workers == 1 {
        let t = Instant::now();
        let out = (0..n).map(&f).collect();
        busy(0, t.elapsed().as_nanos() as u64);
        return out;
    }
    let cursor = AtomicUsize::new(0);
    let parts: Vec<Vec<(usize, std::thread::Result<R>)>> = std::thread::scope(|scope| {
        let handles: Vec<_> = (0..workers)
            .map(|w| {
                let cursor = &cursor;
                let f = &f;
                let busy = &busy;
                scope.spawn(move || {
                    let t = Instant::now();
                    let mut local = Vec::new();
                    loop {
                        let i = cursor.fetch_add(1, Ordering::Relaxed);
                        if i >= n {
                            break;
                        }
                        local.push((
                            i,
                            std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(i))),
                        ));
                    }
                    busy(w, t.elapsed().as_nanos() as u64);
                    local
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    });
    let mut slots: Vec<Option<std::thread::Result<R>>> = Vec::with_capacity(n);
    slots.resize_with(n, || None);
    for part in parts {
        for (i, r) in part {
            debug_assert!(slots[i].is_none(), "task {i} executed twice");
            slots[i] = Some(r);
        }
    }
    let mut out = Vec::with_capacity(n);
    let mut first_panic: Option<Box<dyn std::any::Any + Send>> = None;
    for slot in slots {
        match slot.expect("task not executed") {
            Ok(r) => out.push(r),
            Err(payload) => {
                if first_panic.is_none() {
                    first_panic = Some(payload);
                }
            }
        }
    }
    if let Some(payload) = first_panic {
        std::panic::resume_unwind(payload);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn chunks_cover_range_exactly_once() {
        let hits = AtomicU64::new(0);
        let parts = parallel_chunks(1000, 7, |_, range| {
            for _ in range.clone() {
                hits.fetch_add(1, Ordering::Relaxed);
            }
            range.len()
        });
        assert_eq!(hits.load(Ordering::Relaxed), 1000);
        assert_eq!(parts.iter().sum::<usize>(), 1000);
    }

    #[test]
    fn chunks_handle_small_n() {
        let parts = parallel_chunks(2, 8, |_, r| r.len());
        assert_eq!(parts.iter().sum::<usize>(), 2);
        let parts = parallel_chunks(0, 4, |_, r| r.len());
        assert_eq!(parts.iter().sum::<usize>(), 0);
    }

    #[test]
    fn map_preserves_order() {
        let out = parallel_map(257, 5, |i| i * i);
        for (i, &v) in out.iter().enumerate() {
            assert_eq!(v, i * i);
        }
    }

    #[test]
    fn map_single_worker_path() {
        let out = parallel_map(10, 1, |i| i + 1);
        assert_eq!(out, (1..=10).collect::<Vec<_>>());
    }

    #[test]
    fn map_zero_tasks() {
        let out: Vec<usize> = parallel_map(0, 4, |i| i);
        assert!(out.is_empty());
    }

    #[test]
    fn flat_map_concatenates_in_task_order() {
        for workers in [1usize, 4] {
            let out = parallel_flat_map(9, workers, || 10usize, |i, base, out| {
                for k in 0..i {
                    out.push(*base * i + k);
                }
            });
            let mut want = Vec::new();
            for i in 0..9 {
                for k in 0..i {
                    want.push(10 * i + k);
                }
            }
            assert_eq!(out, want, "workers={workers}");
        }
        let empty: Vec<u8> = parallel_flat_map(0, 4, || (), |_, _, _| {});
        assert!(empty.is_empty());
    }

    #[test]
    fn fill_covers_whole_slice_with_correct_offsets() {
        let mut out = vec![0usize; 1003];
        parallel_fill(&mut out, 128, |start, slice| {
            for (k, v) in slice.iter_mut().enumerate() {
                *v = start + k;
            }
        });
        for (i, &v) in out.iter().enumerate() {
            assert_eq!(v, i);
        }
    }

    #[test]
    fn fill_serial_when_chunk_covers_slice() {
        let mut out = vec![0u64; 10];
        parallel_fill(&mut out, 10, |start, slice| {
            assert_eq!(start, 0);
            assert_eq!(slice.len(), 10);
            slice.fill(7);
        });
        assert_eq!(out, vec![7u64; 10]);
        let mut empty: Vec<u64> = Vec::new();
        parallel_fill(&mut empty, 4, |_, _| {});
    }

    #[test]
    fn timed_variants_report_per_worker_busy() {
        // parallel_map_timed: one callback per worker, indices < workers.
        let busy_calls = std::sync::Mutex::new(Vec::new());
        let out = parallel_map_timed(20, 4, |w, ns| busy_calls.lock().unwrap().push((w, ns)), |i| i);
        assert_eq!(out, (0..20).collect::<Vec<_>>());
        let calls = busy_calls.lock().unwrap();
        assert_eq!(calls.len(), 4);
        assert!(calls.iter().all(|&(w, _)| w < 4));
        drop(calls);

        // Serial path reports exactly index 0.
        let busy_calls = std::sync::Mutex::new(Vec::new());
        parallel_map_timed(5, 1, |w, ns| busy_calls.lock().unwrap().push((w, ns)), |i| i);
        let calls = busy_calls.into_inner().unwrap();
        assert_eq!(calls.len(), 1);
        assert_eq!(calls[0].0, 0);

        // parallel_fill_timed: one callback per chunk, and the busy spans
        // cover real work (busy-wait 2ms each so nanos are non-trivial).
        let total = std::sync::atomic::AtomicU64::new(0);
        let mut out = vec![0u8; 4];
        parallel_fill_timed(
            &mut out,
            1,
            |_, ns| {
                total.fetch_add(ns, Ordering::Relaxed);
            },
            |_, slice| {
                let t = std::time::Instant::now();
                while t.elapsed().as_micros() < 2000 {}
                slice.fill(1);
            },
        );
        assert_eq!(out, vec![1u8; 4]);
        assert!(
            total.load(Ordering::Relaxed) >= 4 * 2_000_000,
            "busy under-reported: {}",
            total.load(Ordering::Relaxed)
        );
    }

    #[test]
    fn map_supports_non_default_non_clone_results() {
        struct Opaque(usize);
        let out = parallel_map(97, 6, Opaque);
        for (i, r) in out.iter().enumerate() {
            assert_eq!(r.0, i);
        }
    }

    #[test]
    fn panicking_task_does_not_deadlock_parallel_map() {
        // Regression: a worker used to die on the first panic, stranding
        // its queued tasks and aborting the scope join. Now every task
        // still runs, the pool drains, and the first panic *by task index*
        // surfaces on the caller — deterministically, whichever worker
        // tripped it first.
        let executed = AtomicU64::new(0);
        let r = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            parallel_map(64, 4, |i| {
                executed.fetch_add(1, Ordering::Relaxed);
                if i == 31 || i == 7 {
                    panic!("task {i} failed");
                }
                i
            })
        }));
        let payload = r.expect_err("panic must surface to the caller");
        let msg = payload
            .downcast_ref::<String>()
            .expect("formatted panic payload");
        assert_eq!(msg, "task 7 failed", "lowest task index wins");
        assert_eq!(
            executed.load(Ordering::Relaxed),
            64,
            "all tasks still execute; no worker strands its queue"
        );
    }
}
