//! Scoped thread pool (std-only).
//!
//! The AMPC simulator fans work out over "worker machines"; each worker is a
//! pool thread with its own cost ledger. The pool exposes two primitives:
//!
//! * [`parallel_chunks`] — split an index range into per-worker chunks and
//!   run a closure per chunk, collecting results in order.
//! * [`parallel_map`] — dynamic work distribution over items via an atomic
//!   cursor (work stealing degenerate case: one shared queue).
//!
//! tokio is not in the offline vendor set; plain scoped threads are both
//! sufficient and simpler to account costs on.

use std::sync::atomic::{AtomicUsize, Ordering};

/// Number of workers used by default: one per available core, capped so the
/// simulation's "machines" stay comparable across hosts.
pub fn default_workers() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(4)
        .clamp(1, 64)
}

/// Split `n` items into `workers` contiguous chunks and run `f(worker_id,
/// range)` on each in parallel. Returns per-worker results in worker order.
pub fn parallel_chunks<R, F>(n: usize, workers: usize, f: F) -> Vec<R>
where
    R: Send,
    F: Fn(usize, std::ops::Range<usize>) -> R + Sync,
{
    let workers = workers.max(1);
    if workers == 1 || n <= 1 {
        return vec![f(0, 0..n)];
    }
    let chunk = n.div_ceil(workers);
    std::thread::scope(|scope| {
        let mut handles = Vec::with_capacity(workers);
        for w in 0..workers {
            let lo = (w * chunk).min(n);
            let hi = ((w + 1) * chunk).min(n);
            let f = &f;
            handles.push(scope.spawn(move || f(w, lo..hi)));
        }
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    })
}

/// Dynamically distribute `n` independent tasks over `workers` threads.
/// `f(task_index)` is called exactly once per index; the per-task results are
/// returned in index order.
pub fn parallel_map<R, F>(n: usize, workers: usize, f: F) -> Vec<R>
where
    R: Send + Default + Clone,
    F: Fn(usize) -> R + Sync,
{
    let workers = workers.max(1).min(n.max(1));
    if workers == 1 {
        return (0..n).map(&f).collect();
    }
    let cursor = AtomicUsize::new(0);
    let mut results: Vec<R> = vec![R::default(); n];
    let slots: Vec<std::sync::Mutex<Option<R>>> = (0..n).map(|_| std::sync::Mutex::new(None)).collect();
    std::thread::scope(|scope| {
        for _ in 0..workers {
            let cursor = &cursor;
            let f = &f;
            let slots = &slots;
            scope.spawn(move || loop {
                let i = cursor.fetch_add(1, Ordering::Relaxed);
                if i >= n {
                    break;
                }
                *slots[i].lock().unwrap() = Some(f(i));
            });
        }
    });
    for (i, slot) in slots.into_iter().enumerate() {
        results[i] = slot.into_inner().unwrap().expect("task not executed");
    }
    results
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn chunks_cover_range_exactly_once() {
        let hits = AtomicU64::new(0);
        let parts = parallel_chunks(1000, 7, |_, range| {
            for _ in range.clone() {
                hits.fetch_add(1, Ordering::Relaxed);
            }
            range.len()
        });
        assert_eq!(hits.load(Ordering::Relaxed), 1000);
        assert_eq!(parts.iter().sum::<usize>(), 1000);
    }

    #[test]
    fn chunks_handle_small_n() {
        let parts = parallel_chunks(2, 8, |_, r| r.len());
        assert_eq!(parts.iter().sum::<usize>(), 2);
        let parts = parallel_chunks(0, 4, |_, r| r.len());
        assert_eq!(parts.iter().sum::<usize>(), 0);
    }

    #[test]
    fn map_preserves_order() {
        let out = parallel_map(257, 5, |i| i * i);
        for (i, &v) in out.iter().enumerate() {
            assert_eq!(v, i * i);
        }
    }

    #[test]
    fn map_single_worker_path() {
        let out = parallel_map(10, 1, |i| i + 1);
        assert_eq!(out, (1..=10).collect::<Vec<_>>());
    }

    #[test]
    fn map_zero_tasks() {
        let out: Vec<usize> = parallel_map(0, 4, |i| i);
        assert!(out.is_empty());
    }
}
