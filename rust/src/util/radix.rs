//! LSD radix sort for u64 sort keys.
//!
//! SortingLSH sorts n packed sketch keys per repetition — the "TeraSort"
//! phase of the production system. A comparison sort pays O(n log n) key
//! loads with a data-dependent branch per compare; least-significant-digit
//! radix makes it O(passes · n) streaming scatters. Two properties matter
//! here:
//!
//! * **Stability.** Each pass preserves the relative order of equal digits,
//!   and the initial order is index order, so the result is identical to
//!   `sort_unstable_by_key(|&i| (keys[i], i))` — ties broken by point index,
//!   bit-for-bit the order the comparison path produced (asserted by
//!   `tests/sketch_parity.rs`).
//! * **Pass skipping.** Packed SimHash keys occupy only the low `bits` bits
//!   (M=30 ⇒ 4 live bytes), so the high-byte histograms are degenerate and
//!   those passes permute nothing; one fused histogram pass up front detects
//!   and skips them.

/// Below this length the constant factors favor the comparison sort; both
/// paths produce the identical permutation, so the cutoff is purely a
/// performance knob.
const RADIX_MIN_N: usize = 512;

/// Indices `0..keys.len()` sorted by `(keys[i], i)` — stable LSD radix on
/// 8-bit digits with degenerate passes skipped.
pub fn argsort_u64(keys: &[u64]) -> Vec<u32> {
    let n = keys.len();
    assert!(n <= u32::MAX as usize, "argsort_u64 indexes with u32");
    let mut idx: Vec<u32> = (0..n as u32).collect();
    if n < RADIX_MIN_N {
        idx.sort_unstable_by_key(|&i| (keys[i as usize], i));
        return idx;
    }
    // All eight digit histograms in one read of the key array.
    let mut hist = [[0u32; 256]; 8];
    for &k in keys {
        for (pass, h) in hist.iter_mut().enumerate() {
            h[((k >> (pass * 8)) & 0xFF) as usize] += 1;
        }
    }
    let mut buf = vec![0u32; n];
    for (pass, h) in hist.iter().enumerate() {
        // A pass where every key shares one digit value permutes nothing.
        if h.iter().any(|&c| c as usize == n) {
            continue;
        }
        let shift = pass * 8;
        let mut cursor = [0u32; 256];
        let mut sum = 0u32;
        for (c, &count) in cursor.iter_mut().zip(h.iter()) {
            *c = sum;
            sum += count;
        }
        for &i in &idx {
            let digit = ((keys[i as usize] >> shift) & 0xFF) as usize;
            buf[cursor[digit] as usize] = i;
            cursor[digit] += 1;
        }
        std::mem::swap(&mut idx, &mut buf);
    }
    idx
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn reference(keys: &[u64]) -> Vec<u32> {
        let mut idx: Vec<u32> = (0..keys.len() as u32).collect();
        idx.sort_unstable_by_key(|&i| (keys[i as usize], i));
        idx
    }

    #[test]
    fn empty_and_tiny_inputs() {
        assert!(argsort_u64(&[]).is_empty());
        assert_eq!(argsort_u64(&[9]), vec![0]);
        assert_eq!(argsort_u64(&[9, 3, 9]), vec![1, 0, 2]);
    }

    #[test]
    fn matches_comparison_sort_above_cutoff() {
        let mut rng = Rng::new(17);
        let keys: Vec<u64> = (0..10_000).map(|_| rng.next_u64()).collect();
        assert_eq!(argsort_u64(&keys), reference(&keys));
    }

    #[test]
    fn heavy_ties_break_by_index() {
        // 8 distinct key values over 5000 entries: every pass but the first
        // is skipped, and ties must come out in ascending index order.
        let mut rng = Rng::new(3);
        let keys: Vec<u64> = (0..5_000).map(|_| rng.next_u64() % 8).collect();
        let order = argsort_u64(&keys);
        assert_eq!(order, reference(&keys));
        for w in order.windows(2) {
            let (a, b) = (w[0], w[1]);
            if keys[a as usize] == keys[b as usize] {
                assert!(a < b, "tie {a},{b} not in index order");
            }
        }
    }

    #[test]
    fn all_equal_keys_are_identity() {
        let keys = vec![42u64; 2_000];
        let order = argsort_u64(&keys);
        assert_eq!(order, (0..2_000).collect::<Vec<u32>>());
    }

    #[test]
    fn high_bytes_only() {
        // Keys living in the top byte exercise the late passes.
        let mut rng = Rng::new(5);
        let keys: Vec<u64> = (0..4_000).map(|_| rng.next_u64() << 56).collect();
        assert_eq!(argsort_u64(&keys), reference(&keys));
    }
}
