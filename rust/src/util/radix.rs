//! LSD radix sort for u64 sort keys — serial and pool-parallel.
//!
//! SortingLSH sorts n packed sketch keys per repetition — the "TeraSort"
//! phase of the production system. A comparison sort pays O(n log n) key
//! loads with a data-dependent branch per compare; least-significant-digit
//! radix makes it O(passes · n) streaming scatters. Three properties matter
//! here:
//!
//! * **Stability.** Each pass preserves the relative order of equal digits,
//!   and the initial order is index order, so the result is identical to
//!   `sort_unstable_by_key(|&i| (keys[i], i))` — ties broken by point index,
//!   bit-for-bit the order the comparison path produced (asserted by
//!   `tests/sketch_parity.rs`).
//! * **Pass skipping.** Packed SimHash keys occupy only the low `bits` bits
//!   (M=30 ⇒ 4 live bytes), so the high-byte passes permute nothing. One
//!   OR/AND mask pass up front finds them: a byte position where every key
//!   agrees has `or_byte == and_byte`, and such a **fully-degenerate byte
//!   skips histogram accumulation too** — the fused histogram loop only
//!   builds counts for live bytes.
//! * **Pool parallelism.** [`argsort_u64_par`] runs each pass as
//!   per-worker-chunk digit histograms, a serial 256 × W prefix scan, and a
//!   parallel prefix-scatter into disjoint output ranges. Worker w's
//!   digit-d block lands after workers < w's, and chunks walk the current
//!   permutation in order, so every pass — and therefore the final
//!   permutation — is **identical to the serial sort for any worker count**
//!   (`tests/simd_parity.rs`). This is what lets one huge repetition use
//!   the whole pool when the wave has spare cores, and `ampc::terasort`
//!   rides the same pipeline via `terasort_u64`.

use crate::util::pool::parallel_chunks;
use std::time::Instant;

/// Below this length the constant factors favor the comparison sort; both
/// paths produce the identical permutation, so the cutoff is purely a
/// performance knob.
const RADIX_MIN_N: usize = 512;

/// Below this many keys the parallel path degrades to the serial sort —
/// spawn/join overhead beats the scatter work (identical output either
/// way).
const RADIX_PAR_MIN_N: usize = 1 << 16;

/// Minimum keys per worker chunk in the parallel path; the effective worker
/// count is capped at `n / RADIX_PAR_MIN_CHUNK`.
const RADIX_PAR_MIN_CHUNK: usize = 1 << 14;

/// Byte value of `k` at radix pass `pass`.
#[inline(always)]
fn digit(k: u64, pass: usize) -> usize {
    ((k >> (pass * 8)) & 0xFF) as usize
}

/// The radix passes that can permute anything: byte positions where at
/// least two keys disagree (`or_byte != and_byte`). Fully-degenerate bytes
/// are skipped before any histogram is accumulated.
fn live_passes(or_mask: u64, and_mask: u64) -> Vec<usize> {
    (0..8)
        .filter(|&p| digit(or_mask, p) != digit(and_mask, p))
        .collect()
}

/// Indices `0..keys.len()` sorted by `(keys[i], i)` — stable LSD radix on
/// 8-bit digits with degenerate passes (and their histograms) skipped via
/// the OR/AND mask.
pub fn argsort_u64(keys: &[u64]) -> Vec<u32> {
    let n = keys.len();
    assert!(n <= u32::MAX as usize, "argsort_u64 indexes with u32");
    let mut idx: Vec<u32> = (0..n as u32).collect();
    if n < RADIX_MIN_N {
        idx.sort_unstable_by_key(|&i| (keys[i as usize], i));
        return idx;
    }
    // Mask pass: one read of the key array finds every byte the sort can
    // skip — including skipping its histogram accumulation below.
    let (mut or_mask, mut and_mask) = (0u64, u64::MAX);
    for &k in keys {
        or_mask |= k;
        and_mask &= k;
    }
    let live = live_passes(or_mask, and_mask);
    if live.is_empty() {
        return idx; // all keys equal: ties break by index — the identity
    }
    // All live digit histograms in one read of the key array.
    let mut hist = vec![[0u32; 256]; live.len()];
    for &k in keys {
        for (h, &pass) in hist.iter_mut().zip(&live) {
            h[digit(k, pass)] += 1;
        }
    }
    let mut buf = vec![0u32; n];
    for (h, &pass) in hist.iter().zip(&live) {
        let mut cursor = [0u32; 256];
        let mut sum = 0u32;
        for (c, &count) in cursor.iter_mut().zip(h.iter()) {
            *c = sum;
            sum += count;
        }
        for &i in &idx {
            let d = digit(keys[i as usize], pass);
            buf[cursor[d] as usize] = i;
            cursor[d] += 1;
        }
        std::mem::swap(&mut idx, &mut buf);
    }
    idx
}

/// [`argsort_u64`] with each pass chunked over up to `workers` pool
/// threads. The permutation is **identical** to the serial sort — and to
/// `sort_unstable_by_key(|&i| (keys[i], i))` — for every worker count;
/// parallelism only changes who computes which slice of each pass.
pub fn argsort_u64_par(keys: &[u64], workers: usize) -> Vec<u32> {
    argsort_u64_par_timed(keys, workers, |_, _| {})
}

/// [`argsort_u64_par`] reporting each chunk worker's busy span to
/// `busy(worker_index, nanos)` — the sorting drivers thread the AMPC
/// ledger through here so a pool-parallel sort's machine-seconds land in
/// Σ busy like every other in-repetition parallel phase (index 0 rides the
/// caller's wall charge; see `CostLedger::add_inner_busy`).
pub fn argsort_u64_par_timed<B>(keys: &[u64], workers: usize, busy: B) -> Vec<u32>
where
    B: Fn(usize, u64) + Sync,
{
    let n = keys.len();
    let cap = (n / RADIX_PAR_MIN_CHUNK).max(1);
    let workers = workers.clamp(1, cap);
    if workers <= 1 || n < RADIX_PAR_MIN_N {
        let t = Instant::now();
        let out = argsort_u64(keys);
        busy(0, t.elapsed().as_nanos() as u64);
        return out;
    }
    par_argsort(keys, workers, &busy)
}

/// A raw output pointer that workers scatter through. Writes are disjoint
/// by construction: the prefix scan hands every (worker, digit) pair its
/// own half-open output range, and the ranges partition `0..n`.
struct ScatterOut(*mut u32);
unsafe impl Send for ScatterOut {}
unsafe impl Sync for ScatterOut {}

/// The parallel radix pipeline (callers guarantee `workers >= 2` and
/// `n >= workers`). Exposed to the module tests so the worker-invariance
/// sweep can exercise the parallel path below the public cutoffs.
fn par_argsort<B>(keys: &[u64], workers: usize, busy: &B) -> Vec<u32>
where
    B: Fn(usize, u64) + Sync,
{
    let n = keys.len();
    assert!(n <= u32::MAX as usize, "argsort_u64 indexes with u32");
    let mut idx: Vec<u32> = (0..n as u32).collect();

    // Mask pass, chunked: fold per-chunk OR/AND masks.
    let masks = parallel_chunks(n, workers, |w, range| {
        let t = Instant::now();
        let (mut or_m, mut and_m) = (0u64, u64::MAX);
        for &k in &keys[range] {
            or_m |= k;
            and_m &= k;
        }
        busy(w, t.elapsed().as_nanos() as u64);
        (or_m, and_m)
    });
    let (or_mask, and_mask) = masks
        .into_iter()
        .fold((0u64, u64::MAX), |(o, a), (co, ca)| (o | co, a & ca));
    let live = live_passes(or_mask, and_mask);
    if live.is_empty() {
        return idx;
    }

    // Fixed chunking of the permutation, shared by the histogram and
    // scatter phases of every pass (both walk the *current* idx order).
    let chunk = n.div_ceil(workers);
    let ranges: Vec<std::ops::Range<usize>> = (0..workers)
        .map(|w| (w * chunk).min(n)..((w + 1) * chunk).min(n))
        .collect();

    let mut buf = vec![0u32; n];
    for &pass in &live {
        // 1. Per-worker digit histograms over the current permutation.
        let idx_ref = &idx;
        let hists: Vec<[u32; 256]> = parallel_chunks(workers, workers, |w, wrange| {
            let t = Instant::now();
            let mut out = Vec::with_capacity(wrange.len());
            for wi in wrange {
                let mut h = [0u32; 256];
                for &i in &idx_ref[ranges[wi].clone()] {
                    h[digit(keys[i as usize], pass)] += 1;
                }
                out.push(h);
            }
            busy(w, t.elapsed().as_nanos() as u64);
            out
        })
        .into_iter()
        .flatten()
        .collect();

        // 2. Serial prefix scan: worker w's digit-d block starts after all
        //    smaller digits and after workers < w's digit-d counts — the
        //    exact positions the serial stable scatter would use.
        let mut starts = vec![[0u32; 256]; workers];
        let mut sum = 0u32;
        for d in 0..256 {
            for (w, h) in hists.iter().enumerate() {
                starts[w][d] = sum;
                sum += h[d];
            }
        }

        // 3. Parallel scatter into disjoint ranges of the shared buffer.
        let out = ScatterOut(buf.as_mut_ptr());
        let out_ref = &out;
        let starts_ref = &starts;
        parallel_chunks(workers, workers, |w, wrange| {
            let t = Instant::now();
            for wi in wrange {
                let mut cursor = starts_ref[wi];
                for &i in &idx_ref[ranges[wi].clone()] {
                    let d = digit(keys[i as usize], pass);
                    // SAFETY: `cursor[d]` walks `[starts[wi][d],
                    // starts[wi][d] + hists[wi][d])`; the prefix scan makes
                    // these ranges disjoint across (worker, digit) pairs
                    // and their union is exactly 0..n, so each output slot
                    // is written once, by one thread, with no overlap.
                    unsafe {
                        *out_ref.0.add(cursor[d] as usize) = i;
                    }
                    cursor[d] += 1;
                }
            }
            busy(w, t.elapsed().as_nanos() as u64);
        });
        std::mem::swap(&mut idx, &mut buf);
    }
    idx
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn reference(keys: &[u64]) -> Vec<u32> {
        let mut idx: Vec<u32> = (0..keys.len() as u32).collect();
        idx.sort_unstable_by_key(|&i| (keys[i as usize], i));
        idx
    }

    #[test]
    fn empty_and_tiny_inputs() {
        assert!(argsort_u64(&[]).is_empty());
        assert_eq!(argsort_u64(&[9]), vec![0]);
        assert_eq!(argsort_u64(&[9, 3, 9]), vec![1, 0, 2]);
    }

    #[test]
    fn matches_comparison_sort_above_cutoff() {
        let mut rng = Rng::new(17);
        let keys: Vec<u64> = (0..10_000).map(|_| rng.next_u64()).collect();
        assert_eq!(argsort_u64(&keys), reference(&keys));
    }

    #[test]
    fn heavy_ties_break_by_index() {
        // 8 distinct key values over 5000 entries: every pass but the first
        // is skipped, and ties must come out in ascending index order.
        let mut rng = Rng::new(3);
        let keys: Vec<u64> = (0..5_000).map(|_| rng.next_u64() % 8).collect();
        let order = argsort_u64(&keys);
        assert_eq!(order, reference(&keys));
        for w in order.windows(2) {
            let (a, b) = (w[0], w[1]);
            if keys[a as usize] == keys[b as usize] {
                assert!(a < b, "tie {a},{b} not in index order");
            }
        }
    }

    #[test]
    fn all_equal_keys_are_identity() {
        let keys = vec![42u64; 2_000];
        let order = argsort_u64(&keys);
        assert_eq!(order, (0..2_000).collect::<Vec<u32>>());
    }

    #[test]
    fn high_bytes_only() {
        // Keys living in the top byte exercise the late passes.
        let mut rng = Rng::new(5);
        let keys: Vec<u64> = (0..4_000).map(|_| rng.next_u64() << 56).collect();
        assert_eq!(argsort_u64(&keys), reference(&keys));
    }

    #[test]
    fn shared_nonzero_bytes_are_skipped_correctly() {
        // Every key shares 0xAB in byte 2 and 0xFF in byte 6 — degenerate
        // but nonzero bytes, which only the OR/AND mask (not a zero test)
        // can prove skippable.
        let mut rng = Rng::new(9);
        let keys: Vec<u64> = (0..3_000)
            .map(|_| {
                let low = rng.next_u64() & 0xFFFF;
                let high = (rng.next_u64() & 0xFF) << 24;
                low | high | (0xABu64 << 16) | (0xFFu64 << 48)
            })
            .collect();
        assert_eq!(argsort_u64(&keys), reference(&keys));
    }

    #[test]
    fn live_pass_mask_detects_degenerate_bytes() {
        // or == and on bytes 1 and 3 (all keys agree there).
        let keys = [0x01_22_03_44u64, 0x05_22_07_44, 0xFF_22_00_44];
        let (mut or_m, mut and_m) = (0u64, u64::MAX);
        for &k in &keys {
            or_m |= k;
            and_m &= k;
        }
        assert_eq!(live_passes(or_m, and_m), vec![1, 3]);
        assert_eq!(live_passes(7, 7), Vec::<usize>::new());
    }

    #[test]
    fn parallel_matches_serial_for_any_worker_count() {
        // Drive the parallel pipeline directly (below the public cutoff
        // n would fall back to serial and test nothing).
        let mut rng = Rng::new(21);
        let cases: Vec<Vec<u64>> = vec![
            (0..20_000).map(|_| rng.next_u64()).collect(),
            (0..20_000).map(|_| rng.next_u64() % 8).collect(), // heavy ties
            (0..20_000).map(|_| rng.next_u64() << 56).collect(), // high byte only
            vec![7u64; 20_000],                                // fully degenerate
        ];
        for (case, keys) in cases.iter().enumerate() {
            let serial = argsort_u64(keys);
            for workers in [2usize, 3, 5, 8] {
                let par = par_argsort(keys, workers, &|_, _| {});
                assert_eq!(par, serial, "case {case} workers {workers}");
            }
        }
    }

    #[test]
    fn public_par_entry_point_handles_cutoffs_and_reports_busy() {
        // Small input: serial fallback, busy reported on index 0.
        let mut rng = Rng::new(4);
        let keys: Vec<u64> = (0..2_000).map(|_| rng.next_u64()).collect();
        let calls = std::sync::Mutex::new(Vec::new());
        let order =
            argsort_u64_par_timed(&keys, 8, |w, ns| calls.lock().unwrap().push((w, ns)));
        assert_eq!(order, argsort_u64(&keys));
        let calls = calls.into_inner().unwrap();
        assert_eq!(calls.len(), 1);
        assert_eq!(calls[0].0, 0);
        // Large input: parallel path, identical permutation.
        let keys: Vec<u64> = (0..(RADIX_PAR_MIN_N + 100))
            .map(|_| rng.next_u64() % 1000)
            .collect();
        assert_eq!(argsort_u64_par(&keys, 4), argsort_u64(&keys));
        assert_eq!(argsort_u64_par(&keys, 1), argsort_u64(&keys));
        assert!(argsort_u64_par(&[], 4).is_empty());
    }
}
