//! `stars` CLI — leader entrypoint for the graph-building system.
//!
//! Subcommands:
//!   gen-data     generate a synthetic dataset and save it to disk
//!   build        build a similarity graph and print its cost report
//!   cluster      build + affinity-cluster + V-Measure
//!   serve        build + snapshot + answer sampled top-k queries (QPS,
//!                latency percentiles, recall@k vs brute force)
//!   experiment   regenerate a paper table/figure (fig1|fig2|fig3|fig4|fig5|table1|table2|table3|all)
//!   smoke        verify the PJRT artifacts load and execute

use stars::coordinator::experiments::{self, ExpConfig};
use stars::coordinator::{run_job, DatasetSpec, FamilySpec, Job, MeasureSpec};
use stars::stars::{Algorithm, BuildParams};
use stars::util::args::Args;

fn main() {
    if let Err(e) = real_main() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn real_main() -> stars::Result<()> {
    let mut args = Args::from_env();
    let cmd = args.take_subcommand().unwrap_or_else(|| "help".into());
    match cmd.as_str() {
        "gen-data" => gen_data(&mut args),
        "build" => build(&mut args),
        "cluster" => cluster(&mut args),
        "serve" => serve(&mut args),
        "experiment" => experiment(&mut args),
        "smoke" => smoke(),
        "trace-check" => trace_check(&mut args),
        "bench-check" => bench_check(&mut args),
        _ => {
            print!("{}", HELP);
            Ok(())
        }
    }
}

const HELP: &str = "\
stars — Tera-Scale Graph Building via two-hop spanners (paper reproduction)

USAGE:
  stars gen-data --dataset <digits|zipf|products|random> --n <N> --out <file> [--seed S]
  stars build    --dataset <name|file> --n <N> --algo <allpair|lsh|lsh+stars|sortinglsh|sortinglsh+stars>
                 [--measure cosine|jaccard|wjaccard|mixture|learned]
                 [--r SKETCHES] [--s LEADERS] [--threshold T] [--window W]
                 [--degree-cap K] [--workers W] [--seed S] [--join direct|dht|shuffle]
  stars cluster  (build flags) [--classes K]
  stars serve    (build flags) [--queries N] [--k K] [--inserts N]
                 [--compact-mode incremental|full] [--full-rebuild-every N]
                 [--quantized] [--rescore-c F]
                 [--queue-limit N] [--deadline-ms MS] [--overload]
                 [--shards N] [--tenants QPS[:BURST]]
                 [--state-dir DIR] [--fsync always|os|every:N] [--seal-limit N]
                 [--metrics-out FILE] [--metrics-every S]
                 build a graph, export a serving snapshot, and answer N
                 sampled top-k queries (reports QPS, p50/p99, recall@k);
                 with --inserts, also stream N points in and report the
                 compaction cost + snapshot memory telemetry;
                 --full-rebuild-every forces one full rebuild per N
                 incremental compactions (drift bound; mix is reported);
                 --quantized serves int8-first with an exact f32 rescore of
                 the top k·F survivors (F = --rescore-c, default 4);
                 --queue-limit serves through the admission-controlled front
                 door (bounded in-flight depth; shed/degrade counters in the
                 report), --deadline-ms sheds queries whose estimated queue
                 wait exceeds the budget, and --overload applies synthetic
                 backlog so one run reports the whole admit/degrade/shed
                 ladder; --shards N (≥ 2) serves through the fence-partitioned
                 scatter-gather engine — answers are bit-identical to
                 single-shard serving (max_candidates is forced to 0) and the
                 report adds per-shard snapshot slices; --tenants applies a
                 per-tenant QPS token bucket at the front door (requires
                 --queue-limit; tenant_sheds appears in the admission stats);
                 --state-dir makes the write path durable: every insert is
                 WAL'd (length+CRC framing, --fsync policy, default os)
                 before it is applied, compactions publish crash-consistent
                 snapshots (atomic tmp+rename), and a rerun over the same
                 dir cold-starts from the newest valid snapshot plus
                 WAL-suffix replay — bit-identical answers, no rebuild
                 (the report's \"durable\" object carries recovered/replayed
                 /cold_start_ms); --seal-limit N seals the delta tail into
                 immutable pre-sketched segments every N inserts (0 = off;
                 answers are bit-identical either way);
                 --metrics-out atomically rewrites a Prometheus-text
                 snapshot of the serve metrics every --metrics-every seconds
                 (default 1) while the sweep runs
  stars experiment <fig1|fig2|fig3|fig4|fig5|table1|table2|table3|all>
                 [--scale F] [--workers W] [--seed S]   (STARS_BENCH_FULL=1 for paper-size R)
  stars smoke    verify artifacts (PJRT runtime end-to-end)
  stars trace-check <files...>   validate NDJSON trace files: every
                 non-empty line must parse as a JSON object (CI gate for
                 STARS_TRACE output)
  stars bench-check <files...>   validate BENCH_*.json files: each must
                 parse and carry schema_version, data_status, and
                 simd_backend keys; serve v7+ files must also carry a
                 well-formed \"sharding\" scaling object, and serve v8 a
                 \"durability\" probe object (CI gate)

ENVIRONMENT:
  STARS_SIMD    force a SIMD backend (scalar|sse2|avx2|neon)
  STARS_FAULTS  seeded fault-injection schedule for the build pipeline, e.g.
                \"seed=7,crash=0.1,delay=0.05:40,corrupt=0.05,max_failures=2\"
                — crashes/delays tasks and corrupts shuffle/DHT traffic
                deterministically; output is bit-identical, recovery
                counters appear under \"faults\" in build/serve reports
  STARS_TRACE   append structured NDJSON trace events (spans, logs, serve
                queries, compactions) to this file; tracing never changes
                results, only observes them
  STARS_TRACE_SAMPLE  \"1/N\" keeps every Nth trace event (deterministic,
                by event index; default 1/1 = everything)
  STARS_LOG     log verbosity: error|info|debug (default info); enabled
                lines also land in the STARS_TRACE sink as \"log\" events
";

fn parse_algo(name: &str) -> stars::Result<Algorithm> {
    Ok(match name {
        "allpair" => Algorithm::AllPair,
        "lsh" => Algorithm::Lsh,
        "lsh+stars" | "stars" => Algorithm::LshStars,
        "sortinglsh" => Algorithm::SortingLsh,
        "sortinglsh+stars" => Algorithm::SortingLshStars,
        other => anyhow::bail!("unknown algorithm '{other}'"),
    })
}

fn job_from_args(args: &Args) -> stars::Result<Job> {
    let n = args.get_parsed_or("n", 10_000usize);
    let dataset = DatasetSpec::parse(args.get_or("dataset", "random"), n)?;
    let algo = parse_algo(args.get_or("algo", "lsh+stars"))?;
    let sorting = matches!(algo, Algorithm::SortingLsh | Algorithm::SortingLshStars);
    let measure = match args.get("measure") {
        Some(m) => MeasureSpec::parse(m)?,
        None => MeasureSpec::default_for(&dataset),
    };
    let family = FamilySpec::default_for(&dataset, sorting);
    let mut params = if sorting {
        BuildParams::knn_mode(algo)
    } else {
        BuildParams::threshold_mode(algo)
    };
    let (r0, s0, w0, cap0) = (params.sketches, params.leaders, params.window, params.degree_cap);
    params = params
        .sketches(args.get_parsed_or("r", r0))
        .leaders(args.get_parsed_or("s", s0))
        .window(args.get_parsed_or("window", w0))
        .degree_cap(args.get_parsed_or("degree-cap", cap0))
        .seed(args.get_parsed_or("seed", 42u64));
    if let Some(t) = args.get("threshold") {
        params = params.threshold(t.parse::<f32>().map_err(|e| anyhow::anyhow!("{e}"))?);
    }
    params = params.join(match args.get_or("join", "direct") {
        "direct" => stars::stars::JoinStrategy::Direct,
        "dht" => stars::stars::JoinStrategy::Dht,
        "shuffle" => stars::stars::JoinStrategy::Shuffle,
        other => anyhow::bail!("unknown join strategy '{other}'"),
    });
    Ok(Job {
        dataset,
        measure,
        family,
        params,
        data_seed: args.get_parsed_or("seed", 42u64),
        workers: args.get_parsed_or("workers", 0usize),
    })
}

fn gen_data(args: &mut Args) -> stars::Result<()> {
    let n = args.get_parsed_or("n", 10_000usize);
    let spec = DatasetSpec::parse(args.get_or("dataset", "random"), n)?;
    let seed = args.get_parsed_or("seed", 42u64);
    let out = args.get_or("out", "dataset.bin").to_string();
    let ds = spec.realize(seed)?;
    stars::data::io::save(&ds, std::path::Path::new(&out))?;
    println!(
        "wrote {} ({} points, dim {}, {} classes) to {out}",
        spec.name(),
        ds.len(),
        ds.dim(),
        ds.num_classes()
    );
    Ok(())
}

fn build(args: &mut Args) -> stars::Result<()> {
    let job = job_from_args(args)?;
    let res = run_job(&job)?;
    println!("{}", res.to_json(&job).to_pretty());
    Ok(())
}

fn cluster(args: &mut Args) -> stars::Result<()> {
    let job = job_from_args(args)?;
    let res = run_job(&job)?;
    let classes = args.get_parsed_or("classes", res.dataset.num_classes().max(2));
    let graph = if job.params.threshold > f32::MIN {
        res.graph.filter_weight(job.params.threshold)
    } else {
        res.graph.clone()
    };
    let level = stars::clustering::affinity_cluster_to_k(&graph, classes);
    let mut doc = res.to_json(&job);
    if !res.dataset.labels.is_empty() {
        let vm = stars::clustering::v_measure(&level.labels, &res.dataset.labels);
        if let stars::util::json::Json::Obj(m) = &mut doc {
            m.insert("vmeasure".into(), stars::util::json::Json::from(vm.v));
            m.insert("homogeneity".into(), stars::util::json::Json::from(vm.homogeneity));
            m.insert("completeness".into(), stars::util::json::Json::from(vm.completeness));
            m.insert("clusters".into(), stars::util::json::Json::from(level.clusters));
        }
    }
    println!("{}", doc.to_pretty());
    Ok(())
}

fn serve(args: &mut Args) -> stars::Result<()> {
    let job = job_from_args(args)?;
    let opts = stars::coordinator::ServeOpts {
        queries: args.get_parsed_or("queries", 1000usize),
        k: args.get_parsed_or("k", 10usize),
        inserts: args.get_parsed_or("inserts", 0usize),
        compaction: match args.get_or("compact-mode", "incremental") {
            "incremental" => stars::serve::CompactionMode::Incremental,
            "full" => stars::serve::CompactionMode::Full,
            other => anyhow::bail!("unknown compaction mode '{other}'"),
        },
        full_rebuild_every: args.get_parsed_or("full-rebuild-every", 0usize),
        quantized: args.flag("quantized"),
        rescore_factor: args.get_parsed_or("rescore-c", 4usize),
        queue_limit: args.get_parsed_or("queue-limit", 0usize),
        deadline_ms: args.get_parsed_or("deadline-ms", 0.0f64),
        overload: args.flag("overload"),
        metrics_out: args.get("metrics-out").map(std::path::PathBuf::from),
        metrics_every_s: args.get_parsed_or("metrics-every", 1.0f64),
        shards: args.get_parsed_or("shards", 1usize),
        tenants: args.get("tenants").map(String::from),
        state_dir: args.get("state-dir").map(std::path::PathBuf::from),
        fsync: args.get_or("fsync", "os").to_string(),
        seal_limit: args.get_parsed_or("seal-limit", 0usize),
    };
    let doc = stars::coordinator::run_serve_with(&job, &opts)?;
    println!("{}", doc.to_pretty());
    Ok(())
}

fn experiment(args: &mut Args) -> stars::Result<()> {
    let which = args
        .take_subcommand()
        .ok_or_else(|| anyhow::anyhow!("experiment name required (fig1..fig5, table1..table3, all)"))?;
    let cfg = ExpConfig {
        scale: args.get_parsed_or("scale", 1.0f64),
        workers: args.get_parsed_or("workers", 0usize),
        seed: args.get_parsed_or("seed", 42u64),
        ..ExpConfig::default()
    };
    match which.as_str() {
        "fig1" => drop(experiments::fig1(&cfg)),
        "fig2" => drop(experiments::fig2(&cfg)),
        "fig3" => drop(experiments::fig3(&cfg)),
        "fig4" => drop(experiments::fig4(&cfg)),
        "fig5" | "fig6" | "fig7" => drop(experiments::fig5_leaders(&cfg)),
        "table1" => drop(experiments::table12(&cfg, false)),
        "table2" => drop(experiments::table12(&cfg, true)),
        "table3" => drop(experiments::table3(&cfg)),
        "ablation" => {
            experiments::ablation_bucket_cap(&cfg);
            experiments::ablation_join(&cfg);
        }
        "all" => {
            experiments::fig1(&cfg);
            experiments::fig2(&cfg);
            experiments::fig3(&cfg);
            experiments::fig4(&cfg);
            experiments::fig5_leaders(&cfg);
            experiments::table12(&cfg, false);
            experiments::table12(&cfg, true);
            experiments::table3(&cfg);
        }
        other => anyhow::bail!("unknown experiment '{other}'"),
    }
    Ok(())
}

/// CI gate: every non-empty line of each NDJSON trace file must parse as a
/// JSON object (the STARS_TRACE sink's contract).
fn trace_check(args: &mut Args) -> stars::Result<()> {
    let files = args.positional().to_vec();
    anyhow::ensure!(!files.is_empty(), "trace-check needs at least one file");
    for file in &files {
        let text = std::fs::read_to_string(file)
            .map_err(|e| anyhow::anyhow!("{file}: {e}"))?;
        let mut lines = 0usize;
        for (i, line) in text.lines().enumerate() {
            if line.trim().is_empty() {
                continue;
            }
            let doc = stars::util::json::parse(line)
                .map_err(|e| anyhow::anyhow!("{file}:{}: unparseable trace line: {e}", i + 1))?;
            anyhow::ensure!(
                matches!(doc, stars::util::json::Json::Obj(_)),
                "{file}:{}: trace line is not a JSON object",
                i + 1
            );
            anyhow::ensure!(
                doc.get("kind").and_then(|k| k.as_str()).is_some(),
                "{file}:{}: trace line has no \"kind\" field",
                i + 1
            );
            lines += 1;
        }
        anyhow::ensure!(lines > 0, "{file}: trace file has no events");
        println!("{file}: {lines} trace lines OK");
    }
    Ok(())
}

/// CI gate: each BENCH_*.json must parse and carry the shared envelope keys
/// (`schema_version`, `data_status`, `simd_backend`).
fn bench_check(args: &mut Args) -> stars::Result<()> {
    let files = args.positional().to_vec();
    anyhow::ensure!(!files.is_empty(), "bench-check needs at least one file");
    for file in &files {
        let text = std::fs::read_to_string(file)
            .map_err(|e| anyhow::anyhow!("{file}: {e}"))?;
        let doc = stars::util::json::parse(&text)
            .map_err(|e| anyhow::anyhow!("{file}: unparseable JSON: {e}"))?;
        for key in ["schema_version", "data_status", "simd_backend"] {
            anyhow::ensure!(
                doc.get(key).is_some(),
                "{file}: missing required key \"{key}\""
            );
        }
        let sv = doc.get("schema_version").and_then(|v| v.as_str());
        anyhow::ensure!(
            sv.is_some_and(|s| !s.is_empty()),
            "{file}: schema_version must be a non-empty string"
        );
        // Serve v7+ carries the multi-shard scaling curve: a "sharding"
        // object of four equal-length, non-empty arrays keyed by shard
        // count.
        if sv == Some("stars-bench-serve/v7") || sv == Some("stars-bench-serve/v8") {
            let sharding = doc
                .get("sharding")
                .ok_or_else(|| anyhow::anyhow!("{file}: serve v7 requires a \"sharding\" object"))?;
            let mut lens = Vec::new();
            for key in ["shard_counts", "batch_qps", "latency_p50_ms", "latency_p99_ms"] {
                let arr = sharding
                    .get(key)
                    .and_then(|v| v.as_arr())
                    .ok_or_else(|| {
                        anyhow::anyhow!("{file}: sharding.{key} must be an array")
                    })?;
                anyhow::ensure!(!arr.is_empty(), "{file}: sharding.{key} is empty");
                lens.push(arr.len());
            }
            anyhow::ensure!(
                lens.windows(2).all(|w| w[0] == w[1]),
                "{file}: sharding arrays must have equal lengths (got {lens:?})"
            );
        }
        // Serve v8 adds the durability probe: WAL append/fsync cost, seal
        // cost, snapshot size, and the restart-without-rebuild numbers.
        if sv == Some("stars-bench-serve/v8") {
            let dur = doc.get("durability").ok_or_else(|| {
                anyhow::anyhow!("{file}: serve v8 requires a \"durability\" object")
            })?;
            for key in [
                "wal_append_ns",
                "wal_fsync_always_ns",
                "seal_us",
                "snapshot_bytes",
                "cold_start_ms",
                "replay_ns_per_record",
            ] {
                anyhow::ensure!(
                    dur.get(key).and_then(|v| v.as_f64()).is_some_and(|v| v >= 0.0),
                    "{file}: durability.{key} must be a non-negative number"
                );
            }
            anyhow::ensure!(
                dur.get("recovered_bit_identical")
                    .and_then(|v| v.as_bool())
                    .unwrap_or(false),
                "{file}: durability.recovered_bit_identical must be true"
            );
        }
        println!("{file}: schema {} OK", sv.unwrap_or("?"));
    }
    Ok(())
}

fn smoke() -> stars::Result<()> {
    use stars::runtime::{ArtifactMeta, CosineScorer, Engine, LearnedModel, SimHashSketcher};
    let meta = ArtifactMeta::load(&ArtifactMeta::default_dir())?;
    let engine = Engine::cpu()?;
    println!("platform: {}", engine.platform());
    let scorer = CosineScorer::load(&engine, &meta)?;
    println!(
        "cosine_scorer: leaders={} block={} dim={}",
        scorer.leaders, scorer.block, scorer.dim
    );
    let a = vec![1.0f32, 0.0, 0.0];
    let b = vec![1.0f32, 0.0, 0.0, 0.0, 1.0, 0.0];
    let s = scorer.score(&a, 1, &b, 2, 3)?;
    anyhow::ensure!((s[0] - 1.0).abs() < 1e-5 && s[1].abs() < 1e-5, "scorer numerics");
    let sketcher = SimHashSketcher::load(&engine, &meta)?;
    println!(
        "simhash_sketch: block={} dim={} bits={}",
        sketcher.block, sketcher.dim, sketcher.bits
    );
    let model = LearnedModel::load(&engine, &meta)?;
    println!(
        "learned_sim: batch={} dim={} auc={:.4}",
        model.meta.batch, model.meta.dim, model.auc
    );
    println!("smoke OK");
    Ok(())
}
