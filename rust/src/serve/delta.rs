//! The streaming write path: a bounded buffer of not-yet-indexed points.
//!
//! Inserts append to a small side dataset that every query scores brute
//! force (the buffer is bounded by [`super::ServeConfig::compact_limit`],
//! so the extra work per query is a constant-size tile). Compaction folds
//! the buffered points into a fresh [`super::StarIndex`] snapshot and trims
//! the absorbed prefix; global point ids are stable across the swap because
//! compaction appends the prefix in insertion order.
//!
//! Dense buffers also keep a [`QuantDataset`] in lockstep (quantize on
//! insert, O(d) per point): when the engine serves in quantized mode the
//! delta tile joins the int8 first pass instead of being brute-forced in
//! f32. The table is maintained unconditionally for dense templates —
//! per-row SQ8 is cheap, and the engine's quantized flag can differ from
//! snapshot to snapshot while the buffer outlives the swap.

use crate::data::types::{Dataset, WeightedSet};
use crate::sim::QuantDataset;

/// Buffer of points inserted since the last snapshot.
pub struct DeltaBuffer {
    ds: Dataset,
    /// SQ8 codes of the buffered dense rows, row-for-row with `ds`
    /// (`None` for set-only templates).
    quant: Option<QuantDataset>,
    /// Global id of the buffer's first point (= current snapshot size).
    base: usize,
    /// Whether inserts must carry a token set — fixed by the snapshot's
    /// feature kinds at construction, so a hybrid index cannot silently
    /// accumulate set-less points that would panic the mixture scorer or
    /// the compaction concat later.
    wants_sets: bool,
}

impl DeltaBuffer {
    /// Empty buffer carrying the same feature kinds as `template` (the
    /// snapshot dataset), with global ids starting at `base`.
    pub fn new(template: &Dataset, base: usize) -> DeltaBuffer {
        let ds = if template.dim() > 0 {
            Dataset::from_dense("delta", template.dim(), Vec::new(), vec![])
        } else {
            Dataset::from_sets("delta", Vec::new(), vec![])
        };
        let quant = (template.dim() > 0).then(|| QuantDataset::empty(template.dim()));
        let wants_sets = template.dim() == 0 || !template.sets.is_empty();
        DeltaBuffer {
            ds,
            quant,
            base,
            wants_sets,
        }
    }

    /// Number of buffered points.
    pub fn len(&self) -> usize {
        self.ds.len()
    }

    /// True when nothing is buffered.
    pub fn is_empty(&self) -> bool {
        self.ds.is_empty()
    }

    /// Global id of the buffer's first point.
    pub fn base(&self) -> usize {
        self.base
    }

    /// The sequencer high-water mark: the global id the *next* insert will
    /// receive. Strictly monotone over the buffer's lifetime — inserts
    /// advance it by one, [`Self::absorb_prefix`] and [`Self::seal_take`]
    /// preserve it exactly (asserted) — which is what lets WAL replay use
    /// `gid < next_gid()` as its already-applied test without ever
    /// double-applying a record.
    pub fn next_gid(&self) -> u32 {
        (self.base + self.ds.len()) as u32
    }

    /// The buffered points as a dataset (brute-force scoring tile).
    pub fn dataset(&self) -> &Dataset {
        &self.ds
    }

    /// SQ8 codes of the buffered dense rows, row-for-row with
    /// [`Self::dataset`] (`None` for set-only buffers) — the quantized
    /// engine's first-pass tile over the delta.
    pub fn quant(&self) -> Option<&QuantDataset> {
        self.quant.as_ref()
    }

    /// Append a point (dense row and/or token set, matching the snapshot's
    /// feature kinds); returns its global id.
    ///
    /// ```
    /// use stars::data::Dataset;
    /// use stars::serve::DeltaBuffer;
    ///
    /// // A snapshot of 100 dense points hands out global ids from 100 on.
    /// let template = Dataset::from_dense("t", 2, vec![1.0, 0.0], vec![]);
    /// let mut delta = DeltaBuffer::new(&template, 100);
    /// assert_eq!(delta.insert(Some(&[0.0, 1.0]), None), 100);
    /// assert_eq!(delta.insert(Some(&[0.5, 0.5]), None), 101);
    /// assert_eq!(delta.len(), 2);
    /// ```
    pub fn insert(&mut self, row: Option<&[f32]>, set: Option<WeightedSet>) -> u32 {
        assert_eq!(
            set.is_some(),
            self.wants_sets,
            "insert feature kinds must match the indexed dataset"
        );
        let local = self.ds.push_point(row, set);
        if let Some(q) = self.quant.as_mut() {
            q.push_row(row.expect("dense template requires a row"));
        }
        (self.base + local as usize) as u32
    }

    /// Drop the first `prefix` points (absorbed into a new snapshot) and
    /// advance `base` past them. Points inserted while the compaction ran
    /// keep their global ids: the new snapshot ends exactly where the
    /// surviving tail begins.
    pub fn absorb_prefix(&mut self, prefix: usize) {
        assert!(
            prefix <= self.ds.len(),
            "absorb_prefix past the buffer end would rewind the sequencer"
        );
        let high = self.next_gid();
        let tail: Vec<u32> = (prefix as u32..self.ds.len() as u32).collect();
        self.ds = self.ds.subset(&tail);
        // Requantizing the surviving tail is O(|tail| · d) — bounded by
        // `compact_limit`, and per-row SQ8 reproduces the original codes
        // exactly (no cross-row state).
        if self.quant.is_some() {
            self.quant = Some(QuantDataset::from_dataset(&self.ds));
        }
        self.base += prefix;
        assert_eq!(
            self.next_gid(),
            high,
            "absorb_prefix must preserve the sequencer high-water"
        );
    }

    /// Take every buffered point out as a `(dataset, quant)` pair and leave
    /// the buffer empty with `base` advanced past them — the seal step of
    /// the LSM write path ([`crate::serve::durable::SealedSegment`]). Like
    /// [`Self::absorb_prefix`], the sequencer high-water is preserved
    /// exactly: the sealed rows keep their global ids (segment-local row
    /// `i` is global `old_base + i`) and the next insert continues the
    /// sequence.
    pub fn seal_take(&mut self) -> (Dataset, Option<QuantDataset>) {
        let high = self.next_gid();
        let n = self.ds.len();
        let fresh = if self.ds.dim() > 0 {
            Dataset::from_dense("delta", self.ds.dim(), Vec::new(), vec![])
        } else {
            Dataset::from_sets("delta", Vec::new(), vec![])
        };
        let ds = std::mem::replace(&mut self.ds, fresh);
        let quant = self
            .quant
            .as_mut()
            .map(|q| std::mem::replace(q, QuantDataset::empty(ds.dim())));
        self.base += n;
        assert_eq!(
            self.next_gid(),
            high,
            "seal_take must preserve the sequencer high-water"
        );
        (ds, quant)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ids_are_global_and_stable_across_absorption() {
        let template = Dataset::from_dense("t", 2, vec![1.0, 0.0], vec![]);
        let mut d = DeltaBuffer::new(&template, 100);
        assert!(d.is_empty());
        assert_eq!(d.insert(Some(&[1.0, 0.0]), None), 100);
        assert_eq!(d.insert(Some(&[0.0, 1.0]), None), 101);
        assert_eq!(d.insert(Some(&[0.5, 0.5]), None), 102);
        assert_eq!(d.len(), 3);
        // Compaction absorbed the first two: the tail keeps id 102.
        d.absorb_prefix(2);
        assert_eq!(d.base(), 102);
        assert_eq!(d.len(), 1);
        assert_eq!(d.dataset().row(0), &[0.5, 0.5]);
        assert_eq!(d.insert(Some(&[2.0, 0.0]), None), 103);
    }

    #[test]
    fn hybrid_template_requires_sets_on_insert() {
        let template = Dataset::hybrid(
            "t",
            2,
            vec![1.0, 0.0],
            vec![WeightedSet::from_tokens(vec![3])],
            vec![],
        );
        let mut d = DeltaBuffer::new(&template, 1);
        let id = d.insert(Some(&[0.0, 1.0]), Some(WeightedSet::from_tokens(vec![5])));
        assert_eq!(id, 1);
        assert_eq!(d.dataset().set(0).tokens, vec![5]);
    }

    #[test]
    #[should_panic(expected = "insert feature kinds")]
    fn hybrid_template_rejects_setless_insert() {
        let template = Dataset::hybrid(
            "t",
            2,
            vec![1.0, 0.0],
            vec![WeightedSet::from_tokens(vec![3])],
            vec![],
        );
        let mut d = DeltaBuffer::new(&template, 1);
        d.insert(Some(&[0.0, 1.0]), None);
    }

    #[test]
    fn dense_buffers_keep_quant_codes_in_lockstep() {
        let template = Dataset::from_dense("t", 2, vec![1.0, 0.0], vec![]);
        let mut d = DeltaBuffer::new(&template, 10);
        assert_eq!(d.quant().unwrap().len(), 0);
        d.insert(Some(&[3.0, -4.0]), None);
        d.insert(Some(&[0.5, 0.5]), None);
        let q = d.quant().unwrap();
        assert_eq!(q.len(), 2);
        // max|x| = 4 → scale 4/127: 3.0 → round(95.25) = 95, -4.0 → -127.
        assert_eq!(q.codes(0), &[95, -127]);
        // Absorbing a prefix requantizes the surviving tail identically.
        d.absorb_prefix(1);
        assert_eq!(d.quant().unwrap().len(), 1);
        assert_eq!(d.quant().unwrap().codes(0), &[127, 127]);
        // Set-only buffers carry no quant table.
        let sets = Dataset::from_sets("t", vec![WeightedSet::from_tokens(vec![1])], vec![]);
        assert!(DeltaBuffer::new(&sets, 1).quant().is_none());
    }

    #[test]
    fn absorb_prefix_zero_and_empty_buffer_are_noops() {
        let template = Dataset::from_dense("t", 2, vec![1.0, 0.0], vec![]);
        let mut d = DeltaBuffer::new(&template, 7);
        // Absorbing nothing from an empty buffer changes nothing.
        d.absorb_prefix(0);
        assert!(d.is_empty());
        assert_eq!(d.base(), 7);
        assert_eq!(d.quant().unwrap().len(), 0);
        // Absorbing a zero-length prefix of a non-empty buffer keeps every
        // point and every id.
        d.insert(Some(&[1.0, 2.0]), None);
        d.insert(Some(&[-3.0, 0.5]), None);
        d.absorb_prefix(0);
        assert_eq!(d.base(), 7);
        assert_eq!(d.len(), 2);
        assert_eq!(d.dataset().row(0), &[1.0, 2.0]);
        assert_eq!(d.dataset().row(1), &[-3.0, 0.5]);
        // The next insert continues the id sequence untouched.
        assert_eq!(d.insert(Some(&[0.0, 1.0]), None), 9);
    }

    #[test]
    fn absorb_full_buffer_then_insert_continues_ids() {
        // An insert that lands while compaction runs keeps its global id:
        // absorbing the whole pre-compaction prefix moves `base` to exactly
        // where the new snapshot ends, so the concurrent insert's id is the
        // next one handed out.
        let template = Dataset::from_dense("t", 2, vec![1.0, 0.0], vec![]);
        let mut d = DeltaBuffer::new(&template, 50);
        assert_eq!(d.insert(Some(&[1.0, 0.0]), None), 50);
        assert_eq!(d.insert(Some(&[0.0, 1.0]), None), 51);
        // Compaction snapshots len() == 2, then an insert races in.
        let prefix = d.len();
        assert_eq!(d.insert(Some(&[0.25, 0.75]), None), 52);
        d.absorb_prefix(prefix);
        assert_eq!(d.base(), 52);
        assert_eq!(d.len(), 1, "the racing insert survives in the tail");
        assert_eq!(d.dataset().row(0), &[0.25, 0.75]);
        assert_eq!(d.quant().unwrap().len(), 1);
        assert_eq!(d.insert(Some(&[5.0, 5.0]), None), 53);
        // Absorbing everything empties the buffer but keeps ids monotone.
        let rest = d.len();
        d.absorb_prefix(rest);
        assert!(d.is_empty());
        assert_eq!(d.base(), 54);
        assert_eq!(d.insert(Some(&[9.0, 9.0]), None), 54);
    }

    #[test]
    fn partial_absorb_requantizes_tail_exactly() {
        // After a partial absorb, the surviving tail's SQ8 codes and scales
        // must equal a from-scratch quantization of the tail dataset —
        // per-row SQ8 carries no cross-row state, so the lockstep table
        // never drifts from what `QuantDataset::from_dataset` would build.
        let template = Dataset::from_dense("t", 3, vec![1.0, 0.0, 0.0], vec![]);
        let mut d = DeltaBuffer::new(&template, 0);
        let rows: [&[f32]; 5] = [
            &[3.0, -4.0, 0.5],
            &[0.0, 0.0, 0.0],
            &[1e-3, -2e-3, 5e-4],
            &[100.0, 50.0, -25.0],
            &[-0.75, 0.25, 0.125],
        ];
        for r in rows {
            d.insert(Some(r), None);
        }
        d.absorb_prefix(2);
        let tail = d.quant().unwrap();
        let fresh = QuantDataset::from_dataset(d.dataset());
        assert_eq!(tail.len(), fresh.len());
        assert_eq!(tail.len(), 3);
        for i in 0..tail.len() {
            assert_eq!(tail.codes(i), fresh.codes(i), "row {i} codes");
            assert_eq!(
                tail.scale(i).to_bits(),
                fresh.scale(i).to_bits(),
                "row {i} scale"
            );
        }
        // A post-absorb insert extends the same table in lockstep.
        d.insert(Some(&[2.0, -2.0, 1.0]), None);
        assert_eq!(d.quant().unwrap().len(), 4);
        assert_eq!(d.quant().unwrap().codes(3), &[127, -127, 64]);
    }

    #[test]
    fn replay_after_partial_absorb_cannot_double_apply() {
        // WAL replay's already-applied test is `gid < next_gid()`. A
        // partial absorb moves points out of the buffer but must keep the
        // high-water fixed, so a replayed record for an absorbed gid is
        // still recognized as applied — the regression this guards is
        // `base` advancing by less than the absorbed prefix, which would
        // rewind next_gid() and let replay re-insert gids 50..52 as fresh
        // points under wrong ids.
        let template = Dataset::from_dense("t", 2, vec![1.0, 0.0], vec![]);
        let mut d = DeltaBuffer::new(&template, 50);
        for i in 0..4 {
            assert_eq!(d.insert(Some(&[i as f32, 1.0]), None), 50 + i);
        }
        assert_eq!(d.next_gid(), 54);
        d.absorb_prefix(2);
        assert_eq!(d.next_gid(), 54, "high-water must survive a partial absorb");
        // Replay of the WAL from gid 50: the first four records are all
        // below the high-water (already applied — two absorbed, two in the
        // tail); only gid 54 onward applies.
        for gid in 50..54u32 {
            assert!(gid < d.next_gid(), "gid {gid} would double-apply");
        }
        assert_eq!(d.insert(Some(&[9.0, 9.0]), None), 54);
    }

    #[test]
    fn seal_take_empties_the_buffer_and_keeps_the_sequencer() {
        let template = Dataset::from_dense("t", 2, vec![1.0, 0.0], vec![]);
        let mut d = DeltaBuffer::new(&template, 10);
        d.insert(Some(&[3.0, -4.0]), None);
        d.insert(Some(&[0.5, 0.5]), None);
        let (ds, quant) = d.seal_take();
        assert_eq!(ds.len(), 2);
        assert_eq!(ds.row(0), &[3.0, -4.0]);
        assert_eq!(quant.as_ref().unwrap().len(), 2);
        assert_eq!(quant.unwrap().codes(0), &[95, -127]);
        assert!(d.is_empty());
        assert_eq!(d.base(), 12);
        assert_eq!(d.next_gid(), 12);
        // Sealed rows keep their ids; the next insert continues after them.
        assert_eq!(d.insert(Some(&[1.0, 1.0]), None), 12);
        // Set-only buffers seal without a quant table.
        let sets = Dataset::from_sets("t", vec![WeightedSet::from_tokens(vec![1])], vec![]);
        let mut sd = DeltaBuffer::new(&sets, 0);
        sd.insert(None, Some(WeightedSet::from_tokens(vec![4])));
        let (sds, squant) = sd.seal_take();
        assert_eq!(sds.len(), 1);
        assert!(squant.is_none());
    }

    #[test]
    fn set_deltas_follow_template_kind() {
        let template = Dataset::from_sets("t", vec![WeightedSet::from_tokens(vec![1])], vec![]);
        let mut d = DeltaBuffer::new(&template, 1);
        let id = d.insert(None, Some(WeightedSet::from_tokens(vec![4, 9])));
        assert_eq!(id, 1);
        assert_eq!(d.dataset().set(0).tokens, vec![4, 9]);
    }
}
