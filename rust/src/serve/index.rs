//! The immutable serving snapshot.
//!
//! A [`StarIndex`] freezes everything the read path needs: the indexed
//! dataset, the degree-capped star graph in CSR form, one prepared
//! [`SketchState`] per routing repetition (so query sketching reuses the
//! cached hyperplane matrices / token tables instead of re-deriving them
//! per batch), and the [`Router`]'s bucket-key → entry tables. Snapshots
//! are shared behind `Arc` and replaced wholesale by compaction — no
//! in-place mutation, so readers take no locks beyond the epoch pointer.

use super::router::Router;
use super::ServeConfig;
use crate::ampc::SnapshotStats;
use crate::data::types::Dataset;
use crate::graph::{Csr, Graph};
use crate::lsh::{LshFamily, SketchState};
use crate::sim::QuantDataset;
use crate::util::pool;
use std::sync::Arc;

/// Minimum points per sketch chunk before the snapshot/query sketch passes
/// spin up pool threads (same economics as the build-side drivers).
const PAR_MIN_CHUNK: usize = 1024;

fn chunk_points(n: usize, workers: usize) -> usize {
    let w = workers.max(1).min(n.div_ceil(PAR_MIN_CHUNK).max(1));
    n.div_ceil(w).max(1)
}

/// An immutable serving snapshot over a built star graph.
///
/// States are held behind `Arc` so incremental compaction can carry them
/// into the next epoch unchanged (they are pure per-repetition caches — see
/// the state-purity contract on [`SketchState`]) instead of re-deriving
/// them per snapshot.
pub struct StarIndex<'f> {
    ds: Dataset,
    csr: Csr,
    states: Vec<Arc<dyn SketchState + 'f>>,
    router: Router,
    /// SQ8 codes of the dense rows for quantized first-pass scoring —
    /// built when `cfg.quantized` and the dataset is dense, shared with
    /// the next epoch by incremental compaction via `Arc` (the extension
    /// clones, but compaction already owns the merge).
    quant: Option<Arc<QuantDataset>>,
    cfg: ServeConfig,
}

impl<'f> StarIndex<'f> {
    /// Build a snapshot from a dataset, its hash family and its built
    /// graph, sized to the host's worker pool.
    pub fn build(
        ds: Dataset,
        family: &'f dyn LshFamily,
        graph: &Graph,
        cfg: ServeConfig,
    ) -> StarIndex<'f> {
        Self::build_with_workers(ds, family, graph, cfg, pool::default_workers())
    }

    /// [`StarIndex::build`] with an explicit worker count for the sketch
    /// and routing passes.
    pub fn build_with_workers(
        ds: Dataset,
        family: &'f dyn LshFamily,
        graph: &Graph,
        cfg: ServeConfig,
        workers: usize,
    ) -> StarIndex<'f> {
        Self::build_from_keys(ds, family, graph, cfg, workers, Vec::new())
    }

    /// [`StarIndex::build_with_workers`] reusing bucket keys the graph
    /// build already computed: `build_keys[rep]`, when `Some`, must be the
    /// full per-point key vector of routing repetition `rep` (exactly what
    /// `StarsBuilder::build_with_keys` hands over). Missing or absent
    /// repetitions are sketched here as before — so a SortingLSH build,
    /// which never computes bucket keys, still exports a snapshot, it just
    /// pays for the routing sketch itself.
    pub fn build_from_keys(
        ds: Dataset,
        family: &'f dyn LshFamily,
        graph: &Graph,
        cfg: ServeConfig,
        workers: usize,
        mut build_keys: Vec<Option<Vec<u64>>>,
    ) -> StarIndex<'f> {
        assert_eq!(
            graph.num_nodes(),
            ds.len(),
            "graph node count != dataset size"
        );
        let n = ds.len();
        let reps = cfg.route_reps.max(1);
        // One prepared state per routing repetition — the same (family,
        // rep) draws the builder bucketed repetitions 0..R with, so routing
        // buckets coincide with build buckets for shared rep ids. States
        // are retained: the query path sketches straight through them.
        let mut states: Vec<Arc<dyn SketchState + 'f>> = Vec::with_capacity(reps);
        let mut keys_per_rep: Vec<Vec<u64>> = Vec::with_capacity(reps);
        for rep in 0..reps {
            let state: Arc<dyn SketchState + 'f> = Arc::from(family.prepare(&ds, rep as u64));
            let keys = match build_keys.get_mut(rep).and_then(Option::take) {
                Some(keys) => {
                    assert_eq!(keys.len(), n, "build keys length != dataset size");
                    keys
                }
                None => {
                    let mut keys = vec![0u64; n];
                    if n > 0 {
                        pool::parallel_fill(&mut keys, chunk_points(n, workers), |lo, slice| {
                            state.bucket_keys_into(&ds, lo, slice)
                        });
                    }
                    keys
                }
            };
            states.push(state);
            keys_per_rep.push(keys);
        }
        let router = Router::build(&keys_per_rep, cfg.route_leaders, cfg.seed);
        let quant =
            (cfg.quantized && ds.dim() > 0).then(|| Arc::new(QuantDataset::from_dataset(&ds)));
        StarIndex {
            csr: Csr::new(graph),
            ds,
            states,
            router,
            quant,
            cfg,
        }
    }

    /// Assemble a snapshot from already-built parts — the incremental
    /// compaction path, where the dataset grew by the delta, the CSR comes
    /// from a re-opened accumulator, the router was extended in place, and
    /// the sketch states are shared with the previous epoch.
    pub(crate) fn from_parts(
        ds: Dataset,
        csr: Csr,
        states: Vec<Arc<dyn SketchState + 'f>>,
        router: Router,
        quant: Option<Arc<QuantDataset>>,
        cfg: ServeConfig,
    ) -> StarIndex<'f> {
        assert_eq!(csr.num_nodes(), ds.len(), "CSR node count != dataset size");
        assert_eq!(states.len(), router.reps(), "state count != router reps");
        if let Some(q) = &quant {
            assert_eq!(q.len(), ds.len(), "quant row count != dataset size");
        }
        StarIndex {
            ds,
            csr,
            states,
            router,
            quant,
            cfg,
        }
    }

    /// Number of indexed points.
    pub fn len(&self) -> usize {
        self.ds.len()
    }

    /// True when nothing is indexed.
    pub fn is_empty(&self) -> bool {
        self.ds.is_empty()
    }

    /// The indexed dataset.
    pub fn dataset(&self) -> &Dataset {
        &self.ds
    }

    /// The star graph adjacency.
    pub fn csr(&self) -> &Csr {
        &self.csr
    }

    /// The routing tables.
    pub fn router(&self) -> &Router {
        &self.router
    }

    /// The snapshot's configuration.
    pub fn config(&self) -> &ServeConfig {
        &self.cfg
    }

    /// The cached per-repetition sketch states (shared with the next epoch
    /// by incremental compaction).
    pub(crate) fn states(&self) -> &[Arc<dyn SketchState + 'f>] {
        &self.states
    }

    /// The SQ8 side table for quantized first-pass scoring (`None` unless
    /// the snapshot was built with [`ServeConfig::quantized`] over a dense
    /// dataset).
    pub fn quant(&self) -> Option<&Arc<QuantDataset>> {
        self.quant.as_ref()
    }

    /// Size/memory telemetry of this snapshot (router tables, CSR arrays,
    /// cached sketch-state tables) for capacity planning — attached to
    /// build reports by `StarsBuilder::build_indexed` and to every
    /// `CompactionReport`.
    pub fn stats(&self) -> SnapshotStats {
        SnapshotStats {
            points: self.ds.len(),
            edges: self.csr.num_edges(),
            router_reps: self.router.reps(),
            router_entries: self.router.num_entries(),
            router_bytes: self.router.heap_bytes(),
            csr_bytes: self.csr.heap_bytes(),
            state_table_bytes: self.states.iter().map(|s| s.table_bytes()).sum(),
            quantized: self.quant.is_some(),
            rescore_factor: if self.quant.is_some() {
                self.cfg.rescore_factor.max(1)
            } else {
                0
            },
            quant_bytes: self.quant.as_ref().map_or(0, |q| q.heap_bytes()),
            // Bytes each row occupies in the *first-pass scoring* storage:
            // SQ8 codes + scale when quantized, the dense f32 row
            // otherwise — the ~4× reduction the quantized tier buys.
            bytes_per_row: match &self.quant {
                Some(q) => q.bytes_per_row(),
                None => self.ds.dim() * std::mem::size_of::<f32>(),
            },
        }
    }

    /// Bucket keys of a query batch under every routing repetition,
    /// rep-major: `keys[rep * queries.len() + qi]`. Chunked over `workers`
    /// pool threads; output is identical for any worker count (each point's
    /// key depends only on the prepared state).
    pub fn query_keys(&self, queries: &Dataset, workers: usize) -> Vec<u64> {
        let nq = queries.len();
        let mut keys = vec![0u64; self.states.len() * nq];
        if nq == 0 {
            return keys;
        }
        for (rep, state) in self.states.iter().enumerate() {
            let slice = &mut keys[rep * nq..(rep + 1) * nq];
            pool::parallel_fill(slice, chunk_points(nq, workers), |lo, out| {
                state.bucket_keys_into(queries, lo, out)
            });
        }
        keys
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::lsh::{LshFamily, SimHash};
    use crate::sim::CosineSim;
    use crate::stars::{Algorithm, BuildParams, StarsBuilder};

    fn small_index(h: &SimHash) -> StarIndex<'_> {
        let ds = synth::gaussian_mixture(600, 16, 6, 0.08, 31);
        let out = StarsBuilder::new(&ds)
            .similarity(&CosineSim)
            .hash(h)
            .params(
                BuildParams::threshold_mode(Algorithm::LshStars)
                    .sketches(6)
                    .threshold(0.4),
            )
            .workers(2)
            .build();
        StarIndex::build(ds, h, &out.graph, ServeConfig::default().route_reps(4))
    }

    #[test]
    fn snapshot_keys_match_family_keys_and_route_home() {
        let h = SimHash::new(16, 8, 5);
        let index = small_index(&h);
        assert_eq!(index.len(), 600);
        // Query the index with its own points: per-rep keys must equal the
        // family's keys, and each point's bucket must route somewhere.
        let queries = index.dataset().subset(&[0, 17, 599]);
        let keys = index.query_keys(&queries, 2);
        for (rep, want_rep) in (0..4u64).enumerate() {
            let want = h.bucket_keys(index.dataset(), want_rep);
            for (qi, &p) in [0usize, 17, 599].iter().enumerate() {
                assert_eq!(keys[rep * 3 + qi], want[p], "rep {rep} q{qi}");
                assert!(
                    !index.router().route(rep, want[p]).is_empty(),
                    "indexed point {p} has no entries under rep {rep}"
                );
            }
        }
    }

    #[test]
    fn query_keys_worker_invariant() {
        let h = SimHash::new(16, 8, 5);
        let index = small_index(&h);
        let queries = index.dataset().subset(&(0..64u32).collect::<Vec<_>>());
        let one = index.query_keys(&queries, 1);
        for w in [2usize, 7] {
            assert_eq!(index.query_keys(&queries, w), one, "workers={w}");
        }
    }

    #[test]
    fn build_from_keys_matches_recomputed_routing() {
        // Handing the build's key vectors over must produce the same
        // routing tables as re-sketching them (they are the same values —
        // that is the point of sharing them).
        let h = SimHash::new(16, 8, 5);
        let ds = synth::gaussian_mixture(600, 16, 6, 0.08, 31);
        let out = StarsBuilder::new(&ds)
            .similarity(&CosineSim)
            .hash(&h)
            .params(
                BuildParams::threshold_mode(Algorithm::LshStars)
                    .sketches(6)
                    .threshold(0.4),
            )
            .workers(2)
            .build();
        let cfg = ServeConfig::default().route_reps(4);
        let keys: Vec<Option<Vec<u64>>> =
            (0..4u64).map(|r| Some(h.bucket_keys(&ds, r))).collect();
        let a = StarIndex::build_from_keys(ds.clone(), &h, &out.graph, cfg.clone(), 2, keys);
        let b = StarIndex::build_with_workers(ds.clone(), &h, &out.graph, cfg, 2);
        assert_eq!(a.router().num_entries(), b.router().num_entries());
        for rep in 0..4u64 {
            let want = h.bucket_keys(&ds, rep);
            for p in [0usize, 99, 300, 599] {
                assert_eq!(
                    a.router().route(rep as usize, want[p]),
                    b.router().route(rep as usize, want[p]),
                    "rep {rep} point {p}"
                );
            }
        }
        let (sa, sb) = (a.stats(), b.stats());
        assert_eq!(sa, sb);
        assert_eq!(sa.points, 600);
        assert_eq!(sa.edges, a.csr().num_edges());
        assert!(sa.router_entries > 0 && sa.router_bytes > 0);
        assert!(sa.csr_bytes > 0);
        // SimHash states cache 4 reps × 8 planes × 16 dims of f32.
        assert_eq!(sa.state_table_bytes, 4 * 8 * 16 * 4);
    }

    #[test]
    fn quantized_build_carries_the_sq8_table() {
        let h = SimHash::new(16, 8, 5);
        let ds = synth::gaussian_mixture(600, 16, 6, 0.08, 31);
        let out = StarsBuilder::new(&ds)
            .similarity(&CosineSim)
            .hash(&h)
            .params(
                BuildParams::threshold_mode(Algorithm::LshStars)
                    .sketches(6)
                    .threshold(0.4),
            )
            .workers(2)
            .build();
        let cfg = ServeConfig::default().route_reps(4).quantized(4);
        let index = StarIndex::build(ds, &h, &out.graph, cfg);
        let q = index.quant().expect("dense quantized snapshot has a table");
        assert_eq!(q.len(), 600);
        let s = index.stats();
        assert!(s.quantized);
        assert_eq!(s.rescore_factor, 4);
        // 16 i8 codes + one f32 scale vs 16 f32 — the ~4× row reduction.
        assert_eq!(s.bytes_per_row, 16 + 4);
        assert_eq!(s.quant_bytes, 600 * (16 + 4));
        // A plain snapshot reports dense row bytes and no table.
        let plain = small_index(&h);
        assert!(plain.quant().is_none());
        let sp = plain.stats();
        assert!(!sp.quantized);
        assert_eq!(sp.rescore_factor, 0);
        assert_eq!(sp.quant_bytes, 0);
        assert_eq!(sp.bytes_per_row, 16 * 4);
    }

    #[test]
    fn empty_index_builds() {
        let ds = crate::data::Dataset::from_dense("e", 4, Vec::new(), vec![]);
        let h = SimHash::new(4, 6, 1);
        let g = crate::graph::Graph::from_edges(0, vec![]);
        let index = StarIndex::build(ds, &h, &g, ServeConfig::default());
        assert!(index.is_empty());
        assert_eq!(index.router().num_entries(), 0);
    }
}
