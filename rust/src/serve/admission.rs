//! Admission-controlled front door for the serve engines.
//!
//! At fleet scale the serve layer's failure mode is not a crash but an
//! overload collapse: unbounded concurrent queries grow tail latency until
//! every caller times out. The [`FrontDoor`] bounds that failure with a
//! ladder of levers, cheapest first:
//!
//! 1. **Tenant cap** ([`FrontDoor::query_for`]) — a per-tenant token
//!    bucket (`tenant_qps`/`tenant_burst`) refuses a hot tenant before it
//!    can occupy a queue slot ([`ShedReason::TenantCap`]), so one abusive
//!    caller cannot starve the rest of the fleet's budget.
//! 2. **Admit** — in-flight depth below the degrade threshold: serve the
//!    configured tier untouched.
//! 3. **Degrade** — depth at or past `degrade_at × queue_limit`: force the
//!    quantized first-pass tier with a reduced rescore width
//!    ([`ServeBackend::query_tier`]), trading a bounded recall dip for
//!    exact f32 work per query, *before* refusing anyone.
//! 4. **Shed** — the queue is full ([`ShedReason::QueueFull`]), or the
//!    EWMA service estimate says the query cannot meet its deadline behind
//!    the current backlog ([`ShedReason::Deadline`]): refuse immediately —
//!    an early, explicit rejection the caller can retry against another
//!    replica, instead of a late timeout.
//!
//! The door is generic over [`ServeBackend`], so the same ladder fronts a
//! single-process [`QueryEngine`] or a scatter-gather
//! [`super::sharded::ShardedEngine`] — in the sharded case one
//! [`AdmissionPermit`] is held per outstanding scatter (a batch *is* one
//! scatter), so in-flight depth counts scatters exactly.
//!
//! Admission is synchronous and conservative (no reordering, no waiting
//! room): depth is bounded by `queue_limit` at every instant, and admitted
//! queries are served by the same deterministic engine — so admitted
//! results are bit-identical to a door-less engine at the same tier, which
//! is what `tests/fault_injection.rs` asserts while shedding under
//! synthetic pressure.

use super::executor::QueryEngine;
use super::sharded::ShardedEngine;
use crate::data::types::Dataset;
use crate::obs::{Counter, HistHandle, Histogram};
use crate::util::fxhash::FxHashMap;
use crate::util::json::Json;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Mutex;
use std::time::Instant;

/// The engine interface the [`FrontDoor`] fronts: answer a batch at the
/// configured or an overridden scoring tier. Implemented by
/// [`QueryEngine`] and [`ShardedEngine`] (whose answers are bit-identical
/// to each other, so the door's ladder composes with either).
pub trait ServeBackend {
    /// Answer a batch at the engine's configured tier.
    fn query(&self, queries: &Dataset, k: usize) -> Vec<Vec<(u32, f32)>>;

    /// Answer a batch with an explicit tier override (`Some(rf)` forces
    /// the quantized first pass with rescore width `c = k · rf`).
    fn query_tier(
        &self,
        queries: &Dataset,
        k: usize,
        quant_rescore: Option<usize>,
    ) -> Vec<Vec<(u32, f32)>>;

    /// True when the degraded quantized tier can actually serve.
    fn quant_ready(&self) -> bool;
}

impl ServeBackend for QueryEngine<'_> {
    fn query(&self, queries: &Dataset, k: usize) -> Vec<Vec<(u32, f32)>> {
        QueryEngine::query(self, queries, k)
    }

    fn query_tier(
        &self,
        queries: &Dataset,
        k: usize,
        quant_rescore: Option<usize>,
    ) -> Vec<Vec<(u32, f32)>> {
        QueryEngine::query_tier(self, queries, k, quant_rescore)
    }

    fn quant_ready(&self) -> bool {
        QueryEngine::quant_ready(self)
    }
}

impl ServeBackend for ShardedEngine<'_> {
    fn query(&self, queries: &Dataset, k: usize) -> Vec<Vec<(u32, f32)>> {
        ShardedEngine::query(self, queries, k)
    }

    fn query_tier(
        &self,
        queries: &Dataset,
        k: usize,
        quant_rescore: Option<usize>,
    ) -> Vec<Vec<(u32, f32)>> {
        ShardedEngine::query_tier(self, queries, k, quant_rescore)
    }

    fn quant_ready(&self) -> bool {
        ShardedEngine::quant_ready(self)
    }
}

/// Admission policy knobs.
#[derive(Clone, Debug)]
pub struct AdmissionConfig {
    /// Maximum concurrent in-flight queries; one more is shed. 0 disables
    /// the queue bound (and with it the degrade threshold).
    pub queue_limit: usize,
    /// Per-query deadline budget, milliseconds. A query whose estimated
    /// queue wait (`depth × EWMA service time`) already exceeds this is
    /// shed on arrival. 0 disables deadline shedding.
    pub deadline_ms: f64,
    /// Occupancy fraction of `queue_limit` at which the degraded tier
    /// engages.
    pub degrade_at: f64,
    /// Rescore width (`c = k · degraded_rescore`) served under pressure —
    /// deliberately below the typical configured factor.
    pub degraded_rescore: usize,
    /// Sustained per-tenant query rate (batches/second) enforced by
    /// [`FrontDoor::query_for`]'s token buckets. 0 disables tenant caps
    /// (`query_for` then behaves exactly like [`FrontDoor::query`]).
    pub tenant_qps: f64,
    /// Token-bucket burst: how many batches a tenant may issue back to
    /// back before the sustained rate applies (buckets start full).
    pub tenant_burst: usize,
}

impl Default for AdmissionConfig {
    fn default() -> AdmissionConfig {
        AdmissionConfig {
            queue_limit: 64,
            deadline_ms: 0.0,
            degrade_at: 0.75,
            degraded_rescore: 2,
            tenant_qps: 0.0,
            tenant_burst: 8,
        }
    }
}

impl AdmissionConfig {
    /// Set the in-flight bound.
    pub fn queue_limit(mut self, limit: usize) -> Self {
        self.queue_limit = limit;
        self
    }

    /// Set the per-query deadline budget (ms); 0 disables.
    pub fn deadline_ms(mut self, ms: f64) -> Self {
        self.deadline_ms = ms;
        self
    }

    /// Set the degrade occupancy fraction.
    pub fn degrade_at(mut self, frac: f64) -> Self {
        self.degrade_at = frac;
        self
    }

    /// Set the degraded tier's rescore width multiplier.
    pub fn degraded_rescore(mut self, rf: usize) -> Self {
        self.degraded_rescore = rf.max(1);
        self
    }

    /// Set the sustained per-tenant rate (batches/s); 0 disables caps.
    pub fn tenant_qps(mut self, qps: f64) -> Self {
        self.tenant_qps = qps.max(0.0);
        self
    }

    /// Set the per-tenant burst allowance (clamped to ≥ 1).
    pub fn tenant_burst(mut self, burst: usize) -> Self {
        self.tenant_burst = burst.max(1);
        self
    }
}

/// Why a query was refused.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ShedReason {
    /// In-flight depth hit `queue_limit`.
    QueueFull,
    /// Estimated wait behind the backlog exceeded the deadline budget.
    Deadline,
    /// The tenant's token bucket was empty ([`FrontDoor::query_for`]).
    TenantCap,
}

/// Outcome of one front-door query.
#[derive(Clone, Debug)]
pub enum Admission {
    /// Served at the engine's configured tier.
    Served(Vec<Vec<(u32, f32)>>),
    /// Served on the degraded quantized tier (reduced rescore width).
    Degraded(Vec<Vec<(u32, f32)>>),
    /// Refused; nothing was computed.
    Shed(ShedReason),
}

impl Admission {
    /// The answers, if the query was served at any tier.
    pub fn results(self) -> Option<Vec<Vec<(u32, f32)>>> {
        match self {
            Admission::Served(r) | Admission::Degraded(r) => Some(r),
            Admission::Shed(_) => None,
        }
    }

    /// True when the query was refused.
    pub fn is_shed(&self) -> bool {
        matches!(self, Admission::Shed(_))
    }
}

/// Counter snapshot of a front door's life so far.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct AdmissionStats {
    /// Queries served (either tier).
    pub admitted: u64,
    /// Queries served on the degraded quantized tier.
    pub degraded: u64,
    /// Queries refused because the queue was full.
    pub queue_sheds: u64,
    /// Queries refused by the deadline estimate.
    pub deadline_sheds: u64,
    /// Queries refused by a per-tenant token bucket.
    pub tenant_sheds: u64,
    /// Highest concurrent in-flight depth ever admitted (≤ `queue_limit`).
    pub depth_high_water: usize,
    /// Median per-query service time over the latency reservoir, ms.
    pub p50_ms: f64,
    /// 99th-percentile per-query service time, ms.
    pub p99_ms: f64,
    /// Current EWMA per-query service estimate, ms (0 until first sample).
    pub ewma_ms: f64,
}

impl AdmissionStats {
    /// Total refusals, all reasons.
    pub fn shed(&self) -> u64 {
        self.queue_sheds + self.deadline_sheds + self.tenant_sheds
    }

    /// JSON object for serving reports and benches.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("admitted", Json::from(self.admitted)),
            ("degraded", Json::from(self.degraded)),
            ("queue_sheds", Json::from(self.queue_sheds)),
            ("deadline_sheds", Json::from(self.deadline_sheds)),
            ("tenant_sheds", Json::from(self.tenant_sheds)),
            ("depth_high_water", Json::from(self.depth_high_water)),
            ("latency_p50_ms", Json::from(self.p50_ms)),
            ("latency_p99_ms", Json::from(self.p99_ms)),
            ("ewma_ms", Json::from(self.ewma_ms)),
        ])
    }
}

/// RAII admission slot: holding one occupies in-flight depth; dropping it
/// releases the slot. [`FrontDoor::query`] uses one internally; tests and
/// external load drivers hold them to apply deterministic pressure. The
/// release runs in `Drop`, so a panicking engine still frees its slot
/// during unwind — the no-leak property `tests/fault_injection.rs` pins.
pub struct AdmissionPermit<'d> {
    in_flight: &'d AtomicUsize,
}

impl Drop for AdmissionPermit<'_> {
    fn drop(&mut self) {
        self.in_flight.fetch_sub(1, Ordering::SeqCst);
    }
}

/// A tenant's token bucket: a fractional token balance refilled at
/// `tenant_qps` tokens/second up to `tenant_burst`, spent one per batch.
struct TokenBucket {
    tokens: f64,
    last: Instant,
}

/// The admission-controlled front door over any [`ServeBackend`].
pub struct FrontDoor<'e, E: ServeBackend + ?Sized> {
    engine: &'e E,
    cfg: AdmissionConfig,
    in_flight: AtomicUsize,
    depth_high_water: AtomicUsize,
    admitted: AtomicU64,
    degraded: AtomicU64,
    queue_sheds: AtomicU64,
    deadline_sheds: AtomicU64,
    tenant_sheds_n: AtomicU64,
    /// Per-tenant buckets, created on first sight (off the hot path —
    /// only `query_for` with `tenant_qps > 0` takes the lock).
    tenants: Mutex<FxHashMap<u64, TokenBucket>>,
    /// EWMA of per-query service time in integer microseconds (0 = no
    /// sample yet). Fixed-point so it fits one lock-free atomic — kept for
    /// the deadline-shedding estimate (a last-values estimate, which the
    /// whole-life histogram below is deliberately not).
    ewma_us: AtomicU64,
    /// Per-query service time, microseconds — a lock-free log-bucketed
    /// [`Histogram`] (≤ 6.25 % relative quantile error), replacing the old
    /// sort-based latency reservoir.
    lat_us: Histogram,
    /// Registry mirror: in-flight depth observed at each admit
    /// (`stars_serve_queue_depth`).
    queue_depth_hist: HistHandle,
    /// Registry mirror: total refusals, all reasons
    /// (`stars_serve_sheds_total`).
    sheds_total: Counter,
    /// Registry mirror: tenant-cap refusals alone
    /// (`stars_serve_tenant_sheds_total`).
    tenant_sheds_total: Counter,
}

impl<'e, E: ServeBackend + ?Sized> FrontDoor<'e, E> {
    /// Front door over an engine with the given policy.
    pub fn new(engine: &'e E, cfg: AdmissionConfig) -> FrontDoor<'e, E> {
        FrontDoor {
            engine,
            cfg,
            in_flight: AtomicUsize::new(0),
            depth_high_water: AtomicUsize::new(0),
            admitted: AtomicU64::new(0),
            degraded: AtomicU64::new(0),
            queue_sheds: AtomicU64::new(0),
            deadline_sheds: AtomicU64::new(0),
            tenant_sheds_n: AtomicU64::new(0),
            tenants: Mutex::new(FxHashMap::default()),
            ewma_us: AtomicU64::new(0),
            lat_us: Histogram::new(),
            queue_depth_hist: crate::obs::registry().histogram("stars_serve_queue_depth"),
            sheds_total: crate::obs::registry().counter("stars_serve_sheds_total"),
            tenant_sheds_total: crate::obs::registry()
                .counter("stars_serve_tenant_sheds_total"),
        }
    }

    /// The policy in force.
    pub fn config(&self) -> &AdmissionConfig {
        &self.cfg
    }

    /// Current in-flight depth (queries plus held permits).
    pub fn depth(&self) -> usize {
        self.in_flight.load(Ordering::SeqCst)
    }

    /// Try to occupy one admission slot. `None` means the queue is full
    /// (counted as a queue shed). External load drivers hold permits to
    /// create deterministic backlog; the multi-shard front end holds one
    /// per outstanding scatter.
    pub fn acquire(&self) -> Option<AdmissionPermit<'_>> {
        let depth = self.in_flight.fetch_add(1, Ordering::SeqCst) + 1;
        if self.cfg.queue_limit > 0 && depth > self.cfg.queue_limit {
            self.in_flight.fetch_sub(1, Ordering::SeqCst);
            self.queue_sheds.fetch_add(1, Ordering::Relaxed);
            self.sheds_total.inc(1);
            return None;
        }
        self.depth_high_water.fetch_max(depth, Ordering::SeqCst);
        self.queue_depth_hist.record(depth as u64);
        Some(AdmissionPermit {
            in_flight: &self.in_flight,
        })
    }

    /// Admit-or-shed one query batch through the ladder (no tenant
    /// attribution — the bucket step is skipped). Admitted batches are
    /// answered by the underlying engine — bit-identical to calling it
    /// directly at the same tier.
    pub fn query(&self, queries: &Dataset, k: usize) -> Admission {
        self.admit_and_serve(queries, k)
    }

    /// [`FrontDoor::query`] on behalf of a tenant: the tenant's token
    /// bucket is the first (cheapest) rung — an empty bucket refuses the
    /// batch before it can occupy a queue slot, so a hot tenant sheds
    /// while cold tenants' admission, tier and results are untouched.
    /// With `tenant_qps = 0` the bucket step is a no-op.
    pub fn query_for(&self, tenant: u64, queries: &Dataset, k: usize) -> Admission {
        if !self.tenant_admit(tenant) {
            self.tenant_sheds_n.fetch_add(1, Ordering::Relaxed);
            self.sheds_total.inc(1);
            self.tenant_sheds_total.inc(1);
            return Admission::Shed(ShedReason::TenantCap);
        }
        self.admit_and_serve(queries, k)
    }

    /// Take one token from `tenant`'s bucket (true = admit). Buckets start
    /// full at `tenant_burst` and refill continuously at `tenant_qps`.
    fn tenant_admit(&self, tenant: u64) -> bool {
        if self.cfg.tenant_qps <= 0.0 {
            return true;
        }
        let burst = self.cfg.tenant_burst.max(1) as f64;
        let now = Instant::now();
        let mut tenants = self.tenants.lock().unwrap();
        let b = tenants.entry(tenant).or_insert(TokenBucket {
            tokens: burst,
            last: now,
        });
        let elapsed = now.duration_since(b.last).as_secs_f64();
        b.last = now;
        b.tokens = (b.tokens + elapsed * self.cfg.tenant_qps).min(burst);
        if b.tokens >= 1.0 {
            b.tokens -= 1.0;
            true
        } else {
            false
        }
    }

    /// The shared admit → deadline → degrade → serve ladder.
    fn admit_and_serve(&self, queries: &Dataset, k: usize) -> Admission {
        let permit = match self.acquire() {
            Some(p) => p,
            None => return Admission::Shed(ShedReason::QueueFull),
        };
        // Depth including this query — the backlog its wait estimate and
        // the degrade decision see.
        let depth = self.depth();
        if self.cfg.deadline_ms > 0.0 {
            let ewma_ms = self.ewma_ms();
            if ewma_ms > 0.0 && depth as f64 * ewma_ms > self.cfg.deadline_ms {
                drop(permit);
                self.deadline_sheds.fetch_add(1, Ordering::Relaxed);
                self.sheds_total.inc(1);
                return Admission::Shed(ShedReason::Deadline);
            }
        }
        let degrade = self.cfg.queue_limit > 0
            && self.cfg.degrade_at > 0.0
            && (depth as f64) >= self.cfg.degrade_at * self.cfg.queue_limit as f64
            && self.engine.quant_ready();
        let t = Instant::now();
        let results = if degrade {
            self.engine
                .query_tier(queries, k, Some(self.cfg.degraded_rescore))
        } else {
            self.engine.query(queries, k)
        };
        self.observe(t.elapsed().as_secs_f64() * 1e3, queries.len());
        drop(permit);
        self.admitted.fetch_add(1, Ordering::Relaxed);
        if degrade {
            self.degraded.fetch_add(1, Ordering::Relaxed);
            Admission::Degraded(results)
        } else {
            Admission::Served(results)
        }
    }

    /// Current EWMA per-query service estimate, milliseconds.
    pub fn ewma_ms(&self) -> f64 {
        self.ewma_us.load(Ordering::Relaxed) as f64 / 1e3
    }

    /// Fold one batch's service time into the EWMA (α = 1/8) and the
    /// latency histogram, normalized to per-query time.
    fn observe(&self, batch_ms: f64, nq: usize) {
        let per_query_ms = batch_ms / nq.max(1) as f64;
        let sample_us = (per_query_ms * 1e3).round().max(1.0) as u64;
        // Lossy read-modify-write is fine: the EWMA is a shedding heuristic,
        // not an accounting value.
        let old = self.ewma_us.load(Ordering::Relaxed);
        let next = if old == 0 {
            sample_us
        } else {
            (old * 7 + sample_us) / 8
        };
        self.ewma_us.store(next, Ordering::Relaxed);
        self.lat_us.record(sample_us);
    }

    /// Counter snapshot. Latency quantiles come from the lock-free
    /// histogram over the door's whole life (monotone in q, within the
    /// bucket scheme's ≤ 6.25 % relative error); 0 before the first sample.
    pub fn stats(&self) -> AdmissionStats {
        let lat = self.lat_us.snapshot();
        AdmissionStats {
            admitted: self.admitted.load(Ordering::Relaxed),
            degraded: self.degraded.load(Ordering::Relaxed),
            queue_sheds: self.queue_sheds.load(Ordering::Relaxed),
            deadline_sheds: self.deadline_sheds.load(Ordering::Relaxed),
            tenant_sheds: self.tenant_sheds_n.load(Ordering::Relaxed),
            depth_high_water: self.depth_high_water.load(Ordering::SeqCst),
            p50_ms: lat.quantile(0.5) as f64 / 1e3,
            p99_ms: lat.quantile(0.99) as f64 / 1e3,
            ewma_ms: self.ewma_ms(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stats_json_keys_stay_stable() {
        // Downstream consumers (driver reports, servebench JSON) key on
        // these names; the histogram migration must not rename them, and
        // the tenant-cap addition may only add keys.
        let s = AdmissionStats {
            p50_ms: 1.0,
            p99_ms: 2.0,
            ..Default::default()
        };
        let j = s.to_json().to_string();
        let v = crate::util::json::parse(&j).unwrap();
        for key in [
            "admitted",
            "degraded",
            "queue_sheds",
            "deadline_sheds",
            "tenant_sheds",
            "depth_high_water",
            "latency_p50_ms",
            "latency_p99_ms",
            "ewma_ms",
        ] {
            assert!(v.get(key).is_some(), "missing {key}");
        }
    }

    #[test]
    fn histogram_latency_quantiles_are_monotone_in_ms() {
        // Spread samples across octaves; the ms-converted quantiles must
        // stay ordered and inside [min, max] (the shed ladder's reports and
        // `tests/fault_injection.rs` rely on p99 ≥ p50).
        let h = Histogram::new();
        for us in [120u64, 450, 900, 3_000, 12_000, 90_000] {
            h.record(us);
        }
        let s = h.snapshot();
        let p50 = s.quantile(0.5) as f64 / 1e3;
        let p99 = s.quantile(0.99) as f64 / 1e3;
        assert!(p99 >= p50, "p99 {p99} < p50 {p50}");
        assert!(p50 >= 0.120 && p99 <= 90.0);
    }

    #[test]
    fn admission_config_builders() {
        let cfg = AdmissionConfig::default()
            .queue_limit(8)
            .deadline_ms(2.5)
            .degrade_at(0.5)
            .degraded_rescore(0)
            .tenant_qps(-3.0)
            .tenant_burst(0);
        assert_eq!(cfg.queue_limit, 8);
        assert_eq!(cfg.deadline_ms, 2.5);
        assert_eq!(cfg.degrade_at, 0.5);
        assert_eq!(cfg.degraded_rescore, 1, "rescore width clamps to ≥ 1");
        assert_eq!(cfg.tenant_qps, 0.0, "negative rates clamp to disabled");
        assert_eq!(cfg.tenant_burst, 1, "burst clamps to ≥ 1");
        let d = AdmissionConfig::default();
        assert_eq!(d.tenant_qps, 0.0, "tenant caps default off");
        assert_eq!(d.tenant_burst, 8);
    }

    #[test]
    fn shed_reason_and_results_accessors() {
        let served = Admission::Served(vec![vec![(1, 0.5)]]);
        assert!(!served.is_shed());
        assert_eq!(served.results().unwrap().len(), 1);
        let shed = Admission::Shed(ShedReason::QueueFull);
        assert!(shed.is_shed());
        assert!(shed.clone().results().is_none());
        assert_ne!(ShedReason::QueueFull, ShedReason::Deadline);
        assert_ne!(ShedReason::TenantCap, ShedReason::QueueFull);
        let t = AdmissionStats {
            queue_sheds: 1,
            deadline_sheds: 2,
            tenant_sheds: 4,
            ..Default::default()
        };
        assert_eq!(t.shed(), 7, "shed() totals all three reasons");
    }
}
