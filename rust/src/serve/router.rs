//! Bucket-key → entry-point routing tables.
//!
//! At snapshot-build time every indexed point's bucket key is computed for
//! each routing repetition (the same `(family, rep)` draws the builder
//! bucketed with), and each bucket retains a bounded sample of members as
//! **entry points**. At query time a query's key either hits a bucket —
//! whose entries are, by the LSH property, likely near the query — or
//! misses (empty slice), in which case other repetitions provide the
//! redundancy, exactly as repetitions do for the builder.
//!
//! Entries are stored flat (one `Vec<u32>` per repetition, buckets as
//! ranges) so routing is one hash probe plus a slice borrow — no per-query
//! allocation.

use crate::util::fxhash::FxHashMap;
use crate::util::rng::{derive_seed, Rng};

/// One repetition's routing table.
struct RepRouter {
    /// bucket key -> (start, len) into `entries`.
    table: FxHashMap<u64, (u32, u32)>,
    /// Entry point ids, grouped per bucket.
    entries: Vec<u32>,
}

/// Per-repetition bucket-key → entry-point tables.
pub struct Router {
    reps: Vec<RepRouter>,
}

impl Router {
    /// Build from per-repetition bucket keys of all indexed points
    /// (`keys_per_rep[r][i]` = key of point `i` under routing repetition
    /// `r`). Each bucket keeps at most `route_leaders` members, sampled
    /// deterministically from `seed` — buckets are processed in sorted key
    /// order, so the table is independent of hash-map iteration order.
    pub fn build(keys_per_rep: &[Vec<u64>], route_leaders: usize, seed: u64) -> Router {
        let route_leaders = route_leaders.max(1);
        let reps = keys_per_rep
            .iter()
            .enumerate()
            .map(|(r, keys)| {
                let mut buckets: FxHashMap<u64, Vec<u32>> = FxHashMap::default();
                for (i, &k) in keys.iter().enumerate() {
                    buckets.entry(k).or_default().push(i as u32);
                }
                let mut ordered: Vec<(u64, Vec<u32>)> = buckets.into_iter().collect();
                ordered.sort_unstable_by_key(|(k, _)| *k);
                let mut rng = Rng::new(derive_seed(seed ^ 0x5EAE, r as u64));
                let mut table = FxHashMap::default();
                let mut entries = Vec::new();
                for (key, members) in ordered {
                    let start = entries.len() as u32;
                    if members.len() <= route_leaders {
                        entries.extend_from_slice(&members);
                    } else {
                        // Sample positions, then sort them so the retained
                        // entries keep ascending-id order (sample_indices
                        // returns an unspecified order).
                        let mut picks = rng.sample_indices(members.len(), route_leaders);
                        picks.sort_unstable();
                        entries.extend(picks.into_iter().map(|p| members[p]));
                    }
                    table.insert(key, (start, entries.len() as u32 - start));
                }
                entries.shrink_to_fit();
                RepRouter { table, entries }
            })
            .collect();
        Router { reps }
    }

    /// Number of routing repetitions.
    pub fn reps(&self) -> usize {
        self.reps.len()
    }

    /// Entry points for `key` under routing repetition `rep` (empty slice
    /// on a bucket miss).
    #[inline]
    pub fn route(&self, rep: usize, key: u64) -> &[u32] {
        let r = &self.reps[rep];
        match r.table.get(&key) {
            Some(&(start, len)) => &r.entries[start as usize..(start + len) as usize],
            None => &[],
        }
    }

    /// Total *live* retained entries across all repetitions (memory
    /// telemetry). Counted through the key tables, so entry slots orphaned
    /// by [`Router::extended`]'s bucket rewrites are excluded.
    pub fn num_entries(&self) -> usize {
        self.reps
            .iter()
            .map(|r| r.table.values().map(|&(_, len)| len as usize).sum::<usize>())
            .sum()
    }

    /// Live retained entries whose point id falls in `[lo, hi)`, across
    /// all repetitions — the per-shard slice of [`Router::num_entries`]
    /// for a fence-partitioned snapshot (sharded serving telemetry).
    /// Counted through the key tables, so orphaned slots are excluded.
    pub fn entries_in_range(&self, lo: u32, hi: u32) -> usize {
        self.reps
            .iter()
            .map(|r| {
                r.table
                    .values()
                    .map(|&(start, len)| {
                        r.entries[start as usize..(start + len) as usize]
                            .iter()
                            .filter(|&&e| e >= lo && e < hi)
                            .count()
                    })
                    .sum::<usize>()
            })
            .sum()
    }

    /// Estimated heap bytes of the routing tables: flat entry arrays plus
    /// the key tables (key + range + map-slot overhead per bucket).
    pub fn heap_bytes(&self) -> usize {
        self.reps
            .iter()
            .map(|r| r.entries.len() * 4 + r.table.len() * 24)
            .sum()
    }

    /// Export every repetition's table as `(sorted (key, start, len)
    /// triples, flat entries)` — snapshot persistence. Triples are emitted
    /// in ascending key order so the byte stream (and its checksum) is
    /// independent of hash-map iteration order.
    pub(crate) fn export_parts(&self) -> Vec<(Vec<(u64, u32, u32)>, Vec<u32>)> {
        self.reps
            .iter()
            .map(|r| {
                let mut triples: Vec<(u64, u32, u32)> = r
                    .table
                    .iter()
                    .map(|(&k, &(start, len))| (k, start, len))
                    .collect();
                triples.sort_unstable_by_key(|&(k, _, _)| k);
                (triples, r.entries.clone())
            })
            .collect()
    }

    /// Reassemble from [`Router::export_parts`] output (snapshot
    /// persistence). Bucket ranges are bounds-checked against the flat
    /// entry array so a corrupted file fails here, not as a slice panic on
    /// some later query. This reproduces the *exact* table — including the
    /// prefix-biased layout [`Router::extended`] leaves behind, which a
    /// fresh [`Router::build`] over the same keys would not.
    pub(crate) fn from_parts(parts: Vec<(Vec<(u64, u32, u32)>, Vec<u32>)>) -> Router {
        let reps = parts
            .into_iter()
            .map(|(triples, entries)| {
                let mut table = FxHashMap::default();
                for (key, start, len) in triples {
                    assert!(
                        start as usize + len as usize <= entries.len(),
                        "router bucket range out of bounds"
                    );
                    assert!(table.insert(key, (start, len)).is_none(), "duplicate router key");
                }
                RepRouter { table, entries }
            })
            .collect();
        Router { reps }
    }

    /// A new router with `delta_keys_per_rep[r][i]` (the bucket keys of
    /// delta point `base + i` under repetition `r`) folded in — the
    /// incremental-compaction analogue of [`Router::build`] whose cost is
    /// proportional to the snapshot tables' size (one clone) plus the
    /// delta, never to a re-sketch of the corpus.
    ///
    /// Delta members append to their buckets until `route_leaders` is
    /// reached (snapshot entries are never displaced — so when a bucket is
    /// already full the delta rides on the existing entries, a
    /// prefix-biased cap rather than [`Router::build`]'s uniform sample);
    /// keys never seen by the snapshot get fresh buckets. Entry lists stay
    /// ascending by id because delta ids all exceed snapshot ids. Buckets
    /// are rewritten at the tail of the flat entry array; the orphaned
    /// slots are compacted away once they outnumber live entries, so
    /// repeated compactions cannot leak unboundedly.
    pub fn extended(
        &self,
        delta_keys_per_rep: &[Vec<u64>],
        base: u32,
        route_leaders: usize,
    ) -> Router {
        assert_eq!(
            delta_keys_per_rep.len(),
            self.reps.len(),
            "delta key repetitions != router repetitions"
        );
        let route_leaders = route_leaders.max(1);
        let reps = self
            .reps
            .iter()
            .zip(delta_keys_per_rep.iter())
            .map(|(old, keys)| {
                // Group delta members per bucket key, ids ascending, and
                // process groups in sorted key order (deterministic — no
                // dependence on hash-map iteration).
                let mut groups: FxHashMap<u64, Vec<u32>> = FxHashMap::default();
                for (i, &k) in keys.iter().enumerate() {
                    groups.entry(k).or_default().push(base + i as u32);
                }
                let mut ordered: Vec<(u64, Vec<u32>)> = groups.into_iter().collect();
                ordered.sort_unstable_by_key(|(k, _)| *k);

                let mut table = old.table.clone();
                let mut entries = old.entries.clone();
                for (key, members) in ordered {
                    let (start, len) = table.get(&key).copied().unwrap_or((0, 0));
                    let kept = &old.entries[start as usize..(start + len) as usize];
                    if kept.len() >= route_leaders {
                        continue;
                    }
                    let new_start = entries.len() as u32;
                    entries.extend_from_slice(kept);
                    let room = route_leaders - kept.len();
                    entries.extend(members.iter().take(room));
                    table.insert(key, (new_start, entries.len() as u32 - new_start));
                }
                let live: usize = table.values().map(|&(_, len)| len as usize).sum();
                if entries.len() > 2 * live {
                    // Compact orphaned slots: repack live ranges in sorted
                    // key order (same deterministic layout Router::build
                    // produces).
                    let mut sorted_keys: Vec<u64> = table.keys().copied().collect();
                    sorted_keys.sort_unstable();
                    let mut packed = Vec::with_capacity(live);
                    for k in sorted_keys {
                        let (s, l) = table[&k];
                        let ns = packed.len() as u32;
                        packed.extend_from_slice(&entries[s as usize..(s + l) as usize]);
                        table.insert(k, (ns, l));
                    }
                    entries = packed;
                }
                entries.shrink_to_fit();
                RepRouter { table, entries }
            })
            .collect();
        Router { reps }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn routes_every_indexed_key_and_misses_unknown() {
        let keys = vec![vec![7u64, 3, 7, 3, 9, 7]];
        let router = Router::build(&keys, 8, 1);
        assert_eq!(router.reps(), 1);
        let mut b7 = router.route(0, 7).to_vec();
        b7.sort_unstable();
        assert_eq!(b7, vec![0, 2, 5]);
        assert_eq!(router.route(0, 9), &[4]);
        assert!(router.route(0, 1234).is_empty());
    }

    #[test]
    fn bucket_entries_are_capped_and_deterministic() {
        let keys = vec![vec![5u64; 100]];
        let a = Router::build(&keys, 3, 42);
        let b = Router::build(&keys, 3, 42);
        assert_eq!(a.route(0, 5), b.route(0, 5));
        assert_eq!(a.route(0, 5).len(), 3);
        assert_eq!(a.num_entries(), 3);
        // Entries are valid member ids in ascending order.
        let e = a.route(0, 5);
        assert!(e.windows(2).all(|w| w[0] < w[1]));
        assert!(e.iter().all(|&i| i < 100));
        // A different seed may pick different entries.
        let c = Router::build(&keys, 3, 43);
        assert_eq!(c.route(0, 5).len(), 3);
    }

    #[test]
    fn extended_appends_delta_members_and_creates_new_buckets() {
        let keys = vec![vec![7u64, 3, 7]]; // snapshot points 0..3
        let router = Router::build(&keys, 8, 1);
        let ext = router.extended(&[vec![7u64, 11]], 3, 8); // delta points 3, 4
        assert_eq!(ext.route(0, 7), &[0, 2, 3]);
        assert_eq!(ext.route(0, 3), &[1]);
        assert_eq!(ext.route(0, 11), &[4]);
        assert!(ext.route(0, 999).is_empty());
        assert_eq!(ext.num_entries(), 5);
        // The source router is untouched (epoch semantics).
        assert_eq!(router.route(0, 7), &[0, 2]);
        assert!(router.route(0, 11).is_empty());
    }

    #[test]
    fn extended_respects_the_entry_cap() {
        let keys = vec![vec![5u64, 5]];
        let router = Router::build(&keys, 3, 0);
        // One slot of room: only the first delta member gets in.
        let ext = router.extended(&[vec![5, 5, 5]], 2, 3);
        assert_eq!(ext.route(0, 5), &[0, 1, 2]);
        assert_eq!(ext.num_entries(), 3);
        // A full bucket keeps its snapshot entries unchanged.
        let ext2 = ext.extended(&[vec![5]], 5, 3);
        assert_eq!(ext2.route(0, 5), &[0, 1, 2]);
    }

    #[test]
    fn repeated_extension_compacts_orphaned_slots() {
        let keys = vec![vec![1u64, 1]];
        let mut router = Router::build(&keys, 64, 0);
        for step in 0..10u32 {
            router = router.extended(&[vec![1]], 2 + step, 64);
        }
        let bucket: Vec<u32> = router.route(0, 1).to_vec();
        assert_eq!(bucket, (0..12).collect::<Vec<u32>>());
        assert_eq!(router.num_entries(), 12);
        // Orphaned slots are bounded: flat storage never exceeds 2x live.
        assert!(
            router.heap_bytes() <= 2 * 12 * 4 + 24,
            "leaked entry slots: {} bytes",
            router.heap_bytes()
        );
    }

    #[test]
    fn export_import_roundtrips_the_extended_layout() {
        // `extended` leaves a prefix-biased, possibly orphan-compacted
        // layout that `Router::build` over the same keys would NOT
        // reproduce — persistence must roundtrip the raw parts instead.
        let keys = vec![vec![7u64, 3, 7], vec![1u64, 1, 2]];
        let mut router = Router::build(&keys, 3, 9);
        for step in 0..6u32 {
            router = router.extended(&[vec![7], vec![1]], 3 + step, 3);
        }
        let back = Router::from_parts(router.export_parts());
        assert_eq!(back.reps(), router.reps());
        assert_eq!(back.num_entries(), router.num_entries());
        for (rep, keyset) in [(0usize, vec![3u64, 7, 999]), (1, vec![1, 2, 999])] {
            for k in keyset {
                assert_eq!(back.route(rep, k), router.route(rep, k), "rep {rep} key {k}");
            }
        }
    }

    #[test]
    #[should_panic(expected = "router bucket range")]
    fn from_parts_rejects_out_of_bounds_ranges() {
        Router::from_parts(vec![(vec![(5u64, 0u32, 3u32)], vec![1, 2])]);
    }

    #[test]
    fn multiple_reps_route_independently() {
        let keys = vec![vec![1u64, 1, 2], vec![9u64, 8, 9]];
        let router = Router::build(&keys, 4, 0);
        assert_eq!(router.reps(), 2);
        assert_eq!(router.route(0, 1), &[0, 1]);
        assert_eq!(router.route(1, 9), &[0, 2]);
        assert!(router.route(1, 1).is_empty());
    }
}
