//! The per-insert write-ahead log.
//!
//! Frame layout (little endian), one frame per insert:
//! ```text
//! len u32 | crc u32 | payload[len]
//! payload: gid u32 | flags u8 (bit0 dense row, bit1 token set) |
//!          [row: dim u32, f32 * dim] |
//!          [set: ntok u32, tokens u32 * ntok, weights f32 * ntok]
//! ```
//! `crc` is CRC-32 (reflected, polynomial 0xEDB8_8320) over the payload.
//! The reader's contract is the recovery lemma the whole durable layer
//! rests on: [`read_wal`] returns a **strict prefix** of the records that
//! were appended, or an error naming the offending record — never a
//! panic, never altered data. A prefix is indistinguishable from a crash
//! that happened at that frame boundary, so replaying it is always a
//! legitimate recovery; a checksum mismatch on a *complete* frame is real
//! corruption and must stop recovery loudly.
//!
//! Torn tails — a crash mid-`write(2)` leaving a partial frame — are
//! detected structurally (fewer bytes remain than the frame header or its
//! declared payload needs at end-of-file) and truncated at the last valid
//! record. Writers never append to a previously-torn file: the store
//! rotates to a fresh `wal-{high}.log` on every recovery and checkpoint,
//! so read-side truncation is sufficient.

use crate::data::types::WeightedSet;
use anyhow::{bail, Context, Result};
use std::io::Write;
use std::path::{Path, PathBuf};

/// Largest payload the reader will accept (guards a corrupted length
/// field from driving a multi-gigabyte allocation).
pub const MAX_RECORD: usize = 1 << 28;

const fn build_crc_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

static CRC_TABLE: [u32; 256] = build_crc_table();

/// CRC-32 (reflected, poly 0xEDB8_8320 — the zlib/PNG polynomial) of
/// `bytes`. Shared by the WAL frames and the snapshot sections.
pub fn crc32(bytes: &[u8]) -> u32 {
    let mut c = 0xFFFF_FFFFu32;
    for &b in bytes {
        c = CRC_TABLE[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    c ^ 0xFFFF_FFFF
}

/// When appended WAL frames reach the disk.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FsyncPolicy {
    /// `fdatasync` after every append — survives power loss, slowest.
    Always,
    /// `fdatasync` every `n` appends — bounded-loss middle ground.
    EveryN(u32),
    /// Leave flushing to the OS page cache — survives process death (the
    /// kernel holds the bytes), not power loss. The default.
    Os,
}

impl FsyncPolicy {
    /// Parse `always` | `os` | `every:N` (the `--fsync` flag grammar).
    pub fn parse(spec: &str) -> Result<FsyncPolicy, String> {
        match spec {
            "always" => Ok(FsyncPolicy::Always),
            "os" => Ok(FsyncPolicy::Os),
            _ => match spec.strip_prefix("every:") {
                Some(n) => n
                    .parse::<u32>()
                    .ok()
                    .filter(|&n| n > 0)
                    .map(FsyncPolicy::EveryN)
                    .ok_or_else(|| format!("bad fsync interval {n:?} (want a positive integer)")),
                None => Err(format!("bad fsync policy {spec:?} (want always | os | every:N)")),
            },
        }
    }
}

/// One logged insert: the global id the sequencer assigned plus the
/// point's features, exactly as they were handed to `insert`.
#[derive(Clone, Debug, PartialEq)]
pub struct WalRecord {
    /// Global point id (sequencer position) of this insert.
    pub gid: u32,
    /// Dense row, when the indexed dataset has one.
    pub row: Option<Vec<f32>>,
    /// Token set, when the indexed dataset has one.
    pub set: Option<WeightedSet>,
}

impl WalRecord {
    fn encode_payload(&self, out: &mut Vec<u8>) {
        out.extend_from_slice(&self.gid.to_le_bytes());
        let flags = self.row.is_some() as u8 | (self.set.is_some() as u8) << 1;
        out.push(flags);
        if let Some(row) = &self.row {
            out.extend_from_slice(&(row.len() as u32).to_le_bytes());
            for &x in row {
                out.extend_from_slice(&x.to_le_bytes());
            }
        }
        if let Some(set) = &self.set {
            out.extend_from_slice(&(set.tokens.len() as u32).to_le_bytes());
            for &t in &set.tokens {
                out.extend_from_slice(&t.to_le_bytes());
            }
            for &w in &set.weights {
                out.extend_from_slice(&w.to_le_bytes());
            }
        }
    }

    /// The full frame (header + payload) this record appends.
    fn encode_frame(&self) -> Vec<u8> {
        let mut payload = Vec::new();
        self.encode_payload(&mut payload);
        let mut frame = Vec::with_capacity(8 + payload.len());
        frame.extend_from_slice(&(payload.len() as u32).to_le_bytes());
        frame.extend_from_slice(&crc32(&payload).to_le_bytes());
        frame.extend_from_slice(&payload);
        frame
    }

    fn decode_payload(payload: &[u8], record: usize) -> Result<WalRecord> {
        let mut c = Cursor { buf: payload, at: 0, record };
        let gid = c.u32()?;
        let flags = c.u8()?;
        if flags & !0b11 != 0 {
            bail!("WAL record {record}: unknown flag bits {flags:#04x}");
        }
        let row = if flags & 1 != 0 {
            let dim = c.u32()? as usize;
            Some(c.f32s(dim)?)
        } else {
            None
        };
        let set = if flags & 2 != 0 {
            let ntok = c.u32()? as usize;
            let tokens = c.u32s(ntok)?;
            let weights = c.f32s(ntok)?;
            Some(WeightedSet { tokens, weights })
        } else {
            None
        };
        if c.at != payload.len() {
            bail!(
                "WAL record {record}: {} trailing payload bytes",
                payload.len() - c.at
            );
        }
        Ok(WalRecord { gid, row, set })
    }
}

/// Bounds-checked little-endian payload reader (decode never panics on a
/// short buffer — it reports the record).
struct Cursor<'a> {
    buf: &'a [u8],
    at: usize,
    record: usize,
}

impl Cursor<'_> {
    fn take(&mut self, n: usize) -> Result<&[u8]> {
        if self.at + n > self.buf.len() {
            bail!(
                "WAL record {}: payload truncated ({} bytes needed at offset {}, {} present)",
                self.record,
                n,
                self.at,
                self.buf.len()
            );
        }
        let s = &self.buf[self.at..self.at + n];
        self.at += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u32s(&mut self, n: usize) -> Result<Vec<u32>> {
        if n > MAX_RECORD / 4 {
            bail!("WAL record {}: absurd element count {n}", self.record);
        }
        Ok(self
            .take(n * 4)?
            .chunks_exact(4)
            .map(|c| u32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    fn f32s(&mut self, n: usize) -> Result<Vec<f32>> {
        if n > MAX_RECORD / 4 {
            bail!("WAL record {}: absurd element count {n}", self.record);
        }
        Ok(self
            .take(n * 4)?
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }
}

/// Appends framed records to one WAL file under an [`FsyncPolicy`].
pub struct WalWriter {
    file: std::fs::File,
    path: PathBuf,
    policy: FsyncPolicy,
    since_sync: u32,
    appends: crate::obs::Counter,
    fsyncs: crate::obs::Counter,
    bytes: crate::obs::Counter,
}

impl WalWriter {
    /// Create (truncating) the WAL file at `path`. Writers always start
    /// fresh files — the store rotates on recovery and checkpoint — so
    /// truncation can only discard a torn tail that recovery already
    /// declined to replay.
    pub fn create(path: &Path, policy: FsyncPolicy) -> Result<WalWriter> {
        let file = std::fs::File::create(path)
            .with_context(|| format!("creating WAL {}", path.display()))?;
        let reg = crate::obs::registry();
        Ok(WalWriter {
            file,
            path: path.to_path_buf(),
            policy,
            since_sync: 0,
            appends: reg.counter("stars_serve_wal_appends_total"),
            fsyncs: reg.counter("stars_serve_wal_fsyncs_total"),
            bytes: reg.counter("stars_serve_wal_bytes_total"),
        })
    }

    /// The file this writer appends to.
    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Record that the file was renamed (atomic rotation publishes the
    /// WAL via tmp + rename; the open handle follows the inode, only the
    /// diagnostic path changes).
    pub(crate) fn set_path(&mut self, path: PathBuf) {
        self.path = path;
    }

    /// Append one record and apply the fsync policy.
    pub fn append(&mut self, rec: &WalRecord) -> Result<()> {
        let frame = rec.encode_frame();
        self.file
            .write_all(&frame)
            .with_context(|| format!("appending to WAL {}", self.path.display()))?;
        self.appends.inc(1);
        self.bytes.inc(frame.len() as u64);
        self.since_sync += 1;
        let due = match self.policy {
            FsyncPolicy::Always => true,
            FsyncPolicy::EveryN(n) => self.since_sync >= n,
            FsyncPolicy::Os => false,
        };
        if due {
            self.sync()?;
        }
        Ok(())
    }

    /// Force the file to disk regardless of policy (checkpoint barrier).
    pub fn sync(&mut self) -> Result<()> {
        self.file
            .sync_data()
            .with_context(|| format!("fsyncing WAL {}", self.path.display()))?;
        self.fsyncs.inc(1);
        self.since_sync = 0;
        Ok(())
    }

    /// Crash simulation: append only the first `keep` bytes of the frame
    /// `rec` would produce — a torn tail exactly as a mid-`write` power cut
    /// would leave it — and flush so the bytes are observable by a reader.
    pub fn append_torn(&mut self, rec: &WalRecord, keep: usize) -> Result<usize> {
        let frame = rec.encode_frame();
        let keep = keep.min(frame.len().saturating_sub(1));
        self.file
            .write_all(&frame[..keep])
            .with_context(|| format!("torn append to WAL {}", self.path.display()))?;
        self.file.sync_data().ok();
        Ok(keep)
    }
}

/// Read every complete record of the WAL at `path`.
///
/// Returns the records plus the number of torn trailing bytes that were
/// truncated (0 for a cleanly closed file). See the module docs for the
/// prefix-or-error contract.
pub fn read_wal(path: &Path) -> Result<(Vec<WalRecord>, usize)> {
    let bytes = std::fs::read(path)
        .with_context(|| format!("reading WAL {}", path.display()))?;
    let mut records = Vec::new();
    let mut at = 0usize;
    while at < bytes.len() {
        let rem = bytes.len() - at;
        if rem < 8 {
            // A frame header needs 8 bytes; fewer at EOF is a torn tail.
            return Ok((records, rem));
        }
        let len = u32::from_le_bytes(bytes[at..at + 4].try_into().unwrap()) as usize;
        if len > MAX_RECORD {
            bail!(
                "WAL {} record {}: length field {len} exceeds the {MAX_RECORD}-byte cap — \
                 corrupt frame header",
                path.display(),
                records.len()
            );
        }
        if rem < 8 + len {
            // Header complete, payload cut off at EOF: torn tail.
            return Ok((records, rem));
        }
        let want = u32::from_le_bytes(bytes[at + 4..at + 8].try_into().unwrap());
        let payload = &bytes[at + 8..at + 8 + len];
        let got = crc32(payload);
        if got != want {
            bail!(
                "WAL {} record {}: checksum mismatch ({got:#010x} != {want:#010x}) — \
                 corrupt payload",
                path.display(),
                records.len()
            );
        }
        records.push(WalRecord::decode_payload(payload, records.len())?);
        at += 8 + len;
    }
    Ok((records, 0))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp(name: &str) -> PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("stars_wal_test_{name}_{}", std::process::id()));
        p
    }

    fn sample_records() -> Vec<WalRecord> {
        vec![
            WalRecord { gid: 100, row: Some(vec![1.0, -2.5, 0.0]), set: None },
            WalRecord {
                gid: 101,
                row: None,
                set: Some(WeightedSet { tokens: vec![3, 9], weights: vec![0.5, 1.5] }),
            },
            WalRecord {
                gid: 102,
                row: Some(vec![f32::MIN_POSITIVE, 7.25]),
                set: Some(WeightedSet { tokens: vec![1], weights: vec![2.0] }),
            },
        ]
    }

    #[test]
    fn crc32_matches_known_vectors() {
        // Reference values of the zlib/PNG CRC-32.
        assert_eq!(crc32(b""), 0);
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b"The quick brown fox jumps over the lazy dog"), 0x414F_A339);
    }

    #[test]
    fn fsync_policy_grammar() {
        assert_eq!(FsyncPolicy::parse("always").unwrap(), FsyncPolicy::Always);
        assert_eq!(FsyncPolicy::parse("os").unwrap(), FsyncPolicy::Os);
        assert_eq!(FsyncPolicy::parse("every:16").unwrap(), FsyncPolicy::EveryN(16));
        assert!(FsyncPolicy::parse("every:0").is_err());
        assert!(FsyncPolicy::parse("every:x").is_err());
        assert!(FsyncPolicy::parse("sometimes").is_err());
    }

    #[test]
    fn append_read_roundtrip_bit_exact() {
        let p = tmp("roundtrip");
        let mut w = WalWriter::create(&p, FsyncPolicy::EveryN(2)).unwrap();
        for r in &sample_records() {
            w.append(r).unwrap();
        }
        drop(w);
        let (back, torn) = read_wal(&p).unwrap();
        std::fs::remove_file(&p).ok();
        assert_eq!(torn, 0);
        assert_eq!(back, sample_records());
        // f32 payloads roundtrip by bits, not by value.
        assert_eq!(back[2].row.as_ref().unwrap()[0].to_bits(), f32::MIN_POSITIVE.to_bits());
    }

    #[test]
    fn torn_tail_truncates_at_every_cut_point() {
        let records = sample_records();
        let frame_len = records[2].encode_frame().len();
        for keep in 0..frame_len {
            let p = tmp(&format!("torn_{keep}"));
            let mut w = WalWriter::create(&p, FsyncPolicy::Os).unwrap();
            w.append(&records[0]).unwrap();
            w.append(&records[1]).unwrap();
            w.append_torn(&records[2], keep).unwrap();
            drop(w);
            let (back, torn) = read_wal(&p).unwrap();
            std::fs::remove_file(&p).ok();
            assert_eq!(back, records[..2], "keep={keep}");
            assert_eq!(torn, keep.min(frame_len - 1), "keep={keep}");
        }
    }

    #[test]
    fn complete_frame_corruption_is_an_error_never_a_misload() {
        // Flip each byte of a complete two-record WAL in turn: the reader
        // must return a strict prefix of the written records or error —
        // never panic, never a record that differs from what was appended.
        let p = tmp("flip");
        let mut w = WalWriter::create(&p, FsyncPolicy::Os).unwrap();
        let records = sample_records();
        w.append(&records[0]).unwrap();
        w.append(&records[1]).unwrap();
        drop(w);
        let clean = std::fs::read(&p).unwrap();
        for i in 0..clean.len() {
            let mut bytes = clean.clone();
            bytes[i] ^= 0x40;
            std::fs::write(&p, &bytes).unwrap();
            match read_wal(&p) {
                Ok((got, _)) => {
                    assert!(got.len() <= 2, "flip at {i}: extra records");
                    for (j, r) in got.iter().enumerate() {
                        assert_eq!(r, &records[j], "flip at {i}: record {j} misloaded");
                    }
                }
                Err(e) => {
                    let msg = format!("{e:#}");
                    assert!(msg.contains("record"), "flip at {i}: undiagnosed error: {msg}");
                }
            }
        }
        std::fs::remove_file(&p).ok();
    }
}
