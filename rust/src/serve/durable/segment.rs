//! Sealed immutable delta segments — the middle tier of the LSM-shaped
//! write path (WAL → active [`crate::serve::DeltaBuffer`] tail → sealed
//! segments → snapshot compaction).
//!
//! When the active tail reaches `ServeConfig::seal_limit`, its rows are
//! taken out whole ([`crate::serve::DeltaBuffer::seal_take`]) and sketched
//! **once** through the snapshot's cached per-repetition `SketchState`s
//! into per-rep bucket tables. Queries then *route into* a segment with
//! the same bucket keys they route into the snapshot with, visiting the
//! query's collision buckets first, instead of treating every sealed row
//! as an unordered brute-force tile.
//!
//! **Exactness.** [`SealedSegment::candidates_into`] emits *complete*
//! coverage: the probed buckets first, then every remaining row in
//! ascending order, each row exactly once. Because the engine's top-k
//! selection (`TopNeighbors`) imposes a strict total order on (score, id)
//! that is independent of push order, scoring a permutation of the same
//! candidate set yields bit-identical answers — so sealed-segment serving
//! is exactly equivalent to the brute-forced `DeltaBuffer` path (gated in
//! `tests/durability.rs`), and seal timing can never change an answer.
//! The bucket structure's payoff today is the write path — the engine's
//! per-query capture clones only the O(active-tail) buffer while sealed
//! rows ride behind `Arc`s, and their sketch/quant work is paid once at
//! seal time — and it is the landing zone for bounded-probe segment
//! serving (stop after the collision buckets, a recall-vs-latency trade
//! documented as future work in ARCHITECTURE.md).
//!
//! Segments are **never persisted**: recovery re-derives them by
//! replaying the WAL suffix through the normal insert path, which may
//! re-seal at different boundaries — harmless, because exactness makes
//! answers independent of seal boundaries.

use crate::data::types::Dataset;
use crate::graph::two_hop::VisitScratch;
use crate::lsh::{sketch, SketchState};
use crate::sim::QuantDataset;
use crate::util::fxhash::FxHashMap;
use std::sync::Arc;

/// An immutable, sketched batch of sealed delta rows. Row `i` of the
/// segment is global point `base() + i`.
pub struct SealedSegment {
    ds: Dataset,
    quant: Option<QuantDataset>,
    base: usize,
    /// Per routing repetition: bucket key → segment-local rows (ascending).
    buckets: Vec<FxHashMap<u64, Vec<u32>>>,
}

impl SealedSegment {
    /// Sketch `ds` (rows `base..base + ds.len()` of the global id space)
    /// through the snapshot's cached per-repetition `states` into a sealed
    /// segment. `quant`, when present, is the rows' SQ8 table in lockstep
    /// with `ds` (handed over from the delta buffer's own table).
    pub fn seal<'f>(
        states: &[Arc<dyn SketchState + 'f>],
        ds: Dataset,
        quant: Option<QuantDataset>,
        base: usize,
        workers: usize,
    ) -> SealedSegment {
        if let Some(q) = &quant {
            assert_eq!(q.len(), ds.len(), "seal quant table out of lockstep");
        }
        let n = ds.len();
        let buckets = states
            .iter()
            .map(|state| {
                let keys = sketch::state_keys_range_par(state.as_ref(), &ds, 0, n, workers);
                let mut table: FxHashMap<u64, Vec<u32>> = FxHashMap::default();
                for (i, &k) in keys.iter().enumerate() {
                    table.entry(k).or_default().push(i as u32);
                }
                table
            })
            .collect();
        SealedSegment {
            ds,
            quant,
            base,
            buckets,
        }
    }

    /// Number of sealed rows.
    pub fn len(&self) -> usize {
        self.ds.len()
    }

    /// True when the segment holds no rows (never constructed by the
    /// engine, which only seals non-empty tails).
    pub fn is_empty(&self) -> bool {
        self.ds.is_empty()
    }

    /// Global id of row 0.
    pub fn base(&self) -> usize {
        self.base
    }

    /// The sealed rows.
    pub fn dataset(&self) -> &Dataset {
        &self.ds
    }

    /// SQ8 codes of the sealed rows, row-for-row with [`Self::dataset`].
    pub fn quant(&self) -> Option<&QuantDataset> {
        self.quant.as_ref()
    }

    /// Routing repetitions the segment was sketched under.
    pub fn reps(&self) -> usize {
        self.buckets.len()
    }

    /// Segment-local candidate rows for query `qi`, collision buckets
    /// first: for each repetition `r`, the bucket at `keys[r * nq + qi]`
    /// (the same rep-major key layout `StarIndex::query_keys` produces, so
    /// a query routes into a segment with exactly the keys it routes into
    /// the snapshot with), then every not-yet-visited row ascending.
    /// Complete coverage — each of the segment's rows appears exactly once
    /// — which is what makes sealed serving bit-identical to brute force
    /// (module docs).
    pub fn candidates_into(
        &self,
        keys: &[u64],
        nq: usize,
        qi: usize,
        visit: &mut VisitScratch,
        out: &mut Vec<u32>,
    ) {
        let n = self.ds.len();
        visit.begin(n);
        for (rep, table) in self.buckets.iter().enumerate() {
            if let Some(members) = table.get(&keys[rep * nq + qi]) {
                for &i in members {
                    if visit.mark(i) {
                        out.push(i);
                    }
                }
            }
        }
        for i in 0..n as u32 {
            if visit.mark(i) {
                out.push(i);
            }
        }
    }

    /// Heap bytes of the sealed rows, quant table and bucket tables
    /// (serving memory telemetry).
    pub fn heap_bytes(&self) -> usize {
        self.ds.dense.len() * 4
            + self.ds.norms.len() * 4
            + self
                .ds
                .sets
                .iter()
                .map(|s| s.tokens.len() * 4 + s.weights.len() * 4)
                .sum::<usize>()
            + self.quant.as_ref().map_or(0, |q| q.heap_bytes())
            + self
                .buckets
                .iter()
                .map(|t| t.len() * 24 + t.values().map(|v| v.len() * 4).sum::<usize>())
                .sum::<usize>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::lsh::{LshFamily, SimHash};

    fn fixture() -> (Dataset, Vec<Arc<dyn SketchState + 'static>>) {
        let ds = synth::gaussian_mixture(60, 8, 4, 0.15, 21);
        // States normally borrow their family; the fixture leaks one per
        // rep so the states are 'static without a self-referential struct.
        let states: Vec<Arc<dyn SketchState>> = (0..3u64)
            .map(|rep| {
                let fam: &'static SimHash = Box::leak(Box::new(SimHash::new(8, 6, 99)));
                Arc::from(fam.prepare(&ds, rep))
            })
            .collect();
        (ds, states)
    }

    #[test]
    fn seal_buckets_match_state_keys() {
        let (ds, states) = fixture();
        let quant = QuantDataset::from_dataset(&ds);
        let seg = SealedSegment::seal(&states, ds.clone(), Some(quant), 500, 2);
        assert_eq!(seg.len(), 60);
        assert_eq!(seg.base(), 500);
        assert_eq!(seg.reps(), 3);
        // Every row lands in exactly the bucket its state key names.
        for (rep, state) in states.iter().enumerate() {
            let keys = sketch::state_keys_range_par(state.as_ref(), &ds, 0, 60, 1);
            for (i, &k) in keys.iter().enumerate() {
                assert!(
                    seg.buckets[rep][&k].contains(&(i as u32)),
                    "rep {rep} row {i} missing from its bucket"
                );
            }
        }
    }

    #[test]
    fn candidates_are_a_complete_permutation() {
        let (ds, states) = fixture();
        let seg = SealedSegment::seal(&states, ds.clone(), None, 0, 1);
        // Query keys: sketch the first 5 rows as "queries" (rep-major).
        let nq = 5;
        let mut keys = vec![0u64; 3 * nq];
        for (rep, state) in states.iter().enumerate() {
            let qk = sketch::state_keys_range_par(state.as_ref(), &ds, 0, nq, 1);
            keys[rep * nq..(rep + 1) * nq].copy_from_slice(&qk);
        }
        let mut visit = VisitScratch::new(0);
        for qi in 0..nq {
            let mut out = Vec::new();
            seg.candidates_into(&keys, nq, qi, &mut visit, &mut out);
            // Complete coverage, each row exactly once.
            let mut sorted = out.clone();
            sorted.sort_unstable();
            assert_eq!(sorted, (0..60u32).collect::<Vec<_>>(), "query {qi}");
            // The query's own collision bucket (rep 0) leads the list.
            let bucket = &seg.buckets[0][&keys[qi]];
            assert_eq!(&out[..bucket.len()], &bucket[..], "query {qi} probe order");
        }
    }
}
