//! `stars::serve::durable` — the durable serve layer: WAL'd write path,
//! sealed immutable delta segments, crash-consistent snapshot persistence
//! (ROADMAP "Tiered LSM-style write path + snapshot persistence").
//!
//! Three pieces, one recovery contract:
//!
//! * [`wal`] — per-insert write-ahead logging with length + CRC-32
//!   framing, an `Always | EveryN | Os` fsync policy, and torn-tail
//!   detection that truncates at the last valid record. The reader
//!   returns a strict prefix of what was appended, or errors — never a
//!   panic, never altered data.
//! * [`segment`] — when the active delta tail hits
//!   `ServeConfig::seal_limit`, its rows are sketched once through the
//!   snapshot's cached `SketchState`s into an immutable
//!   [`SealedSegment`] that queries route into. Complete candidate
//!   coverage keeps sealed serving bit-identical to the brute-forced
//!   `DeltaBuffer` path, so seal timing never changes an answer.
//! * [`store`] — `snapshot-{N}.sss` section files (versioned header,
//!   per-section CRCs, atomic tmp + rename publish) covering dataset +
//!   CSR + router tables + quant codes + sequencer high-water, plus the
//!   checkpoint/rotate/recover protocol over `wal-{B}.log` segments.
//!
//! **Recovery contract** (gated by `tests/durability.rs` and the
//! `scripts/ci.sh` kill-and-restart gate): after a crash at *any* WAL
//! record boundary, inside a torn WAL append, or at any snapshot-publish
//! boundary, `stars serve --state-dir D` cold-starts from the newest
//! valid snapshot plus WAL-suffix replay and answers every query top-k
//! **bit-identical** to a process that never crashed — for the exact and
//! quantized tiers, any worker count, and the sharded engine.
//! Conditions: the same serving configuration and feature flags across
//! restarts (states are re-derived from the family, so the family seed
//! must match), and the single-writer discipline the serve loop already
//! has (one insert sequencer; WAL append strictly before engine apply).

pub mod segment;
pub mod store;
pub mod wal;

pub use segment::SealedSegment;
pub use store::{
    load_snapshot, save_snapshot, snapshot_files, snapshot_path, wal_files, wal_path,
    DurableStore, Recovered,
};
pub use wal::{crc32, read_wal, FsyncPolicy, WalRecord, WalWriter, MAX_RECORD};
