//! Crash-consistent snapshot persistence + the `--state-dir` store.
//!
//! A state directory holds two kinds of files:
//!
//! * `snapshot-{N}.sss` — a full serving snapshot covering global points
//!   `0..N` (`N` = the WAL-replay floor: every gid below it is inside the
//!   file, every gid at or above it must come from WAL replay). Section
//!   format in the `data/io.rs` tradition: versioned magic header, then
//!   tagged sections each carrying its own length and CRC-32, published
//!   atomically via tmp + rename (the `obs::write_snapshot` idiom) so a
//!   crash mid-save can never leave a torn `.sss` behind.
//! * `wal-{B}.log` — an append-only [`super::wal`] segment whose records
//!   all have `gid ≥ B`. Rotated on every checkpoint and recovery;
//!   records still pending (logged but not yet inside a snapshot) are
//!   re-logged into the fresh file, so duplicates across files are
//!   expected and replay's `gid < next` skip rule absorbs them.
//!
//! **Recovery** (`DurableStore::recover`): load the newest `.sss` that
//! validates — falling back to older ones, since a crash can land between
//! publishing a snapshot and pruning its predecessors — then replay every
//! WAL file in base order: skip `gid < next`, apply `gid == next`, and
//! treat `gid > next` as a hard "WAL gap" error (a missing file or
//! misordered record must never silently misnumber the sequencer). The
//! sketch states and sealed segments are **re-derived**, never persisted:
//! states are pure functions of `(family, rep)` (the state-purity
//! contract), and segment boundaries cannot change answers (see
//! [`super::segment`]).
//!
//! What is persisted: dataset rows (+ labels/sets), the CSR adjacency,
//! the router's raw tables (the *extended* layout incremental compaction
//! left, which a fresh `Router::build` would not reproduce), the SQ8
//! codes when the snapshot is quantized, and the sequencer high-water.

use super::wal::{crc32, read_wal, FsyncPolicy, WalRecord, WalWriter};
use crate::data::types::{Dataset, WeightedSet};
use crate::lsh::LshFamily;
use crate::serve::{ServeConfig, StarIndex};
use anyhow::{bail, Context, Result};
use std::io::Write;
use std::path::{Path, PathBuf};
use std::sync::Arc;

const MAGIC: &[u8; 4] = b"SSS1";
const VERSION: u32 = 1;

/// Path of the snapshot covering points `0..floor` in `dir`.
pub fn snapshot_path(dir: &Path, floor: u64) -> PathBuf {
    dir.join(format!("snapshot-{floor}.sss"))
}

/// Path of the WAL segment with base `base` in `dir`.
pub fn wal_path(dir: &Path, base: u64) -> PathBuf {
    dir.join(format!("wal-{base}.log"))
}

fn parse_stem(name: &str, prefix: &str, suffix: &str) -> Option<u64> {
    name.strip_prefix(prefix)?.strip_suffix(suffix)?.parse().ok()
}

/// `(base, path)` of every file in `dir` matching `{prefix}{N}{suffix}`,
/// ascending by `N`.
fn numbered_files(dir: &Path, prefix: &str, suffix: &str) -> Result<Vec<(u64, PathBuf)>> {
    let mut out = Vec::new();
    let entries = std::fs::read_dir(dir)
        .with_context(|| format!("listing state dir {}", dir.display()))?;
    for entry in entries {
        let entry = entry.with_context(|| format!("listing state dir {}", dir.display()))?;
        if let Some(n) = entry.file_name().to_str().and_then(|s| parse_stem(s, prefix, suffix)) {
            out.push((n, entry.path()));
        }
    }
    out.sort_unstable_by_key(|&(n, _)| n);
    Ok(out)
}

/// Snapshot files in `dir`, ascending by replay floor.
pub fn snapshot_files(dir: &Path) -> Result<Vec<(u64, PathBuf)>> {
    numbered_files(dir, "snapshot-", ".sss")
}

/// WAL files in `dir`, ascending by base.
pub fn wal_files(dir: &Path) -> Result<Vec<(u64, PathBuf)>> {
    numbered_files(dir, "wal-", ".log")
}

// ---------------------------------------------------------------------------
// Snapshot serialization

fn push_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn push_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn push_f32(out: &mut Vec<u8>, v: f32) {
    out.extend_from_slice(&v.to_le_bytes());
}

/// Bounds-checked little-endian section reader: every short read is an
/// error naming the offset, never a panic (the corrupted-input fuzz in
/// `tests/durability.rs` drives arbitrary bytes through this).
struct Rd<'a> {
    buf: &'a [u8],
    at: usize,
}

impl<'a> Rd<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if n > self.buf.len() - self.at {
            bail!(
                "payload truncated ({n} bytes needed at offset {}, {} present)",
                self.at,
                self.buf.len()
            );
        }
        let s = &self.buf[self.at..self.at + n];
        self.at += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    /// Element count claimed by a header field, validated against the
    /// bytes actually present before any allocation.
    fn count(&mut self, elem_bytes: usize) -> Result<usize> {
        let n = self.u64()? as usize;
        match n.checked_mul(elem_bytes) {
            Some(total) if total <= self.buf.len() - self.at => Ok(n),
            _ => bail!(
                "claimed {n} elements × {elem_bytes} bytes exceeds the {} remaining",
                self.buf.len() - self.at
            ),
        }
    }

    fn u32s(&mut self, n: usize) -> Result<Vec<u32>> {
        let bytes = n.checked_mul(4).context("element count overflows")?;
        Ok(self
            .take(bytes)?
            .chunks_exact(4)
            .map(|c| u32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    fn u64s(&mut self, n: usize) -> Result<Vec<u64>> {
        let bytes = n.checked_mul(8).context("element count overflows")?;
        Ok(self
            .take(bytes)?
            .chunks_exact(8)
            .map(|c| u64::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    fn f32s(&mut self, n: usize) -> Result<Vec<f32>> {
        let bytes = n.checked_mul(4).context("element count overflows")?;
        Ok(self
            .take(bytes)?
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    fn i8s(&mut self, n: usize) -> Result<Vec<i8>> {
        Ok(self.take(n)?.iter().map(|&b| b as i8).collect())
    }

    fn done(&self) -> Result<()> {
        if self.at != self.buf.len() {
            bail!("{} trailing bytes", self.buf.len() - self.at);
        }
        Ok(())
    }
}

const FLAG_QUANT: u8 = 1;
const FLAG_SETS: u8 = 2;
const FLAG_LABELS: u8 = 4;

fn meta_section(index: &StarIndex, floor: u64) -> Vec<u8> {
    let ds = index.dataset();
    let mut p = Vec::new();
    push_u64(&mut p, ds.len() as u64);
    push_u64(&mut p, ds.dim() as u64);
    push_u64(&mut p, floor);
    push_u32(&mut p, index.router().reps() as u32);
    let flags = if index.quant().is_some() { FLAG_QUANT } else { 0 }
        | if ds.sets.is_empty() { 0 } else { FLAG_SETS }
        | if ds.labels.is_empty() { 0 } else { FLAG_LABELS };
    p.push(flags);
    p
}

fn dset_section(ds: &Dataset) -> Vec<u8> {
    let mut p = Vec::new();
    let name = ds.name.as_bytes();
    push_u32(&mut p, name.len() as u32);
    p.extend_from_slice(name);
    for &x in &ds.dense {
        push_f32(&mut p, x);
    }
    for &l in &ds.labels {
        push_u32(&mut p, l);
    }
    for s in &ds.sets {
        push_u32(&mut p, s.tokens.len() as u32);
        for &t in &s.tokens {
            push_u32(&mut p, t);
        }
        for &w in &s.weights {
            push_f32(&mut p, w);
        }
    }
    p
}

fn csrs_section(index: &StarIndex) -> Vec<u8> {
    let csr = index.csr();
    let mut p = Vec::new();
    push_u64(&mut p, (csr.offset_slice().len() - 1) as u64);
    for &o in csr.offset_slice() {
        push_u64(&mut p, o as u64);
    }
    push_u64(&mut p, csr.neighbor_slice().len() as u64);
    for &v in csr.neighbor_slice() {
        push_u32(&mut p, v);
    }
    for &w in csr.weight_slice() {
        push_f32(&mut p, w);
    }
    p
}

fn rout_section(index: &StarIndex) -> Vec<u8> {
    let parts = index.router().export_parts();
    let mut p = Vec::new();
    push_u32(&mut p, parts.len() as u32);
    for (triples, entries) in parts {
        push_u64(&mut p, triples.len() as u64);
        for (key, start, len) in triples {
            push_u64(&mut p, key);
            push_u32(&mut p, start);
            push_u32(&mut p, len);
        }
        push_u64(&mut p, entries.len() as u64);
        for e in entries {
            push_u32(&mut p, e);
        }
    }
    p
}

fn qunt_section(index: &StarIndex) -> Option<Vec<u8>> {
    let q = index.quant()?;
    let mut p = Vec::new();
    push_u64(&mut p, q.dim() as u64);
    push_u64(&mut p, q.len() as u64);
    p.extend(q.code_slice().iter().map(|&c| c as u8));
    for &s in q.scale_slice() {
        push_f32(&mut p, s);
    }
    Some(p)
}

/// Serialize `index` (replay floor `floor`, asserted equal to its point
/// count) to `path` atomically: sections go to a `.tmp` sibling, which is
/// fsynced and renamed over the target.
pub fn save_snapshot(index: &StarIndex, floor: u64, path: &Path) -> Result<()> {
    assert_eq!(
        floor,
        index.len() as u64,
        "snapshot replay floor must equal the snapshot's point count"
    );
    let mut sections: Vec<([u8; 4], Vec<u8>)> = vec![
        (*b"META", meta_section(index, floor)),
        (*b"DSET", dset_section(index.dataset())),
        (*b"CSRS", csrs_section(index)),
        (*b"ROUT", rout_section(index)),
    ];
    if let Some(q) = qunt_section(index) {
        sections.push((*b"QUNT", q));
    }
    let tmp = path.with_extension("sss.tmp");
    let result = (|| -> Result<()> {
        let mut file = std::fs::File::create(&tmp)
            .with_context(|| format!("creating {}", tmp.display()))?;
        let mut head = Vec::new();
        head.extend_from_slice(MAGIC);
        push_u32(&mut head, VERSION);
        push_u32(&mut head, sections.len() as u32);
        file.write_all(&head)?;
        for (tag, payload) in &sections {
            let mut frame = Vec::with_capacity(16 + payload.len());
            frame.extend_from_slice(tag);
            push_u64(&mut frame, payload.len() as u64);
            push_u32(&mut frame, crc32(payload));
            frame.extend_from_slice(payload);
            file.write_all(&frame)?;
        }
        file.sync_all()
            .with_context(|| format!("fsyncing {}", tmp.display()))?;
        std::fs::rename(&tmp, path)
            .with_context(|| format!("publishing {} over {}", tmp.display(), path.display()))
    })();
    if result.is_err() {
        std::fs::remove_file(&tmp).ok();
    }
    result
}

/// Parse the raw section table of a snapshot file: `(tag, payload)` pairs
/// in file order, CRC-validated. Every failure names the file and the
/// section.
fn read_sections(path: &Path) -> Result<Vec<([u8; 4], Vec<u8>)>> {
    let bytes = std::fs::read(path)
        .with_context(|| format!("reading snapshot {}", path.display()))?;
    let mut r = Rd { buf: &bytes, at: 0 };
    let magic = r
        .take(4)
        .with_context(|| format!("{}: reading magic", path.display()))?;
    if magic != MAGIC {
        bail!(
            "{}: bad magic {magic:?} (expected {MAGIC:?}) — not a stars snapshot file",
            path.display()
        );
    }
    let version = r
        .u32()
        .with_context(|| format!("{}: reading version", path.display()))?;
    if version != VERSION {
        bail!("{}: unsupported snapshot version {version}", path.display());
    }
    let count = r
        .u32()
        .with_context(|| format!("{}: reading section count", path.display()))?;
    if count > 64 {
        bail!("{}: absurd section count {count} — corrupt header", path.display());
    }
    let mut sections = Vec::with_capacity(count as usize);
    for i in 0..count {
        let frame = (|| -> Result<([u8; 4], Vec<u8>)> {
            let tag: [u8; 4] = r.take(4)?.try_into().unwrap();
            let len = r.count(1)?;
            let want = r.u32()?;
            let payload = r.take(len)?;
            let got = crc32(payload);
            if got != want {
                bail!(
                    "section {:?}: checksum mismatch ({got:#010x} != {want:#010x})",
                    String::from_utf8_lossy(&tag)
                );
            }
            Ok((tag, payload.to_vec()))
        })()
        .with_context(|| format!("{}: reading section {i}", path.display()))?;
        sections.push(frame);
    }
    r.done()
        .with_context(|| format!("{}: after the section table", path.display()))?;
    Ok(sections)
}

/// Load a snapshot from `path`, re-deriving the per-repetition sketch
/// states through `family` (they are never persisted — state purity makes
/// re-preparation bit-identical) and re-assembling a [`StarIndex`] under
/// `cfg`. Returns the index and its WAL-replay floor.
///
/// Fails with per-section context on any corruption: a bit flip or
/// truncation anywhere must surface here, never as a panic or a silently
/// different index (fuzzed over every section boundary in
/// `tests/durability.rs`).
pub fn load_snapshot<'f>(
    path: &Path,
    family: &'f dyn LshFamily,
    cfg: ServeConfig,
    workers: usize,
) -> Result<(StarIndex<'f>, u64)> {
    let sections = read_sections(path)?;
    let section = |tag: &[u8; 4]| -> Result<&Vec<u8>> {
        sections
            .iter()
            .find(|(t, _)| t == tag)
            .map(|(_, p)| p)
            .ok_or_else(|| {
                anyhow::anyhow!(
                    "{}: missing section {:?}",
                    path.display(),
                    String::from_utf8_lossy(tag)
                )
            })
    };

    // META
    let (n, dim, floor, reps, flags) = (|| -> Result<_> {
        let mut r = Rd { buf: section(b"META")?, at: 0 };
        let n = r.u64()? as usize;
        let dim = r.u64()? as usize;
        let floor = r.u64()?;
        let reps = r.u32()? as usize;
        let flags = r.u8()?;
        r.done()?;
        if floor != n as u64 {
            bail!("replay floor {floor} != point count {n}");
        }
        if flags & !(FLAG_QUANT | FLAG_SETS | FLAG_LABELS) != 0 {
            bail!("unknown flag bits {flags:#04x}");
        }
        Ok((n, dim, floor, reps, flags))
    })()
    .with_context(|| format!("{}: section META", path.display()))?;

    // DSET
    let ds = (|| -> Result<Dataset> {
        let mut r = Rd { buf: section(b"DSET")?, at: 0 };
        let name_len = r.u32()? as usize;
        if name_len > 4096 {
            bail!("claimed {name_len}-byte dataset name");
        }
        let name = String::from_utf8(r.take(name_len)?.to_vec())
            .context("dataset name not utf8")?;
        let dense = r.f32s(n.checked_mul(dim).context("n×dim overflows")?)?;
        let labels = if flags & FLAG_LABELS != 0 { r.u32s(n)? } else { Vec::new() };
        let sets = if flags & FLAG_SETS != 0 {
            // No with_capacity(n): a corrupted META n must fail on the
            // first short read, not pre-allocate n slots.
            let mut sets = Vec::new();
            for i in 0..n {
                let len = r.u32()? as usize;
                let tokens = r
                    .u32s(len)
                    .with_context(|| format!("set {i} tokens"))?;
                let weights = r
                    .f32s(len)
                    .with_context(|| format!("set {i} weights"))?;
                sets.push(WeightedSet { tokens, weights });
            }
            sets
        } else {
            Vec::new()
        };
        r.done()?;
        Ok(match (dim > 0, !sets.is_empty() || (flags & FLAG_SETS != 0 && n == 0)) {
            (true, true) => Dataset::hybrid(&name, dim, dense, sets, labels),
            (true, false) => Dataset::from_dense(&name, dim, dense, labels),
            (false, true) => Dataset::from_sets(&name, sets, labels),
            (false, false) => bail!("dataset has neither dense nor set features"),
        })
    })()
    .with_context(|| format!("{}: section DSET", path.display()))?;

    // CSRS
    let csr = (|| -> Result<_> {
        let mut r = Rd { buf: section(b"CSRS")?, at: 0 };
        let nodes = r.count(8)?;
        if nodes != n {
            bail!("CSR node count {nodes} != point count {n}");
        }
        let offsets: Vec<usize> = r.u64s(nodes + 1)?.into_iter().map(|o| o as usize).collect();
        let edges = r.count(8)?;
        if Some(&edges) != offsets.last() {
            bail!("CSR edge count {edges} != final offset {:?}", offsets.last());
        }
        if offsets.windows(2).any(|w| w[0] > w[1]) {
            bail!("CSR offsets not monotone");
        }
        let neighbors = r.u32s(edges)?;
        if let Some(&bad) = neighbors.iter().find(|&&v| v as usize >= nodes) {
            bail!("CSR neighbor id {bad} out of range (n = {nodes})");
        }
        let weights = r.f32s(edges)?;
        r.done()?;
        Ok(crate::graph::Csr::from_raw_parts(offsets, neighbors, weights))
    })()
    .with_context(|| format!("{}: section CSRS", path.display()))?;

    // ROUT
    let router = (|| -> Result<_> {
        let mut r = Rd { buf: section(b"ROUT")?, at: 0 };
        let nreps = r.u32()? as usize;
        if nreps != reps {
            bail!("router rep count {nreps} != META rep count {reps}");
        }
        let mut parts = Vec::with_capacity(nreps);
        for rep in 0..nreps {
            let nbuckets = r.count(16)?;
            let mut triples = Vec::with_capacity(nbuckets);
            for _ in 0..nbuckets {
                triples.push((r.u64()?, r.u32()?, r.u32()?));
            }
            let nentries = r.count(4)?;
            let entries = r.u32s(nentries)?;
            for &(key, start, len) in &triples {
                if start as usize + len as usize > entries.len() {
                    bail!("rep {rep} bucket {key:#x}: range out of bounds");
                }
            }
            if triples.windows(2).any(|w| w[0].0 >= w[1].0) {
                bail!("rep {rep}: bucket keys not strictly ascending");
            }
            if let Some(&bad) = entries.iter().find(|&&e| e as usize >= n) {
                bail!("rep {rep}: entry id {bad} out of range (n = {n})");
            }
            parts.push((triples, entries));
        }
        r.done()?;
        Ok(crate::serve::Router::from_parts(parts))
    })()
    .with_context(|| format!("{}: section ROUT", path.display()))?;

    // QUNT — only consulted when the serving config wants the quantized
    // tier; a plain restart of a quantized state dir simply ignores it.
    let quant = if cfg.quantized && dim > 0 {
        if flags & FLAG_QUANT != 0 {
            let q = (|| -> Result<_> {
                let mut r = Rd { buf: section(b"QUNT")?, at: 0 };
                let qdim = r.u64()? as usize;
                if qdim != dim {
                    bail!("quant dim {qdim} != dataset dim {dim}");
                }
                let rows = r.count(dim.max(1))?;
                if rows != n {
                    bail!("quant row count {rows} != point count {n}");
                }
                let codes = r.i8s(rows * dim)?;
                let scales = r.f32s(rows)?;
                r.done()?;
                Ok(crate::sim::QuantDataset::from_raw_parts(dim, codes, scales))
            })()
            .with_context(|| format!("{}: section QUNT", path.display()))?;
            Some(Arc::new(q))
        } else {
            // Snapshot was persisted unquantized; per-row SQ8 is a pure
            // function of the rows, so recomputing is bit-identical to
            // what a quantized build would have stored.
            Some(Arc::new(crate::sim::QuantDataset::from_dataset(&ds)))
        }
    } else {
        None
    };

    let states = (0..reps.max(1))
        .map(|rep| Arc::from(family.prepare(&ds, rep as u64)))
        .collect();
    Ok((StarIndex::from_parts(ds, csr, states, router, quant, cfg), floor))
}

// ---------------------------------------------------------------------------
// The store

/// A recovered serving state: the snapshot-backed index plus the WAL
/// suffix to replay through the normal insert path.
pub struct Recovered<'f> {
    /// The index loaded from the newest valid snapshot.
    pub index: StarIndex<'f>,
    /// WAL records with `gid ≥ index.len()`, gapless and in gid order —
    /// replaying them through `insert` reproduces the uncrashed engine.
    pub replay: Vec<WalRecord>,
}

/// The `--state-dir` front: owns the active WAL writer, the pending
/// (not-yet-snapshotted) records, and the checkpoint/recover protocol.
pub struct DurableStore {
    dir: PathBuf,
    policy: FsyncPolicy,
    wal: Option<WalWriter>,
    /// Records logged since the last checkpoint whose gid may exceed the
    /// newest snapshot's floor — re-logged into the fresh WAL on rotation.
    pending: Vec<WalRecord>,
    replayed: crate::obs::Counter,
    recoveries: crate::obs::Counter,
    saves: crate::obs::Counter,
    load_errors: crate::obs::Counter,
}

impl DurableStore {
    /// Open (creating if needed) the state directory.
    pub fn open(dir: &Path, policy: FsyncPolicy) -> Result<DurableStore> {
        std::fs::create_dir_all(dir)
            .with_context(|| format!("creating state dir {}", dir.display()))?;
        let reg = crate::obs::registry();
        Ok(DurableStore {
            dir: dir.to_path_buf(),
            policy,
            wal: None,
            pending: Vec::new(),
            replayed: reg.counter("stars_serve_wal_replayed_total"),
            recoveries: reg.counter("stars_serve_recoveries_total"),
            saves: reg.counter("stars_serve_snapshot_saves_total"),
            load_errors: reg.counter("stars_serve_snapshot_load_errors_total"),
        })
    }

    /// The state directory.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Attempt recovery: load the newest valid snapshot (falling back to
    /// older ones on per-file corruption) and collect the WAL suffix.
    /// `Ok(None)` means a fresh directory — no snapshot exists and serving
    /// starts with a build + [`Self::checkpoint`]. After a successful
    /// recovery the store has a fresh WAL rotated to the recovered
    /// high-water, ready for [`Self::log_insert`].
    pub fn recover<'f>(
        &mut self,
        family: &'f dyn LshFamily,
        cfg: ServeConfig,
        workers: usize,
    ) -> Result<Option<Recovered<'f>>> {
        let snapshots = snapshot_files(&self.dir)?;
        if snapshots.is_empty() {
            return Ok(None);
        }
        let mut loaded = None;
        let mut errors = Vec::new();
        for (floor, path) in snapshots.iter().rev() {
            match load_snapshot(path, family, cfg.clone(), workers) {
                Ok((index, file_floor)) if file_floor == *floor => {
                    loaded = Some(index);
                    break;
                }
                Ok((_, file_floor)) => {
                    self.load_errors.inc(1);
                    errors.push(format!(
                        "{}: file claims floor {file_floor}, name says {floor}",
                        path.display()
                    ));
                }
                Err(e) => {
                    self.load_errors.inc(1);
                    errors.push(format!("{e:#}"));
                }
            }
        }
        let Some(index) = loaded else {
            bail!(
                "no loadable snapshot in {} ({} candidates): {}",
                self.dir.display(),
                errors.len(),
                errors.join("; ")
            );
        };

        // Replay every WAL file in base order under the skip/apply/gap
        // rule (duplicates from rotation re-logging are expected; a gap is
        // corruption).
        let mut next = index.len() as u64;
        let mut replay = Vec::new();
        for (_, path) in wal_files(&self.dir)? {
            let (records, _torn) = read_wal(&path)?;
            for rec in records {
                match (rec.gid as u64).cmp(&next) {
                    std::cmp::Ordering::Less => {} // already in the snapshot or replayed
                    std::cmp::Ordering::Equal => {
                        replay.push(rec);
                        next += 1;
                    }
                    std::cmp::Ordering::Greater => bail!(
                        "WAL gap in {}: record gid {} but replay expects {next} — \
                         a WAL segment is missing or misordered",
                        path.display(),
                        rec.gid
                    ),
                }
            }
        }
        self.replayed.inc(replay.len() as u64);
        self.recoveries.inc(1);

        // Rotate to a fresh WAL at the recovered high-water. The replayed
        // records become pending again (they are not inside the snapshot),
        // re-logged so the old segments stay prunable at the next
        // checkpoint.
        self.pending = replay.clone();
        self.rotate(next, &[])?;
        Ok(Some(Recovered { index, replay }))
    }

    /// Append one insert to the WAL (write-ahead: call *before* applying
    /// the insert to the engine).
    pub fn log_insert(&mut self, gid: u32, row: Option<&[f32]>, set: Option<&WeightedSet>) -> Result<()> {
        let rec = WalRecord {
            gid,
            row: row.map(|r| r.to_vec()),
            set: set.cloned(),
        };
        self.wal
            .as_mut()
            .expect("log_insert before checkpoint/recover established a WAL")
            .append(&rec)?;
        self.pending.push(rec);
        Ok(())
    }

    /// Crash simulation: append the first `keep` bytes of the record's
    /// frame — the torn tail a mid-write crash leaves — without tracking
    /// it as pending. The caller is expected to abort the process.
    pub fn log_torn(
        &mut self,
        gid: u32,
        row: Option<&[f32]>,
        set: Option<&WeightedSet>,
        keep: usize,
    ) -> Result<usize> {
        let rec = WalRecord {
            gid,
            row: row.map(|r| r.to_vec()),
            set: set.cloned(),
        };
        self.wal
            .as_mut()
            .expect("log_torn before checkpoint/recover established a WAL")
            .append_torn(&rec, keep)
    }

    /// Force the active WAL to disk regardless of fsync policy.
    pub fn sync(&mut self) -> Result<()> {
        match self.wal.as_mut() {
            Some(w) => w.sync(),
            None => Ok(()),
        }
    }

    /// Persist `index` and advance the durable state: publish
    /// `snapshot-{n}.sss` atomically, rotate the WAL to base `n` re-logging
    /// still-pending records (gid ≥ n), then prune WAL segments and
    /// snapshots the new snapshot supersedes. Crash-safe at every step —
    /// recovery handles a published snapshot with unpruned predecessors,
    /// and pruning is strictly after the publish.
    pub fn checkpoint(&mut self, index: &StarIndex) -> Result<PathBuf> {
        let floor = index.len() as u64;
        let path = snapshot_path(&self.dir, floor);
        save_snapshot(index, floor, &path)?;
        self.saves.inc(1);

        self.pending.retain(|r| r.gid as u64 >= floor);
        let keep: Vec<WalRecord> = self.pending.clone();
        self.rotate(floor, &keep)?;

        // Prune superseded files, best-effort: the publish above is the
        // durability point, deletion is housekeeping.
        for (n, p) in snapshot_files(&self.dir)? {
            if n < floor {
                std::fs::remove_file(&p).ok();
            }
        }
        for (b, p) in wal_files(&self.dir)? {
            if b < floor {
                std::fs::remove_file(&p).ok();
            }
        }
        Ok(path)
    }

    /// Open a fresh `wal-{base}.log` with `relog` already appended,
    /// atomically: bytes go to a `.tmp` sibling that is synced and renamed
    /// into place, so a crash mid-rotation leaves any previous
    /// `wal-{base}.log` untouched (re-logged records are never the only
    /// durable copy until the rename lands).
    fn rotate(&mut self, base: u64, relog: &[WalRecord]) -> Result<()> {
        let final_path = wal_path(&self.dir, base);
        let tmp = final_path.with_extension("log.tmp");
        let result = (|| -> Result<WalWriter> {
            let mut wal = WalWriter::create(&tmp, self.policy)?;
            for rec in relog {
                wal.append(rec)?;
            }
            wal.sync()?;
            std::fs::rename(&tmp, &final_path).with_context(|| {
                format!("publishing {} over {}", tmp.display(), final_path.display())
            })?;
            wal.set_path(final_path.clone());
            Ok(wal)
        })();
        match result {
            Ok(wal) => {
                self.wal = Some(wal);
                Ok(())
            }
            Err(e) => {
                std::fs::remove_file(&tmp).ok();
                Err(e)
            }
        }
    }
}
