//! Shard-parallel serving: a fence-partitioned snapshot behind a
//! scatter-gather engine.
//!
//! The paper's serving story (§4) fans queries out across machines; this
//! module is the in-process mirror of that fleet, the same way
//! `ampc::Cluster` simulates the build fleet with threads over shared
//! memory. A [`ShardedIndex`] partitions one immutable [`StarIndex`] epoch
//! by contiguous node range: `fence[s]..fence[s+1]` is the entry range
//! shard `s` **owns**. Every shard holds an `Arc` of the *same* snapshot
//! (shared memory stands in for replicated storage); what the fence
//! partitions is **routing-entry ownership**, not rows. A scatter task for
//! shard `s` runs the single-engine pipeline — sketch → route → two-hop
//! expand → tiled score — but expands only the probed router entries it
//! owns, scores whatever candidates that expansion reaches (two-hop
//! neighborhoods cross fences freely), folds in shard `s`'s own delta
//! slice, and returns a per-shard top list. The gather phase merges the
//! shard lists under the engine's total order (score descending, ties
//! ascending by id), drops cross-shard duplicates, and truncates.
//!
//! **Shard-invariance contract** (`tests/shard_parity.rs`): the merged
//! top-k is bit-identical to [`QueryEngine`]'s answer for any shard count
//! and any worker count. The argument:
//!
//! * every probed router entry is owned by exactly one shard, so the union
//!   of the shards' two-hop expansions is exactly the single engine's
//!   candidate set (cross-shard duplicates are inherent spanner overlap);
//! * scores are pure per `(query, id)` — the tiled kernels compute each
//!   candidate's similarity independently of list composition — so
//!   duplicates carry bit-equal scores and land adjacent under the total
//!   order, where one `dedup` pass removes them;
//! * any member of the global top-k beats all but < k elements of *any*
//!   candidate subset containing it, so it survives every per-shard
//!   top-k cut and the merge restores the global order.
//!
//! The argument needs the *whole* candidate set expanded, which is why
//! sharded serving requires `max_candidates = 0`: the single engine's
//! global cap truncates in probe order, a cut no fence partition can
//! replicate. [`crate::stars::StarsBuilder::build_sharded`] forces the
//! override (with a logged notice); [`ShardedEngine::new`] asserts it.
//!
//! **Quantized tier** runs in two phases to keep the survivor set exact:
//! each shard returns its top-`c` (`c = k · rescore_factor`) *int8
//! estimates* — pure per `(query, id)`, hence bit-equal across shards —
//! and the gather merges them to the global top-`c`, which equals the
//! single engine's survivor set, then rescores those survivors through the
//! exact f32 kernels and keeps the top k. Same recall contract as the
//! single engine, bit-identical output.
//!
//! **Writes** land in per-shard [`DeltaBuffer`]s. A global sequencer lock
//! allocates ids and orders captures: an insert holds the sequencer across
//! its shard push, so anyone capturing under the sequencer sees a gapless
//! global-id view — the invariant compaction's reassembly asserts.
//! Compaction reassembles the union delta in global-id order and runs the
//! *same* rebuild code as the single engine
//! ([`rebuild_full_from`]/[`rebuild_incremental_from`]), so compacted
//! epochs are bit-identical too. Lock order is always sequencer → shard
//! deltas (ascending) → snapshot; nothing acquires in another order.

use super::delta::DeltaBuffer;
use super::executor::{
    rebuild_full_from, rebuild_incremental_from, CompactionReport, QueryScratch, ServeMeasure,
    TopNeighbors, QSCRATCH,
};
use super::index::StarIndex;
use super::CompactionMode;
use crate::ampc::SnapshotStats;
use crate::data::types::{Dataset, WeightedSet};
use crate::graph::two_hop::two_hop_into;
use crate::lsh::LshFamily;
use crate::sim::quant::{self, QuantDataset};
use crate::stars::BuildParams;
use crate::util::fault::{Fault, FaultPlan};
use crate::util::fxhash::FxHashMap;
use crate::util::json::Json;
use crate::util::pool;
use crate::util::simd;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, RwLock};
use std::time::Instant;

#[cfg(doc)]
use super::executor::QueryEngine;

/// Fence for `n` points over `n_shards` contiguous ranges:
/// `fence[s]..fence[s+1]` is shard `s`'s owned node range, `fence` has
/// `n_shards + 1` entries, `fence[0] = 0`, `fence[n_shards] = n`. Ranges
/// differ in size by at most one point; shards beyond `n` own empty
/// ranges (`n_shards > n` is legal — the extra shards simply contribute
/// nothing).
pub fn fence_for(n: usize, n_shards: usize) -> Vec<u64> {
    let s = n_shards.max(1) as u64;
    (0..=s).map(|i| n as u64 * i / s).collect()
}

/// A fence-partitioned serving snapshot: per-shard handles to one shared
/// immutable [`StarIndex`] epoch plus the ownership fence. Built by
/// [`crate::stars::StarsBuilder::build_sharded`] (routing reps are
/// sketched once and split by fence — the shards never re-sketch).
pub struct ShardedIndex<'f> {
    /// One handle per shard; all point at the same epoch (`Arc::ptr_eq`).
    shards: Vec<Arc<StarIndex<'f>>>,
    /// `fence[s]..fence[s+1]` = node range shard `s` owns (`n_shards + 1`
    /// entries).
    fence: Vec<u64>,
}

impl<'f> ShardedIndex<'f> {
    /// Partition a built snapshot into `n_shards` (clamped to ≥ 1)
    /// contiguous ownership ranges.
    pub fn new(index: StarIndex<'f>, n_shards: usize) -> ShardedIndex<'f> {
        let n_shards = n_shards.max(1);
        let snap = Arc::new(index);
        let fence = fence_for(snap.len(), n_shards);
        ShardedIndex {
            shards: (0..n_shards).map(|_| snap.clone()).collect(),
            fence,
        }
    }

    /// Number of shards.
    pub fn n_shards(&self) -> usize {
        self.shards.len()
    }

    /// The ownership fence (`n_shards + 1` entries).
    pub fn fence(&self) -> &[u64] {
        &self.fence
    }

    /// The same snapshot under a different shard count. Shards share the
    /// underlying snapshot `Arc`, so re-sharding costs O(`n_shards`) — the
    /// scaling benches sweep shard counts off one build this way.
    pub fn resharded(&self, n_shards: usize) -> ShardedIndex<'f> {
        let n_shards = n_shards.max(1);
        ShardedIndex {
            shards: (0..n_shards).map(|_| self.shards[0].clone()).collect(),
            fence: fence_for(self.shards[0].len(), n_shards),
        }
    }

    /// Shard `s`'s snapshot handle.
    pub fn shard(&self, s: usize) -> &Arc<StarIndex<'f>> {
        &self.shards[s]
    }

    /// The shared snapshot epoch.
    pub fn snapshot(&self) -> Arc<StarIndex<'f>> {
        self.shards[0].clone()
    }

    /// Shard `s`'s slice of the snapshot telemetry (see
    /// [`shard_stats_of`]).
    pub fn shard_stats(&self, s: usize) -> SnapshotStats {
        shard_stats_of(&self.shards[s], &self.fence, s)
    }
}

/// Shard `s`'s slice of a snapshot's [`SnapshotStats`]: owned points and
/// their CSR adjacency entries are counted exactly; router entries via
/// [`super::router::Router::entries_in_range`]; byte figures are prorated
/// by the shard's share (points for CSR/state/quant bytes, live entries
/// for router bytes) since the underlying storage is shared.
pub fn shard_stats_of(snap: &StarIndex<'_>, fence: &[u64], s: usize) -> SnapshotStats {
    let (lo, hi) = (fence[s] as u32, fence[s + 1] as u32);
    let points = (hi - lo) as usize;
    let frac = points as f64 / snap.len().max(1) as f64;
    let edges: usize = (lo..hi).map(|u| snap.csr().degree(u)).sum();
    let entries = snap.router().entries_in_range(lo, hi);
    let full = snap.stats();
    let efrac = entries as f64 / full.router_entries.max(1) as f64;
    SnapshotStats {
        points,
        edges,
        router_reps: full.router_reps,
        router_entries: entries,
        router_bytes: (full.router_bytes as f64 * efrac) as usize,
        csr_bytes: (full.csr_bytes as f64 * frac) as usize,
        state_table_bytes: (full.state_table_bytes as f64 * frac) as usize,
        quantized: full.quantized,
        rescore_factor: full.rescore_factor,
        quant_bytes: (full.quant_bytes as f64 * frac) as usize,
        bytes_per_row: full.bytes_per_row,
    }
}

/// One shard's write-side state: its delta buffer plus the *global* id of
/// each buffered row (`ids[i]` is row `i`'s id). The buffer's own base is
/// not meaningful here — global ids interleave across shards, so the
/// explicit vector is authoritative.
struct ShardDelta {
    buf: DeltaBuffer,
    ids: Vec<u32>,
}

/// A consistent per-shard delta view captured under the sequencer.
struct ShardView {
    ds: Dataset,
    quant: Option<QuantDataset>,
    ids: Vec<u32>,
}

/// One shard's answer for one query: the per-shard top list plus the
/// scatter task's wall time (observability only).
struct ShardAnswer {
    top: Vec<(u32, f32)>,
    us: u64,
}

/// The scatter-gather serving engine over a [`ShardedIndex`] — the
/// multi-shard counterpart of [`QueryEngine`], bit-identical to it for
/// any shard count (see the module docs for the contract and argument).
pub struct ShardedEngine<'f> {
    family: &'f dyn LshFamily,
    measure: ServeMeasure,
    build: BuildParams,
    workers: usize,
    compact_limit: usize,
    n_shards: usize,
    snapshot: RwLock<Arc<StarIndex<'f>>>,
    /// Insert sequencer: the next global id. Lock order is `seq` → shard
    /// deltas (ascending index); an insert holds `seq` across its shard
    /// push, so capturing under `seq` yields a gapless global-id view.
    seq: Mutex<usize>,
    deltas: Vec<Mutex<ShardDelta>>,
    /// Buffered rows across all shards (mirrors the per-shard `ids` under
    /// `seq`; read lock-free for the auto-compaction trigger and gauges).
    pending_total: AtomicUsize,
    /// Serializes compactions so concurrent triggers rebuild once.
    compacting: Mutex<()>,
    full_compactions: AtomicU64,
    incremental_compactions: AtomicU64,
    incr_since_full: AtomicU64,
    /// Deterministic fault schedule for scatter tasks
    /// ([`ShardedEngine::faults`]); inactive by default. Crash draws
    /// re-execute the task (straggler re-execution), delay draws sleep —
    /// results are bit-identical either way.
    faults: FaultPlan,
    /// Scatter round counter (the fault plan's `round` coordinate).
    round: AtomicU64,
    /// Scatter task re-executions triggered by the fault plan.
    scatter_retries_n: AtomicU64,
    delta_pending_gauge: crate::obs::Gauge,
    retry_counter: crate::obs::Counter,
}

impl<'f> ShardedEngine<'f> {
    /// Engine over a partitioned snapshot. `build` parameterizes
    /// compaction rebuilds, exactly as for [`QueryEngine::new`].
    ///
    /// Panics when the snapshot was built with `max_candidates > 0` — the
    /// global candidate cap truncates in probe order, which no fence
    /// partition can replicate (see the module docs);
    /// [`crate::stars::StarsBuilder::build_sharded`] forces the override.
    pub fn new(
        index: ShardedIndex<'f>,
        family: &'f dyn LshFamily,
        measure: ServeMeasure,
        build: BuildParams,
    ) -> ShardedEngine<'f> {
        let n_shards = index.n_shards();
        let snap = index.snapshot();
        assert_eq!(
            snap.config().max_candidates, 0,
            "sharded serving requires max_candidates = 0 (the global cap truncates in probe \
             order, which shards cannot replicate; build via StarsBuilder::build_sharded)"
        );
        let compact_limit = snap.config().compact_limit;
        let deltas = (0..n_shards)
            .map(|_| {
                Mutex::new(ShardDelta {
                    buf: DeltaBuffer::new(snap.dataset(), snap.len()),
                    ids: Vec::new(),
                })
            })
            .collect();
        let engine = ShardedEngine {
            family,
            measure,
            build,
            workers: pool::default_workers(),
            compact_limit,
            n_shards,
            seq: Mutex::new(snap.len()),
            snapshot: RwLock::new(snap),
            deltas,
            pending_total: AtomicUsize::new(0),
            compacting: Mutex::new(()),
            full_compactions: AtomicU64::new(0),
            incremental_compactions: AtomicU64::new(0),
            incr_since_full: AtomicU64::new(0),
            faults: FaultPlan::none(),
            round: AtomicU64::new(0),
            scatter_retries_n: AtomicU64::new(0),
            delta_pending_gauge: crate::obs::registry().gauge("stars_serve_delta_pending"),
            retry_counter: crate::obs::registry().counter("stars_serve_scatter_retries_total"),
        };
        crate::obs::registry().gauge("stars_serve_shards").set(n_shards as u64);
        engine.publish_shard_metrics();
        engine
    }

    /// Worker count for scatter/gather batches and compaction rebuilds.
    pub fn workers(mut self, workers: usize) -> Self {
        self.workers = workers.max(1);
        self
    }

    /// Pin a deterministic fault schedule onto the scatter path (tests;
    /// defaults to no faults). The plan is pure in `(round, task,
    /// attempt)`, so injected crashes re-execute tasks without changing
    /// any answer.
    pub fn faults(mut self, plan: FaultPlan) -> Self {
        self.faults = plan;
        self
    }

    /// Number of shards.
    pub fn n_shards(&self) -> usize {
        self.n_shards
    }

    /// Points in the current snapshot.
    pub fn num_indexed(&self) -> usize {
        self.snapshot.read().unwrap().len()
    }

    /// Points buffered across all shard deltas.
    pub fn num_pending(&self) -> usize {
        self.pending_total.load(Ordering::Relaxed)
    }

    /// The insert sequencer's high-water mark: the global id the next
    /// [`ShardedEngine::insert`] will assign. Mirrors
    /// [`QueryEngine::next_gid`] — the durable layer WAL-logs each record
    /// under this id before applying it, and replay uses
    /// `gid < next_gid()` as its already-applied test.
    pub fn next_gid(&self) -> u32 {
        *self.seq.lock().unwrap() as u32
    }

    /// The current snapshot epoch (shared by every shard).
    pub fn snapshot(&self) -> Arc<StarIndex<'f>> {
        self.snapshot.read().unwrap().clone()
    }

    /// The current ownership fence.
    pub fn fence(&self) -> Vec<u64> {
        fence_for(self.num_indexed(), self.n_shards)
    }

    /// Shard `s`'s slice of the current snapshot telemetry.
    pub fn shard_stats(&self, s: usize) -> SnapshotStats {
        let snap = self.snapshot();
        let fence = fence_for(snap.len(), self.n_shards);
        shard_stats_of(&snap, &fence, s)
    }

    /// Scatter task re-executions the fault plan has triggered so far.
    pub fn scatter_retries(&self) -> u64 {
        self.scatter_retries_n.load(Ordering::Relaxed)
    }

    /// The engine's compaction mix so far: `(full, incremental)` counts.
    pub fn compaction_mix(&self) -> (u64, u64) {
        (
            self.full_compactions.load(Ordering::Relaxed),
            self.incremental_compactions.load(Ordering::Relaxed),
        )
    }

    /// True when the degraded quantized tier can serve (mirrors
    /// [`QueryEngine::quant_ready`]).
    pub fn quant_ready(&self) -> bool {
        self.measure.supports_quant() && self.snapshot.read().unwrap().quant().is_some()
    }

    /// Refresh the `stars_serve_shard_{s}_*` gauges from the current
    /// snapshot (called at construction and after every compaction swap).
    fn publish_shard_metrics(&self) {
        let snap = self.snapshot();
        let fence = fence_for(snap.len(), self.n_shards);
        for s in 0..self.n_shards {
            let st = shard_stats_of(&snap, &fence, s);
            let reg = crate::obs::registry();
            reg.gauge(&format!("stars_serve_shard_{s}_points")).set(st.points as u64);
            reg.gauge(&format!("stars_serve_shard_{s}_edges")).set(st.edges as u64);
            reg.gauge(&format!("stars_serve_shard_{s}_router_entries"))
                .set(st.router_entries as u64);
        }
    }

    /// Answer a batch: scatter to every shard, gather under the total
    /// order. Bit-identical to [`QueryEngine::query`] over the same
    /// snapshot and inserts, for any shard and worker count.
    pub fn query(&self, queries: &Dataset, k: usize) -> Vec<Vec<(u32, f32)>> {
        self.query_tier(queries, k, None)
    }

    /// [`ShardedEngine::query`] with the explicit scoring-tier override
    /// (mirrors [`QueryEngine::query_tier`]): `Some(rf)` forces the
    /// quantized first pass with rescore width `c = k · rf`.
    pub fn query_tier(
        &self,
        queries: &Dataset,
        k: usize,
        quant_rescore: Option<usize>,
    ) -> Vec<Vec<(u32, f32)>> {
        let nq = queries.len();
        if nq == 0 {
            return Vec::new();
        }
        // Consistent epoch: capturing under the sequencer guarantees no
        // insert is mid-push, so the per-shard views form a gapless
        // global-id set and the batch sees each point exactly once.
        let (snap, views) = {
            let _seq = self.seq.lock().unwrap();
            let snap = self.snapshot.read().unwrap().clone();
            let views: Vec<ShardView> = self
                .deltas
                .iter()
                .map(|m| {
                    let d = m.lock().unwrap();
                    ShardView {
                        ds: d.buf.dataset().clone(),
                        quant: d.buf.quant().cloned(),
                        ids: d.ids.clone(),
                    }
                })
                .collect();
            (snap, views)
        };
        if snap.dataset().dim() > 0 {
            assert_eq!(queries.dim(), snap.dataset().dim(), "query dimension mismatch");
        }
        let keys = snap.query_keys(queries, self.workers);
        let ns = self.n_shards;
        let n = snap.len();
        let fence = fence_for(n, ns);
        let measure = self.measure;
        // The tier decision is batch-global so every shard serves the same
        // tier — the same condition QueryEngine evaluates, with "the delta"
        // read as the union of the shard slices.
        let quant_engaged = k > 0
            && (quant_rescore.is_some() || snap.config().quantized)
            && measure.supports_quant()
            && snap.quant().is_some()
            && views.iter().all(|v| v.ds.is_empty() || v.quant.is_some());
        let rf = quant_rescore.unwrap_or(snap.config().rescore_factor).max(1);
        let c = k.saturating_mul(rf);
        let quant_pass = quant_engaged.then_some(c);
        let lat_hist = crate::obs::registry().histogram("stars_serve_query_latency_us");
        let query_count = crate::obs::registry().counter("stars_serve_queries_total");
        let scatter_hist = crate::obs::registry().histogram("stars_serve_shard_scatter_us");
        if quant_engaged {
            crate::obs::registry()
                .histogram("stars_serve_rescore_width")
                .record(c as u64);
        }
        let plan = self.faults;
        let round = self.round.fetch_add(1, Ordering::Relaxed);
        // Phase 1 — scatter: nq × n_shards independent tasks over the
        // pool; task t answers query t / ns on shard t % ns. The fault
        // plan's crash draws re-execute the task (attempt advances until
        // the plan's max_failures exhausts), delay draws sleep first —
        // neither changes the result.
        let (keys_ref, views_ref, fence_ref, snap_ref) = (&keys, &views, &fence, &snap);
        let per_shard: Vec<ShardAnswer> = pool::parallel_map(nq * ns, self.workers, |t| {
            let (qi, si) = (t / ns, t % ns);
            if plan.is_active() {
                let mut attempt = 0u32;
                loop {
                    match plan.decide(round, t as u64, attempt) {
                        Fault::Crash => {
                            attempt += 1;
                            self.scatter_retries_n.fetch_add(1, Ordering::Relaxed);
                            self.retry_counter.inc(1);
                        }
                        Fault::Delay(ms) => {
                            std::thread::sleep(std::time::Duration::from_millis(ms));
                            break;
                        }
                        Fault::None => break,
                    }
                }
            }
            let v = &views_ref[si];
            let t0 = Instant::now();
            let top = QSCRATCH.with(|cell| {
                scatter_one(
                    snap_ref,
                    fence_ref[si] as u32,
                    fence_ref[si + 1] as u32,
                    &v.ds,
                    v.quant.as_ref(),
                    &v.ids,
                    keys_ref,
                    nq,
                    qi,
                    queries,
                    measure,
                    k,
                    quant_pass,
                    &mut cell.borrow_mut(),
                )
            });
            let us = t0.elapsed().as_micros() as u64;
            scatter_hist.record(us);
            ShardAnswer { top, us }
        });
        // Global-id → (shard, local row) for rescoring delta survivors.
        let mut delta_where: FxHashMap<u32, (u32, u32)> = FxHashMap::default();
        if quant_engaged {
            for (si, v) in views.iter().enumerate() {
                for (li, &g) in v.ids.iter().enumerate() {
                    delta_where.insert(g, (si as u32, li as u32));
                }
            }
        }
        // Phase 2 — gather: merge each query's shard lists under the total
        // order (score desc, id asc), drop cross-shard duplicates (same id
        // ⇒ bit-equal score ⇒ adjacent after the sort), truncate; on the
        // quantized tier the merged estimates are the single engine's
        // survivor set, rescored exactly here.
        let (per_shard_ref, dw_ref) = (&per_shard, &delta_where);
        let out = pool::parallel_map(nq, self.workers, |qi| {
            let t0 = Instant::now();
            let mut scatter_us = 0u64;
            let mut all: Vec<(u32, f32)> = Vec::new();
            for si in 0..ns {
                let a = &per_shard_ref[qi * ns + si];
                all.extend_from_slice(&a.top);
                scatter_us += a.us;
            }
            all.sort_unstable_by(|a, b| b.1.total_cmp(&a.1).then(a.0.cmp(&b.0)));
            all.dedup_by(|a, b| a.0 == b.0);
            let merged = if quant_engaged {
                all.truncate(c);
                QSCRATCH.with(|cell| {
                    rescore_survivors(
                        &all, snap_ref, views_ref, dw_ref, queries, qi, measure, k,
                        &mut cell.borrow_mut(),
                    )
                })
            } else {
                all.truncate(k);
                all
            };
            // Approximate per-query service time: this query's summed
            // scatter work plus the merge/rescore — what a sequential
            // engine would have spent (observability only).
            lat_hist.record(scatter_us + t0.elapsed().as_micros() as u64);
            query_count.inc(1);
            let results = merged.len();
            crate::obs::emit_lazy("serve_query", || {
                vec![
                    ("query", Json::from(qi)),
                    ("k", Json::from(k)),
                    ("results", Json::from(results)),
                    ("quant", Json::from(quant_engaged)),
                    ("shards", Json::from(ns)),
                    ("us", Json::from(scatter_us)),
                ]
            });
            merged
        });
        out
    }

    /// Stream one point in: the sequencer allocates its global id, the
    /// owner shard (`id % n_shards` — any deterministic rule works, the
    /// gather order never depends on placement) buffers the row. Triggers
    /// a compaction when the total pending count reaches the configured
    /// limit. Ids are global and survive compaction unchanged, exactly as
    /// for [`QueryEngine::insert`].
    pub fn insert(&self, row: Option<&[f32]>, set: Option<WeightedSet>) -> u32 {
        let (gid, pending) = {
            let mut seq = self.seq.lock().unwrap();
            let gid = *seq as u32;
            let shard = *seq % self.n_shards;
            let mut d = self.deltas[shard].lock().unwrap();
            d.buf.insert(row, set);
            d.ids.push(gid);
            *seq += 1;
            let pending = self.pending_total.fetch_add(1, Ordering::Relaxed) + 1;
            (gid, pending)
        };
        self.delta_pending_gauge.set(pending as u64);
        if self.compact_limit > 0 && pending >= self.compact_limit {
            self.compact();
        }
        gid
    }

    /// Fold every shard's delta into a fresh shared epoch (the snapshot's
    /// configured mode, with the same `full_rebuild_every` promotion
    /// policy as [`QueryEngine::compact_report`]). Returns false when
    /// nothing was pending.
    pub fn compact(&self) -> bool {
        self.compact_report().is_some()
    }

    /// [`ShardedEngine::compact`] returning the work/telemetry report.
    pub fn compact_report(&self) -> Option<CompactionReport> {
        let cfg = {
            let snap = self.snapshot.read().unwrap();
            let c = snap.config();
            (c.compaction, c.full_rebuild_every)
        };
        let mut mode = cfg.0;
        if mode == CompactionMode::Incremental
            && cfg.1 > 0
            && self.incr_since_full.load(Ordering::Relaxed) + 1 >= cfg.1 as u64
        {
            mode = CompactionMode::Full;
        }
        self.compact_with(mode)
    }

    /// Compact with an explicit mode. The shard deltas are reassembled
    /// into one union delta in global-id order — asserting the gapless-id
    /// invariant the sequencer maintains — and rebuilt through the same
    /// code path as the single engine, so the new epoch is bit-identical
    /// to what a [`QueryEngine`] fed the same inserts would have built.
    pub fn compact_with(&self, mode: CompactionMode) -> Option<CompactionReport> {
        let _serial = self.compacting.lock().unwrap();
        let t0 = Instant::now();
        // Capture under the sequencer: gapless view, like the query path.
        let (snap, views) = {
            let _seq = self.seq.lock().unwrap();
            let snap = self.snapshot.read().unwrap().clone();
            let views: Vec<(Dataset, Vec<u32>)> = self
                .deltas
                .iter()
                .map(|m| {
                    let d = m.lock().unwrap();
                    (d.buf.dataset().clone(), d.ids.clone())
                })
                .collect();
            (snap, views)
        };
        let total: usize = views.iter().map(|(_, ids)| ids.len()).sum();
        if total == 0 {
            return None;
        }
        let n_old = snap.len();
        // Reassemble the union delta in global-id order. The sort is over
        // explicit ids, so the result is independent of shard placement.
        let mut order: Vec<(u32, usize, usize)> = Vec::with_capacity(total);
        for (si, (_, ids)) in views.iter().enumerate() {
            for (li, &g) in ids.iter().enumerate() {
                order.push((g, si, li));
            }
        }
        order.sort_unstable_by_key(|&(g, _, _)| g);
        let mut union = DeltaBuffer::new(snap.dataset(), n_old);
        for (i, &(g, si, li)) in order.iter().enumerate() {
            assert_eq!(
                g as usize,
                n_old + i,
                "sharded delta ids must be gapless (insert sequencer invariant)"
            );
            let ds = &views[si].0;
            let row = (ds.dim() > 0).then(|| ds.row(li));
            let set = (!ds.sets.is_empty()).then(|| ds.set(li).clone());
            let id = union.insert(row, set);
            debug_assert_eq!(id, g);
        }
        let union_ds = union.dataset().clone();
        let (next, mut report) = match mode {
            CompactionMode::Full => rebuild_full_from(
                &snap, &union_ds, self.family, self.measure, &self.build, self.workers,
            ),
            CompactionMode::Incremental => {
                rebuild_incremental_from(&snap, &union_ds, self.measure, &self.build, self.workers)
            }
        };
        match mode {
            CompactionMode::Full => {
                self.full_compactions.fetch_add(1, Ordering::Relaxed);
                self.incr_since_full.store(0, Ordering::Relaxed);
            }
            CompactionMode::Incremental => {
                self.incremental_compactions.fetch_add(1, Ordering::Relaxed);
                self.incr_since_full.fetch_add(1, Ordering::Relaxed);
            }
        }
        report.full_compactions = self.full_compactions.load(Ordering::Relaxed);
        report.incremental_compactions = self.incremental_compactions.load(Ordering::Relaxed);
        report.snapshot = next.stats();
        report.seconds = t0.elapsed().as_secs_f64();
        // Swap: retake the sequencer, publish the epoch, trim each shard's
        // absorbed prefix. Inserts that raced in after the capture keep
        // their ids and stay buffered — still gapless above the new len.
        let pending = {
            let _seq = self.seq.lock().unwrap();
            *self.snapshot.write().unwrap() = Arc::new(next);
            for (m, (_, ids)) in self.deltas.iter().zip(views.iter()) {
                let mut d = m.lock().unwrap();
                d.buf.absorb_prefix(ids.len());
                d.ids.drain(..ids.len());
            }
            self.pending_total.fetch_sub(total, Ordering::Relaxed) - total
        };
        let us = (report.seconds * 1e6) as u64;
        crate::obs::registry().histogram("stars_serve_compaction_us").record(us);
        crate::obs::registry().counter("stars_serve_compactions_total").inc(1);
        self.delta_pending_gauge.set(pending as u64);
        self.publish_shard_metrics();
        let (mode_name, delta_points, scored) =
            (report.mode.name(), report.delta_points, report.candidates_scored);
        crate::obs::emit_lazy("compaction", || {
            vec![
                ("mode", Json::from(mode_name)),
                ("delta_points", Json::from(delta_points)),
                ("candidates_scored", Json::from(scored)),
                ("us", Json::from(us)),
            ]
        });
        Some(report)
    }
}

/// One scatter task: the single-engine pipeline restricted to the entry
/// range `[lo, hi)` this shard owns, over the shared snapshot plus the
/// shard's delta slice. Returns the per-shard exact top-k, or — when
/// `quant_pass` is `Some(c)` — the per-shard top-`c` *int8 estimates*
/// (rescoring happens at the gather, where the global survivor set is
/// known).
#[allow(clippy::too_many_arguments)]
fn scatter_one(
    snap: &StarIndex<'_>,
    lo: u32,
    hi: u32,
    delta: &Dataset,
    delta_quant: Option<&QuantDataset>,
    delta_ids: &[u32],
    keys: &[u64],
    nq: usize,
    qi: usize,
    queries: &Dataset,
    measure: ServeMeasure,
    k: usize,
    quant_pass: Option<usize>,
    s: &mut QueryScratch,
) -> Vec<(u32, f32)> {
    let cfg = snap.config();
    let csr = snap.csr();
    let n = snap.len();
    s.visit.begin(n);
    s.entry_visit.begin(n);
    s.cands.clear();
    // Route + expand, exactly as the single engine — except only owned
    // entries expand here. Each distinct probed entry is owned by exactly
    // one shard, so the union over shards of these expansions is the
    // single engine's candidate set. Two-hop neighborhoods cross the fence
    // freely; the fence partitions entry ownership, not reachability.
    for rep in 0..snap.router().reps() {
        let key = keys[rep * nq + qi];
        for &e in snap.router().route(rep, key).iter().take(cfg.probe_entries) {
            if e < lo || e >= hi {
                continue;
            }
            if s.entry_visit.mark(e) {
                if s.visit.mark(e) {
                    s.cands.push(e);
                }
                two_hop_into(csr, e, cfg.min_w, &mut s.visit, &mut s.cands);
            }
        }
    }
    // Quantized first pass: per-shard top-c estimates over owned snapshot
    // candidates plus this shard's delta slice. Estimates are pure per
    // (query, id) — an associative integer dot plus two fixed-order f32
    // multiplies — so cross-shard duplicates carry bit-equal values.
    if let Some(c) = quant_pass {
        let sq = snap.quant().expect("quantized pass requires an SQ8 snapshot table");
        let backend = simd::active();
        s.qcodes.resize(queries.dim(), 0);
        let qscale = quant::quantize_row(queries.row(qi), &mut s.qcodes);
        let qnorm = queries.norm(qi);
        let mut first = TopNeighbors::new(c);
        sq.dot_estimates_with(backend, &s.qcodes, qscale, &s.cands, &mut s.scores);
        for (&cand, &est) in s.cands.iter().zip(s.scores.iter()) {
            let score = match measure {
                ServeMeasure::Cosine => {
                    quant::cosine_estimate(est, qnorm * snap.dataset().norm(cand as usize))
                }
                _ => est,
            };
            first.push(score, cand);
        }
        if !delta.is_empty() {
            let dq = delta_quant.expect("tier decision guarantees a delta quant table");
            s.cands.clear();
            s.cands.extend(0..delta.len() as u32);
            dq.dot_estimates_with(backend, &s.qcodes, qscale, &s.cands, &mut s.scores);
            for (di, &est) in s.scores.iter().enumerate() {
                let score = match measure {
                    ServeMeasure::Cosine => quant::cosine_estimate(est, qnorm * delta.norm(di)),
                    _ => est,
                };
                first.push(score, delta_ids[di]);
            }
        }
        return first.into_sorted();
    }
    // Exact tier: score owned candidates plus the shard's delta slice.
    let mut top = TopNeighbors::new(k);
    measure.score(queries, qi, snap.dataset(), &s.cands, &mut s.batch, &mut s.scores);
    for (&cand, &w) in s.cands.iter().zip(s.scores.iter()) {
        top.push(w, cand);
    }
    if !delta.is_empty() {
        s.cands.clear();
        s.cands.extend(0..delta.len() as u32);
        measure.score(queries, qi, delta, &s.cands, &mut s.batch, &mut s.scores);
        for (di, &w) in s.scores.iter().enumerate() {
            top.push(w, delta_ids[di]);
        }
    }
    top.into_sorted()
}

/// Quantized-tier phase 2 at the gather: `survivors` is the merged global
/// top-`c` estimate list (identical to the single engine's survivor set);
/// rescore each survivor exactly through the tiled kernels — snapshot ids
/// against the shared dataset, delta ids against their owning shard's
/// view — and keep the top `k`. Scores are pure per `(query, row)`, so
/// the per-shard grouping cannot change them.
#[allow(clippy::too_many_arguments)]
fn rescore_survivors(
    survivors: &[(u32, f32)],
    snap: &StarIndex<'_>,
    views: &[ShardView],
    delta_where: &FxHashMap<u32, (u32, u32)>,
    queries: &Dataset,
    qi: usize,
    measure: ServeMeasure,
    k: usize,
    s: &mut QueryScratch,
) -> Vec<(u32, f32)> {
    let n = snap.len();
    let mut top = TopNeighbors::new(k);
    s.cands.clear();
    for &(gid, _) in survivors {
        if (gid as usize) < n {
            s.cands.push(gid);
        }
    }
    measure.score(queries, qi, snap.dataset(), &s.cands, &mut s.batch, &mut s.scores);
    for (&cand, &w) in s.cands.iter().zip(s.scores.iter()) {
        top.push(w, cand);
    }
    for (si, v) in views.iter().enumerate() {
        s.delta_cands.clear();
        let mut gids: Vec<u32> = Vec::new();
        for &(gid, _) in survivors {
            if (gid as usize) >= n {
                let &(vs, li) = delta_where
                    .get(&gid)
                    .expect("delta survivor id missing from the capture's shard views");
                if vs as usize == si {
                    s.delta_cands.push(li);
                    gids.push(gid);
                }
            }
        }
        if s.delta_cands.is_empty() {
            continue;
        }
        measure.score(queries, qi, &v.ds, &s.delta_cands, &mut s.batch, &mut s.scores);
        for (&gid, &w) in gids.iter().zip(s.scores.iter()) {
            top.push(w, gid);
        }
    }
    top.into_sorted()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fence_covers_and_balances() {
        let f = fence_for(10, 3);
        assert_eq!(f, vec![0, 3, 6, 10]);
        assert_eq!(fence_for(0, 4), vec![0, 0, 0, 0, 0]);
        assert_eq!(fence_for(5, 1), vec![0, 5]);
        // More shards than points: trailing shards own empty ranges.
        let f = fence_for(2, 5);
        assert_eq!(f.len(), 6);
        assert_eq!(*f.last().unwrap(), 2);
        for w in f.windows(2) {
            assert!(w[0] <= w[1]);
        }
        // Every point owned exactly once, sizes within one of each other.
        let f = fence_for(1003, 7);
        let sizes: Vec<u64> = f.windows(2).map(|w| w[1] - w[0]).collect();
        assert_eq!(sizes.iter().sum::<u64>(), 1003);
        let (lo, hi) = (sizes.iter().min().unwrap(), sizes.iter().max().unwrap());
        assert!(hi - lo <= 1, "unbalanced fence: {sizes:?}");
    }
}
