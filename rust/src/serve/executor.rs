//! The concurrent batched query executor.
//!
//! [`QueryEngine`] owns the current [`StarIndex`] epoch (behind
//! `RwLock<Arc<_>>`), the write path of streamed inserts — sealed
//! immutable [`SealedSegment`]s behind the active [`DeltaBuffer`] tail —
//! and the query pipeline: sketch → route → two-hop expand → tiled score
//! → top-k merge with the segments and the tail. Batches fan out over
//! [`crate::util::pool`], one task per query; per-query work is
//! independent and results are assembled in query order, so the returned
//! top-k lists are **bit-identical for any worker count** — the read-side
//! mirror of the builder's determinism contract.
//!
//! With `ServeConfig::seal_limit > 0` the tail seals into a
//! [`SealedSegment`] when it fills: the sealed rows are sketched once
//! through the snapshot's cached states and queries *route into* them
//! (collision buckets first, complete coverage) instead of brute-forcing
//! an ever-growing buffer — and because segment coverage is complete,
//! answers stay bit-identical to the unsealed path for any seal boundary
//! (`serve::durable::segment` has the argument). Compaction drains
//! segments and tail together into the next snapshot epoch.
//!
//! When the snapshot carries an SQ8 table (`ServeConfig::quantized`) and
//! the measure is dense (cosine/dot), scoring runs in **two passes**: an
//! int8 estimate of every candidate (snapshot *and* delta — both tables
//! are maintained), a top-`k · rescore_factor` cut, then an exact f32
//! rescore of the survivors through the same tiled kernels as the exact
//! path. Survivor scores — and hence the ranking among them — are
//! bit-identical to the exact path's scores for the same ids; only the
//! *membership* of the survivor set is approximate, which is why the
//! quantized path is recall-gated rather than bit-identity-gated
//! (ARCHITECTURE.md "Quantized scoring tier"). The first pass itself is
//! deterministic across worker counts and SIMD backends: the int8 dot is
//! an associative integer sum, and the estimate applies two f32 multiplies
//! in a fixed order.

use super::delta::DeltaBuffer;
use super::durable::SealedSegment;
use super::index::StarIndex;
use super::CompactionMode;
use crate::ampc::SnapshotStats;
use crate::data::types::{Dataset, WeightedSet};
use crate::graph::two_hop::{two_hop_into, VisitScratch};
use crate::graph::{Csr, Edge};
use crate::lsh::{sketch, LshFamily};
use crate::sim::quant::{self, QuantDataset};
use crate::sim::{
    BatchScratch, CosineSim, DotSim, JaccardSim, MixtureSim, Similarity, WeightedJaccardSim,
};
use crate::stars::{Accumulator, BuildParams, StarsBuilder};
use crate::util::fxhash::FxHashMap;
use crate::util::json::Json;
use crate::util::pool;
use crate::util::simd;
use std::cell::RefCell;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};
use std::time::Instant;

/// The similarity measure a serving stack scores with. A plain enum (not a
/// trait object) so engines stay `Send + Sync` without lifetime plumbing
/// and queries can carry it by value into pool tasks.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum ServeMeasure {
    /// Cosine over dense rows.
    Cosine,
    /// Dot product over dense rows.
    Dot,
    /// Unweighted Jaccard over token sets.
    Jaccard,
    /// Weighted Jaccard over weighted token sets.
    WeightedJaccard,
    /// α·cosine + (1−α)·jaccard over hybrid points.
    Mixture {
        /// Weight on the cosine component.
        alpha: f32,
    },
}

impl ServeMeasure {
    /// Display name for reports.
    pub fn name(&self) -> &'static str {
        match self {
            ServeMeasure::Cosine => "cosine",
            ServeMeasure::Dot => "dot",
            ServeMeasure::Jaccard => "jaccard",
            ServeMeasure::WeightedJaccard => "weighted-jaccard",
            ServeMeasure::Mixture { .. } => "mixture",
        }
    }

    /// Whether the quantized first pass can estimate this measure: dense
    /// row measures only. Set and mixture measures ignore
    /// `ServeConfig::quantized` and stay on the exact single-pass path.
    pub fn supports_quant(&self) -> bool {
        matches!(self, ServeMeasure::Cosine | ServeMeasure::Dot)
    }

    /// The build-side [`Similarity`] equivalent (compaction rebuilds).
    pub fn to_similarity(self) -> Box<dyn Similarity> {
        match self {
            ServeMeasure::Cosine => Box::new(CosineSim),
            ServeMeasure::Dot => Box::new(DotSim),
            ServeMeasure::Jaccard => Box::new(JaccardSim),
            ServeMeasure::WeightedJaccard => Box::new(WeightedJaccardSim),
            ServeMeasure::Mixture { alpha } => Box::new(MixtureSim { alpha }),
        }
    }

    /// Score query `qi` of `queries` against `cands` in `ds` through the
    /// tiled batch kernels (`out[j]` = similarity to `cands[j]`).
    pub(crate) fn score(
        self,
        queries: &Dataset,
        qi: usize,
        ds: &Dataset,
        cands: &[u32],
        batch: &mut BatchScratch,
        out: &mut Vec<f32>,
    ) {
        match self {
            ServeMeasure::Cosine => {
                batch.cosine_row(queries.row(qi), queries.norm(qi), ds, cands, out)
            }
            ServeMeasure::Dot => batch.dot_row(queries.row(qi), ds, cands, out),
            ServeMeasure::Jaccard => batch.jaccard_set(queries.set(qi), ds, cands, out),
            ServeMeasure::WeightedJaccard => {
                batch.weighted_jaccard_set(queries.set(qi), ds, cands, out)
            }
            ServeMeasure::Mixture { alpha } => batch.mixture_row_set(
                alpha,
                queries.row(qi),
                queries.norm(qi),
                queries.set(qi),
                ds,
                cands,
                out,
            ),
        }
    }
}

/// Per-thread query scratch: visited stamps, candidate/score buffers and
/// the tiled-kernel scratch. One per pool thread, reset per query. Shared
/// with the sharded scatter path (`super::sharded`), which runs the same
/// pipeline per shard.
#[derive(Default)]
pub(crate) struct QueryScratch {
    pub(crate) visit: VisitScratch,
    pub(crate) entry_visit: VisitScratch,
    pub(crate) cands: Vec<u32>,
    pub(crate) scores: Vec<f32>,
    pub(crate) batch: BatchScratch,
    /// SQ8 codes of the current query row (quantized first pass).
    pub(crate) qcodes: Vec<i8>,
    /// Delta-local ids of rescore survivors (quantized second pass).
    pub(crate) delta_cands: Vec<u32>,
    /// Visited stamps for sealed-segment candidate routing.
    pub(crate) seg_visit: VisitScratch,
    /// Segment-local candidate buffer (exact path).
    pub(crate) seg_cands: Vec<u32>,
    /// Per-segment rescore survivors (quantized second pass).
    pub(crate) seg_survivors: Vec<Vec<u32>>,
}

thread_local! {
    pub(crate) static QSCRATCH: RefCell<QueryScratch> = RefCell::new(QueryScratch::default());
}

/// Bounded top-k of neighbors under the serving order: higher score wins,
/// equal scores prefer the smaller id — enforced *including at the k-th
/// boundary*. The generic [`crate::util::topk::TopK`] keeps the
/// first-pushed of boundary ties, which would make the retained set depend
/// on candidate order and diverge from the brute-force reference on
/// tie-heavy measures (small-rational Jaccard scores).
pub(crate) struct TopNeighbors {
    k: usize,
    /// Min-heap: the *worst* retained entry (score asc, id desc) at root.
    heap: Vec<(f32, u32)>,
}

impl TopNeighbors {
    pub(crate) fn new(k: usize) -> TopNeighbors {
        TopNeighbors {
            k,
            heap: Vec::with_capacity(k.min(1024)),
        }
    }

    /// True when `a` ranks strictly worse than `b`: lower score, or equal
    /// score and larger id.
    #[inline]
    fn worse(a: (f32, u32), b: (f32, u32)) -> bool {
        match a.0.total_cmp(&b.0) {
            std::cmp::Ordering::Less => true,
            std::cmp::Ordering::Greater => false,
            std::cmp::Ordering::Equal => a.1 > b.1,
        }
    }

    #[inline]
    pub(crate) fn push(&mut self, score: f32, id: u32) {
        if self.k == 0 {
            return;
        }
        if self.heap.len() < self.k {
            self.heap.push((score, id));
            self.sift_up(self.heap.len() - 1);
        } else if Self::worse(self.heap[0], (score, id)) {
            self.heap[0] = (score, id);
            self.sift_down(0);
        }
    }

    fn sift_up(&mut self, mut i: usize) {
        while i > 0 {
            let parent = (i - 1) / 2;
            if Self::worse(self.heap[i], self.heap[parent]) {
                self.heap.swap(i, parent);
                i = parent;
            } else {
                break;
            }
        }
    }

    fn sift_down(&mut self, mut i: usize) {
        let n = self.heap.len();
        loop {
            let (l, r) = (2 * i + 1, 2 * i + 2);
            let mut worst = i;
            if l < n && Self::worse(self.heap[l], self.heap[worst]) {
                worst = l;
            }
            if r < n && Self::worse(self.heap[r], self.heap[worst]) {
                worst = r;
            }
            if worst == i {
                break;
            }
            self.heap.swap(i, worst);
            i = worst;
        }
    }

    /// Extract `(id, score)` best-first: score descending, ties ascending
    /// by id.
    pub(crate) fn into_sorted(mut self) -> Vec<(u32, f32)> {
        self.heap
            .sort_by(|a, b| b.0.total_cmp(&a.0).then(a.1.cmp(&b.1)));
        self.heap.into_iter().map(|(w, c)| (c, w)).collect()
    }
}

/// Answer one query against a consistent (snapshot, segments, tail) view.
/// `segments` are the sealed delta segments in ascending contiguous base
/// order (row `i` of segment `g` is global id `g.base() + i`; the tail
/// starts exactly where the last segment ends). `quant_rescore` overrides
/// the snapshot's configured scoring tier: `Some(rf)` forces the
/// quantized first pass with rescore width `rf` (the admission front
/// door's degraded tier), `None` serves the configured tier.
#[allow(clippy::too_many_arguments)]
fn answer_one(
    snap: &StarIndex<'_>,
    segments: &[Arc<SealedSegment>],
    delta: &Dataset,
    delta_quant: Option<&QuantDataset>,
    delta_base: usize,
    keys: &[u64],
    nq: usize,
    qi: usize,
    queries: &Dataset,
    measure: ServeMeasure,
    k: usize,
    quant_rescore: Option<usize>,
    s: &mut QueryScratch,
) -> Vec<(u32, f32)> {
    let cfg = snap.config();
    let csr = snap.csr();
    let n = snap.len();
    s.visit.begin(n);
    s.entry_visit.begin(n);
    s.cands.clear();
    // Route + expand: entries in (repetition, bucket) order; each distinct
    // entry expands its two-hop star neighborhood exactly once. The order —
    // and therefore the candidate list and every downstream tie — is fully
    // determined by the query, never by scheduling.
    'route: for rep in 0..snap.router().reps() {
        let key = keys[rep * nq + qi];
        for &e in snap.router().route(rep, key).iter().take(cfg.probe_entries) {
            if s.entry_visit.mark(e) {
                if s.visit.mark(e) {
                    s.cands.push(e);
                }
                two_hop_into(csr, e, cfg.min_w, &mut s.visit, &mut s.cands);
            }
            if cfg.max_candidates > 0 && s.cands.len() >= cfg.max_candidates {
                break 'route;
            }
        }
    }
    // Quantized two-pass path: int8 estimates over the whole candidate set
    // (snapshot and delta), then an exact rescore of the top survivors.
    let (want_quant, rescore_factor) = match quant_rescore {
        Some(rf) => (true, rf.max(1)),
        None => (cfg.quantized, cfg.rescore_factor.max(1)),
    };
    if k > 0
        && want_quant
        && measure.supports_quant()
        && (delta.is_empty() || delta_quant.is_some())
        && segments.iter().all(|g| g.quant().is_some())
    {
        if let Some(sq) = snap.quant() {
            let backend = simd::active();
            s.qcodes.resize(queries.dim(), 0);
            let qscale = quant::quantize_row(queries.row(qi), &mut s.qcodes);
            let qnorm = queries.norm(qi);
            // First pass: keep c = k · rescore_factor estimated-best ids
            // under the same (score desc, id asc) order as the exact path.
            let c = k.saturating_mul(rescore_factor);
            let mut first = TopNeighbors::new(c);
            sq.dot_estimates_with(backend, &s.qcodes, qscale, &s.cands, &mut s.scores);
            for (&cand, &est) in s.cands.iter().zip(s.scores.iter()) {
                let score = match measure {
                    ServeMeasure::Cosine => {
                        quant::cosine_estimate(est, qnorm * snap.dataset().norm(cand as usize))
                    }
                    _ => est,
                };
                first.push(score, cand);
            }
            // Sealed segments join the first pass whole: their SQ8 codes
            // were handed over from the tail at seal time (per-row SQ8 has
            // no cross-row state), so every estimate — and hence the
            // survivor set — is bit-identical to the unsealed buffer's.
            for seg in segments {
                let sq8 = seg.quant().expect("checked above");
                s.cands.clear();
                s.cands.extend(0..seg.len() as u32);
                sq8.dot_estimates_with(backend, &s.qcodes, qscale, &s.cands, &mut s.scores);
                for (i, &est) in s.scores.iter().enumerate() {
                    let score = match measure {
                        ServeMeasure::Cosine => {
                            quant::cosine_estimate(est, qnorm * seg.dataset().norm(i))
                        }
                        _ => est,
                    };
                    first.push(score, (seg.base() + i) as u32);
                }
            }
            if !delta.is_empty() {
                let dq = delta_quant.expect("checked above");
                s.cands.clear();
                s.cands.extend(0..delta.len() as u32);
                dq.dot_estimates_with(backend, &s.qcodes, qscale, &s.cands, &mut s.scores);
                for (di, &est) in s.scores.iter().enumerate() {
                    let score = match measure {
                        ServeMeasure::Cosine => {
                            quant::cosine_estimate(est, qnorm * delta.norm(di))
                        }
                        _ => est,
                    };
                    first.push(score, (delta_base + di) as u32);
                }
            }
            // Second pass: exact f32 rescore of the survivors through the
            // same tiled kernels as the exact path — survivor scores are
            // bit-identical to what the exact path would assign, so the
            // final top-k ranking among survivors is exact.
            s.cands.clear();
            s.delta_cands.clear();
            s.seg_survivors.iter_mut().for_each(Vec::clear);
            if s.seg_survivors.len() < segments.len() {
                s.seg_survivors.resize_with(segments.len(), Vec::new);
            }
            for (gid, _) in first.into_sorted() {
                let g = gid as usize;
                if g < n {
                    s.cands.push(gid);
                } else if g >= delta_base {
                    s.delta_cands.push((g - delta_base) as u32);
                } else {
                    // Owning segment: bases are ascending and contiguous.
                    let si = segments.partition_point(|seg| seg.base() + seg.len() <= g);
                    s.seg_survivors[si].push((g - segments[si].base()) as u32);
                }
            }
            let mut top = TopNeighbors::new(k);
            measure.score(queries, qi, snap.dataset(), &s.cands, &mut s.batch, &mut s.scores);
            for (&cand, &w) in s.cands.iter().zip(s.scores.iter()) {
                top.push(w, cand);
            }
            for (si, seg) in segments.iter().enumerate() {
                if s.seg_survivors[si].is_empty() {
                    continue;
                }
                measure.score(
                    queries,
                    qi,
                    seg.dataset(),
                    &s.seg_survivors[si],
                    &mut s.batch,
                    &mut s.scores,
                );
                for (&c, &w) in s.seg_survivors[si].iter().zip(s.scores.iter()) {
                    top.push(w, (seg.base() + c as usize) as u32);
                }
            }
            if !s.delta_cands.is_empty() {
                measure.score(queries, qi, delta, &s.delta_cands, &mut s.batch, &mut s.scores);
                for (&dc, &w) in s.delta_cands.iter().zip(s.scores.iter()) {
                    top.push(w, (delta_base + dc as usize) as u32);
                }
            }
            return top.into_sorted();
        }
    }
    // Score the snapshot candidates through the tiled kernels.
    let mut top = TopNeighbors::new(k);
    measure.score(queries, qi, snap.dataset(), &s.cands, &mut s.batch, &mut s.scores);
    for (&c, &w) in s.cands.iter().zip(s.scores.iter()) {
        top.push(w, c);
    }
    // Sealed segments: route in with the query's own keys — collision
    // buckets first, then the remainder. Coverage is complete (every
    // sealed row scored exactly once), so the merged top-k is identical
    // to brute-forcing these rows in the tail.
    for seg in segments {
        s.seg_cands.clear();
        seg.candidates_into(keys, nq, qi, &mut s.seg_visit, &mut s.seg_cands);
        measure.score(queries, qi, seg.dataset(), &s.seg_cands, &mut s.batch, &mut s.scores);
        for (&c, &w) in s.seg_cands.iter().zip(s.scores.iter()) {
            top.push(w, (seg.base() + c as usize) as u32);
        }
    }
    // Brute-force the active tail (bounded by the seal/compaction limits).
    if !delta.is_empty() {
        s.cands.clear();
        s.cands.extend(0..delta.len() as u32);
        measure.score(queries, qi, delta, &s.cands, &mut s.batch, &mut s.scores);
        for (di, &w) in s.scores.iter().enumerate() {
            top.push(w, (delta_base + di) as u32);
        }
    }
    top.into_sorted()
}

/// Exact top-k by scanning the whole dataset with the same tiled kernels
/// and tie rule as the engine — the recall reference for tests and
/// `servebench`.
pub fn brute_force_topk(
    ds: &Dataset,
    queries: &Dataset,
    measure: ServeMeasure,
    k: usize,
    workers: usize,
) -> Vec<Vec<(u32, f32)>> {
    let ids: Vec<u32> = (0..ds.len() as u32).collect();
    pool::parallel_map(queries.len(), workers, |qi| {
        QSCRATCH.with(|cell| {
            let s = &mut *cell.borrow_mut();
            measure.score(queries, qi, ds, &ids, &mut s.batch, &mut s.scores);
            let mut top = TopNeighbors::new(k);
            for (&c, &w) in ids.iter().zip(s.scores.iter()) {
                top.push(w, c);
            }
            top.into_sorted()
        })
    })
}

/// What one compaction did: the mode it ran in, how much work it scored,
/// and the resulting snapshot's memory telemetry.
#[derive(Clone, Debug)]
pub struct CompactionReport {
    /// Mode the compaction ran in.
    pub mode: CompactionMode,
    /// Delta points folded into the new epoch.
    pub delta_points: usize,
    /// Distinct (repetition, bucket key) pairs the delta landed in —
    /// existing snapshot buckets and fresh keys alike (incremental mode;
    /// 0 for a full rebuild, which re-buckets everything).
    pub affected_buckets: usize,
    /// Pairwise similarity evaluations performed — the cost the O(delta)
    /// path bounds by |delta| · avg bucket size instead of the full
    /// rebuild's corpus-wide rescoring.
    pub candidates_scored: u64,
    /// Raw edges emitted before dedup/degree-capping.
    pub edges_emitted: usize,
    /// Wall-clock seconds for the whole compaction (sketch through swap).
    pub seconds: f64,
    /// Full compactions this engine has run so far, this one included —
    /// with `incremental_compactions`, the mix the periodic full-rebuild
    /// policy ([`crate::serve::ServeConfig::full_rebuild_every`]) is
    /// steering.
    pub full_compactions: u64,
    /// Incremental compactions this engine has run so far, this one
    /// included.
    pub incremental_compactions: u64,
    /// Fault-recovery events absorbed by the compaction's rebuild (task
    /// retries + corruption re-fetches from the build cluster's ledger);
    /// 0 for incremental compactions (no cluster) and clean rebuilds.
    pub fault_retries: u64,
    /// Memory/size telemetry of the new snapshot epoch.
    pub snapshot: SnapshotStats,
}

impl CompactionReport {
    /// JSON object for serving reports and benches.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("mode", Json::from(self.mode.name())),
            ("delta_points", Json::from(self.delta_points)),
            ("affected_buckets", Json::from(self.affected_buckets)),
            ("candidates_scored", Json::from(self.candidates_scored)),
            ("edges_emitted", Json::from(self.edges_emitted)),
            ("seconds", Json::from(self.seconds)),
            ("full_compactions", Json::from(self.full_compactions)),
            (
                "incremental_compactions",
                Json::from(self.incremental_compactions),
            ),
            ("fault_retries", Json::from(self.fault_retries)),
            ("snapshot", self.snapshot.to_json()),
        ])
    }
}

/// The engine's mutable write path: sealed immutable segments (ascending,
/// contiguous global-id ranges) queued behind the active tail. One mutex
/// guards both — queries capture a consistent view, inserts append to the
/// tail, seals move the tail whole into a new segment, compaction drains
/// everything.
struct WritePath {
    segments: Vec<Arc<SealedSegment>>,
    tail: DeltaBuffer,
}

impl WritePath {
    /// Points not yet folded into a snapshot (sealed + tail).
    fn pending(&self) -> usize {
        self.segments.iter().map(|g| g.len()).sum::<usize>() + self.tail.len()
    }
}

/// The online query engine: an epoch-swapped [`StarIndex`] snapshot plus a
/// streaming write path (sealed [`SealedSegment`]s + a [`DeltaBuffer`]
/// tail), serving worker-count-invariant top-k batches.
pub struct QueryEngine<'f> {
    family: &'f dyn LshFamily,
    measure: ServeMeasure,
    build: BuildParams,
    workers: usize,
    compact_limit: usize,
    seal_limit: usize,
    snapshot: RwLock<Arc<StarIndex<'f>>>,
    delta: Mutex<WritePath>,
    /// Serializes compactions so concurrent triggers rebuild once.
    compacting: Mutex<()>,
    /// Full compactions run so far (all mutated under `compacting`; atomics
    /// only so readers can snapshot the mix without the lock).
    full_compactions: AtomicU64,
    /// Incremental compactions run so far.
    incremental_compactions: AtomicU64,
    /// Incremental compactions since the last full rebuild — the input to
    /// the `full_rebuild_every` policy.
    incr_since_full: AtomicU64,
    /// Cached observability handle: delta-buffer depth gauge, updated on
    /// insert and after every compaction swap (registry lookups take a
    /// mutex; inserts should not).
    delta_pending_gauge: crate::obs::Gauge,
}

impl<'f> QueryEngine<'f> {
    /// Engine over a built snapshot. `build` parameterizes compaction
    /// rebuilds (typically the params the snapshot's graph was built with).
    pub fn new(
        index: StarIndex<'f>,
        family: &'f dyn LshFamily,
        measure: ServeMeasure,
        build: BuildParams,
    ) -> QueryEngine<'f> {
        let compact_limit = index.config().compact_limit;
        let seal_limit = index.config().seal_limit;
        let delta = Mutex::new(WritePath {
            segments: Vec::new(),
            tail: DeltaBuffer::new(index.dataset(), index.len()),
        });
        QueryEngine {
            family,
            measure,
            build,
            workers: pool::default_workers(),
            compact_limit,
            seal_limit,
            snapshot: RwLock::new(Arc::new(index)),
            delta,
            compacting: Mutex::new(()),
            full_compactions: AtomicU64::new(0),
            incremental_compactions: AtomicU64::new(0),
            incr_since_full: AtomicU64::new(0),
            delta_pending_gauge: crate::obs::registry().gauge("stars_serve_delta_pending"),
        }
    }

    /// Worker count for query batches and compaction rebuilds.
    pub fn workers(mut self, workers: usize) -> Self {
        self.workers = workers.max(1);
        self
    }

    /// Points in the current snapshot.
    pub fn num_indexed(&self) -> usize {
        self.snapshot.read().unwrap().len()
    }

    /// Points waiting in the write path (sealed segments + active tail).
    pub fn num_pending(&self) -> usize {
        self.delta.lock().unwrap().pending()
    }

    /// Points sealed into immutable segments awaiting compaction, and the
    /// number of segments holding them.
    pub fn num_sealed(&self) -> (usize, usize) {
        let d = self.delta.lock().unwrap();
        (
            d.segments.iter().map(|g| g.len()).sum::<usize>(),
            d.segments.len(),
        )
    }

    /// The write sequencer's high-water mark: the global id the next
    /// [`QueryEngine::insert`] will assign. Strictly monotone across
    /// seals and compactions — the durable layer WAL-logs each record
    /// under this id *before* applying it, and replay uses
    /// `gid < next_gid()` as its already-applied test.
    pub fn next_gid(&self) -> u32 {
        self.delta.lock().unwrap().tail.next_gid()
    }

    /// The current snapshot epoch (for inspection/metrics).
    pub fn snapshot(&self) -> Arc<StarIndex<'f>> {
        self.snapshot.read().unwrap().clone()
    }

    /// Answer a batch of queries: for each query point, its top-`k`
    /// (id, similarity) neighbors over snapshot ∪ delta, scores descending
    /// with ties broken by ascending id. Ids are global: snapshot points
    /// keep their dataset ids, delta points continue the sequence and
    /// survive compaction unchanged.
    ///
    /// ```
    /// use stars::data::synth;
    /// use stars::lsh::SimHash;
    /// use stars::serve::{QueryEngine, ServeConfig, ServeMeasure};
    /// use stars::sim::CosineSim;
    /// use stars::stars::{Algorithm, BuildParams, StarsBuilder};
    ///
    /// let ds = synth::gaussian_mixture(200, 8, 4, 0.1, 7);
    /// let family = SimHash::new(8, 6, 1);
    /// let params = BuildParams::threshold_mode(Algorithm::LshStars)
    ///     .sketches(4)
    ///     .threshold(0.3);
    /// let (_, index) = StarsBuilder::new(&ds)
    ///     .similarity(&CosineSim)
    ///     .hash(&family)
    ///     .params(params.clone())
    ///     .workers(2)
    ///     .build_indexed(ServeConfig::default().route_reps(4));
    /// let engine = QueryEngine::new(index, &family, ServeMeasure::Cosine, params);
    ///
    /// let top = engine.query(&ds.subset(&[0]), 3);
    /// assert_eq!(top[0][0].0, 0); // a point's nearest neighbor is itself
    /// assert!((top[0][0].1 - 1.0).abs() < 1e-5);
    /// ```
    pub fn query(&self, queries: &Dataset, k: usize) -> Vec<Vec<(u32, f32)>> {
        self.query_tier(queries, k, None)
    }

    /// [`QueryEngine::query`] with an explicit scoring-tier override:
    /// `Some(rf)` forces the quantized first pass with rescore width
    /// `c = k · rf` regardless of the snapshot's configured tier — the
    /// admission front door's graceful-degradation lever (a narrower
    /// rescore scores fewer exact rows per query under pressure). `None`
    /// serves the configured tier; callers should check
    /// [`QueryEngine::quant_ready`] first — without an SQ8 table the
    /// override falls back to the exact path.
    pub fn query_tier(
        &self,
        queries: &Dataset,
        k: usize,
        quant_rescore: Option<usize>,
    ) -> Vec<Vec<(u32, f32)>> {
        let nq = queries.len();
        if nq == 0 {
            return Vec::new();
        }
        // Consistent epoch: the snapshot pointer and the write path are
        // read under the delta lock, which seal and compaction also hold
        // while mutating — a batch sees either (old snapshot, full write
        // path) or (new snapshot, drained path), never a point twice or
        // not at all. Sealed segments ride behind `Arc` (O(1) capture);
        // only the active tail is cloned.
        let (snap, segments, delta, delta_quant, delta_base) = {
            let d = self.delta.lock().unwrap();
            (
                self.snapshot.read().unwrap().clone(),
                d.segments.clone(),
                d.tail.dataset().clone(),
                d.tail.quant().cloned(),
                d.tail.base(),
            )
        };
        if snap.dataset().dim() > 0 {
            assert_eq!(queries.dim(), snap.dataset().dim(), "query dimension mismatch");
        }
        let keys = snap.query_keys(queries, self.workers);
        let measure = self.measure;
        // Observability (results never depend on it): per-query latency and
        // rescore width land in the global registry; with `STARS_TRACE` set
        // each query also emits one NDJSON trace event. Handles are resolved
        // once per batch — recording is relaxed atomic adds.
        let lat_hist = crate::obs::registry().histogram("stars_serve_query_latency_us");
        let query_count = crate::obs::registry().counter("stars_serve_queries_total");
        let quant_engaged = measure.supports_quant()
            && (quant_rescore.is_some() || snap.config().quantized)
            && snap.quant().is_some()
            && (delta.is_empty() || delta_quant.is_some())
            && segments.iter().all(|g| g.quant().is_some());
        if quant_engaged && k > 0 {
            let rf = quant_rescore.unwrap_or(snap.config().rescore_factor).max(1);
            crate::obs::registry()
                .histogram("stars_serve_rescore_width")
                .record(k.saturating_mul(rf) as u64);
        }
        pool::parallel_map(nq, self.workers, |qi| {
            let t0 = Instant::now();
            let out = QSCRATCH.with(|cell| {
                let s = &mut *cell.borrow_mut();
                answer_one(
                    &snap,
                    &segments,
                    &delta,
                    delta_quant.as_ref(),
                    delta_base,
                    &keys,
                    nq,
                    qi,
                    queries,
                    measure,
                    k,
                    quant_rescore,
                    s,
                )
            });
            let us = t0.elapsed().as_micros() as u64;
            lat_hist.record(us);
            query_count.inc(1);
            let results = out.len();
            crate::obs::emit_lazy("serve_query", || {
                vec![
                    ("query", Json::from(qi)),
                    ("k", Json::from(k)),
                    ("results", Json::from(results)),
                    ("quant", Json::from(quant_engaged)),
                    ("us", Json::from(us)),
                ]
            });
            out
        })
    }

    /// True when the degraded quantized tier can actually serve: the
    /// current snapshot carries an SQ8 table and the measure has an int8
    /// kernel. The front door only counts a query as degraded when this
    /// holds — otherwise the tier override is a no-op and the query was
    /// served exact.
    pub fn quant_ready(&self) -> bool {
        self.measure.supports_quant() && self.snapshot.read().unwrap().quant().is_some()
    }

    /// Stream one point in (dense row and/or token set, matching the
    /// indexed feature kinds); returns its global id, queryable
    /// immediately. Seals the active tail into an immutable segment at
    /// `ServeConfig::seal_limit` and triggers a compaction when the whole
    /// write path reaches the compaction limit.
    pub fn insert(&self, row: Option<&[f32]>, set: Option<WeightedSet>) -> u32 {
        let (id, should_seal, should_compact, pending) = {
            let mut d = self.delta.lock().unwrap();
            let id = d.tail.insert(row, set);
            let pending = d.pending();
            (
                id,
                self.seal_limit > 0 && d.tail.len() >= self.seal_limit,
                self.compact_limit > 0 && pending >= self.compact_limit,
                pending,
            )
        };
        self.delta_pending_gauge.set(pending as u64);
        if should_compact {
            // Compaction drains segments and tail alike — sealing first
            // would only waste the sketch work.
            self.compact();
        } else if should_seal {
            self.seal_tail();
        }
        id
    }

    /// Seal the active tail into a [`SealedSegment`] behind the queue.
    /// Serialized against compaction by *try*-locking `compacting` — an
    /// insert already past the delta lock must not invert the compaction
    /// path's `compacting → delta` lock order. Losing the race defers the
    /// seal to a later insert (or lets the running compaction absorb the
    /// tail), which is harmless: segment coverage is complete, so seal
    /// timing never changes an answer.
    fn seal_tail(&self) {
        let Ok(_serial) = self.compacting.try_lock() else {
            return;
        };
        let t0 = Instant::now();
        let snap = self.snapshot.read().unwrap().clone();
        let mut d = self.delta.lock().unwrap();
        if self.seal_limit == 0 || d.tail.len() < self.seal_limit {
            return; // another insert sealed first
        }
        let base = d.tail.base();
        let (ds, quant) = d.tail.seal_take();
        // Sketching O(seal_limit) rows holds the delta lock — bounded,
        // and the alternative (sketch outside the lock) would open a
        // window where the rows are in neither tail nor segment.
        let seg = SealedSegment::seal(snap.states(), ds, quant, base, self.workers);
        d.segments.push(Arc::new(seg));
        drop(d);
        crate::obs::registry().counter("stars_serve_seals_total").inc(1);
        crate::obs::registry()
            .histogram("stars_serve_seal_us")
            .record(t0.elapsed().as_micros() as u64);
    }

    /// Fold the delta buffer into a fresh snapshot epoch using the
    /// snapshot's configured [`CompactionMode`] and swap it in. Queries
    /// keep serving from the old epoch throughout; only the final pointer
    /// swap takes the delta lock. Returns false when there was nothing to
    /// compact.
    pub fn compact(&self) -> bool {
        self.compact_report().is_some()
    }

    /// [`QueryEngine::compact`] returning the work/telemetry report
    /// (`None` when the delta was empty).
    ///
    /// This is where the periodic full-rebuild policy engages: with the
    /// snapshot configured for incremental compaction and
    /// `full_rebuild_every = N > 0`, every Nth compaction is promoted to
    /// [`CompactionMode::Full`] — re-drawing bucket leaders and router
    /// entry samples so sustained incremental traffic cannot drift the
    /// index arbitrarily far from a fresh build. Explicit
    /// [`QueryEngine::compact_with`] calls bypass the policy (but still
    /// count toward the mix).
    pub fn compact_report(&self) -> Option<CompactionReport> {
        let cfg = {
            let snap = self.snapshot.read().unwrap();
            let c = snap.config();
            (c.compaction, c.full_rebuild_every)
        };
        let mut mode = cfg.0;
        if mode == CompactionMode::Incremental
            && cfg.1 > 0
            && self.incr_since_full.load(Ordering::Relaxed) + 1 >= cfg.1 as u64
        {
            mode = CompactionMode::Full;
        }
        self.compact_with(mode)
    }

    /// The engine's compaction mix so far: `(full, incremental)` counts.
    pub fn compaction_mix(&self) -> (u64, u64) {
        (
            self.full_compactions.load(Ordering::Relaxed),
            self.incremental_compactions.load(Ordering::Relaxed),
        )
    }

    /// Compact with an explicit mode, overriding the snapshot's configured
    /// one (benches compare the two on the same engine).
    ///
    /// `Full` rebuilds the star graph over snapshot ∪ delta from scratch —
    /// O(n) however small the delta. `Incremental` sketches *only* the
    /// delta through the snapshot's cached per-repetition states, routes
    /// the keys through the existing bucket tables, scores each delta point
    /// against its buckets' entry points (plus delta points sharing the
    /// bucket), folds the thresholded edges into an accumulator re-opened
    /// from the snapshot CSR, and extends the routing tables in place —
    /// O(|delta| · avg bucket size).
    ///
    /// **Equivalence.** The two modes produce snapshots with bit-identical
    /// CSR edges and query answers (`tests/serve_integration.rs`) whenever
    /// the rebuild's randomized machinery would not have engaged: every
    /// affected bucket is all-pairs-scored (non-Stars algorithm, or
    /// |bucket| ≤ 2·leaders), no bucket exceeds `max_bucket`, the router
    /// retains every bucket member (`route_leaders` ≥ max bucket size),
    /// `route_reps` ≥ the build's repetition count, edge weights are
    /// tie-free, and the measure's kernels are orientation-symmetric
    /// (cosine/dot/jaccard/mixture exactly; weighted-jaccard to the last
    /// ulp). Outside those conditions incremental compaction still yields a
    /// valid two-hop searchable graph — delta points connect through the
    /// routed entry points, the serving analogue of bucket leaders — it
    /// just stops being the rebuild's bit-exact twin (leader re-draws are
    /// the price of not rescoring the corpus).
    pub fn compact_with(&self, mode: CompactionMode) -> Option<CompactionReport> {
        let _serial = self.compacting.lock().unwrap();
        let t0 = Instant::now();
        let (snap, segs, tail_ds, prefix) = {
            let d = self.delta.lock().unwrap();
            if d.segments.is_empty() && d.tail.is_empty() {
                return None;
            }
            (
                self.snapshot.read().unwrap().clone(),
                d.segments.clone(),
                d.tail.dataset().clone(),
                d.tail.len(),
            )
        };
        // Sealed segments re-enter compaction as plain delta rows,
        // concatenated in base order ahead of the captured tail — exactly
        // the global-id order the rows were inserted in, so the rebuild
        // sees the same merged dataset it would have without sealing. The
        // empty tail is skipped rather than concatenated: an empty
        // hybrid-template tail has no sets and would trip concat's
        // feature-kind check.
        let delta_ds = {
            let mut acc: Option<Dataset> = None;
            for g in &segs {
                acc = Some(match acc {
                    Some(a) => a.concat(g.dataset()),
                    None => g.dataset().clone(),
                });
            }
            match (acc, prefix) {
                (Some(a), 0) => a,
                (Some(a), _) => a.concat(&tail_ds),
                (None, _) => tail_ds,
            }
        };
        let (next, mut report) = match mode {
            CompactionMode::Full => self.rebuild_full(&snap, &delta_ds),
            CompactionMode::Incremental => self.rebuild_incremental(&snap, &delta_ds),
        };
        // Mix bookkeeping (consistent under the `compacting` lock): a full
        // rebuild resets the policy counter, an incremental advances it.
        match mode {
            CompactionMode::Full => {
                self.full_compactions.fetch_add(1, Ordering::Relaxed);
                self.incr_since_full.store(0, Ordering::Relaxed);
            }
            CompactionMode::Incremental => {
                self.incremental_compactions.fetch_add(1, Ordering::Relaxed);
                self.incr_since_full.fetch_add(1, Ordering::Relaxed);
            }
        }
        report.full_compactions = self.full_compactions.load(Ordering::Relaxed);
        report.incremental_compactions = self.incremental_compactions.load(Ordering::Relaxed);
        report.snapshot = next.stats();
        report.seconds = t0.elapsed().as_secs_f64();
        // Swap the epoch and drain the absorbed write path atomically
        // w.r.t. readers (who take the delta lock to capture their view).
        // Seals are serialized under `compacting`, which we hold — the
        // queued segments are exactly the captured ones; only the tail
        // can have grown.
        let pending = {
            let mut d = self.delta.lock().unwrap();
            *self.snapshot.write().unwrap() = Arc::new(next);
            debug_assert_eq!(d.segments.len(), segs.len(), "segment sealed during compaction");
            d.segments.clear();
            d.tail.absorb_prefix(prefix);
            d.pending()
        };
        // Observability: compaction time + the post-swap delta depth.
        let us = (report.seconds * 1e6) as u64;
        crate::obs::registry().histogram("stars_serve_compaction_us").record(us);
        crate::obs::registry().counter("stars_serve_compactions_total").inc(1);
        self.delta_pending_gauge.set(pending as u64);
        let (mode_name, delta_points, scored) =
            (report.mode.name(), report.delta_points, report.candidates_scored);
        crate::obs::emit_lazy("compaction", || {
            vec![
                ("mode", Json::from(mode_name)),
                ("delta_points", Json::from(delta_points)),
                ("candidates_scored", Json::from(scored)),
                ("us", Json::from(us)),
            ]
        });
        Some(report)
    }

    /// O(n) compaction: rebuild the star graph over snapshot ∪ delta with
    /// the engine's build parameters (sharing the build's bucket keys with
    /// the snapshot export) and rebuild the routing tables from scratch.
    fn rebuild_full(
        &self,
        snap: &StarIndex<'f>,
        delta: &Dataset,
    ) -> (StarIndex<'f>, CompactionReport) {
        rebuild_full_from(snap, delta, self.family, self.measure, &self.build, self.workers)
    }

    /// O(delta) compaction: sketch → route → score only the delta, fold
    /// into the snapshot's graph, extend the router, share the states.
    fn rebuild_incremental(
        &self,
        snap: &StarIndex<'f>,
        delta: &Dataset,
    ) -> (StarIndex<'f>, CompactionReport) {
        rebuild_incremental_from(snap, delta, self.measure, &self.build, self.workers)
    }
}

/// The full-rebuild compaction as a free function, shared between
/// [`QueryEngine`] and [`super::sharded::ShardedEngine`]: both fold their
/// delta (for the sharded engine, the per-shard deltas reassembled in
/// global-id order) through the *same* code path, which is what makes
/// compacted epochs — and hence every post-compaction answer —
/// bit-identical across shard counts.
pub(crate) fn rebuild_full_from<'f>(
    snap: &StarIndex<'f>,
    delta: &Dataset,
    family: &'f dyn LshFamily,
    measure: ServeMeasure,
    build: &BuildParams,
    workers: usize,
) -> (StarIndex<'f>, CompactionReport) {
    let merged = snap.dataset().concat(delta);
    let cfg = snap.config().clone();
    let sim = measure.to_similarity();
    let (out, keys) = StarsBuilder::new(&merged)
        .similarity(sim.as_ref())
        .hash(family)
        .params(build.clone())
        .workers(workers)
        .build_with_keys(cfg.route_reps.max(1));
    let next = StarIndex::build_from_keys(merged, family, &out.graph, cfg, workers, keys);
    let report = CompactionReport {
        mode: CompactionMode::Full,
        delta_points: delta.len(),
        affected_buckets: 0,
        candidates_scored: out.report.comparisons,
        edges_emitted: out.report.edges_emitted as usize,
        seconds: 0.0,
        full_compactions: 0,
        incremental_compactions: 0,
        fault_retries: out.report.faults.task_retries + out.report.faults.corruption_retries,
        snapshot: SnapshotStats::default(),
    };
    (next, report)
}

/// The incremental compaction as a free function (see
/// [`rebuild_full_from`] for why it is shared).
pub(crate) fn rebuild_incremental_from<'f>(
    snap: &StarIndex<'f>,
    delta: &Dataset,
    measure: ServeMeasure,
    build: &BuildParams,
    workers: usize,
) -> (StarIndex<'f>, CompactionReport) {
    let n_old = snap.len();
    let nd = delta.len();
    let merged = snap.dataset().concat(delta);
    let cfg = snap.config().clone();

    // 1. Sketch only the delta range of the merged dataset through the
    //    snapshot's cached per-repetition states (bit-identical keys by
    //    the state-purity contract — no re-prepare, no corpus pass).
    let delta_keys: Vec<Vec<u64>> = snap
        .states()
        .iter()
        .map(|s| sketch::state_keys_range_par(s.as_ref(), &merged, n_old, nd, workers))
        .collect();

    // 2. Find the affected buckets: group delta points by bucket key
    //    per repetition (sorted key order — the task list, and hence
    //    every downstream edge vector, is identical for any worker
    //    count) and look up each bucket's entry points.
    struct BucketTask<'s> {
        /// Snapshot entry points of the bucket (empty for a new key).
        entries: &'s [u32],
        /// Delta members that routed into the bucket, ids ascending.
        members: Vec<u32>,
    }
    let mut tasks: Vec<BucketTask<'_>> = Vec::new();
    let mut affected = 0usize;
    for (rep, keys) in delta_keys.iter().enumerate() {
        let mut groups: FxHashMap<u64, Vec<u32>> = FxHashMap::default();
        for (i, &k) in keys.iter().enumerate() {
            groups.entry(k).or_default().push((n_old + i) as u32);
        }
        let mut ordered: Vec<(u64, Vec<u32>)> = groups.into_iter().collect();
        ordered.sort_unstable_by_key(|(k, _)| *k);
        for (key, members) in ordered {
            let entries = snap.router().route(rep, key);
            affected += 1;
            if entries.len() + members.len() >= 2 {
                tasks.push(BucketTask { entries, members });
            }
        }
    }

    // 3. Score each delta member against its bucket's routed snapshot
    //    entries plus the bucket's later delta members, through the
    //    tiled kernels; keep pairs at or above the build threshold.
    //    The delta point sits on the leader side, which is weight-exact
    //    versus the rebuild's member-side orientation for every
    //    orientation-symmetric measure (see compact_with docs).
    let threshold = build.threshold;
    let merged_ref = &merged;
    let task_refs = &tasks;
    let scored = AtomicU64::new(0);
    let batches: Vec<Vec<Edge>> = pool::parallel_map(tasks.len(), workers, |ti| {
        QSCRATCH.with(|cell| {
            let s = &mut *cell.borrow_mut();
            let t = &task_refs[ti];
            let mut edges = Vec::new();
            let mut cands: Vec<u32> = Vec::with_capacity(t.entries.len() + t.members.len());
            for (j, &x) in t.members.iter().enumerate() {
                cands.clear();
                cands.extend_from_slice(t.entries);
                cands.extend_from_slice(&t.members[j + 1..]);
                if cands.is_empty() {
                    continue;
                }
                measure.score(
                    merged_ref,
                    x as usize,
                    merged_ref,
                    &cands,
                    &mut s.batch,
                    &mut s.scores,
                );
                scored.fetch_add(cands.len() as u64, Ordering::Relaxed);
                for (&c, &w) in cands.iter().zip(s.scores.iter()) {
                    if w >= threshold {
                        edges.push(Edge::new(x, c, w));
                    }
                }
            }
            edges
        })
    });
    let emitted: usize = batches.iter().map(Vec::len).sum();

    // 4. Fold the delta edges into the snapshot graph through a
    //    re-opened accumulator and finalize the next epoch's graph.
    let mut acc = Accumulator::reopen_from_csr(snap.csr(), merged.len(), build.degree_cap, workers);
    acc.add_wave(batches);
    let graph = acc.finalize();

    // 5. Extend the routing tables with the delta keys and assemble
    //    the next snapshot; sketch states carry over untouched. A
    //    quantized snapshot extends its SQ8 table over just the delta
    //    range — per-row codes are position-independent, so the result
    //    is identical to quantizing the merged dataset from scratch.
    let router = snap
        .router()
        .extended(&delta_keys, n_old as u32, cfg.route_leaders);
    let quant = snap.quant().map(|q| Arc::new(q.extended(&merged, n_old)));
    let next = StarIndex::from_parts(
        merged,
        Csr::new(&graph),
        snap.states().to_vec(),
        router,
        quant,
        cfg,
    );
    let report = CompactionReport {
        mode: CompactionMode::Incremental,
        delta_points: nd,
        affected_buckets: affected,
        candidates_scored: scored.into_inner(),
        edges_emitted: emitted,
        seconds: 0.0,
        full_compactions: 0,
        incremental_compactions: 0,
        fault_retries: 0,
        snapshot: SnapshotStats::default(),
    };
    (next, report)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::lsh::SimHash;
    use crate::serve::ServeConfig;
    use crate::stars::Algorithm;

    fn build_engine(h: &SimHash) -> QueryEngine<'_> {
        let ds = synth::gaussian_mixture(800, 16, 8, 0.08, 47);
        let params = BuildParams::threshold_mode(Algorithm::LshStars)
            .sketches(8)
            .threshold(0.4);
        let out = StarsBuilder::new(&ds)
            .similarity(&CosineSim)
            .hash(h)
            .params(params.clone())
            .workers(2)
            .build();
        let cfg = ServeConfig::default().route_reps(8).compact_limit(0);
        let index = StarIndex::build(ds, h, &out.graph, cfg);
        QueryEngine::new(index, h, ServeMeasure::Cosine, params).workers(2)
    }

    #[test]
    fn self_query_returns_self_first() {
        let h = SimHash::new(16, 8, 3);
        let engine = build_engine(&h);
        let snap = engine.snapshot();
        let queries = snap.dataset().subset(&[5, 123, 700]);
        let res = engine.query(&queries, 5);
        assert_eq!(res.len(), 3);
        for (qi, &p) in [5u32, 123, 700].iter().enumerate() {
            assert!(!res[qi].is_empty(), "query {qi} found nothing");
            assert_eq!(res[qi][0].0, p, "self not top-1 for {p}");
            assert!((res[qi][0].1 - 1.0).abs() < 1e-5);
            // Scores descending.
            for w in res[qi].windows(2) {
                assert!(w[0].1 >= w[1].1);
            }
        }
    }

    #[test]
    fn brute_force_reference_is_exact() {
        let ds = synth::gaussian_mixture(200, 8, 4, 0.1, 9);
        let queries = ds.subset(&[0, 50]);
        let res = brute_force_topk(&ds, &queries, ServeMeasure::Cosine, 3, 2);
        assert_eq!(res.len(), 2);
        // Exhaustive check against a plain scan for query 0 — CosineSim
        // reads the same precomputed norms the kernels do, so scores are
        // bit-identical and the order must match exactly.
        let mut want: Vec<(u32, f32)> = (0..200u32)
            .map(|j| (j, CosineSim.sim(&ds, 0, j as usize)))
            .collect();
        want.sort_by(|a, b| b.1.total_cmp(&a.1).then(a.0.cmp(&b.0)));
        let got: Vec<u32> = res[0].iter().map(|&(id, _)| id).collect();
        let expect: Vec<u32> = want[..3].iter().map(|&(id, _)| id).collect();
        assert_eq!(got, expect);
    }

    #[test]
    fn top_neighbors_breaks_boundary_ties_by_id() {
        // More tied candidates than k, pushed in scrambled order: the
        // retained set must be the lowest ids, independent of push order.
        let mut top = TopNeighbors::new(2);
        for id in [5u32, 2, 9, 1, 7] {
            top.push(1.0, id);
        }
        assert_eq!(top.into_sorted(), vec![(1, 1.0), (2, 1.0)]);
        // Mixed scores: score dominates, ids only break exact ties.
        let mut top = TopNeighbors::new(3);
        for (w, id) in [(0.5f32, 9u32), (0.9, 4), (0.5, 3), (0.7, 8), (0.5, 6)] {
            top.push(w, id);
        }
        assert_eq!(top.into_sorted(), vec![(4, 0.9), (8, 0.7), (3, 0.5)]);
        // k = 0 keeps nothing.
        let mut top = TopNeighbors::new(0);
        top.push(1.0, 1);
        assert!(top.into_sorted().is_empty());
    }

    #[test]
    fn incremental_compaction_absorbs_the_delta() {
        let h = SimHash::new(16, 8, 3);
        let engine = build_engine(&h);
        let snap = engine.snapshot();
        let n = snap.len();
        engine.insert(Some(snap.dataset().row(7)), None);
        let rep = engine.compact_report().expect("delta pending");
        assert_eq!(rep.mode, CompactionMode::Incremental);
        assert_eq!(rep.delta_points, 1);
        assert!(rep.affected_buckets > 0, "duplicate routed nowhere");
        assert!(rep.candidates_scored > 0);
        assert!(rep.edges_emitted > 0);
        assert_eq!(rep.snapshot.points, n + 1);
        assert!(rep.snapshot.router_entries > 0);
        assert_eq!(engine.num_indexed(), n + 1);
        assert_eq!(engine.num_pending(), 0);
        assert!(engine.compact_report().is_none(), "nothing left to compact");
        // The absorbed duplicate is reachable through the new epoch's graph
        // (no delta buffer backs it up any more).
        let res = engine.query(&snap.dataset().subset(&[7]), 5);
        assert_eq!(res[0][0].0, 7);
        assert!(
            res[0].iter().any(|&(id, _)| id == n as u32),
            "absorbed duplicate not reachable: {:?}",
            res[0]
        );
    }

    #[test]
    fn full_rebuild_every_forces_periodic_full() {
        let h = SimHash::new(16, 8, 3);
        let ds = synth::gaussian_mixture(400, 16, 4, 0.1, 7);
        let row: Vec<f32> = ds.row(0).to_vec();
        let params = BuildParams::threshold_mode(Algorithm::LshStars)
            .sketches(4)
            .threshold(0.4);
        let out = StarsBuilder::new(&ds)
            .similarity(&CosineSim)
            .hash(&h)
            .params(params.clone())
            .workers(2)
            .build();
        let cfg = crate::serve::ServeConfig::default()
            .route_reps(4)
            .compact_limit(0)
            .full_rebuild_every(2);
        let index = StarIndex::build(ds, &h, &out.graph, cfg);
        let engine = QueryEngine::new(index, &h, ServeMeasure::Cosine, params).workers(2);
        let mut modes = Vec::new();
        for _ in 0..4 {
            engine.insert(Some(&row), None);
            let rep = engine.compact_report().expect("delta pending");
            modes.push(rep.mode);
        }
        // Every 2nd compaction is promoted to a full rebuild.
        assert_eq!(
            modes,
            vec![
                CompactionMode::Incremental,
                CompactionMode::Full,
                CompactionMode::Incremental,
                CompactionMode::Full,
            ]
        );
        assert_eq!(engine.compaction_mix(), (2, 2));
        // One more round: the mix rides along in the report.
        engine.insert(Some(&row), None);
        let rep = engine.compact_report().unwrap();
        assert_eq!(rep.mode, CompactionMode::Incremental);
        assert_eq!(rep.full_compactions, 2);
        assert_eq!(rep.incremental_compactions, 3);
        let j = rep.to_json().to_string();
        assert!(j.contains("incremental_compactions"));
    }

    #[test]
    fn empty_batch_and_k_zero() {
        let h = SimHash::new(16, 8, 3);
        let engine = build_engine(&h);
        let snap = engine.snapshot();
        let empty = snap.dataset().subset(&[]);
        assert!(engine.query(&empty, 5).is_empty());
        let queries = snap.dataset().subset(&[1]);
        let res = engine.query(&queries, 0);
        assert_eq!(res.len(), 1);
        assert!(res[0].is_empty());
    }

    #[test]
    fn quantized_with_wide_rescore_matches_exact_engine() {
        // With rescore_factor large enough that every candidate survives
        // the first pass, the quantized path degenerates to "exact rescore
        // of everything" — results must be *bitwise* identical to the
        // exact engine, survivors and scores alike.
        let h = SimHash::new(16, 8, 3);
        let ds = synth::gaussian_mixture(800, 16, 8, 0.08, 47);
        let params = BuildParams::threshold_mode(Algorithm::LshStars)
            .sketches(8)
            .threshold(0.4);
        let out = StarsBuilder::new(&ds)
            .similarity(&CosineSim)
            .hash(&h)
            .params(params.clone())
            .workers(2)
            .build();
        let cfg = ServeConfig::default().route_reps(8).compact_limit(0);
        let exact = QueryEngine::new(
            StarIndex::build(ds.clone(), &h, &out.graph, cfg.clone()),
            &h,
            ServeMeasure::Cosine,
            params.clone(),
        )
        .workers(2);
        let quant = QueryEngine::new(
            StarIndex::build(ds.clone(), &h, &out.graph, cfg.quantized(10_000)),
            &h,
            ServeMeasure::Cosine,
            params,
        )
        .workers(2);
        assert!(quant.snapshot().quant().is_some());
        assert!(exact.snapshot().quant().is_none());
        let queries = ds.subset(&[5, 123, 700]);
        let want = exact.query(&queries, 5);
        let got = quant.query(&queries, 5);
        for (w, g) in want.iter().zip(got.iter()) {
            assert_eq!(w.len(), g.len());
            for (&(wid, ws), &(gid, gs)) in w.iter().zip(g.iter()) {
                assert_eq!(wid, gid, "survivor sets diverged");
                assert_eq!(ws.to_bits(), gs.to_bits(), "rescore not exact");
            }
        }
    }

    #[test]
    fn quantized_engine_serves_delta_and_survives_compaction() {
        let h = SimHash::new(16, 8, 3);
        let ds = synth::gaussian_mixture(800, 16, 8, 0.08, 47);
        let params = BuildParams::threshold_mode(Algorithm::LshStars)
            .sketches(8)
            .threshold(0.4);
        let out = StarsBuilder::new(&ds)
            .similarity(&CosineSim)
            .hash(&h)
            .params(params.clone())
            .workers(2)
            .build();
        let cfg = ServeConfig::default()
            .route_reps(8)
            .compact_limit(0)
            .quantized(8);
        let index = StarIndex::build(ds, &h, &out.graph, cfg);
        let engine = QueryEngine::new(index, &h, ServeMeasure::Cosine, params).workers(2);
        let snap = engine.snapshot();
        let n = snap.len();
        // A buffered duplicate of point 7 joins the int8 first pass via the
        // delta's lockstep quant table and must surface next to point 7
        // (identical rows tie at 1.0; ids ascending puts 7 first).
        engine.insert(Some(snap.dataset().row(7)), None);
        let res = engine.query(&snap.dataset().subset(&[7]), 5);
        assert_eq!(res[0][0].0, 7);
        assert!(
            res[0].iter().any(|&(id, _)| id == n as u32),
            "buffered duplicate missed the quantized first pass: {:?}",
            res[0]
        );
        // Incremental compaction extends the SQ8 table over the delta range
        // and reports the quantized telemetry.
        let rep = engine.compact_report().expect("delta pending");
        assert_eq!(rep.mode, CompactionMode::Incremental);
        assert_eq!(rep.snapshot.points, n + 1);
        assert!(rep.snapshot.quantized);
        assert_eq!(rep.snapshot.rescore_factor, 8);
        assert_eq!(rep.snapshot.bytes_per_row, 16 + 4);
        let next = engine.snapshot();
        assert_eq!(next.quant().expect("quant table dropped").len(), n + 1);
        // Still answerable after the swap.
        let res = engine.query(&next.dataset().subset(&[7]), 5);
        assert_eq!(res[0][0].0, 7);
        assert!(res[0].iter().any(|&(id, _)| id == n as u32));
    }

    #[test]
    fn sealed_segments_serve_bit_identical_to_the_brute_forced_tail() {
        // Two engines over the same snapshot, one sealing every 2 inserts,
        // one never sealing: every answer must match bitwise, before and
        // after compaction — the exactness lemma the durable write path
        // rests on (serve::durable::segment module docs).
        let h = SimHash::new(16, 8, 3);
        let ds = synth::gaussian_mixture(400, 16, 8, 0.08, 47);
        let params = BuildParams::threshold_mode(Algorithm::LshStars)
            .sketches(8)
            .threshold(0.4);
        let out = StarsBuilder::new(&ds)
            .similarity(&CosineSim)
            .hash(&h)
            .params(params.clone())
            .workers(2)
            .build();
        for quantized in [false, true] {
            let mut cfg = ServeConfig::default().route_reps(8).compact_limit(0);
            if quantized {
                cfg = cfg.quantized(4);
            }
            let plain = QueryEngine::new(
                StarIndex::build(ds.clone(), &h, &out.graph, cfg.clone()),
                &h,
                ServeMeasure::Cosine,
                params.clone(),
            )
            .workers(2);
            let sealed = QueryEngine::new(
                StarIndex::build(ds.clone(), &h, &out.graph, cfg.seal_limit(2)),
                &h,
                ServeMeasure::Cosine,
                params.clone(),
            )
            .workers(2);
            for i in 0..7 {
                let row: Vec<f32> = ds.row(i * 31).to_vec();
                assert_eq!(
                    plain.insert(Some(&row), None),
                    sealed.insert(Some(&row), None)
                );
            }
            assert_eq!(plain.num_pending(), 7);
            assert_eq!(sealed.num_pending(), 7);
            assert_eq!(sealed.num_sealed(), (6, 3), "7 inserts at seal_limit 2");
            assert_eq!(plain.num_sealed(), (0, 0));
            assert_eq!(sealed.next_gid(), plain.next_gid());
            let queries = ds.subset(&[5, 123, 399]);
            let check = |tag: &str| {
                let want = plain.query(&queries, 6);
                let got = sealed.query(&queries, 6);
                for (w, g) in want.iter().zip(got.iter()) {
                    assert_eq!(w.len(), g.len(), "{tag} (quantized={quantized})");
                    for (&(wid, ws), &(gid, gs)) in w.iter().zip(g.iter()) {
                        assert_eq!(wid, gid, "{tag}: ids diverged (quantized={quantized})");
                        assert_eq!(ws.to_bits(), gs.to_bits(), "{tag}: scores diverged");
                    }
                }
            };
            check("pre-compaction");
            // Compaction drains segments and tail into the same epoch a
            // never-sealing engine reaches.
            assert!(sealed.compact());
            assert!(plain.compact());
            assert_eq!(sealed.num_pending(), 0);
            assert_eq!(sealed.num_sealed(), (0, 0));
            assert_eq!(sealed.num_indexed(), plain.num_indexed());
            check("post-compaction");
        }
    }
}
