//! `stars::serve` — an online two-hop ANN query engine over the star graph.
//!
//! The paper builds the star graph as a *substrate* for nearest-neighbor
//! workloads: by Definition 2.4, the approximate nearest neighbors of a
//! point live inside its two-hop neighborhood. Everything up to this module
//! only *builds* that substrate offline; `serve` turns the built artifact
//! into a read path that answers top-k queries directly instead of
//! re-scanning the dataset:
//!
//! 1. **Sketch** — query batches run through the exact per-repetition
//!    [`crate::lsh::SketchState`]s the builder used (SimHash's tiled
//!    multi-plane kernel, the per-token CWS/MinHash tables), prepared once
//!    per snapshot and chunked over the worker pool.
//! 2. **Route** — each query's bucket key, per repetition, is looked up in
//!    the [`Router`]: the snapshot-time table mapping every bucket key to a
//!    bounded set of *entry points* (the serving analogue of the builder's
//!    per-bucket leaders).
//! 3. **Expand** — entry points fan out through their two-hop star
//!    neighborhoods ([`crate::graph::two_hop::two_hop_into`], stamp-based
//!    and allocation-free on the hot path) into a deduplicated candidate
//!    list.
//! 4. **Score** — the query row/set is scored against the candidate tile
//!    with the same blocked kernels the builder scores buckets with
//!    ([`crate::sim::batch`]), and the top k survive.
//!
//! Writes stream through a [`DeltaBuffer`]: inserted points are scored
//! brute-force alongside every query (the delta is bounded) until a
//! compaction folds them into a fresh [`StarIndex`] snapshot, atomically
//! swapped in via `Arc` — the epoch pattern; readers never block on
//! writers.
//!
//! The write path is durable when serving runs with a state directory
//! ([`durable`]): every insert is WAL-logged before it is applied, the
//! active tail seals into immutable routed [`SealedSegment`]s at
//! [`ServeConfig::seal_limit`], and compaction checkpoints publish
//! crash-consistent `snapshot-{N}.sss` files — so a restart recovers the
//! exact serving state (newest valid snapshot + WAL-suffix replay)
//! instead of rebuilding, with answers bit-identical to an uncrashed
//! process (see [`durable`] for the contract and its conditions).
//!
//! **Compaction** comes in two flavors ([`CompactionMode`], a
//! [`ServeConfig`] knob). `Full` rebuilds the star graph over
//! snapshot ∪ delta from scratch — O(n) per compaction, the original demo
//! behavior. `Incremental` (the default) costs O(|delta| · avg bucket
//! size): delta points are sketched through the snapshot's *cached* states,
//! routed through the existing bucket-key tables, scored only against their
//! buckets' entry points (plus delta points sharing a bucket), and the
//! resulting edges fold into an accumulator re-opened from the snapshot CSR
//! ([`crate::stars::Accumulator::reopen_from_csr`]) before the epoch swap —
//! so sustained insert traffic pays for the work that changed, not the
//! corpus (see `QueryEngine::compact_with` for the exactness conditions
//! under which the two modes produce bit-identical snapshots). Because
//! incremental compaction never re-draws leaders or router samples, a
//! long-lived index drifts from what a fresh build would produce;
//! [`ServeConfig::full_rebuild_every`] bounds the drift by forcing one
//! `Full` per N compactions, and [`executor::CompactionReport`] reports the
//! running full/incremental mix.
//!
//! **Quantized scoring tier** ([`ServeConfig::quantized`]): dense-measure
//! snapshots can carry an SQ8 side table ([`crate::sim::QuantDataset`],
//! `d + 4` bytes per row instead of `4·d`) and score the two-hop candidate
//! set in two passes — an int8 estimate over every candidate, then an
//! exact f32 rescore of the top `k · rescore_factor` survivors with the
//! same tiled kernels as the exact path, so the final ranking *among
//! survivors* is exact. This is the repo's first documented parity
//! relaxation: quantized results are gated on recall against the f32 path
//! (≥ 0.98 · recall@10 on the test recipes), not bit-identity — but the
//! quantized path is itself deterministic across worker counts and SIMD
//! backends (integer first pass; see ARCHITECTURE.md "Quantized scoring
//! tier").
//!
//! **Determinism contract:** like the builder, [`QueryEngine::query`]
//! results are bit-identical for every worker count (per-query work is
//! independent and results are assembled in query order; ties break by
//! score-descending then id-ascending). Asserted by
//! `tests/serve_integration.rs`.
//!
//! **Shard invariance** extends that contract across machines: a
//! [`ShardedIndex`] fence-partitions one snapshot's routing-entry
//! ownership and [`ShardedEngine`] scatter-gathers queries across the
//! shards — with the merged top-k **bit-identical to the single-shard
//! engine for any shard count and any worker count** (requires
//! `max_candidates = 0`; see [`sharded`] for the fence layout, the
//! exactness argument, and the per-shard delta/compaction story, and
//! `tests/shard_parity.rs` for the battery that pins it).

pub mod admission;
pub mod delta;
pub mod durable;
pub mod executor;
pub mod index;
pub mod router;
pub mod sharded;

pub use admission::{
    Admission, AdmissionConfig, AdmissionPermit, AdmissionStats, FrontDoor, ServeBackend,
    ShedReason,
};
pub use delta::DeltaBuffer;
pub use durable::{DurableStore, FsyncPolicy, SealedSegment};
pub use executor::{brute_force_topk, CompactionReport, QueryEngine, ServeMeasure};
pub use index::StarIndex;
pub use router::Router;
pub use sharded::{fence_for, ShardedEngine, ShardedIndex};

/// How `QueryEngine::compact` folds the delta buffer into the next
/// snapshot epoch.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum CompactionMode {
    /// Rebuild the star graph over snapshot ∪ delta from scratch — O(n)
    /// per compaction, independent of how little changed.
    Full,
    /// Sketch/route/score only the delta against its routed buckets and
    /// fold the new edges into the snapshot's graph —
    /// O(|delta| · avg bucket size). The default.
    #[default]
    Incremental,
}

impl CompactionMode {
    /// Display name for reports.
    pub fn name(&self) -> &'static str {
        match self {
            CompactionMode::Full => "full",
            CompactionMode::Incremental => "incremental",
        }
    }
}

/// Configuration of the serving snapshot and engine.
#[derive(Clone, Debug)]
pub struct ServeConfig {
    /// Routing repetitions: how many independent hash draws the snapshot
    /// keys its entry tables by. Using the same repetition ids as the build
    /// (`0..route_reps`) makes the routing buckets coincide with the
    /// builder's bucketing for those repetitions.
    pub route_reps: usize,
    /// Entry points retained per (repetition, bucket) in the router.
    pub route_leaders: usize,
    /// Entry points expanded per (query, repetition) at query time
    /// (≤ `route_leaders` is typical; more probes, more recall).
    pub probe_entries: usize,
    /// Minimum edge weight followed during two-hop expansion. `f32::MIN`
    /// follows every retained edge (the degree-capped graph is already the
    /// strongest-neighbor skeleton).
    pub min_w: f32,
    /// Candidate cap per query (0 = unbounded). Expansion stops, in
    /// deterministic route order, once this many candidates are gathered.
    pub max_candidates: usize,
    /// Delta-buffer size that triggers automatic compaction on insert
    /// (0 = manual compaction only).
    pub compact_limit: usize,
    /// How compaction folds the delta into the next epoch (see
    /// [`CompactionMode`]; incremental by default).
    pub compaction: CompactionMode,
    /// Periodic full-rebuild policy: with `compaction = Incremental`, force
    /// one [`CompactionMode::Full`] per this many compactions (0 = never).
    /// Sustained incremental compaction never re-draws bucket leaders or
    /// router entry samples, so a long-lived index slowly drifts from the
    /// distribution a fresh build would produce; the periodic rebuild
    /// bounds that drift. The full/incremental mix is reported in
    /// [`executor::CompactionReport`].
    pub full_rebuild_every: usize,
    /// Active-tail size that triggers sealing the delta buffer into an
    /// immutable [`durable::SealedSegment`] (0 = never seal — brute-force
    /// the whole buffer, the pre-durable behavior). Sealed rows are
    /// sketched once through the snapshot's cached states and queries
    /// route into them; answers are bit-identical either way (see
    /// [`durable::segment`]), so this is purely a write-path cost knob.
    pub seal_limit: usize,
    /// Quantized first-pass scoring: build an SQ8 table into the snapshot
    /// and score candidates int8-first, exact-f32-rescoring the top
    /// `k · rescore_factor` (dense cosine/dot measures only; set and
    /// mixture measures ignore the flag and stay exact).
    pub quantized: bool,
    /// Rescore width multiplier for the quantized path: the first pass
    /// keeps `k · rescore_factor` survivors for the exact rescore.
    /// Larger = closer to f32 recall, smaller = cheaper. Clamped to ≥ 1.
    pub rescore_factor: usize,
    /// Seed for the router's deterministic entry sampling.
    pub seed: u64,
}

impl Default for ServeConfig {
    fn default() -> ServeConfig {
        ServeConfig {
            route_reps: 8,
            route_leaders: 4,
            probe_entries: 4,
            min_w: f32::MIN,
            max_candidates: 8192,
            compact_limit: 1024,
            compaction: CompactionMode::default(),
            full_rebuild_every: 0,
            seal_limit: 0,
            quantized: false,
            rescore_factor: 4,
            seed: 0x5EA7,
        }
    }
}

impl ServeConfig {
    /// Set the routing repetition count.
    pub fn route_reps(mut self, r: usize) -> Self {
        self.route_reps = r.max(1);
        self
    }

    /// Set the retained entries per bucket.
    pub fn route_leaders(mut self, s: usize) -> Self {
        self.route_leaders = s.max(1);
        self
    }

    /// Set the probed entries per (query, repetition).
    pub fn probe_entries(mut self, s: usize) -> Self {
        self.probe_entries = s.max(1);
        self
    }

    /// Set the expansion weight floor.
    pub fn min_w(mut self, w: f32) -> Self {
        self.min_w = w;
        self
    }

    /// Set the per-query candidate cap (0 = unbounded).
    pub fn max_candidates(mut self, c: usize) -> Self {
        self.max_candidates = c;
        self
    }

    /// Set the auto-compaction threshold (0 = manual only).
    pub fn compact_limit(mut self, c: usize) -> Self {
        self.compact_limit = c;
        self
    }

    /// Set the compaction mode.
    pub fn compaction(mut self, mode: CompactionMode) -> Self {
        self.compaction = mode;
        self
    }

    /// Force one full rebuild per `n` compactions under the incremental
    /// mode (0 = never — incremental forever).
    pub fn full_rebuild_every(mut self, n: usize) -> Self {
        self.full_rebuild_every = n;
        self
    }

    /// Seal the delta tail into an immutable segment once it holds `n`
    /// points (0 = never seal).
    pub fn seal_limit(mut self, n: usize) -> Self {
        self.seal_limit = n;
        self
    }

    /// Enable quantized first-pass scoring with an exact f32 rescore of
    /// the top `k · rescore_factor` survivors (clamped to ≥ 1).
    pub fn quantized(mut self, rescore_factor: usize) -> Self {
        self.quantized = true;
        self.rescore_factor = rescore_factor.max(1);
        self
    }

    /// Set the router sampling seed.
    pub fn seed(mut self, s: u64) -> Self {
        self.seed = s;
        self
    }
}

/// Fraction of `reference` ids also present in `got` (1.0 when `reference`
/// is empty) — the serving recall metric (recall@k when both lists are
/// top-k).
pub fn recall_against(reference: &[(u32, f32)], got: &[(u32, f32)]) -> f64 {
    if reference.is_empty() {
        return 1.0;
    }
    let hit = reference
        .iter()
        .filter(|(id, _)| got.iter().any(|(g, _)| g == id))
        .count();
    hit as f64 / reference.len() as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn config_builders_clamp() {
        let c = ServeConfig::default()
            .route_reps(0)
            .route_leaders(0)
            .probe_entries(0)
            .max_candidates(10)
            .compact_limit(5)
            .compaction(CompactionMode::Full)
            .full_rebuild_every(3)
            .seal_limit(7)
            .quantized(0)
            .seed(1);
        assert_eq!(c.route_reps, 1);
        assert_eq!(c.route_leaders, 1);
        assert_eq!(c.probe_entries, 1);
        assert_eq!(c.max_candidates, 10);
        assert_eq!(c.compact_limit, 5);
        assert_eq!(c.compaction, CompactionMode::Full);
        assert_eq!(c.full_rebuild_every, 3);
        assert_eq!(c.seal_limit, 7);
        assert_eq!(ServeConfig::default().seal_limit, 0, "sealing is opt-in");
        assert!(c.quantized);
        assert_eq!(c.rescore_factor, 1, "rescore factor clamps to >= 1");
        assert_eq!(ServeConfig::default().full_rebuild_every, 0);
        assert_eq!(ServeConfig::default().compaction, CompactionMode::Incremental);
        assert!(!ServeConfig::default().quantized);
        assert_eq!(ServeConfig::default().rescore_factor, 4);
        assert_eq!(CompactionMode::Full.name(), "full");
        assert_eq!(CompactionMode::Incremental.name(), "incremental");
    }

    #[test]
    fn recall_metric() {
        let r = [(1u32, 0.9f32), (2, 0.8), (3, 0.7)];
        let g = [(2u32, 0.8f32), (9, 0.5), (1, 0.9)];
        assert!((recall_against(&r, &g) - 2.0 / 3.0).abs() < 1e-9);
        assert_eq!(recall_against(&[], &g), 1.0);
    }
}
