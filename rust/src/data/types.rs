//! Point and dataset representations.
//!
//! Dense features are stored flat (row-major `Vec<f32>`) for cache-friendly
//! scoring; weighted sets are per-point sorted token lists. A dataset may
//! carry either or both (the Amazon2m analogue carries both: an embedding
//! vector and a co-purchase token set).

/// A weighted set feature: sorted unique `(token, weight)` pairs.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct WeightedSet {
    /// Strictly increasing token ids.
    pub tokens: Vec<u32>,
    /// Non-negative weights, parallel to `tokens`.
    pub weights: Vec<f32>,
}

impl WeightedSet {
    /// Build from unsorted (token, weight) pairs; duplicate tokens have their
    /// weights summed.
    pub fn from_pairs(mut pairs: Vec<(u32, f32)>) -> Self {
        pairs.sort_unstable_by_key(|&(t, _)| t);
        let mut tokens = Vec::with_capacity(pairs.len());
        let mut weights = Vec::with_capacity(pairs.len());
        for (t, w) in pairs {
            if tokens.last() == Some(&t) {
                *weights.last_mut().unwrap() += w;
            } else {
                tokens.push(t);
                weights.push(w);
            }
        }
        WeightedSet { tokens, weights }
    }

    /// Unweighted set (all weights 1).
    pub fn from_tokens(mut tokens: Vec<u32>) -> Self {
        tokens.sort_unstable();
        tokens.dedup();
        let weights = vec![1.0; tokens.len()];
        WeightedSet { tokens, weights }
    }

    /// Number of tokens.
    pub fn len(&self) -> usize {
        self.tokens.len()
    }

    /// True if the set is empty.
    pub fn is_empty(&self) -> bool {
        self.tokens.is_empty()
    }

    /// Total weight.
    pub fn total_weight(&self) -> f32 {
        self.weights.iter().sum()
    }
}

/// What feature kinds a dataset carries.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FeatureKind {
    /// Dense f32 vectors only.
    Dense,
    /// Weighted sets only.
    Sets,
    /// Both (e.g. embedding + co-purchase set).
    Hybrid,
}

/// An in-memory dataset: n points with optional dense features, optional
/// weighted-set features, and optional ground-truth class labels.
#[derive(Clone, Debug, Default)]
pub struct Dataset {
    /// Human-readable name (used in reports).
    pub name: String,
    /// Dense feature dimension (0 if no dense features).
    pub dim: usize,
    /// Flat row-major dense features, length `n * dim`.
    pub dense: Vec<f32>,
    /// Precomputed L2 norms of dense rows (kept in sync by constructors).
    pub norms: Vec<f32>,
    /// Weighted set features (empty if none).
    pub sets: Vec<WeightedSet>,
    /// Ground-truth class labels (empty if none).
    pub labels: Vec<u32>,
    n: usize,
}

impl Dataset {
    /// Dataset of dense vectors.
    pub fn from_dense(name: &str, dim: usize, dense: Vec<f32>, labels: Vec<u32>) -> Self {
        assert!(dim > 0 && dense.len() % dim == 0, "dense length not a multiple of dim");
        let n = dense.len() / dim;
        assert!(labels.is_empty() || labels.len() == n);
        let norms = (0..n)
            .map(|i| {
                dense[i * dim..(i + 1) * dim]
                    .iter()
                    .map(|x| x * x)
                    .sum::<f32>()
                    .sqrt()
            })
            .collect();
        Dataset {
            name: name.to_string(),
            dim,
            dense,
            norms,
            sets: Vec::new(),
            labels,
            n,
        }
    }

    /// Dataset of weighted sets.
    pub fn from_sets(name: &str, sets: Vec<WeightedSet>, labels: Vec<u32>) -> Self {
        let n = sets.len();
        assert!(labels.is_empty() || labels.len() == n);
        Dataset {
            name: name.to_string(),
            dim: 0,
            dense: Vec::new(),
            norms: Vec::new(),
            sets,
            labels,
            n,
        }
    }

    /// Hybrid dataset (dense + sets, same point count).
    pub fn hybrid(
        name: &str,
        dim: usize,
        dense: Vec<f32>,
        sets: Vec<WeightedSet>,
        labels: Vec<u32>,
    ) -> Self {
        let mut ds = Dataset::from_dense(name, dim, dense, labels);
        assert_eq!(sets.len(), ds.n, "set count != point count");
        ds.sets = sets;
        ds.name = name.to_string();
        ds
    }

    /// Number of points.
    pub fn len(&self) -> usize {
        self.n
    }

    /// True when empty.
    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// Dense feature dimension.
    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Which feature kinds are present.
    pub fn kind(&self) -> FeatureKind {
        match (self.dim > 0, !self.sets.is_empty()) {
            (true, true) => FeatureKind::Hybrid,
            (true, false) => FeatureKind::Dense,
            (false, true) => FeatureKind::Sets,
            (false, false) => FeatureKind::Dense, // empty dataset; arbitrary
        }
    }

    /// Dense row `i`.
    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.dense[i * self.dim..(i + 1) * self.dim]
    }

    /// Precomputed L2 norm of row `i`.
    #[inline]
    pub fn norm(&self, i: usize) -> f32 {
        self.norms[i]
    }

    /// Weighted set of point `i`.
    #[inline]
    pub fn set(&self, i: usize) -> &WeightedSet {
        &self.sets[i]
    }

    /// Number of distinct labels (0 if unlabeled).
    pub fn num_classes(&self) -> usize {
        self.labels
            .iter()
            .copied()
            .max()
            .map(|m| m as usize + 1)
            .unwrap_or(0)
    }

    /// Take the first `k` points (for scaled-down experiments).
    pub fn take(&self, k: usize) -> Dataset {
        let k = k.min(self.n);
        Dataset {
            name: self.name.clone(),
            dim: self.dim,
            dense: self.dense[..k * self.dim].to_vec(),
            norms: self.norms[..k.min(self.norms.len())].to_vec(),
            sets: self.sets.iter().take(k).cloned().collect(),
            labels: self.labels.iter().take(k).copied().collect(),
            n: k,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn weighted_set_merges_duplicates() {
        let s = WeightedSet::from_pairs(vec![(3, 1.0), (1, 2.0), (3, 0.5)]);
        assert_eq!(s.tokens, vec![1, 3]);
        assert_eq!(s.weights, vec![2.0, 1.5]);
        assert!((s.total_weight() - 3.5).abs() < 1e-6);
    }

    #[test]
    fn from_tokens_dedups_and_sorts() {
        let s = WeightedSet::from_tokens(vec![5, 1, 5, 2]);
        assert_eq!(s.tokens, vec![1, 2, 5]);
        assert_eq!(s.weights, vec![1.0, 1.0, 1.0]);
    }

    #[test]
    fn dense_dataset_norms() {
        let ds = Dataset::from_dense("t", 2, vec![3.0, 4.0, 0.0, 1.0], vec![0, 1]);
        assert_eq!(ds.len(), 2);
        assert!((ds.norm(0) - 5.0).abs() < 1e-6);
        assert!((ds.norm(1) - 1.0).abs() < 1e-6);
        assert_eq!(ds.row(1), &[0.0, 1.0]);
        assert_eq!(ds.num_classes(), 2);
        assert_eq!(ds.kind(), FeatureKind::Dense);
    }

    #[test]
    fn hybrid_dataset() {
        let sets = vec![WeightedSet::from_tokens(vec![1]), WeightedSet::from_tokens(vec![2])];
        let ds = Dataset::hybrid("h", 1, vec![1.0, 2.0], sets, vec![]);
        assert_eq!(ds.kind(), FeatureKind::Hybrid);
        assert_eq!(ds.set(1).tokens, vec![2]);
    }

    #[test]
    fn take_truncates_consistently() {
        let ds = Dataset::from_dense("t", 2, vec![1.0, 0.0, 0.0, 1.0, 1.0, 1.0], vec![0, 1, 2]);
        let t = ds.take(2);
        assert_eq!(t.len(), 2);
        assert_eq!(t.labels, vec![0, 1]);
        assert_eq!(t.norms.len(), 2);
    }

    #[test]
    #[should_panic]
    fn bad_dense_len_panics() {
        Dataset::from_dense("t", 3, vec![1.0; 4], vec![]);
    }
}
