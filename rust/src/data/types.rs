//! Point and dataset representations.
//!
//! Dense features are stored flat (row-major `Vec<f32>`) for cache-friendly
//! scoring; weighted sets are per-point sorted token lists. A dataset may
//! carry either or both (the Amazon2m analogue carries both: an embedding
//! vector and a co-purchase token set).
//!
//! Set datasets additionally carry a lazily built [`TokenVocab`]: the
//! repetition-invariant token → dense-slot map that the set-family sketch
//! caches (MinHash, WeightedMinHash) previously rediscovered with a full
//! dataset pass on *every* repetition. It is built once on first use and
//! shared across families and repetitions via `Arc`.

use crate::util::fxhash::FxHashMap;
use std::sync::{Arc, OnceLock};

/// Cap on distinct tokens the vocabulary will index. Past this the scan
/// aborts and the vocabulary reports [`TokenVocab::overflow`], signalling
/// sketch caches to fall back to on-the-fly derivation rather than let a
/// pathological token universe blow up resident memory.
pub const TOKEN_VOCAB_MAX: usize = 1 << 22;

/// The repetition-invariant token universe of a dataset: each distinct
/// token mapped to a dense slot in first-occurrence order.
#[derive(Clone, Debug, Default)]
pub struct TokenVocab {
    /// token -> slot, slots dense in `0..len()`.
    slots: FxHashMap<u32, u32>,
    /// True when discovery aborted at [`TOKEN_VOCAB_MAX`] distinct tokens;
    /// `slots` is then incomplete and must not be used.
    overflow: bool,
}

impl TokenVocab {
    fn build(sets: &[WeightedSet]) -> TokenVocab {
        let mut slots: FxHashMap<u32, u32> = FxHashMap::default();
        for set in sets {
            for &tok in &set.tokens {
                let next = slots.len() as u32;
                slots.entry(tok).or_insert(next);
                if slots.len() > TOKEN_VOCAB_MAX {
                    return TokenVocab {
                        slots: FxHashMap::default(),
                        overflow: true,
                    };
                }
            }
        }
        TokenVocab {
            slots,
            overflow: false,
        }
    }

    /// Number of distinct tokens indexed (0 on overflow).
    pub fn len(&self) -> usize {
        self.slots.len()
    }

    /// True when no tokens are indexed.
    pub fn is_empty(&self) -> bool {
        self.slots.is_empty()
    }

    /// True when the universe exceeded [`TOKEN_VOCAB_MAX`] and the map is
    /// unusable.
    pub fn overflow(&self) -> bool {
        self.overflow
    }

    /// Dense slot of `token`, if it occurs in the dataset.
    #[inline]
    pub fn slot(&self, token: u32) -> Option<u32> {
        self.slots.get(&token).copied()
    }

    /// Iterate `(token, slot)` pairs (unspecified order).
    pub fn iter(&self) -> impl Iterator<Item = (u32, u32)> + '_ {
        self.slots.iter().map(|(&t, &s)| (t, s))
    }
}

/// A weighted set feature: sorted unique `(token, weight)` pairs.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct WeightedSet {
    /// Strictly increasing token ids.
    pub tokens: Vec<u32>,
    /// Non-negative weights, parallel to `tokens`.
    pub weights: Vec<f32>,
}

impl WeightedSet {
    /// Build from unsorted (token, weight) pairs; duplicate tokens have their
    /// weights summed.
    pub fn from_pairs(mut pairs: Vec<(u32, f32)>) -> Self {
        pairs.sort_unstable_by_key(|&(t, _)| t);
        let mut tokens = Vec::with_capacity(pairs.len());
        let mut weights = Vec::with_capacity(pairs.len());
        for (t, w) in pairs {
            if tokens.last() == Some(&t) {
                *weights.last_mut().unwrap() += w;
            } else {
                tokens.push(t);
                weights.push(w);
            }
        }
        WeightedSet { tokens, weights }
    }

    /// Unweighted set (all weights 1).
    pub fn from_tokens(mut tokens: Vec<u32>) -> Self {
        tokens.sort_unstable();
        tokens.dedup();
        let weights = vec![1.0; tokens.len()];
        WeightedSet { tokens, weights }
    }

    /// Number of tokens.
    pub fn len(&self) -> usize {
        self.tokens.len()
    }

    /// True if the set is empty.
    pub fn is_empty(&self) -> bool {
        self.tokens.is_empty()
    }

    /// Total weight.
    pub fn total_weight(&self) -> f32 {
        self.weights.iter().sum()
    }
}

/// What feature kinds a dataset carries.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum FeatureKind {
    /// Dense f32 vectors only.
    Dense,
    /// Weighted sets only.
    Sets,
    /// Both (e.g. embedding + co-purchase set).
    Hybrid,
}

/// An in-memory dataset: n points with optional dense features, optional
/// weighted-set features, and optional ground-truth class labels.
#[derive(Clone, Debug, Default)]
pub struct Dataset {
    /// Human-readable name (used in reports).
    pub name: String,
    /// Dense feature dimension (0 if no dense features).
    pub dim: usize,
    /// Flat row-major dense features, length `n * dim`.
    pub dense: Vec<f32>,
    /// Precomputed L2 norms of dense rows (kept in sync by constructors).
    pub norms: Vec<f32>,
    /// Weighted set features (empty if none).
    pub sets: Vec<WeightedSet>,
    /// Ground-truth class labels (empty if none).
    pub labels: Vec<u32>,
    n: usize,
    /// Lazily built shared token universe (see [`Dataset::token_vocab`]).
    /// Reset by every constructor and mutation; cloning a dataset carries
    /// the already-built vocabulary along (same points, same universe).
    vocab: OnceLock<Arc<TokenVocab>>,
}

impl Dataset {
    /// Dataset of dense vectors.
    pub fn from_dense(name: &str, dim: usize, dense: Vec<f32>, labels: Vec<u32>) -> Self {
        assert!(dim > 0 && dense.len() % dim == 0, "dense length not a multiple of dim");
        let n = dense.len() / dim;
        assert!(labels.is_empty() || labels.len() == n);
        let norms = (0..n)
            .map(|i| {
                dense[i * dim..(i + 1) * dim]
                    .iter()
                    .map(|x| x * x)
                    .sum::<f32>()
                    .sqrt()
            })
            .collect();
        Dataset {
            name: name.to_string(),
            dim,
            dense,
            norms,
            sets: Vec::new(),
            labels,
            n,
            vocab: OnceLock::new(),
        }
    }

    /// Dataset of weighted sets.
    pub fn from_sets(name: &str, sets: Vec<WeightedSet>, labels: Vec<u32>) -> Self {
        let n = sets.len();
        assert!(labels.is_empty() || labels.len() == n);
        Dataset {
            name: name.to_string(),
            dim: 0,
            dense: Vec::new(),
            norms: Vec::new(),
            sets,
            labels,
            n,
            vocab: OnceLock::new(),
        }
    }

    /// Hybrid dataset (dense + sets, same point count).
    pub fn hybrid(
        name: &str,
        dim: usize,
        dense: Vec<f32>,
        sets: Vec<WeightedSet>,
        labels: Vec<u32>,
    ) -> Self {
        let mut ds = Dataset::from_dense(name, dim, dense, labels);
        assert_eq!(sets.len(), ds.n, "set count != point count");
        ds.sets = sets;
        ds.name = name.to_string();
        ds
    }

    /// Number of points.
    pub fn len(&self) -> usize {
        self.n
    }

    /// True when empty.
    pub fn is_empty(&self) -> bool {
        self.n == 0
    }

    /// Dense feature dimension.
    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Which feature kinds are present.
    pub fn kind(&self) -> FeatureKind {
        match (self.dim > 0, !self.sets.is_empty()) {
            (true, true) => FeatureKind::Hybrid,
            (true, false) => FeatureKind::Dense,
            (false, true) => FeatureKind::Sets,
            (false, false) => FeatureKind::Dense, // empty dataset; arbitrary
        }
    }

    /// Dense row `i`.
    #[inline]
    pub fn row(&self, i: usize) -> &[f32] {
        &self.dense[i * self.dim..(i + 1) * self.dim]
    }

    /// Precomputed L2 norm of row `i`.
    #[inline]
    pub fn norm(&self, i: usize) -> f32 {
        self.norms[i]
    }

    /// Weighted set of point `i`.
    #[inline]
    pub fn set(&self, i: usize) -> &WeightedSet {
        &self.sets[i]
    }

    /// Number of distinct labels (0 if unlabeled).
    pub fn num_classes(&self) -> usize {
        self.labels
            .iter()
            .copied()
            .max()
            .map(|m| m as usize + 1)
            .unwrap_or(0)
    }

    /// Take the first `k` points (for scaled-down experiments).
    pub fn take(&self, k: usize) -> Dataset {
        let k = k.min(self.n);
        Dataset {
            name: self.name.clone(),
            dim: self.dim,
            dense: self.dense[..k * self.dim].to_vec(),
            norms: self.norms[..k.min(self.norms.len())].to_vec(),
            sets: self.sets.iter().take(k).cloned().collect(),
            labels: self.labels.iter().take(k).copied().collect(),
            n: k,
            vocab: OnceLock::new(),
        }
    }

    /// The shared token universe, built on first call (one pass over all
    /// token occurrences) and cached for the dataset's lifetime. Sketch
    /// caches key their per-repetition tables by these slots, so the
    /// per-repetition cost drops to the per-rep draws alone.
    pub fn token_vocab(&self) -> &Arc<TokenVocab> {
        self.vocab
            .get_or_init(|| Arc::new(TokenVocab::build(&self.sets)))
    }

    /// Select a subset of points by id (queries sampled from a dataset,
    /// serve-side test fixtures). Labels follow when present.
    pub fn subset(&self, ids: &[u32]) -> Dataset {
        let mut dense = Vec::with_capacity(ids.len() * self.dim);
        let mut norms = Vec::with_capacity(ids.len().min(self.norms.len()));
        let mut sets = Vec::new();
        let mut labels = Vec::new();
        for &i in ids {
            let i = i as usize;
            if self.dim > 0 {
                dense.extend_from_slice(self.row(i));
                norms.push(self.norms[i]);
            }
            if !self.sets.is_empty() {
                sets.push(self.sets[i].clone());
            }
            if !self.labels.is_empty() {
                labels.push(self.labels[i]);
            }
        }
        Dataset {
            name: self.name.clone(),
            dim: self.dim,
            dense,
            norms,
            sets,
            labels,
            n: ids.len(),
            vocab: OnceLock::new(),
        }
    }

    /// Append one point carrying the same feature kinds as this dataset:
    /// a dense row when `dim > 0`, a token set when sets are present. The
    /// serving delta buffer grows through this; labels stay untouched (new
    /// points are unlabeled), and the cached vocabulary is invalidated.
    /// Returns the new point's id.
    pub fn push_point(&mut self, row: Option<&[f32]>, set: Option<WeightedSet>) -> u32 {
        if self.dim > 0 {
            let row = row.expect("dataset has dense features; row required");
            assert_eq!(row.len(), self.dim, "row dimension mismatch");
            self.dense.extend_from_slice(row);
            self.norms
                .push(row.iter().map(|x| x * x).sum::<f32>().sqrt());
        } else {
            assert!(row.is_none(), "dataset has no dense features");
        }
        match set {
            // The caller decides the feature kind by what it passes; all we
            // enforce is that set features stay aligned with the point count
            // (so a kind cannot change mid-stream).
            Some(s) => {
                assert_eq!(self.sets.len(), self.n, "set features out of sync");
                self.sets.push(s);
            }
            None => assert!(self.sets.is_empty(), "dataset has set features; set required"),
        }
        self.n += 1;
        self.vocab = OnceLock::new();
        self.n as u32 - 1
    }

    /// New dataset with `other`'s points appended (same feature kinds and
    /// dense dimension required). Labels are kept only when both sides
    /// carry them — the serving compaction path appends unlabeled deltas.
    pub fn concat(&self, other: &Dataset) -> Dataset {
        assert_eq!(self.dim, other.dim, "dense dimension mismatch");
        assert_eq!(
            self.sets.is_empty(),
            other.sets.is_empty(),
            "set feature mismatch"
        );
        let mut dense = self.dense.clone();
        dense.extend_from_slice(&other.dense);
        let mut norms = self.norms.clone();
        norms.extend_from_slice(&other.norms);
        let mut sets = self.sets.clone();
        sets.extend(other.sets.iter().cloned());
        let labels = if !self.labels.is_empty() && !other.labels.is_empty() {
            let mut l = self.labels.clone();
            l.extend_from_slice(&other.labels);
            l
        } else {
            Vec::new()
        };
        Dataset {
            name: self.name.clone(),
            dim: self.dim,
            dense,
            norms,
            sets,
            labels,
            n: self.n + other.n,
            vocab: OnceLock::new(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn weighted_set_merges_duplicates() {
        let s = WeightedSet::from_pairs(vec![(3, 1.0), (1, 2.0), (3, 0.5)]);
        assert_eq!(s.tokens, vec![1, 3]);
        assert_eq!(s.weights, vec![2.0, 1.5]);
        assert!((s.total_weight() - 3.5).abs() < 1e-6);
    }

    #[test]
    fn from_tokens_dedups_and_sorts() {
        let s = WeightedSet::from_tokens(vec![5, 1, 5, 2]);
        assert_eq!(s.tokens, vec![1, 2, 5]);
        assert_eq!(s.weights, vec![1.0, 1.0, 1.0]);
    }

    #[test]
    fn dense_dataset_norms() {
        let ds = Dataset::from_dense("t", 2, vec![3.0, 4.0, 0.0, 1.0], vec![0, 1]);
        assert_eq!(ds.len(), 2);
        assert!((ds.norm(0) - 5.0).abs() < 1e-6);
        assert!((ds.norm(1) - 1.0).abs() < 1e-6);
        assert_eq!(ds.row(1), &[0.0, 1.0]);
        assert_eq!(ds.num_classes(), 2);
        assert_eq!(ds.kind(), FeatureKind::Dense);
    }

    #[test]
    fn hybrid_dataset() {
        let sets = vec![WeightedSet::from_tokens(vec![1]), WeightedSet::from_tokens(vec![2])];
        let ds = Dataset::hybrid("h", 1, vec![1.0, 2.0], sets, vec![]);
        assert_eq!(ds.kind(), FeatureKind::Hybrid);
        assert_eq!(ds.set(1).tokens, vec![2]);
    }

    #[test]
    fn take_truncates_consistently() {
        let ds = Dataset::from_dense("t", 2, vec![1.0, 0.0, 0.0, 1.0, 1.0, 1.0], vec![0, 1, 2]);
        let t = ds.take(2);
        assert_eq!(t.len(), 2);
        assert_eq!(t.labels, vec![0, 1]);
        assert_eq!(t.norms.len(), 2);
    }

    #[test]
    #[should_panic]
    fn bad_dense_len_panics() {
        Dataset::from_dense("t", 3, vec![1.0; 4], vec![]);
    }

    #[test]
    fn token_vocab_is_dense_and_cached() {
        let ds = Dataset::from_sets(
            "t",
            vec![
                WeightedSet::from_tokens(vec![5, 9]),
                WeightedSet::from_tokens(vec![9, 30]),
            ],
            vec![],
        );
        let v = ds.token_vocab();
        assert_eq!(v.len(), 3);
        assert!(!v.overflow());
        let mut slots: Vec<u32> = [5u32, 9, 30].iter().map(|&t| v.slot(t).unwrap()).collect();
        slots.sort_unstable();
        assert_eq!(slots, vec![0, 1, 2], "slots not dense");
        assert_eq!(v.slot(7), None);
        // Second call returns the same cached Arc.
        assert!(Arc::ptr_eq(v, ds.token_vocab()));
        // Clones carry the built vocabulary along.
        let clone = ds.clone();
        assert_eq!(clone.token_vocab().len(), 3);
    }

    #[test]
    fn subset_selects_rows_sets_and_labels() {
        let sets = vec![
            WeightedSet::from_tokens(vec![1]),
            WeightedSet::from_tokens(vec![2]),
            WeightedSet::from_tokens(vec![3]),
        ];
        let ds = Dataset::hybrid(
            "h",
            2,
            vec![1.0, 0.0, 0.0, 2.0, 3.0, 0.0],
            sets,
            vec![7, 8, 9],
        );
        let sub = ds.subset(&[2, 0]);
        assert_eq!(sub.len(), 2);
        assert_eq!(sub.row(0), &[3.0, 0.0]);
        assert_eq!(sub.row(1), &[1.0, 0.0]);
        assert!((sub.norm(0) - 3.0).abs() < 1e-6);
        assert_eq!(sub.set(0).tokens, vec![3]);
        assert_eq!(sub.labels, vec![9, 7]);
    }

    #[test]
    fn push_point_and_concat_grow_consistently() {
        let mut delta = Dataset::from_dense("d", 2, Vec::new(), vec![]);
        assert_eq!(delta.len(), 0);
        assert_eq!(delta.push_point(Some(&[3.0, 4.0]), None), 0);
        assert_eq!(delta.push_point(Some(&[0.0, 1.0]), None), 1);
        assert_eq!(delta.len(), 2);
        assert!((delta.norm(0) - 5.0).abs() < 1e-6);
        let base = Dataset::from_dense("b", 2, vec![1.0, 0.0], vec![0]);
        let merged = base.concat(&delta);
        assert_eq!(merged.len(), 3);
        assert_eq!(merged.row(1), &[3.0, 4.0]);
        assert!(merged.labels.is_empty(), "labels must drop on unlabeled concat");
        assert_eq!(merged.norms.len(), 3);
    }

    #[test]
    fn push_point_sets_only() {
        let mut delta = Dataset::from_sets("d", Vec::new(), vec![]);
        delta.push_point(None, Some(WeightedSet::from_tokens(vec![4, 5])));
        assert_eq!(delta.len(), 1);
        assert_eq!(delta.set(0).tokens, vec![4, 5]);
        assert_eq!(delta.token_vocab().len(), 2);
        // Vocab invalidates on the next push.
        delta.push_point(None, Some(WeightedSet::from_tokens(vec![6])));
        assert_eq!(delta.token_vocab().len(), 3);
    }
}
