//! Synthetic dataset generators — the paper's corpora, scaled to one box.
//!
//! | Generator            | Stands in for | Feature kind |
//! |----------------------|---------------|--------------|
//! | [`gaussian_mixture`] | Random1B/10B  | dense 100-d  |
//! | [`digits`]           | MNIST         | dense 784-d  |
//! | [`zipf_sets`]        | Wikipedia     | weighted sets|
//! | [`products`]         | Amazon2m      | hybrid       |
//!
//! All generators are deterministic in their seed and parallelized over the
//! point index (each point derives its own PRNG stream), so generating 10M
//! points is fast and order-independent.

use crate::data::recipe;
use crate::data::types::{Dataset, WeightedSet};
use crate::util::pool::{default_workers, parallel_chunks};
use crate::util::rng::{derive_seed, Rng, ZipfTable};

/// Gaussian mixture in `dim` dimensions with `modes` modes — the paper's
/// Random1B/Random10B recipe (Appendix D.1): mode i has mean e_{i mod dim}
/// (one-hot) and per-coordinate std `std` (paper: 0.1); each point draws its
/// mode uniformly. Labels are mode ids.
pub fn gaussian_mixture(n: usize, dim: usize, modes: usize, std: f32, seed: u64) -> Dataset {
    assert!(dim > 0 && modes > 0);
    let workers = default_workers();
    let parts = parallel_chunks(n, workers, |_, range| {
        let mut dense = Vec::with_capacity(range.len() * dim);
        let mut labels = Vec::with_capacity(range.len());
        for i in range {
            let mut rng = Rng::new(derive_seed(seed, i as u64));
            let mode = rng.below(modes);
            labels.push(mode as u32);
            let hot = mode % dim;
            for d in 0..dim {
                let mean = if d == hot { 1.0 } else { 0.0 };
                dense.push(rng.gaussian32(mean, std));
            }
        }
        (dense, labels)
    });
    let mut dense = Vec::with_capacity(n * dim);
    let mut labels = Vec::with_capacity(n);
    for (d, l) in parts {
        dense.extend(d);
        labels.extend(l);
    }
    Dataset::from_dense(&format!("random{}", human(n)), dim, dense, labels)
}

/// MNIST stand-in: 10 classes of 784-d non-negative "images".
///
/// Each class has a prototype built from a deterministic set of blurry
/// strokes on the 28x28 grid; samples add per-pixel noise, a global intensity
/// jitter, and a small random translation — enough structure that cosine
/// similarity within a class concentrates near ~0.8 and across classes near
/// ~0.3–0.5, mirroring MNIST's regime for threshold-0.5 experiments.
pub fn digits(n: usize, seed: u64) -> Dataset {
    const SIDE: usize = 28;
    const DIM: usize = SIDE * SIDE;
    const CLASSES: usize = 10;
    // Class prototypes: a handful of gaussian blobs along a class-specific
    // random walk (a crude "pen stroke").
    let mut prototypes = vec![vec![0f32; DIM]; CLASSES];
    for (c, proto) in prototypes.iter_mut().enumerate() {
        let mut rng = Rng::new(derive_seed(seed ^ 0xD161, c as u64));
        let strokes = 3 + rng.below(3);
        for _ in 0..strokes {
            let mut x = 4.0 + 20.0 * rng.next_f64();
            let mut y = 4.0 + 20.0 * rng.next_f64();
            let steps = 8 + rng.below(8);
            let (dx, dy) = (rng.gaussian() * 1.5, rng.gaussian() * 1.5);
            for _ in 0..steps {
                x = (x + dx + rng.gaussian() * 0.7).clamp(1.0, 26.0);
                y = (y + dy + rng.gaussian() * 0.7).clamp(1.0, 26.0);
                // Stamp a 3x3 gaussian blob.
                for oy in -2i64..=2 {
                    for ox in -2i64..=2 {
                        let px = (x as i64 + ox).clamp(0, 27) as usize;
                        let py = (y as i64 + oy).clamp(0, 27) as usize;
                        let w = (-((ox * ox + oy * oy) as f64) / 2.0).exp() as f32;
                        proto[py * SIDE + px] = (proto[py * SIDE + px] + w).min(1.0);
                    }
                }
            }
        }
    }
    let workers = default_workers();
    let parts = parallel_chunks(n, workers, |_, range| {
        let mut dense = Vec::with_capacity(range.len() * DIM);
        let mut labels = Vec::with_capacity(range.len());
        for i in range {
            let mut rng = Rng::new(derive_seed(seed, i as u64));
            let c = rng.below(CLASSES);
            labels.push(c as u32);
            let proto = &prototypes[c];
            let gain = 0.8 + 0.4 * rng.next_f32();
            // Small translation in [-2, 2]^2.
            let tx = rng.range(0, 5) as i64 - 2;
            let ty = rng.range(0, 5) as i64 - 2;
            for py in 0..SIDE as i64 {
                for px in 0..SIDE as i64 {
                    let (sx, sy) = (px - tx, py - ty);
                    let base = if (0..SIDE as i64).contains(&sx) && (0..SIDE as i64).contains(&sy)
                    {
                        proto[(sy as usize) * SIDE + sx as usize]
                    } else {
                        0.0
                    };
                    let noisy = (base * gain + rng.gaussian32(0.0, 0.08)).clamp(0.0, 1.0);
                    dense.push(noisy);
                }
            }
        }
        (dense, labels)
    });
    let mut dense = Vec::with_capacity(n * DIM);
    let mut labels = Vec::with_capacity(n);
    for (d, l) in parts {
        dense.extend(d);
        labels.extend(l);
    }
    Dataset::from_dense("digits", DIM, dense, labels)
}

/// Parameters for the Wikipedia stand-in.
#[derive(Clone, Debug)]
pub struct ZipfSetsParams {
    /// Vocabulary size (distinct "words").
    pub vocab: u32,
    /// Number of latent topics (serves as the label).
    pub topics: usize,
    /// Tokens drawn per document (document length).
    pub doc_len: usize,
    /// Probability a token comes from the topic-specific distribution rather
    /// than the global background.
    pub topic_mass: f64,
    /// Zipf exponent of both token distributions.
    pub zipf_s: f64,
}

impl Default for ZipfSetsParams {
    fn default() -> Self {
        ZipfSetsParams {
            vocab: 50_000,
            topics: 40,
            doc_len: 120,
            topic_mass: 0.7,
            zipf_s: 1.07,
        }
    }
}

/// Wikipedia stand-in: documents as weighted word sets from a Zipfian topic
/// model. Each document draws a topic t (its label), then `doc_len` tokens:
/// with prob `topic_mass` from topic t's Zipf-permuted vocabulary slice,
/// else from the global Zipf background. Weights are term frequencies —
/// exactly the representation the paper uses for Wikipedia (word set +
/// frequency weights), exercising weighted MinHash / weighted Jaccard.
pub fn zipf_sets(n: usize, params: &ZipfSetsParams, seed: u64) -> Dataset {
    let vocab = params.vocab;
    let table = ZipfTable::new(4096.min(vocab as usize), params.zipf_s);
    // Each topic remaps the Zipf head into its own token subspace via a
    // per-topic offset; the background uses the identity mapping.
    let workers = default_workers();
    let parts = parallel_chunks(n, workers, |_, range| {
        let mut sets = Vec::with_capacity(range.len());
        let mut labels = Vec::with_capacity(range.len());
        for i in range {
            let mut rng = Rng::new(derive_seed(seed, i as u64));
            let topic = rng.below(params.topics);
            labels.push(topic as u32);
            let topic_offset =
                (derive_seed(seed ^ 0x70_71C, topic as u64) % vocab as u64) as u32;
            let mut pairs = Vec::with_capacity(params.doc_len);
            for _ in 0..params.doc_len {
                let rank = table.sample(&mut rng) as u32;
                let token = if rng.bool(params.topic_mass) {
                    (rank.wrapping_add(topic_offset)) % vocab
                } else {
                    rank % vocab
                };
                pairs.push((token, 1.0));
            }
            sets.push(WeightedSet::from_pairs(pairs));
        }
        (sets, labels)
    });
    let mut sets = Vec::with_capacity(n);
    let mut labels = Vec::with_capacity(n);
    for (s, l) in parts {
        sets.extend(s);
        labels.extend(l);
    }
    Dataset::from_sets("zipfsets", sets, labels)
}

/// Parameters for the Amazon2m stand-in.
#[derive(Clone, Debug)]
pub struct ProductsParams {
    /// Number of product categories (paper: 47).
    pub classes: u32,
    /// Embedding dimension (paper: 100).
    pub dim: usize,
    /// Noise std around the class mean embedding.
    pub noise: f32,
    /// Co-purchase vocabulary size.
    pub vocab: u32,
    /// Size of each class's co-purchase token pool.
    pub pool_size: usize,
    /// Co-purchase tokens per product.
    pub basket: usize,
    /// Probability a basket token comes from the class pool (vs global).
    pub class_mass: f64,
}

impl Default for ProductsParams {
    fn default() -> Self {
        ProductsParams {
            classes: 47,
            dim: 100,
            // sigma chosen so same-class cosine ~ 1/(1+dim*sigma^2) ~ 0.55:
            // the paper's Amazon2m threshold-0.5 regime.
            noise: 0.09,
            vocab: 20_000,
            // Pool/basket sized so same-class co-purchase Jaccard ~ 0.4 and
            // cross-class ~ 0 — the regime where MinHash symbols carry
            // signal (mirrored in python/compile/model.py PRODUCTS).
            pool_size: 24,
            basket: 40,
            class_mass: 0.8,
        }
    }
}

/// Amazon2m stand-in: 47-category products with a 100-d embedding (class
/// mean from the shared [`recipe`] + gaussian noise — the same geometry the
/// learned model is trained on in python) and a class-biased co-purchase
/// token set. Exercises the SimHash+MinHash mixture family and the learned
/// similarity path.
pub fn products(n: usize, params: &ProductsParams, seed: u64) -> Dataset {
    let means: Vec<Vec<f32>> = (0..params.classes)
        .map(|c| recipe::class_mean(seed, c, params.dim))
        .collect();
    let pools: Vec<Vec<u32>> = (0..params.classes)
        .map(|c| recipe::class_token_pool(seed, c, params.vocab, params.pool_size))
        .collect();
    let workers = default_workers();
    let parts = parallel_chunks(n, workers, |_, range| {
        let mut dense = Vec::with_capacity(range.len() * params.dim);
        let mut sets = Vec::with_capacity(range.len());
        let mut labels = Vec::with_capacity(range.len());
        for i in range {
            let mut rng = Rng::new(derive_seed(seed, i as u64));
            let c = rng.below(params.classes as usize);
            labels.push(c as u32);
            let mean = &means[c];
            for d in 0..params.dim {
                dense.push(mean[d] + rng.gaussian32(0.0, params.noise));
            }
            let pool = &pools[c];
            let mut tokens = Vec::with_capacity(params.basket);
            for _ in 0..params.basket {
                let t = if rng.bool(params.class_mass) {
                    pool[rng.below(pool.len())]
                } else {
                    (rng.next_u64() % params.vocab as u64) as u32
                };
                tokens.push(t);
            }
            sets.push(WeightedSet::from_tokens(tokens));
        }
        (dense, sets, labels)
    });
    let mut dense = Vec::with_capacity(n * params.dim);
    let mut sets = Vec::with_capacity(n);
    let mut labels = Vec::with_capacity(n);
    for (d, s, l) in parts {
        dense.extend(d);
        sets.extend(s);
        labels.extend(l);
    }
    Dataset::hybrid("products", params.dim, dense, sets, labels)
}

fn human(n: usize) -> String {
    if n >= 1_000_000_000 {
        format!("{}B", n / 1_000_000_000)
    } else if n >= 1_000_000 {
        format!("{}M", n / 1_000_000)
    } else if n >= 1_000 {
        format!("{}k", n / 1_000)
    } else {
        n.to_string()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::{cosine, weighted_jaccard};

    #[test]
    fn gaussian_mixture_shape_and_determinism() {
        let a = gaussian_mixture(500, 20, 10, 0.1, 7);
        let b = gaussian_mixture(500, 20, 10, 0.1, 7);
        assert_eq!(a.len(), 500);
        assert_eq!(a.dim(), 20);
        assert_eq!(a.dense, b.dense);
        assert_eq!(a.labels, b.labels);
        assert_eq!(a.num_classes(), 10);
    }

    #[test]
    fn gaussian_mixture_same_mode_is_similar() {
        let ds = gaussian_mixture(2000, 100, 100, 0.1, 3);
        let (mut same, mut same_n, mut diff, mut diff_n) = (0.0, 0, 0.0, 0);
        for i in 0..200 {
            for j in (i + 1)..200 {
                let s = cosine(ds.row(i), ds.row(j));
                if ds.labels[i] == ds.labels[j] {
                    same += s as f64;
                    same_n += 1;
                } else {
                    diff += s as f64;
                    diff_n += 1;
                }
            }
        }
        if same_n > 0 && diff_n > 0 {
            // With one-hot means and sigma=0.1 in d=100, E||x||^2 ~= 2, so
            // same-mode cosine concentrates near 0.5 (the paper's threshold
            // regime) and cross-mode near 0.
            let (ms, md) = (same / same_n as f64, diff / diff_n as f64);
            assert!(ms > 0.4, "same-mode cosine {ms}");
            assert!(md < 0.2, "cross-mode cosine {md}");
        }
    }

    #[test]
    fn digits_class_structure() {
        let ds = digits(400, 11);
        assert_eq!(ds.dim(), 784);
        assert_eq!(ds.num_classes(), 10);
        // Within-class cosine similarity must exceed cross-class on average.
        let (mut same, mut same_n, mut diff, mut diff_n) = (0.0f64, 0, 0.0f64, 0);
        for i in 0..150 {
            for j in (i + 1)..150 {
                let s = cosine(ds.row(i), ds.row(j)) as f64;
                if ds.labels[i] == ds.labels[j] {
                    same += s;
                    same_n += 1;
                } else {
                    diff += s;
                    diff_n += 1;
                }
            }
        }
        let (ms, md) = (same / same_n as f64, diff / diff_n as f64);
        assert!(ms > md + 0.15, "digit classes not separated: same={ms} diff={md}");
        assert!(ms > 0.5, "within-class similarity too low: {ms}");
    }

    #[test]
    fn zipf_sets_topic_structure() {
        let ds = zipf_sets(300, &ZipfSetsParams::default(), 5);
        assert_eq!(ds.len(), 300);
        assert!(ds.sets.iter().all(|s| !s.is_empty()));
        let (mut same, mut same_n, mut diff, mut diff_n) = (0.0f64, 0, 0.0f64, 0);
        for i in 0..120 {
            for j in (i + 1)..120 {
                let s = weighted_jaccard(ds.set(i), ds.set(j)) as f64;
                if ds.labels[i] == ds.labels[j] {
                    same += s;
                    same_n += 1;
                } else {
                    diff += s;
                    diff_n += 1;
                }
            }
        }
        let (ms, md) = (same / same_n.max(1) as f64, diff / diff_n.max(1) as f64);
        assert!(ms > md * 2.0, "topics not separated: same={ms} diff={md}");
    }

    #[test]
    fn products_hybrid_structure() {
        let ds = products(400, &ProductsParams::default(), 9);
        assert_eq!(ds.kind(), crate::data::FeatureKind::Hybrid);
        assert_eq!(ds.num_classes(), 47);
        assert_eq!(ds.sets.len(), 400);
        // Same-class embedding cosine must dominate cross-class.
        let (mut same, mut same_n, mut diff, mut diff_n) = (0.0f64, 0, 0.0f64, 0);
        for i in 0..200 {
            for j in (i + 1)..200 {
                let s = cosine(ds.row(i), ds.row(j)) as f64;
                if ds.labels[i] == ds.labels[j] {
                    same += s;
                    same_n += 1;
                } else {
                    diff += s;
                    diff_n += 1;
                }
            }
        }
        if same_n > 0 {
            let (ms, md) = (same / same_n as f64, diff / diff_n as f64);
            assert!(ms > 0.45 && ms > md + 0.3, "products not separated: {ms} vs {md}");
        }
    }

    #[test]
    fn human_formatting() {
        assert_eq!(human(10_000_000), "10M");
        assert_eq!(human(1_000_000_000), "1B");
        assert_eq!(human(60_000), "60k");
        assert_eq!(human(999), "999");
    }
}
