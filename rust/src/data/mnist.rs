//! IDX-format loader for the real MNIST dataset.
//!
//! The reproduction defaults to the synthetic digits stand-in (no network
//! access at build time), but if the canonical IDX files are present —
//! `train-images-idx3-ubyte` / `train-labels-idx1-ubyte`, optionally
//! gzip-less — this loader turns them into a [`Dataset`] identical in shape
//! to the paper's MNIST setup (60k × 784 floats in [0,1], 10 classes), so
//! every experiment can be re-run on the real corpus:
//!
//! ```text
//! stars build --dataset /data/mnist --algo lsh+stars --r 400
//! ```
//! (pass the *directory* containing the two files).

use crate::data::types::Dataset;
use anyhow::{bail, Context, Result};
use std::io::Read;
use std::path::Path;

const IMAGES_MAGIC: u32 = 0x0000_0803;
const LABELS_MAGIC: u32 = 0x0000_0801;

/// Load MNIST from a directory containing the IDX files.
pub fn load_dir(dir: &Path) -> Result<Dataset> {
    let images = read_file(&dir.join("train-images-idx3-ubyte"))?;
    let labels = read_file(&dir.join("train-labels-idx1-ubyte"))?;
    from_idx(&images, &labels)
}

fn read_file(path: &Path) -> Result<Vec<u8>> {
    let mut buf = Vec::new();
    std::fs::File::open(path)
        .with_context(|| format!("opening {}", path.display()))?
        .read_to_end(&mut buf)?;
    Ok(buf)
}

/// Parse raw IDX image + label buffers into a dataset.
pub fn from_idx(images: &[u8], labels: &[u8]) -> Result<Dataset> {
    let (imagic, idims) = idx_header(images)?;
    if imagic != IMAGES_MAGIC || idims.len() != 3 {
        bail!("not an IDX3 image file (magic {imagic:#x})");
    }
    let (lmagic, ldims) = idx_header(labels)?;
    if lmagic != LABELS_MAGIC || ldims.len() != 1 {
        bail!("not an IDX1 label file (magic {lmagic:#x})");
    }
    let (n, rows, cols) = (idims[0] as usize, idims[1] as usize, idims[2] as usize);
    if ldims[0] as usize != n {
        bail!("image/label count mismatch: {n} vs {}", ldims[0]);
    }
    let dim = rows * cols;
    let pixel_off = 4 + 4 * idims.len();
    let label_off = 4 + 4 * ldims.len();
    if images.len() < pixel_off + n * dim {
        bail!("truncated image file");
    }
    if labels.len() < label_off + n {
        bail!("truncated label file");
    }
    let dense: Vec<f32> = images[pixel_off..pixel_off + n * dim]
        .iter()
        .map(|&b| b as f32 / 255.0)
        .collect();
    let label_vec: Vec<u32> = labels[label_off..label_off + n]
        .iter()
        .map(|&b| b as u32)
        .collect();
    if let Some(&bad) = label_vec.iter().find(|&&l| l > 9) {
        bail!("label {bad} out of range for MNIST");
    }
    Ok(Dataset::from_dense("mnist", dim, dense, label_vec))
}

fn idx_header(buf: &[u8]) -> Result<(u32, Vec<u32>)> {
    if buf.len() < 4 {
        bail!("file too short for IDX header");
    }
    let magic = u32::from_be_bytes(buf[0..4].try_into().unwrap());
    let ndims = (magic & 0xFF) as usize;
    if buf.len() < 4 + 4 * ndims {
        bail!("file too short for {ndims} dims");
    }
    let dims = (0..ndims)
        .map(|d| u32::from_be_bytes(buf[4 + 4 * d..8 + 4 * d].try_into().unwrap()))
        .collect();
    Ok((magic, dims))
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Build a tiny valid IDX fixture in memory.
    fn fixture(n: usize, side: usize) -> (Vec<u8>, Vec<u8>) {
        let mut images = Vec::new();
        images.extend_from_slice(&IMAGES_MAGIC.to_be_bytes());
        images.extend_from_slice(&(n as u32).to_be_bytes());
        images.extend_from_slice(&(side as u32).to_be_bytes());
        images.extend_from_slice(&(side as u32).to_be_bytes());
        for i in 0..n * side * side {
            images.push((i % 256) as u8);
        }
        let mut labels = Vec::new();
        labels.extend_from_slice(&LABELS_MAGIC.to_be_bytes());
        labels.extend_from_slice(&(n as u32).to_be_bytes());
        for i in 0..n {
            labels.push((i % 10) as u8);
        }
        (images, labels)
    }

    #[test]
    fn parses_fixture() {
        let (images, labels) = fixture(20, 4);
        let ds = from_idx(&images, &labels).unwrap();
        assert_eq!(ds.len(), 20);
        assert_eq!(ds.dim(), 16);
        assert_eq!(ds.labels.len(), 20);
        assert_eq!(ds.labels[3], 3);
        // Pixels normalized to [0,1].
        assert!(ds.dense.iter().all(|&p| (0.0..=1.0).contains(&p)));
        assert!((ds.dense[255] - 1.0).abs() < 1e-6); // byte 255 -> 1.0
    }

    #[test]
    fn rejects_wrong_magic() {
        let (mut images, labels) = fixture(5, 4);
        images[3] = 0x01; // corrupt magic dims byte
        assert!(from_idx(&images, &labels).is_err());
    }

    #[test]
    fn rejects_count_mismatch() {
        let (images, _) = fixture(5, 4);
        let (_, labels) = fixture(6, 4);
        assert!(from_idx(&images, &labels).is_err());
    }

    #[test]
    fn rejects_truncated() {
        let (images, labels) = fixture(5, 4);
        assert!(from_idx(&images[..images.len() - 3], &labels).is_err());
        assert!(from_idx(&images, &labels[..labels.len() - 1]).is_err());
    }

    #[test]
    fn rejects_out_of_range_labels() {
        let (images, mut labels) = fixture(5, 4);
        let off = labels.len() - 1;
        labels[off] = 42;
        assert!(from_idx(&images, &labels).is_err());
    }
}
