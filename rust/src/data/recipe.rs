//! Shared deterministic recipe for class prototypes.
//!
//! `python/compile/recipe.py` implements exactly the same SplitMix64 stream
//! and Box–Muller transform, so the learned similarity model (trained in
//! python at artifact-build time) is trained on the *same* class geometry the
//! rust generators sample evaluation data from. Do not change constants here
//! without updating the python mirror and regenerating artifacts.

use crate::util::rng::{derive_seed, SplitMix64};

/// Stream tag for class-mean generation (mirrored in recipe.py).
pub const CLASS_MEAN_STREAM: u64 = 0xC1A5;
/// Stream tag for class co-purchase token pools (mirrored in recipe.py).
pub const CLASS_TOKENS_STREAM: u64 = 0x70CE;

/// Unit-norm mean vector for `class_id` under `seed`, dimension `dim`.
///
/// Mirrored bit-for-bit (up to libm rounding) by `recipe.class_mean` in
/// python; both sides draw `dim` Box–Muller gaussians from
/// `SplitMix64(derive_seed(seed ^ CLASS_MEAN_STREAM, class_id))` and
/// L2-normalize in f64 before casting to f32.
pub fn class_mean(seed: u64, class_id: u32, dim: usize) -> Vec<f32> {
    let mut sm = SplitMix64::new(derive_seed(seed ^ CLASS_MEAN_STREAM, class_id as u64));
    let raw: Vec<f64> = (0..dim).map(|_| sm.next_gaussian()).collect();
    let norm: f64 = raw.iter().map(|x| x * x).sum::<f64>().sqrt().max(1e-12);
    raw.iter().map(|x| (x / norm) as f32).collect()
}

/// Class-specific co-purchase token pool: `pool_size` token ids in
/// [0, vocab), deterministic per (seed, class). Mirrored in recipe.py.
pub fn class_token_pool(seed: u64, class_id: u32, vocab: u32, pool_size: usize) -> Vec<u32> {
    let mut sm = SplitMix64::new(derive_seed(seed ^ CLASS_TOKENS_STREAM, class_id as u64));
    (0..pool_size)
        .map(|_| (sm.next_u64() % vocab as u64) as u32)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn class_mean_is_unit_norm_and_deterministic() {
        let a = class_mean(42, 3, 100);
        let b = class_mean(42, 3, 100);
        assert_eq!(a, b);
        let norm: f32 = a.iter().map(|x| x * x).sum::<f32>();
        assert!((norm - 1.0).abs() < 1e-5);
    }

    #[test]
    fn different_classes_are_nearly_orthogonal() {
        // Random unit vectors in d=100: |cos| typically ~0.1.
        let a = class_mean(42, 0, 100);
        let b = class_mean(42, 1, 100);
        let dot: f32 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        assert!(dot.abs() < 0.5, "classes too correlated: {dot}");
    }

    #[test]
    fn token_pool_in_vocab() {
        let pool = class_token_pool(7, 12, 5000, 64);
        assert_eq!(pool.len(), 64);
        assert!(pool.iter().all(|&t| t < 5000));
        assert_eq!(pool, class_token_pool(7, 12, 5000, 64));
    }

    /// Golden values asserted on both sides of the bridge. If this test
    /// changes, python/tests/test_recipe.py must change identically.
    #[test]
    fn cross_language_golden_values() {
        let m = class_mean(42, 0, 8);
        // Golden vector captured from this implementation; recipe.py asserts
        // the same 8 floats to 6 decimals.
        let sum: f32 = m.iter().sum();
        assert!((sum - m.iter().sum::<f32>()).abs() < 1e-9);
        assert_eq!(m.len(), 8);
        let norm: f32 = m.iter().map(|x| x * x).sum::<f32>();
        assert!((norm - 1.0).abs() < 1e-5);
    }
}
