//! Binary dataset serialization.
//!
//! Layout (little endian):
//! ```text
//! magic "SDS1" | n u64 | dim u64 | has_sets u8 | has_labels u8 |
//! dense  f32 * n*dim |
//! [labels u32 * n] |
//! [sets: per point: len u32, tokens u32*len, weights f32*len]
//! ```
//! Used to persist generated datasets between experiment runs so the
//! expensive generators (10M-point GMMs) run once.

use crate::data::types::{Dataset, WeightedSet};
use anyhow::{bail, Context, Result};
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

const MAGIC: &[u8; 4] = b"SDS1";

/// Largest dataset name the loader accepts. A corrupted header claiming a
/// multi-gigabyte "name" fails fast instead of allocating it.
const MAX_NAME_LEN: usize = 4096;

/// Write a dataset to `path` atomically: bytes go to a `.tmp` sibling
/// which is fsynced and renamed over the target (the same publish idiom as
/// `obs::write_snapshot` and the serve-snapshot store), so a crash or
/// write failure mid-save can never leave a torn file at `path` — the
/// target is either the complete old content, the complete new content, or
/// absent.
pub fn save(ds: &Dataset, path: &Path) -> Result<()> {
    let tmp = path.with_extension("tmp");
    let result = write_to(ds, &tmp).and_then(|()| {
        std::fs::rename(&tmp, path)
            .with_context(|| format!("publishing {} over {}", tmp.display(), path.display()))
    });
    if result.is_err() {
        std::fs::remove_file(&tmp).ok();
    }
    result
}

fn write_to(ds: &Dataset, tmp: &Path) -> Result<()> {
    let file =
        std::fs::File::create(tmp).with_context(|| format!("creating {}", tmp.display()))?;
    let mut w = BufWriter::new(file);
    w.write_all(MAGIC)?;
    w.write_all(&(ds.len() as u64).to_le_bytes())?;
    w.write_all(&(ds.dim() as u64).to_le_bytes())?;
    w.write_all(&[!ds.sets.is_empty() as u8, !ds.labels.is_empty() as u8])?;
    let name = ds.name.as_bytes();
    w.write_all(&(name.len() as u32).to_le_bytes())?;
    w.write_all(name)?;
    for &x in &ds.dense {
        w.write_all(&x.to_le_bytes())?;
    }
    if !ds.labels.is_empty() {
        for &l in &ds.labels {
            w.write_all(&l.to_le_bytes())?;
        }
    }
    if !ds.sets.is_empty() {
        for s in &ds.sets {
            w.write_all(&(s.len() as u32).to_le_bytes())?;
            for &t in &s.tokens {
                w.write_all(&t.to_le_bytes())?;
            }
            for &wt in &s.weights {
                w.write_all(&wt.to_le_bytes())?;
            }
        }
    }
    w.flush()
        .with_context(|| format!("flushing {}", tmp.display()))?;
    w.into_inner()
        .map_err(|e| anyhow::anyhow!("{}: flushing buffered writer: {}", tmp.display(), e.error()))?
        .sync_all()
        .with_context(|| format!("fsyncing {}", tmp.display()))?;
    Ok(())
}

/// Read a dataset from `path`.
///
/// Every failure names the file and the section being read, and the header's
/// claimed sizes are checked against the actual file length *before* any
/// O(n·dim) allocation — a truncated or bit-flipped header fails with a
/// diagnostic instead of an OOM or a silent short read.
pub fn load(path: &Path) -> Result<Dataset> {
    let file =
        std::fs::File::open(path).with_context(|| format!("opening {}", path.display()))?;
    let file_len = file
        .metadata()
        .with_context(|| format!("stat {}", path.display()))?
        .len();
    let mut r = BufReader::new(file);
    let mut magic = [0u8; 4];
    r.read_exact(&mut magic)
        .with_context(|| format!("{}: reading magic (truncated file?)", path.display()))?;
    if &magic != MAGIC {
        bail!(
            "{}: bad magic {:?} (expected {:?}) — not a stars dataset file",
            path.display(),
            magic,
            MAGIC
        );
    }
    let n = read_u64(&mut r)
        .with_context(|| format!("{}: reading point count", path.display()))? as usize;
    let dim = read_u64(&mut r)
        .with_context(|| format!("{}: reading dimension", path.display()))? as usize;
    let mut flags = [0u8; 2];
    r.read_exact(&mut flags)
        .with_context(|| format!("{}: reading feature flags", path.display()))?;
    let (has_sets, has_labels) = (flags[0] != 0, flags[1] != 0);
    let name_len = read_u32(&mut r)
        .with_context(|| format!("{}: reading name length", path.display()))?
        as usize;
    if name_len > MAX_NAME_LEN {
        bail!(
            "{}: header claims a {name_len}-byte dataset name (cap {MAX_NAME_LEN}) — \
             corrupt header",
            path.display()
        );
    }
    // Minimum bytes the header's claims imply, in u128 so n·dim·4 cannot
    // itself overflow. Sets are variable-length, so only their mandatory
    // per-point length fields count toward the floor.
    let mut need: u128 = (4 + 8 + 8 + 2 + 4 + name_len) as u128 + n as u128 * dim as u128 * 4;
    if has_labels {
        need += n as u128 * 4;
    }
    if has_sets {
        need += n as u128 * 4;
    }
    if need > file_len as u128 {
        bail!(
            "{}: truncated or corrupt: header (n={n}, dim={dim}) requires at least \
             {need} bytes but the file is {file_len}",
            path.display()
        );
    }
    let mut name_buf = vec![0u8; name_len];
    r.read_exact(&mut name_buf)
        .with_context(|| format!("{}: reading {name_len}-byte name", path.display()))?;
    let name = String::from_utf8(name_buf)
        .with_context(|| format!("{}: dataset name not utf8", path.display()))?;

    let mut dense = vec![0f32; n * dim];
    read_f32s(&mut r, &mut dense)
        .with_context(|| format!("{}: reading {n}×{dim} dense block", path.display()))?;
    let labels = if has_labels {
        let mut buf = vec![0u32; n];
        read_u32s(&mut r, &mut buf)
            .with_context(|| format!("{}: reading {n} labels", path.display()))?;
        buf
    } else {
        Vec::new()
    };
    let sets = if has_sets {
        let mut sets = Vec::with_capacity(n);
        for i in 0..n {
            let len = read_u32(&mut r)
                .with_context(|| format!("{}: reading set {i} length", path.display()))?
                as usize;
            // A set cannot be longer than the whole file: reject the
            // claimed length before allocating token/weight buffers.
            if len as u128 * 8 > file_len as u128 {
                bail!(
                    "{}: set {i} claims {len} tokens — more than the file can hold; \
                     corrupt set block",
                    path.display()
                );
            }
            let mut tokens = vec![0u32; len];
            read_u32s(&mut r, &mut tokens)
                .with_context(|| format!("{}: reading set {i} tokens", path.display()))?;
            let mut weights = vec![0f32; len];
            read_f32s(&mut r, &mut weights)
                .with_context(|| format!("{}: reading set {i} weights", path.display()))?;
            sets.push(WeightedSet { tokens, weights });
        }
        sets
    } else {
        Vec::new()
    };

    Ok(match (dim > 0, has_sets) {
        (true, true) => Dataset::hybrid(&name, dim, dense, sets, labels),
        (true, false) => Dataset::from_dense(&name, dim, dense, labels),
        (false, true) => Dataset::from_sets(&name, sets, labels),
        (false, false) => bail!("{}: dataset has neither dense nor set features", path.display()),
    })
}

fn read_u64<R: Read>(r: &mut R) -> Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

fn read_u32<R: Read>(r: &mut R) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_u32s<R: Read>(r: &mut R, out: &mut [u32]) -> Result<()> {
    let mut buf = vec![0u8; out.len() * 4];
    r.read_exact(&mut buf)?;
    for (i, c) in buf.chunks_exact(4).enumerate() {
        out[i] = u32::from_le_bytes(c.try_into().unwrap());
    }
    Ok(())
}

fn read_f32s<R: Read>(r: &mut R, out: &mut [f32]) -> Result<()> {
    let mut buf = vec![0u8; out.len() * 4];
    r.read_exact(&mut buf)?;
    for (i, c) in buf.chunks_exact(4).enumerate() {
        out[i] = f32::from_le_bytes(c.try_into().unwrap());
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;

    fn tmp(name: &str) -> std::path::PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("stars_io_test_{name}_{}", std::process::id()));
        p
    }

    #[test]
    fn roundtrip_dense() {
        let ds = synth::gaussian_mixture(100, 8, 4, 0.1, 1);
        let p = tmp("dense");
        save(&ds, &p).unwrap();
        let back = load(&p).unwrap();
        std::fs::remove_file(&p).ok();
        assert_eq!(ds.dense, back.dense);
        assert_eq!(ds.labels, back.labels);
        assert_eq!(ds.name, back.name);
        assert_eq!(ds.norms, back.norms);
    }

    #[test]
    fn roundtrip_sets() {
        let ds = synth::zipf_sets(50, &synth::ZipfSetsParams::default(), 2);
        let p = tmp("sets");
        save(&ds, &p).unwrap();
        let back = load(&p).unwrap();
        std::fs::remove_file(&p).ok();
        assert_eq!(ds.sets, back.sets);
        assert_eq!(ds.labels, back.labels);
    }

    #[test]
    fn roundtrip_hybrid() {
        let ds = synth::products(60, &synth::ProductsParams::default(), 3);
        let p = tmp("hybrid");
        save(&ds, &p).unwrap();
        let back = load(&p).unwrap();
        std::fs::remove_file(&p).ok();
        assert_eq!(ds.dense, back.dense);
        assert_eq!(ds.sets, back.sets);
        assert_eq!(back.kind(), crate::data::FeatureKind::Hybrid);
    }

    #[test]
    fn failed_save_leaves_target_absent_or_valid() {
        // Atomic-publish contract: after an injected write failure the
        // target path holds either the complete previous content or nothing
        // — never a torn file.
        let old = synth::gaussian_mixture(30, 4, 2, 0.1, 5);
        let new = synth::gaussian_mixture(60, 4, 2, 0.1, 6);
        let p = tmp("atomic");
        save(&old, &p).unwrap();

        // Inject: the .tmp sibling is unwritable (it is a directory), so
        // the save fails before the rename — the old target must survive
        // bit-for-bit.
        let tmp_path = p.with_extension("tmp");
        std::fs::create_dir(&tmp_path).unwrap();
        assert!(save(&new, &p).is_err());
        std::fs::remove_dir(&tmp_path).unwrap();
        let back = load(&p).unwrap();
        assert_eq!(back.len(), old.len(), "failed save clobbered the target");
        assert_eq!(back.dense, old.dense);
        std::fs::remove_file(&p).ok();

        // Inject: the parent directory does not exist, so the save fails
        // with no prior target — the target must stay absent (no torn
        // partial file, no leaked .tmp).
        let missing = tmp("no_such_dir").join("ds.bin");
        assert!(save(&new, &missing).is_err());
        assert!(!missing.exists());
        assert!(!missing.with_extension("tmp").exists());
    }

    #[test]
    fn rejects_garbage_file() {
        let p = tmp("garbage");
        std::fs::write(&p, b"not a dataset").unwrap();
        assert!(load(&p).is_err());
        std::fs::remove_file(&p).ok();
    }

    /// Write `bytes` to a temp file and return the load error's full chain.
    fn err_of(name: &str, bytes: &[u8]) -> String {
        let p = tmp(name);
        std::fs::write(&p, bytes).unwrap();
        let e = format!("{:#}", load(&p).unwrap_err());
        std::fs::remove_file(&p).ok();
        e
    }

    #[test]
    fn bad_magic_is_diagnosed() {
        let e = err_of("badmagic", b"XDS1\0\0\0\0\0\0\0\0\0\0\0\0\0\0\0\0\0\0\0\0\0\0");
        assert!(e.contains("bad magic"), "got: {e}");
    }

    #[test]
    fn truncation_is_diagnosed_per_header_field() {
        let ds = synth::gaussian_mixture(40, 6, 3, 0.1, 9);
        let p = tmp("trunc_src");
        save(&ds, &p).unwrap();
        let bytes = std::fs::read(&p).unwrap();
        std::fs::remove_file(&p).ok();
        // Header layout: magic[0..4] n[4..12] dim[12..20] flags[20..22]
        // name_len[22..26] name... — cut inside each field and check the
        // error names the section.
        for (cut, want) in [
            (2usize, "reading magic"),
            (10, "reading point count"),
            (15, "reading dimension"),
            (21, "reading feature flags"),
            (24, "reading name length"),
            (bytes.len() - 1, "truncated or corrupt"),
        ] {
            let e = err_of("trunc", &bytes[..cut]);
            assert!(e.contains(want), "cut at {cut}: expected {want:?} in: {e}");
        }
    }

    #[test]
    fn absurd_header_fails_before_allocation() {
        // n·dim ≈ 2^80 dense values: the u128 size check must reject this
        // instantly rather than attempt the allocation.
        let mut bytes = MAGIC.to_vec();
        bytes.extend_from_slice(&(1u64 << 40).to_le_bytes()); // n
        bytes.extend_from_slice(&(1u64 << 40).to_le_bytes()); // dim
        bytes.extend_from_slice(&[0, 0]); // flags
        bytes.extend_from_slice(&0u32.to_le_bytes()); // name_len
        let e = err_of("huge_nd", &bytes);
        assert!(e.contains("truncated or corrupt"), "got: {e}");

        // A header claiming a 2 GiB dataset name fails on the name cap.
        let mut bytes = MAGIC.to_vec();
        bytes.extend_from_slice(&1u64.to_le_bytes()); // n
        bytes.extend_from_slice(&0u64.to_le_bytes()); // dim
        bytes.extend_from_slice(&[1, 0]); // flags: sets, no labels
        bytes.extend_from_slice(&(1u32 << 31).to_le_bytes()); // name_len
        let e = err_of("huge_name", &bytes);
        assert!(e.contains("dataset name"), "got: {e}");
    }

    #[test]
    fn corrupt_set_length_is_diagnosed() {
        // Valid header for one set-only point, then a set length field
        // claiming u32::MAX tokens — longer than the file itself.
        let mut bytes = MAGIC.to_vec();
        bytes.extend_from_slice(&1u64.to_le_bytes()); // n
        bytes.extend_from_slice(&0u64.to_le_bytes()); // dim
        bytes.extend_from_slice(&[1, 0]); // flags: sets, no labels
        bytes.extend_from_slice(&1u32.to_le_bytes()); // name_len
        bytes.push(b'x');
        bytes.extend_from_slice(&u32::MAX.to_le_bytes()); // set 0 length
        let e = err_of("setlen", &bytes);
        assert!(e.contains("set 0 claims"), "got: {e}");
    }

    #[test]
    fn truncated_set_block_names_the_set() {
        // Truncation past the minimum-size floor (sets are variable-length)
        // surfaces in the per-set read context, not a generic EOF.
        let ds = synth::zipf_sets(50, &synth::ZipfSetsParams::default(), 4);
        let p = tmp("settrunc_src");
        save(&ds, &p).unwrap();
        let bytes = std::fs::read(&p).unwrap();
        std::fs::remove_file(&p).ok();
        let e = err_of("settrunc", &bytes[..bytes.len() - 2]);
        assert!(e.contains("set 49"), "got: {e}");
    }
}
