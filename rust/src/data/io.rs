//! Binary dataset serialization.
//!
//! Layout (little endian):
//! ```text
//! magic "SDS1" | n u64 | dim u64 | has_sets u8 | has_labels u8 |
//! dense  f32 * n*dim |
//! [labels u32 * n] |
//! [sets: per point: len u32, tokens u32*len, weights f32*len]
//! ```
//! Used to persist generated datasets between experiment runs so the
//! expensive generators (10M-point GMMs) run once.

use crate::data::types::{Dataset, WeightedSet};
use anyhow::{bail, Context, Result};
use std::io::{BufReader, BufWriter, Read, Write};
use std::path::Path;

const MAGIC: &[u8; 4] = b"SDS1";

/// Write a dataset to `path`.
pub fn save(ds: &Dataset, path: &Path) -> Result<()> {
    let file = std::fs::File::create(path)
        .with_context(|| format!("creating {}", path.display()))?;
    let mut w = BufWriter::new(file);
    w.write_all(MAGIC)?;
    w.write_all(&(ds.len() as u64).to_le_bytes())?;
    w.write_all(&(ds.dim() as u64).to_le_bytes())?;
    w.write_all(&[!ds.sets.is_empty() as u8, !ds.labels.is_empty() as u8])?;
    let name = ds.name.as_bytes();
    w.write_all(&(name.len() as u32).to_le_bytes())?;
    w.write_all(name)?;
    for &x in &ds.dense {
        w.write_all(&x.to_le_bytes())?;
    }
    if !ds.labels.is_empty() {
        for &l in &ds.labels {
            w.write_all(&l.to_le_bytes())?;
        }
    }
    if !ds.sets.is_empty() {
        for s in &ds.sets {
            w.write_all(&(s.len() as u32).to_le_bytes())?;
            for &t in &s.tokens {
                w.write_all(&t.to_le_bytes())?;
            }
            for &wt in &s.weights {
                w.write_all(&wt.to_le_bytes())?;
            }
        }
    }
    w.flush()?;
    Ok(())
}

/// Read a dataset from `path`.
pub fn load(path: &Path) -> Result<Dataset> {
    let file =
        std::fs::File::open(path).with_context(|| format!("opening {}", path.display()))?;
    let mut r = BufReader::new(file);
    let mut magic = [0u8; 4];
    r.read_exact(&mut magic)?;
    if &magic != MAGIC {
        bail!("{}: not a stars dataset file", path.display());
    }
    let n = read_u64(&mut r)? as usize;
    let dim = read_u64(&mut r)? as usize;
    let mut flags = [0u8; 2];
    r.read_exact(&mut flags)?;
    let (has_sets, has_labels) = (flags[0] != 0, flags[1] != 0);
    let name_len = read_u32(&mut r)? as usize;
    let mut name_buf = vec![0u8; name_len];
    r.read_exact(&mut name_buf)?;
    let name = String::from_utf8(name_buf).context("dataset name not utf8")?;

    let mut dense = vec![0f32; n * dim];
    read_f32s(&mut r, &mut dense)?;
    let labels = if has_labels {
        let mut buf = vec![0u32; n];
        read_u32s(&mut r, &mut buf)?;
        buf
    } else {
        Vec::new()
    };
    let sets = if has_sets {
        let mut sets = Vec::with_capacity(n);
        for _ in 0..n {
            let len = read_u32(&mut r)? as usize;
            let mut tokens = vec![0u32; len];
            read_u32s(&mut r, &mut tokens)?;
            let mut weights = vec![0f32; len];
            read_f32s(&mut r, &mut weights)?;
            sets.push(WeightedSet { tokens, weights });
        }
        sets
    } else {
        Vec::new()
    };

    Ok(match (dim > 0, has_sets) {
        (true, true) => Dataset::hybrid(&name, dim, dense, sets, labels),
        (true, false) => Dataset::from_dense(&name, dim, dense, labels),
        (false, true) => Dataset::from_sets(&name, sets, labels),
        (false, false) => bail!("dataset has neither dense nor set features"),
    })
}

fn read_u64<R: Read>(r: &mut R) -> Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

fn read_u32<R: Read>(r: &mut R) -> Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_u32s<R: Read>(r: &mut R, out: &mut [u32]) -> Result<()> {
    let mut buf = vec![0u8; out.len() * 4];
    r.read_exact(&mut buf)?;
    for (i, c) in buf.chunks_exact(4).enumerate() {
        out[i] = u32::from_le_bytes(c.try_into().unwrap());
    }
    Ok(())
}

fn read_f32s<R: Read>(r: &mut R, out: &mut [f32]) -> Result<()> {
    let mut buf = vec![0u8; out.len() * 4];
    r.read_exact(&mut buf)?;
    for (i, c) in buf.chunks_exact(4).enumerate() {
        out[i] = f32::from_le_bytes(c.try_into().unwrap());
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;

    fn tmp(name: &str) -> std::path::PathBuf {
        let mut p = std::env::temp_dir();
        p.push(format!("stars_io_test_{name}_{}", std::process::id()));
        p
    }

    #[test]
    fn roundtrip_dense() {
        let ds = synth::gaussian_mixture(100, 8, 4, 0.1, 1);
        let p = tmp("dense");
        save(&ds, &p).unwrap();
        let back = load(&p).unwrap();
        std::fs::remove_file(&p).ok();
        assert_eq!(ds.dense, back.dense);
        assert_eq!(ds.labels, back.labels);
        assert_eq!(ds.name, back.name);
        assert_eq!(ds.norms, back.norms);
    }

    #[test]
    fn roundtrip_sets() {
        let ds = synth::zipf_sets(50, &synth::ZipfSetsParams::default(), 2);
        let p = tmp("sets");
        save(&ds, &p).unwrap();
        let back = load(&p).unwrap();
        std::fs::remove_file(&p).ok();
        assert_eq!(ds.sets, back.sets);
        assert_eq!(ds.labels, back.labels);
    }

    #[test]
    fn roundtrip_hybrid() {
        let ds = synth::products(60, &synth::ProductsParams::default(), 3);
        let p = tmp("hybrid");
        save(&ds, &p).unwrap();
        let back = load(&p).unwrap();
        std::fs::remove_file(&p).ok();
        assert_eq!(ds.dense, back.dense);
        assert_eq!(ds.sets, back.sets);
        assert_eq!(back.kind(), crate::data::FeatureKind::Hybrid);
    }

    #[test]
    fn rejects_garbage_file() {
        let p = tmp("garbage");
        std::fs::write(&p, b"not a dataset").unwrap();
        assert!(load(&p).is_err());
        std::fs::remove_file(&p).ok();
    }
}
