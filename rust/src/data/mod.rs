//! Datasets: point storage plus synthetic generators standing in for the
//! paper's corpora (MNIST, Wikipedia, Amazon2m, Random1B/10B).
//!
//! Each generator documents the substitution it makes; see DESIGN.md §3.

pub mod types;
pub mod recipe;
pub mod synth;
pub mod io;
pub mod mnist;

pub use types::{Dataset, FeatureKind, TokenVocab, WeightedSet};
