//! Batched scorers backed by AOT artifacts.
//!
//! [`CosineScorer`] wraps the Pallas pairwise-cosine kernel
//! (python/compile/kernels/pairwise.py): it scores `L` leaders against a
//! block of `B` candidates in one PJRT dispatch, padding ragged inputs. The
//! fixed (L, B, dim) shape comes from `artifacts/meta.json`.
//!
//! [`SimHashSketcher`] wraps the Pallas SimHash kernel: a block of points ×
//! the (constant-folded) hyperplane matrix → sign bits.

use super::engine::{literal_f32, Engine, Executable};
use super::ArtifactMeta;
use anyhow::Result;
use std::sync::Mutex;

/// PJRT-backed leaders×block cosine scorer with fixed artifact shapes.
pub struct CosineScorer {
    exe: Mutex<Executable>,
    /// Max leaders per dispatch.
    pub leaders: usize,
    /// Max candidates per dispatch.
    pub block: usize,
    /// Padded feature dimension the artifact was compiled for.
    pub dim: usize,
    calls: std::sync::atomic::AtomicU64,
}

impl CosineScorer {
    /// Load from artifacts.
    pub fn load(engine: &Engine, meta: &ArtifactMeta) -> Result<CosineScorer> {
        let exe = engine.load_hlo_text(&meta.file("cosine_scorer")?)?;
        Ok(CosineScorer {
            exe: Mutex::new(exe),
            leaders: meta.usize_field("cosine_scorer", "leaders")?,
            block: meta.usize_field("cosine_scorer", "block")?,
            dim: meta.usize_field("cosine_scorer", "dim")?,
            calls: Default::default(),
        })
    }

    /// Number of PJRT dispatches so far (for perf accounting).
    pub fn dispatches(&self) -> u64 {
        self.calls.load(std::sync::atomic::Ordering::Relaxed)
    }

    /// Score `nl` leader rows against `nb` candidate rows.
    ///
    /// `leaders`/`cands` are row-major with the *source* dimension `src_dim ≤
    /// self.dim`; rows are zero-padded up to the artifact dim. Output is
    /// row-major (nl × nb). Inputs larger than the artifact shape are split
    /// over multiple dispatches.
    pub fn score(
        &self,
        leaders: &[f32],
        nl: usize,
        cands: &[f32],
        nb: usize,
        src_dim: usize,
    ) -> Result<Vec<f32>> {
        anyhow::ensure!(src_dim <= self.dim, "src dim {} > artifact dim {}", src_dim, self.dim);
        anyhow::ensure!(leaders.len() == nl * src_dim && cands.len() == nb * src_dim);
        let mut out = vec![0f32; nl * nb];
        for l0 in (0..nl).step_by(self.leaders) {
            let lcount = (nl - l0).min(self.leaders);
            let lpad = pad_block(
                &leaders[l0 * src_dim..(l0 + lcount) * src_dim],
                lcount,
                src_dim,
                self.leaders,
                self.dim,
            );
            for b0 in (0..nb).step_by(self.block) {
                let bcount = (nb - b0).min(self.block);
                let bpad = pad_block(
                    &cands[b0 * src_dim..(b0 + bcount) * src_dim],
                    bcount,
                    src_dim,
                    self.block,
                    self.dim,
                );
                let ll = literal_f32(&lpad, &[self.leaders as i64, self.dim as i64])?;
                let bl = literal_f32(&bpad, &[self.block as i64, self.dim as i64])?;
                self.calls
                    .fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                let scores = self.exe.lock().unwrap().run_f32(&[ll, bl])?;
                // scores is (leaders x block) padded; copy the live region.
                for li in 0..lcount {
                    let src = &scores[li * self.block..li * self.block + bcount];
                    let dst =
                        &mut out[(l0 + li) * nb + b0..(l0 + li) * nb + b0 + bcount];
                    dst.copy_from_slice(src);
                }
            }
        }
        Ok(out)
    }
}

/// PJRT-backed SimHash sketcher: block of points → sign bits (0/1 f32).
pub struct SimHashSketcher {
    exe: Mutex<Executable>,
    /// Points per dispatch.
    pub block: usize,
    /// Padded input dimension.
    pub dim: usize,
    /// Bits per sketch.
    pub bits: usize,
}

impl SimHashSketcher {
    /// Load from artifacts.
    pub fn load(engine: &Engine, meta: &ArtifactMeta) -> Result<SimHashSketcher> {
        let exe = engine.load_hlo_text(&meta.file("simhash_sketch")?)?;
        Ok(SimHashSketcher {
            exe: Mutex::new(exe),
            block: meta.usize_field("simhash_sketch", "block")?,
            dim: meta.usize_field("simhash_sketch", "dim")?,
            bits: meta.usize_field("simhash_sketch", "bits")?,
        })
    }

    /// Sketch `n` rows of `src_dim` features into packed u64 keys
    /// (bit t of the key = sign of hyperplane t).
    pub fn sketch(&self, rows: &[f32], n: usize, src_dim: usize) -> Result<Vec<u64>> {
        anyhow::ensure!(src_dim <= self.dim && self.bits <= 64);
        anyhow::ensure!(rows.len() == n * src_dim);
        let mut keys = vec![0u64; n];
        for r0 in (0..n).step_by(self.block) {
            let count = (n - r0).min(self.block);
            let pad = pad_block(
                &rows[r0 * src_dim..(r0 + count) * src_dim],
                count,
                src_dim,
                self.block,
                self.dim,
            );
            let lit = literal_f32(&pad, &[self.block as i64, self.dim as i64])?;
            let bits = self.exe.lock().unwrap().run_f32(&[lit])?;
            for i in 0..count {
                let mut key = 0u64;
                for t in 0..self.bits {
                    if bits[i * self.bits + t] > 0.5 {
                        key |= 1 << t;
                    }
                }
                keys[r0 + i] = key;
            }
        }
        Ok(keys)
    }
}

/// Zero-pad a (rows × src_dim) block to (pad_rows × pad_dim).
fn pad_block(
    data: &[f32],
    rows: usize,
    src_dim: usize,
    pad_rows: usize,
    pad_dim: usize,
) -> Vec<f32> {
    let mut out = vec![0f32; pad_rows * pad_dim];
    for r in 0..rows {
        out[r * pad_dim..r * pad_dim + src_dim]
            .copy_from_slice(&data[r * src_dim..(r + 1) * src_dim]);
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pad_block_layout() {
        let data = [1.0, 2.0, 3.0, 4.0]; // 2 rows x 2 dim
        let p = pad_block(&data, 2, 2, 3, 4);
        assert_eq!(p.len(), 12);
        assert_eq!(&p[0..4], &[1.0, 2.0, 0.0, 0.0]);
        assert_eq!(&p[4..8], &[3.0, 4.0, 0.0, 0.0]);
        assert_eq!(&p[8..12], &[0.0; 4]);
    }
}
