//! PJRT runtime: loads the HLO artifacts produced by `make artifacts`
//! (python/compile/aot.py) and executes them from the rust hot path.
//!
//! Python never runs at request time. The interchange format is **HLO text**:
//! jax ≥ 0.5 serializes protos with 64-bit instruction ids that the crate's
//! xla_extension 0.5.1 rejects, while the text parser reassigns ids.

// The real engine needs the `xla` crate, which is deliberately not declared
// in Cargo.toml (see the notes there). This guard turns the otherwise-opaque
// "unresolved import `xla`" into an actionable message: add the vendored
// `xla` dependency, then delete this compile_error.
#[cfg(feature = "pjrt")]
compile_error!(
    "the `pjrt` feature requires the vendored `xla` crate: add `xla` to \
     [dependencies] in rust/Cargo.toml and remove this guard (runtime/mod.rs)"
);
#[cfg(feature = "pjrt")]
mod engine;
// Without the `pjrt` feature (and the vendored `xla` crate it requires) the
// engine is a stub with the same API whose loaders return a descriptive
// error — see Cargo.toml. Scorer/model wrappers compile unchanged on top.
#[cfg(not(feature = "pjrt"))]
#[path = "engine_stub.rs"]
mod engine;
mod scorer;
mod learned;

pub use engine::{literal_f32, Engine, Executable};
pub use learned::{LearnedMeta, LearnedModel};
pub use scorer::{CosineScorer, SimHashSketcher};

use crate::util::json::{self, Json};
use anyhow::{Context, Result};
use std::path::{Path, PathBuf};

/// Parsed `artifacts/meta.json`: shapes and file names of every artifact.
#[derive(Clone, Debug)]
pub struct ArtifactMeta {
    /// Directory containing the artifacts.
    pub dir: PathBuf,
    /// Raw parsed JSON.
    pub raw: Json,
}

impl ArtifactMeta {
    /// Load `<dir>/meta.json`.
    pub fn load(dir: &Path) -> Result<ArtifactMeta> {
        let path = dir.join("meta.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts`)", path.display()))?;
        let raw = json::parse(&text).context("parsing meta.json")?;
        Ok(ArtifactMeta {
            dir: dir.to_path_buf(),
            raw,
        })
    }

    /// Default artifact directory: `$STARS_ARTIFACTS` or `./artifacts`.
    pub fn default_dir() -> PathBuf {
        std::env::var("STARS_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }

    /// Path of the artifact file for a named entry.
    pub fn file(&self, entry: &str) -> Result<PathBuf> {
        let file = self
            .raw
            .get(entry)
            .and_then(|e| e.get("file"))
            .and_then(|f| f.as_str())
            .with_context(|| format!("meta.json missing {entry}.file"))?;
        Ok(self.dir.join(file))
    }

    /// Integer field of an entry.
    pub fn usize_field(&self, entry: &str, field: &str) -> Result<usize> {
        self.raw
            .get(entry)
            .and_then(|e| e.get(field))
            .and_then(|v| v.as_usize())
            .with_context(|| format!("meta.json missing {entry}.{field}"))
    }
}
