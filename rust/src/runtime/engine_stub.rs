//! Stub PJRT engine, compiled when the `pjrt` feature is disabled.
//!
//! Mirrors the API surface of `engine.rs` exactly (Engine, Executable,
//! literal_f32) so `runtime::scorer` / `runtime::learned` and the CLI's
//! `smoke` subcommand compile without the `xla` crate closure. Every loader
//! fails with a descriptive error, so artifact-dependent paths degrade the
//! same way a missing `artifacts/` directory does: callers skip with a
//! message instead of failing the build.

use anyhow::{bail, Result};
use std::path::Path;

/// Opaque stand-in for `xla::Literal`. Carries the validated element count so
/// [`literal_f32`] keeps the same shape-checking behavior as the real engine.
#[derive(Clone, Debug)]
pub struct Literal {
    _elems: usize,
}

/// Stub PJRT CPU client.
pub struct Engine {
    _priv: (),
}

impl Engine {
    /// Always fails: the real client needs the `pjrt` feature + `xla` crate.
    pub fn cpu() -> Result<Engine> {
        bail!("PJRT runtime unavailable: built without the `pjrt` feature (see rust/Cargo.toml)")
    }

    /// Platform name (for logs).
    pub fn platform(&self) -> String {
        "stub".to_string()
    }

    /// Always fails (no compiler without PJRT).
    pub fn load_hlo_text(&self, path: &Path) -> Result<Executable> {
        bail!(
            "cannot compile {}: built without the `pjrt` feature",
            path.display()
        )
    }
}

/// Stub compiled computation. Never constructed — [`Engine::cpu`] and
/// [`Engine::load_hlo_text`] both fail first — but the methods must
/// typecheck for the scorer/model wrappers.
pub struct Executable {
    _priv: (),
}

impl Executable {
    /// Unreachable at runtime (no `Executable` can exist without PJRT).
    pub fn run(&self, _inputs: &[Literal]) -> Result<Vec<Literal>> {
        bail!("PJRT runtime unavailable: built without the `pjrt` feature")
    }

    /// Unreachable at runtime (no `Executable` can exist without PJRT).
    pub fn run_f32(&self, _inputs: &[Literal]) -> Result<Vec<f32>> {
        bail!("PJRT runtime unavailable: built without the `pjrt` feature")
    }
}

/// Shape-checked literal constructor, same contract as the real engine.
pub fn literal_f32(data: &[f32], dims: &[i64]) -> Result<Literal> {
    let expected: i64 = dims.iter().product();
    anyhow::ensure!(
        expected as usize == data.len(),
        "literal shape {:?} != data len {}",
        dims,
        data.len()
    );
    Ok(Literal { _elems: data.len() })
}
