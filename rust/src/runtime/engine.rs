//! PJRT client wrapper: compile-once, execute-many.

use anyhow::{Context, Result};
use std::path::Path;

/// A PJRT CPU client plus compile cache.
pub struct Engine {
    client: xla::PjRtClient,
}

impl Engine {
    /// Create a CPU engine.
    pub fn cpu() -> Result<Engine> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        Ok(Engine { client })
    }

    /// Platform name (for logs).
    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load an HLO **text** artifact and compile it.
    pub fn load_hlo_text(&self, path: &Path) -> Result<Executable> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 artifact path")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", path.display()))?;
        Ok(Executable { exe })
    }
}

/// A compiled computation. Inputs are `xla::Literal`s; the output is the
/// flattened tuple the jax lowering produced (`return_tuple=True`).
pub struct Executable {
    exe: xla::PjRtLoadedExecutable,
}

// SAFETY: `PjRtLoadedExecutable` holds a non-atomic `Rc<PjRtClientInternal>`,
// which makes it `!Send`/`!Sync` even though the underlying PJRT CPU client
// is thread-safe for execution. Callers in this crate uphold the required
// discipline: every `Executable` is owned behind a `Mutex` (see
// runtime::scorer / runtime::learned) and ALL PJRT interaction — execute,
// buffer fetch, literal conversion — happens while that lock is held, so the
// `Rc` refcount is never touched concurrently.
unsafe impl Send for Executable {}
unsafe impl Sync for Executable {}

impl Executable {
    /// Execute with the given inputs; returns the untupled outputs.
    pub fn run(&self, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let result = self
            .exe
            .execute::<xla::Literal>(inputs)
            .context("PJRT execute")?;
        let lit = result[0][0]
            .to_literal_sync()
            .context("fetching result literal")?;
        let outs = lit.to_tuple().context("untupling result")?;
        Ok(outs)
    }

    /// Execute and return the single f32 tensor output as a flat Vec.
    pub fn run_f32(&self, inputs: &[xla::Literal]) -> Result<Vec<f32>> {
        let outs = self.run(inputs)?;
        anyhow::ensure!(outs.len() == 1, "expected 1 output, got {}", outs.len());
        outs[0].to_vec::<f32>().context("reading f32 output")
    }
}

/// Build an f32 literal of the given shape from a flat slice.
pub fn literal_f32(data: &[f32], dims: &[i64]) -> Result<xla::Literal> {
    let expected: i64 = dims.iter().product();
    anyhow::ensure!(
        expected as usize == data.len(),
        "literal shape {:?} != data len {}",
        dims,
        data.len()
    );
    Ok(xla::Literal::vec1(data).reshape(dims)?)
}
