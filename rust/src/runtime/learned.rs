//! Learned pairwise similarity model executor.
//!
//! The model (python/compile/model.py, following Grale / paper §C.2, D.3)
//! takes per-side features — product embedding + hashed co-purchase
//! multi-hot — plus three pairwise features (embedding cosine, co-purchase
//! indicator, co-purchase Jaccard), and outputs a similarity in (0, 1).
//! It is trained at artifact-build time on synthetic same/different-category
//! pairs drawn from the *same shared recipe* the rust generators use, then
//! frozen into HLO. This module featurizes pairs and executes the artifact
//! in fixed-size batches.

use super::engine::{literal_f32, Engine, Executable};
use super::ArtifactMeta;
use crate::data::types::Dataset;
use crate::sim::{cosine, jaccard};
use anyhow::Result;
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Knuth multiplicative hash of a co-purchase token into `buckets`.
/// Mirrored in python/compile/model.py — keep in sync.
#[inline]
pub fn hash_token(token: u32, buckets: usize) -> usize {
    (token.wrapping_mul(2654435761) as usize) % buckets
}

/// Shapes of the learned-model artifact.
#[derive(Clone, Copy, Debug)]
pub struct LearnedMeta {
    /// Pairs per PJRT dispatch.
    pub batch: usize,
    /// Embedding dimension.
    pub dim: usize,
    /// Co-purchase hash buckets.
    pub hash_buckets: usize,
    /// Number of pairwise features.
    pub pair_feats: usize,
}

/// Recycle the PJRT client/executable after this many dispatches.
///
/// xla_extension 0.5.1's CPU client retains a small allocation per
/// dispatch, so jobs issuing hundreds of thousands of dispatches (R=400
/// learned builds) grow RSS without bound. Rebuilding the client from the
/// stored HLO artifact releases everything the old client accumulated;
/// at ~50k dispatches the amortized rebuild cost is noise (one compile
/// per tens of seconds of dispatch work). See the EXPERIMENTS.md
/// known-issue note.
pub const RECYCLE_EVERY: u64 = 50_000;

/// PJRT-backed learned similarity model.
pub struct LearnedModel {
    exe: Mutex<Executable>,
    /// HLO artifact path, kept so the executable can be recompiled on a
    /// fresh client when the recycle threshold trips.
    hlo_path: PathBuf,
    /// Artifact shapes.
    pub meta: LearnedMeta,
    /// Holdout AUC recorded by the python training run (from meta.json).
    pub auc: f64,
    dispatches: AtomicU64,
    /// Dispatches since the last client recycle.
    since_recycle: AtomicU64,
    /// Completed client recycles.
    engine_recycles: AtomicU64,
}

impl LearnedModel {
    /// Load from artifacts.
    pub fn load(engine: &Engine, meta: &ArtifactMeta) -> Result<LearnedModel> {
        let hlo_path = meta.file("learned_sim")?;
        let exe = engine.load_hlo_text(&hlo_path)?;
        let auc = meta
            .raw
            .get("learned_sim")
            .and_then(|e| e.get("auc"))
            .and_then(|v| v.as_f64())
            .unwrap_or(f64::NAN);
        Ok(LearnedModel {
            exe: Mutex::new(exe),
            hlo_path,
            meta: LearnedMeta {
                batch: meta.usize_field("learned_sim", "batch")?,
                dim: meta.usize_field("learned_sim", "dim")?,
                hash_buckets: meta.usize_field("learned_sim", "hash_buckets")?,
                pair_feats: meta.usize_field("learned_sim", "pair_feats")?,
            },
            auc,
            dispatches: Default::default(),
            since_recycle: Default::default(),
            engine_recycles: Default::default(),
        })
    }

    /// PJRT dispatch count (perf accounting).
    pub fn dispatches(&self) -> u64 {
        self.dispatches.load(Ordering::Relaxed)
    }

    /// How many times the PJRT client has been recycled (perf accounting;
    /// one recycle per [`RECYCLE_EVERY`] dispatches).
    pub fn engine_recycles(&self) -> u64 {
        self.engine_recycles.load(Ordering::Relaxed)
    }

    /// Recompile the executable on a fresh CPU client when enough
    /// dispatches have accumulated, releasing everything the old client
    /// retained. Must be called with the `exe` lock held (the swap and all
    /// PJRT interaction share that lock — see the `Send`/`Sync` note in
    /// runtime::engine). A failed rebuild keeps serving on the old client:
    /// the leak workaround must never turn a working model into an error.
    fn maybe_recycle(&self, exe: &mut Executable) {
        if self.since_recycle.fetch_add(1, Ordering::Relaxed) + 1 < RECYCLE_EVERY {
            return;
        }
        if let Ok(fresh) = Engine::cpu().and_then(|e| e.load_hlo_text(&self.hlo_path)) {
            *exe = fresh;
            self.engine_recycles.fetch_add(1, Ordering::Relaxed);
        }
        self.since_recycle.store(0, Ordering::Relaxed);
    }

    /// Score arbitrary pairs of dataset points. Pads the final batch.
    ///
    /// The PJRT client is recycled every [`RECYCLE_EVERY`] dispatches to
    /// cap the per-dispatch RSS growth of xla_extension 0.5.1's CPU
    /// client (builds without the `pjrt` feature never construct a model,
    /// so the recycle path is compiled but unreachable there).
    pub fn score(&self, ds: &Dataset, pairs: &[(u32, u32)]) -> Result<Vec<f32>> {
        let m = self.meta;
        anyhow::ensure!(
            ds.dim() == m.dim,
            "dataset dim {} != model dim {}",
            ds.dim(),
            m.dim
        );
        let mut out = Vec::with_capacity(pairs.len());
        let mut ea = vec![0f32; m.batch * m.dim];
        let mut eb = vec![0f32; m.batch * m.dim];
        let mut ha = vec![0f32; m.batch * m.hash_buckets];
        let mut hb = vec![0f32; m.batch * m.hash_buckets];
        let mut pf = vec![0f32; m.batch * m.pair_feats];
        for chunk in pairs.chunks(m.batch) {
            ea.fill(0.0);
            eb.fill(0.0);
            ha.fill(0.0);
            hb.fill(0.0);
            pf.fill(0.0);
            for (k, &(i, j)) in chunk.iter().enumerate() {
                let (i, j) = (i as usize, j as usize);
                ea[k * m.dim..(k + 1) * m.dim].copy_from_slice(ds.row(i));
                eb[k * m.dim..(k + 1) * m.dim].copy_from_slice(ds.row(j));
                for &t in &ds.set(i).tokens {
                    ha[k * m.hash_buckets + hash_token(t, m.hash_buckets)] = 1.0;
                }
                for &t in &ds.set(j).tokens {
                    hb[k * m.hash_buckets + hash_token(t, m.hash_buckets)] = 1.0;
                }
                let jac = jaccard(ds.set(i), ds.set(j));
                pf[k * m.pair_feats] = cosine(ds.row(i), ds.row(j));
                pf[k * m.pair_feats + 1] = if jac > 0.0 { 1.0 } else { 0.0 };
                pf[k * m.pair_feats + 2] = jac;
            }
            let inputs = [
                literal_f32(&ea, &[m.batch as i64, m.dim as i64])?,
                literal_f32(&ha, &[m.batch as i64, m.hash_buckets as i64])?,
                literal_f32(&eb, &[m.batch as i64, m.dim as i64])?,
                literal_f32(&hb, &[m.batch as i64, m.hash_buckets as i64])?,
                literal_f32(&pf, &[m.batch as i64, m.pair_feats as i64])?,
            ];
            self.dispatches.fetch_add(1, Ordering::Relaxed);
            let mut exe = self.exe.lock().unwrap();
            self.maybe_recycle(&mut exe);
            let scores = exe.run_f32(&inputs)?;
            drop(exe);
            out.extend_from_slice(&scores[..chunk.len()]);
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn hash_token_in_range_and_deterministic() {
        for t in [0u32, 1, 17, 9999, u32::MAX] {
            let h = hash_token(t, 64);
            assert!(h < 64);
            assert_eq!(h, hash_token(t, 64));
        }
    }

    #[test]
    fn hash_token_spreads() {
        let mut counts = vec![0usize; 64];
        for t in 0..6400u32 {
            counts[hash_token(t, 64)] += 1;
        }
        assert!(counts.iter().all(|&c| c > 0), "some bucket never hit");
    }
}
