//! Compressed sparse row adjacency with the paper's degree threshold.

use super::edges::{Edge, Graph};
use crate::util::topk::TopK;

/// Undirected CSR adjacency. Optionally degree-capped: each node keeps only
/// its `cap` most-similar incident edges (the paper caps at 250), after which
/// an edge survives if *either* endpoint kept it.
#[derive(Clone, Debug)]
pub struct Csr {
    offsets: Vec<usize>,
    /// Neighbor ids, grouped per node.
    neighbors: Vec<u32>,
    /// Edge weights, parallel to `neighbors`.
    weights: Vec<f32>,
}

impl Csr {
    /// Build from a graph without any degree cap.
    pub fn new(g: &Graph) -> Csr {
        Self::build(g.num_nodes(), g.edges())
    }

    /// Build keeping only each node's `cap` most-similar neighbors.
    /// An edge is retained if either endpoint ranks it within its cap —
    /// matching the paper's "keep the 250 closest points for each node".
    pub fn with_degree_cap(g: &Graph, cap: usize) -> Csr {
        let n = g.num_nodes();
        let mut keep: Vec<TopK<u32>> = (0..n).map(|_| TopK::new(cap)).collect();
        for (idx, e) in g.edges().iter().enumerate() {
            keep[e.u as usize].push(e.w, idx as u32);
            keep[e.v as usize].push(e.w, idx as u32);
        }
        let mut kept = vec![false; g.num_edges()];
        for t in keep {
            for (_, idx) in t.into_sorted() {
                kept[idx as usize] = true;
            }
        }
        let edges: Vec<Edge> = g
            .edges()
            .iter()
            .zip(&kept)
            .filter(|(_, &k)| k)
            .map(|(e, _)| *e)
            .collect();
        Self::build(n, &edges)
    }

    fn build(n: usize, edges: &[Edge]) -> Csr {
        let mut deg = vec![0usize; n];
        for e in edges {
            deg[e.u as usize] += 1;
            deg[e.v as usize] += 1;
        }
        let mut offsets = vec![0usize; n + 1];
        for i in 0..n {
            offsets[i + 1] = offsets[i] + deg[i];
        }
        let mut neighbors = vec![0u32; offsets[n]];
        let mut weights = vec![0f32; offsets[n]];
        let mut cursor = offsets.clone();
        for e in edges {
            neighbors[cursor[e.u as usize]] = e.v;
            weights[cursor[e.u as usize]] = e.w;
            cursor[e.u as usize] += 1;
            neighbors[cursor[e.v as usize]] = e.u;
            weights[cursor[e.v as usize]] = e.w;
            cursor[e.v as usize] += 1;
        }
        Csr {
            offsets,
            neighbors,
            weights,
        }
    }

    /// Reassemble from flat arrays (snapshot persistence). The caller must
    /// hand back exactly what [`Csr::offset_slice`] / [`Csr::neighbor_slice`]
    /// / [`Csr::weight_slice`] exported; shape invariants are re-checked so a
    /// corrupted file cannot produce an index-out-of-bounds panic later.
    pub(crate) fn from_raw_parts(offsets: Vec<usize>, neighbors: Vec<u32>, weights: Vec<f32>) -> Csr {
        assert!(!offsets.is_empty(), "CSR offsets must have n+1 entries");
        assert_eq!(neighbors.len(), weights.len(), "CSR neighbor/weight length mismatch");
        assert_eq!(*offsets.last().unwrap(), neighbors.len(), "CSR final offset != edge count");
        assert!(offsets.windows(2).all(|w| w[0] <= w[1]), "CSR offsets must be non-decreasing");
        let n = (offsets.len() - 1) as u32;
        assert!(neighbors.iter().all(|&v| v < n), "CSR neighbor id out of range");
        Csr {
            offsets,
            neighbors,
            weights,
        }
    }

    /// Flat per-node offsets (`n + 1` entries) — snapshot persistence.
    pub(crate) fn offset_slice(&self) -> &[usize] {
        &self.offsets
    }

    /// Flat neighbor ids, grouped per node — snapshot persistence.
    pub(crate) fn neighbor_slice(&self) -> &[u32] {
        &self.neighbors
    }

    /// Flat edge weights, parallel to the neighbor ids — snapshot
    /// persistence.
    pub(crate) fn weight_slice(&self) -> &[f32] {
        &self.weights
    }

    /// Number of nodes.
    pub fn num_nodes(&self) -> usize {
        self.offsets.len() - 1
    }

    /// Number of undirected edges.
    pub fn num_edges(&self) -> usize {
        self.neighbors.len() / 2
    }

    /// Degree of node `u`.
    pub fn degree(&self, u: u32) -> usize {
        self.offsets[u as usize + 1] - self.offsets[u as usize]
    }

    /// Neighbors of `u` with weights.
    pub fn neighbors(&self, u: u32) -> impl Iterator<Item = (u32, f32)> + '_ {
        let r = self.offsets[u as usize]..self.offsets[u as usize + 1];
        self.neighbors[r.clone()]
            .iter()
            .copied()
            .zip(self.weights[r].iter().copied())
    }

    /// Estimated heap bytes of the adjacency arrays (offsets + neighbors +
    /// weights) — serving-snapshot memory telemetry.
    pub fn heap_bytes(&self) -> usize {
        self.offsets.len() * std::mem::size_of::<usize>()
            + self.neighbors.len() * std::mem::size_of::<u32>()
            + self.weights.len() * std::mem::size_of::<f32>()
    }

    /// Maximum degree.
    pub fn max_degree(&self) -> usize {
        (0..self.num_nodes() as u32)
            .map(|u| self.degree(u))
            .max()
            .unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn path_graph() -> Graph {
        Graph::from_edges(
            4,
            vec![
                Edge::new(0, 1, 0.9),
                Edge::new(1, 2, 0.8),
                Edge::new(2, 3, 0.7),
            ],
        )
    }

    #[test]
    fn adjacency_roundtrip() {
        let csr = Csr::new(&path_graph());
        assert_eq!(csr.num_nodes(), 4);
        assert_eq!(csr.num_edges(), 3);
        assert_eq!(csr.degree(1), 2);
        let n1: Vec<(u32, f32)> = csr.neighbors(1).collect();
        assert!(n1.contains(&(0, 0.9)) && n1.contains(&(2, 0.8)));
        assert_eq!(csr.max_degree(), 2);
    }

    #[test]
    fn degree_cap_keeps_best_edges() {
        // Clique on 6 nodes with distinct weights; cap 2. Under the
        // either-endpoint rule every edge kept by *some* endpoint survives,
        // so total edges shrink but no node's best-2 are ever lost.
        let mut edges = Vec::new();
        for u in 0..6u32 {
            for v in (u + 1)..6 {
                edges.push(Edge::new(u, v, (u * 6 + v) as f32 / 36.0));
            }
        }
        let g = Graph::from_edges(6, edges);
        let csr = Csr::with_degree_cap(&g, 2);
        assert!(csr.num_edges() < g.num_edges());
        // Every node retains its two best incident edges.
        let full = Csr::new(&g);
        for u in 0..6u32 {
            let mut best: Vec<f32> = full.neighbors(u).map(|(_, w)| w).collect();
            best.sort_by(|a, b| b.partial_cmp(a).unwrap());
            let kept: Vec<f32> = csr.neighbors(u).map(|(_, w)| w).collect();
            for want in &best[..2] {
                assert!(kept.contains(want), "node {u} lost a top-2 edge");
            }
        }
    }

    #[test]
    fn degree_cap_or_semantics() {
        // Edge (0,1) is node 0's worst but node 1's only edge: must survive.
        let g = Graph::from_edges(
            4,
            vec![
                Edge::new(0, 1, 0.1),
                Edge::new(0, 2, 0.9),
                Edge::new(0, 3, 0.8),
            ],
        );
        let csr = Csr::with_degree_cap(&g, 2);
        assert!(
            csr.neighbors(1).any(|(v, _)| v == 0),
            "edge kept by the low-degree endpoint was dropped"
        );
    }

    #[test]
    fn raw_parts_roundtrip_preserves_adjacency() {
        let csr = Csr::new(&path_graph());
        let back = Csr::from_raw_parts(
            csr.offset_slice().to_vec(),
            csr.neighbor_slice().to_vec(),
            csr.weight_slice().to_vec(),
        );
        assert_eq!(back.num_nodes(), csr.num_nodes());
        assert_eq!(back.num_edges(), csr.num_edges());
        for u in 0..csr.num_nodes() as u32 {
            let a: Vec<(u32, f32)> = csr.neighbors(u).collect();
            let b: Vec<(u32, f32)> = back.neighbors(u).collect();
            assert_eq!(a, b, "node {u}");
        }
    }

    #[test]
    #[should_panic(expected = "CSR final offset")]
    fn raw_parts_rejects_inconsistent_shapes() {
        Csr::from_raw_parts(vec![0, 2], vec![1], vec![0.5]);
    }

    #[test]
    fn empty_graph() {
        let g = Graph::from_edges(3, vec![]);
        let csr = Csr::new(&g);
        assert_eq!(csr.num_edges(), 0);
        assert_eq!(csr.degree(0), 0);
        assert_eq!(csr.max_degree(), 0);
    }
}
