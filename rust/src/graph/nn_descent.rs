//! NN-Descent local-search refinement (Dong, Moses & Li, WWW 2011 — the
//! paper's reference [17] for "local search" graph-building techniques).
//!
//! Given any starter graph (e.g. a Stars two-hop spanner), iteratively
//! propose neighbor-of-neighbor candidates and keep each node's best k.
//! This converts two-hop reachability into *direct* k-NN edges at the cost
//! of extra comparisons — useful when a downstream consumer needs a true
//! k-NN graph rather than a spanner, and a natural complement to Stars: the
//! spanner supplies a high-recall candidate pool so NN-Descent converges in
//! one or two sweeps instead of from random initialization.

use crate::ampc::CostLedger;
use crate::data::types::Dataset;
use crate::graph::{Csr, Edge, Graph};
use crate::sim::Similarity;
use crate::util::fxhash::FxHashSet;
use crate::util::topk::TopK;

/// Refinement report.
#[derive(Clone, Copy, Debug, Default)]
pub struct RefineStats {
    /// Sweeps executed.
    pub sweeps: usize,
    /// Candidate similarity evaluations performed.
    pub comparisons: u64,
    /// Neighbor-list replacements in the final sweep.
    pub last_updates: u64,
}

/// Refine `g` into a k-NN graph by NN-Descent sweeps.
///
/// Each sweep proposes, for every node, its neighbors' neighbors as
/// candidates, scores the unseen ones, and keeps the best `k`. Stops after
/// `max_sweeps` or when a sweep improves fewer than `min_updates` lists.
pub fn nn_descent(
    ds: &Dataset,
    sim: &dyn Similarity,
    g: &Graph,
    k: usize,
    max_sweeps: usize,
    ledger: &CostLedger,
) -> (Graph, RefineStats) {
    let n = g.num_nodes();
    // Current best-k lists, seeded from the starter graph.
    let mut best: Vec<TopK<u32>> = (0..n).map(|_| TopK::new(k)).collect();
    for e in g.edges() {
        best[e.u as usize].push(e.w, e.v);
        best[e.v as usize].push(e.w, e.u);
    }
    let mut stats = RefineStats::default();
    let mut scores = Vec::new();

    for sweep in 0..max_sweeps {
        stats.sweeps = sweep + 1;
        // Materialize current lists as a CSR for neighbor-of-neighbor walks.
        let mut edges = Vec::new();
        for (u, t) in best.iter().enumerate() {
            for &(w, v) in t.clone().into_sorted().iter() {
                edges.push(Edge::new(u as u32, v, w));
            }
        }
        let csr = Csr::new(&Graph::from_edges(n, edges));
        let mut updates = 0u64;
        for u in 0..n as u32 {
            // Candidates: neighbors of neighbors not already in the list.
            let have: FxHashSet<u32> = csr.neighbors(u).map(|(v, _)| v).collect();
            let mut cands: Vec<u32> = Vec::new();
            let mut seen = FxHashSet::default();
            for (v, _) in csr.neighbors(u) {
                for (w, _) in csr.neighbors(v) {
                    if w != u && !have.contains(&w) && seen.insert(w) {
                        cands.push(w);
                    }
                }
            }
            if cands.is_empty() {
                continue;
            }
            ledger.add_comparisons(cands.len() as u64);
            stats.comparisons += cands.len() as u64;
            sim.sim_batch(ds, u as usize, &cands, &mut scores);
            let before = best[u as usize].threshold();
            for (i, &c) in cands.iter().enumerate() {
                best[u as usize].push(scores[i], c);
            }
            if best[u as usize].threshold() != before {
                updates += 1;
            }
        }
        stats.last_updates = updates;
        if updates * 50 < n as u64 {
            break; // converged: <2% of lists improved
        }
    }

    let mut edges = Vec::new();
    for (u, t) in best.into_iter().enumerate() {
        for (w, v) in t.into_sorted() {
            edges.push(Edge::new(u as u32, v, w));
        }
    }
    (Graph::from_edges(n, edges), stats)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::sim::CosineSim;
    use crate::stars::allpair;

    #[test]
    fn refinement_improves_one_hop_knn_recall() {
        let ds = synth::gaussian_mixture(400, 32, 8, 0.08, 3);
        let cluster = crate::ampc::Cluster::new(2);
        let k = 10;
        let truth = allpair::exact_knn(&ds, &CosineSim, k, &cluster);

        // Starter: a sparse Stars spanner.
        let family = crate::lsh::SimHash::new(32, 8, 5);
        let out = crate::stars::StarsBuilder::new(&ds)
            .similarity(&CosineSim)
            .hash(&family)
            .params(
                crate::stars::BuildParams::knn_mode(crate::stars::Algorithm::SortingLshStars)
                    .sketches(6)
                    .window(40)
                    .leaders(3)
                    .degree_cap(k),
            )
            .workers(2)
            .build();

        let recall_of = |g: &Graph| {
            let csr = Csr::new(g);
            let mut hit = 0usize;
            let mut total = 0usize;
            for u in 0..400u32 {
                let have: FxHashSet<u32> = csr.neighbors(u).map(|(v, _)| v).collect();
                for &(_, v) in &truth[u as usize] {
                    total += 1;
                    if have.contains(&v) {
                        hit += 1;
                    }
                }
            }
            hit as f64 / total as f64
        };

        let before = recall_of(&out.graph);
        let ledger = CostLedger::new(1);
        let (refined, stats) = nn_descent(&ds, &CosineSim, &out.graph, k, 4, &ledger);
        let after = recall_of(&refined);
        assert!(stats.comparisons > 0);
        assert!(
            after > before + 0.05,
            "nn-descent did not improve recall: {before:.3} -> {after:.3}"
        );
        assert!(after > 0.6, "refined recall too low: {after:.3}");
    }

    #[test]
    fn converges_and_stops() {
        let ds = synth::gaussian_mixture(150, 16, 4, 0.08, 4);
        let cluster = crate::ampc::Cluster::new(2);
        // Start from the exact 5-NN graph: first sweep should change little
        // and the loop must terminate well before max_sweeps.
        let truth = allpair::exact_knn(&ds, &CosineSim, 5, &cluster);
        let mut edges = Vec::new();
        for (u, nbrs) in truth.iter().enumerate() {
            for &(w, v) in nbrs {
                edges.push(Edge::new(u as u32, v, w));
            }
        }
        let g = Graph::from_edges(150, edges);
        let ledger = CostLedger::new(1);
        let (refined, stats) = nn_descent(&ds, &CosineSim, &g, 5, 10, &ledger);
        assert!(stats.sweeps <= 3, "did not converge: {} sweeps", stats.sweeps);
        assert!(refined.num_edges() > 0);
    }

    #[test]
    fn empty_graph_is_fixed_point() {
        let ds = synth::gaussian_mixture(50, 8, 2, 0.1, 5);
        let g = Graph::from_edges(50, vec![]);
        let ledger = CostLedger::new(1);
        let (refined, stats) = nn_descent(&ds, &CosineSim, &g, 5, 3, &ledger);
        assert_eq!(refined.num_edges(), 0);
        assert_eq!(stats.comparisons, 0);
    }
}
