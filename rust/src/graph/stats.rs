//! Graph summary statistics used by the experiment reports.

use super::csr::Csr;
use super::edges::Graph;

/// Degree distribution summary.
#[derive(Clone, Debug, Default)]
pub struct DegreeStats {
    /// Minimum degree.
    pub min: usize,
    /// Maximum degree.
    pub max: usize,
    /// Mean degree.
    pub mean: f64,
    /// Number of isolated nodes.
    pub isolated: usize,
}

/// Compute degree statistics of a CSR graph.
pub fn degree_stats(csr: &Csr) -> DegreeStats {
    let n = csr.num_nodes();
    if n == 0 {
        return DegreeStats::default();
    }
    let mut min = usize::MAX;
    let mut max = 0usize;
    let mut sum = 0usize;
    let mut isolated = 0usize;
    for u in 0..n as u32 {
        let d = csr.degree(u);
        min = min.min(d);
        max = max.max(d);
        sum += d;
        if d == 0 {
            isolated += 1;
        }
    }
    DegreeStats {
        min,
        max,
        mean: sum as f64 / n as f64,
        isolated,
    }
}

/// Weight histogram over fixed [0,1] bins (for similarity-valued weights).
pub fn weight_histogram(g: &Graph, bins: usize) -> Vec<usize> {
    let mut h = vec![0usize; bins];
    for e in g.edges() {
        let b = ((e.w.clamp(0.0, 1.0)) * bins as f32) as usize;
        h[b.min(bins - 1)] += 1;
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::Edge;

    #[test]
    fn degree_stats_basic() {
        let g = Graph::from_edges(4, vec![Edge::new(0, 1, 0.5), Edge::new(0, 2, 0.5)]);
        let s = degree_stats(&Csr::new(&g));
        assert_eq!(s.max, 2);
        assert_eq!(s.min, 0);
        assert_eq!(s.isolated, 1);
        assert!((s.mean - 1.0).abs() < 1e-9);
    }

    #[test]
    fn histogram_bins() {
        let g = Graph::from_edges(
            4,
            vec![
                Edge::new(0, 1, 0.05),
                Edge::new(1, 2, 0.55),
                Edge::new(2, 3, 0.95),
                Edge::new(0, 3, 1.0),
            ],
        );
        let h = weight_histogram(&g, 10);
        assert_eq!(h[0], 1);
        assert_eq!(h[5], 1);
        assert_eq!(h[9], 2); // 0.95 and clamped 1.0
        assert_eq!(h.iter().sum::<usize>(), 4);
    }
}
