//! Union-find (disjoint set union) for connected components.
//!
//! Used by single-linkage clustering (Theorem 2.5 / Appendix A): the
//! connected components of an (r/c, r)-two-hop spanner sandwich the
//! components of the r- and r/c-threshold graphs.

/// Disjoint set union with path halving and union by size.
#[derive(Clone, Debug)]
pub struct UnionFind {
    parent: Vec<u32>,
    size: Vec<u32>,
    components: usize,
}

impl UnionFind {
    /// `n` singleton components.
    pub fn new(n: usize) -> UnionFind {
        UnionFind {
            parent: (0..n as u32).collect(),
            size: vec![1; n],
            components: n,
        }
    }

    /// Representative of `x`'s component.
    #[inline]
    pub fn find(&mut self, mut x: u32) -> u32 {
        while self.parent[x as usize] != x {
            let grand = self.parent[self.parent[x as usize] as usize];
            self.parent[x as usize] = grand;
            x = grand;
        }
        x
    }

    /// Merge the components of `a` and `b`; returns true if they were
    /// previously separate.
    pub fn union(&mut self, a: u32, b: u32) -> bool {
        let (mut ra, mut rb) = (self.find(a), self.find(b));
        if ra == rb {
            return false;
        }
        if self.size[ra as usize] < self.size[rb as usize] {
            std::mem::swap(&mut ra, &mut rb);
        }
        self.parent[rb as usize] = ra;
        self.size[ra as usize] += self.size[rb as usize];
        self.components -= 1;
        true
    }

    /// Whether `a` and `b` are connected.
    pub fn connected(&mut self, a: u32, b: u32) -> bool {
        self.find(a) == self.find(b)
    }

    /// Current number of components.
    pub fn num_components(&self) -> usize {
        self.components
    }

    /// Size of `x`'s component.
    pub fn component_size(&mut self, x: u32) -> usize {
        let r = self.find(x);
        self.size[r as usize] as usize
    }

    /// Dense component labels in [0, num_components).
    pub fn labels(&mut self) -> Vec<u32> {
        let n = self.parent.len();
        let mut map = crate::util::fxhash::FxHashMap::default();
        let mut labels = vec![0u32; n];
        for x in 0..n as u32 {
            let r = self.find(x);
            let next = map.len() as u32;
            let id = *map.entry(r).or_insert(next);
            labels[x as usize] = id;
        }
        labels
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::quickcheck::{check, Gen};

    #[test]
    fn union_reduces_components() {
        let mut uf = UnionFind::new(5);
        assert_eq!(uf.num_components(), 5);
        assert!(uf.union(0, 1));
        assert!(uf.union(1, 2));
        assert!(!uf.union(0, 2));
        assert_eq!(uf.num_components(), 3);
        assert!(uf.connected(0, 2));
        assert!(!uf.connected(0, 3));
        assert_eq!(uf.component_size(1), 3);
    }

    #[test]
    fn labels_are_dense_and_consistent() {
        let mut uf = UnionFind::new(6);
        uf.union(0, 3);
        uf.union(4, 5);
        let labels = uf.labels();
        assert_eq!(labels[0], labels[3]);
        assert_eq!(labels[4], labels[5]);
        assert_ne!(labels[0], labels[4]);
        let max = *labels.iter().max().unwrap() as usize;
        assert_eq!(max + 1, uf.num_components());
    }

    #[test]
    fn matches_naive_reachability() {
        check("uf-vs-bfs", 30, |g: &mut Gen| {
            let n = g.usize_in(2, 40);
            let m = g.usize_in(0, 60);
            let edges: Vec<(u32, u32)> = (0..m)
                .map(|_| {
                    let a = g.usize_in(0, n - 1) as u32;
                    let b = g.usize_in(0, n - 1) as u32;
                    (a, b)
                })
                .filter(|(a, b)| a != b)
                .collect();
            let mut uf = UnionFind::new(n);
            for &(a, b) in &edges {
                uf.union(a, b);
            }
            // BFS ground truth.
            let mut adj = vec![Vec::new(); n];
            for &(a, b) in &edges {
                adj[a as usize].push(b);
                adj[b as usize].push(a);
            }
            let mut label = vec![u32::MAX; n];
            let mut next = 0;
            for s in 0..n {
                if label[s] != u32::MAX {
                    continue;
                }
                let mut queue = vec![s as u32];
                label[s] = next;
                while let Some(x) = queue.pop() {
                    for &y in &adj[x as usize] {
                        if label[y as usize] == u32::MAX {
                            label[y as usize] = next;
                            queue.push(y);
                        }
                    }
                }
                next += 1;
            }
            assert_eq!(uf.num_components(), next as usize);
            for a in 0..n as u32 {
                for b in 0..n as u32 {
                    assert_eq!(
                        uf.connected(a, b),
                        label[a as usize] == label[b as usize]
                    );
                }
            }
        });
    }
}
