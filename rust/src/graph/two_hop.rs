//! Two-hop neighborhood queries — the machinery behind Figure 2's recall
//! metrics, the two-hop spanner definition (Definition 2.4), and the serving
//! path's candidate expansion ([`crate::serve`]).

use super::csr::Csr;
use crate::util::fxhash::FxHashSet;

/// Reusable visited-mark scratch for neighborhood expansion.
///
/// The recall metrics build an `FxHashSet` per query, which is fine offline
/// but allocates and hashes on every membership test. The serving hot path
/// instead stamps nodes in a flat `Vec<u32>` keyed by an epoch counter:
/// `begin` bumps the epoch (O(1) reset), `mark` is one indexed load/store.
/// One scratch per worker thread serves any number of queries.
#[derive(Clone, Debug, Default)]
pub struct VisitScratch {
    stamp: Vec<u32>,
    epoch: u32,
}

impl VisitScratch {
    /// Scratch sized for graphs of up to `n` nodes (grows on demand).
    pub fn new(n: usize) -> VisitScratch {
        VisitScratch {
            stamp: vec![0; n],
            epoch: 0,
        }
    }

    /// Start a fresh visited set over `n` nodes.
    pub fn begin(&mut self, n: usize) {
        if self.stamp.len() < n {
            self.stamp.resize(n, 0);
        }
        if self.epoch == u32::MAX {
            // Epoch wrap: clear the stamps once every 2^32 - 1 queries.
            self.stamp.fill(0);
            self.epoch = 0;
        }
        self.epoch += 1;
    }

    /// Mark `v` visited; true if it was not already marked this epoch.
    #[inline]
    pub fn mark(&mut self, v: u32) -> bool {
        let slot = &mut self.stamp[v as usize];
        if *slot == self.epoch {
            false
        } else {
            *slot = self.epoch;
            true
        }
    }

    /// True if `v` has been marked since the last `begin`.
    #[inline]
    pub fn contains(&self, v: u32) -> bool {
        self.stamp[v as usize] == self.epoch
    }
}

/// Append the ≤ 2-hop neighborhood of `p` (edges with weight ≥ `min_w`;
/// excluding `p` itself) to `out`, skipping nodes already marked in `visit`.
///
/// `p` is marked as a side effect, so repeated calls with different seeds
/// and a shared `visit` (the serving path: one call per routed leader)
/// produce a duplicate-free candidate list in deterministic expansion
/// order. Allocation-free given warm buffers.
pub fn two_hop_into(csr: &Csr, p: u32, min_w: f32, visit: &mut VisitScratch, out: &mut Vec<u32>) {
    visit.mark(p);
    for (q, w1) in csr.neighbors(p) {
        if w1 < min_w {
            continue;
        }
        if visit.mark(q) {
            out.push(q);
        }
        for (r, w2) in csr.neighbors(q) {
            if w2 >= min_w && visit.mark(r) {
                out.push(r);
            }
        }
    }
}

/// The set of nodes reachable from `p` in ≤ 2 hops using only edges with
/// weight ≥ `min_w`. Excludes `p` itself.
///
/// Offline/metrics variant: cost scales with the neighborhood, not the
/// graph (the recall sweeps call this per query with no scratch to reuse).
/// The serving hot path uses [`two_hop_into`] with a per-thread
/// [`VisitScratch`] instead.
pub fn two_hop_set(csr: &Csr, p: u32, min_w: f32) -> FxHashSet<u32> {
    let mut out = FxHashSet::default();
    for (q, w1) in csr.neighbors(p) {
        if w1 < min_w {
            continue;
        }
        out.insert(q);
        for (r, w2) in csr.neighbors(q) {
            if w2 >= min_w && r != p {
                out.insert(r);
            }
        }
    }
    out
}

/// One-hop neighbor set of `p` over edges with weight ≥ `min_w`.
pub fn one_hop_set(csr: &Csr, p: u32, min_w: f32) -> FxHashSet<u32> {
    csr.neighbors(p)
        .filter(|&(_, w)| w >= min_w)
        .map(|(q, _)| q)
        .collect()
}

/// Fraction of `targets` found in `found` (1.0 when `targets` is empty).
pub fn recall(found: &FxHashSet<u32>, targets: &[u32]) -> f64 {
    if targets.is_empty() {
        return 1.0;
    }
    let hit = targets.iter().filter(|t| found.contains(t)).count();
    hit as f64 / targets.len() as f64
}

/// Capped recall for the k-ANN relaxation: |found ∩ candidates| / k, capped
/// at 1 (the paper: "if we can find more than 100 approximate 100-nearest
/// neighbors, we regard the ratio as 1").
pub fn capped_recall(found: &FxHashSet<u32>, candidates: &FxHashSet<u32>, k: usize) -> f64 {
    if k == 0 {
        return 1.0;
    }
    let hit = found.iter().filter(|f| candidates.contains(f)).count();
    (hit as f64 / k as f64).min(1.0)
}

/// Verify the two-hop spanner property (Definition 2.4) by explicit check:
/// every pair with similarity ≥ r2 (given as `required_pairs`) must be within
/// two hops; every graph edge must have weight ≥ r1. Returns the number of
/// violated required pairs.
pub fn spanner_violations(
    csr: &Csr,
    required_pairs: &[(u32, u32)],
    r1: f32,
) -> (usize, usize) {
    let mut missing = 0;
    for &(p, q) in required_pairs {
        let hop2 = two_hop_set(csr, p, r1);
        if !hop2.contains(&q) {
            missing += 1;
        }
    }
    let mut bad_edges = 0;
    for u in 0..csr.num_nodes() as u32 {
        for (_, w) in csr.neighbors(u) {
            if w < r1 {
                bad_edges += 1;
            }
        }
    }
    (missing, bad_edges / 2)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph::{Edge, Graph};

    fn csr_of(n: usize, edges: Vec<Edge>) -> Csr {
        Csr::new(&Graph::from_edges(n, edges))
    }

    #[test]
    fn two_hop_reaches_star_leaves() {
        // Star: center 0 — leaves 1..4. Leaves are 2 hops from each other.
        let csr = csr_of(
            5,
            (1..5).map(|v| Edge::new(0, v, 0.9)).collect(),
        );
        let h2 = two_hop_set(&csr, 1, 0.5);
        assert!(h2.contains(&0));
        for v in 2..5 {
            assert!(h2.contains(&v), "leaf {v} not reached");
        }
        let h1 = one_hop_set(&csr, 1, 0.5);
        assert_eq!(h1.len(), 1);
    }

    #[test]
    fn weight_filter_cuts_paths() {
        // 1 -0.9- 0 -0.3- 2: with min_w 0.5 node 2 unreachable.
        let csr = csr_of(3, vec![Edge::new(0, 1, 0.9), Edge::new(0, 2, 0.3)]);
        let h2 = two_hop_set(&csr, 1, 0.5);
        assert!(h2.contains(&0) && !h2.contains(&2));
        let h2_relaxed = two_hop_set(&csr, 1, 0.25);
        assert!(h2_relaxed.contains(&2));
    }

    #[test]
    fn two_hop_into_matches_set_and_dedups_across_seeds() {
        let csr = csr_of(
            6,
            vec![
                Edge::new(0, 1, 0.9),
                Edge::new(1, 2, 0.8),
                Edge::new(2, 3, 0.7),
                Edge::new(4, 5, 0.6),
            ],
        );
        // Single-seed expansion equals the set variant.
        for p in 0..6u32 {
            let mut visit = VisitScratch::new(6);
            visit.begin(6);
            let mut out = Vec::new();
            two_hop_into(&csr, p, 0.5, &mut visit, &mut out);
            let set: FxHashSet<u32> = out.iter().copied().collect();
            assert_eq!(set.len(), out.len(), "duplicates from seed {p}");
            assert_eq!(set, two_hop_set(&csr, p, 0.5), "seed {p}");
        }
        // Shared scratch across seeds: overlapping neighborhoods dedup, and
        // no seed ever appears in the combined candidate list.
        let mut visit = VisitScratch::new(6);
        visit.begin(6);
        let mut out = Vec::new();
        for p in [0u32, 2] {
            visit.mark(p);
            two_hop_into(&csr, p, 0.5, &mut visit, &mut out);
        }
        let set: FxHashSet<u32> = out.iter().copied().collect();
        assert_eq!(set.len(), out.len(), "duplicates across seeds");
        assert!(!out.contains(&0) && !out.contains(&2));
        assert!(set.contains(&1) && set.contains(&3));
    }

    #[test]
    fn visit_scratch_epochs_reset_in_constant_time() {
        let mut v = VisitScratch::new(3);
        v.begin(3);
        assert!(v.mark(1));
        assert!(!v.mark(1));
        assert!(v.contains(1));
        v.begin(3);
        assert!(!v.contains(1), "epoch bump did not reset");
        assert!(v.mark(1));
        // Growing n on a later begin is allowed.
        v.begin(10);
        assert!(v.mark(9));
    }

    #[test]
    fn recall_metrics() {
        let mut found = FxHashSet::default();
        found.insert(1);
        found.insert(2);
        assert!((recall(&found, &[1, 2, 3, 4]) - 0.5).abs() < 1e-9);
        assert_eq!(recall(&found, &[]), 1.0);

        let mut cands = FxHashSet::default();
        cands.insert(1);
        cands.insert(2);
        cands.insert(5);
        assert!((capped_recall(&found, &cands, 2) - 1.0).abs() < 1e-9);
        assert!((capped_recall(&found, &cands, 4) - 0.5).abs() < 1e-9);
    }

    #[test]
    fn spanner_violation_detection() {
        let csr = csr_of(4, vec![Edge::new(0, 1, 0.9), Edge::new(1, 2, 0.9)]);
        // (0,2) is within 2 hops; (0,3) is not.
        let (missing, bad) = spanner_violations(&csr, &[(0, 2), (0, 3)], 0.5);
        assert_eq!(missing, 1);
        assert_eq!(bad, 0);
        // With r1 above the edge weights, both edges are "bad".
        let (_, bad) = spanner_violations(&csr, &[], 0.95);
        assert_eq!(bad, 2);
    }
}
