//! Edge lists and the deduplicated [`Graph`].

/// An undirected weighted edge. Stored with `u < v` after normalization.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Edge {
    /// Smaller endpoint.
    pub u: u32,
    /// Larger endpoint.
    pub v: u32,
    /// Similarity weight.
    pub w: f32,
}

impl Edge {
    /// Normalized edge (u < v). Panics on self-loops in debug builds.
    #[inline]
    pub fn new(a: u32, b: u32, w: f32) -> Edge {
        debug_assert_ne!(a, b, "self loop");
        if a < b {
            Edge { u: a, v: b, w }
        } else {
            Edge { u: b, v: a, w }
        }
    }

    /// Packed (u, v) key for dedup.
    #[inline]
    pub fn key(&self) -> u64 {
        ((self.u as u64) << 32) | self.v as u64
    }
}

/// A deduplicated undirected similarity graph over `n` points.
#[derive(Clone, Debug, Default)]
pub struct Graph {
    n: usize,
    edges: Vec<Edge>,
}

impl Graph {
    /// Build from raw (possibly duplicated) edges: sorts by endpoint pair,
    /// keeps the maximum weight per pair, drops self loops.
    pub fn from_edges(n: usize, mut raw: Vec<Edge>) -> Graph {
        raw.retain(|e| e.u != e.v);
        raw.sort_unstable_by(|a, b| a.key().cmp(&b.key()).then(b.w.total_cmp(&a.w)));
        raw.dedup_by_key(|e| e.key());
        raw.shrink_to_fit();
        Graph { n, edges: raw }
    }

    /// Merge several per-worker edge buffers into one graph.
    pub fn from_parts(n: usize, parts: Vec<Vec<Edge>>) -> Graph {
        let total: usize = parts.iter().map(|p| p.len()).sum();
        let mut raw = Vec::with_capacity(total);
        for p in parts {
            raw.extend(p);
        }
        Graph::from_edges(n, raw)
    }

    /// Number of vertices.
    pub fn num_nodes(&self) -> usize {
        self.n
    }

    /// Number of (deduplicated) edges.
    pub fn num_edges(&self) -> usize {
        self.edges.len()
    }

    /// The edges, sorted by (u, v).
    pub fn edges(&self) -> &[Edge] {
        &self.edges
    }

    /// A copy of this graph keeping only edges with weight ≥ `min_w`.
    pub fn filter_weight(&self, min_w: f32) -> Graph {
        Graph {
            n: self.n,
            edges: self.edges.iter().filter(|e| e.w >= min_w).copied().collect(),
        }
    }

    /// Count edges with weight ≥ `min_w` (Figure 3's metric).
    pub fn count_weight_ge(&self, min_w: f32) -> usize {
        self.edges.iter().filter(|e| e.w >= min_w).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn edge_normalizes_endpoints() {
        let e = Edge::new(5, 2, 0.7);
        assert_eq!((e.u, e.v), (2, 5));
        assert_eq!(e.key(), Edge::new(2, 5, 0.1).key());
    }

    #[test]
    fn graph_dedups_keeping_max_weight() {
        let g = Graph::from_edges(
            10,
            vec![
                Edge::new(1, 2, 0.5),
                Edge::new(2, 1, 0.9),
                Edge::new(1, 2, 0.7),
                Edge::new(3, 4, 0.2),
            ],
        );
        assert_eq!(g.num_edges(), 2);
        assert_eq!(g.edges()[0].w, 0.9);
    }

    #[test]
    fn from_parts_merges() {
        let g = Graph::from_parts(
            5,
            vec![
                vec![Edge::new(0, 1, 0.5)],
                vec![Edge::new(1, 0, 0.6), Edge::new(2, 3, 0.4)],
            ],
        );
        assert_eq!(g.num_edges(), 2);
        assert_eq!(g.edges()[0].w, 0.6);
    }

    #[test]
    fn filter_and_count() {
        let g = Graph::from_edges(
            5,
            vec![
                Edge::new(0, 1, 0.9),
                Edge::new(1, 2, 0.5),
                Edge::new(2, 3, 0.1),
            ],
        );
        assert_eq!(g.count_weight_ge(0.5), 2);
        assert_eq!(g.filter_weight(0.5).num_edges(), 2);
        assert_eq!(g.filter_weight(0.95).num_edges(), 0);
    }
}
