//! Similarity graph representations and queries.
//!
//! The build phase accumulates weighted edges (possibly duplicated across
//! repetitions); [`Graph`] dedups them, [`Csr`] provides adjacency with the
//! paper's degree threshold (keep the ~250 most-similar neighbors per node),
//! [`UnionFind`] provides connected components for single-linkage, and
//! [`two_hop`] implements the recall queries behind Figure 2.

mod edges;
mod csr;
mod components;
pub mod nn_descent;
pub mod two_hop;
pub mod stats;

pub use components::UnionFind;
pub use csr::Csr;
pub use edges::{Edge, Graph};
