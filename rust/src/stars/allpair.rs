//! Brute-force all-pairs baseline (the paper's "AllPair").
//!
//! Used both as a comparison baseline (Figure 1: ≥1000× more comparisons
//! than Stars) and as the ground-truth generator for recall evaluation
//! (exact threshold neighbors and exact k-NN).

use crate::ampc::Cluster;
use crate::data::types::Dataset;
use crate::graph::Edge;
use crate::sim::Similarity;
use crate::util::topk::TopK;

/// Score every pair; emit edges with similarity ≥ `threshold`.
/// Parallelized over row chunks on the cluster.
pub fn allpair_edges(
    ds: &Dataset,
    sim: &dyn Similarity,
    threshold: f32,
    cluster: &Cluster,
) -> Vec<Edge> {
    let n = ds.len();
    // Tasks = row blocks; use more tasks than workers for balance (upper
    // triangle makes early rows costlier).
    let tasks = (cluster.workers() * 8).min(n.max(1));
    let block = n.div_ceil(tasks.max(1));
    // One shared id list; each row's candidates are the tail slice — the
    // previous per-row `collect` allocated O(n) per row.
    let all_ids: Vec<u32> = (0..n as u32).collect();
    let parts = cluster.map_timed(tasks, |t, ledger| {
        let lo = t * block;
        let hi = ((t + 1) * block).min(n);
        let mut edges = Vec::new();
        let mut scores = Vec::new();
        for i in lo..hi {
            let rest = &all_ids[i + 1..];
            if rest.is_empty() {
                continue;
            }
            ledger.add_comparisons(rest.len() as u64);
            sim.sim_batch(ds, i, rest, &mut scores);
            for (k, &j) in rest.iter().enumerate() {
                if scores[k] >= threshold {
                    edges.push(Edge::new(i as u32, j, scores[k]));
                }
            }
        }
        ledger.add_edges(edges.len() as u64);
        edges
    });
    parts.into_iter().flatten().collect()
}

/// Exact k-nearest neighbors of every point (ground truth for Figure 2).
/// Returns, per point, its k best `(similarity, neighbor)` sorted descending.
pub fn exact_knn(
    ds: &Dataset,
    sim: &dyn Similarity,
    k: usize,
    cluster: &Cluster,
) -> Vec<Vec<(f32, u32)>> {
    let n = ds.len();
    let tasks = (cluster.workers() * 4).min(n.max(1));
    let block = n.div_ceil(tasks.max(1));
    let all: Vec<u32> = (0..n as u32).collect();
    let parts: Vec<Vec<Vec<(f32, u32)>>> = cluster.map_timed(tasks, |t, ledger| {
        let lo = t * block;
        let hi = ((t + 1) * block).min(n);
        let mut out = Vec::with_capacity(hi.saturating_sub(lo));
        let mut scores = Vec::new();
        for i in lo..hi {
            let mut topk = TopK::new(k);
            // Score i against everyone (skip self below).
            ledger.add_comparisons((n - 1) as u64);
            sim.sim_batch(ds, i, &all, &mut scores);
            for (j, &s) in scores.iter().enumerate() {
                if j != i {
                    topk.push(s, j as u32);
                }
            }
            out.push(topk.into_sorted());
        }
        out
    });
    parts.into_iter().flatten().collect()
}

/// Exact neighbors above a similarity threshold, per point (ground truth for
/// the "near neighbor" recall panels).
pub fn exact_threshold_neighbors(
    ds: &Dataset,
    sim: &dyn Similarity,
    threshold: f32,
    cluster: &Cluster,
) -> Vec<Vec<u32>> {
    let edges = allpair_edges(ds, sim, threshold, cluster);
    let mut out = vec![Vec::new(); ds.len()];
    for e in edges {
        out[e.u as usize].push(e.v);
        out[e.v as usize].push(e.u);
    }
    out
}

/// Convenience wrapper exposing the cost report alongside the edges.
pub fn allpair_with_report(
    ds: &Dataset,
    sim: &dyn Similarity,
    threshold: f32,
    workers: usize,
) -> (Vec<Edge>, crate::ampc::CostReport) {
    let cluster = Cluster::new(workers);
    let (edges, report) = cluster.run_job(|c| allpair_edges(ds, sim, threshold, c));
    (edges, report)
}

/// Total comparisons a brute-force pass makes on `n` points.
pub fn allpair_comparisons(n: usize) -> u64 {
    (n as u64) * (n as u64 - 1) / 2
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::sim::CosineSim;

    #[test]
    fn counts_exactly_n_choose_2() {
        let ds = synth::gaussian_mixture(101, 8, 4, 0.1, 1);
        let (_, report) = allpair_with_report(&ds, &CosineSim, 0.5, 3);
        assert_eq!(report.comparisons, allpair_comparisons(101));
    }

    #[test]
    fn finds_all_threshold_pairs() {
        let ds = synth::gaussian_mixture(120, 8, 3, 0.05, 2);
        let cluster = Cluster::new(2);
        let edges = allpair_edges(&ds, &CosineSim, 0.7, &cluster);
        // Verify against a naive loop.
        let mut want = 0;
        for i in 0..120 {
            for j in (i + 1)..120 {
                if CosineSim.sim(&ds, i, j) >= 0.7 {
                    want += 1;
                }
            }
        }
        assert_eq!(edges.len(), want);
    }

    #[test]
    fn exact_knn_is_correct() {
        let ds = synth::gaussian_mixture(80, 8, 4, 0.1, 3);
        let cluster = Cluster::new(2);
        let knn = exact_knn(&ds, &CosineSim, 5, &cluster);
        assert_eq!(knn.len(), 80);
        for (i, nbrs) in knn.iter().enumerate() {
            assert_eq!(nbrs.len(), 5);
            // Sorted descending and excludes self.
            for w in nbrs.windows(2) {
                assert!(w[0].0 >= w[1].0);
            }
            assert!(nbrs.iter().all(|&(_, j)| j as usize != i));
            // The top neighbor is the true argmax.
            let best = (0..80)
                .filter(|&j| j != i)
                .map(|j| (CosineSim.sim(&ds, i, j), j as u32))
                .max_by(|a, b| a.0.partial_cmp(&b.0).unwrap())
                .unwrap();
            assert!((nbrs[0].0 - best.0).abs() < 1e-6);
        }
    }

    #[test]
    fn threshold_neighbors_symmetric() {
        let ds = synth::gaussian_mixture(60, 8, 3, 0.1, 4);
        let cluster = Cluster::new(2);
        let nbrs = exact_threshold_neighbors(&ds, &CosineSim, 0.6, &cluster);
        for (i, ns) in nbrs.iter().enumerate() {
            for &j in ns {
                assert!(
                    nbrs[j as usize].contains(&(i as u32)),
                    "asymmetric neighbor lists"
                );
            }
        }
    }
}
