//! Build configuration.

use crate::util::json::Json;

/// Which graph-building algorithm to run.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Algorithm {
    /// Brute-force all-pairs comparison (baseline / ground truth).
    AllPair,
    /// LSH bucketing, all pairs within each bucket (non-Stars baseline).
    Lsh,
    /// LSH bucketing + star graphs per bucket (Stars 1).
    LshStars,
    /// SortingLSH windows, all pairs within each window (non-Stars baseline).
    SortingLsh,
    /// SortingLSH windows + star graphs per window (Stars 2).
    SortingLshStars,
}

impl Algorithm {
    /// Display name matching the paper's figure legends.
    pub fn name(&self) -> &'static str {
        match self {
            Algorithm::AllPair => "allpair",
            Algorithm::Lsh => "lsh",
            Algorithm::LshStars => "lsh+stars",
            Algorithm::SortingLsh => "sortinglsh",
            Algorithm::SortingLshStars => "sortinglsh+stars",
        }
    }

    /// True for the Stars variants.
    pub fn is_stars(&self) -> bool {
        matches!(self, Algorithm::LshStars | Algorithm::SortingLshStars)
    }

    /// All algorithms, in the order the paper's figures list them.
    pub fn all() -> [Algorithm; 5] {
        [
            Algorithm::AllPair,
            Algorithm::Lsh,
            Algorithm::LshStars,
            Algorithm::SortingLsh,
            Algorithm::SortingLshStars,
        ]
    }
}

/// How point features are joined with LSH tables (paper §4).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum JoinStrategy {
    /// In-process access (no accounting; fastest, default).
    Direct,
    /// Sharded in-memory DHT: O(n) RAM, per-bucket feature lookups.
    Dht,
    /// MapReduce shuffle sort: O(Rn) "disk", no resident feature cache.
    Shuffle,
}

/// Parameters for one graph build. Defaults follow the paper's Appendix D.2.
#[derive(Clone, Debug)]
pub struct BuildParams {
    /// Algorithm to run.
    pub algorithm: Algorithm,
    /// Number of sketches R (paper: 25, 100, or 400).
    pub sketches: usize,
    /// Number of leaders s per bucket/window for Stars variants (paper
    /// default 25; Appendix D.4 sweeps 1, 5, 10, 25).
    pub leaders: usize,
    /// Edge-creation threshold r₁ (threshold mode). Pairs scoring below are
    /// compared but not connected. Set to f32::MIN to keep all scored pairs.
    pub threshold: f32,
    /// SortingLSH window size W (paper: 250).
    pub window: usize,
    /// Maximum allowed bucket size; larger buckets are randomly partitioned
    /// (paper: 1000 for LSH non-Stars, 10000 for LSH+Stars, 20000 for
    /// SortingLSH-based).
    pub max_bucket: usize,
    /// Degree threshold: keep only this many most-similar neighbors per node
    /// (paper: 250). 0 disables capping.
    pub degree_cap: usize,
    /// Feature join strategy (paper §4).
    pub join: JoinStrategy,
    /// RNG seed for leader sampling / shifts / sub-bucket partitioning.
    pub seed: u64,
}

impl BuildParams {
    /// Paper-default parameters for the given algorithm in **threshold**
    /// experiments (Figures 1–4): similarity threshold 0.5.
    pub fn threshold_mode(algorithm: Algorithm) -> BuildParams {
        BuildParams {
            algorithm,
            sketches: 25,
            leaders: 25,
            threshold: 0.5,
            window: 250,
            max_bucket: match algorithm {
                Algorithm::LshStars => 10_000,
                Algorithm::SortingLsh | Algorithm::SortingLshStars => 20_000,
                _ => 1_000,
            },
            degree_cap: 250,
            join: JoinStrategy::Direct,
            seed: 0xBEEF,
        }
    }

    /// Paper-default parameters for **k-NN** experiments (SortingLSH based,
    /// Figure 2 right panels): window 250, sketching dimension M=30, keep
    /// the 250 closest per node, no similarity threshold.
    pub fn knn_mode(algorithm: Algorithm) -> BuildParams {
        BuildParams {
            threshold: f32::MIN,
            ..BuildParams::threshold_mode(algorithm)
        }
    }

    /// Set the number of sketches R.
    pub fn sketches(mut self, r: usize) -> Self {
        self.sketches = r;
        self
    }

    /// Set the number of leaders s.
    pub fn leaders(mut self, s: usize) -> Self {
        self.leaders = s;
        self
    }

    /// Set the edge threshold r₁.
    pub fn threshold(mut self, t: f32) -> Self {
        self.threshold = t;
        self
    }

    /// Set the SortingLSH window size W.
    pub fn window(mut self, w: usize) -> Self {
        self.window = w;
        self
    }

    /// Set the degree cap.
    pub fn degree_cap(mut self, cap: usize) -> Self {
        self.degree_cap = cap;
        self
    }

    /// Set the max bucket size.
    pub fn max_bucket(mut self, cap: usize) -> Self {
        self.max_bucket = cap;
        self
    }

    /// Set the join strategy.
    pub fn join(mut self, join: JoinStrategy) -> Self {
        self.join = join;
        self
    }

    /// Set the seed.
    pub fn seed(mut self, seed: u64) -> Self {
        self.seed = seed;
        self
    }

    /// JSON echo for reports.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("algorithm", Json::from(self.algorithm.name())),
            ("sketches", Json::from(self.sketches)),
            ("leaders", Json::from(self.leaders)),
            ("threshold", Json::from(self.threshold as f64)),
            ("window", Json::from(self.window)),
            ("max_bucket", Json::from(self.max_bucket)),
            ("degree_cap", Json::from(self.degree_cap)),
            ("seed", Json::from(self.seed)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_match_paper() {
        let p = BuildParams::threshold_mode(Algorithm::Lsh);
        assert_eq!(p.sketches, 25);
        assert_eq!(p.max_bucket, 1_000);
        let p = BuildParams::threshold_mode(Algorithm::LshStars);
        assert_eq!(p.max_bucket, 10_000);
        assert_eq!(p.leaders, 25);
        assert_eq!(p.degree_cap, 250);
        let p = BuildParams::knn_mode(Algorithm::SortingLshStars);
        assert_eq!(p.window, 250);
        assert_eq!(p.max_bucket, 20_000);
        assert_eq!(p.threshold, f32::MIN);
    }

    #[test]
    fn builder_chaining() {
        let p = BuildParams::threshold_mode(Algorithm::LshStars)
            .sketches(400)
            .leaders(5)
            .threshold(0.4)
            .seed(1);
        assert_eq!(p.sketches, 400);
        assert_eq!(p.leaders, 5);
        assert_eq!(p.threshold, 0.4);
        assert_eq!(p.seed, 1);
    }

    #[test]
    fn names_and_stars_flags() {
        assert_eq!(Algorithm::LshStars.name(), "lsh+stars");
        assert!(Algorithm::LshStars.is_stars());
        assert!(!Algorithm::Lsh.is_stars());
        assert_eq!(Algorithm::all().len(), 5);
    }

    #[test]
    fn params_json_roundtrip() {
        let p = BuildParams::threshold_mode(Algorithm::SortingLsh);
        let j = p.to_json().to_string();
        let v = crate::util::json::parse(&j).unwrap();
        assert_eq!(v.get("algorithm").unwrap().as_str().unwrap(), "sortinglsh");
        assert_eq!(v.get("window").unwrap().as_usize().unwrap(), 250);
    }
}
