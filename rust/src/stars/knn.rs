//! Stars 2 — approximate k-NN graphs via SortingLSH (paper §3.2) — and the
//! non-Stars SortingLSH baseline (all pairs per window).
//!
//! One repetition: draw M hash functions, sort points lexicographically by
//! their hash sequences, split the order into windows of size ≤ W with a
//! random shift r ∈ [W/2, W], then score within each window:
//!
//! * **Stars**: sample `s` leaders per window, compare each to the whole
//!   window (Stars 2 step 4).
//! * **non-Stars**: all pairs per window (Stars 2 step 5 — the paper's
//!   k ≤ n^2ρ branch, which is also the SortingLSH baseline).
//!
//! The final graph keeps each node's `degree_cap` most similar neighbors
//! (paper: 250) — handled by the builder's accumulator.

use crate::ampc::CostLedger;
use crate::data::types::Dataset;
use crate::graph::Edge;
use crate::lsh::sorting::sorted_indices_par_timed;
use crate::lsh::{windows, LshFamily};
use crate::sim::Similarity;
use crate::stars::bucketing::sample_leaders;
use crate::stars::params::BuildParams;
use crate::util::pool;
use crate::util::rng::{derive_seed, Rng};

/// Run one SortingLSH repetition on a single core; returns the edges found.
pub fn sorting_rep(
    ds: &Dataset,
    sim: &dyn Similarity,
    family: &dyn LshFamily,
    params: &BuildParams,
    rep: u64,
    ledger: &CostLedger,
) -> Vec<Edge> {
    sorting_rep_par(ds, sim, family, params, rep, ledger, 1)
}

/// Run one SortingLSH repetition with `inner_workers` cores of
/// in-repetition data parallelism: the sketch stage is chunked over point
/// ranges, the packed keys go through the LSD radix sort, and window scoring
/// is dispatched per window over the pool.
///
/// Determinism: the window split and all leader draws consume the
/// repetition RNG serially in window order before any parallel dispatch,
/// and per-window edge batches are concatenated in window order — the edge
/// vector is identical to the single-core path for every `inner_workers`
/// value (asserted by `tests/sketch_parity.rs`).
pub fn sorting_rep_par(
    ds: &Dataset,
    sim: &dyn Similarity,
    family: &dyn LshFamily,
    params: &BuildParams,
    rep: u64,
    ledger: &CostLedger,
    inner_workers: usize,
) -> Vec<Edge> {
    let n = ds.len();
    let mut rng = Rng::new(derive_seed(params.seed ^ 0x50_47, rep));
    // In-rep parallel phases report extra inner workers' busy spans so Σ
    // busy counts machine-seconds (worker 0 rides the rep's wall charge).
    let inner_busy = |w: usize, nanos: u64| ledger.add_inner_busy(w, nanos);

    // Sketch + sort phase (TeraSort in the real system): data-parallel
    // sketching over point chunks, then the packed-u64 radix fast path for
    // binary-symbol families. One phase span covers both (they share the
    // driver), its busy aggregating every inner worker's chunk time.
    let sketch_span = ledger.phases().enter("sketch");
    let order = sorted_indices_par_timed(family, ds, rep, inner_workers, |w, nanos| {
        inner_busy(w, nanos);
        sketch_span.add_busy(nanos);
    });
    ledger.add_sketches((n * family.sketch_len()) as u64);
    drop(sketch_span);

    let ws = windows(n, params.window, &mut rng);
    // Leader pre-draw in window order: same RNG stream as the sequential
    // loop (windows below 2 members are skipped and draw nothing; `None`
    // means "score all pairs" — Stars 2 step 5, the k ≤ n^2ρ branch, which
    // is also the small-window fallback since all pairs is cheaper than
    // stars when |W| ≤ 2s).
    let stars = params.algorithm.is_stars();
    let s = params.leaders;
    let plans: Vec<Option<Vec<usize>>> = ws
        .iter()
        .map(|w| {
            if w.len() >= 2 && stars && w.len() > 2 * s {
                Some(sample_leaders(w.len(), s, &mut rng))
            } else {
                None
            }
        })
        .collect();

    let score_window = |k: usize, scores: &mut Vec<f32>, edges: &mut Vec<Edge>| {
        let members = &order[ws[k].clone()];
        if members.len() < 2 {
            return;
        }
        match &plans[k] {
            Some(leaders) => {
                // Stars 2 step 4: s random leaders per window, each scored
                // against the two contiguous halves around its position —
                // the batch kernels tile straight from the window slice, no
                // per-leader candidate copy.
                for &lp in leaders {
                    let leader = members[lp];
                    let (before, rest) = members.split_at(lp);
                    let after = &rest[1..];
                    ledger.add_comparisons((members.len() - 1) as u64);
                    for part in [before, after] {
                        if part.is_empty() {
                            continue;
                        }
                        sim.sim_batch(ds, leader as usize, part, scores);
                        for (j, &c) in part.iter().enumerate() {
                            if scores[j] >= params.threshold {
                                edges.push(Edge::new(leader, c, scores[j]));
                            }
                        }
                    }
                }
            }
            None => {
                // Stars 2 step 5 / baseline: all pairs within the window.
                for (pos, &a) in members.iter().enumerate() {
                    let rest = &members[pos + 1..];
                    if rest.is_empty() {
                        continue;
                    }
                    ledger.add_comparisons(rest.len() as u64);
                    sim.sim_batch(ds, a as usize, rest, scores);
                    for (j, &b) in rest.iter().enumerate() {
                        if scores[j] >= params.threshold {
                            edges.push(Edge::new(a, b, scores[j]));
                        }
                    }
                }
            }
        }
    };
    let score_span = ledger.phases().enter("score");
    let edges = pool::parallel_flat_map_timed(
        ws.len(),
        inner_workers,
        |w, nanos| {
            inner_busy(w, nanos);
            score_span.add_busy(nanos);
        },
        Vec::<f32>::new,
        score_window,
    );
    ledger.add_edges(edges.len() as u64);
    drop(score_span);
    edges
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::lsh::SimHash;
    use crate::sim::CosineSim;
    use crate::stars::params::Algorithm;

    fn setup() -> (Dataset, SimHash) {
        let ds = synth::gaussian_mixture(500, 16, 8, 0.08, 11);
        let h = SimHash::new(16, 30, 13);
        (ds, h)
    }

    #[test]
    fn stars_reduces_comparisons_quadratic_to_linear() {
        let (ds, h) = setup();
        let w = 50;
        let p_stars = BuildParams::knn_mode(Algorithm::SortingLshStars)
            .window(w)
            .leaders(2);
        let p_np = BuildParams::knn_mode(Algorithm::SortingLsh).window(w);
        let l1 = CostLedger::new(1);
        let l2 = CostLedger::new(1);
        sorting_rep(&ds, &CosineSim, &h, &p_stars, 0, &l1, );
        sorting_rep(&ds, &CosineSim, &h, &p_np, 0, &l2, );
        // Stars: ~2(W-1) per window; non-stars: W(W-1)/2 per window.
        let ratio = l2.comparisons() as f64 / l1.comparisons() as f64;
        assert!(ratio > 5.0, "expected ~W/2s reduction, got {ratio}");
    }

    #[test]
    fn comparisons_count_matches_formula_nonstars() {
        let (ds, h) = setup();
        let w = 100;
        let p = BuildParams::knn_mode(Algorithm::SortingLsh).window(w).seed(5);
        let ledger = CostLedger::new(1);
        sorting_rep(&ds, &CosineSim, &h, &p, 2, &ledger);
        // Windows partition 500 points; each window of size m costs m(m-1)/2.
        // First window size in [50,100]; bound loosely.
        let c = ledger.comparisons();
        let max = (500f64 / w as f64).ceil() as u64 * (w * (w - 1) / 2) as u64 + (w * w) as u64;
        assert!(c > 0 && c <= max, "comparisons {c} out of range (max {max})");
    }

    #[test]
    fn knn_mode_keeps_all_scored_pairs_as_edges() {
        let (ds, h) = setup();
        let p = BuildParams::knn_mode(Algorithm::SortingLshStars).window(20).leaders(1);
        let ledger = CostLedger::new(1);
        let edges = sorting_rep(&ds, &CosineSim, &h, &p, 0, &ledger);
        assert_eq!(edges.len() as u64, ledger.comparisons());
    }

    #[test]
    fn neighbors_in_same_mode_get_connected() {
        let (ds, h) = setup();
        let p = BuildParams::knn_mode(Algorithm::SortingLshStars).window(64);
        let ledger = CostLedger::new(1);
        let edges = sorting_rep(&ds, &CosineSim, &h, &p, 0, &ledger);
        // Same-mode pairs must be strongly over-represented vs the random
        // baseline (8 modes -> ~12.5% of uniformly random pairs share a
        // mode). Every scored pair becomes an edge in knn mode, so window
        // boundaries dilute the fraction below 1/2, but sorting should
        // still concentrate modes ~3x over random.
        let same = edges
            .iter()
            .filter(|e| ds.labels[e.u as usize] == ds.labels[e.v as usize])
            .count();
        let frac = same as f64 / edges.len() as f64;
        assert!(
            frac > 0.35,
            "same-mode edge fraction {frac:.3} not >> 0.125 baseline"
        );
    }

    #[test]
    fn deterministic_per_seed_and_rep() {
        let (ds, h) = setup();
        let p = BuildParams::knn_mode(Algorithm::SortingLshStars).seed(42);
        let l = CostLedger::new(1);
        let e1 = sorting_rep(&ds, &CosineSim, &h, &p, 7, &l);
        let e2 = sorting_rep(&ds, &CosineSim, &h, &p, 7, &l);
        assert_eq!(e1, e2);
        let e3 = sorting_rep(&ds, &CosineSim, &h, &p, 8, &l);
        assert_ne!(e1.len(), 0);
        assert_ne!(e1, e3);
    }
}
