//! Bucket grouping and oversize partitioning (paper §4).
//!
//! "To ensure robustness to sub-optimal LSH settings, we randomly partition
//! large buckets into size-constrained sub-buckets prior to pairwise
//! scoring." The Stars algorithm's nearly-linear per-bucket cost is what
//! lets the paper relax this cap from 1000 (non-Stars) to 10000 (Stars).

use crate::util::fxhash::FxHashMap;
use crate::util::rng::Rng;

/// Group point ids by bucket key. Singleton buckets are dropped (no pairs).
pub fn group_buckets(keys: &[u64]) -> Vec<Vec<u32>> {
    let mut map: FxHashMap<u64, Vec<u32>> = FxHashMap::default();
    for (i, &k) in keys.iter().enumerate() {
        map.entry(k).or_default().push(i as u32);
    }
    map.into_values().filter(|b| b.len() >= 2).collect()
}

/// Randomly partition any bucket larger than `max_size` into sub-buckets of
/// at most `max_size` members. Buckets within the cap pass through intact.
pub fn split_oversized(buckets: Vec<Vec<u32>>, max_size: usize, rng: &mut Rng) -> Vec<Vec<u32>> {
    let max_size = max_size.max(2);
    let mut out = Vec::with_capacity(buckets.len());
    for mut b in buckets {
        if b.len() <= max_size {
            out.push(b);
            continue;
        }
        rng.shuffle(&mut b);
        for chunk in b.chunks(max_size) {
            if chunk.len() >= 2 {
                out.push(chunk.to_vec());
            }
        }
    }
    out
}

/// Sample `s` distinct leader *positions* within a bucket of length `len`.
pub fn sample_leaders(len: usize, s: usize, rng: &mut Rng) -> Vec<usize> {
    rng.sample_indices(len, s.min(len))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::quickcheck::{check, Gen};

    #[test]
    fn groups_by_key_and_drops_singletons() {
        let keys = vec![7, 3, 7, 3, 9, 7];
        let mut buckets = group_buckets(&keys);
        buckets.sort_by_key(|b| b.len());
        assert_eq!(buckets.len(), 2);
        let mut big = buckets[1].clone();
        big.sort();
        assert_eq!(big, vec![0, 2, 5]);
    }

    #[test]
    fn split_caps_bucket_sizes() {
        check("split-caps", 40, |g: &mut Gen| {
            let n = g.usize_in(2, 2000);
            let cap = g.usize_in(2, 300);
            let bucket: Vec<u32> = (0..n as u32).collect();
            let mut rng = Rng::new(g.usize_in(0, 1 << 20) as u64);
            let subs = split_oversized(vec![bucket], cap, &mut rng);
            let mut all: Vec<u32> = subs.iter().flatten().copied().collect();
            for s in &subs {
                assert!(s.len() <= cap, "sub-bucket of {} > cap {cap}", s.len());
            }
            all.sort();
            // All points preserved except possibly one dropped singleton tail.
            assert!(all.len() >= n - 1, "lost points: {} of {n}", all.len());
            all.dedup();
            assert!(all.len() >= n - 1, "duplicated points");
        });
    }

    #[test]
    fn split_leaves_small_buckets_alone() {
        let mut rng = Rng::new(1);
        let b = vec![vec![1, 2, 3]];
        let out = split_oversized(b.clone(), 10, &mut rng);
        assert_eq!(out, b);
    }

    #[test]
    fn leaders_distinct_and_capped() {
        let mut rng = Rng::new(2);
        let ls = sample_leaders(10, 25, &mut rng);
        assert_eq!(ls.len(), 10);
        let ls = sample_leaders(100, 5, &mut rng);
        assert_eq!(ls.len(), 5);
        let set: std::collections::HashSet<_> = ls.iter().collect();
        assert_eq!(set.len(), 5);
        assert!(ls.iter().all(|&p| p < 100));
    }
}
