//! Stars 1 — approximate threshold graphs via LSH bucketing (paper §3.1) —
//! and the non-Stars LSH baseline (all pairs per bucket).
//!
//! One *repetition* = one hash function draw h ~ H: bucket all points by
//! h(p), partition oversized buckets, then score within each bucket:
//!
//! * **Stars**: sample `s` random leaders per bucket and compare each leader
//!   to the rest — O(s·|B|) comparisons, producing star graphs whose centers
//!   give two-hop paths between all similar bucket members.
//! * **non-Stars**: compare all pairs — O(|B|²).
//!
//! Edges are created only for pairs scoring ≥ r₁ (`params.threshold`),
//! satisfying condition (1) of Definition 2.4 deterministically.

use crate::ampc::{shuffle::shuffle_group, CostLedger, Dht};
use crate::data::types::Dataset;
use crate::graph::Edge;
use crate::lsh::{sketch, LshFamily};
use crate::sim::Similarity;
use crate::stars::bucketing::{group_buckets, sample_leaders, split_oversized};
use crate::stars::params::{BuildParams, JoinStrategy};
use crate::util::pool;
use crate::util::rng::{derive_seed, Rng};

/// Run one LSH repetition on a single core; returns the edges found.
pub fn lsh_rep(
    ds: &Dataset,
    sim: &dyn Similarity,
    family: &dyn LshFamily,
    params: &BuildParams,
    rep: u64,
    ledger: &CostLedger,
    dht: Option<&Dht<'_>>,
) -> Vec<Edge> {
    lsh_rep_par(ds, sim, family, params, rep, ledger, dht, 1)
}

/// Run one LSH repetition with `inner_workers` cores of in-repetition data
/// parallelism: the sketch phase is chunked over point ranges and bucket
/// scoring is dispatched per bucket over the pool. The builder grants inner
/// cores when a wave has fewer repetitions than workers (small R, wave
/// tails), which previously left those cores idle.
///
/// Determinism: all RNG-dependent decisions (sub-bucket splits, leader
/// draws) are made serially in bucket order before any parallel dispatch,
/// and per-bucket edge batches are concatenated in bucket order — so the
/// edge vector is identical to the single-core path for every
/// `inner_workers` value (asserted by `tests/sketch_parity.rs`).
#[allow(clippy::too_many_arguments)]
pub fn lsh_rep_par(
    ds: &Dataset,
    sim: &dyn Similarity,
    family: &dyn LshFamily,
    params: &BuildParams,
    rep: u64,
    ledger: &CostLedger,
    dht: Option<&Dht<'_>>,
    inner_workers: usize,
) -> Vec<Edge> {
    lsh_rep_par_keys(ds, sim, family, params, rep, ledger, dht, inner_workers, false).0
}

/// [`lsh_rep_par`] that can also hand back the repetition's bucket keys
/// (`keep_keys`), so the builder's snapshot export reuses the exact vectors
/// the sketch phase produced instead of re-preparing a state and
/// re-sketching every point (the ROADMAP "share sketch keys" item). The
/// keys are a byproduct — the edge output is unchanged.
#[allow(clippy::too_many_arguments)]
pub fn lsh_rep_par_keys(
    ds: &Dataset,
    sim: &dyn Similarity,
    family: &dyn LshFamily,
    params: &BuildParams,
    rep: u64,
    ledger: &CostLedger,
    dht: Option<&Dht<'_>>,
    inner_workers: usize,
    keep_keys: bool,
) -> (Vec<Edge>, Option<Vec<u64>>) {
    let n = ds.len();
    let mut rng = Rng::new(derive_seed(params.seed ^ 0x7E9, rep));
    // In-rep parallel phases report extra inner workers' busy spans so Σ
    // busy counts machine-seconds (worker 0 rides the rep's wall charge).
    let inner_busy = |w: usize, nanos: u64| ledger.add_inner_busy(w, nanos);

    // Sketch phase: one prepared state, point chunks over the pool. The
    // phase span's busy aggregates every inner worker's chunk time.
    let sketch_span = ledger.phases().enter("sketch");
    let keys = sketch::bucket_keys_par_timed(family, ds, rep, inner_workers, |w, nanos| {
        inner_busy(w, nanos);
        sketch_span.add_busy(nanos);
    });
    ledger.add_sketches(n as u64);
    drop(sketch_span);

    // Join phase: group ids by bucket key (§4's two strategies).
    let join_span = ledger.phases().enter("join");
    let buckets = match params.join {
        JoinStrategy::Shuffle => {
            let records: Vec<(u64, u32)> =
                keys.iter().enumerate().map(|(i, &k)| (k, i as u32)).collect();
            shuffle_group(records, ledger.workers(), ledger, derive_seed(params.seed, rep))
                .into_iter()
                .filter(|g| g.members.len() >= 2)
                .map(|g| g.members)
                .collect()
        }
        _ => group_buckets(&keys),
    };
    let buckets = split_oversized(buckets, params.max_bucket, &mut rng);
    drop(join_span);

    // Leader pre-draw: consume the repetition RNG in bucket order exactly as
    // the sequential scoring loop did (a draw only for Stars buckets above
    // the all-pairs fallback size), so parallel dispatch cannot perturb the
    // stream. `None` means "score all pairs".
    let stars = params.algorithm.is_stars();
    let s = params.leaders;
    let plans: Vec<Option<Vec<usize>>> = buckets
        .iter()
        .map(|b| {
            if stars && b.len() > 2 * s {
                Some(sample_leaders(b.len(), s, &mut rng))
            } else {
                None
            }
        })
        .collect();

    // Scoring phase: one task per bucket. The ledger is atomic, so parallel
    // tasks charge comparisons/DHT traffic exactly as the serial loop does.
    let threshold = params.threshold;
    let score_bucket = |b: usize, scores: &mut Vec<f32>, edges: &mut Vec<Edge>| {
        let bucket = &buckets[b];
        if let Some(dht) = dht {
            dht.lookup_batch(bucket, ledger);
        }
        match &plans[b] {
            Some(leaders) => score_stars_with_leaders(
                ds, sim, bucket, leaders, threshold, ledger, scores, edges,
            ),
            None => score_all_pairs(ds, sim, bucket, threshold, ledger, scores, edges),
        }
    };
    let score_span = ledger.phases().enter("score");
    let edges = pool::parallel_flat_map_timed(
        buckets.len(),
        inner_workers,
        |w, nanos| {
            inner_busy(w, nanos);
            score_span.add_busy(nanos);
        },
        Vec::<f32>::new,
        score_bucket,
    );
    ledger.add_edges(edges.len() as u64);
    drop(score_span);
    (edges, if keep_keys { Some(keys) } else { None })
}

/// Stars scoring: `s` leaders per bucket, each compared to every other
/// member. Creates leader→member edges with weight μ when μ ≥ threshold.
///
/// For buckets with |B| ≤ 2s, star scoring would cost s(|B|−1) ≥ |B|(|B|−1)/2
/// comparisons — more than exhaustive scoring — so we fall back to all pairs
/// (the analogue of Stars 2's k ≤ n^2ρ branch). This strictly strengthens
/// connectivity, preserving the two-hop spanner guarantee.
pub fn score_stars(
    ds: &Dataset,
    sim: &dyn Similarity,
    bucket: &[u32],
    s: usize,
    threshold: f32,
    rng: &mut Rng,
    ledger: &CostLedger,
    scores: &mut Vec<f32>,
    edges: &mut Vec<Edge>,
) {
    if bucket.len() <= 2 * s {
        score_all_pairs(ds, sim, bucket, threshold, ledger, scores, edges);
        return;
    }
    let leaders = sample_leaders(bucket.len(), s, rng);
    score_stars_with_leaders(ds, sim, bucket, &leaders, threshold, ledger, scores, edges);
}

/// Star scoring with pre-drawn leader positions — the parallel dispatch path
/// ([`lsh_rep_par`] draws all leaders serially up front, then fans buckets
/// out over the pool).
#[allow(clippy::too_many_arguments)]
pub fn score_stars_with_leaders(
    ds: &Dataset,
    sim: &dyn Similarity,
    bucket: &[u32],
    leaders: &[usize],
    threshold: f32,
    ledger: &CostLedger,
    scores: &mut Vec<f32>,
    edges: &mut Vec<Edge>,
) {
    for &lp in leaders {
        let leader = bucket[lp];
        // Compare the leader to every other member (paper: y ∈ B \ {x}) by
        // scoring the two contiguous halves around the leader position — the
        // batch kernels tile straight from the bucket slice, and no per-
        // leader candidate copy is ever made.
        let (before, rest) = bucket.split_at(lp);
        let after = &rest[1..];
        ledger.add_comparisons((bucket.len() - 1) as u64);
        for part in [before, after] {
            if part.is_empty() {
                continue;
            }
            sim.sim_batch(ds, leader as usize, part, scores);
            for (k, &c) in part.iter().enumerate() {
                let w = scores[k];
                if w >= threshold && c != leader {
                    edges.push(Edge::new(leader, c, w));
                }
            }
        }
    }
}

/// Non-Stars scoring: all pairs within the bucket.
pub fn score_all_pairs(
    ds: &Dataset,
    sim: &dyn Similarity,
    bucket: &[u32],
    threshold: f32,
    ledger: &CostLedger,
    scores: &mut Vec<f32>,
    edges: &mut Vec<Edge>,
) {
    for (pos, &a) in bucket.iter().enumerate() {
        let rest = &bucket[pos + 1..];
        if rest.is_empty() {
            continue;
        }
        ledger.add_comparisons(rest.len() as u64);
        sim.sim_batch(ds, a as usize, rest, scores);
        for (k, &b) in rest.iter().enumerate() {
            let w = scores[k];
            if w >= threshold && a != b {
                edges.push(Edge::new(a, b, w));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::lsh::SimHash;
    use crate::sim::CosineSim;
    use crate::stars::params::Algorithm;

    fn setup() -> (Dataset, SimHash) {
        let ds = synth::gaussian_mixture(300, 16, 6, 0.08, 4);
        let h = SimHash::new(16, 8, 9);
        (ds, h)
    }

    #[test]
    fn stars_uses_fewer_comparisons_than_all_pairs() {
        let (ds, h) = setup();
        let p_stars = BuildParams::threshold_mode(Algorithm::LshStars).leaders(2);
        let p_np = BuildParams::threshold_mode(Algorithm::Lsh);
        let l1 = CostLedger::new(1);
        let l2 = CostLedger::new(1);
        lsh_rep(&ds, &CosineSim, &h, &p_stars, 0, &l1, None);
        lsh_rep(&ds, &CosineSim, &h, &p_np, 0, &l2, None);
        assert!(
            l1.comparisons() < l2.comparisons(),
            "stars {} !< non-stars {}",
            l1.comparisons(),
            l2.comparisons()
        );
        assert!(l2.comparisons() > 0);
    }

    #[test]
    fn edges_respect_threshold() {
        let (ds, h) = setup();
        let p = BuildParams::threshold_mode(Algorithm::LshStars).threshold(0.6);
        let ledger = CostLedger::new(1);
        let edges = lsh_rep(&ds, &CosineSim, &h, &p, 1, &ledger, None);
        assert!(!edges.is_empty(), "no edges found");
        for e in &edges {
            assert!(e.w >= 0.6, "edge below threshold: {}", e.w);
            let actual = CosineSim.sim(&ds, e.u as usize, e.v as usize);
            assert!((actual - e.w).abs() < 1e-5, "weight != similarity");
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let (ds, h) = setup();
        let p = BuildParams::threshold_mode(Algorithm::LshStars).seed(77);
        let l = CostLedger::new(1);
        let e1 = lsh_rep(&ds, &CosineSim, &h, &p, 3, &l, None);
        let e2 = lsh_rep(&ds, &CosineSim, &h, &p, 3, &l, None);
        assert_eq!(e1.len(), e2.len());
        assert_eq!(e1, e2);
    }

    #[test]
    fn keyed_variant_returns_the_sketch_keys_unchanged_edges() {
        let (ds, h) = setup();
        let p = BuildParams::threshold_mode(Algorithm::LshStars);
        let l = CostLedger::new(1);
        let (e1, keys) = lsh_rep_par_keys(&ds, &CosineSim, &h, &p, 2, &l, None, 1, true);
        assert_eq!(keys.expect("keys requested"), h.bucket_keys(&ds, 2));
        let e2 = lsh_rep(&ds, &CosineSim, &h, &p, 2, &l, None);
        assert_eq!(e1, e2, "keeping keys must not perturb the edges");
        let (_, none) = lsh_rep_par_keys(&ds, &CosineSim, &h, &p, 2, &l, None, 1, false);
        assert!(none.is_none());
    }

    #[test]
    fn inner_workers_charge_extra_machine_seconds() {
        // Large enough that the sketch drivers actually split chunks
        // (PAR_MIN_CHUNK) and the bucket fan-out engages the pool.
        let ds = synth::gaussian_mixture(4000, 16, 8, 0.1, 5);
        let h = SimHash::new(16, 8, 9);
        let p = BuildParams::threshold_mode(Algorithm::LshStars);
        // Single inner worker: all busy reports land on index 0, which the
        // ledger treats as covered by the repetition's wall charge.
        let l1 = CostLedger::new(4);
        let e1 = lsh_rep_par(&ds, &CosineSim, &h, &p, 0, &l1, None, 1);
        assert_eq!(l1.total_time(), 0.0);
        // Four inner workers: extra machines report busy seconds, and the
        // edge output is unchanged.
        let l4 = CostLedger::new(4);
        let e4 = lsh_rep_par(&ds, &CosineSim, &h, &p, 0, &l4, None, 4);
        assert_eq!(e1, e4);
        assert!(l4.total_time() > 0.0, "inner workers reported no busy time");
    }

    #[test]
    fn shuffle_join_matches_direct_join_edges() {
        let (ds, h) = setup();
        let base = BuildParams::threshold_mode(Algorithm::Lsh);
        let direct = base.clone();
        let shuffle = base.join(JoinStrategy::Shuffle);
        let l1 = CostLedger::new(2);
        let l2 = CostLedger::new(2);
        let mut e1 = lsh_rep(&ds, &CosineSim, &h, &direct, 5, &l1, None);
        let mut e2 = lsh_rep(&ds, &CosineSim, &h, &shuffle, 5, &l2, None);
        // Same buckets (up to sub-bucket randomization of oversized buckets —
        // none here), so identical edge sets after sorting.
        e1.sort_by_key(|e| e.key());
        e2.sort_by_key(|e| e.key());
        assert_eq!(e1, e2);
        assert!(l2.report(0.0).shuffle_bytes > 0);
        assert_eq!(l2.report(0.0).shuffle_bytes % 12, 0);
    }

    #[test]
    fn dht_join_charges_lookups() {
        let (ds, h) = setup();
        let p = BuildParams::threshold_mode(Algorithm::LshStars).join(JoinStrategy::Dht);
        let ledger = CostLedger::new(1);
        let dht = Dht::new(&ds, 8);
        lsh_rep(&ds, &CosineSim, &h, &p, 0, &ledger, Some(&dht));
        assert!(ledger.report(0.0).dht_lookups > 0);
    }

    #[test]
    fn bucket_cap_limits_comparisons() {
        let (ds, h) = setup();
        // One-bit hash -> two huge buckets; cap 10 forces sub-buckets.
        let h1 = SimHash::new(16, 1, 2);
        let capped = BuildParams::threshold_mode(Algorithm::Lsh).max_bucket(10);
        let uncapped = BuildParams::threshold_mode(Algorithm::Lsh).max_bucket(100_000);
        let l1 = CostLedger::new(1);
        let l2 = CostLedger::new(1);
        lsh_rep(&ds, &CosineSim, &h1, &capped, 0, &l1, None);
        lsh_rep(&ds, &CosineSim, &h1, &uncapped, 0, &l2, None);
        assert!(l1.comparisons() * 4 < l2.comparisons());
        let _ = h;
    }

    #[test]
    fn leaders_one_gives_single_star_per_bucket() {
        let (ds, _) = setup();
        let bucket: Vec<u32> = (0..20).collect();
        let mut rng = Rng::new(3);
        let ledger = CostLedger::new(1);
        let mut scores = Vec::new();
        let mut edges = Vec::new();
        score_stars(
            &ds, &CosineSim, &bucket, 1, f32::MIN, &mut rng, &ledger, &mut scores, &mut edges,
        );
        assert_eq!(ledger.comparisons(), 19);
        assert_eq!(edges.len(), 19);
        // All edges share the single leader endpoint.
        let leader_counts: std::collections::HashMap<u32, usize> =
            edges.iter().flat_map(|e| [e.u, e.v]).fold(Default::default(), |mut m, v| {
                *m.entry(v).or_default() += 1;
                m
            });
        assert!(leader_counts.values().any(|&c| c == 19));
    }
}
