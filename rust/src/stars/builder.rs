//! [`StarsBuilder`] — the crate's main entry point.
//!
//! Orchestrates a full graph build: repetitions fan out over the AMPC
//! cluster in waves; each wave's edges fold into a degree-capped,
//! **node-sharded** [`Accumulator`] so memory stays bounded at ~n·cap
//! retained edges regardless of R (the paper's degree threshold of 250
//! applied online) and the fold itself runs across the worker pool instead
//! of serializing on the coordinator.
//!
//! Three exits from a build:
//!
//! * [`StarsBuilder::build`] — the graph plus its [`CostReport`].
//! * [`StarsBuilder::build_with_keys`] — additionally hands back the
//!   per-repetition bucket keys the sketch phase computed, so downstream
//!   consumers (snapshot export) never re-sketch repetitions the build
//!   already paid for.
//! * [`StarsBuilder::build_indexed`] — build **and** export a serving
//!   snapshot ([`StarIndex`]) in one step, reusing the build's keys for the
//!   routing repetitions and attaching the snapshot's memory telemetry to
//!   the report.
//!
//! The serving layer's incremental compaction re-enters this module through
//! [`Accumulator::reopen_from_csr`]: a finalized snapshot graph becomes an
//! accumulator again, delta edge waves fold in, and `finalize` produces the
//! next epoch's graph without rescoring the corpus.

use crate::ampc::{Cluster, CostReport, Dht};
use crate::data::types::Dataset;
use crate::graph::{Csr, Edge, Graph};
use crate::lsh::LshFamily;
use crate::serve::StarIndex;
use crate::sim::Similarity;
use crate::stars::params::{Algorithm, BuildParams, JoinStrategy};
use crate::stars::{allpair, knn, threshold};
use crate::util::fxhash::FxHashMap;
use crate::util::pool;
use crate::util::topk::TopK;
use std::sync::Mutex;

/// Result of a graph build.
#[derive(Debug)]
pub struct BuildOutput {
    /// The deduplicated, degree-capped similarity graph.
    pub graph: Graph,
    /// Cost report (comparisons, total/real time, I/O).
    pub report: CostReport,
    /// Echo of the parameters used.
    pub params: BuildParams,
}

/// Wave-restart budget: a failed wave is re-driven from its checkpoint at
/// most this many times before the failure is allowed to surface. The
/// cluster's per-task failure record persists across restarts, so any
/// bounded fault schedule converges well inside this.
const MAX_WAVE_RESTARTS: u32 = 32;

/// Builder for a Stars graph construction job.
pub struct StarsBuilder<'a> {
    ds: &'a Dataset,
    sim: Option<&'a dyn Similarity>,
    family: Option<&'a dyn LshFamily>,
    params: Option<BuildParams>,
    workers: usize,
    faults: Option<crate::util::fault::FaultPlan>,
}

impl<'a> StarsBuilder<'a> {
    /// Start a build over a dataset.
    pub fn new(ds: &'a Dataset) -> StarsBuilder<'a> {
        StarsBuilder {
            ds,
            sim: None,
            family: None,
            params: None,
            workers: crate::util::pool::default_workers(),
            faults: None,
        }
    }

    /// Similarity measure (required).
    pub fn similarity(mut self, sim: &'a dyn Similarity) -> Self {
        self.sim = Some(sim);
        self
    }

    /// LSH family (required unless algorithm is AllPair).
    pub fn hash(mut self, family: &'a dyn LshFamily) -> Self {
        self.family = Some(family);
        self
    }

    /// Build parameters (required).
    pub fn params(mut self, params: BuildParams) -> Self {
        self.params = Some(params);
        self
    }

    /// Worker count for the simulated cluster (default: host cores).
    pub fn workers(mut self, workers: usize) -> Self {
        self.workers = workers.max(1);
        self
    }

    /// Pin an explicit fault schedule for this build's cluster (default:
    /// whatever `STARS_FAULTS` says, inert when unset). Tests use this —
    /// mutating the process environment races across parallel test
    /// threads; a pinned plan does not. The build's output is
    /// bit-identical under any plan; only the recovery counters on the
    /// report differ.
    pub fn faults(mut self, plan: crate::util::fault::FaultPlan) -> Self {
        self.faults = Some(plan);
        self
    }

    /// Run the build and export a serving snapshot over the result in one
    /// step: the returned [`StarIndex`] freezes the built graph (CSR), the
    /// dataset, one prepared sketch state per routing repetition, and the
    /// bucket-key → entry tables. Routing repetitions reuse the build's
    /// repetition ids (`0..route_reps`), so for shared ids the router's
    /// buckets are exactly the buckets the builder scored — and for
    /// LSH-bucketing builds the per-rep key vectors themselves are handed
    /// from the build to the snapshot ([`StarsBuilder::build_with_keys`]),
    /// so the export never re-sketches repetitions the build already paid
    /// for. The returned report carries the snapshot's memory telemetry
    /// ([`crate::ampc::SnapshotStats`]).
    pub fn build_indexed(self, serve: crate::serve::ServeConfig) -> (BuildOutput, StarIndex<'a>) {
        let ds = self.ds;
        let family = self.family.expect("hash family not set");
        let workers = self.workers;
        let (mut out, keys) = self.build_with_keys(serve.route_reps.max(1));
        // Repetitions the build never bucket-keyed (SortingLSH sorts symbol
        // rows, it has no bucket keys; `keep_keys` beyond the build's
        // repetition count) come back `None`; the export re-sketches those
        // through fresh states rather than silently dropping routing reps —
        // correct but paid for twice, hence the notice.
        let missing = keys.iter().filter(|k| k.is_none()).count();
        if missing > 0 {
            crate::info!(
                "snapshot export: re-sketching {missing} routing repetition(s) the build did \
                 not bucket-key (sorted-window/AllPair builds share no keys)"
            );
        }
        let index = StarIndex::build_from_keys(ds.clone(), family, &out.graph, serve, workers, keys);
        out.report.snapshot = Some(index.stats());
        (out, index)
    }

    /// [`StarsBuilder::build_indexed`], then partition the snapshot into a
    /// [`crate::serve::ShardedIndex`] over `n_shards` contiguous ownership
    /// ranges — the build artifact for scatter-gather serving
    /// ([`crate::serve::ShardedEngine`]). Routing repetitions are sketched
    /// once (reusing the build's keys where available, with the same
    /// re-sketch fallback and notice as `build_indexed`) and split by
    /// fence; the shards never re-sketch.
    ///
    /// Sharded serving requires the full two-hop candidate set per query
    /// (the shard-invariance argument in [`crate::serve::sharded`]), so a
    /// nonzero `max_candidates` is overridden to 0 here, with a logged
    /// notice — [`crate::serve::ShardedEngine::new`] asserts it.
    pub fn build_sharded(
        self,
        n_shards: usize,
        mut serve: crate::serve::ServeConfig,
    ) -> (BuildOutput, crate::serve::ShardedIndex<'a>) {
        if serve.max_candidates != 0 {
            crate::info!(
                "build_sharded: overriding max_candidates {} -> 0 (the global cap truncates \
                 in probe order, which no fence partition can replicate)",
                serve.max_candidates
            );
            serve.max_candidates = 0;
        }
        let (out, index) = self.build_indexed(serve);
        (out, crate::serve::ShardedIndex::new(index, n_shards))
    }

    /// Run the build.
    pub fn build(self) -> BuildOutput {
        self.build_with_keys(0).0
    }

    /// Run the build, also handing back the per-repetition bucket keys for
    /// repetitions `< keep_keys` — the ROADMAP "share sketch keys" path:
    /// `build_indexed` routes these straight into the snapshot export
    /// instead of re-preparing states and re-sketching n points per
    /// routing repetition. Entries are `None` for repetitions the build
    /// never bucket-keyed (SortingLSH sorts symbol rows; AllPair hashes
    /// nothing) or that exceed the repetition count.
    pub fn build_with_keys(
        self,
        keep_keys: usize,
    ) -> (BuildOutput, Vec<Option<Vec<u64>>>) {
        let params = self.params.expect("params not set");
        let sim = self.sim.expect("similarity not set");
        let cluster = match self.faults {
            Some(plan) => Cluster::with_faults(self.workers, plan),
            None => Cluster::new(self.workers),
        };
        let n = self.ds.len();

        let ((graph, kept), report) = cluster.run_job(|c| {
            // Root phase span for the whole job: its wall time reconciles
            // with the report's real_time (tests/obs.rs). Pure observation —
            // no result depends on it.
            let _build_span = c.ledger().phases().enter_root("build");
            let mut kept: Vec<Option<Vec<u64>>> = vec![None; keep_keys];
            if params.algorithm == Algorithm::AllPair {
                let edges = allpair::allpair_edges(self.ds, sim, params.threshold, c);
                return (finalize(n, edges, params.degree_cap, c.workers()), kept);
            }
            let family = self.family.expect("hash family not set");
            let dht_store;
            let dht = match params.join {
                JoinStrategy::Dht => {
                    dht_store = Dht::new(self.ds, c.workers());
                    Some(&dht_store)
                }
                _ => None,
            };
            let wave = c.workers().max(1);
            let mut acc = Accumulator::with_workers(n, params.degree_cap, wave);
            let reps = params.sketches;
            let mut done = 0usize;
            while done < reps {
                let count = wave.min(reps - done);
                // When the wave carries fewer repetitions than workers
                // (R < workers, or the last wave's tail), grant each
                // repetition the spare cores for in-repetition data
                // parallelism — sketch chunks and bucket/window scoring
                // tasks — instead of leaving them idle. Edge output is
                // identical for any split (see lsh_rep_par docs), so the
                // graph does not depend on the wave geometry.
                let inner = (wave / count).max(1);
                // Checkpointed wave execution: `done` completed repetitions
                // are already folded into the accumulator, so a wave that
                // fails (a task exhausted its in-place retry budget) is
                // re-driven from here rather than restarting the build.
                // The wave's round label is `done`, stable across restarts,
                // so the fault schedule — and every repetition's output —
                // is the same on the re-drive; the accumulator is only
                // touched after the wave succeeds.
                let mut restarts = 0u32;
                let results = loop {
                    let attempt = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                        c.map_timed_round(done as u64, count, |t, ledger| {
                            let rep = (done + t) as u64;
                            // Root-anchored so the path is "build/rep"
                            // whether the task runs on a pool worker or is
                            // re-executed inline by the straggler pass.
                            let _rep_span = ledger.phases().enter_root("build/rep");
                            match params.algorithm {
                                Algorithm::Lsh | Algorithm::LshStars => {
                                    threshold::lsh_rep_par_keys(
                                        self.ds,
                                        sim,
                                        family,
                                        &params,
                                        rep,
                                        ledger,
                                        dht,
                                        inner,
                                        (rep as usize) < keep_keys,
                                    )
                                }
                                Algorithm::SortingLsh | Algorithm::SortingLshStars => (
                                    knn::sorting_rep_par(
                                        self.ds, sim, family, &params, rep, ledger, inner,
                                    ),
                                    None,
                                ),
                                Algorithm::AllPair => unreachable!(),
                            }
                        })
                    }));
                    match attempt {
                        Ok(r) => break r,
                        Err(payload) => {
                            restarts += 1;
                            if !c.ledger().faults().is_active() || restarts > MAX_WAVE_RESTARTS {
                                std::panic::resume_unwind(payload);
                            }
                            c.ledger().add_wave_restart();
                        }
                    }
                };
                let mut batches = Vec::with_capacity(results.len());
                for (t, (edges, keys)) in results.into_iter().enumerate() {
                    if let Some(k) = keys {
                        if done + t < kept.len() {
                            kept[done + t] = Some(k);
                        }
                    }
                    batches.push(edges);
                }
                {
                    let _acc_span = c.ledger().phases().enter("accumulate");
                    acc.add_wave(batches);
                }
                done += count;
            }
            let _fin_span = c.ledger().phases().enter("finalize");
            (acc.finalize(), kept)
        });

        (
            BuildOutput {
                graph,
                report,
                params,
            },
            kept,
        )
    }
}

fn finalize(n: usize, edges: Vec<Edge>, degree_cap: usize, workers: usize) -> Graph {
    let mut acc = Accumulator::with_workers(n, degree_cap, workers);
    acc.add_wave(vec![edges]);
    acc.finalize()
}

/// Waves smaller than this fold serially — below it the staging pass costs
/// more than it saves.
const PARALLEL_WAVE_MIN: usize = 4096;

/// Per-node neighbor state: a dedup map (keep the max weight seen per
/// neighbor) plus the eviction floor — once a bounded top-k eviction has run,
/// any candidate strictly below the weakest retained weight can never enter
/// the node's final top-`cap` (retained entries only leave via evictions that
/// keep the top `cap`, and weights only grow under max-dedup), so it is
/// dropped without touching the map.
#[derive(Clone)]
struct NodeAcc {
    nbrs: FxHashMap<u32, f32>,
    floor: f32,
}

impl NodeAcc {
    fn new() -> NodeAcc {
        NodeAcc {
            nbrs: FxHashMap::default(),
            floor: f32::NEG_INFINITY,
        }
    }

    #[inline]
    fn offer(&mut self, nbr: u32, w: f32, cap: usize) {
        if w < self.floor {
            return;
        }
        let entry = self.nbrs.entry(nbr).or_insert(f32::NEG_INFINITY);
        if w > *entry {
            *entry = w;
        }
        if self.nbrs.len() > 2 * cap {
            // Bounded top-k eviction: O(m log cap) selection instead of the
            // previous drain + full sort (O(m log m)).
            let mut top: TopK<u32> = TopK::new(cap);
            for (&nbr, &w) in &self.nbrs {
                top.push(w, nbr);
            }
            self.floor = top.threshold().unwrap_or(f32::NEG_INFINITY);
            self.nbrs.clear();
            for (w, nbr) in top.into_sorted() {
                self.nbrs.insert(nbr, w);
            }
        }
    }
}

/// A contiguous node range `[lo, lo + nodes.len())` of the accumulator.
struct Shard {
    lo: u32,
    nodes: Vec<NodeAcc>,
}

/// Online degree-capped edge accumulator, sharded by contiguous node range.
///
/// With `cap == 0` it keeps every (deduplicated) edge. With a cap it keeps,
/// per node, its best neighbors under bounded top-k eviction — memory is
/// O(n·cap) while retained edges match "keep the cap most-similar neighbors
/// per node" (an edge survives if either endpoint retains it, matching
/// [`crate::graph::Csr::with_degree_cap`]).
///
/// [`Accumulator::add_wave`] folds a whole wave of per-repetition batches in
/// parallel: batches are partitioned by destination shard across the worker
/// pool, then each shard folds its slice independently. Per node, entries
/// arrive in (batch order, edge order) — the same order the serial fold
/// uses — so sharded and serial accumulation produce identical graphs
/// (verified by `tests/batch_parity.rs`; f32 weight ties may be broken
/// either way, as in the serial fold).
pub struct Accumulator {
    n: usize,
    cap: usize,
    workers: usize,
    shard_size: usize,
    raw: Vec<Edge>,
    shards: Vec<Mutex<Shard>>,
}

impl Accumulator {
    /// New accumulator over `n` nodes, sized to the host's worker pool.
    pub fn new(n: usize, cap: usize) -> Accumulator {
        Accumulator::with_workers(n, cap, pool::default_workers())
    }

    /// New accumulator over `n` nodes with an explicit worker count.
    pub fn with_workers(n: usize, cap: usize, workers: usize) -> Accumulator {
        let workers = workers.max(1);
        // 2 shards per worker: contiguous ranges balance unevenly when node
        // ids correlate with density, so oversharding smooths the tail.
        let shard_size = if cap == 0 || n == 0 {
            1
        } else {
            n.div_ceil(workers * 2).max(1)
        };
        let mut shards = Vec::new();
        if cap > 0 {
            let mut lo = 0usize;
            while lo < n {
                let hi = (lo + shard_size).min(n);
                shards.push(Mutex::new(Shard {
                    lo: lo as u32,
                    nodes: vec![NodeAcc::new(); hi - lo],
                }));
                lo = hi;
            }
        }
        Accumulator {
            n,
            cap,
            workers,
            shard_size,
            raw: Vec::new(),
            shards,
        }
    }

    /// Re-open a finalized graph for incremental folding: an accumulator
    /// over `n ≥ csr.num_nodes()` nodes (new nodes start empty) seeded with
    /// the snapshot CSR's surviving edges, ready to `add_wave` delta edge
    /// batches and `finalize` into the next epoch's graph.
    ///
    /// Equivalence: per node, the CSR adjacency is a superset of the node's
    /// own top-`cap` over everything the snapshot build offered it (the
    /// either-endpoint retention rule only ever *adds* partner-kept
    /// entries), and a candidate outside a top-`cap` cannot re-enter the
    /// top-`cap` of any candidate superset — so folding delta edges here
    /// and finalizing selects, per node, exactly what a from-scratch build
    /// over (snapshot candidates ∪ delta edges) would select, up to f32
    /// weight ties. This is what makes O(|delta|) compaction bit-compatible
    /// with a full rebuild (`tests/serve_integration.rs`).
    pub fn reopen_from_csr(csr: &Csr, n: usize, cap: usize, workers: usize) -> Accumulator {
        assert!(n >= csr.num_nodes(), "cannot shrink the node range");
        let mut acc = Accumulator::with_workers(n, cap, workers);
        if cap == 0 {
            // Uncapped: replay each surviving undirected edge once.
            for u in 0..csr.num_nodes() as u32 {
                for (v, w) in csr.neighbors(u) {
                    if u < v {
                        acc.raw.push(Edge::new(u, v, w));
                    }
                }
            }
            return acc;
        }
        {
            let shards = &acc.shards;
            let chunk_workers = workers.max(1).min(shards.len().max(1));
            pool::parallel_chunks(shards.len(), chunk_workers, |_, range| {
                for s in range {
                    let mut shard = shards[s].lock().unwrap();
                    let lo = shard.lo as usize;
                    let hi = (lo + shard.nodes.len()).min(csr.num_nodes());
                    for u in lo..hi {
                        let node = &mut shard.nodes[u - lo];
                        for (v, w) in csr.neighbors(u as u32) {
                            node.offer(v, w, cap);
                        }
                    }
                }
            });
        }
        acc
    }

    /// Fold a batch of edges in, serially (small batches / tests).
    pub fn add(&mut self, edges: Vec<Edge>) {
        if self.cap == 0 {
            self.raw.extend(edges);
            return;
        }
        let cap = self.cap;
        for e in &edges {
            for (node, nbr) in [(e.u, e.v), (e.v, e.u)] {
                let shard = self.shards[node as usize / self.shard_size]
                    .get_mut()
                    .unwrap();
                let idx = node as usize - shard.lo as usize;
                shard.nodes[idx].offer(nbr, e.w, cap);
            }
        }
    }

    /// Fold a whole wave of per-repetition batches in, in parallel across
    /// the worker pool. Equivalent to `add`-ing each batch in order.
    pub fn add_wave(&mut self, batches: Vec<Vec<Edge>>) {
        if self.cap == 0 {
            for b in batches {
                self.raw.extend(b);
            }
            return;
        }
        let total: usize = batches.iter().map(|b| b.len()).sum();
        if self.workers == 1 || total < PARALLEL_WAVE_MIN {
            for b in batches {
                self.add(b);
            }
            return;
        }
        let nshards = self.shards.len();
        let shard_size = self.shard_size;
        // Phase 1: partition each batch's half-edges by destination shard
        // (one task per batch, dynamically balanced).
        let staged: Vec<Vec<Vec<(u32, u32, f32)>>> =
            pool::parallel_map(batches.len(), self.workers, |b| {
                let mut parts: Vec<Vec<(u32, u32, f32)>> = vec![Vec::new(); nshards];
                for e in &batches[b] {
                    parts[e.u as usize / shard_size].push((e.u, e.v, e.w));
                    parts[e.v as usize / shard_size].push((e.v, e.u, e.w));
                }
                parts
            });
        drop(batches);
        // Phase 2: each shard folds its staged entries, batches in wave
        // order, so per-node insertion order matches the serial fold. Each
        // shard is visited by exactly one chunk, so the locks never contend.
        let cap = self.cap;
        let shards = &self.shards;
        pool::parallel_chunks(nshards, self.workers, |_, range| {
            for s in range {
                let mut shard = shards[s].lock().unwrap();
                let lo = shard.lo as usize;
                for batch in &staged {
                    for &(node, nbr, w) in &batch[s] {
                        shard.nodes[node as usize - lo].offer(nbr, w, cap);
                    }
                }
            }
        });
    }

    /// Produce the final graph (per-shard top-`cap` selection in parallel).
    pub fn finalize(mut self) -> Graph {
        if self.cap == 0 {
            return Graph::from_edges(self.n, std::mem::take(&mut self.raw));
        }
        let cap = self.cap;
        // `finalize` consumes the accumulator, so it exclusively owns every
        // shard: take them out of their mutexes instead of locking each one.
        let shards: Vec<Shard> = std::mem::take(&mut self.shards)
            .into_iter()
            .map(|m| m.into_inner().unwrap())
            .collect();
        let workers = self.workers.min(shards.len().max(1));
        let parts = pool::parallel_chunks(shards.len(), workers, |_, range| {
            let mut edges = Vec::new();
            for s in range {
                let shard = &shards[s];
                for (i, acc) in shard.nodes.iter().enumerate() {
                    let node = shard.lo + i as u32;
                    if acc.nbrs.len() > cap {
                        let mut top: TopK<u32> = TopK::new(cap);
                        for (&nbr, &w) in &acc.nbrs {
                            top.push(w, nbr);
                        }
                        for (w, nbr) in top.into_sorted() {
                            edges.push(Edge::new(node, nbr, w));
                        }
                    } else {
                        for (&nbr, &w) in &acc.nbrs {
                            edges.push(Edge::new(node, nbr, w));
                        }
                    }
                }
            }
            edges
        });
        Graph::from_parts(self.n, parts)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::lsh::SimHash;
    use crate::sim::{CosineSim, CountingSim};

    #[test]
    fn accumulator_uncapped_keeps_everything() {
        let mut acc = Accumulator::new(5, 0);
        acc.add(vec![Edge::new(0, 1, 0.5), Edge::new(1, 2, 0.6)]);
        acc.add(vec![Edge::new(0, 1, 0.9)]);
        let g = acc.finalize();
        assert_eq!(g.num_edges(), 2);
        assert_eq!(g.edges()[0].w, 0.9); // dedup keeps max
    }

    #[test]
    fn accumulator_caps_degree() {
        let mut acc = Accumulator::new(10, 2);
        // Node 0 sees 6 neighbors with increasing weights.
        acc.add((1..=6).map(|v| Edge::new(0, v, v as f32 / 10.0)).collect());
        let g = acc.finalize();
        let kept: Vec<&Edge> = g.edges().iter().collect();
        // Node 0 keeps its best 2 (5, 6) — but each neighbor also keeps the
        // edge from its own side, and their degree is 1 ≤ cap, so all
        // survive under the either-endpoint rule.
        assert_eq!(kept.len(), 6);
        // Now flood every node: pairwise clique weights distinct.
        let mut acc = Accumulator::new(10, 1);
        let mut edges = Vec::new();
        for i in 0..10u32 {
            for j in (i + 1)..10 {
                edges.push(Edge::new(i, j, (i * 10 + j) as f32 / 100.0));
            }
        }
        acc.add(edges);
        let g = acc.finalize();
        // Each node keeps 1 → at most 10 edges survive.
        assert!(g.num_edges() <= 10, "{} edges", g.num_edges());
    }

    #[test]
    fn eviction_keeps_the_strongest() {
        // Push 99 neighbors of node 0 in increasing weight; survivors must
        // be the heaviest ones despite repeated eviction passes.
        let mut acc = Accumulator::new(200, 2);
        for v in 1..100u32 {
            acc.add(vec![Edge::new(0, v + 1, v as f32 / 100.0)]);
        }
        let g = acc.finalize();
        let best: Vec<f32> = g
            .edges()
            .iter()
            .filter(|e| e.u == 0)
            .map(|e| e.w)
            .collect();
        assert!(best.iter().any(|&w| (w - 0.99).abs() < 1e-6));
    }

    #[test]
    fn eviction_floor_admits_later_stronger_entries() {
        // Interleave weak and strong inserts so evictions run mid-stream;
        // a neighbor strictly above the floor must still get in.
        let mut acc = Accumulator::with_workers(50, 2, 1);
        let mut edges = Vec::new();
        for v in 1..40u32 {
            edges.push(Edge::new(0, v, 0.3 + (v as f32 % 7.0) * 1e-3));
        }
        edges.push(Edge::new(0, 41, 0.9));
        edges.push(Edge::new(0, 42, 0.95));
        acc.add(edges);
        let g = acc.finalize();
        let node0: Vec<(u32, f32)> = g
            .edges()
            .iter()
            .filter(|e| e.u == 0)
            .map(|e| (e.v, e.w))
            .collect();
        assert!(node0.iter().any(|&(v, _)| v == 41));
        assert!(node0.iter().any(|&(v, _)| v == 42));
    }

    #[test]
    fn add_wave_matches_sequential_adds() {
        // Same edges folded as one parallel wave vs one batch at a time.
        let mut rng = crate::util::rng::Rng::new(77);
        let n = 300usize;
        let mut batches = Vec::new();
        let mut uniq = 0u32;
        for _ in 0..8 {
            let mut batch = Vec::new();
            for _ in 0..2000 {
                let u = rng.below(n) as u32;
                let mut v = rng.below(n) as u32;
                if u == v {
                    v = (v + 1) % n as u32;
                }
                // Unique weights: ties cannot mask ordering bugs.
                uniq += 1;
                batch.push(Edge::new(u, v, uniq as f32 * 1e-5));
            }
            batches.push(batch);
        }
        let mut wave = Accumulator::with_workers(n, 5, 4);
        wave.add_wave(batches.clone());
        let g1 = wave.finalize();
        let mut seq = Accumulator::with_workers(n, 5, 1);
        for b in batches {
            seq.add(b);
        }
        let g2 = seq.finalize();
        assert_eq!(g1.num_edges(), g2.num_edges());
        assert_eq!(g1.edges(), g2.edges());
    }

    #[test]
    fn reopened_accumulator_matches_from_scratch_fold() {
        // Folding a second wave into an accumulator re-opened from the
        // finalized first wave must equal folding both waves from scratch
        // (unique weights, so eviction order cannot hide behind ties).
        let mut rng = crate::util::rng::Rng::new(91);
        let n = 300usize;
        let mut batches = Vec::new();
        let mut uniq = 0u32;
        for _ in 0..8 {
            let mut batch = Vec::new();
            for _ in 0..1500 {
                let u = rng.below(n) as u32;
                let mut v = rng.below(n) as u32;
                if u == v {
                    v = (v + 1) % n as u32;
                }
                uniq += 1;
                batch.push(Edge::new(u, v, uniq as f32 * 1e-5));
            }
            batches.push(batch);
        }
        let mut scratch = Accumulator::with_workers(n, 5, 4);
        scratch.add_wave(batches.clone());
        let want = scratch.finalize();

        let (first, second) = batches.split_at(4);
        let mut acc = Accumulator::with_workers(n, 5, 2);
        acc.add_wave(first.to_vec());
        let snapshot = acc.finalize();
        let csr = Csr::new(&snapshot);
        let mut reopened = Accumulator::reopen_from_csr(&csr, n, 5, 3);
        reopened.add_wave(second.to_vec());
        let got = reopened.finalize();
        assert_eq!(want.num_edges(), got.num_edges());
        assert_eq!(want.edges(), got.edges());
    }

    #[test]
    fn reopen_grows_the_node_range_for_delta_points() {
        // Snapshot over 4 nodes; reopen over 6 and wire the new nodes in.
        let mut acc = Accumulator::with_workers(4, 2, 1);
        acc.add(vec![Edge::new(0, 1, 0.9), Edge::new(2, 3, 0.8)]);
        let csr = Csr::new(&acc.finalize());
        let mut re = Accumulator::reopen_from_csr(&csr, 6, 2, 2);
        re.add(vec![Edge::new(4, 0, 0.7), Edge::new(5, 4, 0.6)]);
        let g = re.finalize();
        assert_eq!(g.num_nodes(), 6);
        let mut keys: Vec<(u32, u32)> = g.edges().iter().map(|e| (e.u, e.v)).collect();
        keys.sort_unstable();
        assert_eq!(keys, vec![(0, 1), (0, 4), (2, 3), (4, 5)]);
        // Uncapped reopen replays the snapshot edges verbatim.
        let re0 = Accumulator::reopen_from_csr(&csr, 6, 0, 2);
        let g0 = re0.finalize();
        assert_eq!(g0.num_edges(), 2);
    }

    #[test]
    fn build_with_keys_exports_the_build_reps_keys() {
        let ds = synth::gaussian_mixture(300, 16, 6, 0.08, 25);
        let family = SimHash::new(16, 8, 5);
        let (out, keys) = StarsBuilder::new(&ds)
            .similarity(&CosineSim)
            .hash(&family)
            .params(
                crate::stars::BuildParams::threshold_mode(Algorithm::LshStars)
                    .sketches(6)
                    .threshold(0.4),
            )
            .workers(2)
            .build_with_keys(4);
        assert!(out.graph.num_edges() > 0);
        assert_eq!(keys.len(), 4);
        for (rep, k) in keys.iter().enumerate() {
            assert_eq!(
                k.as_ref().expect("lsh build must export keys"),
                &family.bucket_keys(&ds, rep as u64),
                "rep {rep}"
            );
        }
        // Sorting builds never compute bucket keys — nothing to share.
        let sorting = SimHash::new(16, 30, 6);
        let (_, keys) = StarsBuilder::new(&ds)
            .similarity(&CosineSim)
            .hash(&sorting)
            .params(
                crate::stars::BuildParams::knn_mode(Algorithm::SortingLshStars)
                    .sketches(3)
                    .window(50)
                    .degree_cap(10),
            )
            .workers(2)
            .build_with_keys(3);
        assert!(keys.iter().all(Option::is_none));
    }

    #[test]
    fn end_to_end_build_lsh_stars() {
        let ds = synth::gaussian_mixture(400, 16, 8, 0.08, 21);
        let sim = CountingSim::new(CosineSim);
        let family = SimHash::new(16, 8, 5);
        let out = StarsBuilder::new(&ds)
            .similarity(&sim)
            .hash(&family)
            .params(
                crate::stars::BuildParams::threshold_mode(Algorithm::LshStars)
                    .sketches(10)
                    .threshold(0.5),
            )
            .workers(2)
            .build();
        assert!(out.graph.num_edges() > 0);
        assert_eq!(out.report.comparisons, sim.comparisons());
        assert!(out.report.total_time > 0.0);
        assert!(out.report.real_time > 0.0);
        for e in out.graph.edges() {
            assert!(e.w >= 0.5);
        }
    }

    #[test]
    fn end_to_end_build_sorting_stars() {
        let ds = synth::gaussian_mixture(400, 16, 8, 0.08, 22);
        let family = SimHash::new(16, 30, 6);
        let out = StarsBuilder::new(&ds)
            .similarity(&CosineSim)
            .hash(&family)
            .params(
                crate::stars::BuildParams::knn_mode(Algorithm::SortingLshStars)
                    .sketches(8)
                    .window(50)
                    .degree_cap(10),
            )
            .workers(2)
            .build();
        assert!(out.graph.num_edges() > 0);
        let csr = crate::graph::Csr::new(&out.graph);
        // Degree cap semantics: max degree can exceed cap (either-endpoint
        // rule) but must be far below the uncapped worst case.
        assert!(csr.max_degree() < 100, "degree {}", csr.max_degree());
    }

    #[test]
    fn build_indexed_exports_a_matching_snapshot() {
        let ds = synth::gaussian_mixture(400, 16, 8, 0.08, 24);
        let family = SimHash::new(16, 8, 5);
        let (out, index) = StarsBuilder::new(&ds)
            .similarity(&CosineSim)
            .hash(&family)
            .params(
                crate::stars::BuildParams::threshold_mode(Algorithm::LshStars)
                    .sketches(10)
                    .threshold(0.5),
            )
            .workers(2)
            .build_indexed(crate::serve::ServeConfig::default().route_reps(4));
        assert_eq!(index.len(), ds.len());
        assert_eq!(index.csr().num_edges(), out.graph.num_edges());
        // Routing buckets reuse the build's repetition draws: every point's
        // rep-0 key routes to a non-empty entry list containing bucket
        // members that share that key.
        let keys = family.bucket_keys(&ds, 0);
        for p in [0usize, 100, 399] {
            let entries = index.router().route(0, keys[p]);
            assert!(!entries.is_empty(), "point {p} routes nowhere");
            for &e in entries {
                assert_eq!(keys[e as usize], keys[p], "entry outside bucket");
            }
        }
    }

    #[test]
    fn allpair_build_via_builder() {
        let ds = synth::gaussian_mixture(100, 8, 4, 0.1, 23);
        let out = StarsBuilder::new(&ds)
            .similarity(&CosineSim)
            .params(crate::stars::BuildParams::threshold_mode(Algorithm::AllPair))
            .workers(2)
            .build();
        assert_eq!(out.report.comparisons, 100 * 99 / 2);
    }
}
