//! [`StarsBuilder`] — the crate's main entry point.
//!
//! Orchestrates a full graph build: repetitions fan out over the AMPC
//! cluster in waves; each wave's edges fold into a degree-capped
//! accumulator so memory stays bounded at ~n·cap retained edges regardless
//! of R (the paper's degree threshold of 250 applied online).

use crate::ampc::{Cluster, CostReport, Dht};
use crate::data::types::Dataset;
use crate::graph::{Edge, Graph};
use crate::lsh::LshFamily;
use crate::sim::Similarity;
use crate::stars::params::{Algorithm, BuildParams, JoinStrategy};
use crate::stars::{allpair, knn, threshold};
use crate::util::fxhash::FxHashMap;

/// Result of a graph build.
#[derive(Debug)]
pub struct BuildOutput {
    /// The deduplicated, degree-capped similarity graph.
    pub graph: Graph,
    /// Cost report (comparisons, total/real time, I/O).
    pub report: CostReport,
    /// Echo of the parameters used.
    pub params: BuildParams,
}

/// Builder for a Stars graph construction job.
pub struct StarsBuilder<'a> {
    ds: &'a Dataset,
    sim: Option<&'a dyn Similarity>,
    family: Option<&'a dyn LshFamily>,
    params: Option<BuildParams>,
    workers: usize,
}

impl<'a> StarsBuilder<'a> {
    /// Start a build over a dataset.
    pub fn new(ds: &'a Dataset) -> StarsBuilder<'a> {
        StarsBuilder {
            ds,
            sim: None,
            family: None,
            params: None,
            workers: crate::util::pool::default_workers(),
        }
    }

    /// Similarity measure (required).
    pub fn similarity(mut self, sim: &'a dyn Similarity) -> Self {
        self.sim = Some(sim);
        self
    }

    /// LSH family (required unless algorithm is AllPair).
    pub fn hash(mut self, family: &'a dyn LshFamily) -> Self {
        self.family = Some(family);
        self
    }

    /// Build parameters (required).
    pub fn params(mut self, params: BuildParams) -> Self {
        self.params = Some(params);
        self
    }

    /// Worker count for the simulated cluster (default: host cores).
    pub fn workers(mut self, workers: usize) -> Self {
        self.workers = workers.max(1);
        self
    }

    /// Run the build.
    pub fn build(self) -> BuildOutput {
        let params = self.params.expect("params not set");
        let sim = self.sim.expect("similarity not set");
        let cluster = Cluster::new(self.workers);
        let n = self.ds.len();

        let (graph, report) = cluster.run_job(|c| {
            if params.algorithm == Algorithm::AllPair {
                let edges = allpair::allpair_edges(self.ds, sim, params.threshold, c);
                return finalize(n, edges, params.degree_cap);
            }
            let family = self.family.expect("hash family not set");
            let dht_store;
            let dht = match params.join {
                JoinStrategy::Dht => {
                    dht_store = Dht::new(self.ds, c.workers());
                    Some(&dht_store)
                }
                _ => None,
            };
            let mut acc = Accumulator::new(n, params.degree_cap);
            let wave = c.workers().max(1);
            let reps = params.sketches;
            let mut done = 0usize;
            while done < reps {
                let count = wave.min(reps - done);
                let results = c.map_timed(count, |t, ledger| {
                    let rep = (done + t) as u64;
                    match params.algorithm {
                        Algorithm::Lsh | Algorithm::LshStars => {
                            threshold::lsh_rep(self.ds, sim, family, &params, rep, ledger, dht)
                        }
                        Algorithm::SortingLsh | Algorithm::SortingLshStars => {
                            knn::sorting_rep(self.ds, sim, family, &params, rep, ledger)
                        }
                        Algorithm::AllPair => unreachable!(),
                    }
                });
                for edges in results {
                    acc.add(edges);
                }
                done += count;
            }
            acc.finalize()
        });

        BuildOutput {
            graph,
            report,
            params,
        }
    }
}

fn finalize(n: usize, edges: Vec<Edge>, degree_cap: usize) -> Graph {
    let mut acc = Accumulator::new(n, degree_cap);
    acc.add(edges);
    acc.finalize()
}

/// Online degree-capped edge accumulator.
///
/// With `cap == 0` it keeps every (deduplicated) edge. With a cap it keeps,
/// per node, a map of its best neighbors, evicting the weakest once the map
/// exceeds 2·cap — so memory is O(n·cap) while retained edges match "keep
/// the cap most-similar neighbors per node" (an edge survives if either
/// endpoint retains it, matching [`crate::graph::Csr::with_degree_cap`]).
pub struct Accumulator {
    n: usize,
    cap: usize,
    raw: Vec<Edge>,
    per_node: Vec<FxHashMap<u32, f32>>,
}

impl Accumulator {
    /// New accumulator over `n` nodes.
    pub fn new(n: usize, cap: usize) -> Accumulator {
        Accumulator {
            n,
            cap,
            raw: Vec::new(),
            per_node: if cap == 0 {
                Vec::new()
            } else {
                vec![FxHashMap::default(); n]
            },
        }
    }

    /// Fold a batch of edges in.
    pub fn add(&mut self, edges: Vec<Edge>) {
        if self.cap == 0 {
            self.raw.extend(edges);
            return;
        }
        for e in edges {
            self.insert(e.u, e.v, e.w);
            self.insert(e.v, e.u, e.w);
        }
    }

    fn insert(&mut self, node: u32, nbr: u32, w: f32) {
        let map = &mut self.per_node[node as usize];
        let entry = map.entry(nbr).or_insert(f32::MIN);
        if w > *entry {
            *entry = w;
        }
        if map.len() > 2 * self.cap {
            // Evict down to cap: keep the cap strongest neighbors.
            let mut items: Vec<(u32, f32)> = map.drain().collect();
            items.sort_unstable_by(|a, b| b.1.total_cmp(&a.1));
            items.truncate(self.cap);
            map.extend(items);
        }
    }

    /// Produce the final graph.
    pub fn finalize(mut self) -> Graph {
        if self.cap == 0 {
            return Graph::from_edges(self.n, std::mem::take(&mut self.raw));
        }
        let mut edges = Vec::new();
        for (node, map) in self.per_node.iter_mut().enumerate() {
            let mut items: Vec<(u32, f32)> = map.drain().collect();
            if items.len() > self.cap {
                items.sort_unstable_by(|a, b| b.1.total_cmp(&a.1));
                items.truncate(self.cap);
            }
            for (nbr, w) in items {
                edges.push(Edge::new(node as u32, nbr, w));
            }
        }
        Graph::from_edges(self.n, edges)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::lsh::SimHash;
    use crate::sim::{CosineSim, CountingSim};

    #[test]
    fn accumulator_uncapped_keeps_everything() {
        let mut acc = Accumulator::new(5, 0);
        acc.add(vec![Edge::new(0, 1, 0.5), Edge::new(1, 2, 0.6)]);
        acc.add(vec![Edge::new(0, 1, 0.9)]);
        let g = acc.finalize();
        assert_eq!(g.num_edges(), 2);
        assert_eq!(g.edges()[0].w, 0.9); // dedup keeps max
    }

    #[test]
    fn accumulator_caps_degree() {
        let mut acc = Accumulator::new(10, 2);
        // Node 0 sees 6 neighbors with increasing weights.
        acc.add((1..=6).map(|v| Edge::new(0, v, v as f32 / 10.0)).collect());
        let g = acc.finalize();
        let kept: Vec<&Edge> = g.edges().iter().collect();
        // Node 0 keeps its best 2 (5, 6) — but each neighbor also keeps the
        // edge from its own side, and their degree is 1 ≤ cap, so all
        // survive under the either-endpoint rule.
        assert_eq!(kept.len(), 6);
        // Now flood every node: pairwise clique weights distinct.
        let mut acc = Accumulator::new(10, 1);
        let mut edges = Vec::new();
        for i in 0..10u32 {
            for j in (i + 1)..10 {
                edges.push(Edge::new(i, j, (i * 10 + j) as f32 / 100.0));
            }
        }
        acc.add(edges);
        let g = acc.finalize();
        // Each node keeps 1 → at most 10 edges survive.
        assert!(g.num_edges() <= 10, "{} edges", g.num_edges());
    }

    #[test]
    fn eviction_keeps_the_strongest() {
        // Push 99 neighbors of node 0 in increasing weight; survivors must
        // be the heaviest ones despite repeated eviction passes.
        let mut acc = Accumulator::new(200, 2);
        for v in 1..100u32 {
            acc.add(vec![Edge::new(0, v + 1, v as f32 / 100.0)]);
        }
        let g = acc.finalize();
        let best: Vec<f32> = g
            .edges()
            .iter()
            .filter(|e| e.u == 0)
            .map(|e| e.w)
            .collect();
        assert!(best.iter().any(|&w| (w - 0.99).abs() < 1e-6));
    }

    #[test]
    fn end_to_end_build_lsh_stars() {
        let ds = synth::gaussian_mixture(400, 16, 8, 0.08, 21);
        let sim = CountingSim::new(CosineSim);
        let family = SimHash::new(16, 8, 5);
        let out = StarsBuilder::new(&ds)
            .similarity(&sim)
            .hash(&family)
            .params(
                crate::stars::BuildParams::threshold_mode(Algorithm::LshStars)
                    .sketches(10)
                    .threshold(0.5),
            )
            .workers(2)
            .build();
        assert!(out.graph.num_edges() > 0);
        assert_eq!(out.report.comparisons, sim.comparisons());
        assert!(out.report.total_time > 0.0);
        assert!(out.report.real_time > 0.0);
        for e in out.graph.edges() {
            assert!(e.w >= 0.5);
        }
    }

    #[test]
    fn end_to_end_build_sorting_stars() {
        let ds = synth::gaussian_mixture(400, 16, 8, 0.08, 22);
        let family = SimHash::new(16, 30, 6);
        let out = StarsBuilder::new(&ds)
            .similarity(&CosineSim)
            .hash(&family)
            .params(
                crate::stars::BuildParams::knn_mode(Algorithm::SortingLshStars)
                    .sketches(8)
                    .window(50)
                    .degree_cap(10),
            )
            .workers(2)
            .build();
        assert!(out.graph.num_edges() > 0);
        let csr = crate::graph::Csr::new(&out.graph);
        // Degree cap semantics: max degree can exceed cap (either-endpoint
        // rule) but must be far below the uncapped worst case.
        assert!(csr.max_degree() < 100, "degree {}", csr.max_degree());
    }

    #[test]
    fn allpair_build_via_builder() {
        let ds = synth::gaussian_mixture(100, 8, 4, 0.1, 23);
        let out = StarsBuilder::new(&ds)
            .similarity(&CosineSim)
            .params(crate::stars::BuildParams::threshold_mode(Algorithm::AllPair))
            .workers(2)
            .build();
        assert_eq!(out.report.comparisons, 100 * 99 / 2);
    }
}
