//! The Stars graph-building algorithms (paper §3) and their baselines.
//!
//! * [`Algorithm::LshStars`] — Stars 1: LSH bucketing + star graphs per
//!   bucket (approximate threshold graphs / threshold two-hop spanners).
//! * [`Algorithm::Lsh`] — non-Stars baseline: all pairs within each bucket.
//! * [`Algorithm::SortingLshStars`] — Stars 2: SortingLSH windows + star
//!   graphs per window (approximate k-NN two-hop spanners).
//! * [`Algorithm::SortingLsh`] — non-Stars baseline: all pairs per window.
//! * [`Algorithm::AllPair`] — brute force (ground truth / small data only).
//!
//! Entry point: [`StarsBuilder`].

mod params;
mod bucketing;
pub mod threshold;
pub mod knn;
pub mod allpair;
mod builder;

pub use builder::{Accumulator, BuildOutput, StarsBuilder};
pub use bucketing::{group_buckets, sample_leaders, split_oversized};
pub use params::{Algorithm, BuildParams, JoinStrategy};
