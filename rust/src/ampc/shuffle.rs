//! MapReduce-style shuffle join (paper §4, the disk-heavy join).
//!
//! "We require O(Rn) additional disk storage and O(Rn log(Rn)) time to
//! materialize the joined table." The shuffle groups (bucket_key, point_id)
//! records by key via [`terasort_u64`] — the radix digit pipeline shared
//! with SortingLSH's per-repetition sort — charging shuffle bytes; the
//! grouped runs are the LSH buckets handed to the scoring phase.

use super::metrics::CostLedger;
use super::terasort::terasort_u64;
use crate::util::fxhash;
use crate::util::rng::derive_seed;

/// Stream salt separating shuffle-partition corruption draws from DHT ones.
const SHUFFLE_CORRUPT_STREAM: u64 = 0x5_4FFE_CC5A_17;

/// A grouped bucket: the shared key and the member point ids.
#[derive(Clone, Debug, PartialEq)]
pub struct KeyGroup {
    /// Bucket key.
    pub key: u64,
    /// Members (point ids) in arbitrary order.
    pub members: Vec<u32>,
}

/// Order-independent multiset checksum over shuffle records — the same
/// value before and after sorting, so a sorted partition that fails to
/// match the pre-shuffle digest has lost or mangled records in transit.
fn multiset_digest(records: &[(u64, u32)]) -> u64 {
    records.iter().fold(0u64, |acc, &(key, id)| {
        acc.wrapping_add(fxhash::hash_u64(fxhash::combine(key, id as u64)))
    })
}

/// Group `(key, id)` records by key using a distributed-style shuffle sort.
/// Returns groups in ascending key order; within a group, members keep
/// their record order (the radix sort is stable — and the join drivers
/// emit records in ascending id order, so members come out id-ascending).
/// Singleton groups are retained (callers usually skip them — no pairs to
/// score).
///
/// When the ledger's fault plan injects corruption, the sorted output is
/// checksummed against the input's multiset digest and re-sorted on
/// mismatch (re-charging shuffle bytes — a real re-shuffle moves the bytes
/// again). The radix pipeline is stable and deterministic, so the retried
/// result is bit-identical to a clean first pass.
pub fn shuffle_group(
    records: Vec<(u64, u32)>,
    workers: usize,
    ledger: &CostLedger,
    seed: u64,
) -> Vec<KeyGroup> {
    // Phase span: shuffle wall time plus the bytes it moves (including
    // corruption-retry re-shuffles). Observation only.
    let shuffle_span = ledger.phases().enter("shuffle");
    shuffle_span.add_bytes(12 * records.len() as u64);
    let plan = *ledger.faults();
    let check = plan.corrupt_prob > 0.0 && !records.is_empty();
    let want = if check { multiset_digest(&records) } else { 0 };
    // 12 bytes per record: u64 key + u32 id. The stable u64 fast path needs
    // no splitter sampling; the seed keys this partition's corruption
    // stream.
    let mut sorted = terasort_u64(records, workers, 12, |r| r.0, ledger);
    if check {
        let stream = derive_seed(seed, SHUFFLE_CORRUPT_STREAM) ^ want;
        let mut attempt = 0u32;
        loop {
            let mut got = multiset_digest(&sorted);
            if plan.corrupt(stream, attempt) {
                got = !got; // injected: the partition read back wrong
            }
            if got == want {
                break;
            }
            ledger.add_corruption_retry();
            shuffle_span.add_bytes(12 * sorted.len() as u64);
            attempt += 1;
            // Re-shuffle. Sorting the already-sorted records through the
            // same stable pipeline yields the identical permutation a clean
            // first pass produces, so recovery preserves bit-identity.
            sorted = terasort_u64(sorted, workers, 12, |r| r.0, ledger);
        }
    }
    let mut groups: Vec<KeyGroup> = Vec::new();
    for (key, id) in sorted {
        match groups.last_mut() {
            Some(g) if g.key == key => g.members.push(id),
            _ => groups.push(KeyGroup {
                key,
                members: vec![id],
            }),
        }
    }
    groups
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn groups_by_key() {
        let ledger = CostLedger::new(2);
        let groups = shuffle_group(
            vec![(5, 1), (3, 2), (5, 3), (3, 4), (9, 5)],
            2,
            &ledger,
            7,
        );
        assert_eq!(groups.len(), 3);
        assert_eq!(groups[0].key, 3);
        let mut m = groups[0].members.clone();
        m.sort();
        assert_eq!(m, vec![2, 4]);
        assert_eq!(groups[2].key, 9);
        assert_eq!(groups[2].members, vec![5]);
    }

    #[test]
    fn empty_input() {
        let ledger = CostLedger::new(2);
        assert!(shuffle_group(vec![], 2, &ledger, 1).is_empty());
    }

    #[test]
    fn charges_bytes_proportional_to_records() {
        let ledger = CostLedger::new(2);
        let records: Vec<(u64, u32)> = (0..100).map(|i| (i % 10, i as u32)).collect();
        shuffle_group(records, 4, &ledger, 2);
        assert_eq!(ledger.report(0.0).shuffle_bytes, 2 * 12 * 100);
    }

    #[test]
    fn injected_corruption_retries_to_identical_groups() {
        use crate::util::fault::FaultPlan;
        let records: Vec<(u64, u32)> = (0..200).map(|i| (i % 17, i as u32)).collect();
        let clean = {
            let ledger = CostLedger::new(2);
            shuffle_group(records.clone(), 2, &ledger, 42)
        };
        let plan = FaultPlan::parse("seed=8,corrupt=1.0,max_failures=2").unwrap();
        let ledger = CostLedger::with_faults(2, plan);
        let groups = shuffle_group(records, 2, &ledger, 42);
        assert_eq!(groups, clean, "recovery must reproduce the clean grouping");
        let c = ledger.fault_counters();
        assert_eq!(c.corruption_retries, 2, "corrupt=1.0 fires max_failures times");
        // Every retry honestly re-charges the shuffle bytes it re-moves.
        assert_eq!(ledger.report(0.0).shuffle_bytes, 3 * 2 * 12 * 200);
    }

    #[test]
    fn multiset_digest_is_order_independent() {
        let a = vec![(5u64, 1u32), (3, 2), (9, 5)];
        let mut b = a.clone();
        b.reverse();
        assert_eq!(multiset_digest(&a), multiset_digest(&b));
        assert_ne!(multiset_digest(&a), multiset_digest(&a[..2]));
    }
}
