//! MapReduce-style shuffle join (paper §4, the disk-heavy join).
//!
//! "We require O(Rn) additional disk storage and O(Rn log(Rn)) time to
//! materialize the joined table." The shuffle groups (bucket_key, point_id)
//! records by key via [`terasort_u64`] — the radix digit pipeline shared
//! with SortingLSH's per-repetition sort — charging shuffle bytes; the
//! grouped runs are the LSH buckets handed to the scoring phase.

use super::metrics::CostLedger;
use super::terasort::terasort_u64;

/// A grouped bucket: the shared key and the member point ids.
#[derive(Clone, Debug, PartialEq)]
pub struct KeyGroup {
    /// Bucket key.
    pub key: u64,
    /// Members (point ids) in arbitrary order.
    pub members: Vec<u32>,
}

/// Group `(key, id)` records by key using a distributed-style shuffle sort.
/// Returns groups in ascending key order; within a group, members keep
/// their record order (the radix sort is stable — and the join drivers
/// emit records in ascending id order, so members come out id-ascending).
/// Singleton groups are retained (callers usually skip them — no pairs to
/// score).
pub fn shuffle_group(
    records: Vec<(u64, u32)>,
    workers: usize,
    ledger: &CostLedger,
    _seed: u64,
) -> Vec<KeyGroup> {
    // 12 bytes per record: u64 key + u32 id. The stable u64 fast path needs
    // no splitter sampling, so the seed is unused (kept for signature
    // stability with the generic terasort-based join).
    let sorted = terasort_u64(records, workers, 12, |r| r.0, ledger);
    let mut groups: Vec<KeyGroup> = Vec::new();
    for (key, id) in sorted {
        match groups.last_mut() {
            Some(g) if g.key == key => g.members.push(id),
            _ => groups.push(KeyGroup {
                key,
                members: vec![id],
            }),
        }
    }
    groups
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn groups_by_key() {
        let ledger = CostLedger::new(2);
        let groups = shuffle_group(
            vec![(5, 1), (3, 2), (5, 3), (3, 4), (9, 5)],
            2,
            &ledger,
            7,
        );
        assert_eq!(groups.len(), 3);
        assert_eq!(groups[0].key, 3);
        let mut m = groups[0].members.clone();
        m.sort();
        assert_eq!(m, vec![2, 4]);
        assert_eq!(groups[2].key, 9);
        assert_eq!(groups[2].members, vec![5]);
    }

    #[test]
    fn empty_input() {
        let ledger = CostLedger::new(2);
        assert!(shuffle_group(vec![], 2, &ledger, 1).is_empty());
    }

    #[test]
    fn charges_bytes_proportional_to_records() {
        let ledger = CostLedger::new(2);
        let records: Vec<(u64, u32)> = (0..100).map(|i| (i % 10, i as u32)).collect();
        shuffle_group(records, 4, &ledger, 2);
        assert_eq!(ledger.report(0.0).shuffle_bytes, 2 * 12 * 100);
    }
}
