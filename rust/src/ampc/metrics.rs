//! Cost accounting: comparisons, per-worker busy time, shuffle bytes.

use crate::util::json::Json;
use std::sync::atomic::{AtomicU64, Ordering};

/// Shared, thread-safe cost counters for one graph-building job.
#[derive(Debug)]
pub struct CostLedger {
    /// Per-worker busy nanoseconds ("total running time" contributors).
    busy_nanos: Vec<AtomicU64>,
    comparisons: AtomicU64,
    sketch_evals: AtomicU64,
    edges_emitted: AtomicU64,
    shuffle_bytes: AtomicU64,
    dht_lookups: AtomicU64,
    dht_bytes: AtomicU64,
}

impl CostLedger {
    /// Ledger for `workers` workers.
    pub fn new(workers: usize) -> CostLedger {
        CostLedger {
            busy_nanos: (0..workers.max(1)).map(|_| AtomicU64::new(0)).collect(),
            comparisons: AtomicU64::new(0),
            sketch_evals: AtomicU64::new(0),
            edges_emitted: AtomicU64::new(0),
            shuffle_bytes: AtomicU64::new(0),
            dht_lookups: AtomicU64::new(0),
            dht_bytes: AtomicU64::new(0),
        }
    }

    /// Number of workers this ledger tracks.
    pub fn workers(&self) -> usize {
        self.busy_nanos.len()
    }

    /// Charge busy time to a worker.
    #[inline]
    pub fn add_busy(&self, worker: usize, nanos: u64) {
        self.busy_nanos[worker % self.busy_nanos.len()].fetch_add(nanos, Ordering::Relaxed);
    }

    /// Charge busy time from an in-repetition *inner* worker (the spare
    /// cores a wave grants when it has fewer repetitions than machines).
    ///
    /// Accounting model: `Cluster::map_timed` already charges a
    /// repetition's full wall time to one worker slot, and inner worker 0's
    /// span is concurrent with (and bounded by) that wall charge — so only
    /// workers ≥ 1 add machine-seconds. With this, Σ busy reflects the
    /// machine-seconds a real fleet would spend instead of under-reporting
    /// every multi-core repetition as one machine.
    #[inline]
    pub fn add_inner_busy(&self, worker: usize, nanos: u64) {
        if worker > 0 {
            self.add_busy(worker, nanos);
        }
    }

    /// Record `n` pairwise similarity evaluations.
    #[inline]
    pub fn add_comparisons(&self, n: u64) {
        self.comparisons.fetch_add(n, Ordering::Relaxed);
    }

    /// Record `n` LSH sketch evaluations.
    #[inline]
    pub fn add_sketches(&self, n: u64) {
        self.sketch_evals.fetch_add(n, Ordering::Relaxed);
    }

    /// Record `n` emitted edges (pre-dedup).
    #[inline]
    pub fn add_edges(&self, n: u64) {
        self.edges_emitted.fetch_add(n, Ordering::Relaxed);
    }

    /// Record shuffle I/O bytes.
    #[inline]
    pub fn add_shuffle_bytes(&self, n: u64) {
        self.shuffle_bytes.fetch_add(n, Ordering::Relaxed);
    }

    /// Record a DHT lookup of `bytes` payload.
    #[inline]
    pub fn add_dht_lookup(&self, bytes: u64) {
        self.dht_lookups.fetch_add(1, Ordering::Relaxed);
        self.dht_bytes.fetch_add(bytes, Ordering::Relaxed);
    }

    /// Total comparisons so far.
    pub fn comparisons(&self) -> u64 {
        self.comparisons.load(Ordering::Relaxed)
    }

    /// Sum of per-worker busy time, seconds — the paper's "total running
    /// time ... over all machines".
    pub fn total_time(&self) -> f64 {
        self.busy_nanos
            .iter()
            .map(|b| b.load(Ordering::Relaxed))
            .sum::<u64>() as f64
            / 1e9
    }

    /// Immutable snapshot.
    pub fn report(&self, real_time: f64) -> CostReport {
        CostReport {
            workers: self.busy_nanos.len(),
            comparisons: self.comparisons.load(Ordering::Relaxed),
            sketch_evals: self.sketch_evals.load(Ordering::Relaxed),
            edges_emitted: self.edges_emitted.load(Ordering::Relaxed),
            shuffle_bytes: self.shuffle_bytes.load(Ordering::Relaxed),
            dht_lookups: self.dht_lookups.load(Ordering::Relaxed),
            dht_bytes: self.dht_bytes.load(Ordering::Relaxed),
            total_time: self.total_time(),
            real_time,
            simd_backend: crate::util::simd::active().name(),
            snapshot: None,
        }
    }
}

/// Size/memory telemetry of a serving snapshot — router tables, CSR
/// adjacency, cached sketch-state tables. `StarsBuilder::build_indexed`
/// attaches one to its [`CostReport`] so capacity planning is tracked in
/// the same reports as build costs (bytes are heap estimates of the live
/// arrays, not allocator-exact).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct SnapshotStats {
    /// Indexed points.
    pub points: usize,
    /// Undirected star-graph edges in the snapshot CSR.
    pub edges: usize,
    /// Routing repetitions.
    pub router_reps: usize,
    /// Live entry points across all routing tables.
    pub router_entries: usize,
    /// Router heap bytes (entry arrays + key tables).
    pub router_bytes: usize,
    /// CSR heap bytes (offsets + neighbors + weights).
    pub csr_bytes: usize,
    /// Cached sketch-state table bytes (hyperplanes, per-token tables).
    pub state_table_bytes: usize,
    /// Whether the snapshot carries an SQ8 table for quantized first-pass
    /// scoring (`ServeConfig::quantized`).
    pub quantized: bool,
    /// Exact-rescore width multiplier of the quantized path (`c = k ·
    /// rescore_factor` survivors per query); 0 when not quantized.
    pub rescore_factor: usize,
    /// SQ8 table heap bytes (i8 codes + per-row scales); 0 when not
    /// quantized.
    pub quant_bytes: usize,
    /// Bytes per row of the first-pass scoring storage: `dim + 4` (codes
    /// + scale) when quantized, `4 · dim` (the dense f32 row) otherwise —
    /// the ~4× row-storage reduction shows up here.
    pub bytes_per_row: usize,
}

impl SnapshotStats {
    /// JSON object for experiment/serving reports.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("points", Json::from(self.points)),
            ("edges", Json::from(self.edges)),
            ("router_reps", Json::from(self.router_reps)),
            ("router_entries", Json::from(self.router_entries)),
            ("router_bytes", Json::from(self.router_bytes)),
            ("csr_bytes", Json::from(self.csr_bytes)),
            ("state_table_bytes", Json::from(self.state_table_bytes)),
            ("quantized", Json::from(self.quantized)),
            ("rescore_factor", Json::from(self.rescore_factor)),
            ("quant_bytes", Json::from(self.quant_bytes)),
            ("bytes_per_row", Json::from(self.bytes_per_row)),
        ])
    }
}

/// Snapshot of a job's costs — the row schema of the paper's tables.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct CostReport {
    /// Worker count.
    pub workers: usize,
    /// Pairwise similarity evaluations (Figure 1's metric).
    pub comparisons: u64,
    /// LSH sketch evaluations.
    pub sketch_evals: u64,
    /// Edges emitted before dedup.
    pub edges_emitted: u64,
    /// Bytes moved by shuffle joins.
    pub shuffle_bytes: u64,
    /// DHT lookups performed.
    pub dht_lookups: u64,
    /// Bytes served by the DHT.
    pub dht_bytes: u64,
    /// Σ per-worker busy seconds (paper: total running time).
    pub total_time: f64,
    /// Wall-clock seconds (paper: real running time).
    pub real_time: f64,
    /// The SIMD backend the hot kernels dispatched to
    /// (`crate::util::simd::active().name()` — "scalar", "avx2" or "neon";
    /// empty on a defaulted report). Results never depend on it (the
    /// bit-identity contract), but throughput does, so every cost report
    /// records which lanes produced its numbers.
    pub simd_backend: &'static str,
    /// Serving-snapshot telemetry, when the job exported one
    /// (`StarsBuilder::build_indexed`).
    pub snapshot: Option<SnapshotStats>,
}

impl CostReport {
    /// Convert to JSON for experiment reports.
    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("workers", Json::from(self.workers)),
            ("comparisons", Json::from(self.comparisons)),
            ("sketch_evals", Json::from(self.sketch_evals)),
            ("edges_emitted", Json::from(self.edges_emitted)),
            ("shuffle_bytes", Json::from(self.shuffle_bytes)),
            ("dht_lookups", Json::from(self.dht_lookups)),
            ("dht_bytes", Json::from(self.dht_bytes)),
            ("total_time_s", Json::from(self.total_time)),
            ("real_time_s", Json::from(self.real_time)),
            ("simd_backend", Json::from(self.simd_backend)),
        ];
        if let Some(s) = &self.snapshot {
            pairs.push(("snapshot", s.to_json()));
        }
        Json::obj(pairs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let l = CostLedger::new(4);
        l.add_comparisons(10);
        l.add_comparisons(5);
        l.add_busy(0, 1_000_000_000);
        l.add_busy(3, 500_000_000);
        l.add_edges(7);
        l.add_sketches(3);
        l.add_shuffle_bytes(100);
        l.add_dht_lookup(400);
        assert_eq!(l.comparisons(), 15);
        assert!((l.total_time() - 1.5).abs() < 1e-9);
        let r = l.report(2.0);
        assert_eq!(r.comparisons, 15);
        assert_eq!(r.edges_emitted, 7);
        assert_eq!(r.dht_lookups, 1);
        assert_eq!(r.real_time, 2.0);
    }

    #[test]
    fn inner_busy_skips_worker_zero() {
        // Worker 0's span is concurrent with the rep's wall charge; only
        // extra machines add to Σ busy.
        let l = CostLedger::new(4);
        l.add_inner_busy(0, 1_000_000_000);
        assert_eq!(l.total_time(), 0.0);
        l.add_inner_busy(2, 500_000_000);
        assert!((l.total_time() - 0.5).abs() < 1e-9);
    }

    #[test]
    fn worker_index_wraps() {
        let l = CostLedger::new(2);
        l.add_busy(5, 100); // worker 5 % 2 = 1
        assert!(l.total_time() > 0.0);
    }

    #[test]
    fn report_to_json_parses() {
        let l = CostLedger::new(1);
        l.add_comparisons(3);
        let j = l.report(0.1).to_json().to_string();
        let v = crate::util::json::parse(&j).unwrap();
        assert_eq!(v.get("comparisons").unwrap().as_usize().unwrap(), 3);
        // Every report names the lanes that produced it.
        let backend = v.get("simd_backend").unwrap().as_str().unwrap().to_string();
        assert_eq!(backend, crate::util::simd::active().name());
    }
}
